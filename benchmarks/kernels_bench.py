"""Micro-benchmarks for the Pallas kernels (interpret mode on CPU — the
derived column reports correctness vs oracle, not TPU speed) plus the
vectorized-analytics suite. Two machine-readable records come out:
BENCH_kernels.json (per-kernel correctness + interpret-mode timing; the
regression gate bounds the *error*, never the CPU wall time) and
BENCH_analytics.json (loop-vs-batched speedups)."""
from __future__ import annotations

from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit_json, quick, row, timeit
from repro.core.dcov import (
    dcor,
    dcor_all,
    dcor_all_cols,
    dcor_numpy,
    dcor_state_corr,
    dcor_state_from_window,
    dcor_state_push,
)
from repro.kernels.dcov import dcor_all_pallas, dcor_pallas, dcor_ref
from repro.kernels.dcov.dcov import default_interpret
from repro.kernels.flash_attention import attention_ref, flash_attention_bhsd
from repro.kernels.ssd_scan import ssd, ssd_ref

ANALYTICS_JSON = Path(__file__).resolve().parent.parent / "BENCH_analytics.json"
KERNELS_JSON = Path(__file__).resolve().parent.parent / "BENCH_kernels.json"
# CI smoke: fewer timing iterations (QUICK=0/false/empty means full run)
QUICK = quick()


def bench_dcov_kernel(record: dict | None = None):
    rng = np.random.default_rng(0)
    n = 512
    x = jnp.asarray(rng.normal(size=n), jnp.float32)
    y = jnp.asarray(np.asarray(x) ** 2 + rng.normal(size=n) * 0.1, jnp.float32)
    us_pallas = timeit(lambda: dcor_pallas(x, y, block=128).block_until_ready())
    us_ref = timeit(lambda: dcor_ref(x, y).block_until_ready())
    us_core = timeit(lambda: dcor(x, y).block_until_ready())
    err = abs(float(dcor_pallas(x, y)) - float(dcor_ref(x, y)))
    row("dcov_pallas_n512", us_pallas, f"err_vs_ref={err:.1e}")
    row("dcov_ref_n512", us_ref, "materialized n×n oracle")
    row("dcov_core_jnp_n512", us_core, "model-side jnp implementation")
    if record is not None:
        record["dcov_pallas_n512"] = {"us": us_pallas, "err_vs_ref": err}
    # ORACLE-scale: beyond one VMEM tile the auto-blocked kernel (8×8
    # grid of 256-tiles here) must stay correct, not degrade to an
    # oversized single tile. Timing is interpret-mode (correctness gate
    # only); one rep keeps the 64-step grid walk affordable in CI.
    n2 = 2048
    x2 = jnp.asarray(rng.normal(size=n2), jnp.float32)
    y2 = jnp.asarray(np.asarray(x2) ** 2 + rng.normal(size=n2) * 0.1, jnp.float32)
    us2 = timeit(lambda: dcor_pallas(x2, y2).block_until_ready(), iters=1)
    err2 = abs(float(dcor_pallas(x2, y2)) - float(dcor_ref(x2, y2)))
    row("dcov_pallas_n2048", us2, f"err_vs_ref={err2:.1e} (auto block)")
    if record is not None:
        record["dcov_pallas_n2048"] = {"us": us2, "err_vs_ref": err2}


def bench_flash_attention_kernel(record: dict | None = None):
    rng = np.random.default_rng(1)
    b, hq, hkv, s, d = 1, 4, 2, 256, 64
    q = jnp.asarray(rng.normal(size=(b, hq, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    us = timeit(
        lambda: flash_attention_bhsd(q, k, v, block_q=64, block_k=64)
        .block_until_ready(),
        iters=2,
    )
    err = float(
        jnp.max(
            jnp.abs(
                flash_attention_bhsd(q, k, v, block_q=64, block_k=64)
                - attention_ref(q, k, v)
            )
        )
    )
    row("flash_attention_s256", us, f"err_vs_ref={err:.1e} (interpret mode)")
    if record is not None:
        record["flash_attention_s256"] = {"us": us, "err_vs_ref": err}


def bench_ssd_kernel(record: dict | None = None):
    rng = np.random.default_rng(2)
    b, s, nh, hd, n, chunk = 1, 256, 2, 32, 16, 32
    x = jnp.asarray(rng.normal(size=(b, s, nh, hd)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(b, s, nh)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2, size=(nh,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    us = timeit(
        lambda: ssd(x, dt, A, Bm, Cm, chunk=chunk)[0].block_until_ready(), iters=2
    )
    y1, s1 = ssd(x, dt, A, Bm, Cm, chunk=chunk)
    y2, s2 = ssd_ref(x, dt, A, Bm, Cm, chunk=chunk)
    err = float(jnp.max(jnp.abs(y1 - y2)))
    row("ssd_scan_s256", us, f"err_vs_ref={err:.1e} (interpret mode)")
    if record is not None:
        record["ssd_scan_s256"] = {"us": us, "err_vs_ref": err}


def bench_incremental_dcor(record: dict | None = None):
    """Fleet-path windowed dCor: O(W·C) rank-1 ring update + O(C²) readout
    per observation vs the O(W²·C) full recompute (``dcor_all_cols``).
    Both sides are jitted jnp on the same backend, so the speedup ratio is
    machine-stable and gated by check_regression like the other ratios."""
    w, d, m = 64, 5, 2
    c = d + m
    rng = np.random.default_rng(3)
    rows = jnp.asarray(rng.normal(size=(w + 40, c)), jnp.float32)
    n32 = jnp.int32(w)

    full = jax.jit(lambda cols: dcor_all_cols(cols, n32, d))
    push = jax.jit(lambda st, new, slot: dcor_state_push(st, new, slot, n32))
    corr = jax.jit(lambda st: dcor_state_corr(st, n32, d))

    st = {k: v.block_until_ready() for k, v in
          dcor_state_from_window(rows[:w], n32).items()}
    new_row, slot = rows[w], jnp.int32(0)

    def per_step_incremental():
        return corr(push(st, new_row, slot)).block_until_ready()

    def per_step_full():
        return full(rows[:w]).block_until_ready()

    iters = 3 if QUICK else 30
    us_full = timeit(per_step_full, iters=iters)
    us_incr = timeit(per_step_incremental, iters=iters)
    speedup = us_full / max(us_incr, 1e-9)

    # Correctness over a 40-push ring replay (wrap-around included):
    # incremental readout vs a full recompute of the reassembled window.
    win = np.asarray(rows[:w]).copy()
    err = 0.0
    for t in range(40):
        s = (w + t) % w
        st = push(st, rows[w + t], jnp.int32(s))
        win[s] = np.asarray(rows[w + t])
        err = max(err, float(np.abs(
            np.asarray(corr(st)) - np.asarray(full(jnp.asarray(win)))
        ).max()))

    row(
        f"dcor_incremental_W{w}_D{d}",
        us_incr,
        f"full={us_full:.0f}us speedup={speedup:.1f}x err={err:.1e}",
    )
    if record is not None:
        record[f"dcor_incremental_W{w}_D{d}"] = {
            "full_us": us_full,
            "incremental_us": us_incr,
            "speedup": speedup,
            "err_vs_ref": err,
        }


def bench_coral_iteration_overhead():
    """Per-iteration optimizer cost (dCor over the sliding window) — the
    paper's 'lightweight online search' claim."""
    from repro.core import CORAL, tpu_pod_space

    space = tpu_pod_space()
    opt = CORAL(space, tau_target=10.0, p_budget=100.0)
    rng = np.random.default_rng(0)
    for i in range(10):
        cfg = space.random(rng)
        opt.observe(cfg, 10 + rng.random(), 50 + rng.random())
    us = timeit(lambda: opt.correlations(), iters=5)
    row("coral_correlation_step", us, "5 dims × 2 metrics, window=10")
    us2 = timeit(lambda: opt.propose(), iters=5)
    row("coral_propose_step", us2, "Alg-2 + prohibited-set escape")


# ---------------------------------------------------------------------------
# Vectorized-analytics suite — loop-vs-batched timings, recorded to
# BENCH_analytics.json so later PRs can track the perf trajectory.
# ---------------------------------------------------------------------------


def bench_batched_dcor(record: dict | None = None):
    """CORAL's correlation step: 2×D per-pair dcor calls vs one dcor_all."""
    w, d, m = 10, 5, 2
    rng = np.random.default_rng(0)
    settings = rng.normal(size=(w, d)).astype(np.float32)
    metrics = rng.normal(size=(w, m)).astype(np.float32)

    def loop():
        out = np.zeros((d, m), np.float32)
        for i in range(d):
            for j in range(m):
                out[i, j] = dcor_numpy(metrics[:, j], settings[:, i])
        return out

    def batched():
        return np.asarray(
            dcor_all(jnp.asarray(settings), jnp.asarray(metrics), np.int32(w))
        )

    iters = 3 if QUICK else 20
    us_loop = timeit(loop, iters=iters)
    us_batched = timeit(batched, iters=iters)
    err = float(np.abs(loop() - batched()).max())
    speedup = us_loop / max(us_batched, 1e-9)
    row(
        f"dcor_window_W{w}_D{d}",
        us_batched,
        f"loop={us_loop:.0f}us speedup={speedup:.1f}x err={err:.1e}",
    )
    if record is not None:
        record[f"dcor_window_W{w}_D{d}"] = {
            "loop_us": us_loop,
            "batched_us": us_batched,
            "speedup": speedup,
            "max_abs_err": err,
        }


def bench_batched_dcor_pallas(record: dict | None = None):
    """ORACLE-scale batched Gram kernel vs C·(C−1)/2 + C pairwise launches."""
    n, d, m = 512, 5, 2
    rng = np.random.default_rng(1)
    settings = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    metrics = jnp.asarray(rng.normal(size=(n, m)), jnp.float32)

    def pairwise():
        return np.array(
            [
                [float(dcor_pallas(metrics[:, j], settings[:, i], block=128))
                 for j in range(m)]
                for i in range(d)
            ]
        )

    def batched():
        return np.asarray(dcor_all_pallas(settings, metrics, block=128))

    iters = 1 if QUICK else 3
    us_pair = timeit(pairwise, iters=iters)
    us_batched = timeit(batched, iters=iters)
    err = float(np.abs(pairwise() - batched()).max())
    speedup = us_pair / max(us_batched, 1e-9)
    row(
        f"dcor_all_pallas_n{n}",
        us_batched,
        f"pairwise={us_pair:.0f}us speedup={speedup:.1f}x err={err:.1e} "
        "(interpret mode)",
    )
    if record is not None:
        record[f"dcor_all_pallas_n{n}_D{d}_M{m}"] = {
            "pairwise_us": us_pair,
            "batched_us": us_batched,
            "speedup": speedup,
            "max_abs_err": err,
        }


def bench_oracle_vectorized(record: dict | None = None):
    """Exhaustive search on the 2160-config Xavier-NX space: scalar Python
    sweep vs one array-based evaluation."""
    from repro.core import jetson_like_space
    from repro.core.baselines import oracle, oracle_scalar
    from repro.device import jetson_like_simulator

    space = jetson_like_space("xavier_nx")
    dev = jetson_like_simulator(space, 1.0, noise=0.0)
    tau_t = 30.0

    iters = 1 if QUICK else 3
    us_scalar = timeit(lambda: oracle_scalar(space, dev, tau_t), iters=iters)
    us_vec = timeit(lambda: oracle(space, dev, tau_t), iters=iters)
    same = oracle(space, dev, tau_t).config == oracle_scalar(space, dev, tau_t).config
    speedup = us_scalar / max(us_vec, 1e-9)
    row(
        f"oracle_xavier_nx_{space.size()}",
        us_vec,
        f"scalar={us_scalar:.0f}us speedup={speedup:.1f}x same_config={same}",
    )
    if record is not None:
        record[f"oracle_xavier_nx_{space.size()}"] = {
            "scalar_us": us_scalar,
            "vectorized_us": us_vec,
            "speedup": speedup,
            "same_config": bool(same),
        }


def bench_analytics_suite():
    """Run the analytics benches and emit BENCH_analytics.json."""
    record: dict = {}
    bench_batched_dcor(record)
    bench_batched_dcor_pallas(record)
    bench_oracle_vectorized(record)
    payload = {
        "regenerate": "PYTHONPATH=src python -m benchmarks.kernels_bench",
        "results": record,
    }
    emit_json(ANALYTICS_JSON, payload)
    row("analytics_json", 0.0, f"wrote {ANALYTICS_JSON.name}")


def bench_kernels_suite():
    """Run the Pallas-kernel benches and emit BENCH_kernels.json."""
    record: dict = {}
    bench_dcov_kernel(record)
    bench_flash_attention_kernel(record)
    bench_ssd_kernel(record)
    bench_incremental_dcor(record)
    bench_coral_iteration_overhead()
    payload = {
        "regenerate": "PYTHONPATH=src python -m benchmarks.kernels_bench",
        # Timing provenance: interpret-mode CPU numbers (e.g. the ~100ms
        # dcov_pallas_n2048 walk) must never be compared against compiled
        # accelerator numbers — check_regression refuses records whose
        # backend/interpret provenance differs from the baseline's.
        "backend": jax.default_backend(),
        "pallas_interpret": bool(default_interpret()),
        # timing-depth provenance: QUICK runs use 3 timing iterations
        "quick": QUICK,
        "results": record,
    }
    emit_json(KERNELS_JSON, payload)
    row("kernels_json", 0.0, f"wrote {KERNELS_JSON.name}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    bench_kernels_suite()
    bench_analytics_suite()
