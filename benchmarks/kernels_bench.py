"""Micro-benchmarks for the Pallas kernels (interpret mode on CPU — the
derived column reports correctness vs oracle, not TPU speed)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core.dcov import dcor
from repro.kernels.dcov import dcor_pallas, dcor_ref
from repro.kernels.flash_attention import attention_ref, flash_attention_bhsd
from repro.kernels.ssd_scan import ssd, ssd_ref


def bench_dcov_kernel():
    rng = np.random.default_rng(0)
    n = 512
    x = jnp.asarray(rng.normal(size=n), jnp.float32)
    y = jnp.asarray(np.asarray(x) ** 2 + rng.normal(size=n) * 0.1, jnp.float32)
    us_pallas = timeit(lambda: dcor_pallas(x, y, block=128).block_until_ready())
    us_ref = timeit(lambda: dcor_ref(x, y).block_until_ready())
    us_core = timeit(lambda: dcor(x, y).block_until_ready())
    err = abs(float(dcor_pallas(x, y)) - float(dcor_ref(x, y)))
    row("dcov_pallas_n512", us_pallas, f"err_vs_ref={err:.1e}")
    row("dcov_ref_n512", us_ref, "materialized n×n oracle")
    row("dcov_core_jnp_n512", us_core, "model-side jnp implementation")


def bench_flash_attention_kernel():
    rng = np.random.default_rng(1)
    b, hq, hkv, s, d = 1, 4, 2, 256, 64
    q = jnp.asarray(rng.normal(size=(b, hq, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    us = timeit(
        lambda: flash_attention_bhsd(q, k, v, block_q=64, block_k=64)
        .block_until_ready(),
        iters=2,
    )
    err = float(
        jnp.max(
            jnp.abs(
                flash_attention_bhsd(q, k, v, block_q=64, block_k=64)
                - attention_ref(q, k, v)
            )
        )
    )
    row("flash_attention_s256", us, f"err_vs_ref={err:.1e} (interpret mode)")


def bench_ssd_kernel():
    rng = np.random.default_rng(2)
    b, s, nh, hd, n, chunk = 1, 256, 2, 32, 16, 32
    x = jnp.asarray(rng.normal(size=(b, s, nh, hd)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(b, s, nh)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2, size=(nh,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    us = timeit(
        lambda: ssd(x, dt, A, Bm, Cm, chunk=chunk)[0].block_until_ready(), iters=2
    )
    y1, s1 = ssd(x, dt, A, Bm, Cm, chunk=chunk)
    y2, s2 = ssd_ref(x, dt, A, Bm, Cm, chunk=chunk)
    err = float(jnp.max(jnp.abs(y1 - y2)))
    row("ssd_scan_s256", us, f"err_vs_ref={err:.1e} (interpret mode)")


def bench_coral_iteration_overhead():
    """Per-iteration optimizer cost (dCor over the sliding window) — the
    paper's 'lightweight online search' claim."""
    from repro.core import CORAL, tpu_pod_space

    space = tpu_pod_space()
    opt = CORAL(space, tau_target=10.0, p_budget=100.0)
    rng = np.random.default_rng(0)
    for i in range(10):
        cfg = space.random(rng)
        opt.observe(cfg, 10 + rng.random(), 50 + rng.random())
    us = timeit(lambda: opt.correlations(), iters=5)
    row("coral_correlation_step", us, "5 dims × 2 metrics, window=10")
    us2 = timeit(lambda: opt.propose(), iters=5)
    row("coral_propose_step", us2, "Alg-2 + prohibited-set escape")
