"""Sanitizer-lane smoke: run the engine with REPRO_CHECKIFY=1 and
REPRO_CONTRACTS=1 forced on, so CI proves the instrumented executables
stay healthy (no checkify poison, no contract drift) on every push.

Covers the two load-bearing engine paths:

- one dual-constraint static cell (jetson-like space, vmapped seeds);
- the fleet path at FLEET_TWINS twins (default 64 — the CI smoke
  prefix of the nightly 1024-twin fleet).

No JSON is emitted: this is a gate, not a tracked benchmark — the
checkified executables are deliberately not comparable to the plain
engine's telemetry.

    PYTHONPATH=src python -m benchmarks.sanitize_smoke
    FLEET_TWINS=16 PYTHONPATH=src python -m benchmarks.sanitize_smoke
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import row


def force_lanes() -> None:
    """Force both lanes before any engine call builds an executable —
    the lane flags are read at call time and the runner cache is keyed
    on the checkify flag, so this cannot leak a stale executable into
    other entry points."""
    os.environ["REPRO_CHECKIFY"] = "1"
    os.environ["REPRO_CONTRACTS"] = "1"


def smoke_static_cell() -> None:
    from repro.core.episode import run_coral_batch
    from repro.core.evaluate import RegimeTargets
    from repro.core.space import jetson_like_space
    from repro.device import jetson_like_simulator

    space = jetson_like_space()
    sim = jetson_like_simulator(space)
    lt, lp = sim.exact_all()
    # jointly satisfiable dual cell: throughput floor taken from the
    # configs inside the power envelope
    p_budget = float(np.percentile(lp, 70))
    targets = RegimeTargets(
        mode="dual",
        tau_target=float(np.percentile(lt[lp <= p_budget], 50)),
        p_budget=p_budget,
    )
    t0 = time.perf_counter()
    eps = run_coral_batch(space, lt, lp, targets, seeds=(0, 1, 2, 3))
    wall = time.perf_counter() - t0
    ok = sum(
        ep.outcome.feasible(targets.tau_target, targets.p_budget)
        for ep in eps
    )
    row(
        "sanitize_static_dual",
        wall * 1e6 / len(eps),
        f"checkify+contracts clean, feasible={ok}/{len(eps)}",
    )


def smoke_fleet() -> None:
    from repro.experiments.fleet import run_fleet

    n = int(os.environ.get("FLEET_TWINS") or 64)
    t0 = time.perf_counter()
    rec = run_fleet(n_twins=n, seed=0, probe_steady=False)
    wall = time.perf_counter() - t0
    res = rec["results"]
    row(
        f"sanitize_fleet_n{n}",
        wall * 1e6 / n,
        f"checkify+contracts clean, feasible_rate={res['feasible_rate']:.3f}",
    )


if __name__ == "__main__":
    force_lanes()
    print("name,us_per_call,derived")
    smoke_static_cell()
    smoke_fleet()
