"""One benchmark per paper table/figure (§IV), on the Jetson-like device
model (Fig. 1-10, Table 4) and the TPU-pod integration."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timeit
from repro.core import run_coral, jetson_like_space, tpu_pod_space
from repro.core.baselines import alert, alert_online, oracle, preset
from repro.device import jetson_like_simulator

# model-scale analogues of the paper's detectors (20× parameter span):
# (scale, power slack): heavier models leave less headroom (paper §IV-C)
MODELS = {"yolo": (1.0, 1.08), "frcnn": (6.0, 1.03), "retinanet": (12.0, 1.015)}
DEVICES = ("xavier_nx", "orin_nano")


def _setup(device: str, scale: float, seed: int = 0, noise: float = 0.02):
    space = jetson_like_space(device)
    return space, (lambda s=seed, n=noise: jetson_like_simulator(space, scale, seed=s, noise=n))


def bench_fig1_tradeoff():
    """Fig. 1: same-throughput configs span ~2× power; same-power configs
    span a wide throughput range."""
    for device in DEVICES:
        space, mk = _setup(device, 1.0)
        dev = mk(n=0.0)
        subgrid = space.grid()[::5]

        def sweep():
            return dev.exact_all(subgrid)

        us = timeit(sweep, iters=1, warmup=0)
        taus, pows = sweep()
        # iso-throughput power spread
        bins = np.round(taus / (taus.max() * 0.05))
        spreads = [
            pows[bins == b].max() / pows[bins == b].min()
            for b in np.unique(bins)
            if (bins == b).sum() > 5
        ]
        # iso-power throughput spread
        pbins = np.round(pows / (pows.max() * 0.05))
        tspread = [
            taus[pbins == b].max() / taus[pbins == b].min()
            for b in np.unique(pbins)
            if (pbins == b).sum() > 5
        ]
        row(
            f"fig1_tradeoff_{device}", us,
            f"iso_tau_power_spread={max(spreads):.2f}x "
            f"iso_power_tau_spread={max(tspread):.2f}x (paper: ~2x / 40-75fps)",
        )


def _targets(space, mk, tau_frac=0.55, pb_slack=1.08):
    om = oracle(space, mk(n=0.0), tau_target=0.0)
    tau_t = round(om.tau * tau_frac)
    orc_single = oracle(space, mk(n=0.0), tau_t)
    p_budget = orc_single.power * pb_slack
    return tau_t, p_budget, om, orc_single


def bench_fig3_4_single_constraint():
    """Fig. 3/4: single-constraint (throughput target, no power cap)."""
    for device in DEVICES:
        space, mk = _setup(device, 1.0)
        tau_t, _, om, orc = _targets(space, mk)
        ratios = []
        us = timeit(
            lambda: ratios.append(
                run_coral(space, mk(len(ratios)), tau_t, iters=10,
                          seed=len(ratios))[0].tau / orc.tau
            ),
            iters=8, warmup=0,
        )
        mx = preset(space, mk(1), "max_power")
        df = preset(space, mk(2), "default")
        al = alert(space, mk(3), tau_t)
        row(
            f"fig3_4_single_{device}", us,
            f"coral/oracle_tau=[{min(ratios):.2f}..{max(ratios):.2f}] "
            f"alert={al.tau/orc.tau:.2f} max_power={mx.tau/orc.tau:.2f} "
            f"default={df.tau/orc.tau:.2f} (paper: CORAL 96-100%, presets 33-60%)",
        )


def bench_fig5_6_dual_constraint():
    """Fig. 5/6: strict dual constraints (power limit + throughput target)."""
    for device in DEVICES:
        space, mk = _setup(device, 1.0)
        tau_t, p_b, om, orc = _targets(space, mk)
        orc_dual = oracle(space, mk(n=0.0), tau_t, p_b)
        feas, effs = 0, []
        for seed in range(8):
            out, _ = run_coral(space, mk(seed), tau_t, p_b, iters=10, seed=seed)
            feas += out.feasible(tau_t, p_b)
            if out.feasible(tau_t, p_b):
                effs.append(out.efficiency / orc_dual.efficiency)
        al = alert(space, mk(9), tau_t, p_b)
        alo = alert_online(space, mk(10), tau_t, p_b)
        mx = preset(space, mk(11), "max_power")
        df = preset(space, mk(12), "default")
        row(
            f"fig5_6_dual_{device}", 0.0,
            f"coral_feasible={feas}/8 coral_eff/oracle={np.mean(effs):.2f} "
            f"alert_power={al.power:.1f}W(budget={p_b:.1f}) "
            f"alert_online_found={alo.config is not None} "
            f"max_power_feasible={mx.feasible(tau_t,p_b)} "
            f"default_feasible={df.feasible(tau_t,p_b)} "
            "(paper: CORAL meets both; ALERT busts budget; others fail)",
        )


def bench_fig7_10_generalization():
    """Fig. 7-10: generalization across model scales (FRCNN, RETINANET)."""
    for device in DEVICES:
        for model, (scale, slack) in MODELS.items():
            if model == "yolo":
                continue  # covered by fig5/6
            space, mk = _setup(device, scale)
            tau_t, p_b, om, orc = _targets(space, mk, pb_slack=slack)
            feas = 0
            for seed in range(6):
                out, _ = run_coral(space, mk(seed), tau_t, p_b, iters=10, seed=seed)
                feas += out.feasible(tau_t, p_b)
            al = alert(space, mk(7), tau_t, p_b)
            alo = alert_online(space, mk(8), tau_t, p_b)
            row(
                f"fig7_10_{model}_{device}", 0.0,
                f"coral_feasible={feas}/6 alert_feasible={al.feasible(tau_t,p_b)} "
                f"alert_online_found={alo.config is not None} "
                "(paper: gap grows with model size; baselines fail)",
            )


def bench_table4_space_sizes():
    """Table 4: evaluated configuration-space sizes."""
    for device, paper_n in (("xavier_nx", 2160), ("orin_nano", 1600)):
        n = jetson_like_space(device).size()
        row(f"table4_space_{device}", 0.0,
            f"grid={n} (paper_total={paper_n}; paper prunes failed configs)")
    row("table4_space_tpu_pod", 0.0, f"grid={tpu_pod_space().size()}")


def bench_iteration_budget():
    """§III-B: convergence within the 10-iteration budget vs ORACLE cost."""
    space, mk = _setup("xavier_nx", 1.0)
    tau_t, p_b, om, orc = _targets(space, mk)
    dev = mk(0)
    out, _ = run_coral(space, dev, tau_t, p_b, iters=10)
    row(
        "iteration_budget", 0.0,
        f"coral_measurements={dev.n_measurements} "
        f"oracle_measurements={space.size()} "
        f"speedup={space.size()/dev.n_measurements:.0f}x",
    )
