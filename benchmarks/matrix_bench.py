"""Scenario-matrix benchmark: devices × models × workloads × regimes.

Runs CORAL + all baselines through every cell (EXPERIMENTS.md §Scenario
matrix), writes the schema-validated BENCH_matrix.json plus the
BENCH_matrix.md summary table, and enforces the acceptance gates:
every single-target cell ≥ 0.9 normalized-vs-oracle and zero power-budget
violations in dual-constraint cells.

    PYTHONPATH=src python -m benchmarks.matrix_bench          # full grid
    QUICK=1 PYTHONPATH=src python -m benchmarks.matrix_bench  # CI smoke
"""
from __future__ import annotations

import time
from pathlib import Path

from benchmarks.common import emit_json, quick, row

MATRIX_JSON = Path(__file__).resolve().parent.parent / "BENCH_matrix.json"
MATRIX_MD = MATRIX_JSON.with_suffix(".md")

SINGLE_TARGET_SCORE_GATE = 0.9


def bench_matrix_suite():
    from repro.experiments import (
        REGIMES,
        enumerate_cells,
        markdown_report,
        run_matrix,
        validate_matrix_record,
    )
    from repro.experiments.scenarios import FULL_MATRIX_WORKLOADS

    QUICK = quick()
    # QUICK trims the workload axis only — iters/seeds stay identical, so
    # the cells both modes run produce identical scores and the committed
    # full-grid baseline gates the CI smoke run cell-for-cell.
    cells = enumerate_cells() if QUICK else enumerate_cells(
        workloads=FULL_MATRIX_WORKLOADS
    )
    regenerate = ("QUICK=1 " if QUICK else "") + (
        "PYTHONPATH=src python -m benchmarks.matrix_bench"
    )
    t0 = time.perf_counter()
    record = run_matrix(
        cells, iters=10, seeds=(0, 1, 2), regenerate=regenerate, quick=QUICK
    )
    elapsed_us = (time.perf_counter() - t0) * 1e6
    validate_matrix_record(record)
    emit_json(MATRIX_JSON, record)
    MATRIX_MD.write_text(markdown_report(record))

    s = record["summary"]
    row(
        "matrix_grid",
        elapsed_us,
        f"cells={s['n_cells']} mean_score={s['mean_coral_score']:.3f}",
    )
    for regime in record["grid"]["regimes"]:
        cell_scores = [
            c["coral"]["score"] for c in record["cells"] if c["regime"] == regime
        ]
        row(
            f"matrix_{regime}",
            0.0,
            f"worst_cell={min(cell_scores):.3f} "
            f"mean={sum(cell_scores) / len(cell_scores):.3f}",
        )

    failures = []
    for c in record["cells"]:
        if REGIMES[c["regime"]].single_target:
            if c["coral"]["score"] < SINGLE_TARGET_SCORE_GATE:
                failures.append(
                    f"single-target cell {c['device']}/{c['model']}/"
                    f"{c['workload']}/{c['regime']} scored "
                    f"{c['coral']['score']:.3f} < {SINGLE_TARGET_SCORE_GATE}"
                )
    if s["dual_power_violations"]:
        failures.append(
            f"{s['dual_power_violations']} power-budget violations in "
            "dual-constraint cells (gate: 0)"
        )
    if failures:
        raise RuntimeError("; ".join(failures))
    return record


def main() -> None:
    print("name,us_per_call,derived")
    bench_matrix_suite()


if __name__ == "__main__":
    main()
