"""Scenario-matrix benchmark: devices × models × workloads × regimes.

Runs CORAL + all baselines through every cell (EXPERIMENTS.md §Scenario
matrix), writes the schema-validated BENCH_matrix.json plus the
BENCH_matrix.md summary table, and enforces the acceptance gates:
every single-target cell ≥ 0.9 normalized-vs-oracle, zero power-budget
violations in dual-constraint cells, every edge↔pod offload cell ≥ 0.85
of the batched joint oracle with zero power violations and zero
feasible presets/ablations (EXPERIMENTS.md §Offload), every multi-tenant
cotenant cell ≥ 0.85 of the joint oracle with zero shared-rail
violations and every preset + the per-tenant-greedy combination
infeasible (EXPERIMENTS.md §Multi-tenant), every fault-injection cell
≥ 0.85 of the fault-free oracle for hardened CORAL with zero power
violations while the non-hardened ablation ends infeasible or violating
on every (cell, seed) run (EXPERIMENTS.md §Fault tolerance), and (full
runs) the compiled
episode engine ≥ 10×/5× over the scalar episode loops on the
static/drift grids — both layers measured best-of-N on identical
inputs, compile time reported separately (``episode_engine.compile_s``;
the CI compilation cache amortizes it across runs).

    PYTHONPATH=src python -m benchmarks.matrix_bench          # full grid
    QUICK=1 PYTHONPATH=src python -m benchmarks.matrix_bench  # CI smoke
"""
from __future__ import annotations

import time
from pathlib import Path

from benchmarks.common import emit_json, quick, row

MATRIX_JSON = Path(__file__).resolve().parent.parent / "BENCH_matrix.json"
MATRIX_MD = MATRIX_JSON.with_suffix(".md")

SINGLE_TARGET_SCORE_GATE = 0.9
# Compiled-vs-scalar episode-engine wall-clock gates (full runs only —
# the trimmed QUICK grid under-amortizes the batch and is not gated).
# The in-bench assert allows the same 25% measurement slack the
# regression gate uses everywhere for timing ratios: the committed
# record demonstrates the full target, while a uniformly slower runner
# generation measuring 9.x can't flip the nightly red without a real
# regression (check_regression separately holds fresh runs to 75% of
# max(baseline, gate)).
EPISODE_STATIC_SPEEDUP_GATE = 10.0
EPISODE_DRIFT_SPEEDUP_GATE = 5.0
EPISODE_SPEEDUP_SLACK = 0.75


def bench_episode_engine(cells, iters=10, seeds=(0, 1, 2), reps=3) -> dict:
    """Time the episode *layer* (the CORAL control loops) compiled vs
    scalar on identical inputs: same landscapes, same noise streams,
    same targets. Best-of-``reps`` per side — both layers run in-process
    back to back, so machine noise hits them symmetrically. The first
    compiled call carries jit compilation; its overhang above the warm
    best is reported as ``compile_s``."""
    from repro.core.episode import run_drift_requests, run_static_requests
    from repro.experiments.matrix import (
        _drift_requests,
        _prep_cell,
        _prep_drift_cell,
        _scalar_drift_runs,
        _scalar_static_runs,
        _static_requests,
    )
    from repro.experiments.scenarios import DRIFT_INTERVALS, REGIMES

    static_cells = [c for c in cells if not REGIMES[c.regime].dynamic]
    dynamic_cells = [c for c in cells if REGIMES[c.regime].dynamic]

    def interleaved_best(compiled_fn, scalar_fn):
        """Best-of-``reps`` for both sides, alternating compiled/scalar
        each rep so a load spike on a noisy runner hits both layers
        rather than skewing the ratio one way."""
        compiled_times, scalar_times = [], []
        for _ in range(reps):
            t0 = time.perf_counter()
            compiled_fn()
            compiled_times.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            scalar_fn()
            scalar_times.append(time.perf_counter() - t0)
        return min(compiled_times), min(scalar_times)

    preps = {c: _prep_cell(c) for c in static_cells}
    reqs = [r for c in static_cells for r in _static_requests(preps[c], seeds)]
    t0 = time.perf_counter()
    run_static_requests(reqs, iters=iters)
    cold_static = time.perf_counter() - t0

    def scalar_static():
        for c in static_cells:
            _scalar_static_runs(c, preps[c], seeds, iters, 10)

    compiled_static, scalar_static_s = interleaved_best(
        lambda: run_static_requests(reqs, iters=iters), scalar_static
    )

    dpreps = {c: _prep_drift_cell(c, DRIFT_INTERVALS) for c in dynamic_cells}
    dreqs = [
        r
        for c in dynamic_cells
        for adaptive in (True, False)
        for r in _drift_requests(dpreps[c], seeds, adaptive)
    ]
    t0 = time.perf_counter()
    run_drift_requests(dreqs, intervals=DRIFT_INTERVALS)
    cold_drift = time.perf_counter() - t0

    def scalar_drift():
        for c in dynamic_cells:
            for adaptive in (True, False):
                _scalar_drift_runs(
                    c, dpreps[c], seeds, adaptive, DRIFT_INTERVALS, 10, 10
                )

    compiled_drift, scalar_drift_s = interleaved_best(
        lambda: run_drift_requests(dreqs, intervals=DRIFT_INTERVALS),
        scalar_drift,
    )

    return {
        "static": {
            "scalar_s": round(scalar_static_s, 4),
            "compiled_s": round(compiled_static, 4),
            "speedup": round(scalar_static_s / max(compiled_static, 1e-9), 2),
        },
        "drift": {
            "scalar_s": round(scalar_drift_s, 4),
            "compiled_s": round(compiled_drift, 4),
            "speedup": round(scalar_drift_s / max(compiled_drift, 1e-9), 2),
        },
        "compile_s": round(
            max(cold_static - compiled_static, 0.0)
            + max(cold_drift - compiled_drift, 0.0),
            4,
        ),
    }


def bench_matrix_suite():
    from repro.experiments import (
        COTENANT_CORAL_GATE,
        DRIFT_ADAPTIVE_GATE,
        DRIFT_SEPARATION,
        DRIFT_STATIC_CEILING,
        FAULT_CORAL_GATE,
        OFFLOAD_CORAL_GATE,
        REGIMES,
        enumerate_cells,
        markdown_report,
        run_matrix,
        validate_matrix_record,
    )
    from repro.experiments.scenarios import (
        FULL_MATRIX_WORKLOADS,
        MATRIX_COTENANT_CELLS,
        MATRIX_DRIFT_CELLS,
        MATRIX_FAULT_CELLS,
        MATRIX_OFFLOAD_CELLS,
        QUICK_COTENANT_CELLS,
        QUICK_DRIFT_CELLS,
        QUICK_FAULT_CELLS,
        QUICK_OFFLOAD_CELLS,
    )

    QUICK = quick()
    # QUICK trims the workload axis, the drift grid (one cell per
    # dynamic regime) and the offload grid (one cell per network class)
    # — iters/seeds stay identical, so the cells both modes run produce
    # identical scores and the committed full-grid baseline gates the CI
    # smoke run cell-for-cell.
    if QUICK:
        cells = enumerate_cells() + list(QUICK_DRIFT_CELLS)
        offload_cells = QUICK_OFFLOAD_CELLS
        cotenant_cells = QUICK_COTENANT_CELLS
        fault_cells = QUICK_FAULT_CELLS
    else:
        cells = enumerate_cells(workloads=FULL_MATRIX_WORKLOADS) + list(
            MATRIX_DRIFT_CELLS
        )
        offload_cells = MATRIX_OFFLOAD_CELLS
        cotenant_cells = MATRIX_COTENANT_CELLS
        fault_cells = MATRIX_FAULT_CELLS
    regenerate = ("QUICK=1 " if QUICK else "") + (
        "PYTHONPATH=src python -m benchmarks.matrix_bench"
    )
    # speedup probe first: its cold compiled call carries (and reports)
    # the jit compilation, so the record's own wall_clock_s runs warm
    engine_probe = bench_episode_engine(cells, reps=2 if QUICK else 4)
    t0 = time.perf_counter()
    record = run_matrix(
        cells,
        iters=10,
        seeds=(0, 1, 2),
        regenerate=regenerate,
        quick=QUICK,
        offload_cells=offload_cells,
        cotenant_cells=cotenant_cells,
        fault_cells=fault_cells,
    )
    elapsed_us = (time.perf_counter() - t0) * 1e6
    record["episode_engine"] = engine_probe
    validate_matrix_record(record)
    emit_json(MATRIX_JSON, record)
    MATRIX_MD.write_text(markdown_report(record))

    s = record["summary"]
    row(
        "matrix_grid",
        elapsed_us,
        f"cells={s['n_cells']} mean_score={s['mean_coral_score']:.3f}",
    )
    for kind in ("static", "drift"):
        e = engine_probe[kind]
        row(
            f"episode_engine_{kind}",
            e["compiled_s"] * 1e6,
            f"scalar={e['scalar_s']:.3f}s speedup={e['speedup']:.1f}x "
            f"(compile={engine_probe['compile_s']:.1f}s, amortized by the "
            "persistent jit cache)",
        )
    for regime in record["grid"]["regimes"]:
        cell_scores = [
            c["coral"]["score"] for c in record["cells"] if c["regime"] == regime
        ]
        if not cell_scores:
            continue  # dynamic regimes report below
        row(
            f"matrix_{regime}",
            0.0,
            f"worst_cell={min(cell_scores):.3f} "
            f"mean={sum(cell_scores) / len(cell_scores):.3f}",
        )
    for c in record["drift_cells"]:
        rec = c["adaptive"]["recovery_intervals"]
        row(
            f"drift_{c['regime']}_{c['device']}_{c['model']}",
            0.0,
            f"adaptive={c['adaptive']['final_score']:.3f} "
            f"static={c['static']['final_score']:.3f} "
            f"recovery={'—' if rec is None else f'{rec:.1f}'}",
        )
    for c in record["offload_cells"]:
        row(
            f"offload_{c['regime']}_{c['device']}_{c['model']}",
            0.0,
            f"coral={c['coral']['score']:.3f} "
            f"demand={c['offload']['demand']:.1f} "
            f"edge_max={c['offload']['edge_only_max']:.1f}",
        )
    for c in record["cotenant_cells"]:
        floors = "+".join(
            f"{t['floor']:.0f}" for t in c["cotenant"]["tenants"]
        )
        g = c["cotenant"]["greedy"]
        greedy_feasible = not (g["violates_tau"] or g["violates_power"])
        row(
            f"cotenant_{c['regime']}_{c['device']}_{c['model']}",
            0.0,
            f"coral={c['coral']['score']:.3f} floors={floors} "
            f"greedy_feasible={greedy_feasible}",
        )
    for c in record["fault_cells"]:
        a = c["ablation"]
        row(
            f"fault_{c['regime']}_{c['device']}_{c['model']}",
            0.0,
            f"hardened={c['hardened']['score']:.3f} "
            f"ablation_failed={a['failed_runs']}/{a['n_runs']} "
            f"fallback={c['hardened']['fallback_intervals']:.1f}",
        )

    failures = []
    for c in record["cells"]:
        if REGIMES[c["regime"]].single_target:
            if c["coral"]["score"] < SINGLE_TARGET_SCORE_GATE:
                failures.append(
                    f"single-target cell {c['device']}/{c['model']}/"
                    f"{c['workload']}/{c['regime']} scored "
                    f"{c['coral']['score']:.3f} < {SINGLE_TARGET_SCORE_GATE}"
                )
    if s["dual_power_violations"]:
        failures.append(
            f"{s['dual_power_violations']} power-budget violations in "
            "dual-constraint cells (gate: 0)"
        )
    # Dynamic-regime acceptance: on every drift cell the adaptive loop
    # must reach the post-shift oracle while the static one-shot ablation
    # demonstrably does not — and the gap must be decisive.
    for c in record["drift_cells"]:
        name = f"{c['device']}/{c['model']}/{c['regime']}"
        a = c["adaptive"]["final_score"]
        st = c["static"]["final_score"]
        if a < DRIFT_ADAPTIVE_GATE:
            failures.append(
                f"drift cell {name}: adaptive post-shift score {a:.3f} < "
                f"{DRIFT_ADAPTIVE_GATE}"
            )
        if st > DRIFT_STATIC_CEILING:
            failures.append(
                f"drift cell {name}: static ablation scored {st:.3f} > "
                f"{DRIFT_STATIC_CEILING} (drift did not break one-shot tuning)"
            )
        if a - st < DRIFT_SEPARATION:
            failures.append(
                f"drift cell {name}: adaptive-static separation "
                f"{a - st:.3f} < {DRIFT_SEPARATION}"
            )
    # Offload-regime acceptance (EXPERIMENTS.md §Offload): CORAL must
    # hold ≥ OFFLOAD_CORAL_GATE of the batched joint-space oracle on
    # every cell with zero true power violations, while every static
    # preset and the no-offload ablation stays infeasible — the offload
    # knob must be demonstrably necessary, not merely available.
    for c in record["offload_cells"]:
        name = f"{c['device']}/{c['model']}/{c['regime']}"
        if c["coral"]["score"] < OFFLOAD_CORAL_GATE:
            failures.append(
                f"offload cell {name}: CORAL joint-space score "
                f"{c['coral']['score']:.3f} < {OFFLOAD_CORAL_GATE}"
            )
    if s.get("offload_power_violations"):
        failures.append(
            f"{s['offload_power_violations']} power-budget violations in "
            "offload cells (gate: 0)"
        )
    if s.get("offload_feasible_baselines"):
        failures.append(
            f"{s['offload_feasible_baselines']} offload presets/ablations "
            "were feasible (gate: 0 — demand must break the un-offloaded "
            "edge and the power cap must break the all-hi preset)"
        )
    # Multi-tenant acceptance (EXPERIMENTS.md §Multi-tenant): CORAL must
    # hold ≥ COTENANT_CORAL_GATE of the batched joint oracle on every
    # cotenant cell with zero shared-rail violations, while every static
    # preset and the per-tenant-greedy combination miss a floor or bust
    # the cap — joint negotiation must be demonstrably necessary.
    for c in record["cotenant_cells"]:
        name = f"{c['device']}/{c['model']}/{c['regime']}"
        if c["coral"]["score"] < COTENANT_CORAL_GATE:
            failures.append(
                f"cotenant cell {name}: CORAL joint-space score "
                f"{c['coral']['score']:.3f} < {COTENANT_CORAL_GATE}"
            )
    if s.get("cotenant_power_violations"):
        failures.append(
            f"{s['cotenant_power_violations']} shared-rail power "
            "violations in cotenant cells (gate: 0)"
        )
    if s.get("cotenant_feasible_baselines"):
        failures.append(
            f"{s['cotenant_feasible_baselines']} cotenant presets/greedy "
            "combinations were feasible (gate: 0 — the floors must force "
            "joint slot/DVFS negotiation)"
        )
    # Fault-tolerance acceptance (EXPERIMENTS.md §Fault tolerance):
    # hardened CORAL must hold ≥ FAULT_CORAL_GATE of the fault-free
    # oracle on every fault cell with zero true power violations, while
    # every non-hardened ablation run ends infeasible or violating —
    # the ingest gate / watchdog / actuation readback must be
    # demonstrably necessary, not merely present.
    for c in record["fault_cells"]:
        name = f"{c['device']}/{c['model']}/{c['regime']}"
        if c["hardened"]["score"] < FAULT_CORAL_GATE:
            failures.append(
                f"fault cell {name}: hardened score "
                f"{c['hardened']['score']:.3f} < {FAULT_CORAL_GATE}"
            )
    if s.get("fault_power_violations"):
        failures.append(
            f"{s['fault_power_violations']} power-budget violations in "
            "hardened fault cells (gate: 0)"
        )
    if s.get("fault_feasible_ablations"):
        failures.append(
            f"{s['fault_feasible_ablations']} non-hardened ablation runs "
            "ended feasible under fault injection (gate: 0 — the faults "
            "must break the raw-ingest path)"
        )
    # Episode-engine wall-clock acceptance (full grid only: the trimmed
    # QUICK batch under-amortizes the compiled call). A miss triggers
    # one deeper re-probe before failing — small wall-clock gates on
    # shared runners see transient load spikes that a second interleaved
    # best-of measurement reliably rides out.
    if not QUICK:
        gates = (
            ("static", EPISODE_STATIC_SPEEDUP_GATE),
            ("drift", EPISODE_DRIFT_SPEEDUP_GATE),
        )
        for extra_reps in (5, 7):
            if all(engine_probe[k]["speedup"] >= g for k, g in gates):
                break
            reprobe = bench_episode_engine(cells, reps=extra_reps)
            for kind in ("static", "drift"):
                if reprobe[kind]["speedup"] > engine_probe[kind]["speedup"]:
                    engine_probe[kind] = reprobe[kind]
            record["episode_engine"] = engine_probe
            emit_json(MATRIX_JSON, record)
        for kind, gate in gates:
            got = engine_probe[kind]["speedup"]
            if got < EPISODE_SPEEDUP_SLACK * gate:
                failures.append(
                    f"episode engine: {kind} compiled-vs-scalar speedup "
                    f"{got:.1f}x < {EPISODE_SPEEDUP_SLACK * gate:.1f}x "
                    f"({EPISODE_SPEEDUP_SLACK:.0%} of the {gate:.0f}x target)"
                )
    if failures:
        raise RuntimeError("; ".join(failures))
    return record


def main() -> None:
    print("name,us_per_call,derived")
    bench_matrix_suite()


if __name__ == "__main__":
    main()
