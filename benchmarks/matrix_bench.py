"""Scenario-matrix benchmark: devices × models × workloads × regimes.

Runs CORAL + all baselines through every cell (EXPERIMENTS.md §Scenario
matrix), writes the schema-validated BENCH_matrix.json plus the
BENCH_matrix.md summary table, and enforces the acceptance gates:
every single-target cell ≥ 0.9 normalized-vs-oracle and zero power-budget
violations in dual-constraint cells.

    PYTHONPATH=src python -m benchmarks.matrix_bench          # full grid
    QUICK=1 PYTHONPATH=src python -m benchmarks.matrix_bench  # CI smoke
"""
from __future__ import annotations

import time
from pathlib import Path

from benchmarks.common import emit_json, quick, row

MATRIX_JSON = Path(__file__).resolve().parent.parent / "BENCH_matrix.json"
MATRIX_MD = MATRIX_JSON.with_suffix(".md")

SINGLE_TARGET_SCORE_GATE = 0.9


def bench_matrix_suite():
    from repro.experiments import (
        DRIFT_ADAPTIVE_GATE,
        DRIFT_SEPARATION,
        DRIFT_STATIC_CEILING,
        REGIMES,
        enumerate_cells,
        markdown_report,
        run_matrix,
        validate_matrix_record,
    )
    from repro.experiments.scenarios import (
        FULL_MATRIX_WORKLOADS,
        MATRIX_DRIFT_CELLS,
        QUICK_DRIFT_CELLS,
    )

    QUICK = quick()
    # QUICK trims the workload axis and the drift grid (one cell per
    # dynamic regime) — iters/seeds stay identical, so the cells both
    # modes run produce identical scores and the committed full-grid
    # baseline gates the CI smoke run cell-for-cell.
    if QUICK:
        cells = enumerate_cells() + list(QUICK_DRIFT_CELLS)
    else:
        cells = enumerate_cells(workloads=FULL_MATRIX_WORKLOADS) + list(
            MATRIX_DRIFT_CELLS
        )
    regenerate = ("QUICK=1 " if QUICK else "") + (
        "PYTHONPATH=src python -m benchmarks.matrix_bench"
    )
    t0 = time.perf_counter()
    record = run_matrix(
        cells, iters=10, seeds=(0, 1, 2), regenerate=regenerate, quick=QUICK
    )
    elapsed_us = (time.perf_counter() - t0) * 1e6
    validate_matrix_record(record)
    emit_json(MATRIX_JSON, record)
    MATRIX_MD.write_text(markdown_report(record))

    s = record["summary"]
    row(
        "matrix_grid",
        elapsed_us,
        f"cells={s['n_cells']} mean_score={s['mean_coral_score']:.3f}",
    )
    for regime in record["grid"]["regimes"]:
        cell_scores = [
            c["coral"]["score"] for c in record["cells"] if c["regime"] == regime
        ]
        if not cell_scores:
            continue  # dynamic regimes report below
        row(
            f"matrix_{regime}",
            0.0,
            f"worst_cell={min(cell_scores):.3f} "
            f"mean={sum(cell_scores) / len(cell_scores):.3f}",
        )
    for c in record["drift_cells"]:
        rec = c["adaptive"]["recovery_intervals"]
        row(
            f"drift_{c['regime']}_{c['device']}_{c['model']}",
            0.0,
            f"adaptive={c['adaptive']['final_score']:.3f} "
            f"static={c['static']['final_score']:.3f} "
            f"recovery={'—' if rec is None else f'{rec:.1f}'}",
        )

    failures = []
    for c in record["cells"]:
        if REGIMES[c["regime"]].single_target:
            if c["coral"]["score"] < SINGLE_TARGET_SCORE_GATE:
                failures.append(
                    f"single-target cell {c['device']}/{c['model']}/"
                    f"{c['workload']}/{c['regime']} scored "
                    f"{c['coral']['score']:.3f} < {SINGLE_TARGET_SCORE_GATE}"
                )
    if s["dual_power_violations"]:
        failures.append(
            f"{s['dual_power_violations']} power-budget violations in "
            "dual-constraint cells (gate: 0)"
        )
    # Dynamic-regime acceptance: on every drift cell the adaptive loop
    # must reach the post-shift oracle while the static one-shot ablation
    # demonstrably does not — and the gap must be decisive.
    for c in record["drift_cells"]:
        name = f"{c['device']}/{c['model']}/{c['regime']}"
        a = c["adaptive"]["final_score"]
        st = c["static"]["final_score"]
        if a < DRIFT_ADAPTIVE_GATE:
            failures.append(
                f"drift cell {name}: adaptive post-shift score {a:.3f} < "
                f"{DRIFT_ADAPTIVE_GATE}"
            )
        if st > DRIFT_STATIC_CEILING:
            failures.append(
                f"drift cell {name}: static ablation scored {st:.3f} > "
                f"{DRIFT_STATIC_CEILING} (drift did not break one-shot tuning)"
            )
        if a - st < DRIFT_SEPARATION:
            failures.append(
                f"drift cell {name}: adaptive-static separation "
                f"{a - st:.3f} < {DRIFT_SEPARATION}"
            )
    if failures:
        raise RuntimeError("; ".join(failures))
    return record


def main() -> None:
    print("name,us_per_call,derived")
    bench_matrix_suite()


if __name__ == "__main__":
    main()
