"""Fleet-scale bench: tune N heterogeneous device twins in one compiled
``jit(vmap(scan))`` call and emit BENCH_fleet.json.

Twin count: FLEET_TWINS env override, else 64 in QUICK mode (CI smoke),
else 1024 (the paper-scale nightly fleet). Because twin ``i`` is sampled
from ``default_rng([seed, i])`` independently of the fleet size, the
smoke fleet is an exact prefix of the nightly fleet — floors calibrated
on one transfer to the other.

The ``results`` block of the record is deterministic for a given
(n_twins, seed, iters, window); the ``engine`` block is wall-clock and
memory telemetry for the machine that produced it (never gated on
absolute time — benchmarks/check_regression.py gates the deterministic
quality metrics and the warm-start gain ratio only).

    PYTHONPATH=src python -m benchmarks.fleet_bench
    QUICK=1 PYTHONPATH=src python -m benchmarks.fleet_bench
    FLEET_TWINS=256 PYTHONPATH=src python -m benchmarks.fleet_bench
"""
from __future__ import annotations

import os
from pathlib import Path

from benchmarks.common import emit_json, quick, row
from repro.experiments.fleet import FLEET_ITERS, FLEET_WINDOW, run_fleet
from repro.experiments.report import fleet_convergence_figure
from repro.experiments.schema import validate_fleet_record

FLEET_JSON = Path(__file__).resolve().parent.parent / "BENCH_fleet.json"
FLEET_FIG = FLEET_JSON.with_name("FIG_fleet_convergence.png")
QUICK = quick()

FULL_TWINS = 1024
SMOKE_TWINS = 64


def fleet_twins() -> int:
    """Twin count: FLEET_TWINS env override > QUICK smoke > full fleet."""
    raw = os.environ.get("FLEET_TWINS")
    if raw:
        return int(raw)
    return SMOKE_TWINS if QUICK else FULL_TWINS


def bench_fleet_suite() -> dict:
    n = fleet_twins()
    rec = run_fleet(
        n_twins=n,
        seed=0,
        iters=FLEET_ITERS,
        window=FLEET_WINDOW,
        probe_steady=True,
    )
    res, eng = rec["results"], rec["engine"]
    payload = {
        "schema_version": 1,
        "regenerate": "PYTHONPATH=src python -m benchmarks.fleet_bench",
        "quick": QUICK,
        "results": res,
        "engine": eng,
    }
    validate_fleet_record(payload)
    emit_json(FLEET_JSON, payload)
    row(
        f"fleet_cold_n{n}",
        eng["cold_wall_s"] * 1e6,
        f"feasible_rate={res['feasible_rate']:.3f} "
        f"mean_m2f={res['mean_m2f_cold']}",
    )
    row(
        f"fleet_warm_n{res['warm_matched']}",
        eng["warm_wall_s"] * 1e6,
        f"m2f cold={res['mean_m2f_cold_cohort']} "
        f"warm={res['mean_m2f_warm_cohort']} gain={res['warm_gain']}x",
    )
    if eng.get("twins_per_s") is not None:
        row(
            "fleet_steady_throughput",
            eng["steady_wall_s"] * 1e6,
            f"{eng['twins_per_s']:.0f} twins/s (post-compile, "
            f"{res['iters']} iters each)",
        )
    row(
        "fleet_memory",
        0.0,
        f"tables={eng['table_bytes']}B batch={eng['batch_bytes']}B "
        f"consts={eng['consts_bytes']}B",
    )
    row("fleet_json", 0.0, f"wrote {FLEET_JSON.name}")
    fig = fleet_convergence_figure(payload, str(FLEET_FIG))
    row(
        "fleet_figure",
        0.0,
        f"wrote {FLEET_FIG.name}" if fig else "skipped (no matplotlib)",
    )
    return payload


if __name__ == "__main__":
    print("name,us_per_call,derived")
    bench_fleet_suite()
