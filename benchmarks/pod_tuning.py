"""Integration benchmark: CORAL tuning the TPU pod for real dry-run
roofline artifacts (the framework's first-class feature)."""
from __future__ import annotations


from benchmarks.common import row
from repro.core import run_coral, tpu_pod_space
from repro.core.baselines import oracle
from repro.device import DeviceSimulator


def bench_pod_tuning_from_artifacts():
    from repro.launch.tune import terms_from_artifact

    pairs = [
        ("qwen2.5-3b", "decode_32k"),
        ("deepseek-v2-236b", "decode_32k"),
        ("mamba2-2.7b", "train_4k"),
    ]
    space = tpu_pod_space()
    for arch, shape in pairs:
        terms = terms_from_artifact(arch, shape)
        if terms is None:
            row(f"pod_tune_{arch}_{shape}", 0.0, "SKIP (no dry-run artifact)")
            continue
        dev0 = DeviceSimulator(space, terms, noise=0.0)
        om = oracle(space, dev0, tau_target=0.0)
        tau_t = om.tau * 0.6
        p_b = dev0.exact(space.preset("max_power"))[1] * 0.8
        orc = oracle(space, dev0, tau_t, p_b)
        out, _ = run_coral(space, DeviceSimulator(space, terms, seed=0),
                           tau_t, p_b, iters=10)
        row(
            f"pod_tune_{arch}_{shape}", 0.0,
            f"feasible={out.feasible(tau_t, p_b)} "
            f"coral_eff/oracle={out.efficiency/max(orc.efficiency,1e-12):.2f} "
            f"dominant={terms.dominant}",
        )
