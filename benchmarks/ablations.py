"""Ablations of the Alg.-2 under-specification resolutions (DESIGN.md §2b):
the discrete-grid step floor and the power-probe policy. Reproduces the
numbers cited in EXPERIMENTS.md §Perf."""
from __future__ import annotations

from benchmarks.common import row
from repro.core import jetson_like_space, tpu_pod_space
from repro.core.baselines import oracle
from repro.core.coral import CORAL
from repro.device import DeviceSimulator, jetson_like_simulator, synthetic_terms


def _run(space, dev, tau_t, p_b, seed, **kw):
    opt = CORAL(space, tau_t, p_b, seed=seed, **kw)
    for _ in range(10):
        cfg = opt.propose()
        tau, p = dev.measure(cfg)
        opt.observe(cfg, tau, p)
    r = opt.result()
    return r is not None and r.tau >= tau_t and r.power <= p_b


def _scenarios():
    jspace = jetson_like_space("xavier_nx")
    mk_j = lambda s: jetson_like_simulator(jspace, 1.0, seed=s)
    om = oracle(jspace, jetson_like_simulator(jspace, 1.0, noise=0.0), 0.0)
    tau_j = round(om.tau * 0.55)
    pb_j = oracle(jspace, jetson_like_simulator(jspace, 1.0, noise=0.0), tau_j).power * 1.08

    pspace = tpu_pod_space()
    terms = synthetic_terms("balanced")
    mk_p = lambda s: DeviceSimulator(pspace, terms, seed=s)
    om2 = oracle(pspace, DeviceSimulator(pspace, terms, noise=0.0), 0.0)
    tau_p, pb_p = om2.tau * 0.6, om2.power * 0.62
    return (
        ("jetson_dual", jspace, mk_j, tau_j, pb_j),
        ("pod_dual", pspace, mk_p, tau_p, pb_p),
    )


def bench_ablation_step_floor():
    for name, space, mk, tau_t, p_b in _scenarios():
        res = {}
        for floor in (True, False):
            ok = sum(
                _run(space, mk(s), tau_t, p_b, s, step_floor=floor)
                for s in range(8)
            )
            res[floor] = ok
        row(
            f"ablation_step_floor_{name}", 0.0,
            f"with_floor={res[True]}/8 without={res[False]}/8 "
            "(anchor collapse freezes the search without the floor)",
        )


def bench_ablation_probe_policy():
    for name, space, mk, tau_t, p_b in _scenarios():
        parts = []
        for policy in ("budget_aware", "oneshot", "persistent", "off"):
            ok = sum(
                _run(space, mk(s), tau_t, p_b, s, probe_policy=policy)
                for s in range(8)
            )
            parts.append(f"{policy}={ok}/8")
        row(f"ablation_probe_policy_{name}", 0.0, " ".join(parts))
