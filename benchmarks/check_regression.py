"""Bench-regression gate: freshly produced BENCH_*.json vs committed
baselines (benchmarks/baselines/).

Fails (exit 1) on

  - a recorded speedup dropping more than 25% below its baseline (timing
    ratios, not absolute µs — both sides of a ratio ran on the same
    machine, so the gate is stable across runner generations);
  - the compiled episode engine's speedup over the scalar episode loops
    falling below 75% of max(baseline, the bench's own 10×/5×
    static/drift acceptance floors) — the error-bounded-floor pattern of
    the kernels gate applied to wall-clock ratios. Quick (trimmed-grid)
    records are not gated: their small batches under-amortize the
    compiled call;
  - any scenario-matrix cell's normalized-vs-oracle score dropping below
    the baseline's recorded floor (``coral.score_floor`` for stationary
    cells, ``adaptive.score_floor`` for drift cells);
  - any power-budget violation in dual-constraint cells, or a drift cell
    whose adaptive-static separation collapses below 0.3;
  - an offload cell (schema v4 ``offload_cells``) scoring below the
    0.85 joint-oracle gate, recording a true power violation, or whose
    presets / no-offload ablation became feasible — the calibrated
    demand must keep the placement knob necessary;
  - a multi-tenant cotenant cell (schema v5 ``cotenant_cells``) scoring
    below the 0.85 joint-oracle gate, recording a shared-rail power
    violation, or whose presets / per-tenant-greedy combination became
    feasible — the calibrated floors must keep joint slot/DVFS
    negotiation necessary;
  - a fault-injection cell (schema v6 ``fault_cells``) whose hardened
    run scores below the 0.85 fault-free-oracle gate or records a true
    power violation, or whose non-hardened ablation run ends feasible —
    the injected faults must keep the hardened ingest/actuation path
    necessary;
  - a kernel record whose max |err| vs the reference implementation grew
    past 10x its baseline, with an absolute floor of 1e-5 for near-exact
    baselines (interpret-mode wall time is never gated). Kernel records
    carry backend/pallas_interpret provenance; when fresh and baseline
    provenance differ (e.g. interpret-mode CPU vs compiled TPU) the
    comparison is refused with a visible note rather than gated — a
    ~100ms interpret-mode grid walk must never gate a compiled run, and
    vice versa;
  - a fleet record (--records fleet) whose feasible rate or warm-start
    gain drops below the absolute floors, or — when fresh and baseline
    ran the same fleet (n_twins, seed) — below 75%-of-baseline;
  - a fresh record that is missing or fails schema validation.

Serving gates depend on host pipelining headroom and are therefore only
enforced when SERVING_PERF_STRICT is on (the same flag the test suite
uses — see benchmarks/common.py).

    PYTHONPATH=src python -m benchmarks.check_regression
    PYTHONPATH=src python -m benchmarks.check_regression --records matrix
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List

from benchmarks.common import serving_perf_strict

ROOT = Path(__file__).resolve().parent.parent
BASELINE_DIR = ROOT / "benchmarks" / "baselines"

SLOWDOWN_FACTOR = 0.75  # fresh speedup must keep ≥75% of baseline

# Episode-engine acceptance floors (mirror benchmarks.matrix_bench) —
# compiled lax.scan episodes vs the scalar interpreter loops.
EPISODE_SPEEDUP_FLOORS = {"static": 10.0, "drift": 5.0}


def _load(path: Path, errors: List[str]) -> dict | None:
    if not path.exists():
        errors.append(f"{path.name}: missing (run its bench first)")
        return None
    try:
        return json.loads(path.read_text())
    except json.JSONDecodeError as e:
        errors.append(f"{path.name}: unreadable JSON ({e})")
        return None


def check_analytics(fresh: dict, base: dict, errors: List[str]) -> None:
    for name, brec in base["results"].items():
        frec = fresh["results"].get(name)
        if frec is None:
            errors.append(f"analytics:{name}: missing from fresh record")
            continue
        if "speedup" in brec and "speedup" in frec:
            floor = SLOWDOWN_FACTOR * brec["speedup"]
            if frec["speedup"] < floor:
                errors.append(
                    f"analytics:{name}: speedup {frec['speedup']:.2f}x < "
                    f"{floor:.2f}x (75% of baseline {brec['speedup']:.2f}x)"
                )
        if brec.get("same_config") is True and frec.get("same_config") is False:
            errors.append(
                f"analytics:{name}: vectorized oracle no longer matches the "
                "scalar sweep"
            )


def check_serving(fresh: dict, base: dict, errors: List[str]) -> None:
    strict = serving_perf_strict()
    fcurve = fresh["results"]["tau_vs_concurrency"]
    bcurve = base["results"]["tau_vs_concurrency"]
    gain_floor = SLOWDOWN_FACTOR * bcurve["gain_best_c_vs_c1"]
    if fcurve["gain_best_c_vs_c1"] < gain_floor:
        msg = (
            f"serving:tau_vs_concurrency: gain "
            f"{fcurve['gain_best_c_vs_c1']:.2f}x < {gain_floor:.2f}x "
            f"(75% of baseline {bcurve['gain_best_c_vs_c1']:.2f}x)"
        )
        if strict:
            errors.append(msg)
        else:
            print(f"  [skip: SERVING_PERF_STRICT=0] {msg}")
    closed = fresh["results"]["closed_loop_bursty"]
    if not closed["feasible"]:
        msg = "serving:closed_loop_bursty: CORAL found no feasible config"
        if strict:
            errors.append(msg)
        else:
            print(f"  [skip: SERVING_PERF_STRICT=0] {msg}")


def check_matrix(fresh: dict, base: dict, errors: List[str]) -> None:
    sys.path.insert(0, str(ROOT / "src"))
    from repro.experiments.matrix import score_floors
    from repro.experiments.schema import validate_matrix_record

    try:
        validate_matrix_record(fresh)
    except ValueError as e:
        errors.append(f"matrix: schema validation failed: {e}")
        return
    floors = score_floors(base)
    fresh_cells = {
        (c["device"], c["model"], c["workload"], c["regime"]): c["coral"]["score"]
        for c in fresh["cells"]
    }
    # dynamic cells gate on the drift-adaptive post-shift score
    for c in fresh.get("drift_cells", ()):
        key = (c["device"], c["model"], c["workload"], c["regime"])
        fresh_cells[key] = c["adaptive"]["final_score"]
    # offload and cotenant cells gate on the joint-space CORAL score
    for family in ("offload_cells", "cotenant_cells"):
        for c in fresh.get(family, ()):
            key = (c["device"], c["model"], c["workload"], c["regime"])
            fresh_cells[key] = c["coral"]["score"]
    # fault cells gate on the hardened score
    for c in fresh.get("fault_cells", ()):
        key = (c["device"], c["model"], c["workload"], c["regime"])
        fresh_cells[key] = c["hardened"]["score"]
    compared = 0
    for key, floor in floors.items():
        score = fresh_cells.get(key)
        if score is None:
            continue  # QUICK runs trim the workload axis and drift grid
        compared += 1
        if score < floor:
            errors.append(
                f"matrix:{'/'.join(key)}: score {score:.3f} dropped below "
                f"recorded floor {floor:.3f}"
            )
    if not compared:
        errors.append("matrix: no overlapping cells between fresh and baseline")
    viol = fresh["summary"]["dual_power_violations"]
    if viol:
        errors.append(
            f"matrix: {viol} power-budget violations in dual-constraint cells"
        )
    # Drift separation must hold in every fresh dynamic cell: a static
    # ablation that stops breaking means the drift no longer stresses
    # one-shot tuning — a silent loss of the scenario's point. The
    # threshold is the bench's own gate constant so the two cannot drift.
    from repro.experiments.matrix import DRIFT_SEPARATION

    for c in fresh.get("drift_cells", ()):
        sep = c["adaptive"]["final_score"] - c["static"]["final_score"]
        if sep < DRIFT_SEPARATION:
            errors.append(
                f"matrix:{c['device']}/{c['model']}/{c['regime']}: "
                f"drift adaptive-static separation {sep:.3f} < "
                f"{DRIFT_SEPARATION}"
            )
    # Offload regimes (EXPERIMENTS.md §Offload): the joint edge↔pod
    # search must stay efficient AND the scenario must keep its point —
    # zero true power violations, and zero feasible presets/ablations
    # (if a φ=0 row or a static preset becomes feasible, the calibrated
    # demand no longer forces the placement knob).
    from repro.experiments.matrix import OFFLOAD_CORAL_GATE

    for c in fresh.get("offload_cells", ()):
        if c["coral"]["score"] < OFFLOAD_CORAL_GATE:
            errors.append(
                f"matrix:{c['device']}/{c['model']}/{c['regime']}: "
                f"offload CORAL score {c['coral']['score']:.3f} < "
                f"{OFFLOAD_CORAL_GATE}"
            )
    fsum = fresh["summary"]
    if fsum.get("offload_power_violations"):
        errors.append(
            f"matrix: {fsum['offload_power_violations']} power-budget "
            "violations in offload cells"
        )
    if fsum.get("offload_feasible_baselines"):
        errors.append(
            f"matrix: {fsum['offload_feasible_baselines']} offload "
            "presets/ablations were feasible (calibrated demand must keep "
            "the un-offloaded edge and the static presets infeasible)"
        )
    # Cotenant regimes (EXPERIMENTS.md §Multi-tenant): the joint
    # slots × shared-DVFS search must stay efficient AND the scenario
    # must keep its point — zero shared-rail violations, and zero
    # feasible presets or per-tenant-greedy combinations (if a preset
    # or the greedy split becomes feasible, the calibrated floors no
    # longer force joint negotiation).
    from repro.experiments.matrix import COTENANT_CORAL_GATE

    for c in fresh.get("cotenant_cells", ()):
        if c["coral"]["score"] < COTENANT_CORAL_GATE:
            errors.append(
                f"matrix:{c['device']}/{c['model']}/{c['regime']}: "
                f"cotenant CORAL score {c['coral']['score']:.3f} < "
                f"{COTENANT_CORAL_GATE}"
            )
    if fsum.get("cotenant_power_violations"):
        errors.append(
            f"matrix: {fsum['cotenant_power_violations']} shared-rail "
            "power violations in cotenant cells"
        )
    if fsum.get("cotenant_feasible_baselines"):
        errors.append(
            f"matrix: {fsum['cotenant_feasible_baselines']} cotenant "
            "presets/greedy combinations were feasible (calibrated floors "
            "must keep per-tenant-greedy and the static presets "
            "infeasible)"
        )
    # Fault cells (EXPERIMENTS.md §Fault tolerance): hardened CORAL must
    # stay efficient under injection AND the scenario must keep its
    # point — zero true power violations, and zero non-hardened ablation
    # runs ending feasible (if the raw-ingest path survives the faults,
    # the schedules no longer exercise the hardening).
    from repro.experiments.matrix import FAULT_CORAL_GATE

    for c in fresh.get("fault_cells", ()):
        if c["hardened"]["score"] < FAULT_CORAL_GATE:
            errors.append(
                f"matrix:{c['device']}/{c['model']}/{c['regime']}: "
                f"hardened fault score {c['hardened']['score']:.3f} < "
                f"{FAULT_CORAL_GATE}"
            )
    if fsum.get("fault_power_violations"):
        errors.append(
            f"matrix: {fsum['fault_power_violations']} power-budget "
            "violations in hardened fault cells"
        )
    if fsum.get("fault_feasible_ablations"):
        errors.append(
            f"matrix: {fsum['fault_feasible_ablations']} non-hardened "
            "ablation runs ended feasible under fault injection (the "
            "schedules must keep the hardened ingest/actuation path "
            "necessary)"
        )
    # Episode-engine wall-clock: fresh full-grid speedups must hold 75%
    # of max(baseline, acceptance floor) — the floor keeps the gate
    # meaningful when a baseline was recorded on a noisy runner, the
    # ratio keeps improvements from silently eroding.
    fresh_engine = fresh.get("episode_engine")
    base_engine = base.get("episode_engine", {})
    if fresh_engine and not fresh.get("quick"):
        for kind, floor in EPISODE_SPEEDUP_FLOORS.items():
            got = fresh_engine[kind]["speedup"]
            base_speedup = base_engine.get(kind, {}).get("speedup", floor)
            required = SLOWDOWN_FACTOR * max(base_speedup, floor)
            if got < required:
                errors.append(
                    f"matrix:episode_engine:{kind}: speedup {got:.1f}x < "
                    f"{required:.1f}x (75% of max(baseline "
                    f"{base_speedup:.1f}x, floor {floor:.0f}x))"
                )


# Kernel-error floor: float32 interpret-mode errs jitter across BLAS/
# platform generations, so tiny baselines (1e-8-ish) get an absolute
# floor rather than a pure 10x ratio — but the floor stays far below any
# real precision regression (a low-precision accumulation lands ~1e-4+).
KERNEL_ERR_FLOOR = 1e-5

# Kernel-speedup floor: per-step ratios of two jitted microkernels swing
# with machine state (the same incremental-dCor build measured 2.2x–5.1x
# across runs of identical code), so %-of-baseline would flake — but an
# asymptotic regression (e.g. an accidental O(W²) push) lands at ~1x,
# which an absolute floor catches on any runner, QUICK or full.
KERNEL_SPEEDUP_FLOOR = 1.3


def _kernel_provenance(rec: dict) -> tuple:
    return (rec.get("backend"), rec.get("pallas_interpret"))


def check_kernels(fresh: dict, base: dict, errors: List[str]) -> None:
    """Kernel records gate on *correctness* (max |err| vs the reference
    implementations) and same-machine speedup ratios, not interpret-mode
    wall time — CPU interpret timings are noise, numerical drift is a
    real regression. Cross-backend comparisons are refused outright:
    both sides must have matching backend + pallas_interpret provenance."""
    fp, bp = _kernel_provenance(fresh), _kernel_provenance(base)
    if fp != bp:
        print(
            f"  [skip] kernels: provenance mismatch — fresh "
            f"backend={fp[0]}/interpret={fp[1]} vs baseline "
            f"backend={bp[0]}/interpret={bp[1]}; cross-backend "
            "comparison refused (re-baseline on this backend to gate)"
        )
        return
    for name, brec in base["results"].items():
        frec = fresh["results"].get(name)
        if frec is None:
            errors.append(f"kernels:{name}: missing from fresh record")
            continue
        if "err_vs_ref" in brec:
            err = frec.get("err_vs_ref")
            if err is None:
                errors.append(f"kernels:{name}: fresh record lacks err_vs_ref")
                continue
            bound = max(10.0 * brec["err_vs_ref"], KERNEL_ERR_FLOOR)
            if err > bound:
                errors.append(
                    f"kernels:{name}: err_vs_ref {err:.2e} > bound "
                    f"{bound:.2e} (10x baseline, floor {KERNEL_ERR_FLOOR:.0e})"
                )
        # speedup entries (e.g. incremental dCor vs full recompute) gate
        # on the absolute floor, not %-of-baseline — see the floor note
        if "speedup" in brec and "speedup" in frec:
            if frec["speedup"] < KERNEL_SPEEDUP_FLOOR:
                errors.append(
                    f"kernels:{name}: speedup {frec['speedup']:.2f}x < "
                    f"absolute floor {KERNEL_SPEEDUP_FLOOR}x"
                )


# Fleet absolute floors — hold for any fleet size/seed because twin i's
# perturbation draw is independent of the fleet size (the 64-twin smoke
# fleet is a prefix of the 1024-twin nightly fleet).
FLEET_FEASIBLE_FLOOR = 0.85  # fraction of twins that find a feasible config
FLEET_WARM_GAIN_FLOOR = 1.2  # cold/warm measurements-to-feasible ratio


def check_fleet(fresh: dict, base: dict, errors: List[str]) -> None:
    """Fleet records gate on the deterministic quality metrics only (the
    ``engine`` wall-clock block is machine telemetry): absolute floors
    always, plus 75%-of-baseline ratios when fresh and baseline ran the
    identical fleet."""
    sys.path.insert(0, str(ROOT / "src"))
    from repro.experiments.schema import validate_fleet_record

    try:
        validate_fleet_record(fresh)
    except ValueError as e:
        errors.append(f"fleet: schema validation failed: {e}")
        return
    fr, br = fresh["results"], base["results"]
    if fr["feasible_rate"] < FLEET_FEASIBLE_FLOOR:
        errors.append(
            f"fleet: feasible_rate {fr['feasible_rate']:.3f} < floor "
            f"{FLEET_FEASIBLE_FLOOR}"
        )
    if fr["warm_matched"] == 0:
        errors.append("fleet: no warm-start twin was matched to a source")
    gain = fr["warm_gain"]
    if gain is None or gain < FLEET_WARM_GAIN_FLOOR:
        errors.append(
            f"fleet: warm_gain {gain} < floor {FLEET_WARM_GAIN_FLOOR} "
            "(warm starts must reach feasibility in measurably fewer "
            "measurements than cold)"
        )
    fleet_key = ("n_twins", "seed", "iters", "window")
    if any(fr[k] != br[k] for k in fleet_key):
        print(
            f"  [note] fleet: fresh ran {fr['n_twins']} twins (seed "
            f"{fr['seed']}) vs baseline {br['n_twins']} (seed {br['seed']})"
            " — only absolute floors gated"
        )
        return
    if fr["feasible_rate"] < br["feasible_rate"] - 0.05:
        errors.append(
            f"fleet: feasible_rate {fr['feasible_rate']:.3f} dropped >5pp "
            f"below baseline {br['feasible_rate']:.3f}"
        )
    if gain is not None and br["warm_gain"] is not None:
        required = SLOWDOWN_FACTOR * br["warm_gain"]
        if gain < required:
            errors.append(
                f"fleet: warm_gain {gain:.2f}x < {required:.2f}x "
                f"(75% of baseline {br['warm_gain']:.2f}x)"
            )


CHECKS = {
    "analytics": ("BENCH_analytics.json", check_analytics),
    "serving": ("BENCH_serving.json", check_serving),
    "matrix": ("BENCH_matrix.json", check_matrix),
    "kernels": ("BENCH_kernels.json", check_kernels),
    "fleet": ("BENCH_fleet.json", check_fleet),
}


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--records",
        default="analytics,serving,matrix,kernels",
        help="comma-separated subset of: analytics, serving, matrix, "
        "kernels, fleet (fleet is opt-in: its bench is a separate job)",
    )
    ap.add_argument("--fresh-dir", type=Path, default=ROOT)
    ap.add_argument("--baseline-dir", type=Path, default=BASELINE_DIR)
    args = ap.parse_args(argv)

    errors: List[str] = []
    for name in args.records.split(","):
        name = name.strip()
        if name not in CHECKS:
            errors.append(f"unknown record {name!r}")
            continue
        filename, fn = CHECKS[name]
        fresh = _load(args.fresh_dir / filename, errors)
        base = _load(args.baseline_dir / filename, errors)
        if fresh is None or base is None:
            continue
        before = len(errors)
        fn(fresh, base, errors)
        status = "FAIL" if len(errors) > before else "ok"
        print(f"{name}: {status}")
    if errors:
        print("\nregression gate FAILED:", file=sys.stderr)
        for e in errors:
            print(f"  - {e}", file=sys.stderr)
        return 1
    print("regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
