"""Bench-regression gate: freshly produced BENCH_*.json vs committed
baselines (benchmarks/baselines/).

Fails (exit 1) on

  - a recorded speedup dropping more than 25% below its baseline (timing
    ratios, not absolute µs — both sides of a ratio ran on the same
    machine, so the gate is stable across runner generations);
  - any scenario-matrix cell's normalized-vs-oracle score dropping below
    the baseline's recorded floor (``coral.score_floor``, the worst seed
    minus a jitter margin);
  - any power-budget violation in dual-constraint cells;
  - a fresh record that is missing or fails schema validation.

Serving gates depend on host pipelining headroom and are therefore only
enforced when SERVING_PERF_STRICT is on (the same flag the test suite
uses — see benchmarks/common.py).

    PYTHONPATH=src python -m benchmarks.check_regression
    PYTHONPATH=src python -m benchmarks.check_regression --records matrix
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List

from benchmarks.common import serving_perf_strict

ROOT = Path(__file__).resolve().parent.parent
BASELINE_DIR = ROOT / "benchmarks" / "baselines"

SLOWDOWN_FACTOR = 0.75  # fresh speedup must keep ≥75% of baseline


def _load(path: Path, errors: List[str]) -> dict | None:
    if not path.exists():
        errors.append(f"{path.name}: missing (run its bench first)")
        return None
    try:
        return json.loads(path.read_text())
    except json.JSONDecodeError as e:
        errors.append(f"{path.name}: unreadable JSON ({e})")
        return None


def check_analytics(fresh: dict, base: dict, errors: List[str]) -> None:
    for name, brec in base["results"].items():
        frec = fresh["results"].get(name)
        if frec is None:
            errors.append(f"analytics:{name}: missing from fresh record")
            continue
        if "speedup" in brec and "speedup" in frec:
            floor = SLOWDOWN_FACTOR * brec["speedup"]
            if frec["speedup"] < floor:
                errors.append(
                    f"analytics:{name}: speedup {frec['speedup']:.2f}x < "
                    f"{floor:.2f}x (75% of baseline {brec['speedup']:.2f}x)"
                )
        if brec.get("same_config") is True and frec.get("same_config") is False:
            errors.append(
                f"analytics:{name}: vectorized oracle no longer matches the "
                "scalar sweep"
            )


def check_serving(fresh: dict, base: dict, errors: List[str]) -> None:
    strict = serving_perf_strict()
    fcurve = fresh["results"]["tau_vs_concurrency"]
    bcurve = base["results"]["tau_vs_concurrency"]
    gain_floor = SLOWDOWN_FACTOR * bcurve["gain_best_c_vs_c1"]
    if fcurve["gain_best_c_vs_c1"] < gain_floor:
        msg = (
            f"serving:tau_vs_concurrency: gain "
            f"{fcurve['gain_best_c_vs_c1']:.2f}x < {gain_floor:.2f}x "
            f"(75% of baseline {bcurve['gain_best_c_vs_c1']:.2f}x)"
        )
        if strict:
            errors.append(msg)
        else:
            print(f"  [skip: SERVING_PERF_STRICT=0] {msg}")
    closed = fresh["results"]["closed_loop_bursty"]
    if not closed["feasible"]:
        msg = "serving:closed_loop_bursty: CORAL found no feasible config"
        if strict:
            errors.append(msg)
        else:
            print(f"  [skip: SERVING_PERF_STRICT=0] {msg}")


def check_matrix(fresh: dict, base: dict, errors: List[str]) -> None:
    sys.path.insert(0, str(ROOT / "src"))
    from repro.experiments.matrix import score_floors
    from repro.experiments.schema import validate_matrix_record

    try:
        validate_matrix_record(fresh)
    except ValueError as e:
        errors.append(f"matrix: schema validation failed: {e}")
        return
    floors = score_floors(base)
    fresh_cells = {
        (c["device"], c["model"], c["workload"], c["regime"]): c
        for c in fresh["cells"]
    }
    compared = 0
    for key, floor in floors.items():
        cell = fresh_cells.get(key)
        if cell is None:
            continue  # QUICK runs trim the workload axis
        compared += 1
        score = cell["coral"]["score"]
        if score < floor:
            errors.append(
                f"matrix:{'/'.join(key)}: score {score:.3f} dropped below "
                f"recorded floor {floor:.3f}"
            )
    if not compared:
        errors.append("matrix: no overlapping cells between fresh and baseline")
    viol = fresh["summary"]["dual_power_violations"]
    if viol:
        errors.append(
            f"matrix: {viol} power-budget violations in dual-constraint cells"
        )


CHECKS = {
    "analytics": ("BENCH_analytics.json", check_analytics),
    "serving": ("BENCH_serving.json", check_serving),
    "matrix": ("BENCH_matrix.json", check_matrix),
}


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--records",
        default="analytics,serving,matrix",
        help="comma-separated subset of: analytics, serving, matrix",
    )
    ap.add_argument("--fresh-dir", type=Path, default=ROOT)
    ap.add_argument("--baseline-dir", type=Path, default=BASELINE_DIR)
    args = ap.parse_args(argv)

    errors: List[str] = []
    for name in args.records.split(","):
        name = name.strip()
        if name not in CHECKS:
            errors.append(f"unknown record {name!r}")
            continue
        filename, fn = CHECKS[name]
        fresh = _load(args.fresh_dir / filename, errors)
        base = _load(args.baseline_dir / filename, errors)
        if fresh is None or base is None:
            continue
        before = len(errors)
        fn(fresh, base, errors)
        status = "FAIL" if len(errors) > before else "ok"
        print(f"{name}: {status}")
    if errors:
        print("\nregression gate FAILED:", file=sys.stderr)
        for e in errors:
            print(f"  - {e}", file=sys.stderr)
        return 1
    print("regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
