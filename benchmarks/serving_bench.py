"""Serving-runtime benchmarks: the τ-vs-concurrency response curve and the
closed-loop CORAL-over-live-traffic run. Emits BENCH_serving.json.

    PYTHONPATH=src python -m benchmarks.serving_bench        # full
    QUICK=1 PYTHONPATH=src python -m benchmarks.serving_bench  # CI smoke
"""
from __future__ import annotations

from pathlib import Path

from benchmarks.common import emit_json, quick, row

QUICK = quick()


def _engine(batch_size: int = 2, max_len: int = 64):
    import jax

    from repro.configs.registry import get_config
    from repro.configs.runtime import RunConfig
    from repro.models.transformer import ApplyCtx, init_model_params
    from repro.serving import ServingEngine

    cfg = get_config("qwen2.5-3b").reduced()
    rcfg = RunConfig(remat="none", moe_impl="dense")
    ctx = ApplyCtx(cfg, rcfg, None)
    params = init_model_params(jax.random.PRNGKey(0), cfg, rcfg)
    return ServingEngine(ctx, params, batch_size=batch_size, max_len=max_len), cfg


def bench_serving_suite():
    """τ vs concurrency (best-of interleaved reps — the container shares
    cores with noisy neighbours, and interference only ever slows a run
    down) + CORAL closed-loop under a bursty trace."""
    from repro.core import tpu_pod_space
    from repro.device.measure import analytic_scale_and_power
    from repro.serving import (
        ServingController,
        ServingRuntime,
        build_serving_record,
        measure_concurrency_curve,
        workload,
    )

    engine, cfg = _engine()
    space = tpu_pod_space()
    c_values = [int(v) for v in space.dims[space.index("concurrency")].values]
    best, rounds = measure_concurrency_curve(
        engine, c_values, rounds=3 if QUICK else 8,
        groups=6 if QUICK else 10, vocab=cfg.vocab,
    )
    for c in c_values:
        row(f"serving_tau_c{c}", 1e6 / max(best[c], 1e-9),
            f"tok_s={best[c]:.0f},x_vs_c1={best[c] / best[1]:.2f}")

    # closed loop: bursty Poisson at ~60% of measured capacity
    cap = max(best.values())
    new_tokens = 8
    iters = 4 if QUICK else 10
    interval_s = 0.3 if QUICK else 0.5
    trace = workload.bursty_poisson(
        rate=0.6 * cap / new_tokens, duration_s=iters * interval_s + 2.0,
        prompt_lens=8, new_tokens=new_tokens, vocab=cfg.vocab, seed=1,
    )
    tau_target = 0.35 * cap
    p_budget = analytic_scale_and_power(
        space.names, space.preset("max_power"))[1] * 0.8
    controller = ServingController(
        ServingRuntime(engine, concurrency=1), space, trace,
        tau_target=tau_target, p_budget=p_budget, interval_s=interval_s,
    )
    outcome, records = controller.run(iters)
    feasible = outcome.feasible(tau_target, p_budget)
    row("serving_closed_loop", sum(r.p99_latency_s for r in records) * 1e6,
        f"feasible={feasible},tau={outcome.tau:.0f}")

    emit_json(
        Path("BENCH_serving.json"),
        build_serving_record(
            "PYTHONPATH=src python -m benchmarks.serving_bench",
            c_values, best, rounds, batch_size=2, iters=iters,
            interval_s=interval_s, tau_target=tau_target, p_budget=p_budget,
            outcome=outcome, records=records,
        ),
    )


def main() -> None:
    print("name,us_per_call,derived")
    bench_serving_suite()


if __name__ == "__main__":
    main()
