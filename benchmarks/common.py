"""Shared helpers for the benchmark harness — including the single
source of truth for the env flags CI and local runs both read.

Flags (see also tests/conftest.py, which re-exports the same helpers so
the test suite and the benches cannot drift):

  QUICK                — CI-smoke mode: fewer iterations/seeds everywhere.
  SERVING_PERF_STRICT  — keep the concurrency-gain perf gates hard
                         (default on; hosted runners set 0 to demote the
                         host-headroom-dependent gates to skips).
  PALLAS_INTERPRET     — force the Pallas kernels' interpret mode on/off
                         (default: auto from the jax backend).
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path
from typing import Callable, Optional

def env_flag(name: str, default: bool = False) -> bool:
    """Truthiness of an env var: unset → ``default``; "", 0, false, no →
    False; anything else → True. Delegates to ``repro.envflags`` — the
    single shared truthy-parser — so "QUICK=" and "QUICK=0" mean the same
    thing in every entry point, bench or kernel."""
    from repro.envflags import env_flag as _env_flag

    return _env_flag(name, default)


def quick() -> bool:
    """CI-smoke mode (QUICK=1)."""
    return env_flag("QUICK")


def serving_perf_strict() -> bool:
    """Whether host-headroom-dependent serving perf gates are hard
    failures (default) or skips (SERVING_PERF_STRICT=0)."""
    return env_flag("SERVING_PERF_STRICT", default=True)


def pallas_interpret() -> Optional[bool]:
    """Explicit PALLAS_INTERPRET override, or None for backend-auto.
    Delegates to the kernels' canonical routing point
    (``repro.kernels.runtime``, built on the same ``repro.envflags``
    parser) so the harness helper and ``default_interpret`` cannot
    drift."""
    from repro.kernels.runtime import parse_interpret_env

    return parse_interpret_env(os.environ.get("PALLAS_INTERPRET"))


def timeit(fn: Callable, iters: int = 3, warmup: int = 1) -> float:
    """Mean wall-time per call in microseconds."""
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


def row(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")


def emit_json(path: Path, payload: dict) -> None:
    """Write a machine-readable benchmark record (sorted keys, trailing
    newline) so successive PRs can diff the perf trajectory."""
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
