"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Callable


def timeit(fn: Callable, iters: int = 3, warmup: int = 1) -> float:
    """Mean wall-time per call in microseconds."""
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


def row(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")


def emit_json(path: Path, payload: dict) -> None:
    """Write a machine-readable benchmark record (sorted keys, trailing
    newline) so successive PRs can diff the perf trajectory."""
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
