# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
import sys


def main() -> None:
    from benchmarks import (
        ablations,
        kernels_bench,
        matrix_bench,
        paper_figs,
        pod_tuning,
        serving_bench,
    )

    benches = [
        paper_figs.bench_fig1_tradeoff,
        paper_figs.bench_fig3_4_single_constraint,
        paper_figs.bench_fig5_6_dual_constraint,
        paper_figs.bench_fig7_10_generalization,
        paper_figs.bench_table4_space_sizes,
        paper_figs.bench_iteration_budget,
        kernels_bench.bench_dcov_kernel,
        kernels_bench.bench_flash_attention_kernel,
        kernels_bench.bench_ssd_kernel,
        kernels_bench.bench_coral_iteration_overhead,
        kernels_bench.bench_analytics_suite,
        pod_tuning.bench_pod_tuning_from_artifacts,
        serving_bench.bench_serving_suite,
        matrix_bench.bench_matrix_suite,
        ablations.bench_ablation_step_floor,
        ablations.bench_ablation_probe_policy,
    ]
    print("name,us_per_call,derived")
    failures = 0
    for b in benches:
        try:
            b()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{b.__name__},0.0,ERROR:{type(e).__name__}:{e}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
