"""Regression tests for the ISSUE-2 satellite fixes: the real throughput
mode in CORAL, WalltimeDevice noise clamping, and the de-ghosted ALERT-
Online selection."""
import numpy as np
import pytest

from repro.core import run_coral, tpu_pod_space
from repro.core.baselines import alert_online, oracle
from repro.device import DeviceSimulator, synthetic_terms
from repro.device.measure import WalltimeDevice


def test_throughput_mode_maximizes_tau_not_efficiency():
    """mode="throughput" used to set tau_target=inf, sending every
    observation down Alg. 1's infeasible branch: all rewards were
    -(p/τ) and the search maximized efficiency. The real single-target
    path rewards τ itself."""
    space = tpu_pod_space()
    terms = synthetic_terms("balanced")
    orc = oracle(space, DeviceSimulator(space, terms, noise=0.0),
                 tau_target=0.0)  # noise-free max-τ upper bound
    out, tr = run_coral(
        space, DeviceSimulator(space, terms, seed=0), tau_target=0.0,
        iters=10, seed=0, mode="throughput",
    )
    assert out.config is not None
    assert out.tau >= 0.85 * orc.tau, (out.tau, orc.tau)
    # feasible observations are rewarded with τ (positive), not a penalty
    assert max(tr.rewards) > 0
    assert max(tr.rewards) == pytest.approx(max(tr.taus))


def test_throughput_mode_respects_power_cap():
    space = tpu_pod_space()
    terms = synthetic_terms("balanced")
    dev0 = DeviceSimulator(space, terms, noise=0.0)
    p_cap = dev0.exact(space.preset("max_power"))[1] * 0.75
    out, tr = run_coral(
        space, DeviceSimulator(space, terms, seed=1), tau_target=0.0,
        p_budget=p_cap, iters=10, seed=1, mode="throughput",
    )
    assert out.config is not None
    assert out.power <= p_cap
    # and it still maximizes τ among capped configs, beating the min preset
    p_min_tau = dev0.exact(space.preset("min_power"))[0]
    assert out.tau > p_min_tau


def test_throughput_mode_power_probe_fires_over_cap():
    """The lines 14-17 cores→MIN/concurrency→MAX probe used to be dead in
    throughput mode: every predicate compared best.τ against the inf
    sentinel. With a finite violated cap it must fire."""
    from repro.core import CORAL

    space = tpu_pod_space()
    opt = CORAL(space, tau_target=0.0, p_budget=100.0, mode="throughput",
                seed=0)
    opt.observe(space.preset("max_power"), tau=50.0, power=300.0)
    opt.observe(space.preset("default"), tau=40.0, power=200.0)
    opt.observe(space.midpoint(), tau=45.0, power=250.0)
    cand = opt.propose()
    i_cores, i_conc = space.index("host_cores"), space.index("concurrency")
    assert cand[i_cores] == space.dims[i_cores].lo
    assert cand[i_conc] == space.dims[i_conc].hi


class _FixedNoise:
    """Stand-in rng whose normal() always returns the same draw."""

    def __init__(self, z):
        self.z = z

    def normal(self, loc, scale):
        return self.z


def _walltime_with_stub_rates(base=50.0):
    space = tpu_pod_space()
    dev = WalltimeDevice(space, engine=None)
    dev._rate_cache = {
        int(v): base for v in space.dims[space.index("concurrency")].values
    }
    return space, dev


def test_walltime_measure_clamps_noise_tail():
    """A noise tail used to emit τ ≤ 0, flipping the reward penalty's
    sign; both channels are now clamped like DeviceSimulator.measure."""
    space, dev = _walltime_with_stub_rates()
    dev.rng = _FixedNoise(-200.0)  # 1 + z < 0 on both channels
    tau, p = dev.measure(space.preset("default"))
    assert tau > 0 and p > 0


def test_walltime_noise_is_symmetric_on_power():
    space, dev = _walltime_with_stub_rates()
    tau0, p0 = dev.exact(space.preset("default"))
    dev.rng = _FixedNoise(0.5)
    tau, p = dev.measure(space.preset("default"))
    assert tau == pytest.approx(tau0 * 1.5)
    assert p == pytest.approx(p0 * 1.5)  # power jitters too, not just τ


def test_alert_online_selects_best_measured_feasible_trial():
    """The Kalman filter was updated every trial but never consulted; it
    is gone (there is no profiled baseline for its slowdown factor to
    correct). Selection must be exactly the best measured feasible trial
    by efficiency."""
    space = tpu_pod_space()
    terms = synthetic_terms("balanced")
    dev0 = DeviceSimulator(space, terms, noise=0.0)
    tau_t = dev0.exact(space.preset("default"))[0] * 0.5
    p_b = dev0.exact(space.preset("max_power"))[1] * 0.9

    out = alert_online(space, DeviceSimulator(space, terms, seed=3), tau_t,
                       p_b, iters=10, seed=5)
    # replay the identical config/measurement streams
    rng = np.random.default_rng(5)
    replay = DeviceSimulator(space, terms, seed=3)
    trials = [(cfg := space.random(rng), *replay.measure(cfg))
              for _ in range(10)]
    feas = [t for t in trials if t[1] >= tau_t and t[2] <= p_b]
    assert feas, "scenario must produce at least one feasible trial"
    best = max(feas, key=lambda t: t[1] / max(t[2], 1e-9))
    assert out.config == best[0]
    assert out.tau == pytest.approx(best[1])
