"""Unit + property tests for distance covariance (paper Eq. 1-4)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.dcov import dcor, dcor_matrix, dcov2


def test_paper_worked_example():
    """§III-D: α_cpu = 0.94, β_cpu = 0.99 for the given window."""
    tau = jnp.array([15.2, 16.1, 15.8, 14.9, 15.5])
    p = jnp.array([9800.0, 10100.0, 10050.0, 9500.0, 9750.0])
    s = jnp.array([1200.0, 1400.0, 1400.0, 1000.0, 1200.0])
    assert float(dcor(tau, s)) == pytest.approx(0.94, abs=0.01)
    assert float(dcor(p, s)) == pytest.approx(0.99, abs=0.01)


def test_perfect_linear_dependence_is_one():
    x = jnp.arange(50.0)
    assert float(dcor(x, 3 * x + 2)) == pytest.approx(1.0, abs=1e-5)


def test_nonlinear_dependence_detected():
    """Pearson(x, x²) ≈ 0 for symmetric x, but dCor must be clearly > 0."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=500)
    y = x**2
    pearson = abs(np.corrcoef(x, y)[0, 1])
    d = float(dcor(jnp.asarray(x), jnp.asarray(y)))
    assert pearson < 0.2  # linear correlation barely sees it...
    assert d > 0.4  # ...distance correlation clearly does


def test_independence_near_zero():
    rng = np.random.default_rng(1)
    x = rng.normal(size=800)
    y = rng.normal(size=800)
    assert float(dcor(jnp.asarray(x), jnp.asarray(y))) < 0.15


def test_constant_input_is_zero():
    x = jnp.arange(20.0)
    assert float(dcor(x, jnp.zeros(20))) == 0.0
    assert float(dcor(jnp.zeros(20), x)) == 0.0


def test_dcov2_nonnegative_and_symmetric():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=64))
    y = jnp.asarray(rng.normal(size=64))
    assert float(dcov2(x, y)) >= -1e-6
    assert float(dcov2(x, y)) == pytest.approx(float(dcov2(y, x)), rel=1e-5)


def test_dcor_matrix_shape_and_consistency():
    rng = np.random.default_rng(3)
    s = jnp.asarray(rng.normal(size=(30, 5)))
    m = jnp.asarray(rng.normal(size=(30, 2)))
    M = dcor_matrix(s, m)
    assert M.shape == (5, 2)
    assert float(M[0, 0]) == pytest.approx(float(dcor(m[:, 0], s[:, 0])), abs=1e-5)


@settings(max_examples=30, deadline=None)
@given(
    st.lists(st.floats(-1e3, 1e3), min_size=4, max_size=40),
    st.lists(st.floats(-1e3, 1e3), min_size=4, max_size=40),
)
def test_property_dcor_in_unit_interval(xs, ys):
    n = min(len(xs), len(ys))
    v = float(dcor(jnp.asarray(xs[:n]), jnp.asarray(ys[:n])))
    assert 0.0 <= v <= 1.0


@settings(max_examples=20, deadline=None)
@given(
    st.lists(
        st.floats(-100, 100).filter(lambda v: abs(v) > 1e-3),
        min_size=5, max_size=30, unique=True,
    ),
    st.floats(0.1, 10.0),
    st.floats(-5.0, 5.0),
)
def test_property_scale_invariance(xs, a, b):
    """dCor is invariant to positive affine transforms of either argument."""
    x = jnp.asarray(xs)
    y = x**2  # deterministic dependence
    d1 = float(dcor(x, y))
    d2 = float(dcor(a * x + b, y))
    assert d1 == pytest.approx(d2, abs=5e-3)
