"""Unit tests for distance covariance (paper Eq. 1-4). Hypothesis-based
property tests live in test_properties.py (optional dependency)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dcov import dcor, dcor_all, dcov2


def test_paper_worked_example():
    """§III-D: α_cpu = 0.94, β_cpu = 0.99 for the given window."""
    tau = jnp.array([15.2, 16.1, 15.8, 14.9, 15.5])
    p = jnp.array([9800.0, 10100.0, 10050.0, 9500.0, 9750.0])
    s = jnp.array([1200.0, 1400.0, 1400.0, 1000.0, 1200.0])
    assert float(dcor(tau, s)) == pytest.approx(0.94, abs=0.01)
    assert float(dcor(p, s)) == pytest.approx(0.99, abs=0.01)


def test_perfect_linear_dependence_is_one():
    x = jnp.arange(50.0)
    assert float(dcor(x, 3 * x + 2)) == pytest.approx(1.0, abs=1e-5)


def test_nonlinear_dependence_detected():
    """Pearson(x, x²) ≈ 0 for symmetric x, but dCor must be clearly > 0."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=500)
    y = x**2
    pearson = abs(np.corrcoef(x, y)[0, 1])
    d = float(dcor(jnp.asarray(x), jnp.asarray(y)))
    assert pearson < 0.2  # linear correlation barely sees it...
    assert d > 0.4  # ...distance correlation clearly does


def test_independence_near_zero():
    rng = np.random.default_rng(1)
    x = rng.normal(size=800)
    y = rng.normal(size=800)
    assert float(dcor(jnp.asarray(x), jnp.asarray(y))) < 0.15


def test_constant_input_is_zero():
    x = jnp.arange(20.0)
    assert float(dcor(x, jnp.zeros(20))) == 0.0
    assert float(dcor(jnp.zeros(20), x)) == 0.0


def test_dcov2_nonnegative_and_symmetric():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=64))
    y = jnp.asarray(rng.normal(size=64))
    assert float(dcov2(x, y)) >= -1e-6
    assert float(dcov2(x, y)) == pytest.approx(float(dcov2(y, x)), rel=1e-5)


def test_dcor_all_shape_and_consistency():
    rng = np.random.default_rng(3)
    s = jnp.asarray(rng.normal(size=(30, 5)), jnp.float32)
    m = jnp.asarray(rng.normal(size=(30, 2)), jnp.float32)
    M = dcor_all(s, m, np.int32(30))
    assert M.shape == (5, 2)
    assert float(M[0, 0]) == pytest.approx(float(dcor(m[:, 0], s[:, 0])), abs=1e-5)


def test_dcor_all_padded_window_matches_unpadded():
    """Fixed-W padding with n_valid must equal the unpadded computation."""
    rng = np.random.default_rng(4)
    w, n = 10, 6
    s = np.zeros((w, 3), np.float32)
    m = np.zeros((w, 2), np.float32)
    s[:n] = rng.normal(size=(n, 3))
    m[:n] = rng.normal(size=(n, 2))
    padded = np.asarray(dcor_all(jnp.asarray(s), jnp.asarray(m), np.int32(n)))
    exact = np.asarray(
        dcor_all(jnp.asarray(s[:n]), jnp.asarray(m[:n]), np.int32(n))
    )
    np.testing.assert_allclose(padded, exact, atol=1e-5)


# ------------------------------------------------------- incremental dCor
def _replay(rows, w, d):
    """Push rows through the incremental state one at a time, returning
    (incremental corr, full-recompute corr) at each step."""
    from repro.core.dcov import (
        dcor_all_cols,
        dcor_state_corr,
        dcor_state_init,
        dcor_state_push,
    )

    c = rows.shape[1]
    st = dcor_state_init(w, c)
    win = np.zeros((w, c), np.float32)
    out = []
    for t, r in enumerate(rows):
        slot, n_filled = t % w, min(t, w)
        st = dcor_state_push(st, jnp.asarray(r), jnp.int32(slot),
                             jnp.int32(n_filled))
        win[slot] = r
        n_valid = min(t + 1, w)
        incr = np.asarray(dcor_state_corr(st, jnp.int32(n_valid), d))
        full = np.asarray(dcor_all_cols(jnp.asarray(win), jnp.int32(n_valid), d))
        out.append((incr, full))
    return out


def test_incremental_dcor_matches_full_recompute():
    """Ring-buffer rank-1 updates track dcor_all_cols through fill-up AND
    wrap-around (the O(W·C) path the fleet engine runs per observation)."""
    rng = np.random.default_rng(5)
    w, d, m = 8, 4, 2
    rows = rng.normal(size=(3 * w, d + m)).astype(np.float32)
    for incr, full in _replay(rows, w, d):
        np.testing.assert_allclose(incr, full, atol=2e-3)


def test_incremental_dcor_from_window_seed():
    """Warm-start path: a state built from an existing (possibly padded)
    window must read out the same correlations as the full recompute."""
    from repro.core.dcov import (
        dcor_all_cols,
        dcor_state_corr,
        dcor_state_from_window,
    )

    rng = np.random.default_rng(6)
    w, d, m, n = 10, 3, 2, 6
    cols = np.zeros((w, d + m), np.float32)
    cols[:n] = rng.normal(size=(n, d + m))
    st = dcor_state_from_window(jnp.asarray(cols), jnp.int32(n))
    incr = np.asarray(dcor_state_corr(st, jnp.int32(n), d))
    full = np.asarray(dcor_all_cols(jnp.asarray(cols), jnp.int32(n), d))
    np.testing.assert_allclose(incr, full, atol=1e-4)
    assert incr.shape == (d, m)


def test_incremental_dcor_values_in_unit_interval():
    rng = np.random.default_rng(7)
    rows = rng.normal(size=(20, 6)).astype(np.float32)
    for incr, _ in _replay(rows, 6, 4):
        assert (incr >= -1e-5).all() and (incr <= 1 + 1e-5).all()
