"""End-to-end behaviour: the paper's full evaluation loop (Fig. 2) wired to
the framework — dry-run roofline terms → device simulator → CORAL vs
baselines — plus launcher entry points."""
import json
import os

import numpy as np
import pytest

from repro.core import run_coral, tpu_pod_space
from repro.core.baselines import alert, alert_online, oracle, preset
from repro.device import DeviceSimulator, RooflineTerms


@pytest.fixture(scope="module")
def artifact_terms():
    """Use a real dry-run artifact when present, else synthetic terms."""
    path = "experiments/dryrun/qwen2.5-3b__decode_32k__16x16.json"
    if os.path.exists(path):
        with open(path) as f:
            rec = json.load(f)
        r = rec["roofline"]
        return RooflineTerms(
            r["t_compute"], r["t_memory"], r["t_collective"], 2e-3,
            items_per_step=rec.get("global_batch", 128), n_chips=r["n_chips"],
        )
    from repro.device import synthetic_terms

    return synthetic_terms("memory_bound")


def test_full_loop_dual_constraint(artifact_terms):
    space = tpu_pod_space()
    dev0 = DeviceSimulator(space, artifact_terms, noise=0.0)
    orc_max = oracle(space, dev0, tau_target=0.0)
    tau_t = orc_max.tau * 0.6
    # anchor the budget to the max-power preset: τ-max configs can tie at
    # low power on memory/collective-bound workloads
    p_b = dev0.exact(space.preset("max_power"))[1] * 0.8
    orc = oracle(space, dev0, tau_t, p_b)
    assert orc.config is not None, "scenario must be satisfiable"

    feas = 0
    for seed in range(3):
        out, trace = run_coral(
            space, DeviceSimulator(space, artifact_terms, seed=seed),
            tau_t, p_b, iters=10, seed=seed,
        )
        assert len(trace.configs) == 10
        feas += out.feasible(tau_t, p_b)
    assert feas >= 2

    al = alert(space, DeviceSimulator(space, artifact_terms, seed=9), tau_t, p_b)
    alo = alert_online(space, DeviceSimulator(space, artifact_terms, seed=9),
                       tau_t, p_b)
    assert alo.config is None or alo.power <= p_b  # only feasible trials win
    mx = preset(space, DeviceSimulator(space, artifact_terms, seed=9), "max_power")
    # the paper's qualitative ordering
    assert al.tau >= orc.tau * 0.9  # ALERT chases throughput...
    assert not mx.feasible(tau_t, p_b) or mx.power > orc.power


def test_train_launcher_runs():
    from repro.launch.train import train

    _, losses = train("qwen2.5-3b", steps=6, batch=4, seq=32, reduced=True,
                      log_every=0)
    assert len(losses) == 6 and all(np.isfinite(losses))


def test_serve_launcher_runs():
    from repro.launch.serve import serve

    m = serve("qwen2.5-3b", requests=2, prompt_len=8, new_tokens=4, batch=2)
    assert m["requests"] == 2


def test_input_specs_cover_all_pairs():
    from repro.configs.registry import REGISTRY
    from repro.configs.shapes import SHAPES
    from repro.configs.runtime import RunConfig
    from repro.launch.specs import input_specs

    for arch, cfg in REGISTRY.items():
        for shape in SHAPES.values():
            spec = input_specs(cfg, shape, RunConfig())
            assert spec, (arch, shape.name)
            if shape.kind == "decode":
                assert spec["tokens"].shape == (shape.global_batch, 1)
                assert "length" in spec["cache"]
