"""Equivalence tests for the vectorized analytics hot path: batched dCor
(core + Pallas twin), batched perf/power model, and the array-based oracle
sweep must match their scalar counterparts."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dcov import dcor, dcor_all
from repro.core.space import jetson_like_space, tpu_pod_space
from repro.core.baselines import alert, oracle, oracle_scalar
from repro.device import DeviceSimulator, jetson_like_simulator, synthetic_terms
from repro.device.perfmodel import canon, canon_columns
from repro.kernels.dcov import dcor_all_pallas, dcov_gram_pallas, dcov_gram_ref


# ----------------------------------------------------------- batched dCor
def test_dcor_all_matches_per_pair_loop():
    rng = np.random.default_rng(0)
    w, d, m = 10, 5, 2
    s = rng.normal(size=(w, d)).astype(np.float32)
    mm = rng.normal(size=(w, m)).astype(np.float32)
    batched = np.asarray(dcor_all(jnp.asarray(s), jnp.asarray(mm), np.int32(w)))
    for i in range(d):
        for j in range(m):
            ref = float(dcor(jnp.asarray(mm[:, j]), jnp.asarray(s[:, i])))
            assert batched[i, j] == pytest.approx(ref, abs=1e-5)


def test_coral_correlations_match_legacy_loop():
    """The rewired single-call correlations() equals the per-dim loop."""
    from repro.core import CORAL
    from repro.core.dcov import dcor_numpy

    space = tpu_pod_space()
    opt = CORAL(space, tau_target=10.0, p_budget=100.0, window=10)
    rng = np.random.default_rng(0)
    for _ in range(7):  # partial window on purpose
        cfg = space.random(rng)
        opt.observe(cfg, 10 + rng.random() * 5, 50 + rng.random() * 10)
    alpha, beta = opt.correlations()
    hist = opt.state.history[-opt.window:]
    taus = np.array([o.tau for o in hist], np.float32)
    pows = np.array([o.power for o in hist], np.float32)
    for i in range(len(space.dims)):
        s = np.array([o.config[i] for o in hist], np.float32)
        assert alpha[i] == pytest.approx(dcor_numpy(taus, s), abs=1e-5)
        assert beta[i] == pytest.approx(dcor_numpy(pows, s), abs=1e-5)


@pytest.mark.parametrize("n,block", [(30, 64), (200, 128)])
def test_dcor_all_pallas_matches_core(n, block):
    rng = np.random.default_rng(1)
    s = jnp.asarray(rng.normal(size=(n, 5)), jnp.float32)
    m = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    a = np.asarray(dcor_all_pallas(s, m, block=block))
    b = np.asarray(dcor_all(s, m, np.int32(n)))
    np.testing.assert_allclose(a, b, atol=1e-5)


def test_dcov_gram_pallas_matches_ref():
    rng = np.random.default_rng(2)
    cols = jnp.asarray(rng.normal(size=(100, 7)), jnp.float32)
    g_kernel = np.asarray(dcov_gram_pallas(cols, block=64))
    g_ref = np.asarray(dcov_gram_ref(cols))
    np.testing.assert_allclose(g_kernel, g_ref, rtol=1e-4, atol=1e-3)


# ------------------------------------------------------ batched perf model
@pytest.fixture(scope="module")
def pod_dev():
    return DeviceSimulator(tpu_pod_space(), synthetic_terms("balanced"), noise=0.0)


def test_throughput_power_batch_match_scalar_full_grid(pod_dev):
    grid = pod_dev.space.grid()
    cols = canon_columns(pod_dev.space.names, grid)
    tau_b = pod_dev.perf.throughput_batch(cols)
    p_b = pod_dev.power_model.power_batch(cols)
    for k in range(0, grid.shape[0], 97):  # stride through all dims' levels
        d = canon(dict(zip(pod_dev.space.names, grid[k])))
        assert tau_b[k] == pytest.approx(pod_dev.perf.throughput(d), rel=1e-12)
        assert p_b[k] == pytest.approx(pod_dev.power_model.power(d), rel=1e-12)


def test_exact_all_matches_exact(pod_dev):
    grid = pod_dev.space.grid()[::53]
    tau_b, p_b = pod_dev.exact_all(grid)
    scalar = [pod_dev.exact(tuple(r)) for r in grid]
    np.testing.assert_allclose(tau_b, [t for t, _ in scalar], rtol=1e-12)
    np.testing.assert_allclose(p_b, [p for _, p in scalar], rtol=1e-12)


def test_measure_all_matches_scalar_noise_stream():
    sp = jetson_like_space("xavier_nx")
    grid = sp.grid()[:40]
    d_batch = jetson_like_simulator(sp, 1.0, seed=5, noise=0.03)
    d_scalar = jetson_like_simulator(sp, 1.0, seed=5, noise=0.03)
    tau_b, p_b = d_batch.measure_all(grid)
    scalar = [d_scalar.measure(tuple(r)) for r in grid]
    np.testing.assert_allclose(tau_b, [t for t, _ in scalar], rtol=1e-12)
    np.testing.assert_allclose(p_b, [p for _, p in scalar], rtol=1e-12)
    assert d_batch.n_measurements == d_scalar.n_measurements == 40


# ------------------------------------------------------- vectorized oracle
@pytest.mark.parametrize("tau_target,p_budget", [
    (0.0, float("inf")),        # single-target: max throughput
    (30.0, float("inf")),       # throughput-constrained efficiency
    (30.0, 25.0),               # dual constraint
    (1e9, float("inf")),        # infeasible everywhere
])
def test_vectorized_oracle_identical_to_scalar(tau_target, p_budget):
    sp = jetson_like_space("xavier_nx")
    dev = jetson_like_simulator(sp, 1.0, seed=0, noise=0.0)
    vec = oracle(sp, dev, tau_target, p_budget)
    ref = oracle_scalar(sp, dev, tau_target, p_budget)
    assert vec.config == ref.config
    assert vec.tau == pytest.approx(ref.tau, rel=1e-12)
    assert vec.power == pytest.approx(ref.power, rel=1e-12)
    assert vec.measurements == ref.measurements


def test_oracle_scalar_device_fallback():
    """A device exposing only scalar exact() still works (loop fallback)."""
    sp = jetson_like_space("xavier_nx")
    inner = jetson_like_simulator(sp, 1.0, noise=0.0)

    class ScalarOnly:
        def exact(self, cfg):
            return inner.exact(cfg)

    vec = oracle(sp, inner, 30.0)
    fall = oracle(sp, ScalarOnly(), 30.0)
    assert vec.config == fall.config


def test_alert_lexsort_selection_matches_scalar_max():
    """The lexsort pick must equal the original max(key=(pred, -power))
    over the profile dict, at every Kalman gain (incl. tie-heavy targets)."""
    sp = jetson_like_space("xavier_nx")
    dev = jetson_like_simulator(sp, 1.0, seed=2, noise=0.02)
    grid = sp.grid()
    tau_prof, p_prof = dev.measure_all(grid)
    configs = [tuple(float(v) for v in row) for row in grid]
    for tau_target in (0.0, 30.0, 1e9):
        for xi in (0.5, 1.0, 1.7):
            pred = tau_prof * xi
            meets = pred >= tau_target
            pool = meets if meets.any() else np.ones_like(meets)
            idx = int(np.lexsort((p_prof, -np.where(pool, pred, -np.inf)))[0])
            # scalar reference: first max over the profile in grid order
            cand = [k for k in range(len(configs)) if pool[k]]
            ref = max(cand, key=lambda k: (pred[k], -p_prof[k]))
            assert configs[idx] == configs[ref]


def test_alert_profiles_in_one_batched_sweep():
    """ALERT's offline profiling counts the full grid in one sweep and the
    online loop still measures once per iteration."""
    sp = jetson_like_space("xavier_nx")
    dev = jetson_like_simulator(sp, 1.0, seed=1, noise=0.02)
    out = alert(sp, dev, tau_target=30.0, online_iters=10)
    assert out.config is not None
    assert out.measurements == sp.size() + 10
    assert dev.n_measurements == sp.size() + 10
