"""Training substrate (loss goes down, checkpoint roundtrip) and serving
engine/runtime integration."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import REGISTRY
from repro.configs.runtime import RunConfig
from repro.models import ApplyCtx, init_model_params
from repro.serving import Request, ServingEngine, ServingRuntime
from repro.training import AdamWConfig, SyntheticLM, make_train_step
from repro.training import checkpoint as ckpt
from repro.training.adamw import init as adamw_init
from repro.training.train_step import cross_entropy


def test_cross_entropy_matches_uniform():
    logits = jnp.zeros((2, 3, 7))
    labels = jnp.zeros((2, 3), jnp.int32)
    assert float(cross_entropy(logits, labels)) == pytest.approx(np.log(7), rel=1e-5)


def test_loss_decreases_tiny_model():
    cfg = REGISTRY["qwen2.5-3b"].reduced()
    rcfg = RunConfig(remat="none", moe_impl="dense")
    ctx = ApplyCtx(cfg, rcfg, None)
    params = init_model_params(jax.random.PRNGKey(0), cfg, rcfg)
    opt_cfg = AdamWConfig(lr=2e-3, warmup_steps=2, total_steps=40, weight_decay=0.0)
    step = jax.jit(make_train_step(ctx, opt_cfg), donate_argnums=(0, 1))
    opt = adamw_init(params)
    data = SyntheticLM(cfg.vocab, seq_len=32, global_batch=8, seed=0)
    losses = []
    for i in range(30):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses[:3] + losses[-3:]


def test_synthetic_data_deterministic_and_shaped():
    d = SyntheticLM(vocab=100, seq_len=16, global_batch=4, seed=3)
    b1, b2 = d.batch(7), d.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 16) and b1["labels"].shape == (4, 16)
    assert b1["tokens"].max() < 100
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_checkpoint_roundtrip(tmp_path):
    cfg = REGISTRY["qwen2.5-3b"].reduced()
    rcfg = RunConfig()
    params = init_model_params(jax.random.PRNGKey(0), cfg, rcfg)
    opt = adamw_init(params)
    path = os.path.join(tmp_path, "ckpt")
    ckpt.save(path, params, opt, step=11, meta={"arch": cfg.name})
    p2, o2, step = ckpt.restore(path, params, opt)
    assert step == 11
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        params, p2,
    )


def test_engine_generate_and_greedy_consistency():
    cfg = REGISTRY["qwen2.5-3b"].reduced()
    rcfg = RunConfig(remat="none", moe_impl="dense")
    ctx = ApplyCtx(cfg, rcfg, None)
    params = init_model_params(jax.random.PRNGKey(0), cfg, rcfg)
    eng = ServingEngine(ctx, params, batch_size=2, max_len=64)
    prompt = np.zeros((2, 8), np.int32)
    out = eng.generate(prompt, n_tokens=5)
    assert out.shape == (2, 5)
    out2 = eng.generate(prompt, n_tokens=5)
    np.testing.assert_array_equal(out, out2)  # greedy is deterministic


def test_runtime_drain_metrics():
    cfg = REGISTRY["qwen2.5-3b"].reduced()
    rcfg = RunConfig(remat="none", moe_impl="dense")
    ctx = ApplyCtx(cfg, rcfg, None)
    params = init_model_params(jax.random.PRNGKey(0), cfg, rcfg)
    eng = ServingEngine(ctx, params, batch_size=2, max_len=64)
    rt = ServingRuntime(eng, batch_size=2, concurrency=2)
    rng = np.random.default_rng(0)
    for rid in range(4):
        rt.submit(Request(rid, rng.integers(0, cfg.vocab, 8, dtype=np.int32), 4))
    m = rt.drain()
    assert m["requests"] == 4
    assert m["throughput_tok_s"] > 0
    assert m["p99_latency_s"] >= m["p50_latency_s"]


def test_walltime_device_integration():
    """CORAL against *measured* throughput of a real reduced model."""
    from repro.core import run_coral, tpu_pod_space
    from repro.device.measure import WalltimeDevice

    cfg = REGISTRY["qwen2.5-3b"].reduced()
    rcfg = RunConfig(remat="none", moe_impl="dense")
    ctx = ApplyCtx(cfg, rcfg, None)
    params = init_model_params(jax.random.PRNGKey(0), cfg, rcfg)
    eng = ServingEngine(ctx, params, batch_size=2, max_len=64)
    space = tpu_pod_space()
    dev = WalltimeDevice(space, eng, prompt_len=8, steps=4)
    tau0, p0 = dev.measure(space.preset("default"))
    assert tau0 > 0 and p0 > 0
    out, _ = run_coral(space, dev, tau_target=tau0 * 0.5, iters=6)
    assert out.config is not None
    assert out.tau >= tau0 * 0.45
