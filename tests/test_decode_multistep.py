"""Multi-step decode consistency: N successive decode_step calls must
reproduce the teacher-forced forward logits at every position — across the
attention (ring cache), MLA (latent cache), SSM (recurrent state) and
hybrid (both) families. Hypothesis-based property tests live in
test_properties.py (optional dependency)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import REGISTRY
from repro.configs.runtime import RunConfig
from repro.models import (
    ApplyCtx,
    decode_step,
    forward_train,
    init_model_params,
    prefill,
)

RCFG = RunConfig(remat="none", moe_impl="dense")
B, S, N_DEC = 2, 24, 4  # prefill S-N_DEC tokens, decode the last N_DEC


@pytest.mark.parametrize(
    "name",
    ["qwen2.5-3b", "deepseek-v2-236b", "mamba2-2.7b", "hymba-1.5b",
     "whisper-medium", "qwen2-vl-72b"],
)
def test_multistep_decode_matches_forward(name):
    cfg = REGISTRY[name].reduced()
    ctx = ApplyCtx(cfg, RCFG, None)
    params = init_model_params(jax.random.PRNGKey(0), cfg, RCFG)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens}
    if cfg.n_vision_tokens:
        batch["vision_embeds"] = jnp.ones(
            (B, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16) * 0.02
    if cfg.is_encoder_decoder:
        batch["enc_feats"] = jnp.ones(
            (B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16) * 0.02

    full_logits, _ = jax.jit(lambda p, b: forward_train(ctx, p, b))(params, batch)

    pre = dict(batch)
    pre["tokens"] = tokens[:, : S - N_DEC]
    cache, _ = jax.jit(lambda p, b: prefill(ctx, p, b, capacity=S))(params, pre)
    dec = jax.jit(lambda p, c, t: decode_step(ctx, p, c, t))
    for i in range(S - N_DEC, S):
        cache, logits = dec(params, cache, tokens[:, i : i + 1])
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(full_logits[:, i], np.float32),
            atol=0.15, rtol=0.08,
            err_msg=f"{name}: decode step at position {i} diverged",
        )
    assert int(cache["length"]) == S
