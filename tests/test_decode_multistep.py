"""Multi-step decode consistency: N successive decode_step calls must
reproduce the teacher-forced forward logits at every position — across the
attention (ring cache), MLA (latent cache), SSM (recurrent state) and
hybrid (both) families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.registry import REGISTRY
from repro.configs.runtime import RunConfig
from repro.models import (
    ApplyCtx,
    decode_step,
    forward_train,
    init_model_params,
    prefill,
)

RCFG = RunConfig(remat="none", moe_impl="dense")
B, S, N_DEC = 2, 24, 4  # prefill S-N_DEC tokens, decode the last N_DEC


@pytest.mark.parametrize(
    "name",
    ["qwen2.5-3b", "deepseek-v2-236b", "mamba2-2.7b", "hymba-1.5b",
     "whisper-medium", "qwen2-vl-72b"],
)
def test_multistep_decode_matches_forward(name):
    cfg = REGISTRY[name].reduced()
    ctx = ApplyCtx(cfg, RCFG, None)
    params = init_model_params(jax.random.PRNGKey(0), cfg, RCFG)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens}
    if cfg.n_vision_tokens:
        batch["vision_embeds"] = jnp.ones(
            (B, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16) * 0.02
    if cfg.is_encoder_decoder:
        batch["enc_feats"] = jnp.ones(
            (B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16) * 0.02

    full_logits, _ = jax.jit(lambda p, b: forward_train(ctx, p, b))(params, batch)

    pre = dict(batch)
    pre["tokens"] = tokens[:, : S - N_DEC]
    cache, _ = jax.jit(lambda p, b: prefill(ctx, p, b, capacity=S))(params, pre)
    dec = jax.jit(lambda p, c, t: decode_step(ctx, p, c, t))
    for i in range(S - N_DEC, S):
        cache, logits = dec(params, cache, tokens[:, i : i + 1])
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(full_logits[:, i], np.float32),
            atol=0.15, rtol=0.08,
            err_msg=f"{name}: decode step at position {i} diverged",
        )
    assert int(cache["length"]) == S


# ---------------------------------------------------------------------------
# CORAL state-machine invariants under arbitrary observation sequences
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    st.lists(
        st.tuples(st.floats(0.1, 100.0), st.floats(0.1, 100.0)),
        min_size=1, max_size=12,
    ),
    st.floats(1.0, 50.0),
    st.floats(5.0, 80.0),
)
def test_property_coral_invariants(measurements, tau_target, p_budget):
    from repro.core import tpu_pod_space
    from repro.core.coral import CORAL

    space = tpu_pod_space()
    opt = CORAL(space, tau_target, p_budget, seed=0)
    for tau, p in measurements:
        cfg = opt.propose()
        assert cfg not in opt.state.prohibited, "proposed a prohibited config"
        for v, d in zip(cfg, space.dims):
            assert v in d.values, "proposal off the grid"
        opt.observe(cfg, tau, p)
        st_ = opt.state
        # best has the max reward seen; second is <= best
        assert st_.best.reward == max(o.reward for o in st_.history)
        if st_.second is not None:
            assert st_.second.reward <= st_.best.reward
        # prohibited configs are exactly the infeasible observations
        for o in st_.history:
            infeasible = o.tau < tau_target or o.power > p_budget
            assert (o.config in st_.prohibited) == any(
                (h.config == o.config and (h.tau < tau_target or h.power > p_budget))
                for h in st_.history
            ) or not infeasible
    res = opt.result()
    feas = [o for o in opt.state.history
            if o.tau >= tau_target and o.power <= p_budget]
    if feas:
        assert res.tau >= tau_target and res.power <= p_budget
