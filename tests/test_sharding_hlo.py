"""Sharding rules + HLO cost-model tests (multi-device paths exercised in
a subprocess with forced host devices — conftest keeps this process at 1)."""
import subprocess
import sys
import textwrap

import pytest

from repro.launch.hlo_analysis import HloCostModel, _shape_info
from repro.sharding.specs import RULE_SETS, spec_for_axes


class _FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


def test_spec_for_axes_divisible():
    p = spec_for_axes(
        _FakeMesh(), ("embed", "ff"), (4096, 14336), RULE_SETS["megatron_fsdp"]
    )
    assert tuple(p) == ("data", "model")


def test_spec_for_axes_indivisible_replicates():
    # a 24-wide kv projection cannot shard over a 16-way model axis
    p = spec_for_axes(
        _FakeMesh(), ("embed", "kv_heads_flat"), (2048, 24),
        RULE_SETS["megatron_fsdp"],
    )
    assert tuple(p) == ("data", None)


def test_spec_no_axis_reuse():
    p = spec_for_axes(
        _FakeMesh(), ("ff", "experts"), (1536, 160), RULE_SETS["megatron_fsdp"]
    )
    # both map to "model" but an axis may be used once
    assert tuple(p).count("model") == 1


def test_shape_info():
    b, dims = _shape_info("bf16[16,4096,2048]{2,1,0}")
    assert b == 16 * 4096 * 2048 * 2
    assert dims == [16, 4096, 2048]
    b2, _ = _shape_info("(f32[8,8], s32[])")
    assert b2 == 8 * 8 * 4 + 4


HLO_FIXTURE = textwrap.dedent(
    """
    HloModule test

    %body (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
      %p = (s32[], f32[4,4]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[4,4]{1,0} get-tuple-element(%p), index=1
      %one = s32[] constant(1)
      %ni = s32[] add(%i, %one)
      %y = f32[4,4]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[4,4]{1,0} all-reduce(%y), replica_groups={{0,1,2,3}}, to_apply=%sum
      ROOT %t = (s32[], f32[4,4]) tuple(%ni, %ar)
    }

    %cond (p: (s32[], f32[4,4])) -> pred[] {
      %p = (s32[], f32[4,4]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %n = s32[] constant(10)
      ROOT %lt = pred[] compare(%i, %n), direction=LT
    }

    ENTRY %main (a: f32[4,4]) -> f32[4,4] {
      %a = f32[4,4]{1,0} parameter(0)
      %zero = s32[] constant(0)
      %init = (s32[], f32[4,4]) tuple(%zero, %a)
      %w = (s32[], f32[4,4]) while(%init), condition=%cond, body=%body
      ROOT %out = f32[4,4]{1,0} get-tuple-element(%w), index=1
    }
    """
)


def test_cost_model_loop_multiplication():
    m = HloCostModel(HLO_FIXTURE)
    c = m.total()
    # dot: 2*4*4*4 = 128 flops, ×10 trips
    assert c.flops == pytest.approx(128 * 10)
    # all-reduce operand = result bytes = 64 floats? (4x4 f32 = 64B), ×10
    assert c.collectives["all-reduce"] == pytest.approx(64 * 10)


def test_cost_model_on_real_scan():
    """Compiled lax.scan of matmuls: flops must scale with trip count."""
    import jax
    import jax.numpy as jnp

    n, trips = 64, 7

    def f(x, w):
        def body(c, _):
            return jnp.dot(c, w, preferred_element_type=jnp.float32), None

        y, _ = jax.lax.scan(body, x, None, length=trips)
        return y

    compiled = (
        jax.jit(f)
        .lower(
            jax.ShapeDtypeStruct((n, n), jnp.float32),
            jax.ShapeDtypeStruct((n, n), jnp.float32),
        )
        .compile()
    )
    c = HloCostModel(compiled.as_text()).total()
    expect = 2 * n**3 * trips
    assert c.flops == pytest.approx(expect, rel=0.05), (c.flops, expect)


SUBPROC_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.registry import REGISTRY
from repro.configs.runtime import RunConfig
from repro.models import moe as moe_lib
from repro.models.layers import init_params
mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = REGISTRY["qwen3-moe-235b-a22b"].reduced()
rcfg = RunConfig(capacity_factor=8.0)  # high cf: no dropping -> exact match
specs = moe_lib.moe_param_specs(cfg, 1)
params = init_params(jax.random.PRNGKey(0), specs, jnp.float32)
lp = jax.tree.map(lambda a: a[0], params)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model), jnp.float32)
y_dense, aux_d = moe_lib.moe_ffn_dense(cfg, lp, x)
with mesh:
    y_ep, aux_e = jax.jit(lambda xx: moe_lib.moe_ffn_ep(cfg, rcfg, mesh, lp, xx))(x)
err = float(jnp.max(jnp.abs(y_dense - y_ep)))
aerr = abs(float(aux_d) - float(aux_e))
assert err < 2e-4, f"EP vs dense mismatch: {err}"
# aux is estimated per model-shard token slice (pmean'd): a small-sample
# estimator of the dense global aux, not bit-identical
assert aerr < 0.5, f"aux mismatch: {aerr}"
print("EP_OK", err)
"""


def test_expert_parallel_matches_dense_subprocess():
    """The shard_map expert-parallel MoE must equal the dense reference
    (run with 8 forced host devices in a subprocess)."""
    r = subprocess.run(
        [sys.executable, "-c", SUBPROC_SNIPPET],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=__file__.rsplit("/tests/", 1)[0],
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "EP_OK" in r.stdout


SUBPROC_2D = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs.registry import REGISTRY
from repro.configs.runtime import RunConfig
from repro.models import moe as moe_lib
from repro.models.layers import init_params
mesh = jax.make_mesh((2, 4), ("data", "model"))
rcfg = RunConfig(capacity_factor=8.0)
for arch in ("qwen3-moe-235b-a22b", "deepseek-v2-236b"):
    cfg = REGISTRY[arch].reduced()
    specs = moe_lib.moe_param_specs(cfg, 1)
    params = init_params(jax.random.PRNGKey(0), specs, jnp.float32)
    lp = jax.tree.map(lambda a: a[0], params)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 2, cfg.d_model), jnp.float32)
    y_dense, _ = moe_lib.moe_ffn_dense(cfg, lp, x)
    with mesh:
        y_2d, _ = jax.jit(lambda xx: moe_lib.moe_ffn_ep2d(cfg, rcfg, mesh, lp, xx))(x)
    err = float(jnp.max(jnp.abs(y_dense - y_2d)))
    assert err < 2e-4, (arch, err)
print("EP2D_OK")
"""


def test_expert_parallel_2d_matches_dense_subprocess():
    """The serving 2-D expert sharding (experts x d_ff) must equal dense."""
    r = subprocess.run(
        [sys.executable, "-c", SUBPROC_2D],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=__file__.rsplit("/tests/", 1)[0],
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "EP2D_OK" in r.stdout


HLO_INPLACE_FIXTURE = textwrap.dedent(
    """
    HloModule inplace

    %fused_computation (p0: f32[8,128], p1: f32[1,128], p2: s32[]) -> f32[8,128] {
      %p0 = f32[8,128]{1,0} parameter(0)
      %p1 = f32[1,128]{1,0} parameter(1)
      %p2 = s32[] parameter(2)
      %z = s32[] constant(0)
      ROOT %dus = f32[8,128]{1,0} dynamic-update-slice(%p0, %p1, %p2, %z)
    }

    ENTRY %main (a: f32[8,128], u: f32[1,128], i: s32[]) -> f32[8,128] {
      %a = f32[8,128]{1,0} parameter(0)
      %u = f32[1,128]{1,0} parameter(1)
      %i = s32[] parameter(2)
      ROOT %f = f32[8,128]{1,0} fusion(%a, %u, %i), kind=kLoop, calls=%fused_computation
    }
    """
)


def test_cost_model_inplace_dus_fusion():
    """In-place cache-update fusions charge only the update slice."""
    m = HloCostModel(HLO_INPLACE_FIXTURE)
    c = m.total()
    # 2 × (update 1×128×4B + index 4B) — NOT the 8×128 buffer
    assert c.bytes <= 2 * (128 * 4 + 8), c.bytes
