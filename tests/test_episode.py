"""Episode-engine equivalence: compiled lax.scan episodes must replay
the scalar interpreter loops' selections exactly.

The contract (see repro/core/episode.py): same seeds ⇒ identical chosen
configs at every step, identical final picks, and τ/p traces equal to
the scalar measurements (reconstructed in float64 from the same
landscape × noise products, so equality is exact — the tolerance in the
assertions is pure paranoia). Three cell families are pinned: a strict
dual-constraint cell, a throughput-mode cell, and a thermal-ramp drift
cell (adaptive + static ablation)."""

import numpy as np
import pytest

from repro.core.episode import (
    alert_online_outcome,
    preset_outcome,
    run_coral_batch,
    run_drift_requests,
)
from repro.core.evaluate import run_drift_regime, run_regime
from repro.core.baselines import alert_online, preset
from repro.experiments.scenarios import (
    DRIFT_INTERVALS,
    DRIFTS,
    REGIMES,
    WORKLOADS,
    Cell,
    cell_simulator,
    drifting_cell_simulator,
    resolve_targets,
)

SEEDS = (0, 1, 2)

DUAL_CELL = Cell("edge-xavier-nx", "qwen2.5-3b", "decode_steady", "strict_dual")
THROUGHPUT_CELL = Cell(
    "edge-orin-nano", "granite-8b", "decode_steady", "max_throughput"
)
DRIFT_CELL = Cell("edge-orin-nx", "qwen2.5-3b", "decode_steady", "thermal-ramp")


def _static_equiv(cell):
    sim0 = cell_simulator(cell, noise=0.0)
    targets = resolve_targets(cell, sim0)
    land_tau, land_p = sim0.exact_all()
    noise = WORKLOADS[cell.workload].noise
    eps = run_coral_batch(
        sim0.space, land_tau, land_p, targets, SEEDS, noise=noise
    )
    for seed, ep in zip(SEEDS, eps):
        dev = cell_simulator(cell, seed=seed)
        out, tr = run_regime(sim0.space, dev, targets, seed=seed)
        assert [tuple(c) for c in tr.configs] == [
            tuple(c) for c in ep.configs
        ], f"seed {seed}: chosen configs diverge"
        np.testing.assert_allclose(tr.taus, ep.taus, rtol=1e-12)
        np.testing.assert_allclose(tr.powers, ep.powers, rtol=1e-12)
        np.testing.assert_allclose(tr.rewards, ep.rewards, rtol=1e-12)
        assert tuple(out.config) == tuple(ep.outcome.config)
        assert out.tau == pytest.approx(ep.outcome.tau, rel=1e-12)
        assert out.power == pytest.approx(ep.outcome.power, rel=1e-12)


def test_compiled_matches_scalar_on_dual_cell():
    _static_equiv(DUAL_CELL)


def test_compiled_matches_scalar_on_throughput_cell():
    _static_equiv(THROUGHPUT_CELL)


@pytest.mark.parametrize("adaptive", [True, False])
def test_compiled_matches_scalar_on_thermal_ramp_drift_cell(adaptive):
    cell = DRIFT_CELL
    regime = REGIMES[cell.regime]
    sched = DRIFTS[regime.drift]
    sim0 = cell_simulator(cell, noise=0.0)
    targets = resolve_targets(cell, sim0)
    noise = WORKLOADS[cell.workload].noise

    from repro.device.simulator import DriftingSimulator

    twin = DriftingSimulator(cell_simulator(cell, noise=0.0), sched)
    land_tau, land_p = twin.landscapes(DRIFT_INTERVALS)
    scale = sched.states_stacked(DRIFT_INTERVALS)["budget_scale"]
    reqs = [
        dict(
            space=sim0.space,
            land_tau=land_tau,
            land_p=land_p,
            budget_scale=scale,
            targets=targets,
            seed=seed,
            noise=noise,
            adaptive=adaptive,
        )
        for seed in SEEDS
    ]
    eps = run_drift_requests(reqs, intervals=DRIFT_INTERVALS)
    for seed, ep in zip(SEEDS, eps):
        dev = drifting_cell_simulator(cell, seed=seed)
        opt, tr = run_drift_regime(
            sim0.space, dev, targets, sched, DRIFT_INTERVALS,
            seed=seed, adaptive=adaptive, sigma=noise,
        )
        assert [tuple(c) for c in tr.configs] == [
            tuple(c) for c in ep.configs
        ], f"seed {seed}: applied configs diverge"
        assert tr.exploring == ep.exploring
        assert tr.resets == ep.resets
        np.testing.assert_allclose(tr.taus, ep.taus, rtol=1e-12)
        np.testing.assert_allclose(tr.powers, ep.powers, rtol=1e-12)
        np.testing.assert_allclose(tr.budgets, ep.budgets, rtol=1e-12)
        res = opt.result()
        scalar_pick = tuple(res.config) if res is not None else None
        engine_pick = (
            tuple(ep.result_config) if ep.result_config is not None else None
        )
        assert scalar_pick == engine_pick


def test_open_loop_baselines_match_scalar():
    """ALERT-Online and the presets route through the engine's table
    twins under the compiled engine — Outcomes must be bitwise equal."""
    cell = DUAL_CELL
    sim0 = cell_simulator(cell, noise=0.0)
    targets = resolve_targets(cell, sim0)
    land_tau, land_p = sim0.exact_all()
    noise = WORKLOADS[cell.workload].noise
    ref = alert_online(
        sim0.space,
        cell_simulator(cell, seed=102),
        targets.tau_target,
        targets.p_budget,
        iters=10,
        seed=102,
    )
    got = alert_online_outcome(
        sim0.space, land_tau, land_p, targets, noise, 102, iters=10
    )
    assert (ref.config is None) == (got.config is None)
    if ref.config is not None:
        assert tuple(ref.config) == tuple(got.config)
        assert ref.tau == got.tau and ref.power == got.power
    for kind, seed in (("max_power", 103), ("default", 104)):
        ref = preset(sim0.space, cell_simulator(cell, seed=seed), kind)
        got = preset_outcome(sim0.space, land_tau, land_p, kind, noise, seed)
        assert tuple(ref.config) == tuple(got.config)
        assert ref.tau == got.tau and ref.power == got.power


def test_run_static_cell_records_identical_across_engines():
    """The whole per-cell record — scores, violation flags, baselines —
    is engine-independent."""
    from repro.experiments.matrix import run_static_cell

    a = run_static_cell(DUAL_CELL, seeds=(0, 1), engine="compiled")
    b = run_static_cell(DUAL_CELL, seeds=(0, 1), engine="scalar")
    assert a == b


def test_drift_cell_records_identical_across_engines():
    from repro.experiments.matrix import run_drift_cell

    a = run_drift_cell(DRIFT_CELL, seeds=(0,), engine="compiled")
    b = run_drift_cell(DRIFT_CELL, seeds=(0,), engine="scalar")
    assert a == b