"""Per-architecture smoke tests: REDUCED same-family variants (≤2 layers,
d_model ≤ 512, ≤4 experts) — one forward/train step on CPU, shape + NaN
checks, and prefill→decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, REGISTRY
from repro.configs.runtime import RunConfig
from repro.models import (
    ApplyCtx,
    decode_step,
    forward_train,
    init_model_params,
    prefill,
)
from repro.training import AdamWConfig, make_train_step
from repro.training.adamw import init as adamw_init

RCFG = RunConfig(remat="none", moe_impl="dense")
B, S = 2, 32


def _setup(name):
    cfg = REGISTRY[name].reduced()
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    ctx = ApplyCtx(cfg, RCFG, None)
    params = init_model_params(jax.random.PRNGKey(0), cfg, RCFG)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab),
    }
    if cfg.n_vision_tokens:
        batch["vision_embeds"] = jnp.ones(
            (B, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16
        ) * 0.02
    if cfg.is_encoder_decoder:
        batch["enc_feats"] = jnp.ones(
            (B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16
        ) * 0.02
    return cfg, ctx, params, batch


@pytest.mark.parametrize("name", ARCH_IDS)
def test_forward_shapes_and_no_nans(name):
    cfg, ctx, params, batch = _setup(name)
    logits, aux = jax.jit(lambda p, b: forward_train(ctx, p, b))(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.isnan(logits).any()), f"{name}: NaN in logits"
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("name", ARCH_IDS)
def test_one_train_step(name):
    cfg, ctx, params, batch = _setup(name)
    step = jax.jit(make_train_step(ctx, AdamWConfig(lr=1e-3, warmup_steps=1,
                                                    total_steps=10)))
    opt = adamw_init(params)
    new_params, _, metrics = step(params, opt, batch)
    assert jnp.isfinite(metrics["loss"]), f"{name}: non-finite loss"
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()), params, new_params),
    )
    assert delta > 0


@pytest.mark.parametrize("name", ARCH_IDS)
def test_prefill_decode_consistency(name):
    """decode(prefill(t_0..t_{n-1}), t_n) logits must match
    forward(t_0..t_n) at position n."""
    cfg, ctx, params, batch = _setup(name)
    full_logits, _ = jax.jit(lambda p, b: forward_train(ctx, p, b))(params, batch)
    pre_batch = dict(batch)
    pre_batch.pop("labels", None)
    pre_batch["tokens"] = batch["tokens"][:, : S - 1]
    cache, pl_logits = jax.jit(lambda p, b: prefill(ctx, p, b, capacity=S))(params, pre_batch)
    # prefill last-logit == forward logit at S-2
    np.testing.assert_allclose(
        np.asarray(pl_logits[:, 0], np.float32),
        np.asarray(full_logits[:, S - 2], np.float32),
        atol=0.1, rtol=0.05,
    )
    new_cache, dec_logits = jax.jit(lambda p, c, t: decode_step(ctx, p, c, t))(
        params, cache, batch["tokens"][:, S - 1 :]
    )
    assert int(new_cache["length"]) == S
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, 0], np.float32),
        np.asarray(full_logits[:, S - 1], np.float32),
        atol=0.1, rtol=0.05,
    )


def test_param_counts_match_assignment():
    """Analytic parameter counts of the FULL configs match the assigned
    model sizes (±10% where the assignment's own numbers allow)."""
    expect = {
        "granite-8b": 8.2e9,
        "qwen2-vl-72b": 72.7e9,
        "mamba2-2.7b": 2.8e9,
        "deepseek-v2-236b": 236e9,
        "internlm2-20b": 19.9e9,
        "whisper-medium": 1.0e9,
        "qwen3-moe-235b-a22b": 235e9,
        "qwen2.5-3b": 3.4e9,
        "hymba-1.5b": 1.6e9,
    }
    for name, n in expect.items():
        got = REGISTRY[name].n_params()
        assert abs(got - n) / n < 0.1, (name, got, n)
    assert REGISTRY["deepseek-v2-236b"].n_active_params() == pytest.approx(
        21.4e9, rel=0.1
    )
    assert REGISTRY["qwen3-moe-235b-a22b"].n_active_params() == pytest.approx(
        22e9, rel=0.1
    )
