"""Scenario-matrix harness tests: cell enumeration, per-device landscapes,
record schema, and the paper's dual-constraint story (presets violate the
power budget, CORAL stays feasible)."""

import json

import pytest

from repro.configs.registry import get_config
from repro.core.baselines import oracle, preset
from repro.core.evaluate import RegimeTargets, measurements_to_feasible, run_regime
from repro.device import build_cell_simulator, get_profile
from repro.experiments import (
    MATRIX_DEVICES,
    MATRIX_MODELS,
    MATRIX_REGIMES,
    MATRIX_WORKLOADS,
    REGIMES,
    WORKLOADS,
    Cell,
    cell_simulator,
    enumerate_cells,
    resolve_targets,
    run_static_cell,
    run_matrix,
    validate_matrix_record,
)
from repro.experiments.report import markdown_report
from repro.experiments.schema import _check  # structural fallback validator

DUAL_CELL = Cell("edge-xavier-nx", "qwen2.5-3b", "decode_steady", "strict_dual")


# ---------------------------------------------------------------- enumeration
def test_enumeration_is_exhaustive_and_deterministic():
    cells = enumerate_cells()
    assert len(cells) == (
        len(MATRIX_DEVICES)
        * len(MATRIX_MODELS)
        * len(MATRIX_WORKLOADS)
        * len(MATRIX_REGIMES)
    )
    assert len(set(c.key() for c in cells)) == len(cells)  # no duplicates
    assert cells == enumerate_cells()  # stable order
    # every axis value appears
    assert {c.device for c in cells} == set(MATRIX_DEVICES)
    assert {c.model for c in cells} == set(MATRIX_MODELS)
    assert {c.regime for c in cells} == set(MATRIX_REGIMES)
    # axis-major order: devices outermost
    assert [c.device for c in cells] == sorted(
        [c.device for c in cells], key=list(MATRIX_DEVICES).index
    )


def test_enumeration_rejects_unknown_names():
    with pytest.raises(KeyError):
        enumerate_cells(devices=("no-such-device",))
    with pytest.raises(KeyError):
        enumerate_cells(regimes=("no-such-regime",))
    with pytest.raises(KeyError):
        enumerate_cells(workloads=("no-such-trace",))


def test_matrix_axes_meet_paper_grid_shape():
    """The acceptance grid: ≥2 devices × ≥3 models × ≥3 regimes including
    one strict dual-constraint regime."""
    assert len(MATRIX_DEVICES) >= 2
    assert len(MATRIX_MODELS) >= 3
    assert len(MATRIX_REGIMES) >= 3
    assert any(REGIMES[r].dual_constraint for r in MATRIX_REGIMES)
    assert any(REGIMES[r].single_target for r in MATRIX_REGIMES)


# ------------------------------------------------------------- device models
def test_device_profiles_produce_distinct_oracle_optima():
    """The PolyThrottle observation: per-device tuning landscapes differ
    enough that one device's optimum does not transfer."""
    outs = {}
    for dev in MATRIX_DEVICES:
        sim = build_cell_simulator(
            get_profile(dev), get_config("granite-8b"), noise=0.0
        )
        outs[dev] = oracle(sim.space, sim, tau_target=0.0)
    taus = [round(o.tau, 6) for o in outs.values()]
    assert len(set(taus)) == len(taus), "devices share a τ optimum"
    # normalized knob positions differ too (spaces differ, so compare the
    # relative position of each chosen knob within its ladder)

    def rel(dev, out):
        space = get_profile(dev).space()
        return tuple(
            d.values.index(v) / (len(d.values) - 1)
            for d, v in zip(space.dims, out.config)
        )

    positions = {dev: rel(dev, o) for dev, o in outs.items()}
    assert len(set(positions.values())) == len(positions)


def test_cell_simulator_heterogeneity_across_models_and_workloads():
    prof = get_profile("edge-xavier-nx")
    small = build_cell_simulator(prof, get_config("qwen2.5-3b"), noise=0.0)
    large = build_cell_simulator(prof, get_config("internlm2-20b"), noise=0.0)
    assert oracle(small.space, small, 0.0).tau > 2 * oracle(large.space, large, 0.0).tau
    decode = build_cell_simulator(prof, get_config("qwen2.5-3b"), kind="decode")
    prefill = build_cell_simulator(prof, get_config("qwen2.5-3b"), kind="prefill")
    # decode streams weights (memory-bound); prefill is compute-bound
    assert decode.perf.terms.t_memory > decode.perf.terms.t_compute
    assert prefill.perf.terms.t_compute > prefill.perf.terms.t_memory


def test_resolve_targets_shapes():
    cell = Cell("edge-orin-nano", "granite-8b", "decode_steady", "strict_dual")
    t = resolve_targets(cell)
    assert t.mode == "dual" and t.capped and t.tau_target > 0
    t1 = resolve_targets(
        Cell("edge-orin-nano", "granite-8b", "decode_steady", "max_throughput")
    )
    assert t1.mode == "throughput" and not t1.capped


# ------------------------------------------------------------------- regimes
def test_run_regime_and_measurements_to_feasible():
    cell = Cell("edge-xavier-nx", "granite-8b", "decode_steady", "single_tau")
    sim0 = cell_simulator(cell, noise=0.0)
    targets = resolve_targets(cell, sim0)
    out, tr = run_regime(sim0.space, cell_simulator(cell, seed=0), targets, iters=10)
    assert out.config is not None
    assert len(tr.taus) == 10
    m2f = measurements_to_feasible(tr, targets)
    assert m2f is not None and 1 <= m2f <= 10
    # a trace that never meets the target reports None
    never = RegimeTargets(mode="dual", tau_target=float("inf"))
    assert measurements_to_feasible(tr, never) is None


def test_dual_constraint_presets_violate_budget_coral_stays_feasible():
    """The paper's §IV-C headline: under a strict power cap the static
    presets bust the budget while CORAL lands inside it."""
    sim0 = cell_simulator(DUAL_CELL, noise=0.0)
    targets = resolve_targets(DUAL_CELL, sim0)
    # max-power preset truly exceeds the cap (noise-free evaluation)
    mp = preset(sim0.space, cell_simulator(DUAL_CELL, seed=103), "max_power")
    _, mp_power = sim0.exact(mp.config)
    assert mp_power > targets.p_budget
    # CORAL's chosen config, noise-free, stays inside both constraints
    for seed in (0, 1, 2):
        out, _ = run_regime(
            sim0.space, cell_simulator(DUAL_CELL, seed=seed), targets, seed=seed
        )
        tau, power = sim0.exact(out.config)
        assert power <= targets.p_budget * (1 + 1e-9), (seed, power)
        assert tau >= targets.tau_target * (1 - 1e-9), (seed, tau)


# ---------------------------------------------------------- record + schema
def test_run_static_cell_record_is_schema_shaped_and_scored():
    rec = run_static_cell(DUAL_CELL, iters=10, seeds=(0, 1))
    assert rec["coral"]["power_violations"] == 0
    assert rec["coral"]["score"] > 0.8
    assert rec["baselines"]["max_power"]["violates_power"]
    assert rec["oracle"]["measurements"] == rec["space_size"]
    assert rec["coral"]["measurements"] == 10


def test_matrix_record_validates_and_roundtrips(tmp_path):
    cells = enumerate_cells(
        devices=MATRIX_DEVICES[:2],
        models=("qwen2.5-3b",),
        regimes=("single_tau", "strict_dual"),
    )
    rec = run_matrix(cells, iters=10, seeds=(0,), quick=True)
    validate_matrix_record(rec)  # jsonschema if present, fallback otherwise
    errors = []
    from repro.experiments.schema import MATRIX_SCHEMA

    _check(rec, MATRIX_SCHEMA, "$", errors)  # always exercise the fallback
    assert not errors, errors
    # survives a JSON round-trip (what CI uploads / the gate reads)
    path = tmp_path / "BENCH_matrix.json"
    path.write_text(json.dumps(rec))
    validate_matrix_record(json.loads(path.read_text()))
    report = markdown_report(rec)
    assert "| edge-xavier-nx |" in report and "strict_dual" in report


def test_schema_rejects_malformed_records():
    rec = run_matrix(
        enumerate_cells(
            devices=("edge-orin-nano",),
            models=("qwen2.5-3b",),
            regimes=("max_throughput",),
        ),
        iters=5,
        seeds=(0,),
    )
    validate_matrix_record(rec)
    broken = json.loads(json.dumps(rec))
    del broken["cells"][0]["coral"]["score"]
    with pytest.raises(ValueError):
        validate_matrix_record(broken)
    broken2 = json.loads(json.dumps(rec))
    broken2["cells"][0]["mode"] = "neither"
    with pytest.raises(ValueError):
        validate_matrix_record(broken2)


def test_serving_controller_accepts_injected_profile():
    from repro.serving.controller import ServingController

    profile = get_profile("edge-xavier-nx")
    ctl = ServingController(
        runtime=object(),  # not exercised: constructor wiring only
        space=None,
        workload=iter(()),
        tau_target=10.0,
        profile=profile,
    )
    assert ctl.hw is profile.hw
    assert ctl.space.names == profile.space().names
    with pytest.raises(ValueError):
        ServingController(object(), None, iter(()), tau_target=1.0)


def test_workload_noise_reaches_simulator():
    cell = Cell("edge-orin-nano", "qwen2.5-3b", "decode_bursty", "single_tau")
    assert cell_simulator(cell).noise == WORKLOADS["decode_bursty"].noise
    assert cell_simulator(cell, noise=0.0).noise == 0.0
