import os
import sys

# NOTE: no XLA_FLAGS here by design — smoke tests and benches must see the
# single real device. Only repro.launch.dryrun forces 512 host devices.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)  # for the benchmarks package

# Env-flag handling is centralized in benchmarks.common — tests import
# these instead of reading os.environ ad hoc, so CI and local runs read
# every flag (QUICK, SERVING_PERF_STRICT, PALLAS_INTERPRET) identically.
from benchmarks.common import (  # noqa: E402,F401
    env_flag,
    pallas_interpret,
    quick,
    serving_perf_strict,
)
