import os
import sys

# NOTE: no XLA_FLAGS here by design — smoke tests and benches must see the
# single real device. Only repro.launch.dryrun forces 512 host devices.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
