"""Continuous-batching runtime: equal-length grouping, arrival admission,
slot refill, interval metrics, the concurrency→τ response (the knob was a
no-op before this runtime existed), and CORAL closed-loop over live
traffic."""

import jax
import numpy as np
import pytest

from repro.configs.registry import REGISTRY
from repro.configs.runtime import RunConfig
from repro.models import ApplyCtx, init_model_params
from repro.serving import (
    Request,
    ServingController,
    ServingEngine,
    ServingRuntime,
    measure_runtime_throughput,
    workload,
)

VOCAB = 512  # reduced() clamps qwen2.5-3b's vocab to this


@pytest.fixture(scope="module")
def engine():
    cfg = REGISTRY["qwen2.5-3b"].reduced()
    rcfg = RunConfig(remat="none", moe_impl="dense")
    ctx = ApplyCtx(cfg, rcfg, None)
    params = init_model_params(jax.random.PRNGKey(0), cfg, rcfg)
    eng = ServingEngine(ctx, params, batch_size=2, max_len=64)
    # compile the prompt shapes the module's tests use
    measure_runtime_throughput(eng, 1, prompt_len=8, new_tokens=2, groups=1)
    measure_runtime_throughput(eng, 1, prompt_len=12, new_tokens=2, groups=1)
    return eng


def _req(rid, length, n=4, arrival=None, seed=None):
    rng = np.random.default_rng(length if seed is None else seed)
    return Request(rid, rng.integers(0, VOCAB, length, dtype=np.int32), n,
                   arrival_s=arrival)


def test_drain_serves_all_with_partial_groups(engine):
    rt = ServingRuntime(engine, concurrency=2)
    for rid in range(5):  # odd count -> one partial group
        rt.submit(_req(rid, 8, n=3))
    m = rt.drain()
    assert m["requests"] == 5 and m["queue_depth"] == 0
    assert m["throughput_tok_s"] > 0
    assert m["p99_latency_s"] >= m["p50_latency_s"]
    assert all(r.output.size == 3 for r in rt.done)


def test_equal_length_grouping_preserves_long_prompts(engine):
    """Old scheduler clipped every request to the group head's prompt
    length — a longer prompt arriving behind a shorter one was silently
    truncated. Groups are now equal-length, so the output of a request
    must not depend on what it queued behind."""
    long_req = _req(0, 12, n=4, seed=7)
    solo = ServingRuntime(engine, concurrency=1)
    solo.submit(Request(0, long_req.prompt.copy(), 4))
    solo.drain()
    ref = solo.done[0].output

    rt = ServingRuntime(engine, concurrency=1)
    rt.submit(_req(1, 8, n=4, seed=3))  # shorter request at the head
    rt.submit(Request(2, long_req.prompt.copy(), 4))
    rt.drain()
    got = next(r for r in rt.done if r.rid == 2).output
    np.testing.assert_array_equal(got, ref)


def test_arrival_admission_honors_trace_offsets(engine):
    rt = ServingRuntime(engine, concurrency=1)
    rt.submit(_req(0, 8, n=2, arrival=0.0))
    rt.submit(_req(1, 8, n=2, arrival=0.4))
    m = rt.drain()
    assert m["requests"] == 2
    late = next(r for r in rt.done if r.rid == 1)
    assert late.started - rt._t0 >= 0.4  # not prefilled before it "arrived"


def test_run_for_interval_and_window_metrics(engine):
    rt = ServingRuntime(engine, concurrency=2, window_s=1.0)
    for r in workload.steady(rate=40, duration_s=2.0, prompt_lens=8,
                             new_tokens=4, vocab=VOCAB):
        rt.submit(r)
    m = rt.run_for(0.4, idle_wait=True)
    assert m["interval_s"] == pytest.approx(0.4, abs=0.15)
    assert m["throughput_tok_s"] > 0
    w = rt.metrics_window()
    assert w["throughput_tok_s"] > 0 and "queue_depth" in w


def test_workload_generators_shapes_and_rates():
    for gen, kw in (
        (workload.steady, {}),
        (workload.bursty_poisson, {"burst_factor": 5.0}),
        (workload.diurnal, {"period_s": 2.0}),
    ):
        reqs = gen(rate=50.0, duration_s=4.0, prompt_lens=(8, 12),
                   new_tokens=(2, 6), vocab=128, seed=2, **kw)
        assert reqs, gen.__name__
        arr = np.array([r.arrival_s for r in reqs])
        assert (np.diff(arr) >= 0).all() and arr.max() < 4.0
        # mean rate within a loose factor of nominal
        assert 0.4 * 50 * 4 < len(reqs) < 2.0 * 50 * 4, (gen.__name__, len(reqs))
        assert all(r.prompt.size in (8, 12) for r in reqs)
        assert all(2 <= r.max_new_tokens <= 6 for r in reqs)
        assert all(r.prompt.max() < 128 for r in reqs)


def test_concurrency_raises_measured_throughput(engine):
    """The acceptance property: measured decode τ rises from c=1 (strictly
    by c=2) and ≥20% by c=max, then saturates (far below linear-in-c).
    Uses interleaved best-of rounds: this container shares cores with
    noisy neighbours and interference only ever slows a run down, so the
    per-level max converges to the level's true capability. Extra rounds
    run only while the criterion is unmet. The gain thresholds are a
    property of the host's host/device overlap headroom, not of the code
    alone — set SERVING_PERF_STRICT=0 to demote them to a skip on
    machines whose XLA threadpool already saturates every core."""
    from benchmarks.common import serving_perf_strict

    from repro.serving import measure_concurrency_curve

    cs = (1, 2, 3, 4, 5)
    best, _ = measure_concurrency_curve(engine, cs, rounds=6, groups=8)
    peak = max(best[c] for c in cs[1:])
    strict = serving_perf_strict()
    if not strict and not (best[2] > best[1] and peak >= 1.2 * best[1]):
        pytest.skip(f"no pipelining headroom on this host: {best}")
    assert best[2] > best[1], best
    assert peak >= 1.2 * best[1], best
    assert peak <= 3.5 * best[1], best  # pipelining saturates, not linear


@pytest.fixture(scope="module")
def second_engine():
    """A second registry model (distinct weights/shape from qwen2.5-3b)
    for the co-serving tests."""
    cfg = REGISTRY["hymba-1.5b"].reduced()
    rcfg = RunConfig(remat="none", moe_impl="dense")
    ctx = ApplyCtx(cfg, rcfg, None)
    params = init_model_params(jax.random.PRNGKey(1), cfg, rcfg)
    eng = ServingEngine(ctx, params, batch_size=2, max_len=64)
    measure_runtime_throughput(eng, 1, prompt_len=8, new_tokens=2, groups=1)
    return eng


def test_two_registry_models_served_with_isolated_metrics(engine, second_engine):
    """Two registry models co-served through per-tenant rings: each ring's
    windowed metrics see only its own traffic (a burst on one tenant never
    lands in the neighbour's record), the per-tenant τ are measurably
    distinct, and the aggregate view still adds up."""
    rt = ServingRuntime(engine, concurrency=2, window_s=4.0)
    rt.add_tenant("hymba", engine=second_engine, slots=1, tau_floor=1.0)
    # asymmetric load: a burst for the default tenant, a trickle for hymba
    for rid in range(8):
        rt.submit(_req(rid, 8, n=4))
    for rid in range(2):
        rt.submit(_req(100 + rid, 8, n=4), tenant="hymba")
    m = rt.drain()
    assert m["requests"] == 10 and m["queue_depth"] == 0
    tm = rt.tenant_metrics()
    assert set(tm) == {"default", "hymba"}
    assert tm["default"]["requests"] == 8
    assert tm["hymba"]["requests"] == 2
    assert tm["hymba"]["tau_floor"] == 1.0
    # every completion is tagged with its ring; neither pool leaked
    assert all(r.tenant == "default" for r in rt.ring().done)
    assert all(r.tenant == "hymba" for r in rt.ring("hymba").done)
    # measurably distinct per-tenant τ: different models, different load
    t0 = tm["default"]["throughput_tok_s"]
    t1 = tm["hymba"]["throughput_tok_s"]
    assert t0 > 0 and t1 > 0 and abs(t0 - t1) > 0.05 * max(t0, t1)


def test_attribute_power_sums_exactly_to_rail(engine, second_engine):
    rt = ServingRuntime(engine, concurrency=1, window_s=4.0)
    rt.add_tenant("hymba", engine=second_engine, slots=1)
    for rid in range(4):
        rt.submit(_req(rid, 8, n=4))
    for rid in range(2):
        rt.submit(_req(100 + rid, 8, n=4), tenant="hymba")
    rt.drain()
    total = 7.3
    att = rt.attribute_power(total)
    assert set(att) == {"default", "hymba"}
    assert sum(att.values()) == total  # exact, not approx — one rail
    assert att["default"] > att["hymba"] > 0  # weighted by window tokens
    # empty window (no traffic yet): equal split, still exact
    idle = ServingRuntime(engine, concurrency=1)
    idle.add_tenant("hymba", engine=second_engine)
    att0 = idle.attribute_power(total)
    assert sum(att0.values()) == total
    assert att0["default"] == pytest.approx(att0["hymba"])


def test_slot_allocation_shifts_tenant_throughput(engine):
    """The live slot knob is a genuine resource split: 3-vs-1 slots beats
    1-vs-3 for the favored tenant under saturating load on both rings."""

    def tput(slots0, slots1):
        rt = ServingRuntime(engine, concurrency=slots0, window_s=4.0)
        rt.add_tenant("b", engine=engine, slots=slots1)
        for rid in range(10):
            rt.submit(_req(rid, 8, n=4))
            rt.submit(_req(100 + rid, 8, n=4), tenant="b")
        rt.set_slot_allocation({"default": slots0, "b": slots1})
        rt.drain()
        tm = rt.tenant_metrics()
        return tm["default"]["throughput_tok_s"]

    favored = max(tput(3, 1) for _ in range(2))
    starved = min(tput(1, 3) for _ in range(2))
    assert favored > starved


def test_multitenant_controller_tunes_joint_headroom(engine, second_engine):
    """Closed loop over a cotenant space: per-tenant slot dims are enacted
    on the rings, feedback is the joint headroom against the rings' τ
    floors, and the records carry the per-tenant split."""
    from repro.core.space import cotenant_space, tenant_slot_indices
    from repro.device.hw import get_profile

    cap = measure_runtime_throughput(engine, 2, prompt_len=8, new_tokens=8,
                                     groups=4)
    rt = ServingRuntime(engine, concurrency=1)
    rt.ring().tau_floor = 0.10 * cap
    rt.add_tenant("hymba", engine=second_engine, slots=1,
                  tau_floor=0.05 * cap)
    space = cotenant_space("edge_xavier_nx", 2)
    new_tokens = 8
    iters, interval = 4, 0.4
    tr0 = workload.steady(rate=0.2 * cap / new_tokens,
                          duration_s=iters * interval + 1.0, prompt_lens=8,
                          new_tokens=new_tokens, vocab=VOCAB, seed=1)
    tr1 = workload.steady(rate=0.1 * cap / new_tokens,
                          duration_s=iters * interval + 1.0, prompt_lens=8,
                          new_tokens=new_tokens, vocab=VOCAB, seed=2)
    for i, r in enumerate(tr1):
        r.tenant = "hymba"
        r.rid = 10000 + i
    trace = sorted(tr0 + tr1, key=lambda r: r.arrival_s)
    ctrl = ServingController(
        rt, space, trace, tau_target=1.0, p_budget=1e9,
        interval_s=interval, hw=get_profile("edge-xavier-nx").hw,
    )
    outcome, records = ctrl.run(iters)
    assert len(records) == iters
    slot_idx = tenant_slot_indices(space)
    for rec in records:
        assert set(rec.tenant_taus) == {"default", "hymba"}
        # the τ channel is the scalarized joint headroom, not raw tok/s
        floors = [rt.ring().tau_floor, rt.ring("hymba").tau_floor]
        taus = [rec.tenant_taus["default"], rec.tenant_taus["hymba"]]
        assert rec.tau == pytest.approx(min(t / f for t, f in zip(taus, floors)))
    # the slot knobs were genuinely applied across intervals
    assert len({tuple(r.config[i] for i in slot_idx) for r in records}) > 1


def test_cotenant_controller_requires_floors_and_matching_rings(engine):
    from repro.core.space import cotenant_space

    space = cotenant_space("edge_xavier_nx", 2)
    rt = ServingRuntime(engine, concurrency=1)  # one ring, two slot dims
    with pytest.raises(ValueError, match="tenant rings"):
        ServingController(rt, space, [], tau_target=1.0)
    rt.add_tenant("b", engine=engine)  # floors unset (0.0)
    with pytest.raises(ValueError, match="tau_floor"):
        ServingController(rt, space, [], tau_target=1.0)


def test_closed_loop_coral_finds_feasible_under_bursty_trace(engine):
    from repro.core import tpu_pod_space
    from repro.device.measure import analytic_scale_and_power

    space = tpu_pod_space()
    cap = measure_runtime_throughput(engine, 5, prompt_len=8, new_tokens=16,
                                     groups=8)
    new_tokens = 8
    iters, interval_s = 8, 0.4
    trace = workload.bursty_poisson(
        rate=0.5 * cap / new_tokens, duration_s=iters * interval_s + 2.0,
        prompt_lens=8, new_tokens=new_tokens, vocab=VOCAB, seed=1,
    )
    tau_target = 0.25 * cap
    p_budget = analytic_scale_and_power(
        space.names, space.preset("max_power"))[1] * 0.9
    controller = ServingController(
        ServingRuntime(engine, concurrency=1), space, trace,
        tau_target=tau_target, p_budget=p_budget, interval_s=interval_s,
    )
    outcome, records = controller.run(iters)
    assert len(records) == iters
    assert outcome.config is not None
    assert outcome.feasible(tau_target, p_budget), [
        (r.config, r.tau, r.power) for r in records
    ]
    # the knob was genuinely applied: the runtime ran at the proposed
    # concurrency levels, not a fixed one
    assert len({int(r.config[-1]) for r in records}) > 1
