"""Continuous-batching runtime: equal-length grouping, arrival admission,
slot refill, interval metrics, the concurrency→τ response (the knob was a
no-op before this runtime existed), and CORAL closed-loop over live
traffic."""

import jax
import numpy as np
import pytest

from repro.configs.registry import REGISTRY
from repro.configs.runtime import RunConfig
from repro.models import ApplyCtx, init_model_params
from repro.serving import (
    Request,
    ServingController,
    ServingEngine,
    ServingRuntime,
    measure_runtime_throughput,
    workload,
)

VOCAB = 512  # reduced() clamps qwen2.5-3b's vocab to this


@pytest.fixture(scope="module")
def engine():
    cfg = REGISTRY["qwen2.5-3b"].reduced()
    rcfg = RunConfig(remat="none", moe_impl="dense")
    ctx = ApplyCtx(cfg, rcfg, None)
    params = init_model_params(jax.random.PRNGKey(0), cfg, rcfg)
    eng = ServingEngine(ctx, params, batch_size=2, max_len=64)
    # compile the prompt shapes the module's tests use
    measure_runtime_throughput(eng, 1, prompt_len=8, new_tokens=2, groups=1)
    measure_runtime_throughput(eng, 1, prompt_len=12, new_tokens=2, groups=1)
    return eng


def _req(rid, length, n=4, arrival=None, seed=None):
    rng = np.random.default_rng(length if seed is None else seed)
    return Request(rid, rng.integers(0, VOCAB, length, dtype=np.int32), n,
                   arrival_s=arrival)


def test_drain_serves_all_with_partial_groups(engine):
    rt = ServingRuntime(engine, concurrency=2)
    for rid in range(5):  # odd count -> one partial group
        rt.submit(_req(rid, 8, n=3))
    m = rt.drain()
    assert m["requests"] == 5 and m["queue_depth"] == 0
    assert m["throughput_tok_s"] > 0
    assert m["p99_latency_s"] >= m["p50_latency_s"]
    assert all(r.output.size == 3 for r in rt.done)


def test_equal_length_grouping_preserves_long_prompts(engine):
    """Old scheduler clipped every request to the group head's prompt
    length — a longer prompt arriving behind a shorter one was silently
    truncated. Groups are now equal-length, so the output of a request
    must not depend on what it queued behind."""
    long_req = _req(0, 12, n=4, seed=7)
    solo = ServingRuntime(engine, concurrency=1)
    solo.submit(Request(0, long_req.prompt.copy(), 4))
    solo.drain()
    ref = solo.done[0].output

    rt = ServingRuntime(engine, concurrency=1)
    rt.submit(_req(1, 8, n=4, seed=3))  # shorter request at the head
    rt.submit(Request(2, long_req.prompt.copy(), 4))
    rt.drain()
    got = next(r for r in rt.done if r.rid == 2).output
    np.testing.assert_array_equal(got, ref)


def test_arrival_admission_honors_trace_offsets(engine):
    rt = ServingRuntime(engine, concurrency=1)
    rt.submit(_req(0, 8, n=2, arrival=0.0))
    rt.submit(_req(1, 8, n=2, arrival=0.4))
    m = rt.drain()
    assert m["requests"] == 2
    late = next(r for r in rt.done if r.rid == 1)
    assert late.started - rt._t0 >= 0.4  # not prefilled before it "arrived"


def test_run_for_interval_and_window_metrics(engine):
    rt = ServingRuntime(engine, concurrency=2, window_s=1.0)
    for r in workload.steady(rate=40, duration_s=2.0, prompt_lens=8,
                             new_tokens=4, vocab=VOCAB):
        rt.submit(r)
    m = rt.run_for(0.4, idle_wait=True)
    assert m["interval_s"] == pytest.approx(0.4, abs=0.15)
    assert m["throughput_tok_s"] > 0
    w = rt.metrics_window()
    assert w["throughput_tok_s"] > 0 and "queue_depth" in w


def test_workload_generators_shapes_and_rates():
    for gen, kw in (
        (workload.steady, {}),
        (workload.bursty_poisson, {"burst_factor": 5.0}),
        (workload.diurnal, {"period_s": 2.0}),
    ):
        reqs = gen(rate=50.0, duration_s=4.0, prompt_lens=(8, 12),
                   new_tokens=(2, 6), vocab=128, seed=2, **kw)
        assert reqs, gen.__name__
        arr = np.array([r.arrival_s for r in reqs])
        assert (np.diff(arr) >= 0).all() and arr.max() < 4.0
        # mean rate within a loose factor of nominal
        assert 0.4 * 50 * 4 < len(reqs) < 2.0 * 50 * 4, (gen.__name__, len(reqs))
        assert all(r.prompt.size in (8, 12) for r in reqs)
        assert all(2 <= r.max_new_tokens <= 6 for r in reqs)
        assert all(r.prompt.max() < 128 for r in reqs)


def test_concurrency_raises_measured_throughput(engine):
    """The acceptance property: measured decode τ rises from c=1 (strictly
    by c=2) and ≥20% by c=max, then saturates (far below linear-in-c).
    Uses interleaved best-of rounds: this container shares cores with
    noisy neighbours and interference only ever slows a run down, so the
    per-level max converges to the level's true capability. Extra rounds
    run only while the criterion is unmet. The gain thresholds are a
    property of the host's host/device overlap headroom, not of the code
    alone — set SERVING_PERF_STRICT=0 to demote them to a skip on
    machines whose XLA threadpool already saturates every core."""
    from benchmarks.common import serving_perf_strict

    from repro.serving import measure_concurrency_curve

    cs = (1, 2, 3, 4, 5)
    best, _ = measure_concurrency_curve(engine, cs, rounds=6, groups=8)
    peak = max(best[c] for c in cs[1:])
    strict = serving_perf_strict()
    if not strict and not (best[2] > best[1] and peak >= 1.2 * best[1]):
        pytest.skip(f"no pipelining headroom on this host: {best}")
    assert best[2] > best[1], best
    assert peak >= 1.2 * best[1], best
    assert peak <= 3.5 * best[1], best  # pipelining saturates, not linear


def test_closed_loop_coral_finds_feasible_under_bursty_trace(engine):
    from repro.core import tpu_pod_space
    from repro.device.measure import analytic_scale_and_power

    space = tpu_pod_space()
    cap = measure_runtime_throughput(engine, 5, prompt_len=8, new_tokens=16,
                                     groups=8)
    new_tokens = 8
    iters, interval_s = 8, 0.4
    trace = workload.bursty_poisson(
        rate=0.5 * cap / new_tokens, duration_s=iters * interval_s + 2.0,
        prompt_lens=8, new_tokens=new_tokens, vocab=VOCAB, seed=1,
    )
    tau_target = 0.25 * cap
    p_budget = analytic_scale_and_power(
        space.names, space.preset("max_power"))[1] * 0.9
    controller = ServingController(
        ServingRuntime(engine, concurrency=1), space, trace,
        tau_target=tau_target, p_budget=p_budget, interval_s=interval_s,
    )
    outcome, records = controller.run(iters)
    assert len(records) == iters
    assert outcome.config is not None
    assert outcome.feasible(tau_target, p_budget), [
        (r.config, r.tau, r.power) for r in records
    ]
    # the knob was genuinely applied: the runtime ran at the proposed
    # concurrency levels, not a fixed one
    assert len({int(r.config[-1]) for r in records}) > 1
