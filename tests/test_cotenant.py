"""Multi-tenant co-inference twin tests (EXPERIMENTS.md §Multi-tenant).

The co-tenancy invariants the tentpole is built around:

  * the slot knobs are a real negotiation: granting a tenant more slots
    raises its τ and *lowers* every neighbour's (interference flows
    through the shared stream-contention kappa, not an exogenous drift
    term);
  * the measured channel is the scalarized (joint headroom, rail power)
    pair — feasible ⇔ every tenant meets its floor — so CORAL's dual
    mode, the batched joint oracle and the compiled episode engine all
    run unchanged;
  * the noise protocol is the exact-RNG contract of ``core.contracts``
    §TWIN_RNG_PROTOCOL, byte-replayable by ``core.episode``;
  * the recorded cell carries its calibration provenance (floors from
    solo-max fractions, budget from the pmin anchor) and the
    per-tenant-greedy ablation's joint evaluation.
"""

import numpy as np
import pytest

from repro.core.coral import joint_headroom
from repro.core.evaluate import CellSpec, run_cell, run_regime
from repro.core.episode import run_static_requests
from repro.core.space import tenant_slot_indices
from repro.device import build_twin
from repro.experiments import (
    COTENANT_REGIMES,
    MATRIX_COTENANT_CELLS,
    WORKLOADS,
    cotenant_cell_simulator,
    resolve_cotenant_targets,
    tenant_names,
)

CELL = MATRIX_COTENANT_CELLS[0]  # edge-xavier-nx / qwen2.5-3b+granite-8b


# ---------------------------------------------------------- twin physics
def test_slot_grant_helps_owner_hurts_neighbor():
    """More slots for tenant 1 at fixed clocks: τ_1 rises, τ_0 falls —
    the neighbour is a knob with a genuine cost, not exogenous drift."""
    sim = cotenant_cell_simulator(CELL, noise=0.0)
    grid = sim.space.grid()
    i0, i1 = tenant_slot_indices(sim.space)
    base = grid[(grid[:, i0] == 1.0) & (grid[:, i1] == 1.0)]
    grown = base.copy()
    grown[:, i1] = 3.0
    tau_base = sim.tenant_taus(base)
    tau_grown = sim.tenant_taus(grown)
    assert (tau_grown[1] > tau_base[1] + 1e-12).all()
    assert (tau_grown[0] < tau_base[0] - 1e-12).all()


def test_headroom_is_min_over_tenant_floors():
    """The scalarized τ channel is exactly min_k τ_k/floor_k, and
    headroom ≥ 1 ⇔ every tenant individually meets its floor."""
    sim = cotenant_cell_simulator(CELL, noise=0.0)
    taus = sim.tenant_taus()
    h, p = sim.exact_all()
    manual = np.min(
        [taus[k] / sim.floors[k] for k in range(sim.n_tenants)], axis=0
    )
    np.testing.assert_allclose(h, manual, rtol=1e-12)
    np.testing.assert_allclose(h, joint_headroom(taus, sim.floors), rtol=1e-12)
    all_met = np.all(
        [taus[k] >= sim.floors[k] for k in range(sim.n_tenants)], axis=0
    )
    np.testing.assert_array_equal(h >= 1.0, all_met)
    assert (p > 0).all()


def test_shared_rail_rises_with_total_occupancy():
    """One rail: adding any tenant's slots at fixed clocks can only raise
    the shared draw (utilization grows), never lower it."""
    sim = cotenant_cell_simulator(CELL, noise=0.0)
    grid = sim.space.grid()
    i0, i1 = tenant_slot_indices(sim.space)
    lean = grid[(grid[:, i0] == 1.0) & (grid[:, i1] == 1.0)]
    busy = lean.copy()
    busy[:, i0] = 3.0
    busy[:, i1] = 3.0
    assert (sim.rail_power(busy) >= sim.rail_power(lean) - 1e-12).all()


# ------------------------------------------------------ the RNG protocol
def test_measure_all_matches_sequential_measures():
    """core.contracts §TWIN_RNG_PROTOCOL: the (N, 2) config-major noise
    block of ``measure_all`` is the same stream as N sequential
    ``measure`` calls — the property the compiled engine's replay rests
    on."""
    rows = cotenant_cell_simulator(CELL, seed=3).space.grid()[:17]
    batched = cotenant_cell_simulator(CELL, seed=3)
    tb, pb = batched.measure_all(rows)
    seq = cotenant_cell_simulator(CELL, seed=3)
    ts, ps = zip(*(seq.measure(tuple(r)) for r in rows))
    np.testing.assert_allclose(tb, ts, rtol=1e-12)
    np.testing.assert_allclose(pb, ps, rtol=1e-12)
    assert seq.n_measurements == batched.n_measurements == len(rows)


def test_noise_free_twin_draws_nothing():
    sim = cotenant_cell_simulator(CELL, noise=0.0, seed=5)
    before = sim.rng.bit_generator.state["state"].copy()
    sim.measure(next(iter(sim.space.all_configs())))
    sim.measure_all(sim.space.grid()[:4])
    assert sim.rng.bit_generator.state["state"] == before


# ------------------------------------------------- engine ↔ scalar loop
def test_engine_matches_scalar_on_cotenant_cell():
    """The compiled episode engine replays the CotenantSimulator noise
    protocol byte-for-byte on the joint slots × shared-DVFS space."""
    sim0 = cotenant_cell_simulator(CELL, noise=0.0)
    targets = resolve_cotenant_targets(CELL, sim0)
    assert targets.mode == "dual" and targets.tau_target == 1.0
    land_tau, land_p = sim0.exact_all()
    _, workloads = tenant_names(CELL)
    noise = max(WORKLOADS[w].noise for w in workloads)
    seeds = (0, 1)
    reqs = [
        dict(space=sim0.space, land_tau=land_tau, land_p=land_p,
             targets=targets, seed=s, noise=noise)
        for s in seeds
    ]
    eps = run_static_requests(reqs, iters=12)
    for seed, ep in zip(seeds, eps):
        dev = cotenant_cell_simulator(CELL, seed=seed)
        out, tr = run_regime(sim0.space, dev, targets, iters=12, seed=seed)
        assert [tuple(c) for c in tr.configs] == [tuple(c) for c in ep.configs]
        np.testing.assert_allclose(tr.taus, ep.taus, rtol=1e-12)
        np.testing.assert_allclose(tr.powers, ep.powers, rtol=1e-12)
        assert tuple(out.config) == tuple(ep.outcome.config)
        assert out.tau == pytest.approx(ep.outcome.tau, rel=1e-12)
        assert out.power == pytest.approx(ep.outcome.power, rel=1e-12)


def test_run_cotenant_cell_records_identical_across_engines():
    from repro.experiments.matrix import run_cotenant_cell

    a = run_cotenant_cell(CELL, iters=12, seeds=(0, 1), engine="compiled")
    b = run_cotenant_cell(CELL, iters=12, seeds=(0, 1), engine="scalar")
    assert a == b


# ----------------------------------------------- records & provenance
def test_cotenant_calibration_provenance():
    """The recorded cotenant block carries the calibration the gates rest
    on: floors = tau_frac × solo max, τ* = 1 (headroom), budget = p_slack
    × the pmin anchor, and the per-tenant-greedy combination is jointly
    evaluated (and busts a constraint on this calibrated cell)."""
    from repro.experiments.matrix import run_cotenant_cell

    rec = run_cotenant_cell(CELL, iters=12, seeds=(0,))
    c = rec["cotenant"]
    regime = COTENANT_REGIMES[CELL.regime]
    sim0 = cotenant_cell_simulator(CELL, noise=0.0)
    assert c["p_slack"] == regime.p_slack
    assert rec["tau_target"] == 1.0
    h_all, p_all = sim0.exact_all()
    assert rec["p_budget"] == pytest.approx(
        regime.p_slack * p_all[h_all >= 1.0].min(), rel=1e-3
    )
    for k, t in enumerate(c["tenants"]):
        assert t["floor"] == pytest.approx(
            regime.tau_fracs[k] * t["solo_max"], rel=1e-3
        )
        assert t["floor"] == sim0.floors[k]
    g = c["greedy"]
    assert g["violates_tau"] or g["violates_power"]
    h, p = sim0.exact(tuple(g["config"]))
    assert g["headroom"] == pytest.approx(h, rel=1e-12)
    assert g["power"] == pytest.approx(p, rel=1e-12)


def test_run_cell_dispatches_cotenant_family():
    from repro.experiments.matrix import run_cotenant_cell

    out = run_cell(CellSpec(CELL, iters=12, seeds=(0,)))
    assert out.family == "cotenant"
    assert out.record == run_cotenant_cell(CELL, iters=12, seeds=(0,))


def test_build_twin_dispatches_all_families():
    from repro.device.cotenant import CotenantSimulator
    from repro.device.network import OffloadSimulator
    from repro.device.simulator import DeviceSimulator, DriftingSimulator
    from repro.experiments.scenarios import (
        MATRIX_DRIFT_CELLS,
        MATRIX_OFFLOAD_CELLS,
        Cell,
    )

    assert isinstance(build_twin(CELL), CotenantSimulator)
    assert isinstance(build_twin(MATRIX_OFFLOAD_CELLS[0]), OffloadSimulator)
    assert isinstance(build_twin(MATRIX_DRIFT_CELLS[0]), DriftingSimulator)
    static = Cell("edge-xavier-nx", "qwen2.5-3b", "decode_steady", "single_tau")
    assert isinstance(build_twin(static), DeviceSimulator)
