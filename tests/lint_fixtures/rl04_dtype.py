"""Golden RL04 fixture: dtype-unannotated constructor + float64 leak
in engine-state-shaped code.
"""
import jax.numpy as jnp
import numpy as np


def init_state(w):
    hist = jnp.zeros((w, 4))  # RL04: no explicit dtype
    budget = np.float64(0.0)  # RL04: float64 in engine state
    return hist, budget
