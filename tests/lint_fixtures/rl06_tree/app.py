from pkg.used import live

print(live())
