def dead():
    return 0
