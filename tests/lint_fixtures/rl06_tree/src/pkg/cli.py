"""A __main__-guarded module is its own entry point — never dead."""


def main():
    return 2


if __name__ == "__main__":
    main()
