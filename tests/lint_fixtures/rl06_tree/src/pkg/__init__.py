"""Golden RL06 fixture package: `used` is imported by app.py, `orphan`
is reachable from no entry point.
"""
