def live():
    return 1
