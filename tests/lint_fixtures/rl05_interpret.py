"""Golden RL05 fixture: kernel wrapper deriving interpret mode locally
instead of routing through repro.kernels.runtime.default_interpret.
"""
import os

import jax


def run_kernel(x, interpret=True):  # RL05: hardcoded interpret default
    return x


def local_resolve():
    if os.environ.get("PALLAS_INTERPRET"):  # RL05: forked env parsing
        return True
    return jax.default_backend() != "tpu"  # RL05: backend-derived mode
