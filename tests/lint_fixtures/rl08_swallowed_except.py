"""Golden RL08 fixture: a bare ``except:`` plus two typed handlers that
silently swallow the failure, and one compliant handler that must NOT
be flagged."""


def poll_bare(devices):
    out = []
    for d in devices:
        try:
            out.append(d.read())
        except:  # RL08: bare except hides faults from the ledger
            out.append(None)
    return out


def poll_swallow_pass(devices):
    out = []
    for d in devices:
        try:
            out.append(d.read())
        except TimeoutError:  # RL08: failure vanishes without a trace
            pass
    return out


def poll_swallow_continue(devices):
    out = []
    for d in devices:
        try:
            out.append(d.read())
        except (OSError, ValueError):  # RL08: same, via continue
            continue
    return out


def poll_accounted(devices, counters):
    # compliant: the failure is counted, so the watchdog can see it
    out = []
    for d in devices:
        try:
            out.append(d.read())
        except TimeoutError:
            counters["timeouts"] += 1
            out.append(None)
    return out
