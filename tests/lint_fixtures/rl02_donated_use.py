"""Golden RL02 fixture: reading a buffer after donating it.

`step` donates its first argument; `loop` reads `params` again after
the donating call, when its buffer may already be aliased.
"""
import jax


def add(a, b):
    return a + b


step = jax.jit(add, donate_argnums=(0,))


def loop(params, grads):
    out = step(params, grads)
    return out + params  # RL02: `params` was donated on the line above
