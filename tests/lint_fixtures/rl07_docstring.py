"""Golden RL07 fixture: a public function with no docstring, plus a
docstring quoting a carry-field shape that disagrees with the
*_CONTRACT tables in core/contracts.py."""


def undocumented_public_fn(x):  # RL07: missing docstring
    return x + 1


def stale_shape_doc(carry):
    """Reads ``hist_sm: Float32[Array, "W D"]`` from the carry — the
    contract table says the history buffer is (T+W, D+4), so this spec
    is stale on purpose."""
    return carry["hist_sm"]


def _private_helper(x):  # private: RL07 must not flag this
    return x
