"""Golden RL03 fixture: nondeterminism in a benchmark results writer.

A wall-clock stamp inside the results payload and an unsorted
json.dump both break the byte-identical-results contract.
"""
import json
import time


def write_results(results, path):
    results["stamp"] = time.time()  # RL03: wall clock in results
    with open(path, "w") as fh:
        json.dump(results, fh)  # RL03: no sort_keys=True
