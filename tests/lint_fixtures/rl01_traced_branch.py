"""Golden RL01 fixture: Python branching on a traced value.

`decide` is jit-decorated, so its parameters are tracers; the `if` and
the float() both force concrete values at trace time.
"""
import jax


@jax.jit
def decide(x, lo):
    y = x - lo
    if y > 0:  # RL01: Python `if` on a traced value
        return float(y)  # RL01: float() on a traced value
    return y
