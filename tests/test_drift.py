"""Drift subsystem tests: the CUSUM change-point detector's operating
characteristics (zero false triggers on stationary noise, guaranteed
trigger under a thermal ramp), the drifting device twin's semantics, and
re-exploration's state contract (prohibited memory kept, epoch reset)."""

import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core import CORAL, DriftConfig
from repro.core.baselines import oracle
from repro.core.drift import CusumDetector, DriftMonitor
from repro.core.evaluate import run_drift_regime
from repro.device import (
    DriftingSimulator,
    DriftSchedule,
    ThermalRamp,
    build_cell_simulator,
    get_profile,
)
from repro.experiments import (
    DRIFT_SHIFT_START,
    DRIFTS,
    MATRIX_DRIFT_CELLS,
    REGIMES,
    drifting_cell_simulator,
    resolve_targets,
)

NOISE = 0.02  # decode_steady trace noise — what the monitor is tuned for


# ------------------------------------------------------------- detector
def test_cusum_no_false_trigger_on_stationary_noise_across_seeds():
    """In-control behavior: 200 noisy samples of an unchanged config,
    20 seeds — the monitor must never fire (h=9σ, k=1σ leaves the
    per-run false-alarm probability astronomically small)."""
    for seed in range(20):
        rng = np.random.default_rng(seed)
        mon = DriftMonitor(ref_tau=100.0, ref_power=10.0, sigma=NOISE)
        for _ in range(200):
            tau = 100.0 * (1.0 + rng.normal(0.0, NOISE))
            p = 10.0 * (1.0 + rng.normal(0.0, NOISE))
            assert not mon.update(tau, p), f"false trigger, seed {seed}"


def test_cusum_triggers_within_k_intervals_on_thermal_ramp():
    """A thermal-ramp twin degrades the held config's τ; the monitor must
    fire within K intervals of the shift start for every seed."""
    K = 8
    cell = MATRIX_DRIFT_CELLS[0]  # edge-orin-nx thermal-ramp cell
    sched = DRIFTS["thermal-ramp"]
    sim0 = build_cell_simulator(
        get_profile(cell.device), get_config(cell.model), noise=0.0
    )
    held = oracle(sim0.space, sim0, 0.55 * oracle(sim0.space, sim0, 0.0).tau)
    for seed in range(5):
        dev = DriftingSimulator(
            build_cell_simulator(
                get_profile(cell.device), get_config(cell.model), seed=seed
            ),
            sched,
        )
        mon = DriftMonitor(held.tau, held.power, sigma=NOISE)
        fired_at = None
        for t in range(DRIFT_SHIFT_START + K + 1):
            dev.set_time(t)
            tau, p = dev.measure(held.config)
            if mon.update(tau, p):
                fired_at = t
                break
        assert fired_at is not None, f"no trigger by t={t}, seed {seed}"
        assert fired_at >= DRIFT_SHIFT_START, "fired before the shift"
        assert fired_at <= DRIFT_SHIFT_START + K


def test_cusum_two_sided():
    det = CusumDetector(k=1.0, h=9.0)
    for _ in range(5):
        det.update(4.0)  # +4σ sustained
    assert det.tripped
    det.reset()
    assert not det.tripped
    for _ in range(5):
        det.update(-4.0)  # the negative side trips independently
    assert det.tripped


# -------------------------------------------------------- drifting twin
def test_drifting_simulator_identity_before_shift_and_batched_scalar_agree():
    cell = MATRIX_DRIFT_CELLS[0]
    ds = drifting_cell_simulator(cell, noise=0.0)
    base = ds.base
    t0, p0 = base.exact_all()
    dt0, dp0 = ds.exact_all()
    np.testing.assert_allclose(t0, dt0)
    np.testing.assert_allclose(p0, dp0)
    ds.set_time(100)
    grid = ds.space.grid()
    t1, p1 = ds.exact_all(grid[:16])
    for i in range(16):
        tau, p = ds.exact(tuple(grid[i]))
        assert tau == pytest.approx(t1[i])
        assert p == pytest.approx(p1[i])


def test_thermal_derate_is_per_level_and_inflates_static_power():
    """Thermal throttling must cost high DVFS steps a larger τ fraction
    than low steps (delivered-clock derate is quadratic in the requested
    level) and raise power everywhere (leakage)."""
    prof = get_profile("edge-orin-nx")
    base = build_cell_simulator(prof, get_config("qwen2.5-3b"), noise=0.0)
    ds = DriftingSimulator(
        base, DriftSchedule((ThermalRamp(0, 1, 0.3, 0.3, 0.3),))
    )
    t0, p0 = base.exact_all()
    ds.set_time(10)
    t1, p1 = ds.exact_all()
    assert (p1 > p0).all()
    grid = base.space.grid()
    mem = grid[:, base.space.index("mem_freq")]
    ratios = t1 / t0
    # decode is memory-bound: the top memory step must lose a strictly
    # larger τ fraction than the bottom step
    assert ratios[mem == mem.max()].mean() < ratios[mem == mem.min()].mean()


def test_drift_schedule_composition_and_budget_scale():
    sched = DRIFTS["budget-step"]
    assert sched.state_at(DRIFT_SHIFT_START - 1).budget_scale == 1.0
    assert sched.state_at(DRIFT_SHIFT_START).budget_scale == pytest.approx(0.55)
    ramp = DRIFTS["thermal-ramp"]
    mid = ramp.state_at(DRIFT_SHIFT_START + 3)
    full = ramp.state_at(DRIFT_SHIFT_START + 60)
    assert 0 < mid.clock_derate < full.clock_derate
    assert ramp.shift_start == DRIFT_SHIFT_START
    assert ramp.shift_end == DRIFT_SHIFT_START + 6


# ------------------------------------------------------- re-exploration
def _drift_coral():
    cell = MATRIX_DRIFT_CELLS[0]
    sim0 = build_cell_simulator(
        get_profile(cell.device), get_config(cell.model), noise=0.0
    )
    targets = resolve_targets(cell, sim0)
    opt = CORAL(
        sim0.space,
        targets.tau_target,
        targets.p_budget,
        mode=targets.mode,
        drift=DriftConfig(explore_budget=6, sigma=NOISE),
    )
    return opt, sim0


def test_re_explore_preserves_prohibited_set_and_resets_epoch():
    opt, sim0 = _drift_coral()
    for _ in range(6):
        cfg = opt.propose()
        tau, p = sim0.exact(cfg)
        opt.observe(cfg, tau, p)
    prohibited_before = set(opt.state.prohibited)
    assert opt.state.best is not None
    assert not opt.exploring  # budget spent → holding
    opt.re_explore()
    assert opt.state.prohibited >= prohibited_before  # memory kept
    assert opt.state.best is None and opt.state.second is None
    assert opt.state.epoch_start == len(opt.state.history)
    assert opt.state.resets == 1
    assert opt.exploring  # fresh epoch explores again
    # previously-visited configs are re-measurable in the new epoch (their
    # pre-shift measurements are stale), but prohibited ones stay skipped
    cand = opt.propose()
    assert cand not in opt.state.prohibited


def test_hold_measurements_do_not_mutate_state():
    opt, sim0 = _drift_coral()
    for _ in range(6):
        cfg = opt.propose()
        tau, p = sim0.exact(cfg)
        opt.observe(cfg, tau, p)
    held = opt.next_config()
    n_hist = len(opt.state.history)
    prohibited = set(opt.state.prohibited)
    tau, p = sim0.exact(held)
    for _ in range(5):  # calm holds: monitor feeds, nothing else moves
        opt.record(held, tau, p)
    assert len(opt.state.history) == n_hist
    assert opt.state.prohibited == prohibited
    assert opt.state.resets == 0


def test_commanded_budget_step_triggers_re_exploration():
    opt, sim0 = _drift_coral()
    for _ in range(6):
        cfg = opt.propose()
        tau, p = sim0.exact(cfg)
        opt.observe(cfg, tau, p)
    held = opt.next_config()
    _, held_p = sim0.exact(held)
    opt.set_p_budget(held_p * 0.5)  # cut below the held draw
    assert opt.state.resets == 1
    assert opt.exploring


# ------------------------------------------------- end-to-end separation
@pytest.mark.parametrize("cell", MATRIX_DRIFT_CELLS[:2])
def test_adaptive_recovers_where_static_breaks(cell):
    """The acceptance property on the thermal cells: after the shift the
    adaptive loop's choice is feasible and near the post-shift oracle
    while the static ablation's held config violates the constraints."""
    regime = REGIMES[cell.regime]
    sched = DRIFTS[regime.drift]
    sim0 = build_cell_simulator(
        get_profile(cell.device), get_config(cell.model), noise=0.0
    )
    targets = resolve_targets(cell, sim0)
    twin = DriftingSimulator(
        build_cell_simulator(
            get_profile(cell.device), get_config(cell.model), noise=0.0
        ),
        sched,
    )
    intervals = 56
    twin.set_time(intervals - 1)
    cap_post = targets.p_budget * twin.state.budget_scale
    post = oracle(sim0.space, twin, targets.tau_target, cap_post)

    def run(adaptive):
        dev = drifting_cell_simulator(cell, seed=0)
        opt, tr = run_drift_regime(
            sim0.space,
            dev,
            targets,
            sched,
            intervals,
            seed=0,
            adaptive=adaptive,
            sigma=NOISE,
        )
        res = opt.result()
        return twin.exact(res.config), tr.resets

    (a_tau, a_p), a_resets = run(True)
    (s_tau, s_p), s_resets = run(False)
    assert a_resets >= 1 and s_resets == 0
    assert a_tau >= targets.tau_target and a_p <= cap_post * (1 + 1e-9)
    a_eff = (a_tau / a_p) / post.efficiency
    assert a_eff >= 0.85
    static_violates = s_tau < targets.tau_target or s_p > cap_post
    assert static_violates, "static ablation should break under this drift"


def test_run_drift_regime_static_never_re_explores():
    cell = MATRIX_DRIFT_CELLS[4]
    sim0 = build_cell_simulator(
        get_profile(cell.device), get_config(cell.model), noise=0.0
    )
    targets = resolve_targets(cell, sim0)
    sched = DRIFTS[REGIMES[cell.regime].drift]
    dev = drifting_cell_simulator(cell, seed=1)
    opt, tr = run_drift_regime(
        sim0.space,
        dev,
        targets,
        sched,
        40,
        seed=1,
        adaptive=False,
        sigma=NOISE,
    )
    assert tr.resets == 0
    assert len(set(tr.configs[10:])) == 1  # one held config, forever
