"""Tier-1 tests for the runtime sanitizer lanes (core/sanitize.py):
the checkify lane catches poisoned values end-to-end, flipping the
REPRO_CHECKIFY flag never serves a stale executable, and the
compile-count guard proves the static matrix and the fleet path each
compile exactly once per engine spec.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sanitize
from repro.core.episode import _compiled_runner, run_coral_batch
from repro.core.evaluate import RegimeTargets
from repro.core.space import jetson_like_space
from repro.device import jetson_like_simulator
from repro.experiments.fleet import run_fleet


@pytest.fixture(scope="module")
def cell():
    sp = jetson_like_space()
    sim = jetson_like_simulator(sp)
    lt, lp = sim.exact_all()
    tg = RegimeTargets(
        mode="dual",
        tau_target=float(np.percentile(lt, 70)),
        p_budget=float(np.percentile(lp, 60)),
    )
    return sp, np.asarray(lt), np.asarray(lp), tg


def test_checkify_lane_off_by_default(monkeypatch):
    monkeypatch.delenv("REPRO_CHECKIFY", raising=False)
    assert not sanitize.checkify_enabled()
    monkeypatch.setenv("REPRO_CHECKIFY", "1")
    assert sanitize.checkify_enabled()
    monkeypatch.setenv("REPRO_CHECKIFY", "0")
    assert not sanitize.checkify_enabled()


def test_wrap_checkify_catches_nan():
    checked = jax.jit(sanitize.wrap_checkify(jnp.log))
    err, out = checked(jnp.array(-1.0))
    with pytest.raises(Exception, match="nan"):
        err.throw()
    # clean input: throw() is a no-op and the value is intact
    err, out = checked(jnp.array(1.0))
    err.throw()
    assert float(out) == 0.0


def test_checkify_flag_is_part_of_the_cache_key(monkeypatch, cell):
    sp, lt, lp, tg = cell
    from repro.core.episode import EngineSpec

    spec = EngineSpec(spaces=(sp,), iters=9, window=6)
    monkeypatch.delenv("REPRO_CHECKIFY", raising=False)
    plain = _compiled_runner(spec)
    monkeypatch.setenv("REPRO_CHECKIFY", "1")
    checked = _compiled_runner(spec)
    assert checked is not plain
    monkeypatch.delenv("REPRO_CHECKIFY", raising=False)
    assert _compiled_runner(spec) is plain


def test_checkify_engine_smoke_clean(monkeypatch, cell):
    sp, lt, lp, tg = cell
    monkeypatch.setenv("REPRO_CHECKIFY", "1")
    (ep,) = run_coral_batch(sp, lt, lp, tg, seeds=(0,), iters=9, window=6)
    assert np.isfinite(ep.taus).all() and np.isfinite(ep.rewards).all()


def test_checkify_engine_raises_on_poisoned_landscape(monkeypatch, cell):
    # a fully NaN-poisoned latency landscape must fail loudly, not
    # silently propagate into the episode result
    sp, lt, lp, tg = cell
    monkeypatch.setenv("REPRO_CHECKIFY", "1")
    bad = np.full_like(lt, np.nan)
    with pytest.raises(Exception, match="nan generated"):
        run_coral_batch(sp, bad, lp, tg, seeds=(0,), iters=9, window=6)


def test_static_matrix_compiles_once(cell):
    sp, lt, lp, tg = cell
    # unique (iters, window, batch) so this spec is cold in-process no
    # matter which tests ran before
    kw = dict(iters=13, window=5)
    with sanitize.count_compiles() as cold:
        run_coral_batch(sp, lt, lp, tg, seeds=(0, 1), **kw)
    assert cold.count("run") == 1, cold.names
    # same spec, fresh data: zero executable builds
    with sanitize.count_compiles() as warm:
        run_coral_batch(sp, lt, lp, tg, seeds=(2, 3), **kw)
    assert warm.total == 0, warm.names


def test_fleet_path_compiles_once():
    kw = dict(n_twins=4, iters=11, window=7)
    with sanitize.count_compiles() as cold:
        run_fleet(seed=0, **kw)
    # exactly two executables: the cold pass (B=4) and the warm re-run
    # of every warm_every-th twin (B=1) — distinct batch shapes
    assert cold.count("run") == 2, cold.names
    with sanitize.count_compiles() as warm:
        run_fleet(seed=1, **kw)
    assert warm.total == 0, warm.names
