"""Attention implementation tests: blocked == direct, windows, ring cache."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.runtime import RunConfig
from repro.models.attention import attention
from repro.models.transformer import _ring_kv_pos

RNG = np.random.default_rng(0)


def _mk(b=2, sq=64, skv=64, hq=4, hkv=2, d=16):
    q = jnp.asarray(RNG.normal(size=(b, sq, hq, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, skv, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, skv, hkv, d)), jnp.float32)
    qp = jnp.broadcast_to(jnp.arange(sq), (b, sq))
    kp = jnp.broadcast_to(jnp.arange(skv), (b, skv))
    return q, k, v, qp, kp


def test_blocked_equals_direct():
    q, k, v, qp, kp = _mk()
    direct = attention(q, k, v, qp, kp, causal=True,
                       rcfg=RunConfig(attn_blocked_threshold=1 << 20))
    blocked = attention(
        q, k, v, qp, kp, causal=True,
        rcfg=RunConfig(attn_blocked_threshold=1, attn_block_q=16, attn_block_k=16),
    )
    np.testing.assert_allclose(np.asarray(direct), np.asarray(blocked), atol=2e-5)


def test_blocked_equals_direct_window():
    q, k, v, qp, kp = _mk()
    kw = dict(causal=True, window=12)
    direct = attention(q, k, v, qp, kp,
                       rcfg=RunConfig(attn_blocked_threshold=1 << 20), **kw)
    blocked = attention(
        q, k, v, qp, kp,
        rcfg=RunConfig(attn_blocked_threshold=1, attn_block_q=16, attn_block_k=16),
        **kw,
    )
    np.testing.assert_allclose(np.asarray(direct), np.asarray(blocked), atol=2e-5)


def test_window_masks_old_tokens():
    """With window=1 each position attends only to itself: output = v row."""
    b, s, h, d = 1, 8, 1, 4
    q = jnp.ones((b, s, h, d))
    k = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    out = attention(q, k, v, pos, pos, causal=True, window=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(v), atol=1e-5)


def test_negative_kv_pos_invalid():
    """Slots with negative positions (unwritten ring slots) are masked."""
    q, k, v, qp, kp = _mk(sq=1, skv=8)
    kp_valid = kp
    kp_partial = jnp.where(kp < 4, kp, -1)  # only first 4 slots valid
    qp1 = jnp.full((2, 1), 100)
    out_partial = attention(q, k, v, qp1, kp_partial, causal=True)
    out_trunc = attention(q, k[:, :4], v[:, :4], qp1, kp_valid[:, :4], causal=True)
    np.testing.assert_allclose(
        np.asarray(out_partial), np.asarray(out_trunc), atol=1e-5
    )


def test_ring_kv_pos_semantics():
    w = 8
    # before wrap: slots 0..t hold 0..t; rest negative
    p = np.asarray(_ring_kv_pos(jnp.asarray(5), w))
    assert list(p[:6]) == [0, 1, 2, 3, 4, 5]
    assert all(x < 0 for x in p[6:])
    # after wrap at t=10 (w=8): slot s holds the latest p≡s (mod 8), p<=10
    p = np.asarray(_ring_kv_pos(jnp.asarray(10), w))
    for s, val in enumerate(p):
        assert val % w == s and 10 - w < val <= 10


def test_gqa_equals_repeated_heads():
    """GQA must equal MHA with explicitly repeated KV heads."""
    q, k, v, qp, kp = _mk(hq=4, hkv=2)
    out_gqa = attention(q, k, v, qp, kp, causal=True)
    k_rep = jnp.repeat(k, 2, axis=2)
    v_rep = jnp.repeat(v, 2, axis=2)
    # interleave to match grouped layout: group g of kv-head h is q-head h*g
    out_mha = attention(q, k_rep, v_rep, qp, kp, causal=True)
    np.testing.assert_allclose(np.asarray(out_gqa), np.asarray(out_mha), atol=2e-5)


def test_mla_decode_equals_full_attention():
    """Absorbed MLA decode == expanded MLA attention at the last position."""
    from repro.configs.registry import REGISTRY
    from repro.models import mla as mla_lib
    from repro.models.layers import init_params
    import jax

    cfg = REGISTRY["deepseek-v2-236b"].reduced()
    specs = mla_lib.mla_param_specs(cfg, 1)
    params = init_params(jax.random.PRNGKey(0), specs, jnp.float32)
    lp = jax.tree.map(lambda a: a[0], params)
    b, s = 2, 9
    x = jnp.asarray(RNG.normal(size=(b, s, cfg.d_model)) * 0.3, jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    out_full, (latent, krope) = mla_lib.mla_full(cfg, lp, x, pos, RunConfig())
    out_dec = mla_lib.mla_decode(
        cfg, lp, x[:, -1:], pos[:, -1:], latent, krope, pos
    )
    np.testing.assert_allclose(
        np.asarray(out_dec[:, 0]), np.asarray(out_full[:, -1]), atol=2e-3, rtol=1e-2
    )


def test_swa_sliced_path_equals_direct():
    """Static-window KV-sliced blocked attention == direct masked attention."""
    b, s, hq, hkv, d, w = 2, 256, 4, 2, 16, 48
    q = jnp.asarray(RNG.normal(size=(b, s, hq, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, hkv, d)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    direct = attention(q, k, v, pos, pos, causal=True, window=w,
                       rcfg=RunConfig(attn_blocked_threshold=1 << 20))
    swa = attention(
        q, k, v, pos, pos, causal=True, window=w,
        rcfg=RunConfig(attn_blocked_threshold=1, attn_block_q=32, attn_block_k=32),
    )
    np.testing.assert_allclose(np.asarray(direct), np.asarray(swa), atol=2e-5)


def test_window_segments():
    from repro.models.transformer import window_segments

    assert window_segments([None, 8, 8, None]) == [
        (0, 1, None), (1, 3, 8), (3, 4, None)
    ]
    assert window_segments([None, None]) == [(0, 2, None)]
    assert window_segments([]) == []
