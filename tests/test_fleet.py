"""Fleet-scale tuning tests: twin sampling determinism, firmware-ladder
bans, the one-compiled-call fleet engine (warm starts, byte-identical
results blocks) and the episode jit's buffer donation."""
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.device.hw import (
    FLEET_FAMILIES,
    perturbed_profile,
    sample_perturbations,
)
from repro.experiments.fleet import (
    build_twin,
    ladder_banned_rows,
    run_fleet,
)

# Shared fleet shape across tests — one compiled engine spec per module.
ITERS, WINDOW = 12, 6


def test_sample_perturbations_prefix_stable():
    """Twin i's draw depends only on (seed, i): a small fleet is an exact
    prefix of a larger one, so smoke floors transfer to the nightly run."""
    small = sample_perturbations(6, seed=9)
    large = sample_perturbations(24, seed=9)
    assert small == large[:6]
    assert sample_perturbations(6, seed=10) != small


def test_sample_perturbations_ranges():
    perts = sample_perturbations(64, seed=0)
    for p in perts:
        assert p.family in FLEET_FAMILIES
        assert 0.85 <= p.compute_scale <= 1.15
        assert 0.88 <= p.mem_scale <= 1.12
        assert 0.0 <= p.ambient_derate <= 0.12
        assert p.ladder_variant in (0, 1, 2)


def test_perturbed_profile_scales_applied():
    pert = sample_perturbations(1, seed=3)[0]
    prof = perturbed_profile(pert)
    assert prof.name.endswith("#00000")
    base = perturbed_profile(
        type(pert)(family=pert.family, twin_id=pert.twin_id)
    )
    assert prof.compute_eff == base.compute_eff * pert.compute_scale


def test_ladder_banned_rows():
    twin = build_twin(sample_perturbations(1, seed=0)[0])
    space = twin.space
    assert not ladder_banned_rows(space, 0).any()
    for variant in (1, 2):
        banned = ladder_banned_rows(space, variant)
        assert banned.any()
        assert not banned.all()  # a locked ladder still leaves rows


def test_fleet_results_deterministic():
    """Same (n_twins, seed, iters, window) ⇒ byte-identical results block
    — the determinism contract BENCH_fleet.json's schema documents."""
    a = run_fleet(n_twins=8, seed=3, iters=ITERS, window=WINDOW)
    b = run_fleet(n_twins=8, seed=3, iters=ITERS, window=WINDOW)
    assert json.dumps(a["results"], sort_keys=True) == json.dumps(
        b["results"], sort_keys=True
    )


def test_fleet_warm_start_beats_cold():
    rec = run_fleet(n_twins=12, seed=0, iters=ITERS, window=WINDOW)
    res = rec["results"]
    assert res["feasible_rate"] > 0.5
    assert res["warm_matched"] >= 1
    assert res["warm_gain"] is not None and res["warm_gain"] > 1.0
    for fam, curves in res["convergence"].items():
        assert len(curves["cold"]) == ITERS
        # convergence curves are cumulative — monotone non-decreasing
        assert all(
            x <= y for x, y in zip(curves["cold"], curves["cold"][1:])
        )
    eng = rec["engine"]
    assert eng["table_bytes"] > 0 and eng["batch_bytes"] > 0


def test_episode_jit_donates_per_call_buffers():
    """donate_argnums on the episode jit: per-call operands (batch +
    measurement tables) are offered to XLA, which deletes every donated
    input it can alias to an output (dtype/shape-compatible; e.g. the
    int32 batch columns alias the int32 final-state outputs). The cached
    space constants (argument 2) are never donated and stay alive."""
    from repro.core.episode import EngineSpec, _compiled_runner, _device_consts
    from repro.core.space import jetson_like_space

    space = jetson_like_space("xavier_nx")
    spec = EngineSpec(spaces=(space,), iters=4, window=4)
    n = spec.n
    batch = {
        "space_id": jnp.zeros(1, jnp.int32),
        "table_id": jnp.zeros(1, jnp.int32),
        "tau_target": jnp.full(1, 5.0, jnp.float32),
        "p_budget": jnp.full(1, 1e9, jnp.float32),
        "throughput": jnp.zeros(1, bool),
    }
    tables = {
        "tau": jnp.ones((1, 4, n), jnp.float32),
        "p": jnp.ones((1, 4, n), jnp.float32),
    }
    sid_ref, tid_ref = batch["space_id"], batch["table_id"]
    res = _compiled_runner(spec)(batch, tables)
    jax.block_until_ready(res)
    assert sid_ref.is_deleted()
    assert tid_ref.is_deleted()
    consts = _device_consts(spec)
    assert not any(v.is_deleted() for v in consts.values())


def test_fleet_banned_rows_never_chosen():
    """Firmware-locked rows are born prohibited: no twin with a ladder
    variant ever measures a banned configuration."""
    from repro.core.episode import run_fleet_requests
    from repro.experiments.fleet import _request

    perts = sample_perturbations(9, seed=1)
    twins = [build_twin(p) for p in perts if p.ladder_variant != 0]
    assert twins, "sampler produced no ladder variants in 9 draws"
    reqs = [_request(t) for t in twins]
    results = run_fleet_requests(reqs, iters=ITERS, window=WINDOW)
    for twin, res in zip(twins, results):
        banned = np.flatnonzero(twin.banned)
        assert not np.isin(res["idx"], banned).any()
