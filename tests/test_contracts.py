"""Tier-1 tests for the shape/dtype contract lane (core/contracts.py):
the tables parse, check_container catches every class of violation, the
REPRO_CONTRACTS=1 lane validates a real engine run, and a corrupted
container fails loudly.
"""
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core import contracts
from repro.core.contracts import (
    CARRY_CONTRACT,
    ContractError,
    check_container,
    check_twin,
    contracts_enabled,
)
from repro.core.dcov import dcor_state_init
from repro.core.episode import run_coral_batch
from repro.core.evaluate import RegimeTargets
from repro.core.space import jetson_like_space
from repro.device import jetson_like_simulator

CONTRACT = {
    "hist": 'Float32[Array, "T+W D"]',
    "count": 'Int32[Array, ""]',
    "mask": 'Bool[Array, "N"]',
}
DIMS = {"T": 4, "W": 2, "D": 3, "N": 5}


def _good():
    return {
        "hist": np.zeros((6, 3), np.float32),
        "count": np.int32(0),
        "mask": np.zeros(5, bool),
    }


def test_every_committed_spec_parses():
    for table in (
        contracts.CARRY_CONTRACT,
        contracts.FLEET_CARRY_CONTRACT,
        contracts.DRIFT_CARRY_CONTRACT,
        contracts.DCOR_STATE_CONTRACT,
        contracts.FLEET_BATCH_CONTRACT,
        contracts.TWIN_CONTRACT,
    ):
        for spec in table.values():
            dtype, dims_expr = contracts._parse(spec)
            assert dtype in ("float32", "float64", "int32", "bool")
            contracts._expect_shape(
                dims_expr, {"T": 4, "W": 2, "D": 3, "N": 5, "C": 5, "B": 2,
                            "N0": 7},
            )


def test_check_container_accepts_valid():
    check_container("c", _good(), CONTRACT, DIMS)


def test_check_container_rejects_missing_and_extra_fields():
    c = _good()
    del c["mask"]
    with pytest.raises(ContractError, match="missing=\\['mask'\\]"):
        check_container("c", c, CONTRACT, DIMS)
    c = _good()
    c["stray"] = np.zeros(1, np.float32)
    with pytest.raises(ContractError, match="extra=\\['stray'\\]"):
        check_container("c", c, CONTRACT, DIMS)


def test_check_container_rejects_wrong_dtype():
    c = _good()
    c["hist"] = c["hist"].astype(np.float64)
    with pytest.raises(ContractError, match="dtype float64"):
        check_container("c", c, CONTRACT, DIMS)


def test_check_container_rejects_wrong_shape():
    c = _good()
    c["hist"] = np.zeros((6, 4), np.float32)  # D is 3
    with pytest.raises(ContractError, match="shape"):
        check_container("c", c, CONTRACT, DIMS)


def test_carry_contract_layering():
    base = set(contracts.carry_contract(fleet=False, drift=False))
    fleet = set(contracts.carry_contract(fleet=True, drift=False))
    drift = set(contracts.carry_contract(fleet=False, drift=True))
    assert base == set(CARRY_CONTRACT)
    assert fleet - base == set(contracts.FLEET_CARRY_CONTRACT)
    assert drift - base == set(contracts.DRIFT_CARRY_CONTRACT)


def test_lane_is_off_by_default(monkeypatch):
    monkeypatch.delenv("REPRO_CONTRACTS", raising=False)
    assert not contracts_enabled()
    monkeypatch.setenv("REPRO_CONTRACTS", "1")
    assert contracts_enabled()
    monkeypatch.setenv("REPRO_CONTRACTS", "")
    assert not contracts_enabled()


def test_contracts_lane_engine_smoke(monkeypatch):
    # with the lane on, _init_carry and the dcov constructors validate
    # at trace time — a drifted field would raise before compilation
    monkeypatch.setenv("REPRO_CONTRACTS", "1")
    sp = jetson_like_space()
    sim = jetson_like_simulator(sp)
    lt, lp = sim.exact_all()
    tg = RegimeTargets(
        mode="dual",
        tau_target=float(np.percentile(lt, 70)),
        p_budget=float(np.percentile(lp, 60)),
    )
    (ep,) = run_coral_batch(sp, lt, lp, tg, seeds=(0,), iters=8, window=6)
    assert len(ep.taus) == 8


def test_dcor_state_checked_under_lane(monkeypatch):
    monkeypatch.setenv("REPRO_CONTRACTS", "1")
    state = dcor_state_init(window=4, c=5)
    assert state["win"].shape == (4, 5)


def test_check_twin_rejects_f32_landscape():
    # TWIN_CONTRACT pins the oracle landscape to float64 — a float32
    # twin would silently halve the measurement precision
    n0 = 7
    twin = SimpleNamespace(
        space=SimpleNamespace(size=lambda: n0),
        banned=np.zeros(n0, bool),
        land_tau=np.ones(n0, np.float32),
        land_p=np.ones(n0, np.float64),
    )
    with pytest.raises(ContractError, match="land_tau"):
        check_twin(twin)
    twin.land_tau = twin.land_tau.astype(np.float64)
    check_twin(twin)
