"""Tier-1 fault-tolerance tests (EXPERIMENTS.md §Fault tolerance): the
declarative fault schedules realize deterministically; the hardened
ingest gate rejects spikes and missing telemetry; the watchdog degrades
to the safe anchor and recovers; actuation verification retries with
exponential backoff and counts exhaustion; checkpoint/restore resumes
byte-identical; pod-link outages expire shipped requests back to the
edge; and the scalar and compiled fault engines agree bit-for-bit."""
import json
import math

import numpy as np
import pytest

from repro.core import CORAL, jetson_like_space, tpu_pod_space
from repro.core.drift import CusumDetector, DriftMonitor
from repro.core.faults import (
    FaultSchedule,
    FirmwareReset,
    RobustConfig,
    SensorDropout,
    TelemetrySpike,
)
from repro.device import jetson_like_simulator
from repro.device.network import get_network
from repro.serving.controller import IntervalRecord, ServingController
from repro.serving.runtime import Request, ServingRuntime

JSPACE = jetson_like_space()


def _sim(seed=0, noise=0.0):
    return jetson_like_simulator(JSPACE, 1.0, seed=seed, noise=noise)


def _targets(sim):
    """A (tau_target, p_budget) pair with genuine feasible rows."""
    taus, powers = (np.asarray(a) for a in sim.exact_all())
    p_budget = float(np.median(powers))
    tau_target = 0.5 * float(taus[powers <= p_budget].max())
    return tau_target, p_budget


# ------------------------------------------------------- fault schedules
def test_fault_schedule_realizes_deterministic_prefix_stable_tables():
    sched = FaultSchedule(
        "s",
        (
            SensorDropout(start=2, stop=6, rate=1.0),
            TelemetrySpike(
                start=0, rate=0.5, magnitude=100.0, axis="power",
                direction="up",
            ),
        ),
    )
    a = sched.realize(30, seed=3)
    b = sched.realize(30, seed=3)
    for f in ("drop", "spike", "stick", "reset", "pod_out"):
        assert np.array_equal(getattr(a, f), getattr(b, f)), f
    assert a.drop[2:6].all() and not a.drop[:2].any() and not a.drop[6:].any()
    # up-only power spikes never push the reported draw down (a down
    # spike could anchor the ablation on a feasible-looking row)
    assert (a.spike[:, 1] >= 1.0).all()
    assert (a.spike[:, 0] == 1.0).all()  # tau channel untouched
    # per-event streams: appending an event must not shift the others
    grown = FaultSchedule("s", sched.events + (FirmwareReset(at=(4,)),))
    c = grown.realize(30, seed=3)
    assert np.array_equal(c.drop, a.drop)
    assert np.array_equal(c.spike, a.spike)
    assert c.reset[4] and c.reset.sum() == 1


# ------------------------------------------------- hardened ingest + watchdog
def test_robust_ingest_rejects_spikes_and_missing_samples():
    sim = _sim(noise=0.02)
    tau_t, p_b = _targets(sim)
    opt = CORAL(JSPACE, tau_t, p_b, seed=1, robust=RobustConfig())
    for _ in range(6):  # fill past min_accept so the MAD gate arms
        cfg = opt.next_config()
        opt.record(cfg, *sim.measure(cfg))
    n = len(opt.state.history)
    cfg = opt.next_config()
    tau, power = sim.exact(cfg)
    assert opt.record(cfg, tau * 1000.0, power) == 0.0  # storm spike
    assert len(opt.state.history) == n  # never reached the dCor window
    opt.record(cfg, float("inf"), power)  # missing sample: skipped
    opt.record(cfg, float("nan"), float("nan"))
    assert len(opt.state.history) == n
    opt.record(cfg, tau, power)  # clean sample passes the same gate
    assert len(opt.state.history) == n + 1


def test_watchdog_trips_to_safe_anchor_and_recovers():
    sim = _sim()
    tau_t, p_b = _targets(sim)
    rb = RobustConfig(watchdog=3)
    opt = CORAL(JSPACE, tau_t, p_b, seed=0, robust=rb)
    for _ in range(8):
        cfg = opt.next_config()
        opt.record(cfg, *sim.measure(cfg))
    best = opt.state.best
    assert best is not None  # a known-feasible anchor exists
    # one short of the watchdog threshold: still proposing
    for _ in range(rb.watchdog - 1):
        opt.record(opt.next_config(), float("nan"), float("nan"))
    assert opt._dark == rb.watchdog - 1
    opt.record(opt.next_config(), float("nan"), float("nan"))
    # tripped: degrade to the last-known-feasible anchor and hold it
    assert opt.next_config() == best.config == opt.safe_config()
    opt.record(opt.safe_config(), float("nan"), float("nan"))
    assert opt.next_config() == best.config  # still dark, still held
    # telemetry returns: the accepted sample re-arms the proposal loop
    opt.record(opt.safe_config(), *sim.exact(opt.safe_config()))
    assert opt._dark == 0
    # with no feasible anchor the fallback is the min-power row: never
    # bust the power budget on a device we cannot observe
    blind = CORAL(JSPACE, tau_target=1e9, p_budget=1e-9, robust=rb)
    assert blind.safe_config() == JSPACE.preset("min_power")


# ------------------------------------------------- drift monitor NaN guard
def test_cusum_nan_guard_keeps_statistics():
    det = CusumDetector(k=0.5, h=2.0)
    det.update(2.0)
    pos = det.pos
    assert pos > 0.0
    # regression: max(0.0, pos + nan - k) used to wipe the statistic
    assert det.update(float("nan")) is det.tripped
    assert det.pos == pos and det.neg == 0.0
    det.update(float("inf"))
    det.update(float("-inf"))
    assert det.pos == pos
    det.update(2.0)  # detection still works after garbage telemetry
    assert det.tripped


def test_drift_monitor_skips_nonfinite_telemetry():
    mon = DriftMonitor(ref_tau=100.0, ref_power=10.0, calibration=4)
    mon.update(float("nan"), 10.0)  # would poison the calibration mean
    mon.update(100.0, float("inf"))
    assert math.isfinite(mon.ref_tau) and math.isfinite(mon.ref_power)
    assert mon.ref_tau == 100.0 and mon.ref_power == 10.0
    for _ in range(10):
        mon.update(100.0, 10.0)
    assert not mon.tripped
    tripped = False
    for _ in range(20):
        tripped = mon.update(50.0, 10.0)  # genuine level shift
    assert tripped


# ------------------------------------------------- actuation verification
class _StickyKnob:
    """A knob whose first ``fail_writes`` writes are silently dropped."""

    def __init__(self, fail_writes):
        self.value = 0
        self.writes = 0
        self.fail_writes = fail_writes

    def set(self, v):
        self.writes += 1
        if self.writes > self.fail_writes:
            self.value = v

    def get(self):
        return self.value


def _bare_controller(robust, sleeper):
    """A controller with a runtime double: the actuation/checkpoint
    tests exercise knob verification and state serialization, never
    live traffic, so __init__ touches nothing on the runtime."""

    class _RuntimeDouble:
        pass

    return ServingController(
        _RuntimeDouble(), tpu_pod_space(), [], tau_target=1.0,
        p_budget=100.0, robust=robust, sleeper=sleeper,
    )


def test_actuation_retry_backoff_and_exhaustion():
    sleeps = []
    rb = RobustConfig(act_retries=3, backoff_s=0.05)
    c = _bare_controller(rb, sleeps.append)
    stuck = _StickyKnob(fail_writes=10**9)
    assert not c._verified_apply(stuck.set, stuck.get, 7)
    assert c.actuation_failures == 1
    assert stuck.writes == 1 + rb.act_retries  # bounded retry budget
    assert sleeps == pytest.approx([0.05, 0.10, 0.20])  # exponential
    # transient stick: the retry lands, no failure is charged
    sleeps.clear()
    flaky = _StickyKnob(fail_writes=1)
    assert c._verified_apply(flaky.set, flaky.get, 9)
    assert flaky.value == 9 and c.actuation_failures == 1
    assert sleeps == pytest.approx([0.05])
    # non-robust controller keeps the fire-and-forget single write
    sleeps.clear()
    c0 = _bare_controller(None, sleeps.append)
    stuck = _StickyKnob(fail_writes=10**9)
    assert not c0._verified_apply(stuck.set, stuck.get, 7)
    assert stuck.writes == 1 and sleeps == []
    assert c0.actuation_failures == 1


# ------------------------------------------------- checkpoint / restore
def test_checkpoint_restore_resumes_byte_identical():
    """Run A: 40 uninterrupted intervals. Run B: 20 intervals, then the
    controller 'crashes' — checkpoint through a JSON round-trip into a
    fresh optimizer — and resumes 20 more against the same twin. The
    commanded config sequences and the final pick must match A exactly
    (the checkpoint carries anchors, history, monitor and RNG
    bit-state)."""
    tau_t, p_b = _targets(_sim())
    rb = RobustConfig()

    def fresh(seed=5):
        return CORAL(JSPACE, tau_t, p_b, seed=seed, robust=rb)

    def drive(opt, sim, iters):
        out = []
        for _ in range(iters):
            cfg = opt.next_config()
            tau, power = sim.measure(cfg)
            opt.record(cfg, tau, power)
            out.append(cfg)
        return out

    opt_a, sim_a = fresh(), _sim(seed=3, noise=0.05)
    seq_a = drive(opt_a, sim_a, 40)

    opt_b, sim_b = fresh(), _sim(seed=3, noise=0.05)
    seq_b = drive(opt_b, sim_b, 20)
    blob = json.dumps(opt_b.to_checkpoint(), sort_keys=True)
    del opt_b  # the crash
    opt_c = fresh()
    opt_c.restore(json.loads(blob))
    seq_b += drive(opt_c, sim_b, 20)  # the twin (the device) survived

    assert seq_b == seq_a
    res_a, res_c = opt_a.result(), opt_c.result()
    assert (res_a is None) == (res_c is None)
    if res_a is not None:
        assert res_a.config == res_c.config
        assert res_a.tau == res_c.tau and res_a.power == res_c.power


def test_controller_checkpoint_roundtrip_and_version_guard(tmp_path):
    c = _bare_controller(RobustConfig(), lambda s: None)
    cfg = c.opt.next_config()
    c.opt.record(cfg, 5.0, 3.0)
    c.records.append(
        IntervalRecord(
            config=tuple(cfg), tau=5.0, power=3.0, reward=0.1,
            requests_done=4, queue_depth=0, p50_latency_s=0.1,
            p99_latency_s=0.2,
        )
    )
    c.actuation_failures = 2
    path = tmp_path / "controller.ckpt.json"
    c.save_checkpoint(path)
    assert not path.with_suffix(".json.tmp").exists()  # atomic write
    c2 = _bare_controller(RobustConfig(), lambda s: None)
    c2.restore_checkpoint(path)
    assert c2.records == c.records
    assert c2.actuation_failures == 2
    assert json.dumps(c2.checkpoint(), sort_keys=True) == json.dumps(
        c.checkpoint(), sort_keys=True
    )
    with pytest.raises(ValueError, match="checkpoint version"):
        c2.restore({"version": 2})


# ------------------------------------------------- pod outage / re-admit
class _EngineDouble:
    """Minimal engine double (test_offload idiom): counts entries so the
    test can prove where re-admitted requests were actually served."""

    batch = 4

    def __init__(self):
        self.prefill_calls = 0
        self.decode_calls = 0

    def prefill(self, prompts):
        self.prefill_calls += 1
        return {}, np.zeros((prompts.shape[0], prompts.shape[1], 8))

    def decode(self, cache, tok):
        self.decode_calls += 1
        return cache, np.zeros((tok.shape[0], 1, 8))


def _pod_runtime(timeout_s):
    eng = _EngineDouble()
    rt = ServingRuntime(eng, concurrency=2)
    rt.attach_pod(
        get_network("lte-uplink"), pod_time_per_token=1e-3,
        timeout_s=timeout_s,
    )
    rt.set_offload(1.0)
    rng = np.random.default_rng(0)
    for i in range(4):
        rt.submit(
            Request(i, rng.integers(0, 99, 8, dtype=np.int32), 2,
                    arrival_s=0.0)
        )
    return eng, rt


def test_pod_outage_expires_shipped_requests_back_to_edge():
    eng, rt = _pod_runtime(timeout_s=0.05)
    rt.step()  # ship everything while the link is still up
    assert len(rt._pod_inflight) == 4
    rt.set_pod_outage(True)  # responses lost until cleared
    rt.run_for(0.5, idle_wait=True)
    rt.set_pod_outage(False)
    rt.drain()
    # every shipped request hit its deadline, was re-admitted pinned to
    # the edge route, and was genuinely served by the local engine
    assert rt.pod_expired == 4
    assert len(rt.done) == 4
    assert all(r.route == "edge" for r in rt.done)
    assert eng.prefill_calls > 0


def test_pod_outage_cleared_before_deadline_loses_nothing():
    eng, rt = _pod_runtime(timeout_s=30.0)
    rt.step()
    rt.set_pod_outage(True)
    rt.run_for(0.1, idle_wait=True)
    assert len(rt.done) == 0  # responses held while the link is down
    rt.set_pod_outage(False)  # link recovers well before the deadline
    rt.drain()
    assert rt.pod_expired == 0
    assert len(rt.done) == 4
    assert all(r.route == "pod" for r in rt.done)
    assert eng.prefill_calls == 0  # nothing bounced to the edge


# ------------------------------------------------- scalar ↔ compiled parity
def test_fault_cell_scalar_compiled_parity_and_gates():
    """The compiled jit(vmap(scan)) fault engine must reproduce the
    scalar reference loop bit-for-bit on a real fault cell, and the
    record must clear the committed gates: hardened score at the
    FAULT_CORAL_GATE floor with zero power violations while the
    non-hardened ablation ends infeasible on every run."""
    from repro.experiments import FAULT_CORAL_GATE, QUICK_FAULT_CELLS
    from repro.experiments.matrix import run_fault_cell

    cell = QUICK_FAULT_CELLS[0]
    recs = {
        e: run_fault_cell(cell, seeds=(0,), engine=e)
        for e in ("compiled", "scalar")
    }
    assert json.dumps(recs["compiled"], sort_keys=True) == json.dumps(
        recs["scalar"], sort_keys=True
    )
    rec = recs["compiled"]
    assert rec["hardened"]["score"] >= FAULT_CORAL_GATE
    assert rec["hardened"]["power_violations"] == 0
    assert rec["ablation"]["failed_runs"] == rec["ablation"]["n_runs"]
