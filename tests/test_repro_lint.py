"""Tier-1 tests for tools/repro_lint: every golden fixture trips its
rule, the committed tree is clean, and the disable-pragma escape hatch
works (and demands a reason). Stdlib + pytest only — the lint tool must
stay runnable in the jax-less CI lint job.
"""
from pathlib import Path

import pytest

from tools.repro_lint.engine import REPO_ROOT, lint_paths
from tools.repro_lint.importgraph import dead_modules

FIXTURES = Path(__file__).parent / "lint_fixtures"


def _codes(violations):
    return {v.code for v in violations}


@pytest.mark.parametrize(
    "fixture, code, min_hits",
    [
        ("rl01_traced_branch.py", "RL01", 2),  # the `if` and the float()
        ("rl02_donated_use.py", "RL02", 1),
        ("rl03_nondeterminism.py", "RL03", 2),  # clock + unsorted dump
        ("rl04_dtype.py", "RL04", 2),  # missing dtype + float64
        ("rl05_interpret.py", "RL05", 3),  # default, env read, backend
        ("rl07_docstring.py", "RL07", 2),  # missing doc + stale shape
        ("rl08_swallowed_except.py", "RL08", 3),  # bare + pass + continue
    ],
)
def test_rule_fires_on_golden_fixture(fixture, code, min_hits):
    hits = lint_paths([str(FIXTURES / fixture)], select={code})
    assert len(hits) >= min_hits, f"{code} missed its golden fixture"
    assert _codes(hits) == {code}


def test_rl06_fixture_tree():
    tree = FIXTURES / "rl06_tree"
    dead = dead_modules(tree / "src", "pkg", [tree / "app.py"])
    assert [p.name for p in dead] == ["orphan.py"]


def test_rl06_main_guard_is_entry_point():
    # with no extra roots at all, cli.py (guarded) still survives
    tree = FIXTURES / "rl06_tree"
    dead = dead_modules(tree / "src", "pkg", [])
    names = {p.name for p in dead}
    assert "cli.py" not in names
    assert "orphan.py" in names


def test_repo_is_lint_clean():
    assert lint_paths(["src", "tests", "benchmarks"]) == []


def test_fixtures_excluded_from_directory_walks():
    # linting tests/ must not surface the deliberate fixture violations
    hits = lint_paths(["tests"])
    assert not any("lint_fixtures" in v.path for v in hits)


def test_committed_bench_writers_pass_rl03():
    # satellite guarantee: every BENCH_*.json writer in benchmarks/ is
    # deterministic by RL03's standard
    assert lint_paths(["benchmarks"], select={"RL03"}) == []


def test_disable_pragma_suppresses(tmp_path):
    f = tmp_path / "snippet.py"
    f.write_text(
        "import jax\n\n\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    if x > 0:  # repro-lint: disable=RL01 — fixture reason\n"
        "        return x\n"
        "    return -x\n"
    )
    assert lint_paths([str(f)], select={"RL01", "RL00"}) == []


def test_disable_pragma_on_preceding_comment_line(tmp_path):
    f = tmp_path / "snippet.py"
    f.write_text(
        "import jax\n\n\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    # repro-lint: disable=RL01 — fixture reason\n"
        "    if x > 0:\n"
        "        return x\n"
        "    return -x\n"
    )
    assert lint_paths([str(f)], select={"RL01", "RL00"}) == []


def test_disable_pragma_requires_reason(tmp_path):
    f = tmp_path / "snippet.py"
    f.write_text(
        "import jax\n\n\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    if x > 0:  # repro-lint: disable=RL01\n"
        "        return x\n"
        "    return -x\n"
    )
    hits = lint_paths([str(f)], select={"RL01", "RL00"})
    # the reasonless pragma does NOT suppress, and is itself flagged
    assert "RL00" in _codes(hits)
    assert "RL01" in _codes(hits)


def test_violation_render_is_ruff_style(tmp_path):
    f = tmp_path / "snippet.py"
    f.write_text(
        "import jax\n\n\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    if x > 0:\n"
        "        return x\n"
        "    return -x\n"
    )
    (v,) = lint_paths([str(f)], select={"RL01"})
    text = v.render()
    assert text.startswith(f"{v.path}:{v.line}:{v.col}: RL01 ")
    assert "[fix: " in text


def test_shape_metadata_is_not_tainted(tmp_path):
    # x.shape / len() yield static Python values — branching on them
    # inside jit is legitimate and must not fire RL01
    f = tmp_path / "snippet.py"
    f.write_text(
        "import jax\n\n\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    n, d = x.shape\n"
        "    if n > d:\n"
        "        return x.T\n"
        "    return x\n"
    )
    assert lint_paths([str(f)], select={"RL01"}) == []


def test_static_argnames_are_exempt(tmp_path):
    f = tmp_path / "snippet.py"
    f.write_text(
        "import functools\n"
        "import jax\n\n\n"
        '@functools.partial(jax.jit, static_argnames=("mode",))\n'
        "def f(x, mode):\n"
        '    if mode == "fast":\n'
        "        return x\n"
        "    return 2 * x\n"
    )
    assert lint_paths([str(f)], select={"RL01"}) == []


def test_rl02_reassignment_clears_poison(tmp_path):
    # the classic donation loop: params is rebound from the call result
    f = tmp_path / "snippet.py"
    f.write_text(
        "import jax\n\n\n"
        "def g(a, b):\n"
        "    return a + b, b\n\n\n"
        "step = jax.jit(g, donate_argnums=(0,))\n\n\n"
        "def loop(params, grads):\n"
        "    for _ in range(3):\n"
        "        params, grads = step(params, grads)\n"
        "    return params\n"
    )
    assert lint_paths([str(f)], select={"RL02"}) == []


def test_rl03_sorted_json_is_clean(tmp_path):
    f = tmp_path / "bench_snippet.py"
    f.write_text(
        "import json\n\n\n"
        "def write(results, path):\n"
        "    path.write_text(json.dumps(results, sort_keys=True))\n"
    )
    assert lint_paths([str(f)], select={"RL03"}) == []


def test_emit_json_results_are_key_order_independent():
    """Property behind RL03: the canonical writer's bytes cannot depend
    on dict insertion order (hypothesis-driven where available)."""
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    import json
    import sys

    sys.path.insert(0, str(REPO_ROOT / "benchmarks"))
    try:
        from common import emit_json
    finally:
        sys.path.pop(0)

    @hypothesis.given(
        st.dictionaries(
            st.text(min_size=1, max_size=8),
            st.one_of(st.integers(), st.floats(allow_nan=False), st.text(max_size=8)),
            min_size=1,
            max_size=8,
        ),
        st.randoms(),
    )
    @hypothesis.settings(max_examples=50, deadline=None)
    def check(payload, rng):
        import tempfile
        from pathlib import Path as P

        keys = list(payload)
        rng.shuffle(keys)
        shuffled = {k: payload[k] for k in keys}
        with tempfile.TemporaryDirectory() as d:
            a, b = P(d) / "a.json", P(d) / "b.json"
            emit_json(a, payload)
            emit_json(b, shuffled)
            assert a.read_bytes() == b.read_bytes()
            # and the bytes round-trip
            assert json.loads(a.read_text()) == payload

    check()
