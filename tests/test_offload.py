"""Edge↔pod offload seam tests (EXPERIMENTS.md §Offload).

The placement invariants the tentpole is built around:

  * pod-side compute power NEVER lands on the edge power rail — the
    measured p channel is edge silicon + radio only, at both the twin
    level (``OffloadSimulator.exact_all``) and the serving level
    (pod-routed requests never enter the engine's slots);
  * network energy is metered per shipped token on the edge rail;
  * a pod-routed request's latency includes the network (upload
    serialization + RTT), so windowed SLO metrics see the link;
  * the compiled episode engine and the scalar CORAL loop are
    byte-equivalent on the enlarged joint space.
"""

import numpy as np
import pytest

from repro.core.evaluate import run_regime
from repro.core.episode import run_static_requests
from repro.core.space import OFFLOAD_DIM
from repro.device.network import OffloadSimulator, get_network
from repro.experiments import (
    MATRIX_OFFLOAD_CELLS,
    OFFLOAD_REGIMES,
    WORKLOADS,
    offload_cell_simulator,
    resolve_offload_targets,
)
from repro.serving.runtime import Request, ServingRuntime

CELL = MATRIX_OFFLOAD_CELLS[0]  # edge-xavier-nx / qwen2.5-3b / mmpp


# ------------------------------------------------------------- twin rail
def test_pod_power_never_on_edge_rail():
    """The measured power channel is the edge rail: pod DVFS moves τ but
    not p (demand-saturated rows draw identical power at every pod
    frequency), and the radio terms are exactly the documented
    ``radio_idle + ship_energy · φ·τ`` increment over the edge-only
    power."""
    sim = offload_cell_simulator(CELL, noise=0.0)
    grid = sim.space.grid()
    cols = {n: grid[:, i] for i, n in enumerate(sim.space.names)}
    phi = cols[OFFLOAD_DIM]
    tau, p = sim.exact_all(grid)
    _, p_edge = sim.capacity_all(grid)

    net = sim.network
    radio = np.where(phi > 0.0, net.radio_idle_w + net.ship_energy_j * phi * tau, 0.0)
    np.testing.assert_allclose(p, p_edge + radio, rtol=1e-12)

    # φ=0 rows: pure edge rail, no radio terms at all
    np.testing.assert_allclose(p[phi == 0.0], p_edge[phi == 0.0], rtol=1e-12)

    # demand-saturated φ>0 rows: pod frequency changes τ-side routing
    # capacity only — the edge rail cannot see the pod's own draw
    sat = (phi > 0.0) & (tau >= sim.demand - 1e-9)
    assert sat.any(), "calibrated demand should saturate some joint rows"
    key_names = [n for n in sim.space.names if n != "pod_tpu_freq"]
    by_edge_knobs = {}
    for row, pw, is_sat in zip(grid, p, sat):
        if not is_sat:
            continue
        k = tuple(row[sim.space.names.index(n)] for n in key_names)
        by_edge_knobs.setdefault(k, []).append(pw)
    multi = [v for v in by_edge_knobs.values() if len(v) > 1]
    assert multi, "need saturated rows differing only in pod_tpu_freq"
    for powers in multi:
        assert max(powers) - min(powers) < 1e-9


def test_offload_capacity_is_two_path_min():
    """φ=0 degenerates to the plain edge path; φ=1 to the pod path."""
    sim = offload_cell_simulator(CELL, noise=0.0)
    sim.demand = float("inf")
    grid = sim.space.grid()
    cols = {n: grid[:, i] for i, n in enumerate(sim.space.names)}
    cap, _ = sim.capacity_all(grid)
    phi = cols[OFFLOAD_DIM]
    pod_only = phi == 1.0
    if pod_only.any():
        np.testing.assert_allclose(
            cap[pod_only], sim.offload_cap(cols["pod_tpu_freq"][pod_only])
        )
    # mixed rows can never beat the sum of both pure paths
    edge_best = sim.edge_only_max()
    pod_best = float(sim.offload_cap(np.asarray([cols["pod_tpu_freq"].max()]))[0])
    assert cap.max() <= edge_best + pod_best + 1e-9


# ------------------------------------------------------- serving runtime
class _CountingEngine:
    """Minimal engine double: counts entries so the test can prove
    pod-routed requests never reach the edge compute path."""

    batch = 4

    def __init__(self):
        self.prefill_calls = 0
        self.decode_calls = 0

    def prefill(self, prompts):
        self.prefill_calls += 1
        return {}, np.zeros((prompts.shape[0], prompts.shape[1], 8))

    def decode(self, cache, tok):
        self.decode_calls += 1
        return cache, np.zeros((tok.shape[0], 1, 8))


def _run_split(frac, n=8, max_new=4, prompt_len=8):
    net = get_network("lte-uplink")
    eng = _CountingEngine()
    rt = ServingRuntime(eng, concurrency=2)
    rt.attach_pod(net, pod_time_per_token=1e-3)
    rt.set_offload(frac)
    rng = np.random.default_rng(0)
    for i in range(n):
        rt.submit(
            Request(i, rng.integers(0, 99, prompt_len, dtype=np.int32), max_new,
                    arrival_s=0.0)
        )
    rt.drain()
    return net, eng, rt


def test_pod_routed_requests_never_enter_engine():
    net, eng, rt = _run_split(1.0)
    assert len(rt.done) == 8
    assert all(r.route == "pod" for r in rt.done)
    assert eng.prefill_calls == 0 and eng.decode_calls == 0
    assert rt.prefills == 0  # the edge compute rail stayed dark


def test_deterministic_fractional_routing():
    net, eng, rt = _run_split(0.5)
    pod = [r for r in rt.done if r.route == "pod"]
    edge = [r for r in rt.done if r.route == "edge"]
    assert len(pod) == 4 and len(edge) == 4
    assert eng.prefill_calls > 0  # edge share genuinely ran locally
    # same seed + same knob ⇒ identical split (accumulator, not RNG)
    _, _, rt2 = _run_split(0.5)
    assert [r.route for r in rt2.done] == [r.route for r in rt.done]


def test_network_energy_metered_per_shipped_token():
    net, eng, rt = _run_split(0.5, n=8, max_new=4, prompt_len=8)
    pod = [r for r in rt.done if r.route == "pod"]
    expect = sum(
        (r.prompt.size + r.max_new_tokens) * net.ship_energy_per_token_j
        for r in pod
    )
    assert rt.network_energy_j == pytest.approx(expect, rel=1e-12)
    # no offload, no radio energy
    _, _, rt0 = _run_split(0.0)
    assert rt0.network_energy_j == 0.0


def test_pod_latency_includes_network():
    """SLO accounting sees the link: a pod-routed completion can never
    finish before upload serialization + RTT + remote service."""
    net, eng, rt = _run_split(0.5, prompt_len=8, max_new=4)
    pod = [r for r in rt.done if r.route == "pod"]
    assert pod
    for r in pod:
        lat = r.finished - rt._effective_arrival(r)
        floor = (
            r.prompt.size * net.token_bytes / net.bandwidth
            + net.rtt_s
            + r.max_new_tokens * 1e-3
        )
        assert lat >= floor - 1e-9


def test_offload_knob_requires_network():
    from repro.device import get_profile
    from repro.serving.controller import ServingController

    prof = get_profile("edge-xavier-nx")
    space = offload_cell_simulator(CELL, noise=0.0).space
    rt = ServingRuntime(_CountingEngine(), concurrency=2)
    with pytest.raises(ValueError, match="offload_frac"):
        ServingController(rt, space, [], tau_target=1.0, profile=prof)


# ------------------------------------------------- engine ↔ scalar loop
def test_engine_matches_scalar_on_offload_cell():
    """The compiled episode engine replays the OffloadSimulator noise
    protocol byte-for-byte on the enlarged joint space."""
    sim0 = offload_cell_simulator(CELL, noise=0.0)
    targets = resolve_offload_targets(CELL, sim0)
    assert targets.mode == "dual" and np.isfinite(targets.p_budget)
    land_tau, land_p = sim0.exact_all()
    noise = WORKLOADS[CELL.workload].noise
    seeds = (0, 1)
    reqs = [
        dict(space=sim0.space, land_tau=land_tau, land_p=land_p,
             targets=targets, seed=s, noise=noise)
        for s in seeds
    ]
    eps = run_static_requests(reqs, iters=12)
    for seed, ep in zip(seeds, eps):
        dev = offload_cell_simulator(CELL, seed=seed)
        out, tr = run_regime(sim0.space, dev, targets, iters=12, seed=seed)
        assert [tuple(c) for c in tr.configs] == [tuple(c) for c in ep.configs]
        np.testing.assert_allclose(tr.taus, ep.taus, rtol=1e-12)
        np.testing.assert_allclose(tr.powers, ep.powers, rtol=1e-12)
        assert tuple(out.config) == tuple(ep.outcome.config)
        assert out.tau == pytest.approx(ep.outcome.tau, rel=1e-12)
        assert out.power == pytest.approx(ep.outcome.power, rel=1e-12)


def test_run_offload_cell_records_identical_across_engines():
    from repro.experiments.matrix import run_offload_cell

    a = run_offload_cell(CELL, iters=12, seeds=(0, 1), engine="compiled")
    b = run_offload_cell(CELL, iters=12, seeds=(0, 1), engine="scalar")
    assert a == b


def test_offload_regime_calibration_provenance():
    """The recorded offload block carries the calibration the gates rest
    on: λ = demand_factor × edge-only max, τ* = slo_frac × λ, and the
    φ=0 restriction of the joint grid has no feasible row."""
    from repro.experiments.matrix import run_offload_cell

    rec = run_offload_cell(CELL, iters=12, seeds=(0,))
    o = rec["offload"]
    regime = OFFLOAD_REGIMES[CELL.regime]
    assert o["network"] == regime.network
    assert o["demand"] == pytest.approx(
        regime.demand_factor * o["edge_only_max"], rel=1e-3
    )
    assert rec["tau_target"] == pytest.approx(
        regime.slo_frac * o["demand"], rel=1e-3
    )
    assert o["no_offload"]["feasible_rows"] == 0
    assert o["no_offload"]["violates_tau"]
