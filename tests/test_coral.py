"""CORAL optimizer behaviour + the paper's headline claims on the device
simulator (§IV-B semantics)."""
import pytest

from repro.core import CORAL, run_coral, tpu_pod_space, jetson_like_space
from repro.core.baselines import alert, alert_online, oracle, preset
from repro.device import DeviceSimulator, jetson_like_simulator, synthetic_terms


@pytest.fixture(scope="module")
def jspace():
    return jetson_like_space("xavier_nx")


def _jdev(jspace, seed=0, noise=0.02):
    return jetson_like_simulator(jspace, 1.0, seed=seed, noise=noise)


def test_prohibited_configs_not_reproposed(jspace):
    opt = CORAL(jspace, tau_target=1e9, p_budget=0.0)  # everything infeasible
    seen = set()
    for _ in range(8):
        cfg = opt.propose()
        assert cfg not in seen, "re-proposed a prohibited/visited config"
        seen.add(cfg)
        opt.observe(cfg, tau=1.0, power=100.0)


def test_best_second_ordering(jspace):
    opt = CORAL(jspace, tau_target=10, p_budget=100)
    c1, c2, c3 = list(jspace.all_configs())[:3]
    opt.observe(c1, 20, 10)  # r=2
    opt.observe(c2, 30, 10)  # r=3 -> best
    opt.observe(c3, 25, 10)  # r=2.5 -> second
    assert opt.state.best.config == c2
    assert opt.state.second.config == c3


def test_single_constraint_matches_oracle(jspace):
    """Paper: CORAL achieves 96-100% of ORACLE in single-target scenarios."""
    orc_max = oracle(jspace, _jdev(jspace, noise=0.0), tau_target=0.0)
    tau_t = round(orc_max.tau * 0.55)
    orc = oracle(jspace, _jdev(jspace, noise=0.0), tau_t)
    ratios = []
    for seed in range(5):
        out, _ = run_coral(jspace, _jdev(jspace, seed), tau_t, iters=10, seed=seed)
        assert out.feasible(tau_t, float("inf"))
        ratios.append(out.tau / orc.tau)
    assert min(ratios) >= 0.96, ratios


def test_dual_constraint_feasible_within_budget(jspace):
    """Paper: CORAL consistently finds valid configs in dual-constraint
    scenarios within the 10-iteration budget."""
    orc_max = oracle(jspace, _jdev(jspace, noise=0.0), tau_target=0.0)
    tau_t = round(orc_max.tau * 0.55)
    p_budget = oracle(jspace, _jdev(jspace, noise=0.0), tau_t).power * 1.08
    ok = 0
    for seed in range(5):
        out, _ = run_coral(jspace, _jdev(jspace, seed), tau_t, p_budget,
                           iters=10, seed=seed)
        ok += out.feasible(tau_t, p_budget)
    assert ok >= 4, f"only {ok}/5 runs feasible"


def test_alert_exceeds_power_budget_dual(jspace):
    """Paper: ALERT prioritizes throughput and busts strict power caps."""
    orc_max = oracle(jspace, _jdev(jspace, noise=0.0), tau_target=0.0)
    tau_t = round(orc_max.tau * 0.55)
    p_budget = oracle(jspace, _jdev(jspace, noise=0.0), tau_t).power * 1.08
    al = alert(jspace, _jdev(jspace, 3), tau_t, p_budget)
    assert al.power > p_budget


def test_alert_online_fails_narrow_region(jspace):
    orc_max = oracle(jspace, _jdev(jspace, noise=0.0), tau_target=0.0)
    tau_t = round(orc_max.tau * 0.55)
    p_budget = oracle(jspace, _jdev(jspace, noise=0.0), tau_t).power * 1.08
    fails = 0
    for seed in range(5):
        alo = alert_online(jspace, _jdev(jspace, seed), tau_t, p_budget, seed=seed)
        fails += not alo.feasible(tau_t, p_budget)
    assert fails >= 3, "random exploration should mostly miss the narrow region"


def test_presets_straddle_the_tradeoff(jspace):
    """max-power over-consumes; default under-delivers (paper Fig. 3)."""
    mx = preset(jspace, _jdev(jspace, 1), "max_power")
    df = preset(jspace, _jdev(jspace, 2), "default")
    assert mx.power > 2 * df.power
    assert mx.tau > 2 * df.tau


def test_coral_measurement_budget(jspace):
    """CORAL must use orders of magnitude fewer measurements than ORACLE."""
    dev = _jdev(jspace, 0)
    run_coral(jspace, dev, 30, iters=10)
    assert dev.n_measurements == 10
    assert jspace.size() > 100 * dev.n_measurements


def test_tpu_pod_space_scenario():
    space = tpu_pod_space()
    terms = synthetic_terms("balanced")
    dev0 = DeviceSimulator(space, terms, noise=0.0)
    orc = oracle(space, dev0, tau_target=0.0)
    tau_t = orc.tau * 0.6
    p_b = orc.power * 0.62
    ok = 0
    for seed in range(5):
        out, _ = run_coral(space, DeviceSimulator(space, terms, seed=seed),
                           tau_t, p_b, iters=10, seed=seed)
        ok += out.feasible(tau_t, p_b)
    assert ok >= 3
