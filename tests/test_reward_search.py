"""Algorithm 1 (reward) and Algorithm 2 (search) unit tests."""
import pytest

from repro.core.reward import reward
from repro.core.search import next_config
from repro.core.space import tpu_pod_space


def test_reward_feasible_is_efficiency():
    ps = set()
    r = reward(tau=40.0, p=8.0, x=(1,), prohibited=ps, tau_target=30, p_budget=10)
    assert r == pytest.approx(5.0)
    assert not ps


def test_reward_infeasible_penalty_and_prohibited():
    ps = set()
    r = reward(tau=20.0, p=8.0, x=(1, 2), prohibited=ps, tau_target=30, p_budget=10)
    assert r == pytest.approx(-0.4)
    assert (1, 2) in ps


def test_reward_power_violation():
    ps = set()
    r = reward(tau=40.0, p=12.0, x=(3,), prohibited=ps, tau_target=30, p_budget=10)
    assert r < 0 and (3,) in ps


def test_infeasible_always_ranks_below_feasible():
    ps = set()
    r_feas = reward(1.0, 1000.0, (0,), ps, tau_target=0.5, p_budget=2000)
    r_infeas = reward(1000.0, 1.0, (1,), ps, tau_target=2000, p_budget=2000)
    assert r_feas > r_infeas


def _uniform(space, v=1.0):
    return [v] * len(space.dims)


def test_search_moves_down_when_target_met():
    space = tpu_pod_space()
    x = space.preset("max_power")
    y = space.preset("default")
    z = next_config(
        space, x, y, _uniform(space), _uniform(space),
        tau_last=100, p_last=50, tau_target=10, p_min=0, aside=False,
        tau_best=100, p_best=50, power_probe=False,
    )
    # τ met and power above floor -> every dim moves toward lower values
    for zi, xi in zip(z, x):
        assert zi <= xi


def test_search_moves_up_when_target_unmet():
    space = tpu_pod_space()
    x = space.preset("default")
    y = space.preset("min_power")
    z = next_config(
        space, x, y, _uniform(space), _uniform(space),
        tau_last=5, p_last=50, tau_target=10, p_min=0, aside=False,
        tau_best=5, p_best=50, power_probe=False,
    )
    for zi, yi in zip(z, y):
        assert zi >= yi


def test_search_result_on_grid():
    space = tpu_pod_space()
    z = next_config(
        space, space.preset("max_power"), space.preset("default"),
        _uniform(space, 0.7), _uniform(space, 0.3),
        tau_last=100, p_last=50, tau_target=10, p_min=0, aside=False,
        tau_best=100, p_best=50, power_probe=False,
    )
    for zi, dim in zip(z, space.dims):
        assert zi in dim.values


def test_weak_correlation_dims_change_minimally():
    """γ_i ≈ 0 dims must stay put even when anchors differ."""
    space = tpu_pod_space()
    x = space.preset("max_power")
    y = space.preset("default")
    alpha = [0.0] * len(space.dims)
    beta = [0.0] * len(space.dims)
    z = next_config(
        space, x, y, alpha, beta,
        tau_last=100, p_last=50, tau_target=10, p_min=0, aside=False,
        tau_best=100, p_best=50, power_probe=False,
    )
    assert tuple(z) == tuple(x)


def test_power_probe_pins_cores_min_concurrency_max():
    space = tpu_pod_space()
    z = next_config(
        space, space.preset("max_power"), space.preset("default"),
        _uniform(space), _uniform(space),
        tau_last=100, p_last=50, tau_target=10, p_min=0, aside=False,
        tau_best=100, p_best=50, power_probe=True,
    )
    i_cores = space.index("host_cores")
    i_conc = space.index("concurrency")
    assert z[i_cores] == space.dims[i_cores].lo
    assert z[i_conc] == space.dims[i_conc].hi


def test_aside_flips_anchors():
    space = tpu_pod_space()
    x = space.preset("max_power")
    y = space.preset("min_power")
    kw = dict(
        tau_last=100, p_last=50, tau_target=10, p_min=0,
        tau_best=100, p_best=50, power_probe=False,
    )
    g = _uniform(space)
    z_no = next_config(space, x, y, g, g, aside=False, **kw)
    z_yes = next_config(space, x, y, g, g, aside=True, **kw)
    # down-direction from l: l is x when aside=False, y when aside=True
    assert z_no != z_yes
