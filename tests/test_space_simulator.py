"""Config space + device simulator tests. Hypothesis-based property tests
live in test_properties.py (optional dependency)."""
import numpy as np
import pytest

from repro.core.space import jetson_like_space, tpu_pod_space
from repro.device import DeviceSimulator, synthetic_terms
from repro.device.perfmodel import canon


def test_space_sizes_match_table2_structure():
    assert tpu_pod_space().size() == 8 * 5 * 6 * 3 * 5
    assert jetson_like_space("xavier_nx").size() == 8 * 5 * 6 * 3 * 3
    assert jetson_like_space("orin_nano").size() == 8 * 5 * 4 * 2 * 5


def test_snap_to_grid():
    sp = tpu_pod_space()
    cfg = sp.snap([1234, 3.7, 700, 2000, 2.2])
    for v, d in zip(cfg, sp.dims):
        assert v in d.values


def test_presets():
    sp = tpu_pod_space()
    assert sp.preset("max_power") == tuple(d.hi for d in sp.dims)
    default = sp.preset("default")
    assert default[sp.index("concurrency")] == sp.dims[sp.index("concurrency")].lo


def test_neighbors_differ_in_one_dim():
    sp = tpu_pod_space()
    c = sp.preset("default")
    for nb in sp.neighbors(c):
        diffs = sum(a != b for a, b in zip(c, nb))
        assert diffs == 1


def test_canon_aliases():
    d = canon({"cpu_freq": 1, "cpu_cores": 2, "gpu_freq": 3, "mem_freq": 4,
               "concurrency": 5})
    assert d == {"host_cpu_freq": 1, "host_cores": 2, "tpu_freq": 3,
                 "hbm_freq": 4, "concurrency": 5}


@pytest.fixture(scope="module")
def dev():
    return DeviceSimulator(tpu_pod_space(), synthetic_terms("balanced"), noise=0.0)


def test_power_monotone_in_tpu_freq(dev):
    sp = dev.space
    base = list(sp.preset("default"))
    i = sp.index("tpu_freq")
    powers = []
    for f in sp.dims[i].values:
        c = list(base)
        c[i] = f
        powers.append(dev.exact(tuple(c))[1])
    assert all(a <= b + 1e-6 for a, b in zip(powers, powers[1:]))


def test_throughput_monotone_in_tpu_freq_when_compute_bound():
    sp = tpu_pod_space()
    d = DeviceSimulator(sp, synthetic_terms("compute_bound"), noise=0.0)
    base = list(sp.preset("max_power"))
    i = sp.index("tpu_freq")
    taus = []
    for f in sp.dims[i].values:
        c = list(base)
        c[i] = f
        taus.append(d.exact(tuple(c))[0])
    assert all(a <= b + 1e-6 for a, b in zip(taus, taus[1:]))


def test_hbm_freq_irrelevant_when_compute_bound():
    sp = tpu_pod_space()
    d = DeviceSimulator(sp, synthetic_terms("compute_bound"), noise=0.0)
    base = list(sp.preset("max_power"))
    i = sp.index("hbm_freq")
    taus = set()
    for f in sp.dims[i].values:
        c = list(base)
        c[i] = f
        taus.add(round(d.exact(tuple(c))[0], 6))
    assert len(taus) == 1  # memory clock can't move a compute-bound workload


def test_same_throughput_different_power_exists(dev):
    """The paper's Fig.-1 motivation: ~equal τ at ≥1.3× power spread."""
    taus = {}
    for c in list(dev.space.all_configs())[::7]:
        t, p = dev.exact(c)
        taus.setdefault(round(t / 500), []).append(p)
    spreads = [max(v) / min(v) for v in taus.values() if len(v) > 3]
    assert max(spreads) > 1.3


def test_measure_noise_and_counting():
    d = DeviceSimulator(tpu_pod_space(), synthetic_terms("balanced"),
                        noise=0.05, seed=0)
    c = d.space.preset("default")
    vals = {d.measure(c)[0] for _ in range(5)}
    assert len(vals) > 1  # noisy
    assert d.n_measurements == 5


def test_grid_matches_all_configs_order():
    sp = tpu_pod_space()
    g = sp.grid()
    assert g.shape == (sp.size(), len(sp.dims))
    assert np.array_equal(g, np.array(list(sp.all_configs())))
