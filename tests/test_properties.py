"""Hypothesis property tests (collected from test_dcov, test_space_simulator
and test_decode_multistep). The whole module is skipped when ``hypothesis``
is not installed, so tier-1 collection never hard-fails on the optional
dependency."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.dcov import dcor, dcor_all  # noqa: E402
from repro.core.space import tpu_pod_space  # noqa: E402
from repro.device import DeviceSimulator, synthetic_terms  # noqa: E402


# ------------------------------------------------------------------- dcov
@settings(max_examples=30, deadline=None)
@given(
    st.lists(st.floats(-1e3, 1e3), min_size=4, max_size=40),
    st.lists(st.floats(-1e3, 1e3), min_size=4, max_size=40),
)
def test_property_dcor_in_unit_interval(xs, ys):
    n = min(len(xs), len(ys))
    v = float(dcor(jnp.asarray(xs[:n]), jnp.asarray(ys[:n])))
    assert 0.0 <= v <= 1.0


@settings(max_examples=20, deadline=None)
@given(
    st.lists(
        st.floats(-100, 100).filter(lambda v: abs(v) > 1e-3),
        min_size=5, max_size=30, unique=True,
    ),
    st.floats(0.1, 10.0),
    st.floats(-5.0, 5.0),
)
def test_property_scale_invariance(xs, a, b):
    """dCor is invariant to positive affine transforms of either argument."""
    x = jnp.asarray(xs)
    y = x**2  # deterministic dependence
    d1 = float(dcor(x, y))
    d2 = float(dcor(a * x + b, y))
    assert d1 == pytest.approx(d2, abs=5e-3)


@settings(max_examples=15, deadline=None)
@given(st.integers(3, 10), st.integers(0, 2**31 - 1))
def test_property_dcor_all_matches_per_pair(n, seed):
    """The batched engine equals the per-pair loop at every window fill."""
    rng = np.random.default_rng(seed)
    w, d, m = 10, 3, 2
    s = np.zeros((w, d), np.float32)
    mm = np.zeros((w, m), np.float32)
    s[:n] = rng.normal(size=(n, d))
    mm[:n] = rng.normal(size=(n, m))
    batched = np.asarray(dcor_all(jnp.asarray(s), jnp.asarray(mm), np.int32(n)))
    for i in range(d):
        for j in range(m):
            ref = float(dcor(jnp.asarray(mm[:n, j]), jnp.asarray(s[:n, i])))
            assert batched[i, j] == pytest.approx(ref, abs=1e-5)


# -------------------------------------------------------------- simulator
@settings(max_examples=25, deadline=None)
@given(st.integers(0, 3599))
def test_property_simulator_outputs_positive(idx):
    sp = tpu_pod_space()
    dev = DeviceSimulator(sp, synthetic_terms("balanced"), noise=0.0)
    cfgs = list(sp.all_configs())
    tau, p = dev.exact(cfgs[idx % len(cfgs)])
    assert tau > 0 and p > 0


# ---------------------------------------------------------------------------
# CORAL state-machine invariants under arbitrary observation sequences
# ---------------------------------------------------------------------------


# ------------------------------------------------- episode engine windows
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(10.0, 5000.0), st.floats(0.0, 0.08))
def test_property_compiled_episode_matches_scalar_on_random_windows(
    seed, tau_target, noise
):
    """Engine-vs-scalar equivalence as a property: on a random synthetic
    landscape, random τ target/budget and random measurement noise, the
    compiled episode replays the scalar loop's selections exactly and
    its float64 trace equals the scalar measurements."""
    from repro.core.episode import run_coral_batch
    from repro.core.evaluate import RegimeTargets, run_coral
    from repro.device import jetson_like_simulator
    from repro.core.space import jetson_like_space

    space = jetson_like_space("xavier_nx")
    dev0 = jetson_like_simulator(space, 1.0, noise=0.0)
    land_tau, land_p = dev0.exact_all()
    p_budget = float(np.quantile(land_p, 0.7))
    targets = RegimeTargets(mode="dual", tau_target=tau_target, p_budget=p_budget)
    dev = jetson_like_simulator(space, 1.0, seed=seed, noise=noise)
    out, tr = run_coral(
        space, dev, tau_target, p_budget, iters=10, seed=seed
    )
    (ep,) = run_coral_batch(
        space, land_tau, land_p, targets, [seed], iters=10, noise=noise
    )
    assert [tuple(c) for c in tr.configs] == [tuple(c) for c in ep.configs]
    np.testing.assert_allclose(tr.taus, ep.taus, rtol=1e-12)
    np.testing.assert_allclose(tr.powers, ep.powers, rtol=1e-12)
    assert (out.config is None) == (ep.outcome.config is None)
    if out.config is not None:
        assert tuple(out.config) == tuple(ep.outcome.config)


@settings(max_examples=25, deadline=None)
@given(
    st.lists(
        st.tuples(st.floats(0.1, 100.0), st.floats(0.1, 100.0)),
        min_size=1, max_size=12,
    ),
    st.floats(1.0, 50.0),
    st.floats(5.0, 80.0),
)
def test_property_coral_invariants(measurements, tau_target, p_budget):
    from repro.core.coral import CORAL

    space = tpu_pod_space()
    opt = CORAL(space, tau_target, p_budget, seed=0)
    for tau, p in measurements:
        cfg = opt.propose()
        assert cfg not in opt.state.prohibited, "proposed a prohibited config"
        for v, d in zip(cfg, space.dims):
            assert v in d.values, "proposal off the grid"
        opt.observe(cfg, tau, p)
        st_ = opt.state
        # best has the max reward seen; second is <= best
        assert st_.best.reward == max(o.reward for o in st_.history)
        if st_.second is not None:
            assert st_.second.reward <= st_.best.reward
        # prohibited configs are exactly the infeasible observations
        for o in st_.history:
            infeasible = o.tau < tau_target or o.power > p_budget
            assert (o.config in st_.prohibited) == any(
                (h.config == o.config and (h.tau < tau_target or h.power > p_budget))
                for h in st_.history
            ) or not infeasible
    res = opt.result()
    feas = [o for o in opt.state.history
            if o.tau >= tau_target and o.power <= p_budget]
    if feas:
        assert res.tau >= tau_target and res.power <= p_budget


@settings(max_examples=15, deadline=None)
@given(
    st.integers(4, 12),
    st.integers(2, 5),
    st.integers(1, 30),
    st.integers(0, 2**31 - 1),
)
def test_property_incremental_dcor_matches_full(w, d, steps, seed):
    """The fleet engine's O(W·C) ring update reads out the same window
    correlations as the O(W²·C) full recompute, at every fill level
    (padded rows masked by n_valid) and through wrap-around."""
    from repro.core.dcov import (
        dcor_all_cols,
        dcor_state_corr,
        dcor_state_init,
        dcor_state_push,
    )

    rng = np.random.default_rng(seed)
    m = 2
    c = d + m
    state = dcor_state_init(w, c)
    win = np.zeros((w, c), np.float32)
    for t in range(steps):
        row = rng.normal(size=c).astype(np.float32)
        slot = t % w
        state = dcor_state_push(
            state, jnp.asarray(row), jnp.int32(slot), jnp.int32(min(t, w))
        )
        win[slot] = row
    n_valid = min(steps, w)
    incr = np.asarray(dcor_state_corr(state, jnp.int32(n_valid), d))
    full = np.asarray(dcor_all_cols(jnp.asarray(win), jnp.int32(n_valid), d))
    np.testing.assert_allclose(incr, full, atol=5e-3)
