"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dcov import dcor_pallas, dcor_ref
from repro.kernels.flash_attention import attention_ref, flash_attention_bhsd
from repro.kernels.ssd_scan import ssd, ssd_ref

RNG = np.random.default_rng(0)


# ------------------------------------------------------------------- dcov
@pytest.mark.parametrize("n", [5, 63, 128, 300])
@pytest.mark.parametrize("block", [64, 128])
def test_dcov_kernel_matches_ref(n, block):
    x = jnp.asarray(RNG.normal(size=n), jnp.float32)
    y = jnp.asarray(x**2 + RNG.normal(size=n) * 0.1, jnp.float32)
    a = float(dcor_pallas(x, y, block=block))
    b = float(dcor_ref(x, y))
    assert a == pytest.approx(b, abs=1e-5)


def test_dcov_kernel_matches_core_dcor():
    from repro.core.dcov import dcor

    x = jnp.asarray(RNG.normal(size=200), jnp.float32)
    y = jnp.asarray(np.sin(np.asarray(x)) + RNG.normal(size=200) * 0.05)
    assert float(dcor_pallas(x, y)) == pytest.approx(float(dcor(x, y)), abs=1e-5)


# --------------------------------------------------------- flash attention
@pytest.mark.parametrize(
    "b,hq,hkv,s,d,causal,window",
    [
        (1, 2, 2, 64, 32, True, None),
        (2, 4, 2, 96, 32, True, None),  # GQA
        (1, 4, 1, 128, 16, True, 24),  # MQA + sliding window
        (2, 2, 2, 80, 32, False, None),  # bidirectional (whisper encoder)
        (1, 8, 2, 72, 64, True, 16),
    ],
)
def test_flash_attention_matches_ref(b, hq, hkv, s, d, causal, window):
    q = jnp.asarray(RNG.normal(size=(b, hq, s, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, hkv, s, d)), jnp.float32)
    out = flash_attention_bhsd(q, k, v, causal=causal, window=window,
                               block_q=32, block_k=32)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = jnp.asarray(RNG.normal(size=(1, 2, 64, 32)), dtype)
    k = jnp.asarray(RNG.normal(size=(1, 2, 64, 32)), dtype)
    v = jnp.asarray(RNG.normal(size=(1, 2, 64, 32)), dtype)
    out = flash_attention_bhsd(q, k, v, block_q=32, block_k=32)
    ref = attention_ref(q, k, v)
    atol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=atol
    )
    assert out.dtype == dtype


def test_flash_attention_unpadded_tail():
    """Sequence not a multiple of the block size."""
    q = jnp.asarray(RNG.normal(size=(1, 2, 70, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 2, 70, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 2, 70, 32)), jnp.float32)
    out = flash_attention_bhsd(q, k, v, block_q=32, block_k=32)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# ----------------------------------------------------------------- ssd scan
@pytest.mark.parametrize(
    "b,s,nh,hd,n,chunk",
    [(1, 32, 2, 16, 8, 8), (2, 64, 4, 16, 16, 16), (1, 48, 1, 8, 4, 16)],
)
def test_ssd_kernel_matches_ref(b, s, nh, hd, n, chunk):
    x = jnp.asarray(RNG.normal(size=(b, s, nh, hd)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(b, s, nh)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2, size=(nh,)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    y1, s1 = ssd(x, dt, A, Bm, Cm, chunk=chunk)
    y2, s2 = ssd_ref(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)


def test_ssd_kernel_initial_state_chaining():
    """Running two halves with carried state == running the whole sequence."""
    b, s, nh, hd, n, chunk = 1, 32, 2, 8, 4, 8
    x = jnp.asarray(RNG.normal(size=(b, s, nh, hd)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(b, s, nh)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2, size=(nh,)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    y_full, s_full = ssd(x, dt, A, Bm, Cm, chunk=chunk)
    h = s // 2
    y1, st = ssd(x[:, :h], dt[:, :h], A, Bm[:, :h], Cm[:, :h], chunk=chunk)
    y2, s_end = ssd(x[:, h:], dt[:, h:], A, Bm[:, h:], Cm[:, h:], chunk=chunk,
                    initial_state=st)
    np.testing.assert_allclose(np.asarray(y_full[:, h:]), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s_end), atol=1e-4)


def test_ssd_decode_step_consistent_with_scan():
    """The recurrent decode step must equal the chunked scan one token at a
    time (the serve path vs the train path)."""
    from repro.models.ssm import ssd_chunked

    b, s, nh, hd, n = 1, 6, 2, 8, 4
    x = jnp.asarray(RNG.normal(size=(b, s, nh, hd)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(b, s, nh)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2, size=(nh,)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    y_scan, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=4)
    state = jnp.zeros((b, nh, hd, n), jnp.float32)
    ys = []
    for t in range(s):
        dA = jnp.exp(dt[:, t] * A[None, :])
        dBx = jnp.einsum("bn,bh,bhp->bhpn", Bm[:, t], dt[:, t], x[:, t])
        state = state * dA[:, :, None, None] + dBx
        ys.append(jnp.einsum("bn,bhpn->bhp", Cm[:, t], state))
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_step), atol=1e-4)
