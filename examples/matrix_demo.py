"""The paper's evaluation grid, end to end: run the scenario matrix
(device profiles × registry models × constraint regimes) and print the
markdown summary table — the Table/Fig. §IV layout.

    PYTHONPATH=src python examples/matrix_demo.py
"""
from repro.experiments import enumerate_cells, markdown_report, run_matrix

cells = enumerate_cells()  # 2 devices × 3 models × decode × 3 regimes
record = run_matrix(cells, iters=10, seeds=(0, 1, 2), quick=True)
print(markdown_report(record))

s = record["summary"]
print(
    f"CORAL reached {s['mean_coral_score']:.0%} of the exhaustive-search "
    f"oracle on average across {s['n_cells']} cells "
    f"(worst single-target cell {s['min_single_target_score']:.0%}), with "
    f"{s['dual_power_violations']} power-budget violations under strict "
    "dual constraints — the paper's 96-100% grid, reproduced as one command."
)
