"""Drift-adaptive CORAL on a non-stationary device twin.

Runs the thermal-ramp dynamic cell end to end: CORAL explores, holds its
best config, a thermal throttle ramps in at t=20, the CUSUM monitor
fires, bounded re-exploration finds the post-shift optimum — while the
static (one-shot) ablation rides its broken config into the ground.

    PYTHONPATH=src python examples/drift_demo.py
"""

from repro.core.baselines import oracle
from repro.core.evaluate import run_drift_regime
from repro.experiments import (
    DRIFT_INTERVALS,
    DRIFT_SHIFT_START,
    DRIFTS,
    MATRIX_DRIFT_CELLS,
    REGIMES,
    cell_simulator,
    drifting_cell_simulator,
    resolve_targets,
)


def main() -> None:
    cell = MATRIX_DRIFT_CELLS[0]  # edge-orin-nx / qwen2.5-3b / thermal-ramp
    regime = REGIMES[cell.regime]
    schedule = DRIFTS[regime.drift]
    sim0 = cell_simulator(cell, noise=0.0)
    targets = resolve_targets(cell, sim0)
    print(f"cell: {cell.device} / {cell.model} / {cell.regime}")
    print(
        f"targets: tau >= {targets.tau_target:.2f}, "
        f"p <= {targets.p_budget:.2f} W; shift at t={DRIFT_SHIFT_START}"
    )

    twin = drifting_cell_simulator(cell, noise=0.0)
    twin.set_time(DRIFT_INTERVALS - 1)
    post = oracle(sim0.space, twin, targets.tau_target, targets.p_budget)
    print(
        f"post-shift oracle: {post.config} -> tau={post.tau:.2f}, "
        f"p={post.power:.2f}"
    )

    for adaptive in (True, False):
        dev = drifting_cell_simulator(cell, seed=0)
        opt, tr = run_drift_regime(
            sim0.space,
            dev,
            targets,
            schedule,
            DRIFT_INTERVALS,
            seed=0,
            adaptive=adaptive,
            sigma=0.02,
        )
        res = opt.result()
        tau, p = twin.exact(res.config)
        feasible = tau >= targets.tau_target and p <= targets.p_budget
        eff_ratio = (tau / p) / post.efficiency
        label = "drift-adaptive" if adaptive else "static (one-shot)"
        print(
            f"{label:>18}: held {res.config} -> tau={tau:.2f} p={p:.2f} "
            f"feasible={feasible} score={eff_ratio if feasible else 0.0:.3f} "
            f"re-explorations={tr.resets}"
        )


if __name__ == "__main__":
    main()
