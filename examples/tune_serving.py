"""CORAL against a *real measured* serving engine.

Boots a reduced model, serves batched requests, measures actual decode
tokens/sec on this host, and lets CORAL tune the pod knobs against the
WalltimeDevice (measured base rate + analytical DVFS/power scaling — this
container has no clock control or power rail; see DESIGN.md §2).

    PYTHONPATH=src python examples/tune_serving.py
"""
import jax

from repro.configs.registry import get_config
from repro.configs.runtime import RunConfig
from repro.core import run_coral, tpu_pod_space
from repro.device.measure import WalltimeDevice
from repro.models.transformer import ApplyCtx, init_model_params
from repro.serving import ServingEngine

cfg = get_config("qwen2.5-3b").reduced()
rcfg = RunConfig(remat="none", moe_impl="dense")
ctx = ApplyCtx(cfg, rcfg, None)
params = init_model_params(jax.random.PRNGKey(0), cfg, rcfg)
engine = ServingEngine(ctx, params, batch_size=4, max_len=96)

space = tpu_pod_space()
device = WalltimeDevice(space, engine, prompt_len=16, steps=8)

tau0, p0 = device.measure(space.preset("default"))
print(f"measured default-config decode rate: {tau0:.1f} tok/s @ {p0/1e3:.2f} kW")

tau_target = tau0 * 0.9
outcome, trace = run_coral(space, device, tau_target, p_budget=p0 * 1.1, iters=10)
print(f"CORAL found: {outcome.config}")
print(f"  {outcome.tau:.1f} tok/s @ {outcome.power/1e3:.2f} kW "
      f"(target ≥{tau_target:.1f}, budget ≤{p0*1.1/1e3:.2f} kW) "
      f"feasible={outcome.feasible(tau_target, p0*1.1)}")
