"""CORAL closed-loop against the *live* continuous-batching runtime.

Boots a reduced model, measures the real τ-vs-concurrency response of this
host (the knob the old sequential scheduler ignored), then runs CORAL
closed-loop against live bursty traffic: apply the proposed config to the
runtime (concurrency for real, DVFS as pacing), serve one control
interval of the trace, observe windowed (τ, p), repeat. Emits the
per-interval trace and BENCH_serving.json.

    PYTHONPATH=src python examples/tune_serving.py
"""
import json

import jax

from repro.configs.registry import get_config
from repro.configs.runtime import RunConfig
from repro.core import tpu_pod_space
from repro.device.measure import analytic_scale_and_power
from repro.models.transformer import ApplyCtx, init_model_params
from repro.serving import (
    ServingController,
    ServingEngine,
    ServingRuntime,
    build_serving_record,
    measure_concurrency_curve,
    workload,
)

cfg = get_config("qwen2.5-3b").reduced()
rcfg = RunConfig(remat="none", moe_impl="dense")
ctx = ApplyCtx(cfg, rcfg, None)
params = init_model_params(jax.random.PRNGKey(0), cfg, rcfg)
engine = ServingEngine(ctx, params, batch_size=2, max_len=64)
space = tpu_pod_space()

# 1) measured τ vs concurrency — identical workload per level, the knob is
#    the only variable (bit-identical across c before this runtime existed)
c_values = [int(v) for v in space.dims[space.index("concurrency")].values]
curve, rounds = measure_concurrency_curve(engine, c_values, rounds=3,
                                          vocab=cfg.vocab)
print("measured decode throughput vs concurrency:")
for c, tau in curve.items():
    print(f"  c={c}: {tau:7.0f} tok/s  ({tau / curve[1]:.2f}x vs c=1)")

# 2) CORAL closed-loop under a bursty Poisson trace at ~60% of capacity
cap = max(curve.values())
new_tokens = 8
iters, interval_s = 10, 0.5
trace = workload.bursty_poisson(
    rate=0.6 * cap / new_tokens, duration_s=iters * interval_s + 2.0,
    prompt_lens=8, new_tokens=new_tokens, vocab=cfg.vocab, seed=1,
)
tau_target = 0.45 * cap
p_budget = analytic_scale_and_power(space.names, space.preset("max_power"))[1] * 0.8
runtime = ServingRuntime(engine, concurrency=1)
controller = ServingController(
    runtime, space, trace, tau_target=tau_target, p_budget=p_budget,
    interval_s=interval_s,
)
outcome, records = controller.run(iters)

print(f"\nclosed loop ({iters} control intervals of {interval_s}s, "
      f"target ≥{tau_target:.0f} tok/s, budget ≤{p_budget / 1e3:.2f} kW):")
for k, r in enumerate(records):
    print(f"  [{k}] c={int(r.config[-1])} f={r.config[2]:.0f}MHz "
          f"τ={r.tau:7.0f} tok/s p={r.power / 1e3:.2f}kW r={r.reward:8.2f} "
          f"queue={r.queue_depth} p99={r.p99_latency_s * 1e3:.0f}ms")
print(f"CORAL found: {outcome.config}")
print(f"  {outcome.tau:.0f} tok/s @ {outcome.power / 1e3:.2f} kW "
      f"feasible={outcome.feasible(tau_target, p_budget)}")

record = build_serving_record(
    "PYTHONPATH=src python examples/tune_serving.py",
    c_values, curve, rounds, batch_size=2, iters=iters,
    interval_s=interval_s, tau_target=tau_target, p_budget=p_budget,
    outcome=outcome, records=records, include_intervals=True,
)
with open("BENCH_serving.json", "w") as f:
    json.dump(record, f, indent=2, sort_keys=True)
    f.write("\n")
print("wrote BENCH_serving.json")
