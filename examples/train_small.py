"""End-to-end driver: train a ~100M-class reduced model for a few hundred
steps on the synthetic LM pipeline and verify the loss drops.

    PYTHONPATH=src python examples/train_small.py [--steps 200]
"""
import argparse

import numpy as np

from repro.launch.train import train

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen2.5-3b")
    args = ap.parse_args()
    _, losses = train(
        args.arch, steps=args.steps, batch=8, seq=64, reduced=True, lr=1e-3,
        ckpt_dir="/tmp/repro_ckpt", ckpt_every=max(args.steps // 2, 1),
        log_every=max(args.steps // 10, 1),
    )
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    print(f"loss: {first:.3f} -> {last:.3f}")
    assert last < first, "training did not reduce the loss"
    print("OK: loss decreased; checkpoint written to /tmp/repro_ckpt")
