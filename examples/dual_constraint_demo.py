"""The paper's §IV-B dual-constraint experiment, end to end: CORAL vs
ORACLE / ALERT / ALERT-Online / presets on the Jetson-like device across
the three detector-scale analogues (YOLO / FRCNN / RETINANET ≈ 1×/6×/12×).

    PYTHONPATH=src python examples/dual_constraint_demo.py
"""
from repro.core import run_coral, jetson_like_space
from repro.core.baselines import alert, alert_online, oracle, preset
from repro.device import jetson_like_simulator

for device_name in ("xavier_nx", "orin_nano"):
    space = jetson_like_space(device_name)
    # heavier models leave less power headroom (paper §IV-C)
    for model, scale, slack in (("yolo", 1.0, 1.08), ("frcnn", 6.0, 1.03),
                                ("retinanet", 12.0, 1.015)):
        mk = lambda s=0, n=0.02: jetson_like_simulator(space, scale, seed=s, noise=n)
        om = oracle(space, mk(n=0.0), tau_target=0.0)
        tau_t = round(om.tau * 0.55)
        p_b = oracle(space, mk(n=0.0), tau_t).power * slack
        print(f"\n=== {device_name} / {model}:  τ ≥ {tau_t} fps,  p ≤ {p_b:.2f} W ===")
        orc = oracle(space, mk(n=0.0), tau_t, p_b)
        print(f"  ORACLE       : {orc.tau:6.1f} fps @ {orc.power:5.2f} W "
              f"({orc.measurements} measurements)")
        out, _ = run_coral(space, mk(0), tau_t, p_b, iters=10)
        print(f"  CORAL        : {out.tau:6.1f} fps @ {out.power:5.2f} W "
              f"feasible={out.feasible(tau_t, p_b)} (10 measurements)")
        al = alert(space, mk(1), tau_t, p_b)
        print(f"  ALERT        : {al.tau:6.1f} fps @ {al.power:5.2f} W "
              f"feasible={al.feasible(tau_t, p_b)}  <- exceeds power budget")
        alo = alert_online(space, mk(2), tau_t, p_b)
        print(f"  ALERT-Online : found={alo.config is not None} "
              f"feasible={alo.feasible(tau_t, p_b)}")
        for kind in ("max_power", "default"):
            pr = preset(space, mk(3), kind)
            print(f"  {kind:13s}: {pr.tau:6.1f} fps @ {pr.power:5.2f} W "
                  f"feasible={pr.feasible(tau_t, p_b)}")
