"""Quickstart: CORAL in 40 lines.

Finds a pod configuration that meets a throughput target within a power
budget — online, in 10 measurements, without offline profiling — and
compares it against exhaustive ORACLE profiling.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import run_coral, tpu_pod_space
from repro.core.baselines import oracle
from repro.device import DeviceSimulator, synthetic_terms

# 1. The tunable knob space (Table-2 analogue for a TPU v5e pod).
space = tpu_pod_space()
print(f"configuration space: {space.size()} combinations of {space.names}")

# 2. The device: an analytical TPU-pod model. In production the roofline
#    terms come from the compiled multi-pod dry-run (repro.launch.tune);
#    here we use a synthetic balanced workload.
terms = synthetic_terms("balanced")
device = DeviceSimulator(space, terms, seed=0)

# 3. Targets: 60% of max throughput within 62% of its power draw.
ground_truth = DeviceSimulator(space, terms, noise=0.0)
best = oracle(space, ground_truth, tau_target=0.0)
tau_target = best.tau * 0.6
p_budget = best.power * 0.62
print(f"target: ≥{tau_target:.0f} items/s at ≤{p_budget/1e3:.1f} kW")

# 4. Run CORAL (10 online measurements).
outcome, trace = run_coral(space, device, tau_target, p_budget, iters=10)
print(f"CORAL:  {outcome.tau:.0f} items/s @ {outcome.power/1e3:.2f} kW "
      f"feasible={outcome.feasible(tau_target, p_budget)} "
      f"({device.n_measurements} measurements)")

# 5. Compare with exhaustive ORACLE profiling.
orc = oracle(space, ground_truth, tau_target, p_budget)
print(f"ORACLE: {orc.tau:.0f} items/s @ {orc.power/1e3:.2f} kW "
      f"({orc.measurements} measurements)")
print(f"CORAL efficiency = {outcome.efficiency/orc.efficiency:.0%} of ORACLE "
      f"at {device.n_measurements/orc.measurements:.2%} of the profiling cost")
