"""Serving launcher: batched generation with the runtime's concurrency
knob (reduced configs on CPU; same code path on a pod).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
      --requests 8 --prompt-len 32 --new-tokens 16 --concurrency 2
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.configs.runtime import RunConfig
from repro.models.transformer import ApplyCtx, init_model_params
from repro.serving import Request, ServingEngine, ServingRuntime


def serve(
    arch: str,
    requests: int = 8,
    prompt_len: int = 32,
    new_tokens: int = 16,
    batch: int = 4,
    concurrency: int = 1,
    seed: int = 0,
):
    cfg = get_config(arch).reduced()
    rcfg = RunConfig(remat="none", moe_impl="dense")
    ctx = ApplyCtx(cfg, rcfg, None)
    params = init_model_params(jax.random.PRNGKey(seed), cfg, rcfg)
    engine = ServingEngine(ctx, params, batch, prompt_len + new_tokens + 1)
    runtime = ServingRuntime(engine, batch_size=batch, concurrency=concurrency)
    rng = np.random.default_rng(seed)
    for rid in range(requests):
        runtime.submit(
            Request(rid, rng.integers(0, cfg.vocab, prompt_len, dtype=np.int32),
                    new_tokens)
        )
    metrics = runtime.drain()
    print(
        f"{arch}: {metrics['requests']} requests, "
        f"{metrics['throughput_tok_s']:.1f} tok/s, "
        f"p50={metrics['p50_latency_s']*1e3:.0f}ms p99={metrics['p99_latency_s']*1e3:.0f}ms"
    )
    return metrics


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--concurrency", type=int, default=1)
    args = ap.parse_args()
    serve(args.arch, args.requests, args.prompt_len, args.new_tokens,
          args.batch, args.concurrency)


if __name__ == "__main__":
    main()
