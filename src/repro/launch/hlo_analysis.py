"""Roofline-term extraction from a compiled dry-run artifact.

  compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
  memory term     = HLO_bytes / (chips × HBM_bw)
  collective term = collective_bytes / (chips × link_bw)

``compiled.cost_analysis()`` reports the per-device program but counts each
``while`` body (lax.scan over layers, blocked-attention KV loops) exactly
ONCE, which understates a 94-layer model by ~94×. We therefore implement a
mini cost model over ``compiled.as_text()``:

  * computations are parsed into instruction tables (name → shape),
  * dot FLOPs = 2 · |result| · Π(contracting dims of lhs),
  * op bytes  = |result| + Σ|operands| at kernel granularity (fusion
    internals excluded — fused intermediates never touch HBM),
  * collective operand bytes are tallied per kind,
  * ``while`` ops multiply their body+condition costs by the trip count
    (largest integer bound in the condition computation), recursively, so
    nested scans (layers × attention KV blocks) compose.

All quantities are per-chip (the compiled module is the per-device SPMD
program). Validated against hand-counted matmul FLOPs and the analytic
6·N·D in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "opaque": 0,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|[\w]+\[[\d,]*\](?:\{[\d,]*\})?)\s+([\w\-]+)"
)
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")


def _shape_info(shape_str: str) -> Tuple[int, List[int]]:
    """'bf16[16,4096]{1,0}' or tuple '(f32[2], s32[])' -> (bytes, dims of
    first array component)."""
    total = 0
    first_dims: Optional[List[int]] = None
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims_s = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in dims_s.split(",") if d]
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        if first_dims is None:
            first_dims = dims
    return total, (first_dims or [])


@dataclasses.dataclass
class Instr:
    name: str
    shape_str: str
    op: str
    line: str

    @property
    def bytes(self) -> int:
        return _shape_info(self.shape_str)[0]

    @property
    def dims(self) -> List[int]:
        return _shape_info(self.shape_str)[1]


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES}
    )

    def __iadd__(self, o: "Costs"):
        self.flops += o.flops
        self.bytes += o.bytes
        for k in self.collectives:
            self.collectives[k] += o.collectives[k]
        return self

    def scaled(self, f: float) -> "Costs":
        return Costs(
            self.flops * f,
            self.bytes * f,
            {k: v * f for k, v in self.collectives.items()},
        )


class HloCostModel:
    # ops whose operands/results we do not charge to HBM traffic
    _FREE = {
        "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
        "after-all", "iota", "partition-id", "replica-id",
    }
    # ops charged for HBM traffic (kernel granularity). Pure elementwise ops
    # (add/multiply/convert/broadcast/...) are excluded: on the TPU backend
    # they fuse into neighbours; the CPU-compiled module we parse fuses far
    # less, and counting them would inflate the memory term ~10×.
    _MEMORY_OPS = {
        "dot", "fusion", "convolution", "copy", "transpose",
        "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
        "reduce", "reduce-window", "sort", "select-and-scatter", "reverse",
        "concatenate", "pad", "slice",
    }

    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[Instr]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._cost_cache: Dict[str, Costs] = {}

    # ------------------------------------------------------------- parsing
    def _parse(self, text: str):
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            s = line.strip()
            m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->.*\{", s)
            if m and not s.startswith("//"):
                cur = m.group(2)
                self.computations[cur] = []
                if m.group(1):
                    self.entry = cur
                continue
            if s == "}":
                # stay robust to nested braces on one-liners
                cur = cur if s != "}" else None
                continue
            if cur is None:
                continue
            im = _INSTR_RE.match(s)
            if im:
                self.computations[cur].append(
                    Instr(im.group(1), im.group(2), im.group(3), s)
                )
        if self.entry is None and self.computations:
            # fall back: last computation is usually main
            self.entry = list(self.computations)[-1]

    # ---------------------------------------------------------- trip count
    def _trip_count(self, cond_name: str) -> int:
        instrs = self.computations.get(cond_name, [])
        best = 1
        for i in instrs:
            if i.op == "constant" and i.shape_str.startswith(("s32[]", "u32[]", "s64[]")):
                cm = re.search(r"constant\((-?\d+)\)", i.line)
                if cm:
                    best = max(best, int(cm.group(1)))
        return best

    # ------------------------------------------------------------- costing
    def _dot_flops(self, instr: Instr, table: Dict[str, Instr]) -> float:
        _, rdims = _shape_info(instr.shape_str)
        out_elems = 1
        for d in rdims:
            out_elems *= d
        # lhs operand = first %name after "dot(" (operands may be printed
        # with their full shapes: "dot(f32[64,64]{1,0} %gte.4, ...)")
        lhs_m = re.search(r"dot\([^%)]*%([\w.\-]+)", instr.line)
        cdims_m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.line)
        k = 1
        if lhs_m and cdims_m and lhs_m.group(1) in table:
            ldims = table[lhs_m.group(1)].dims
            for ci in cdims_m.group(1).split(","):
                if ci and int(ci) < len(ldims):
                    k *= ldims[int(ci)]
        return 2.0 * out_elems * k

    def _op_bytes(self, instr: Instr, table: Dict[str, Instr]) -> float:
        # slicing ops touch only the sliced region, not the whole operand
        if instr.op in ("dynamic-slice", "slice", "gather"):
            return 2.0 * instr.bytes  # read region + write result
        if instr.op in ("dynamic-update-slice", "scatter"):
            # read+write of the updated region (≈ the update operand, which
            # is the smallest operand); buffer itself is aliased in place
            upd = instr.bytes
            paren = instr.line.find("(")
            ops = []
            if paren >= 0:
                for om in _OPERANDS_RE.finditer(instr.line[paren:]):
                    if om.group(1) in table:
                        ops.append(table[om.group(1)].bytes)
            if len(ops) >= 2:
                upd = min(ops[1:]) if len(ops) > 1 else instr.bytes
            return 2.0 * upd
        if instr.op == "fusion":
            called = re.search(r"calls=%?([\w.\-]+)", instr.line)
            if called and self._is_inplace_update(called.group(1), instr):
                # in-place cache-update fusion: the big buffer is aliased;
                # charge only the non-buffer operands (the update slice)
                paren = instr.line.find("(")
                ops = []
                if paren >= 0:
                    for om in _OPERANDS_RE.finditer(instr.line[paren:]):
                        if om.group(1) in table:
                            ops.append(table[om.group(1)].bytes)
                if ops:
                    return 2.0 * (sum(ops) - max(ops))
        total = float(instr.bytes)
        # fusions slicing a loop-invariant buffer (e.g. one layer of the
        # stacked KV cache) would otherwise be charged the full buffer per
        # trip; cap each operand at 4× the result (reductions still count).
        cap = 4.0 * total if instr.op == "fusion" and total > 0 else float("inf")
        paren = instr.line.find("(")
        if paren >= 0:
            # first parenthesized group holds the operands
            depth = 0
            end = paren
            for j, ch in enumerate(instr.line[paren:], start=paren):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = j
                        break
            for om in _OPERANDS_RE.finditer(instr.line[paren : end + 1]):
                op_name = om.group(1)
                if op_name in table:
                    total += min(float(table[op_name].bytes), cap)
        return total

    @staticmethod
    def _dims_of(shape_str: str) -> str:
        m = re.search(r"\[[\d,]*\]", shape_str)
        return m.group(0) if m else ""

    def _is_inplace_update(self, comp_name: str, fusion: Instr) -> bool:
        """True if the fused computation is a dynamic-update-slice into a
        buffer with the fusion's own result dims (aliased in place by XLA —
        no full-buffer HBM round-trip). Dims-only compare: converts inside
        the fusion may change the dtype."""
        want = self._dims_of(fusion.shape_str)
        for i in self.computations.get(comp_name, []):
            if i.op == "dynamic-update-slice" and self._dims_of(i.shape_str) == want:
                return True
        return False

    def _collective(self, instr: Instr) -> Optional[Tuple[str, float]]:
        for k in _COLLECTIVES:
            if instr.op == k or instr.op.startswith(k + "-start"):
                rb = float(instr.bytes)
                gm = re.search(r"replica_groups=\{?\{([\d,]+)\}", instr.line)
                group = len(gm.group(1).split(",")) if gm else 1
                if instr.op.endswith("-start"):
                    rb /= 2.0  # async start result = (operand, result) tuple
                if k == "all-gather":
                    return k, rb / max(group, 1)
                if k == "reduce-scatter":
                    return k, rb * max(group, 1)
                return k, rb
            if instr.op == k + "-done":
                return k, 0.0
        return None

    def cost_of(self, comp_name: str) -> Costs:
        if comp_name in self._cost_cache:
            return self._cost_cache[comp_name]
        total = Costs()
        instrs = self.computations.get(comp_name, [])
        table = {i.name: i for i in instrs}
        for i in instrs:
            if i.op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", i.line)
                cm = re.search(r"condition=%?([\w.\-]+)", i.line)
                if bm and cm:
                    trips = self._trip_count(cm.group(1))
                    body = self.cost_of(bm.group(1))
                    cond = self.cost_of(cm.group(1))
                    inner = Costs()
                    inner += body
                    inner += cond
                    total += inner.scaled(trips)
                continue
            if i.op in ("call", "conditional", "async-start"):
                for cm in re.finditer(
                    r"(?:to_apply|called_computation|branch_computations)=\{?%?([\w.\-]+)",
                    i.line,
                ):
                    total += self.cost_of(cm.group(1))
                continue
            coll = self._collective(i)
            if coll is not None:
                kind, operand_bytes = coll
                total.collectives[kind] += operand_bytes
                total.bytes += operand_bytes
                continue
            if i.op == "dot":
                total.flops += self._dot_flops(i, table)
                total.bytes += self._op_bytes(i, table)
                continue
            if i.op == "convolution":
                # approximate: 2 · |result| · (window elems · in_features)
                _, rdims = _shape_info(i.shape_str)
                out_elems = 1
                for d in rdims:
                    out_elems *= d
                total.flops += 2.0 * out_elems  # conservative lower bound
                total.bytes += self._op_bytes(i, table)
                continue
            if i.op in self._MEMORY_OPS:
                total.bytes += self._op_bytes(i, table)
        self._cost_cache[comp_name] = total
        return total

    def total(self) -> Costs:
        assert self.entry is not None, "no ENTRY computation found"
        return self.cost_of(self.entry)


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    bytes_per_chip: float
    collective_per_chip: Dict[str, float]
    n_chips: int
    peak_flops: float = 197e12
    hbm_bw: float = 819e9
    link_bw: float = 50e9
    xla_flops_per_chip: float = 0.0  # raw cost_analysis (loop bodies once)
    xla_bytes_per_chip: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / self.peak_flops

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / self.hbm_bw

    @property
    def t_collective(self) -> float:
        return sum(self.collective_per_chip.values()) / self.link_bw

    @property
    def dominant(self) -> str:
        t = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(t, key=t.get)

    def as_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "collective_per_chip": dict(self.collective_per_chip),
            "n_chips": self.n_chips,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "dominant": self.dominant,
            "xla_flops_per_chip": self.xla_flops_per_chip,
            "xla_bytes_per_chip": self.xla_bytes_per_chip,
        }


def roofline_from_compiled(compiled, n_chips: int) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    model = HloCostModel(compiled.as_text())
    c = model.total()
    return Roofline(
        flops_per_chip=c.flops,
        bytes_per_chip=c.bytes,
        collective_per_chip=c.collectives,
        n_chips=n_chips,
        xla_flops_per_chip=float(cost.get("flops", 0.0)),
        xla_bytes_per_chip=float(cost.get("bytes accessed", 0.0)),
    )


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Loop-aware collective operand bytes (per chip)."""
    return HloCostModel(hlo_text).total().collectives
