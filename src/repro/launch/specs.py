"""input_specs(): ShapeDtypeStruct stand-ins for every model input of every
(arch × input-shape) pair — weak-type-correct, shardable, no allocation."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.runtime import RunConfig
from repro.configs.shapes import InputShape
from repro.models.transformer import abstract_cache


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _modality_extras(cfg: ModelConfig, b: int) -> Dict[str, jax.ShapeDtypeStruct]:
    out = {}
    if cfg.n_vision_tokens:
        out["vision_embeds"] = sds((b, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.is_encoder_decoder:
        out["enc_feats"] = sds((b, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    return out


def cache_capacity(cfg: ModelConfig, shape: InputShape, rcfg: RunConfig) -> int:
    """Decode cache capacity: full seq_len up to 32k; beyond that the
    sub-quadratic sliding-window variant (DESIGN.md §5)."""
    if shape.seq_len > 32_768:
        return rcfg.long_context_window
    return shape.seq_len


def input_specs(
    cfg: ModelConfig, shape: InputShape, rcfg: RunConfig = RunConfig()
) -> Dict[str, object]:
    """Returns the kwargs pytree for the step function of this shape.

    train   -> {batch: {tokens, labels [, extras]}}
    prefill -> {batch: {tokens [, extras]}}
    decode  -> {cache: <abstract cache>, tokens: (B,1)}
    """
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {
            "tokens": sds((b, s), jnp.int32),
            "labels": sds((b, s), jnp.int32),
        }
        batch.update(_modality_extras(cfg, b))
        return {"batch": batch}
    if shape.kind == "prefill":
        batch = {"tokens": sds((b, s), jnp.int32)}
        batch.update(_modality_extras(cfg, b))
        return {"batch": batch}
    if shape.kind == "decode":
        w = cache_capacity(cfg, shape, rcfg)
        cache = abstract_cache(cfg, b, w)
        return {"cache": cache, "tokens": sds((b, 1), jnp.int32)}
    raise ValueError(shape.kind)
