import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

# ruff: noqa: E402  (the two lines above MUST precede any jax import)
"""Multi-pod dry-run: lower + compile every (arch × input-shape) pair on the
production meshes, print memory/cost analysis, and write the roofline
artifact that §Roofline and the CORAL tuner consume.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --multi-pod
"""
import argparse
import json
import math
import sys
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import REGISTRY, get_config
from repro.configs.runtime import RunConfig
from repro.configs.shapes import SHAPES
from repro.launch.hlo_analysis import roofline_from_compiled
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.models.layers import abstract_params
from repro.models.transformer import ApplyCtx, param_specs
from repro.serving.engine import make_prefill_step, make_serve_step
from repro.sharding.specs import (
    activation_sharding,
    cache_shardings,
    param_shardings,
)
from repro.training import AdamWConfig, make_train_step
from repro.training.adamw import init as adamw_init


def _batch_shardings(mesh, batch_specs, global_batch):
    out = {}
    for k, v in batch_specs.items():
        out[k] = activation_sharding(mesh, global_batch, len(v.shape) - 1)
    return out


def lower_one(arch: str, shape_name: str, mesh, rcfg: RunConfig):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ctx = ApplyCtx(cfg, rcfg, mesh)
    specs = param_specs(cfg)
    params = abstract_params(specs, rcfg.pdtype)
    p_shard = param_shardings(mesh, specs, rcfg.sharding_rules)
    kwargs = input_specs(cfg, shape, rcfg)

    if shape.kind == "train":
        opt_cfg = AdamWConfig()
        step = make_train_step(ctx, opt_cfg)
        opt_state = jax.eval_shape(adamw_init, params)
        opt_shard = {
            "m": p_shard,
            "v": p_shard,
            "step": NamedSharding(mesh, P()),
        }
        b_shard = _batch_shardings(mesh, kwargs["batch"], shape.global_batch)
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, opt_shard, b_shard),
            donate_argnums=(0, 1),
        )
        args = (params, opt_state, kwargs["batch"])
    elif shape.kind == "prefill":
        step = make_prefill_step(ctx)
        b_shard = _batch_shardings(mesh, kwargs["batch"], shape.global_batch)
        jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
        args = (params, kwargs["batch"])
    else:  # decode
        step = make_serve_step(ctx)
        c_shard = cache_shardings(mesh, cfg, kwargs["cache"], shape.global_batch)
        if rcfg.decode_tp_over_data:
            # TP decode: tokens replicated over data; contraction over the
            # data-sharded embed dim reduces activations instead of
            # gathering weights.
            t_shard = NamedSharding(mesh, P(None, None))
        else:
            t_shard = activation_sharding(mesh, shape.global_batch, 1)
        jitted = jax.jit(
            step, in_shardings=(p_shard, c_shard, t_shard), donate_argnums=(1,)
        )
        args = (params, kwargs["cache"], kwargs["tokens"])

    with mesh:
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    return lowered, compiled


def run_pair(arch: str, shape_name: str, multi_pod: bool, rcfg: RunConfig,
             out_dir: str) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = math.prod(mesh.shape.values())
    t0 = time.time()
    lowered, compiled = lower_one(arch, shape_name, mesh, rcfg)
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    roof = roofline_from_compiled(compiled, n_chips)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "compile_seconds": round(compile_s, 1),
        "sharding_rules": rcfg.sharding_rules,
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak_bytes": int(
                getattr(mem, "peak_memory_in_bytes",
                        getattr(mem, "temp_size_in_bytes", 0))
            ),
        },
        "roofline": roof.as_dict(),
    }
    cfg = get_config(arch)
    rec["model_params"] = cfg.n_params()
    rec["model_params_active"] = cfg.n_active_params()
    rec["global_batch"] = SHAPES[shape_name].global_batch
    rec["seq_len"] = SHAPES[shape_name].seq_len
    # useful-compute ratio: 6·N·D (dense) / 6·N_active·D (MoE) vs HLO flops
    shp = SHAPES[shape_name]
    if shp.kind == "train":
        model_flops = 6.0 * cfg.n_active_params() * shp.global_batch * shp.seq_len
    elif shp.kind == "prefill":
        model_flops = 2.0 * cfg.n_active_params() * shp.global_batch * shp.seq_len
    else:
        model_flops = 2.0 * cfg.n_active_params() * shp.global_batch
    rec["model_flops"] = model_flops
    hlo_global = rec["roofline"]["flops_per_chip"] * n_chips
    rec["model_flops_ratio"] = model_flops / hlo_global if hlo_global else 0.0
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fn = os.path.join(out_dir, f"{arch}__{shape_name}__{rec['mesh']}.json")
        with open(fn, "w") as f:
            json.dump(rec, f, indent=2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--rules", default=None, help="sharding rule set override")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--continue-on-error", action="store_true")
    args = ap.parse_args()

    archs = list(REGISTRY) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    rcfg = RunConfig()
    if args.rules:
        import dataclasses

        rcfg = dataclasses.replace(rcfg, sharding_rules=args.rules)

    failures = []
    for arch in archs:
        for shape in shapes:
            tag = f"{arch} × {shape} × {'2x16x16' if args.multi_pod else '16x16'}"
            try:
                rec = run_pair(arch, shape, args.multi_pod, rcfg, args.out)
                r = rec["roofline"]
                print(
                    f"[OK] {tag}: compile={rec['compile_seconds']}s "
                    f"peak={rec['memory']['peak_bytes']/2**30:.2f}GiB/dev "
                    f"t_comp={r['t_compute']*1e3:.2f}ms t_mem={r['t_memory']*1e3:.2f}ms "
                    f"t_coll={r['t_collective']*1e3:.2f}ms dominant={r['dominant']}",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001
                failures.append(tag)
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
                if not args.continue_on_error:
                    traceback.print_exc()
                    sys.exit(1)
    if failures:
        print(f"{len(failures)} failures: {failures}")
        sys.exit(1)
    print("all dry-runs passed")


if __name__ == "__main__":
    main()
