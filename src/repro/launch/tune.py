"""CORAL as a first-class framework feature.

``tune`` wires the paper's optimizer to a real deployment decision: given
an (arch × input-shape × mesh) whose roofline terms came from the compiled
dry-run artifact, find the pod configuration (clock levels, host cores,
concurrency) that meets a throughput target within a power budget.

Usage:
  PYTHONPATH=src python -m repro.launch.tune --arch qwen2.5-3b \
      --shape decode_32k --tau-frac 0.6 --power-frac 0.8
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Optional

from repro.core import run_coral, tpu_pod_space
from repro.core.baselines import alert, alert_online, oracle, preset
from repro.device import DeviceSimulator, RooflineTerms


def terms_from_artifact(
    arch: str, shape: str, mesh: str = "16x16",
    dryrun_dir: str = "experiments/dryrun",
) -> Optional[RooflineTerms]:
    fn = os.path.join(dryrun_dir, f"{arch}__{shape}__{mesh}.json")
    if not os.path.exists(fn):
        return None
    with open(fn) as f:
        rec = json.load(f)
    r = rec["roofline"]
    return RooflineTerms(
        t_compute=r["t_compute"],
        t_memory=r["t_memory"],
        t_collective=r["t_collective"],
        t_host=2.0e-3,
        items_per_step=float(rec.get("global_batch", 1) or 1),
        n_chips=r["n_chips"],
    )


def tune(
    arch: str,
    shape: str,
    tau_frac: float = 0.6,
    power_frac: float = 0.8,
    iters: int = 10,
    seed: int = 0,
    dryrun_dir: str = "experiments/dryrun",
    verbose: bool = True,
):
    space = tpu_pod_space()
    terms = terms_from_artifact(arch, shape, dryrun_dir=dryrun_dir)
    if terms is None:
        raise FileNotFoundError(
            f"no dry-run artifact for {arch}×{shape}; run repro.launch.dryrun first"
        )
    dev_exact = DeviceSimulator(space, terms, noise=0.0)
    orc_max = oracle(space, dev_exact, tau_target=0.0)
    tau_target = orc_max.tau * tau_frac
    # budget relative to the max-power preset (τ-max configs can tie at low
    # power on collective-bound workloads, which would make 0.8× infeasible)
    p_budget = dev_exact.exact(space.preset("max_power"))[1] * power_frac
    orc = oracle(space, dev_exact, tau_target, p_budget)

    out, trace = run_coral(
        space, DeviceSimulator(space, terms, seed=seed), tau_target, p_budget,
        iters=iters, seed=seed,
    )
    result = {
        "arch": arch,
        "shape": shape,
        "tau_target": tau_target,
        "p_budget_kw": p_budget / 1e3,
        "coral": {
            "config": out.config,
            "tau": out.tau,
            "power_kw": out.power / 1e3,
            "feasible": out.feasible(tau_target, p_budget),
            "measurements": iters,
        },
        "oracle": {
            "config": orc.config,
            "tau": orc.tau,
            "power_kw": orc.power / 1e3,
            "measurements": orc.measurements,
        },
    }
    if verbose:
        print(json.dumps(result, indent=2, default=str))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--tau-frac", type=float, default=0.6)
    ap.add_argument("--power-frac", type=float, default=0.8)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--baselines", action="store_true")
    args = ap.parse_args()
    res = tune(args.arch, args.shape, args.tau_frac, args.power_frac,
               args.iters, args.seed)
    if args.baselines:
        space = tpu_pod_space()
        terms = terms_from_artifact(args.arch, args.shape)
        tau_t, p_b = res["tau_target"], res["p_budget_kw"] * 1e3
        for name, fn in (
            ("ALERT", lambda d: alert(space, d, tau_t, p_b)),
            ("ALERT-Online", lambda d: alert_online(space, d, tau_t, p_b)),
            ("max-power", lambda d: preset(space, d, "max_power")),
            ("default", lambda d: preset(space, d, "default")),
        ):
            o = fn(DeviceSimulator(space, terms, seed=args.seed + 1))
            print(
                f"{name:14s} tau={o.tau:10.1f} p={o.power/1e3:7.2f}kW "
                f"feasible={o.feasible(tau_t, p_b)} measurements={o.measurements}"
            )


if __name__ == "__main__":
    main()
