"""Render the §Dry-run and §Roofline markdown tables from the artifacts in
experiments/dryrun/.

  PYTHONPATH=src python -m repro.launch.report [--mesh 16x16] [--out -]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dryrun_dir: str, mesh: str):
    recs = []
    for fn in sorted(glob.glob(os.path.join(dryrun_dir, f"*__{mesh}.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def fmt_bytes(b: float) -> str:
    return f"{b/2**30:.2f}"


def model_flops_ratio(r: dict) -> float:
    """MODEL_FLOPS (6·N_active·D train / 2·N_active·D fwd) over HLO FLOPs."""
    from repro.configs.registry import get_config
    from repro.configs.shapes import SHAPES

    cfg = get_config(r["arch"])
    shp = SHAPES[r["shape"]]
    n = cfg.n_active_params()
    if shp.kind == "train":
        mf = 6.0 * n * shp.global_batch * shp.seq_len
    elif shp.kind == "prefill":
        mf = 2.0 * n * shp.global_batch * shp.seq_len
    else:
        mf = 2.0 * n * shp.global_batch
    hlo = r["roofline"]["flops_per_chip"] * r["n_chips"]
    return mf / hlo if hlo else 0.0


SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def roofline_table(recs) -> str:
    lines = [
        "| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | dominant | "
        "MODEL_FLOPS/HLO | peak GiB/dev |",
        "|------|-------|-----------:|----------:|-----------:|----------|"
        "----------------:|-------------:|",
    ]
    recs = sorted(recs, key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9)))
    for r in recs:
        rf = r["roofline"]
        ratio = r.get("model_flops_ratio") or model_flops_ratio(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['t_compute']:.4f} | "
            f"{rf['t_memory']:.3f} | {rf['t_collective']:.3f} | "
            f"{rf['dominant']} | {ratio:.2f} | "
            f"{fmt_bytes(r['memory']['peak_bytes'])} |"
        )
    return "\n".join(lines)


def dryrun_table(recs) -> str:
    lines = [
        "| arch | shape | mesh | compile s | FLOPs/chip | bytes/chip | "
        "AG | AR | RS | A2A | CP |",
        "|------|-------|------|----------:|-----------:|-----------:|"
        "---:|---:|---:|----:|---:|",
    ]
    recs = sorted(recs, key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9)))
    for r in recs:
        rf = r["roofline"]
        c = rf["collective_per_chip"]
        gb = lambda x: f"{x/2**30:.2f}"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compile_seconds']} | {rf['flops_per_chip']:.2e} | "
            f"{rf['bytes_per_chip']:.2e} | {gb(c['all-gather'])} | "
            f"{gb(c['all-reduce'])} | {gb(c['reduce-scatter'])} | "
            f"{gb(c['all-to-all'])} | {gb(c['collective-permute'])} |"
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--table", default="roofline", choices=("roofline", "dryrun"))
    args = ap.parse_args()
    recs = load(args.dir, args.mesh)
    print(f"{len(recs)} artifacts for mesh {args.mesh}\n")
    if args.table == "roofline":
        print(roofline_table(recs))
    else:
        print(dryrun_table(recs))


if __name__ == "__main__":
    main()
