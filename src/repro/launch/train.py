"""Training launcher: runs real steps on the available devices (reduced
configs on CPU; full configs on a real pod via the same code path).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --reduced \
      --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.configs.runtime import RunConfig
from repro.models.transformer import ApplyCtx, init_model_params
from repro.training import AdamWConfig, SyntheticLM, make_train_step, multimodal_extras
from repro.training import checkpoint as ckpt
from repro.training.adamw import init as adamw_init


def train(
    arch: str,
    steps: int = 20,
    batch: int = 8,
    seq: int = 128,
    reduced: bool = True,
    lr: float = 3e-4,
    seed: int = 0,
    ckpt_dir: str | None = None,
    ckpt_every: int = 0,
    log_every: int = 5,
):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    rcfg = RunConfig(remat="none", moe_impl="dense", param_dtype="float32")
    ctx = ApplyCtx(cfg, rcfg, None)
    params = init_model_params(jax.random.PRNGKey(seed), cfg, rcfg)
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=max(steps // 10, 1), total_steps=steps)
    opt_state = adamw_init(params)
    step_fn = jax.jit(make_train_step(ctx, opt_cfg), donate_argnums=(0, 1))

    data = SyntheticLM(cfg.vocab, seq, batch, seed=seed)
    extras = multimodal_extras(cfg, batch, seed)
    losses = []
    t0 = time.time()
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        b.update({k: jnp.asarray(v) for k, v in extras.items()})
        params, opt_state, metrics = step_fn(params, opt_state, b)
        loss = float(metrics["loss"])
        losses.append(loss)
        if log_every and (i % log_every == 0 or i == steps - 1):
            print(
                f"step {i:4d}  loss={loss:.4f}  xent={float(metrics['xent']):.4f}"
                f"  gnorm={float(metrics['grad_norm']):.3f}"
                f"  lr={float(metrics['lr']):.2e}",
                flush=True,
            )
        if ckpt_dir and ckpt_every and (i + 1) % ckpt_every == 0:
            ckpt.save(ckpt_dir, params, opt_state, step=i + 1, meta={"arch": arch})
    wall = time.time() - t0
    print(f"{steps} steps in {wall:.1f}s  ({steps * batch * seq / wall:.0f} tok/s)")
    if ckpt_dir:
        ckpt.save(ckpt_dir, params, opt_state, step=steps, meta={"arch": arch})
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    args = ap.parse_args()
    _, losses = train(
        args.arch, args.steps, args.batch, args.seq, args.reduced, args.lr,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
    )
    first, last = np.mean(losses[:3]), np.mean(losses[-3:])
    print(f"loss {first:.3f} -> {last:.3f} ({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
