"""Mamba2-2.7B — attention-free state-space model using SSD
(state-space duality). [arXiv:2405.21060]
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    arch_type="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,  # attention-free
    n_kv_heads=0,
    d_ff=0,  # no separate FFN; the Mamba2 block is the whole mixer
    vocab=50_280,
    ssm=SSMConfig(d_state=128, headdim=64, expand=2, chunk_size=256),
    rope_type="none",
    source="arXiv:2405.21060 (Mamba2/SSD): 64L d2560 dstate128 v50280",
)
