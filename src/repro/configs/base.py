"""Model configuration system.

Every assigned architecture is described by a single frozen ``ModelConfig``.
The model zoo (``repro.models``) consumes only this dataclass — adding an
architecture means adding one config file, no model-code changes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN block."""

    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    # index of first MoE layer; earlier layers use a dense FFN of d_ff
    first_moe_layer: int = 0
    router_aux_loss_coef: float = 0.001


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block parameters."""

    d_state: int
    headdim: int = 64
    expand: int = 2
    chunk_size: int = 256
    d_conv: int = 4
    # For hybrid models: the SSM branch can have its own inner width.
    d_inner: Optional[int] = None

    def inner(self, d_model: int) -> int:
        return self.d_inner if self.d_inner is not None else self.expand * d_model

    def n_ssm_heads(self, d_model: int) -> int:
        return self.inner(d_model) // self.headdim


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""

    kv_lora_rank: int
    q_lora_rank: int
    qk_rope_head_dim: int
    qk_nope_head_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Complete architecture description."""

    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default: d_model // n_heads

    # attention details
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rope_type: str = "rope"  # rope | mrope | none (e.g. whisper: learned pos)
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w (qwen2-vl)
    qk_norm: bool = False
    sliding_window: Optional[int] = None  # SWA width for hybrid archs
    global_attn_every: int = 0  # 0 = never (all SWA) unless sliding_window None

    # optional blocks
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    mla: Optional[MLAConfig] = None

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq_len: int = 1500  # whisper: 30s of audio at 50 fps after conv

    # multimodal stub frontends
    n_vision_tokens: int = 0  # vlm: number of patch embeddings per sample

    # norm / activation
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    source: str = ""  # citation for the config numbers

    # ---------------------------------------------------------------- utils
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        p = self.vocab * d  # embedding
        if not self.tie_embeddings:
            p += self.vocab * d  # lm head

        def attn_params() -> int:
            if self.mla is not None:
                m = self.mla
                qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
                a = d * m.q_lora_rank + m.q_lora_rank * n_q * qk_dim
                a += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                a += m.kv_lora_rank * n_q * (m.qk_nope_head_dim + m.v_head_dim)
                a += n_q * m.v_head_dim * d
                return a
            return d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d

        def ssm_params() -> int:
            if self.ssm is None:
                return 0
            di = self.ssm.inner(d)
            nh = self.ssm.n_ssm_heads(d)
            # in_proj (z, x, B, C, dt) + conv + out_proj
            conv_dim = di + 2 * self.ssm.d_state
            return (
                d * (2 * di + 2 * self.ssm.d_state + nh)
                + conv_dim * self.ssm.d_conv
                + di * d
                + 2 * nh  # A_log, D
            )

        def ffn_params(layer: int) -> int:
            if self.moe is not None and layer >= self.moe.first_moe_layer:
                e = self.moe
                per = 3 * d * e.d_ff_expert
                return (
                    e.n_experts * per
                    + e.n_shared_experts * per
                    + d * e.n_experts  # router
                )
            return 3 * d * self.d_ff  # gate/up/down

        for layer in range(self.n_layers):
            if self.arch_type == "ssm":
                p += ssm_params()
            elif self.arch_type == "hybrid":
                p += attn_params() + ssm_params()
            else:
                p += attn_params()
            if self.arch_type != "ssm":
                p += ffn_params(layer)
            p += 2 * d  # norms
        if self.is_encoder_decoder:
            # encoder layers: self-attn + ffn; decoder already counted above,
            # add cross-attention per decoder layer.
            for _ in range(self.n_encoder_layers):
                p += attn_params() + 3 * d * self.d_ff + 2 * d
            p += self.n_layers * attn_params()  # cross attn
        return p

    def n_active_params(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.n_params()
        e = self.moe
        total = self.n_params()
        n_moe_layers = self.n_layers - e.first_moe_layer
        per = 3 * self.d_model * e.d_ff_expert
        inactive = n_moe_layers * (e.n_experts - e.top_k) * per
        return total - inactive

    def flops_per_token(self) -> float:
        """Dense-equivalent FLOPs to produce one token (2·active params —
        the standard matmul-dominated inference estimate)."""
        return 2.0 * self.n_active_params()

    def bytes_per_token(self, dtype_bytes: int = 2) -> float:
        """DRAM traffic per decode step: every active parameter streamed
        once (bf16 by default). Batch amortizes this, not multiplies it —
        the weight stream is shared across the batch."""
        return float(dtype_bytes) * self.n_active_params()

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests (<=2 layers etc.)."""
        kw: dict = dict(
            n_layers=2,
            d_model=min(self.d_model, 128),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            head_dim=32 if self.head_dim is not None or self.mla else None,
            n_encoder_layers=2 if self.is_encoder_decoder else 0,
            encoder_seq_len=16 if self.is_encoder_decoder else self.encoder_seq_len,
            n_vision_tokens=8 if self.n_vision_tokens else 0,
            sliding_window=16 if self.sliding_window else None,
        )
        if self.rope_type == "mrope":
            kw["mrope_sections"] = (4, 6, 6)  # sums to reduced head_dim/2

        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=2, d_ff_expert=64,
                n_shared_experts=min(self.moe.n_shared_experts, 1),
                first_moe_layer=min(self.moe.first_moe_layer, 1),
            )
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, headdim=16, chunk_size=8,
                d_inner=64 if self.ssm.d_inner is not None else None,
            )
        if self.mla is not None:
            kw["mla"] = MLAConfig(
                kv_lora_rank=32, q_lora_rank=48, qk_rope_head_dim=16,
                qk_nope_head_dim=16, v_head_dim=16,
            )
        if self.n_kv_heads == self.n_heads:
            kw["n_kv_heads"] = kw["n_heads"]
        return dataclasses.replace(self, **kw)
