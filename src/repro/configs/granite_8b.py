"""Granite-8B-Code — llama-architecture dense decoder, GQA kv=8.
[arXiv:2405.04324]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    arch_type="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14_336,
    vocab=49_152,
    rope_theta=10_000_000.0,
    source="arXiv:2405.04324 (Granite Code): 36L d4096 32H kv8 ff14336 v49152",
)
