"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.configs import (
    hymba_1_5b,
    granite_8b,
    qwen2_vl_72b,
    mamba2_2_7b,
    moonshot_v1_16b_a3b,
    deepseek_v2_236b,
    internlm2_20b,
    whisper_medium,
    qwen3_moe_235b_a22b,
    qwen2_5_3b,
)

_MODULES = (
    hymba_1_5b,
    granite_8b,
    qwen2_vl_72b,
    mamba2_2_7b,
    moonshot_v1_16b_a3b,
    deepseek_v2_236b,
    internlm2_20b,
    whisper_medium,
    qwen3_moe_235b_a22b,
    qwen2_5_3b,
)

REGISTRY: dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}

ARCH_IDS = tuple(REGISTRY)


def get_config(arch: str) -> ModelConfig:
    if arch not in REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[arch]
