"""Qwen2-VL-72B language backbone — dense GQA decoder with M-RoPE.
Vision encoder (ViT + merger) is a STUB: input_specs() provides
pre-projected patch embeddings (see DESIGN.md §5).
[arXiv:2409.12191]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    arch_type="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29_568,
    vocab=152_064,
    qkv_bias=True,
    rope_type="mrope",
    mrope_sections=(16, 24, 24),  # t/h/w halves of the 128-dim rotary space
    rope_theta=1_000_000.0,
    n_vision_tokens=256,
    source="arXiv:2409.12191 (Qwen2-VL): 80L d8192 64H kv8 ff29568 v152064, M-RoPE",
)
