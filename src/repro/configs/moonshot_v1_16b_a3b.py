"""Moonlight-16B-A3B (moonshot) — DeepSeek-V3-style MoE: 64 routed experts
top-6 + 2 shared experts, dense layer 0, MHA (kv=16).
[hf:moonshotai/Moonlight-16B-A3B]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    arch_type="dense",  # dense attention; MoE FFN (assigned family tag: dense)
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=11_264,  # dense FFN width used for the first (non-MoE) layer
    vocab=163_840,
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        d_ff_expert=1408,
        n_shared_experts=2,
        first_moe_layer=1,
    ),
    rope_theta=50_000.0,
    source="hf:moonshotai/Moonlight-16B-A3B: 48L d2048 16H kv16 64e top-6 ff_e1408 v163840",
)
