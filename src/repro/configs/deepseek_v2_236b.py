"""DeepSeek-V2-236B — MLA attention (kv_lora=512) + MoE with 2 shared and
160 routed experts (top-6); layer 0 has a dense FFN.
[arXiv:2405.04434]
"""
from repro.configs.base import ModelConfig, MLAConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,  # MLA: per-head KV derived from the shared latent
    d_ff=12_288,  # dense FFN width for layer 0
    vocab=102_400,
    head_dim=192,  # qk_nope(128) + qk_rope(64)
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=1536,
        qk_rope_head_dim=64,
        qk_nope_head_dim=128,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_experts=160,
        top_k=6,
        d_ff_expert=1536,
        n_shared_experts=2,
        first_moe_layer=1,
    ),
    rope_theta=10_000.0,
    source="arXiv:2405.04434 (DeepSeek-V2): 60L d5120 128H MLA kv_lora512 160e top-6 v102400",
)
