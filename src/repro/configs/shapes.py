"""Assigned input shapes.

Each shape selects which step function the dry-run lowers:
  train_4k    -> train_step   (tokens+labels, full sequence)
  prefill_32k -> prefill_step (fill a KV cache over the whole prompt)
  decode_32k  -> serve_step   (ONE new token against a seq_len cache)
  long_500k   -> serve_step   (sub-quadratic: SSM state or windowed cache)
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}

# Window used for full-attention archs on long_500k (sub-quadratic variant,
# see DESIGN.md §5). SSM/hybrid archs ignore it for their SSM state.
LONG_CONTEXT_WINDOW = 8_192
