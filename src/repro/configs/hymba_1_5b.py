"""Hymba-1.5B — hybrid-head architecture: parallel attention + Mamba heads
per layer, SWA everywhere except a few global-attention layers.
[arXiv:2411.13676]
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,  # GQA kv=5
    d_ff=5504,
    vocab=32_001,
    head_dim=64,  # 1600 / 25
    sliding_window=1024,
    global_attn_every=16,  # layers 0, 16 (and last) use global attention
    ssm=SSMConfig(d_state=16, headdim=64, expand=2, chunk_size=256),
    rope_theta=10_000.0,
    source="arXiv:2411.13676 (Hymba): 32L d1600 25H kv5 ff5504 v32001 s16",
)
