"""Qwen3-MoE-235B-A22B — 128 routed experts top-8, GQA kv=4, head_dim 128,
q/k-norm. [hf:Qwen/Qwen3-30B-A3B scaled per assignment]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    arch_type="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=12_288,  # (unused: all layers MoE; kept for reduced/dense fallback)
    vocab=151_936,
    head_dim=128,
    qk_norm=True,
    moe=MoEConfig(
        n_experts=128,
        top_k=8,
        d_ff_expert=1536,
        n_shared_experts=0,
        first_moe_layer=0,
    ),
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-235B-A22B: 94L d4096 64H kv4 128e top-8 ff_e1536 v151936",
)
