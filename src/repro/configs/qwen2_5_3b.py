"""Qwen2.5-3B — dense GQA decoder with QKV bias. [hf:Qwen/Qwen2.5-3B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    arch_type="dense",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    d_ff=11_008,
    vocab=151_936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen2.5-3B: 36L d2048 16H kv2 ff11008 v151936, QKV bias",
)
