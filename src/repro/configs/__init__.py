from repro.configs.base import MLAConfig, MoEConfig, ModelConfig, SSMConfig  # noqa: F401
from repro.configs.shapes import (  # noqa: F401
    LONG_CONTEXT_WINDOW,
    SHAPES,
    InputShape,
)


def __getattr__(name):  # lazy: avoid import cycle with registry's arch imports
    if name in ("REGISTRY", "ARCH_IDS", "get_config"):
        from repro.configs import registry

        return getattr(registry, name)
    raise AttributeError(name)
