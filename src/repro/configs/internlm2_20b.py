"""InternLM2-20B — dense GQA decoder. [arXiv:2403.17297]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    arch_type="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16_384,
    vocab=92_544,
    rope_theta=1_000_000.0,
    source="arXiv:2403.17297 (InternLM2): 48L d6144 48H kv8 ff16384 v92544",
)
