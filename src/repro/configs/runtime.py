"""Runtime (non-architecture) configuration: dtypes, remat, block sizes,
sharding rule set, MoE capacity — everything the perf hillclimb tunes."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # attention blocking (XLA online-softmax path; also the Pallas tile hints)
    attn_block_q: int = 1024
    attn_block_k: int = 1024
    # use the blocked path above this many KV positions
    attn_blocked_threshold: int = 2048
    # remat policy for the scanned layer body: none | full | dots
    remat: str = "full"
    # sharding rule set name (see repro.sharding.specs)
    sharding_rules: str = "megatron_fsdp"
    # MoE
    moe_impl: str = "auto"  # dense | expert_parallel | auto
    capacity_factor: float = 1.25
    # decode
    long_context_window: int = 8192
    use_pallas: bool = False  # TPU deployment flag; CPU CI uses XLA path
    # decode-time tensor-parallel mode: replicate the (small) activations
    # over the data axes and let the embed-dim contraction reduce with an
    # activation all-reduce, instead of fsdp-gathering the weights every
    # step (§Perf hillclimb #2).
    decode_tp_over_data: bool = False

    @property
    def pdtype(self):
        return _DTYPES[self.param_dtype]

    @property
    def cdtype(self):
        return _DTYPES[self.compute_dtype]
