"""Whisper-medium — encoder-decoder transformer backbone. The
mel-spectrogram + conv feature extractor is a STUB: input_specs() feeds
(B, 1500, d_model) precomputed frame embeddings (see DESIGN.md §5).
[arXiv:2212.04356]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    arch_type="audio",
    n_layers=24,  # decoder layers
    n_encoder_layers=24,
    is_encoder_decoder=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51_865,
    rope_type="none",  # whisper uses learned/sinusoidal absolute positions
    encoder_seq_len=1500,
    qkv_bias=True,
    source="arXiv:2212.04356 (Whisper medium): 24+24L d1024 16H ff4096 v51865",
)
