"""Flat-file checkpointing (npz + JSON manifest), no external deps."""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, params, opt_state=None, step: int = 0, meta: dict | None = None):
    os.makedirs(path, exist_ok=True)
    np.savez(os.path.join(path, "params.npz"), **_flatten(params))
    if opt_state is not None:
        np.savez(os.path.join(path, "opt_state.npz"), **_flatten(opt_state))
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump({"step": step, "meta": meta or {}}, f, indent=2)


def restore(path: str, params_template, opt_template=None) -> Tuple[Any, Any, int]:
    """Restore into the structure of the given templates."""

    def unflatten(npz, template):
        flat = dict(npz)
        leaves_paths = jax.tree_util.tree_flatten_with_path(template)[0]
        treedef = jax.tree_util.tree_structure(template)
        leaves = []
        for path, leaf in leaves_paths:
            key = "/".join(
                str(getattr(p, "key", getattr(p, "idx", p))) for p in path
            )
            arr = flat[key]
            assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
            leaves.append(arr.astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    params = unflatten(np.load(os.path.join(path, "params.npz")), params_template)
    opt = None
    if opt_template is not None:
        opt = unflatten(np.load(os.path.join(path, "opt_state.npz")), opt_template)
    with open(os.path.join(path, "manifest.json")) as f:
        step = json.load(f)["step"]
    return params, opt, step
