"""Synthetic data pipeline.

A deterministic, shardable token stream: each (step, shard) pair derives
its batch from a counter-based PRNG, so multi-host pipelines produce
disjoint, reproducible data without a filesystem dataset. Structure (a
Zipf-ish unigram mixture + short-range copy structure) gives a non-trivial,
learnable distribution so loss curves actually move in the examples.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    copy_prob: float = 0.35  # P(token t = token t-k) — learnable structure

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        b, s = self.global_batch, self.seq_len
        # Zipf-like unigram distribution over vocab
        base = rng.zipf(1.3, size=(b, s + 1)) % self.vocab
        # overlay copy structure: with prob copy_prob, token = token[t-3]
        copy = rng.random((b, s + 1)) < self.copy_prob
        tok = base.copy()
        tok[:, 3:] = np.where(copy[:, 3:], tok[:, :-3], tok[:, 3:])
        tokens = tok[:, :-1].astype(np.int32)
        labels = tok[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def multimodal_extras(
    cfg, global_batch: int, seed: int = 0
) -> Dict[str, np.ndarray]:
    """Stub modality frontends (DESIGN.md carve-out): precomputed patch /
    frame embeddings with the right shape."""
    rng = np.random.default_rng(seed)
    out = {}
    if cfg.n_vision_tokens:
        out["vision_embeds"] = rng.normal(
            0, 0.02, (global_batch, cfg.n_vision_tokens, cfg.d_model)
        ).astype(np.float32)
    if cfg.is_encoder_decoder:
        out["enc_feats"] = rng.normal(
            0, 0.02, (global_batch, cfg.encoder_seq_len, cfg.d_model)
        ).astype(np.float32)
    return out
