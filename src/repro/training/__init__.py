from repro.training.adamw import AdamWConfig  # noqa: F401
from repro.training.data import SyntheticLM, multimodal_extras  # noqa: F401
from repro.training.train_step import (  # noqa: F401
    cross_entropy,
    loss_fn,
    make_eval_step,
    make_train_step,
)
