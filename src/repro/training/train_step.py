"""Loss + train step (pure functions, jit/pjit-ready)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.transformer import ApplyCtx, forward_train
from repro.training import adamw

AUX_LOSS_COEF = 1e-2


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy in fp32. logits (B,S,V), labels (B,S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def loss_fn(ctx: ApplyCtx, params, batch) -> Tuple[jax.Array, dict]:
    logits, aux = forward_train(ctx, params, batch)
    xent = cross_entropy(logits, batch["labels"])
    loss = xent + AUX_LOSS_COEF * aux
    return loss, {"loss": loss, "xent": xent, "moe_aux": aux}


def make_train_step(ctx: ApplyCtx, opt_cfg: adamw.AdamWConfig):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(ctx, p, batch), has_aux=True
        )(params)
        new_params, new_opt, opt_metrics = adamw.update(
            opt_cfg, grads, opt_state, params
        )
        metrics = dict(metrics, **opt_metrics)
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(ctx: ApplyCtx):
    def eval_step(params, batch):
        _, metrics = loss_fn(ctx, params, batch)
        return metrics

    return eval_step
