"""AdamW with decoupled weight decay + cosine schedule (hand-rolled, no
optax dependency). Optimizer state mirrors the param pytree so it inherits
the same shardings."""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def init(params) -> dict:
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def update(
    cfg: AdamWConfig, grads, state: dict, params
) -> Tuple[Any, dict, dict]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = schedule(cfg, step)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / (1 - cfg.b1**step)
        vhat = v / (1 - cfg.b2**step)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
