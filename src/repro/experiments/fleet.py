"""Fleet-scale CORAL: 1000+ heterogeneous device twins in one compiled call.

The scenario matrix tunes *registry* devices — one twin per profile. A
deployed fleet is that profile times manufacturing spread: every unit
has its own silicon lottery, enclosure temperature and firmware ladder,
so (PolyThrottle's observation) every unit needs its own search. This
module turns ``device.hw.sample_perturbations`` into per-twin
landscapes/targets, runs the whole fleet through the episode engine's
fleet path (``run_fleet_requests`` — one ``jit(vmap(scan))``), and then
re-runs a cohort *warm-started* from converged neighbors to price what
fleet memory is worth: measurements-to-feasible, cold vs warm.

Warm-start policy (EXPERIMENTS.md §Fleet):
  - a cohort twin's source is its nearest converged neighbor in
    perturbation space, same family (same ``ConfigSpace``), preferring
    the same firmware ladder variant;
  - the source contributes its last-W observation window (the dCor
    context), its prohibited set minus its own firmware bans, its
    best/second/last anchors re-scored under the *target's* constraints,
    and its observed cheapest/fastest rows as pmin/pmax probe anchors;
  - the warm re-run uses the twin's own noise stream, so cold vs warm is
    a paired comparison on identical measurement draws.

Everything is deterministic in the fleet seed: twin i's perturbation
and noise stream depend only on (seed, i), never on fleet size — the
64-twin CI smoke fleet is a prefix of the 1024-twin nightly fleet.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.registry import get_config
from repro.core.contracts import check_twin, contracts_enabled
from repro.core.episode import _f64_reward, run_fleet_requests
from repro.core.evaluate import RegimeTargets
from repro.core.space import ConfigSpace, space_grid
from repro.device.hw import (
    FLEET_FAMILIES,
    DriftSchedule,
    FleetPerturbation,
    perturbed_profile,
    sample_perturbations,
)
from repro.device.simulator import DriftingSimulator, build_cell_simulator
from repro.experiments.scenarios import WORKLOADS

# One fleet regime: the τ floor is a fraction of each twin's own max
# throughput and the budget is slack over each twin's own cheapest
# τ-feasible draw — the strictest satisfiable shape in the matrix
# (the "pmin" anchor), resolved per twin so heterogeneous silicon gets
# heterogeneous absolute targets.
FLEET_MODEL = "qwen2.5-3b"
FLEET_WORKLOAD = "decode_steady"
FLEET_TAU_FRAC = 0.55
FLEET_P_SLACK = 1.30
FLEET_ITERS = 30
FLEET_WINDOW = 12
FLEET_WARM_EVERY = 4  # every 4th twin re-runs warm-started

_ACCEL_DIMS = ("gpu_freq", "tpu_freq")
_MEM_DIMS = ("mem_freq", "hbm_freq")


def ladder_banned_rows(space: ConfigSpace, variant: int) -> np.ndarray:
    """Firmware DVFS-ladder variant as a mask of locked-out grid rows.

    Variant 0 is stock firmware. Variant 1 caps the accelerator ladder
    below its top step (conservative thermals); variant 2 caps the
    memory ladder. Expressing variants as *bans* keeps every unit on its
    family's ``ConfigSpace`` — the compiled constants (escape key
    tables, ladders) are shared fleet-wide, and the engine's prohibited
    mechanism enforces the lockout from the first proposal.
    """
    banned = np.zeros(space.size(), bool)
    if variant == 0:
        return banned
    names = space.names
    cands = _ACCEL_DIMS if variant == 1 else _MEM_DIMS
    dim = next(i for i, nm in enumerate(names) if nm in cands)
    grid = space_grid(space)
    top = max(space.dims[dim].values)
    return grid[:, dim] == top


@dataclasses.dataclass
class FleetTwin:
    """One unit: its perturbation, resolved hardware, ground truth and
    per-twin absolute targets (over its *allowed* rows only). Contract
    (core/contracts.py::TWIN_CONTRACT, checked under REPRO_CONTRACTS=1):
    ``banned: Bool[Array, "N0"]``, ``land_tau / land_p: Float64[Array,
    "N0"]`` with N0 = space.size()."""

    pert: FleetPerturbation
    space: ConfigSpace
    banned: np.ndarray  # (N0,) bool — firmware-locked rows
    land_tau: np.ndarray  # (N0,) float64 noise-free landscape
    land_p: np.ndarray
    targets: RegimeTargets
    noise: float
    noise_seed: int

    @property
    def twin_id(self) -> int:
        return self.pert.twin_id


def build_twin(
    pert: FleetPerturbation,
    model: str = FLEET_MODEL,
    workload: str = FLEET_WORKLOAD,
    tau_frac: float = FLEET_TAU_FRAC,
    p_slack: float = FLEET_P_SLACK,
) -> FleetTwin:
    """Resolve one perturbation into landscapes + targets. The ambient
    derate is applied as a stationary one-event drift schedule, so the
    landscape math is exactly the drift simulator's."""
    profile = perturbed_profile(pert)
    w = WORKLOADS[workload]
    sim0 = build_cell_simulator(
        profile,
        get_config(model),
        kind=w.kind,
        batch=w.batch,
        seq=w.seq,
        noise=0.0,
        seed=0,
    )
    twin_sim = DriftingSimulator(sim0, DriftSchedule((pert.ambient(),)))
    land_tau, land_p = twin_sim.exact_all()
    space = profile.space()
    banned = ladder_banned_rows(space, pert.ladder_variant)
    allowed = ~banned
    tau_target = round(tau_frac * float(land_tau[allowed].max()), 3)
    feas = allowed & (land_tau >= tau_target)
    p_budget = float(land_p[feas].min()) * p_slack
    noise_seed = int(np.random.SeedSequence((pert.twin_id, 7, 0)).generate_state(1)[0])
    twin = FleetTwin(
        pert=pert,
        space=space,
        banned=banned,
        land_tau=land_tau,
        land_p=land_p,
        targets=RegimeTargets(mode="dual", tau_target=tau_target, p_budget=p_budget),
        noise=w.noise,
        noise_seed=noise_seed,
    )
    # REPRO_CONTRACTS=1: the ground-truth arrays must match the twin's
    # own grid (contracts.TWIN_CONTRACT — Float64 on purpose here)
    if contracts_enabled():
        check_twin(twin)
    return twin


def build_fleet(
    n: int,
    seed: int,
    families: Sequence[str] = FLEET_FAMILIES,
    model: str = FLEET_MODEL,
    workload: str = FLEET_WORKLOAD,
) -> List[FleetTwin]:
    """Sample + resolve ``n`` twins (threaded: landscape sweeps are
    numpy and release the GIL)."""
    perts = sample_perturbations(n, seed, families)
    workers = min(n, os.cpu_count() or 1)
    if workers > 1:
        with concurrent.futures.ThreadPoolExecutor(workers) as pool:
            return list(pool.map(lambda p: build_twin(p, model, workload), perts))
    return [build_twin(p, model, workload) for p in perts]


def _request(twin: FleetTwin, warm: Optional[dict] = None) -> dict:
    req = dict(
        space=twin.space,
        land_tau=twin.land_tau,
        land_p=twin.land_p,
        targets=twin.targets,
        seed=twin.noise_seed,
        noise=twin.noise,
        banned=twin.banned,
    )
    if warm is not None:
        req["warm"] = warm
    return req


def measurements_to_feasible(twin: FleetTwin, idxs: np.ndarray) -> Optional[int]:
    """1-based index of the first *truly* feasible measurement (noise-
    free landscape values at the chosen rows), None if the episode never
    lands one — the honest fleet-convergence statistic (the matrix's
    noisy-trace variant would credit lucky noise draws)."""
    t = twin.land_tau[idxs]
    p = twin.land_p[idxs]
    feas = (t >= twin.targets.tau_target) & (p <= twin.targets.p_budget)
    if not feas.any():
        return None
    return int(np.argmax(feas)) + 1


def twin_score(twin: FleetTwin, idxs: np.ndarray) -> Optional[float]:
    """Best truly-feasible measured efficiency (τ/p), normalized by the
    twin's exhaustive-search optimum over its allowed rows. None if no
    feasible row was measured."""
    t = twin.land_tau[idxs]
    p = twin.land_p[idxs]
    feas = (t >= twin.targets.tau_target) & (p <= twin.targets.p_budget)
    if not feas.any():
        return None
    allowed = ~twin.banned
    oracle_feas = (
        allowed
        & (twin.land_tau >= twin.targets.tau_target)
        & (twin.land_p <= twin.targets.p_budget)
    )
    best = float((t[feas] / p[feas]).max())
    opt = float((twin.land_tau[oracle_feas] / twin.land_p[oracle_feas]).max())
    return best / opt


def _pert_vec(p: FleetPerturbation) -> np.ndarray:
    return np.asarray(
        [
            p.compute_scale,
            p.mem_scale,
            p.host_scale,
            p.power_scale,
            p.ambient_derate,
        ]
    )


def match_neighbor(
    twin: FleetTwin,
    sources: List[Tuple[FleetTwin, dict]],
) -> Optional[Tuple[FleetTwin, dict]]:
    """Nearest converged source in perturbation space: same family
    (hence identical ``ConfigSpace``), preferring the same firmware
    ladder variant; falls back to any variant of the family."""
    fam = [
        (s, r)
        for s, r in sources
        if s.pert.family == twin.pert.family and s.twin_id != twin.twin_id
    ]
    same_ladder = [
        (s, r)
        for s, r in fam
        if s.pert.ladder_variant == twin.pert.ladder_variant
    ]
    pool = same_ladder or fam
    if not pool:
        return None
    me = _pert_vec(twin.pert)
    dists = [float(np.linalg.norm(_pert_vec(s.pert) - me)) for s, _ in pool]
    return pool[int(np.argmin(dists))]


def warm_context(source: FleetTwin, src_res: dict, twin: FleetTwin) -> dict:
    """The warm-start payload a converged source hands a new twin.

    Window rows transfer verbatim (the dCor patterns are what carry);
    anchors are re-scored under the *target's* constraints so the
    engine's best/second ordering is consistent with the rewards it will
    compute; the source's own firmware bans are stripped from the
    transferred prohibited set (they are policy, not physics, and the
    target's bans are re-imposed independently)."""
    w = src_res["window"]
    k = min(src_res["n_obs"], w.shape[0])
    rows = w[:k]
    d = len(twin.space.dims)
    taus, ps = rows[:, d].astype(np.float64), rows[:, d + 1].astype(np.float64)
    idxr = rows[:, d + 3].astype(np.int64)
    r = _f64_reward(
        twin.targets.mode,
        taus,
        ps,
        twin.targets.tau_target,
        twin.targets.p_budget,
    )
    order = np.argsort(-r, kind="stable")
    best = int(order[0])
    anchors = dict(
        best_idx=int(idxr[best]),
        best_tau=float(taus[best]),
        best_p=float(ps[best]),
        best_r=float(r[best]),
        best_valid=True,
    )
    if k > 1:
        sec = int(order[1])
        anchors.update(
            sec_idx=int(idxr[sec]),
            sec_tau=float(taus[sec]),
            sec_p=float(ps[sec]),
            sec_r=float(r[sec]),
            sec_valid=True,
        )
    anchors.update(
        last_idx=int(idxr[-1]),
        last_tau=float(taus[-1]),
        last_p=float(ps[-1]),
        last_valid=True,
    )
    return dict(
        hist=rows,
        prohibit=src_res["prohibited"] & ~source.banned,
        min_idx=int(idxr[int(np.argmin(ps))]),
        max_idx=int(idxr[int(np.argmax(taus))]),
        **anchors,
    )


def _curve(m2fs: List[Optional[int]], iters: int) -> List[float]:
    """Fraction of twins feasible within m measurements, m = 1..iters."""
    n = max(len(m2fs), 1)
    got = np.zeros(iters, np.int64)
    for m in m2fs:
        if m is not None:
            got[m - 1 :] += 1
    return [round(float(v) / n, 6) for v in got]


def _mean(vals: List[float]) -> Optional[float]:
    return round(float(np.mean(vals)), 6) if vals else None


def run_fleet(
    n_twins: int = 1024,
    seed: int = 0,
    iters: int = FLEET_ITERS,
    window: int = FLEET_WINDOW,
    warm_every: int = FLEET_WARM_EVERY,
    families: Sequence[str] = FLEET_FAMILIES,
    model: str = FLEET_MODEL,
    workload: str = FLEET_WORKLOAD,
    probe_steady: bool = False,
) -> dict:
    """The fleet experiment: one compiled call tunes every twin cold,
    then every ``warm_every``-th twin re-runs warm-started from its
    nearest converged non-cohort neighbor. Returns the BENCH_fleet
    payload: a deterministic ``results`` block (same seed ⇒ byte-
    identical) plus an ``engine`` block of wall-clock / bytes accounting
    (machine-dependent, excluded from the determinism contract).

    ``probe_steady`` re-runs the cold wave once more to time the
    compiled call without compilation (twins/sec)."""
    t0 = time.perf_counter()
    twins = build_fleet(n_twins, seed, families, model, workload)
    prep_s = time.perf_counter() - t0

    stats: dict = {}
    t0 = time.perf_counter()
    cold = run_fleet_requests([_request(tw) for tw in twins], iters, window, stats)
    cold_s = time.perf_counter() - t0

    steady_s = None
    if probe_steady:
        t0 = time.perf_counter()
        run_fleet_requests([_request(tw) for tw in twins], iters, window)
        steady_s = time.perf_counter() - t0

    m2f_cold = [measurements_to_feasible(tw, r["idx"]) for tw, r in zip(twins, cold)]
    scores = [twin_score(tw, r["idx"]) for tw, r in zip(twins, cold)]

    # ---- warm cohort: every warm_every-th twin, sources = the rest ----
    cohort = [i for i in range(n_twins) if i % warm_every == 0]
    sources = [
        (twins[i], cold[i])
        for i in range(n_twins)
        if i % warm_every != 0 and m2f_cold[i] is not None
    ]
    warm_reqs, warm_ids = [], []
    for i in cohort:
        match = match_neighbor(twins[i], sources)
        if match is None:
            continue
        src, src_res = match
        warm_reqs.append(_request(twins[i], warm=warm_context(src, src_res, twins[i])))
        warm_ids.append(i)
    t0 = time.perf_counter()
    warm = run_fleet_requests(warm_reqs, iters, window) if warm_reqs else []
    warm_s = time.perf_counter() - t0
    m2f_warm = {
        i: measurements_to_feasible(twins[i], r["idx"])
        for i, r in zip(warm_ids, warm)
    }

    # paired cohort comparison: same twin, same noise stream
    paired = [
        (m2f_cold[i], m2f_warm[i])
        for i in warm_ids
        if m2f_cold[i] is not None and m2f_warm[i] is not None
    ]
    mean_cold_cohort = _mean([float(c) for c, _ in paired])
    mean_warm_cohort = _mean([float(w) for _, w in paired])
    warm_gain = (
        round(mean_cold_cohort / mean_warm_cohort, 6)
        if paired and mean_warm_cohort
        else None
    )

    per_family: Dict[str, dict] = {}
    convergence: Dict[str, dict] = {}
    for fam in families:
        ids = [i for i in range(n_twins) if twins[i].pert.family == fam]
        fam_m2f = [m2f_cold[i] for i in ids]
        fam_warm = [m2f_warm[i] for i in warm_ids if twins[i].pert.family == fam]
        per_family[fam] = {
            "n_twins": len(ids),
            "feasible_rate": round(
                sum(m is not None for m in fam_m2f) / max(len(ids), 1), 6
            ),
            "mean_m2f": _mean([float(m) for m in fam_m2f if m is not None]),
            "mean_score": _mean([scores[i] for i in ids if scores[i] is not None]),
        }
        convergence[fam] = {
            "cold": _curve(fam_m2f, iters),
            "warm": _curve(fam_warm, iters),
        }
    convergence["all"] = {
        "cold": _curve(m2f_cold, iters),
        "warm": _curve(list(m2f_warm.values()), iters),
    }

    results = {
        "n_twins": n_twins,
        "seed": seed,
        "iters": iters,
        "window": window,
        "families": list(families),
        "model": model,
        "workload": workload,
        "feasible_rate": round(sum(m is not None for m in m2f_cold) / n_twins, 6),
        "mean_m2f_cold": _mean([float(m) for m in m2f_cold if m is not None]),
        "mean_score": _mean([s for s in scores if s is not None]),
        "warm_cohort": len(cohort),
        "warm_matched": len(warm_ids),
        "mean_m2f_cold_cohort": mean_cold_cohort,
        "mean_m2f_warm_cohort": mean_warm_cohort,
        "warm_gain": warm_gain,
        "per_family": per_family,
        "convergence": convergence,
    }

    import jax

    dev = jax.local_devices()[0]
    mem = dev.memory_stats() if hasattr(dev, "memory_stats") else None
    engine = {
        "backend": jax.default_backend(),
        "prep_s": round(prep_s, 3),
        "cold_wall_s": round(cold_s, 3),
        "warm_wall_s": round(warm_s, 3),
        "steady_wall_s": round(steady_s, 3) if steady_s is not None else None,
        "twins_per_s": round(n_twins / steady_s, 2) if steady_s else None,
        "table_bytes": stats.get("table_bytes"),
        "batch_bytes": stats.get("batch_bytes"),
        "consts_bytes": stats.get("consts_bytes"),
        "peak_device_bytes": (
            int(mem["peak_bytes_in_use"])
            if mem and "peak_bytes_in_use" in mem
            else None
        ),
    }
    return {"results": results, "engine": engine}
