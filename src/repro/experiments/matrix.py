"""Run the scenario matrix: CORAL + all baselines through every cell.

Each cell is scored on three axes (the paper's Table/Fig. §IV summary):

  normalized score — performance of the chosen config, noise-free, as a
      fraction of the cell's exhaustive-search ORACLE under the regime's
      own objective (max_throughput: τ ratio; τ-targeted regimes:
      efficiency τ/p ratio among what the oracle ranks);
  violation rate  — fraction of runs whose chosen config truly breaks a
      constraint (evaluated on the noise-free twin, so a lucky noise
      sample can't hide a real power-budget bust);
  exploration cost — measurements until the first feasible observation
      (ORACLE pays the full grid; CORAL its iteration budget).

All optimizer selections run against the *noisy* device (the 1-second
tegrastats-style samples CORAL actually sees); all scoring runs against
the noise-free twin.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.baselines import Outcome, alert, alert_online, oracle, preset
from repro.core.evaluate import (
    RegimeTargets,
    measurements_to_feasible,
    run_drift_regime,
    run_regime,
)
from repro.experiments.scenarios import (
    DRIFT_INTERVALS,
    DRIFT_SHIFT_START,
    DRIFTS,
    REGIMES,
    WORKLOADS,
    Cell,
    cell_simulator,
    drifting_cell_simulator,
    enumerate_cells,
    resolve_targets,
)

# Per-baseline device seeds: every baseline sees its own noise stream,
# deterministically, so matrix records are reproducible bit-for-bit.
_BASELINE_SEEDS = {"alert": 101, "alert_online": 102, "max_power": 103, "default": 104}

# Regression-gate margin: the recorded floor sits this far under the
# worst seed, absorbing cross-platform float jitter without letting a
# real regression through.
SCORE_FLOOR_MARGIN = 0.05


def _score(tau: float, power: float, regime_name: str, oracle_ref: Outcome) -> float:
    """Normalized-vs-oracle performance under the regime's objective."""
    if oracle_ref.config is None:
        return 0.0
    if REGIMES[regime_name].mode == "throughput":
        return tau / max(oracle_ref.tau, 1e-9)
    eff = tau / max(power, 1e-9)
    return eff / max(oracle_ref.efficiency, 1e-9)


def _violations(
    tau: float, power: float, targets: RegimeTargets
) -> Tuple[bool, bool]:
    """(τ-target miss, power-budget bust) of a chosen config, noise-free."""
    tau_miss = targets.mode != "throughput" and tau < targets.tau_target * (1 - 1e-9)
    power_bust = targets.capped and power > targets.p_budget * (1 + 1e-9)
    return tau_miss, power_bust


def run_cell(
    cell: Cell,
    iters: int = 10,
    seeds: Sequence[int] = (0, 1, 2),
    window: int = 10,
) -> dict:
    """One cell → one JSON-ready record (see schema.MATRIX_SCHEMA)."""
    sim0 = cell_simulator(cell, noise=0.0)
    space = sim0.space
    targets = resolve_targets(cell, sim0)
    oracle_ref = oracle(space, sim0, targets.tau_target, targets.p_budget)

    # ---- CORAL, one run per seed against the noisy device -------------
    scores: List[float] = []
    tau_misses: List[bool] = []
    power_busts: List[bool] = []
    m2f: List[Optional[int]] = []
    best: Optional[Tuple[float, float, float, tuple]] = None
    for seed in seeds:
        dev = cell_simulator(cell, seed=seed)
        out, tr = run_regime(space, dev, targets, iters=iters, window=window, seed=seed)
        if out.config is None:
            # found nothing: a feasibility failure (τ miss), not a power
            # bust — no config ever drew power over the cap. Same mapping
            # as _outcome_record below for config-less baselines.
            scores.append(0.0)
            tau_misses.append(True)
            power_busts.append(False)
            m2f.append(None)
            continue
        tau, power = sim0.exact(out.config)
        miss, bust = _violations(tau, power, targets)
        # A pick that truly breaks the regime's constraints earns no
        # credit — an infeasible low-clock config can beat the feasible
        # optimum on raw τ/p, and crediting it would let feasibility
        # regressions read as score improvements.
        s = 0.0 if (miss or bust) else _score(tau, power, cell.regime, oracle_ref)
        scores.append(s)
        tau_misses.append(miss)
        power_busts.append(bust)
        m2f.append(measurements_to_feasible(tr, targets))
        if not (miss or bust) and (best is None or s > best[0]):
            best = (s, tau, power, tuple(out.config))
    n = len(seeds)
    reached = [v for v in m2f if v is not None]
    coral = {
        "score": sum(scores) / n,
        "score_min": min(scores),
        "score_floor": round(max(0.0, min(scores) - SCORE_FLOOR_MARGIN), 4),
        "violation_rate": sum(a or b for a, b in zip(tau_misses, power_busts)) / n,
        "power_violations": int(sum(power_busts)),
        "found_feasible_rate": len(reached) / n,
        "measurements_to_feasible": (
            sum(reached) / len(reached) if reached else None
        ),
        "measurements": iters,
        "tau": best[1] if best else 0.0,
        "power": best[2] if best else 0.0,
        "config": list(best[3]) if best else None,
    }

    # ---- baselines, one run each --------------------------------------
    def _outcome_record(out: Outcome) -> dict:
        if out.config is None:
            return {
                "score": None,
                "tau": 0.0,
                "power": 0.0,
                "violates_tau": True,
                "violates_power": False,
                "measurements": out.measurements,
            }
        tau, power = sim0.exact(out.config)
        miss, bust = _violations(tau, power, targets)
        # Baselines keep their raw normalized score next to the violation
        # flags — the paper's presentation (ALERT achieves high τ *while*
        # busting the cap) needs both visible. Only CORAL's scores feed
        # the gates, and those zero out on violation above.
        return {
            "score": _score(tau, power, cell.regime, oracle_ref),
            "tau": tau,
            "power": power,
            "violates_tau": bool(miss),
            "violates_power": bool(bust),
            "measurements": out.measurements,
        }

    # ALERT prioritizes throughput (its published objective) — in capped
    # regimes the budget is handed over but, faithfully, soft.
    baselines = {
        "alert": _outcome_record(
            alert(
                space,
                cell_simulator(cell, seed=_BASELINE_SEEDS["alert"]),
                targets.tau_target,
                targets.p_budget,
            )
        ),
        "alert_online": _outcome_record(
            alert_online(
                space,
                cell_simulator(cell, seed=_BASELINE_SEEDS["alert_online"]),
                targets.tau_target,
                targets.p_budget,
                iters=iters,
                seed=_BASELINE_SEEDS["alert_online"],
            )
        ),
        "max_power": _outcome_record(
            preset(
                space,
                cell_simulator(cell, seed=_BASELINE_SEEDS["max_power"]),
                "max_power",
            )
        ),
        "default": _outcome_record(
            preset(
                space,
                cell_simulator(cell, seed=_BASELINE_SEEDS["default"]),
                "default",
            )
        ),
    }

    return {
        "device": cell.device,
        "model": cell.model,
        "workload": cell.workload,
        "regime": cell.regime,
        "mode": targets.mode,
        "tau_target": targets.tau_target,
        "p_budget": targets.p_budget if targets.capped else None,
        "space_size": space.size(),
        "oracle": {
            "config": list(oracle_ref.config) if oracle_ref.config else None,
            "tau": oracle_ref.tau,
            "power": oracle_ref.power,
            "measurements": oracle_ref.measurements,
        },
        "coral": coral,
        "baselines": baselines,
    }


# Drift-cell acceptance levels (gated in benchmarks/matrix_bench.py):
# drift-adaptive CORAL must average ≥ this fraction of the post-shift
# oracle, the static ablation must average ≤ the ceiling, and the gap
# between them must demonstrate that re-exploration — not luck — closed it.
DRIFT_ADAPTIVE_GATE = 0.85
DRIFT_STATIC_CEILING = 0.5
DRIFT_SEPARATION = 0.3


def run_drift_cell(
    cell: Cell,
    seeds: Sequence[int] = (0, 1, 2),
    window: int = 10,
    explore_budget: int = 10,
    intervals: int = DRIFT_INTERVALS,
    shift_start: int = DRIFT_SHIFT_START,
) -> dict:
    """One dynamic (non-stationary) cell → one JSON-ready record.

    Runs drift-adaptive CORAL and the static (no-re-exploration) ablation
    through the same drifting twin and scores both against the
    *post-shift* oracle: the exhaustive search on the fully-shifted
    noise-free landscape under the post-shift budget. Metrics:

      final_score      — the optimizer's end-of-run choice, normalized
                         vs. the post-shift oracle (violating → 0);
      recovery_intervals — intervals from the shift until the loop holds
                         a ≥0.85-scoring config for the rest of the run
                         (None if it never settles that high);
      transient_violation_rate — fraction of post-shift intervals whose
                         *applied* config truly violated the constraints
                         in force at that interval (exploration probes
                         included: re-exploration's price is visible);
      resets           — exploration epochs spent after the shift.
    """
    regime = REGIMES[cell.regime]
    schedule = DRIFTS[regime.drift]
    sim0 = cell_simulator(cell, noise=0.0)
    space = sim0.space
    targets = resolve_targets(cell, sim0)
    sigma = WORKLOADS[cell.workload].noise

    from repro.device.simulator import DriftingSimulator

    twin = DriftingSimulator(sim0, schedule)
    twin.set_time(intervals - 1)
    p_budget_post = targets.p_budget * twin.state.budget_scale
    post_oracle = oracle(space, twin, targets.tau_target, p_budget_post)

    def final_state_score(cfg) -> float:
        """Normalized-vs-post-oracle score at the fully-shifted state."""
        if cfg is None or post_oracle.config is None:
            return 0.0
        twin.set_time(intervals - 1)
        tau, p = twin.exact(cfg)
        if (
            tau < targets.tau_target * (1 - 1e-9)
            or p > p_budget_post * (1 + 1e-9)
        ):
            return 0.0
        if targets.mode == "throughput":
            return tau / max(post_oracle.tau, 1e-9)
        return (tau / max(p, 1e-9)) / max(post_oracle.efficiency, 1e-9)

    def variant(adaptive: bool) -> dict:
        finals: List[float] = []
        recoveries: List[Optional[int]] = []
        transients: List[float] = []
        resets: List[int] = []
        for seed in seeds:
            dev = drifting_cell_simulator(cell, seed=seed)
            opt, tr = run_drift_regime(
                space,
                dev,
                targets,
                schedule,
                intervals,
                explore_budget=explore_budget,
                window=window,
                seed=seed,
                adaptive=adaptive,
                sigma=sigma,
            )
            res = opt.result()
            finals.append(final_state_score(res.config if res else None))
            resets.append(tr.resets)
            # recovery: first post-shift interval from which every *held*
            # interval onward scores ≥ the adaptive gate (exploration
            # probes between holds don't break the streak — they are the
            # search, not the operating point)
            holds = [
                t
                for t in range(shift_start, intervals)
                if not tr.exploring[t]
            ]
            rec = None
            scores = {t: final_state_score(tr.configs[t]) for t in holds}
            for t in holds:
                if all(scores[u] >= DRIFT_ADAPTIVE_GATE for u in holds if u >= t):
                    rec = t - shift_start
                    break
            recoveries.append(rec)
            # transient violations, against the constraints in force at t
            viol = 0
            for t in range(shift_start, intervals):
                twin.set_time(t)
                tau, p = twin.exact(tr.configs[t])
                cap_t = targets.p_budget * schedule.state_at(t).budget_scale
                if (
                    tau < targets.tau_target * (1 - 1e-9)
                    or p > cap_t * (1 + 1e-9)
                ):
                    viol += 1
            transients.append(viol / (intervals - shift_start))
        n = len(seeds)
        recovered = [r for r in recoveries if r is not None]
        mean_final = sum(finals) / n
        return {
            "final_score": mean_final,
            "final_score_min": min(finals),
            "final_score_max": max(finals),
            "score_floor": round(max(0.0, mean_final - SCORE_FLOOR_MARGIN), 4),
            "recovered_rate": len(recovered) / n,
            "recovery_intervals": (
                sum(recovered) / len(recovered) if recovered else None
            ),
            "transient_violation_rate": sum(transients) / n,
            "resets": sum(resets) / n,
        }

    adaptive = variant(True)
    static = variant(False)
    twin.set_time(intervals - 1)
    return {
        "device": cell.device,
        "model": cell.model,
        "workload": cell.workload,
        "regime": cell.regime,
        "mode": targets.mode,
        "tau_target": targets.tau_target,
        "p_budget": targets.p_budget if targets.capped else None,
        "p_budget_post": p_budget_post if targets.capped else None,
        "space_size": space.size(),
        "drift": {
            "schedule": regime.drift,
            "shift_start": shift_start,
            "shift_end": schedule.shift_end,
            "intervals": intervals,
        },
        "post_oracle": {
            "config": list(post_oracle.config) if post_oracle.config else None,
            "tau": post_oracle.tau,
            "power": post_oracle.power,
        },
        "adaptive": adaptive,
        "static": static,
    }


def run_matrix(
    cells: Optional[Sequence[Cell]] = None,
    iters: int = 10,
    seeds: Sequence[int] = (0, 1, 2),
    regenerate: str = "PYTHONPATH=src python -m benchmarks.matrix_bench",
    quick: bool = False,
) -> dict:
    """Run every cell and assemble the schema'd BENCH_matrix record.

    Cells whose regime names a drift schedule run the non-stationary
    loop (``run_drift_cell``, adaptive vs. static ablation) and land in
    the record's ``drift_cells`` array; stationary cells keep the
    CORAL-vs-baselines shape in ``cells``.
    """
    if cells is None:
        cells = enumerate_cells()
    static_cells = [c for c in cells if not REGIMES[c.regime].dynamic]
    dynamic_cells = [c for c in cells if REGIMES[c.regime].dynamic]
    records = [run_cell(c, iters=iters, seeds=seeds) for c in static_cells]
    drift_records = [run_drift_cell(c, seeds=seeds) for c in dynamic_cells]
    return {
        "schema_version": 2,
        "regenerate": regenerate,
        "quick": quick,
        "iters": iters,
        "seeds": list(seeds),
        "grid": {
            "devices": sorted({c.device for c in cells}),
            "models": sorted({c.model for c in cells}),
            "workloads": sorted({c.workload for c in cells}),
            "regimes": sorted({c.regime for c in cells}),
        },
        "cells": records,
        "drift_cells": drift_records,
        "summary": _summarize(records, drift_records),
    }


def _summarize(records: List[dict], drift_records: List[dict] = ()) -> dict:
    single = [
        r["coral"]["score"] for r in records if REGIMES[r["regime"]].single_target
    ]
    dual = [r for r in records if REGIMES[r["regime"]].dual_constraint]
    all_scores = [r["coral"]["score"] for r in records]
    summary = {
        "n_cells": len(records),
        "mean_coral_score": sum(all_scores) / max(len(all_scores), 1),
        # null, not NaN, when the grid has no single-target regime — bare
        # NaN tokens are not valid JSON for strict artifact consumers.
        "min_single_target_score": min(single) if single else None,
        "dual_power_violations": int(
            sum(r["coral"]["power_violations"] for r in dual)
        ),
        # τ-floor boundary misses (power stayed within budget) — reported
        # separately because the acceptance gate is the power cap.
        "dual_tau_miss_cells": int(
            sum(
                r["coral"]["violation_rate"] > 0
                and r["coral"]["power_violations"] == 0
                for r in dual
            )
        ),
        "n_drift_cells": len(drift_records),
        "min_drift_adaptive_score": (
            min(r["adaptive"]["final_score"] for r in drift_records)
            if drift_records
            else None
        ),
        "max_drift_static_score": (
            max(r["static"]["final_score"] for r in drift_records)
            if drift_records
            else None
        ),
        "min_drift_separation": (
            min(
                r["adaptive"]["final_score"] - r["static"]["final_score"]
                for r in drift_records
            )
            if drift_records
            else None
        ),
    }
    return summary


def score_floors(record: dict) -> Dict[Tuple[str, str, str, str], float]:
    """(device, model, workload, regime) → recorded floor, for the
    bench-regression gate. Dynamic cells contribute their drift-adaptive
    floor — cell keys are unique across both arrays because a regime is
    either stationary or dynamic, never both."""
    floors = {
        (c["device"], c["model"], c["workload"], c["regime"]): c["coral"][
            "score_floor"
        ]
        for c in record["cells"]
    }
    for c in record.get("drift_cells", ()):
        key = (c["device"], c["model"], c["workload"], c["regime"])
        floors[key] = c["adaptive"]["score_floor"]
    return floors
