"""Run the scenario matrix: CORAL + all baselines through every cell.

Each cell is scored on three axes (the paper's Table/Fig. §IV summary):

  normalized score — performance of the chosen config, noise-free, as a
      fraction of the cell's exhaustive-search ORACLE under the regime's
      own objective (max_throughput: τ ratio; τ-targeted regimes:
      efficiency τ/p ratio among what the oracle ranks);
  violation rate  — fraction of runs whose chosen config truly breaks a
      constraint (evaluated on the noise-free twin, so a lucky noise
      sample can't hide a real power-budget bust);
  exploration cost — measurements until the first feasible observation
      (ORACLE pays the full grid; CORAL its iteration budget).

All optimizer selections run against the *noisy* device (the 1-second
tegrastats-style samples CORAL actually sees); all scoring runs against
the noise-free twin.

Episode engines: ``engine="compiled"`` (default) routes every CORAL
episode through the array-native ``lax.scan`` engine
(``repro.core.episode``) — one vmapped compiled call per (grid shape ×
mode) group instead of nested interpreter loops — while
``engine="scalar"`` keeps the original Python loops as the equivalence
baseline (the ``oracle_scalar`` pattern). Both engines produce
identical records: the equivalence suite (tests/test_episode.py) pins
chosen configs per seed, and scoring is shared float64 array code.

Beyond the static grid the matrix carries three further cell families,
each through the same engines: dynamic (drift) cells — adaptive vs
static ablation against the post-shift oracle (EXPERIMENTS.md §Drift) —
edge↔pod offload cells, where CORAL searches the joint route-
fraction × concurrency × two-sided-DVFS space against a batched joint
oracle while every static preset and the φ=0 ablation are infeasible
by calibration (EXPERIMENTS.md §Offload, ``run_offload_cell``) — and
multi-tenant cotenant cells, where CORAL negotiates per-tenant decode
slots × shared DVFS against per-tenant τ floors and one shared rail cap
while the per-tenant-greedy combination and every preset miss a floor
or bust the cap (EXPERIMENTS.md §Multi-tenant, ``run_cotenant_cell``).

Twins are built through ``repro.device.build_twin`` — the cell's regime
name alone picks the simulator flavor; record-level runners here are
reachable uniformly through ``repro.core.evaluate.run_cell(CellSpec)``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.baselines import Outcome, alert, alert_online, oracle, preset
from repro.core.episode import (
    alert_online_outcome,
    preset_outcome,
    run_drift_requests,
    run_fault_requests,
    run_static_requests,
)
from repro.core.evaluate import (
    RegimeTargets,
    Trace,
    measurements_to_feasible,
    run_drift_regime,
    run_fault_regime,
    run_regime,
)
from repro.core.space import row_index, tenant_slot_indices
from repro.device.factory import build_twin
from repro.device.simulator import FaultySimulator
from repro.experiments.scenarios import (
    COTENANT_REGIMES,
    DRIFT_INTERVALS,
    DRIFT_SHIFT_START,
    FAULT_INTERVALS,
    FAULT_REGIMES,
    MATRIX_COTENANT_CELLS,
    MATRIX_FAULT_CELLS,
    MATRIX_OFFLOAD_CELLS,
    OFFLOAD_REGIMES,
    REGIMES,
    WORKLOADS,
    Cell,
    _fault_base_cell,
    enumerate_cells,
    fault_tables,
    resolve_cotenant_targets,
    resolve_fault_targets,
    resolve_offload_targets,
    resolve_targets,
    tenant_names,
)

# Per-baseline device seeds: every baseline sees its own noise stream,
# deterministically, so matrix records are reproducible bit-for-bit.
_BASELINE_SEEDS = {
    "alert": 101,
    "alert_online": 102,
    "max_power": 103,
    "default": 104,
    "min_power": 105,
}

# Regression-gate margin: the recorded floor sits this far under the
# worst seed, absorbing cross-platform float jitter without letting a
# real regression through.
SCORE_FLOOR_MARGIN = 0.05


def _score(tau: float, power: float, mode: str, oracle_ref: Outcome) -> float:
    """Normalized-vs-oracle performance under the regime's objective
    (``mode``: "throughput" → τ ratio, "dual" → efficiency ratio)."""
    if oracle_ref.config is None:
        return 0.0
    if mode == "throughput":
        return tau / max(oracle_ref.tau, 1e-9)
    eff = tau / max(power, 1e-9)
    return eff / max(oracle_ref.efficiency, 1e-9)


def _violations(
    tau: float, power: float, targets: RegimeTargets
) -> Tuple[bool, bool]:
    """(τ-target miss, power-budget bust) of a chosen config, noise-free."""
    tau_miss = targets.mode != "throughput" and tau < targets.tau_target * (1 - 1e-9)
    power_bust = targets.capped and power > targets.p_budget * (1 + 1e-9)
    return tau_miss, power_bust


# ---------------------------------------------------------------------------
# Static (stationary) cells
# ---------------------------------------------------------------------------


def _prep_cell(cell: Cell) -> dict:
    """Shared per-cell precompute: noise-free twin, resolved targets,
    the float64 (τ, p) landscape over the grid, and the oracle."""
    sim0 = build_twin(cell, noise=0.0)
    targets = resolve_targets(cell, sim0)
    land_tau, land_p = sim0.exact_all()
    oracle_ref = oracle(sim0.space, sim0, targets.tau_target, targets.p_budget)
    return {
        "sim0": sim0,
        "space": sim0.space,
        "targets": targets,
        "land_tau": land_tau,
        "land_p": land_p,
        "oracle": oracle_ref,
        "noise": WORKLOADS[cell.workload].noise,
    }


def _static_requests(prep: dict, seeds: Sequence[int]) -> List[dict]:
    return [
        {
            "space": prep["space"],
            "land_tau": prep["land_tau"],
            "land_p": prep["land_p"],
            "targets": prep["targets"],
            "seed": seed,
            "noise": prep["noise"],
        }
        for seed in seeds
    ]


def _scalar_static_runs(
    cell: Cell, prep: dict, seeds: Sequence[int], iters: int, window: int
) -> List[Tuple[Outcome, Trace]]:
    """The original per-seed Python loops (equivalence baseline)."""
    runs = []
    for seed in seeds:
        dev = build_twin(cell, seed=seed)
        runs.append(
            run_regime(
                prep["space"], dev, prep["targets"], iters=iters,
                window=window, seed=seed,
            )
        )
    return runs


def _cell_record(
    cell: Cell,
    prep: dict,
    runs: List[Tuple[Outcome, Trace]],
    iters: int,
    seeds: Sequence[int],
    engine: str,
    preset_kinds: Tuple[str, ...] = ("max_power", "default"),
) -> dict:
    """Assemble one cell's JSON record from its per-seed episode runs.

    The noisy devices the scalar baselines run against come from
    ``device.build_twin`` (the regime picks the twin flavor);
    ``preset_kinds`` lists the open-loop presets to record.
    """
    sim0, targets, oracle_ref = prep["sim0"], prep["targets"], prep["oracle"]
    scores: List[float] = []
    tau_misses: List[bool] = []
    power_busts: List[bool] = []
    m2f: List[Optional[int]] = []
    best: Optional[Tuple[float, float, float, tuple]] = None
    for out, tr in runs:
        if out.config is None:
            # found nothing: a feasibility failure (τ miss), not a power
            # bust — no config ever drew power over the cap. Same mapping
            # as _outcome_record below for config-less baselines.
            scores.append(0.0)
            tau_misses.append(True)
            power_busts.append(False)
            m2f.append(None)
            continue
        tau, power = sim0.exact(out.config)
        miss, bust = _violations(tau, power, targets)
        # A pick that truly breaks the regime's constraints earns no
        # credit — an infeasible low-clock config can beat the feasible
        # optimum on raw τ/p, and crediting it would let feasibility
        # regressions read as score improvements.
        s = 0.0 if (miss or bust) else _score(tau, power, targets.mode, oracle_ref)
        scores.append(s)
        tau_misses.append(miss)
        power_busts.append(bust)
        m2f.append(measurements_to_feasible(tr, targets))
        if not (miss or bust) and (best is None or s > best[0]):
            best = (s, tau, power, tuple(out.config))
    n = len(seeds)
    reached = [v for v in m2f if v is not None]
    coral = {
        "score": sum(scores) / n,
        "score_min": min(scores),
        "score_floor": round(max(0.0, min(scores) - SCORE_FLOOR_MARGIN), 4),
        "violation_rate": sum(a or b for a, b in zip(tau_misses, power_busts)) / n,
        "power_violations": int(sum(power_busts)),
        "found_feasible_rate": len(reached) / n,
        "measurements_to_feasible": (
            sum(reached) / len(reached) if reached else None
        ),
        "measurements": iters,
        "tau": best[1] if best else 0.0,
        "power": best[2] if best else 0.0,
        "config": list(best[3]) if best else None,
    }

    # ---- baselines, one run each --------------------------------------
    def _outcome_record(out: Outcome) -> dict:
        if out.config is None:
            return {
                "score": None,
                "tau": 0.0,
                "power": 0.0,
                "violates_tau": True,
                "violates_power": False,
                "measurements": out.measurements,
            }
        tau, power = sim0.exact(out.config)
        miss, bust = _violations(tau, power, targets)
        # Baselines keep their raw normalized score next to the violation
        # flags — the paper's presentation (ALERT achieves high τ *while*
        # busting the cap) needs both visible. Only CORAL's scores feed
        # the gates, and those zero out on violation above.
        return {
            "score": _score(tau, power, targets.mode, oracle_ref),
            "tau": tau,
            "power": power,
            "violates_tau": bool(miss),
            "violates_power": bool(bust),
            "measurements": out.measurements,
        }

    # ALERT prioritizes throughput (its published objective) — in capped
    # regimes the budget is handed over but, faithfully, soft. Its
    # offline profiling is already one batched ``measure_all`` sweep, so
    # it runs the same way under both engines; ALERT-Online and the
    # presets are open-loop and route through the episode harness's
    # table twins under the compiled engine (bitwise-equal Outcomes).
    space = prep["space"]
    if engine == "compiled":
        alert_online_out = alert_online_outcome(
            space,
            prep["land_tau"],
            prep["land_p"],
            targets,
            prep["noise"],
            _BASELINE_SEEDS["alert_online"],
            iters=iters,
        )
        preset_outs = {
            kind: preset_outcome(
                space,
                prep["land_tau"],
                prep["land_p"],
                kind,
                prep["noise"],
                _BASELINE_SEEDS[kind],
            )
            for kind in preset_kinds
        }
    else:
        alert_online_out = alert_online(
            space,
            build_twin(cell, seed=_BASELINE_SEEDS["alert_online"]),
            targets.tau_target,
            targets.p_budget,
            iters=iters,
            seed=_BASELINE_SEEDS["alert_online"],
        )
        preset_outs = {
            kind: preset(
                space, build_twin(cell, seed=_BASELINE_SEEDS[kind]), kind
            )
            for kind in preset_kinds
        }
    baselines = {
        "alert": _outcome_record(
            alert(
                space,
                build_twin(cell, seed=_BASELINE_SEEDS["alert"]),
                targets.tau_target,
                targets.p_budget,
            )
        ),
        "alert_online": _outcome_record(alert_online_out),
        **{kind: _outcome_record(preset_outs[kind]) for kind in preset_kinds},
    }

    return {
        "device": cell.device,
        "model": cell.model,
        "workload": cell.workload,
        "regime": cell.regime,
        "mode": targets.mode,
        "tau_target": targets.tau_target,
        "p_budget": targets.p_budget if targets.capped else None,
        "space_size": space.size(),
        "oracle": {
            "config": list(prep["oracle"].config) if prep["oracle"].config else None,
            "tau": prep["oracle"].tau,
            "power": prep["oracle"].power,
            "measurements": prep["oracle"].measurements,
        },
        "coral": coral,
        "baselines": baselines,
    }


def run_static_cell(
    cell: Cell,
    iters: int = 10,
    seeds: Sequence[int] = (0, 1, 2),
    window: int = 10,
    engine: str = "compiled",
) -> dict:
    """One stationary cell → one JSON-ready record (schema.MATRIX_SCHEMA)."""
    prep = _prep_cell(cell)
    if engine == "compiled":
        eps = run_static_requests(
            _static_requests(prep, seeds), iters=iters, window=window
        )
        runs = [(ep.outcome, ep.trace()) for ep in eps]
    else:
        runs = _scalar_static_runs(cell, prep, seeds, iters, window)
    return _cell_record(cell, prep, runs, iters, seeds, engine)


# Deprecated alias (one release): the stationary record runner is now
# ``run_static_cell``; the family-dispatching entrypoint is
# ``repro.core.evaluate.run_cell(CellSpec)``.
run_cell = run_static_cell


# ---------------------------------------------------------------------------
# Offload (edge↔pod) cells
# ---------------------------------------------------------------------------

# The joint offload grid is ~2.5× the size of a single-device grid and
# its dual-feasible region is deliberately narrow (5–18% of rows), so
# the measurement budget scales with it: 24 measurements keeps every
# calibrated cell ≥ OFFLOAD_CORAL_GATE of the joint oracle with zero
# true power busts (gated in benchmarks/matrix_bench.py and
# check_regression.py), while every static preset and the no-offload
# ablation stay infeasible by construction.
OFFLOAD_ITERS = 24
OFFLOAD_CORAL_GATE = 0.85


def _prep_offload_cell(cell: Cell) -> dict:
    """Offload-cell precompute: the noise-free edge↔pod twin (demand
    pinned at demand_factor × the edge-only max), resolved end-to-end
    targets, the joint-grid (τ_served, p_edge) landscape, and the batched
    joint-space oracle — same keys as ``_prep_cell`` so the episode
    request shape is shared."""
    sim0 = build_twin(cell, noise=0.0)
    targets = resolve_offload_targets(cell, sim0)
    land_tau, land_p = sim0.exact_all()
    oracle_ref = oracle(sim0.space, sim0, targets.tau_target, targets.p_budget)
    return {
        "sim0": sim0,
        "space": sim0.space,
        "targets": targets,
        "land_tau": land_tau,
        "land_p": land_p,
        "oracle": oracle_ref,
        "noise": WORKLOADS[cell.workload].noise,
    }


def _scalar_offload_runs(
    cell: Cell, prep: dict, seeds: Sequence[int], iters: int, window: int
) -> List[Tuple[Outcome, Trace]]:
    """Per-seed Python loops over the edge↔pod twin (equivalence
    baseline for the offload-enlarged episode engine)."""
    runs = []
    for seed in seeds:
        dev = build_twin(cell, seed=seed)
        runs.append(
            run_regime(
                prep["space"], dev, prep["targets"], iters=iters,
                window=window, seed=seed,
            )
        )
    return runs


def _no_offload_record(prep: dict) -> dict:
    """The no-offload ablation: exhaustive search restricted to the
    φ=0 rows of the joint grid. On calibrated offload cells no such row
    meets the SLO (demand exceeds the edge-only max by construction), so
    this records the *best the un-offloaded edge can do* — its max-τ row
    — with the violation flags that show why routing is required."""
    space = prep["space"]
    targets = prep["targets"]
    grid = space.grid()
    phi = grid[:, space.names.index("offload_frac")]
    tau, p = prep["land_tau"], prep["land_p"]
    local = np.nonzero(phi == 0.0)[0]
    feasible = local[
        (tau[local] >= targets.tau_target * (1 - 1e-9))
        & (p[local] <= targets.p_budget * (1 + 1e-9))
    ]
    if feasible.size:
        eff = tau[feasible] / np.maximum(p[feasible], 1e-9)
        pick = int(feasible[int(np.argmax(eff))])
    else:
        pick = int(local[int(np.argmax(tau[local]))])
    miss, bust = _violations(float(tau[pick]), float(p[pick]), targets)
    return {
        "feasible_rows": int(feasible.size),
        "config": [float(v) for v in grid[pick]],
        "tau": float(tau[pick]),
        "power": float(p[pick]),
        "violates_tau": bool(miss),
        "violates_power": bool(bust),
    }


def _offload_cell_record(
    cell: Cell,
    prep: dict,
    runs: List[Tuple[Outcome, Trace]],
    iters: int,
    seeds: Sequence[int],
    engine: str,
) -> dict:
    """One offload cell's record: the static-cell shape (CORAL vs
    baselines vs the batched joint oracle, min_power included) plus the
    network/demand provenance and the no-offload ablation."""
    regime = OFFLOAD_REGIMES[cell.regime]
    rec = _cell_record(
        cell,
        prep,
        runs,
        iters,
        seeds,
        engine,
        preset_kinds=("max_power", "default", "min_power"),
    )
    sim0 = prep["sim0"]
    rec["offload"] = {
        "network": regime.network,
        "trace": regime.trace,
        "demand": sim0.demand,
        "demand_factor": regime.demand_factor,
        "slo_frac": regime.slo_frac,
        "p_slack": regime.p_slack,
        "edge_only_max": round(float(sim0.edge_only_max()), 3),
        "no_offload": _no_offload_record(prep),
    }
    return rec


def run_offload_cell(
    cell: Cell,
    iters: int = OFFLOAD_ITERS,
    seeds: Sequence[int] = (0, 1, 2),
    window: int = 10,
    engine: str = "compiled",
) -> dict:
    """One edge↔pod offload cell → one JSON-ready record (the
    ``offload_cells`` entry of schema v4 — see
    ``repro.experiments.schema`` and docs/BENCH_SCHEMAS.md)."""
    prep = _prep_offload_cell(cell)
    if engine == "compiled":
        eps = run_static_requests(
            _static_requests(prep, seeds), iters=iters, window=window
        )
        runs = [(ep.outcome, ep.trace()) for ep in eps]
    else:
        runs = _scalar_offload_runs(cell, prep, seeds, iters, window)
    return _offload_cell_record(cell, prep, runs, iters, seeds, engine)


# ---------------------------------------------------------------------------
# Cotenant (multi-tenant co-inference) cells
# ---------------------------------------------------------------------------

# The joint slots × shared-DVFS grid keeps a deliberately narrow dual-
# feasible region (4–7% of rows on the calibrated cells), so the budget
# is the largest of the three families: 40 measurements keeps every cell
# ≥ COTENANT_CORAL_GATE of the batched joint oracle across all seeds
# (the skewed cells first observe a feasible row around measurement
# 14–20, and the refinement tail after that is what closes the gap to
# the oracle). Calibration note: the budget is *not* monotonic in
# iters — a later noisy-feasible but truly-infeasible probe can outrank
# an earlier genuine pick on noisy efficiency — so treat this constant
# as calibrated, not merely "enough".
COTENANT_ITERS = 40
COTENANT_CORAL_GATE = 0.85


def _prep_cotenant_cell(cell: Cell) -> dict:
    """Cotenant-cell precompute: the noise-free multi-tenant twin
    (per-tenant τ floors pinned from the regime's solo-max fractions),
    resolved joint targets (τ channel = joint headroom, target 1.0), the
    (headroom, rail-power) landscape and the batched joint oracle — same
    keys as ``_prep_cell`` so the episode request shape is shared."""
    sim0 = build_twin(cell, noise=0.0)
    targets = resolve_cotenant_targets(cell, sim0)
    land_tau, land_p = sim0.exact_all()
    oracle_ref = oracle(sim0.space, sim0, targets.tau_target, targets.p_budget)
    _, workloads = tenant_names(cell)
    return {
        "sim0": sim0,
        "space": sim0.space,
        "targets": targets,
        "land_tau": land_tau,
        "land_p": land_p,
        "oracle": oracle_ref,
        "noise": max(WORKLOADS[w].noise for w in workloads),
    }


def _scalar_cotenant_runs(
    cell: Cell, prep: dict, seeds: Sequence[int], iters: int, window: int
) -> List[Tuple[Outcome, Trace]]:
    """Per-seed Python loops over the multi-tenant twin (equivalence
    baseline for cotenant episodes on the compiled engine)."""
    runs = []
    for seed in seeds:
        dev = build_twin(cell, seed=seed)
        runs.append(
            run_regime(
                prep["space"], dev, prep["targets"], iters=iters,
                window=window, seed=seed,
            )
        )
    return runs


def _greedy_record(prep: dict) -> dict:
    """The per-tenant-greedy ablation: each tenant plans as if it owned
    the rail — the grid restricted to rows where every *other* tenant is
    parked at 1 slot — and picks its cheapest floor-meeting row (its
    max-τ row if none meets the floor). The combined operating point is
    the elementwise max of the picks with each tenant keeping its own
    slot ask, snapped to the grid and evaluated *jointly*. On calibrated
    cotenant cells this combination misses a floor or busts the shared
    cap: per-tenant planning never pays for the contention its own slots
    impose on the neighbor."""
    sim0, targets = prep["sim0"], prep["targets"]
    space = sim0.space
    grid = space.grid()
    taus = sim0.tenant_taus()  # (K, N) noise-free per-tenant τ
    power = prep["land_p"]
    slot_idx = list(tenant_slot_indices(space))
    picks = []
    for k in range(sim0.n_tenants):
        others = [i for j, i in enumerate(slot_idx) if j != k]
        solo = np.nonzero(
            np.all([grid[:, i] == 1.0 for i in others], axis=0)
        )[0]
        feas = solo[taus[k, solo] >= sim0.floors[k] * (1 - 1e-9)]
        pick = (
            int(feas[int(np.argmin(power[feas]))])
            if feas.size
            else int(solo[int(np.argmax(taus[k, solo]))])
        )
        picks.append(np.array(grid[pick], np.float64))
    combined = np.max(picks, axis=0)
    for k, i in enumerate(slot_idx):
        combined[i] = picks[k][i]
    cfg = space.snap(tuple(combined))
    headroom, p = sim0.exact(cfg)
    miss, bust = _violations(headroom, p, targets)
    return {
        "config": [float(v) for v in cfg],
        "headroom": headroom,
        "power": p,
        "violates_tau": bool(miss),
        "violates_power": bool(bust),
    }


def _cotenant_cell_record(
    cell: Cell,
    prep: dict,
    runs: List[Tuple[Outcome, Trace]],
    iters: int,
    seeds: Sequence[int],
    engine: str,
) -> dict:
    """One cotenant cell's record: the static-cell shape on the
    (headroom, rail-power) channel — min_power preset included — plus the
    per-tenant provenance (floors, solo maxima) and the per-tenant-greedy
    ablation."""
    regime = COTENANT_REGIMES[cell.regime]
    rec = _cell_record(
        cell,
        prep,
        runs,
        iters,
        seeds,
        engine,
        preset_kinds=("max_power", "default", "min_power"),
    )
    sim0 = prep["sim0"]
    models, workloads = tenant_names(cell)
    rec["cotenant"] = {
        "n_tenants": sim0.n_tenants,
        "p_slack": regime.p_slack,
        "tenants": [
            {
                "model": m,
                "workload": w,
                "tau_frac": regime.tau_fracs[k],
                "floor": sim0.floors[k],
                "solo_max": round(sim0.solo_max(k), 3),
            }
            for k, (m, w) in enumerate(zip(models, workloads))
        ],
        "greedy": _greedy_record(prep),
    }
    return rec


def run_cotenant_cell(
    cell: Cell,
    iters: int = COTENANT_ITERS,
    seeds: Sequence[int] = (0, 1, 2),
    window: int = 10,
    engine: str = "compiled",
) -> dict:
    """One multi-tenant co-inference cell → one JSON-ready record (the
    ``cotenant_cells`` entry of schema v5 — see
    ``repro.experiments.schema`` and docs/BENCH_SCHEMAS.md)."""
    prep = _prep_cotenant_cell(cell)
    if engine == "compiled":
        eps = run_static_requests(
            _static_requests(prep, seeds), iters=iters, window=window
        )
        runs = [(ep.outcome, ep.trace()) for ep in eps]
    else:
        runs = _scalar_cotenant_runs(cell, prep, seeds, iters, window)
    return _cotenant_cell_record(cell, prep, runs, iters, seeds, engine)


# ---------------------------------------------------------------------------
# Fault (injected-failure) cells
# ---------------------------------------------------------------------------

# Fault cells run the FAULT_INTERVALS timeline (explore → fault window →
# recover) and are scored against the *fault-free* oracle: the question
# is what the chosen config actually delivers once the glitch is gone,
# so both scoring and the oracle use the base cell's noise-free twin.
# Acceptance levels (gated in benchmarks/matrix_bench.py and
# check_regression.py): hardened CORAL must hold ≥ FAULT_CORAL_GATE of
# the fault-free oracle with zero true power busts on every cell, while
# the non-hardened ablation — same twin, same fault realization — must
# end infeasible or violating on every (cell, seed).
FAULT_ITERS = FAULT_INTERVALS
FAULT_CORAL_GATE = 0.85


def _prep_fault_cell(cell: Cell, iters: int, seeds: Sequence[int]) -> dict:
    """Fault-cell precompute: the *base* cell's noise-free twin (faults
    corrupt the measurement/actuation path, so ground truth is the clean
    landscape), the base regime's targets, the fault-free oracle, and
    one realized fault-table set per seed — shared by the hardened run,
    the ablation, and both engines, so every comparison sees the same
    glitches."""
    base = _fault_base_cell(cell)
    sim0 = build_twin(base, noise=0.0)
    targets = resolve_fault_targets(cell)
    land_tau, land_p = sim0.exact_all()
    oracle_ref = oracle(sim0.space, sim0, targets.tau_target, targets.p_budget)
    return {
        "sim0": sim0,
        "space": sim0.space,
        "targets": targets,
        "land_tau": land_tau,
        "land_p": land_p,
        "oracle": oracle_ref,
        "noise": WORKLOADS[cell.workload].noise,
        "tables": {s: fault_tables(cell, s, intervals=iters) for s in seeds},
    }


def _fault_requests(
    prep: dict, seeds: Sequence[int], hardened: bool
) -> List[dict]:
    return [
        {
            "space": prep["space"],
            "land_tau": prep["land_tau"],
            "land_p": prep["land_p"],
            "targets": prep["targets"],
            "seed": seed,
            "noise": prep["noise"],
            "tables": prep["tables"][seed],
            "hardened": hardened,
        }
        for seed in seeds
    ]


def _scalar_fault_runs(
    cell: Cell,
    prep: dict,
    seeds: Sequence[int],
    hardened: bool,
    iters: int,
    window: int,
) -> List[dict]:
    """Per-seed Python fault loops, normalized to the engine's run shape
    (equivalence baseline for the fault-enlarged episode engine)."""
    runs = []
    for seed in seeds:
        dev = FaultySimulator(
            build_twin(_fault_base_cell(cell), seed=seed), prep["tables"][seed]
        )
        opt, tr = run_fault_regime(
            prep["space"], dev, prep["targets"], iters=iters, window=window,
            seed=seed, hardened=hardened,
        )
        res = opt.result()
        runs.append(
            {
                "outcome": (
                    Outcome(res.config, res.tau, res.power, iters)
                    if res is not None
                    else Outcome(None, 0.0, 0.0, iters)
                ),
                "accepted": list(tr.accepted),
                "fallback": list(tr.fallback),
            }
        )
    return runs


def _fault_variant_record(
    prep: dict, runs: List[dict], seeds: Sequence[int]
) -> dict:
    """Score one variant (hardened or ablation) from per-seed run shapes.
    Everything is evaluated on the fault-free twin: the fault episode
    decided *which* config got picked; what that config truly delivers
    is a property of the clean landscape."""
    sim0, targets, oracle_ref = prep["sim0"], prep["targets"], prep["oracle"]
    scores: List[float] = []
    misses: List[bool] = []
    busts: List[bool] = []
    failed: List[bool] = []  # ended infeasible (no pick or violating pick)
    fallbacks: List[int] = []
    rejected: List[int] = []
    best: Optional[Tuple[float, float, float, tuple]] = None
    for run in runs:
        out = run["outcome"]
        if out.config is None:
            scores.append(0.0)
            misses.append(True)
            busts.append(False)
            failed.append(True)
        else:
            tau, power = sim0.exact(out.config)
            miss, bust = _violations(tau, power, targets)
            s = 0.0 if (miss or bust) else _score(
                tau, power, targets.mode, oracle_ref
            )
            scores.append(s)
            misses.append(miss)
            busts.append(bust)
            failed.append(bool(miss or bust))
            if not (miss or bust) and (best is None or s > best[0]):
                best = (s, tau, power, tuple(out.config))
        fallbacks.append(int(sum(run["fallback"])))
        rejected.append(len(run["accepted"]) - int(sum(run["accepted"])))
    n = len(seeds)
    return {
        "score": sum(scores) / n,
        "score_min": min(scores),
        "score_floor": round(max(0.0, min(scores) - SCORE_FLOOR_MARGIN), 4),
        "violation_rate": sum(a or b for a, b in zip(misses, busts)) / n,
        "power_violations": int(sum(busts)),
        # per-seed "ended infeasible or violating" count — the ablation
        # gate requires failed_runs == n_runs on every fault cell
        "n_runs": n,
        "failed_runs": int(sum(failed)),
        "fallback_intervals": sum(fallbacks) / n,
        "rejected_samples": sum(rejected) / n,
        "tau": best[1] if best else 0.0,
        "power": best[2] if best else 0.0,
        "config": list(best[3]) if best else None,
    }


def _fault_cell_record(
    cell: Cell,
    prep: dict,
    hardened_runs: List[dict],
    ablation_runs: List[dict],
    iters: int,
    seeds: Sequence[int],
) -> dict:
    regime = FAULT_REGIMES[cell.regime]
    targets = prep["targets"]
    return {
        "device": cell.device,
        "model": cell.model,
        "workload": cell.workload,
        "regime": cell.regime,
        "mode": targets.mode,
        "tau_target": targets.tau_target,
        "p_budget": targets.p_budget if targets.capped else None,
        "space_size": prep["space"].size(),
        "fault": {
            "schedule": regime.fault,
            "base_regime": regime.base,
            "intervals": iters,
        },
        "oracle": {
            "config": (
                list(prep["oracle"].config) if prep["oracle"].config else None
            ),
            "tau": prep["oracle"].tau,
            "power": prep["oracle"].power,
            "measurements": prep["oracle"].measurements,
        },
        "hardened": _fault_variant_record(prep, hardened_runs, seeds),
        "ablation": _fault_variant_record(prep, ablation_runs, seeds),
    }


def run_fault_cell(
    cell: Cell,
    iters: int = FAULT_ITERS,
    seeds: Sequence[int] = (0, 1, 2),
    window: int = 10,
    engine: str = "compiled",
) -> dict:
    """One fault-injection cell → one JSON-ready record (the
    ``fault_cells`` entry of schema v6 — see ``repro.experiments.schema``
    and docs/BENCH_SCHEMAS.md).

    Runs hardened CORAL (robust ingest gate + watchdog fallback +
    actuation readback/retry) and the non-hardened ablation through the
    same fault-injected twin — byte-identical fault realizations — and
    scores both against the fault-free oracle."""
    prep = _prep_fault_cell(cell, iters, seeds)
    runs = {}
    for hardened in (True, False):
        if engine == "compiled":
            runs[hardened] = run_fault_requests(
                _fault_requests(prep, seeds, hardened),
                iters=iters,
                window=window,
            )
        else:
            runs[hardened] = _scalar_fault_runs(
                cell, prep, seeds, hardened, iters, window
            )
    return _fault_cell_record(cell, prep, runs[True], runs[False], iters, seeds)


# ---------------------------------------------------------------------------
# Dynamic (drift) cells
# ---------------------------------------------------------------------------

# Drift-cell acceptance levels (gated in benchmarks/matrix_bench.py):
# drift-adaptive CORAL must average ≥ this fraction of the post-shift
# oracle, the static ablation must average ≤ the ceiling, and the gap
# between them must demonstrate that re-exploration — not luck — closed it.
DRIFT_ADAPTIVE_GATE = 0.85
DRIFT_STATIC_CEILING = 0.5
DRIFT_SEPARATION = 0.3


def _prep_drift_cell(cell: Cell, intervals: int) -> dict:
    """Per-cell drift precompute: the stacked per-interval landscapes
    (one sweep per *unique* drift state), per-interval budget scales,
    and the post-shift oracle — everything scoring and the compiled
    episode engine share."""
    regime = REGIMES[cell.regime]
    twin = build_twin(cell, noise=0.0)
    sim0, schedule = twin.base, twin.schedule
    targets = resolve_targets(cell, sim0)
    land_tau, land_p = twin.landscapes(intervals)
    budget_scale = schedule.states_stacked(intervals)["budget_scale"]
    twin.set_time(intervals - 1)
    p_budget_post = targets.p_budget * twin.state.budget_scale
    post_oracle = oracle(sim0.space, twin, targets.tau_target, p_budget_post)
    return {
        "sim0": sim0,
        "space": sim0.space,
        "targets": targets,
        "schedule": schedule,
        "regime": regime,
        "land_tau": land_tau,
        "land_p": land_p,
        "budget_scale": budget_scale,
        "p_budget_post": p_budget_post,
        "post_oracle": post_oracle,
        "noise": WORKLOADS[cell.workload].noise,
    }


def _drift_requests(
    prep: dict, seeds: Sequence[int], adaptive: bool
) -> List[dict]:
    return [
        {
            "space": prep["space"],
            "land_tau": prep["land_tau"],
            "land_p": prep["land_p"],
            "budget_scale": prep["budget_scale"],
            "targets": prep["targets"],
            "seed": seed,
            "noise": prep["noise"],
            "adaptive": adaptive,
        }
        for seed in seeds
    ]


def _scalar_drift_runs(
    cell: Cell,
    prep: dict,
    seeds: Sequence[int],
    adaptive: bool,
    intervals: int,
    explore_budget: int,
    window: int,
) -> List[dict]:
    """Original Python drift loops, normalized to the engine's run shape."""
    runs = []
    space = prep["space"]
    for seed in seeds:
        dev = build_twin(cell, seed=seed)
        opt, tr = run_drift_regime(
            space,
            dev,
            prep["targets"],
            prep["schedule"],
            intervals,
            explore_budget=explore_budget,
            window=window,
            seed=seed,
            adaptive=adaptive,
            sigma=prep["noise"],
        )
        res = opt.result()
        runs.append(
            {
                "idxs": np.asarray(
                    [row_index(space, cfg) for cfg in tr.configs]
                ),
                "exploring": list(tr.exploring),
                "resets": tr.resets,
                "result_idx": (
                    row_index(space, res.config) if res is not None else None
                ),
            }
        )
    return runs


def _compiled_drift_runs(eps: List, space) -> List[dict]:
    return [
        {
            "idxs": np.asarray([row_index(space, cfg) for cfg in ep.configs]),
            "exploring": ep.exploring,
            "resets": ep.resets,
            "result_idx": (
                row_index(space, ep.result_config)
                if ep.result_config is not None
                else None
            ),
        }
        for ep in eps
    ]


def _drift_variant_record(
    prep: dict,
    runs: List[dict],
    seeds: Sequence[int],
    intervals: int,
    shift_start: int,
) -> dict:
    """Score one variant (adaptive or static) from per-seed run shapes —
    batched twin sweeps over the precomputed per-interval landscapes
    instead of ``set_time`` round-trips per interval per seed."""
    targets = prep["targets"]
    post_oracle = prep["post_oracle"]
    p_budget_post = prep["p_budget_post"]
    lt_post, lp_post = prep["land_tau"][-1], prep["land_p"][-1]

    def final_state_scores(idxs: np.ndarray) -> np.ndarray:
        """Normalized-vs-post-oracle scores at the fully-shifted state
        for a vector of config rows (violating → 0)."""
        if post_oracle.config is None:
            return np.zeros(idxs.shape[0])
        tau, p = lt_post[idxs], lp_post[idxs]
        ok = (tau >= targets.tau_target * (1 - 1e-9)) & (
            p <= p_budget_post * (1 + 1e-9)
        )
        if targets.mode == "throughput":
            score = tau / max(post_oracle.tau, 1e-9)
        else:
            score = (tau / np.maximum(p, 1e-9)) / max(
                post_oracle.efficiency, 1e-9
            )
        return np.where(ok, score, 0.0)

    finals: List[float] = []
    recoveries: List[Optional[int]] = []
    transients: List[float] = []
    resets: List[int] = []
    post = np.arange(shift_start, intervals)
    for run in runs:
        idxs = run["idxs"]
        ridx = run["result_idx"]
        finals.append(
            float(final_state_scores(np.asarray([ridx]))[0])
            if ridx is not None
            else 0.0
        )
        resets.append(run["resets"])
        # recovery: first post-shift interval from which every *held*
        # interval onward scores ≥ the adaptive gate (exploration probes
        # between holds don't break the streak — they are the search,
        # not the operating point). The streak check is a suffix-min
        # over hold scores — O(holds), not the O(holds²) rescan.
        holds = np.asarray(
            [t for t in post if not run["exploring"][t]], np.int64
        )
        rec = None
        if holds.size:
            scores = final_state_scores(idxs[holds])
            suffix_min = np.minimum.accumulate(scores[::-1])[::-1]
            clears = np.nonzero(suffix_min >= DRIFT_ADAPTIVE_GATE)[0]
            if clears.size:
                rec = int(holds[clears[0]]) - shift_start
        recoveries.append(rec)
        # transient violations, against the constraints in force at t —
        # one gather over the stacked landscapes
        tau_t = prep["land_tau"][post, idxs[post]]
        p_t = prep["land_p"][post, idxs[post]]
        cap_t = targets.p_budget * prep["budget_scale"][post]
        viol = (tau_t < targets.tau_target * (1 - 1e-9)) | (
            p_t > cap_t * (1 + 1e-9)
        )
        transients.append(float(viol.sum()) / (intervals - shift_start))
    n = len(seeds)
    recovered = [r for r in recoveries if r is not None]
    mean_final = sum(finals) / n
    return {
        "final_score": mean_final,
        "final_score_min": min(finals),
        "final_score_max": max(finals),
        "score_floor": round(max(0.0, mean_final - SCORE_FLOOR_MARGIN), 4),
        "recovered_rate": len(recovered) / n,
        "recovery_intervals": (
            sum(recovered) / len(recovered) if recovered else None
        ),
        "transient_violation_rate": sum(transients) / n,
        "resets": sum(resets) / n,
    }


def _drift_cell_record(
    cell: Cell,
    prep: dict,
    adaptive: dict,
    static: dict,
    intervals: int,
    shift_start: int,
) -> dict:
    targets = prep["targets"]
    post_oracle = prep["post_oracle"]
    return {
        "device": cell.device,
        "model": cell.model,
        "workload": cell.workload,
        "regime": cell.regime,
        "mode": targets.mode,
        "tau_target": targets.tau_target,
        "p_budget": targets.p_budget if targets.capped else None,
        "p_budget_post": prep["p_budget_post"] if targets.capped else None,
        "space_size": prep["space"].size(),
        "drift": {
            "schedule": prep["regime"].drift,
            "shift_start": shift_start,
            "shift_end": prep["schedule"].shift_end,
            "intervals": intervals,
        },
        "post_oracle": {
            "config": list(post_oracle.config) if post_oracle.config else None,
            "tau": post_oracle.tau,
            "power": post_oracle.power,
        },
        "adaptive": adaptive,
        "static": static,
    }


def run_drift_cell(
    cell: Cell,
    seeds: Sequence[int] = (0, 1, 2),
    window: int = 10,
    explore_budget: int = 10,
    intervals: int = DRIFT_INTERVALS,
    shift_start: int = DRIFT_SHIFT_START,
    engine: str = "compiled",
) -> dict:
    """One dynamic (non-stationary) cell → one JSON-ready record.

    Runs drift-adaptive CORAL and the static (no-re-exploration) ablation
    through the same drifting twin and scores both against the
    *post-shift* oracle: the exhaustive search on the fully-shifted
    noise-free landscape under the post-shift budget. Metrics:

      final_score      — the optimizer's end-of-run choice, normalized
                         vs. the post-shift oracle (violating → 0);
      recovery_intervals — intervals from the shift until the loop holds
                         a ≥0.85-scoring config for the rest of the run
                         (None if it never settles that high);
      transient_violation_rate — fraction of post-shift intervals whose
                         *applied* config truly violated the constraints
                         in force at that interval (exploration probes
                         included: re-exploration's price is visible);
      resets           — exploration epochs spent after the shift.
    """
    prep = _prep_drift_cell(cell, intervals)
    variants = {}
    for adaptive in (True, False):
        if engine == "compiled":
            eps = run_drift_requests(
                _drift_requests(prep, seeds, adaptive),
                intervals=intervals,
                explore_budget=explore_budget,
                window=window,
            )
            runs = _compiled_drift_runs(eps, prep["space"])
        else:
            runs = _scalar_drift_runs(
                cell, prep, seeds, adaptive, intervals, explore_budget, window
            )
        variants[adaptive] = _drift_variant_record(
            prep, runs, seeds, intervals, shift_start
        )
    return _drift_cell_record(
        cell, prep, variants[True], variants[False], intervals, shift_start
    )


# ---------------------------------------------------------------------------
# The full matrix
# ---------------------------------------------------------------------------


def run_matrix(
    cells: Optional[Sequence[Cell]] = None,
    iters: int = 10,
    seeds: Sequence[int] = (0, 1, 2),
    regenerate: str = "PYTHONPATH=src python -m benchmarks.matrix_bench",
    quick: bool = False,
    engine: str = "compiled",
    window: int = 10,
    offload_cells: Optional[Sequence[Cell]] = None,
    cotenant_cells: Optional[Sequence[Cell]] = None,
    fault_cells: Optional[Sequence[Cell]] = None,
) -> dict:
    """Run every cell and assemble the schema'd BENCH_matrix record.

    Cells whose regime names a drift schedule run the non-stationary
    loop (``run_drift_cell``, adaptive vs. static ablation) and land in
    the record's ``drift_cells`` array; stationary cells keep the
    CORAL-vs-baselines shape in ``cells``; edge↔pod offload cells
    (``offload_cells`` — defaults to ``MATRIX_OFFLOAD_CELLS`` on the
    full grid, to none when an explicit ``cells`` list is given) run
    CORAL over the joint route-fraction × DVFS space at the larger
    ``OFFLOAD_ITERS`` budget and land in ``offload_cells``; multi-tenant
    co-inference cells (``cotenant_cells`` — defaults to
    ``MATRIX_COTENANT_CELLS`` on the full grid) run CORAL over the joint
    per-tenant-slots × shared-DVFS space at the ``COTENANT_ITERS``
    budget and land in ``cotenant_cells``.

    Under the compiled engine every CORAL episode across all cells ×
    seeds (× drift variants) is submitted as one request batch — the
    engine groups them by (grid shape, mode) and runs each group as a
    single vmapped ``lax.scan`` call; offload episodes form their own
    batch because their measurement budget differs. ``wall_clock_s``
    records the per-phase split so the nightly run tracks where time
    goes.
    """
    if offload_cells is None:
        offload_cells = MATRIX_OFFLOAD_CELLS if cells is None else ()
    if cotenant_cells is None:
        cotenant_cells = MATRIX_COTENANT_CELLS if cells is None else ()
    if fault_cells is None:
        fault_cells = MATRIX_FAULT_CELLS if cells is None else ()
    if cells is None:
        cells = enumerate_cells()
    static_cells = [c for c in cells if not REGIMES[c.regime].dynamic]
    dynamic_cells = [c for c in cells if REGIMES[c.regime].dynamic]
    wall: Dict[str, float] = {}

    # ---- static cells --------------------------------------------------
    t0 = time.perf_counter()
    preps = {c: _prep_cell(c) for c in static_cells}
    wall["static_prep_s"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    runs_by_cell: Dict[Cell, list] = {}
    if engine == "compiled":
        reqs, owners = [], []
        for c in static_cells:
            cell_reqs = _static_requests(preps[c], seeds)
            owners.extend([c] * len(cell_reqs))
            reqs.extend(cell_reqs)
        eps = run_static_requests(reqs, iters=iters, window=window)
        for c, ep in zip(owners, eps):
            runs_by_cell.setdefault(c, []).append((ep.outcome, ep.trace()))
    else:
        for c in static_cells:
            runs_by_cell[c] = _scalar_static_runs(c, preps[c], seeds, iters, window)
    wall["static_episodes_s"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    records = [
        _cell_record(c, preps[c], runs_by_cell[c], iters, seeds, engine)
        for c in static_cells
    ]
    wall["static_score_s"] = time.perf_counter() - t0

    # ---- offload cells -------------------------------------------------
    t0 = time.perf_counter()
    opreps = {c: _prep_offload_cell(c) for c in offload_cells}
    wall["offload_prep_s"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    offload_runs: Dict[Cell, list] = {}
    if engine == "compiled":
        reqs, owners = [], []
        for c in offload_cells:
            cell_reqs = _static_requests(opreps[c], seeds)
            owners.extend([c] * len(cell_reqs))
            reqs.extend(cell_reqs)
        if reqs:
            eps = run_static_requests(reqs, iters=OFFLOAD_ITERS, window=window)
            for c, ep in zip(owners, eps):
                offload_runs.setdefault(c, []).append((ep.outcome, ep.trace()))
    else:
        for c in offload_cells:
            offload_runs[c] = _scalar_offload_runs(
                c, opreps[c], seeds, OFFLOAD_ITERS, window
            )
    wall["offload_episodes_s"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    offload_records = [
        _offload_cell_record(
            c, opreps[c], offload_runs[c], OFFLOAD_ITERS, seeds, engine
        )
        for c in offload_cells
    ]
    wall["offload_score_s"] = time.perf_counter() - t0

    # ---- cotenant cells ------------------------------------------------
    t0 = time.perf_counter()
    cpreps = {c: _prep_cotenant_cell(c) for c in cotenant_cells}
    wall["cotenant_prep_s"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    cotenant_runs: Dict[Cell, list] = {}
    if engine == "compiled":
        reqs, owners = [], []
        for c in cotenant_cells:
            cell_reqs = _static_requests(cpreps[c], seeds)
            owners.extend([c] * len(cell_reqs))
            reqs.extend(cell_reqs)
        if reqs:
            eps = run_static_requests(reqs, iters=COTENANT_ITERS, window=window)
            for c, ep in zip(owners, eps):
                cotenant_runs.setdefault(c, []).append((ep.outcome, ep.trace()))
    else:
        for c in cotenant_cells:
            cotenant_runs[c] = _scalar_cotenant_runs(
                c, cpreps[c], seeds, COTENANT_ITERS, window
            )
    wall["cotenant_episodes_s"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    cotenant_records = [
        _cotenant_cell_record(
            c, cpreps[c], cotenant_runs[c], COTENANT_ITERS, seeds, engine
        )
        for c in cotenant_cells
    ]
    wall["cotenant_score_s"] = time.perf_counter() - t0

    # ---- fault cells ---------------------------------------------------
    t0 = time.perf_counter()
    fpreps = {c: _prep_fault_cell(c, FAULT_ITERS, seeds) for c in fault_cells}
    wall["fault_prep_s"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    fault_runs: Dict[Tuple[Cell, bool], list] = {}
    if engine == "compiled":
        reqs, owners = [], []
        for c in fault_cells:
            for hardened in (True, False):
                cell_reqs = _fault_requests(fpreps[c], seeds, hardened)
                owners.extend([(c, hardened)] * len(cell_reqs))
                reqs.extend(cell_reqs)
        if reqs:
            outs = run_fault_requests(reqs, iters=FAULT_ITERS, window=window)
            for owner, out in zip(owners, outs):
                fault_runs.setdefault(owner, []).append(out)
    else:
        for c in fault_cells:
            for hardened in (True, False):
                fault_runs[(c, hardened)] = _scalar_fault_runs(
                    c, fpreps[c], seeds, hardened, FAULT_ITERS, window
                )
    wall["fault_episodes_s"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    fault_records = [
        _fault_cell_record(
            c, fpreps[c], fault_runs[(c, True)], fault_runs[(c, False)],
            FAULT_ITERS, seeds,
        )
        for c in fault_cells
    ]
    wall["fault_score_s"] = time.perf_counter() - t0

    # ---- drift cells ---------------------------------------------------
    t0 = time.perf_counter()
    dpreps = {c: _prep_drift_cell(c, DRIFT_INTERVALS) for c in dynamic_cells}
    wall["drift_prep_s"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    drift_runs: Dict[Tuple[Cell, bool], list] = {}
    if engine == "compiled":
        reqs, owners = [], []
        for c in dynamic_cells:
            for adaptive in (True, False):
                cell_reqs = _drift_requests(dpreps[c], seeds, adaptive)
                owners.extend([(c, adaptive)] * len(cell_reqs))
                reqs.extend(cell_reqs)
        eps = run_drift_requests(reqs, intervals=DRIFT_INTERVALS, window=window)
        by_owner: Dict[Tuple[Cell, bool], list] = {}
        for owner, ep in zip(owners, eps):
            by_owner.setdefault(owner, []).append(ep)
        for owner, cell_eps in by_owner.items():
            drift_runs[owner] = _compiled_drift_runs(
                cell_eps, dpreps[owner[0]]["space"]
            )
    else:
        for c in dynamic_cells:
            for adaptive in (True, False):
                drift_runs[(c, adaptive)] = _scalar_drift_runs(
                    c, dpreps[c], seeds, adaptive, DRIFT_INTERVALS, 10, window
                )
    wall["drift_episodes_s"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    drift_records = []
    for c in dynamic_cells:
        variants = {
            adaptive: _drift_variant_record(
                dpreps[c],
                drift_runs[(c, adaptive)],
                seeds,
                DRIFT_INTERVALS,
                DRIFT_SHIFT_START,
            )
            for adaptive in (True, False)
        }
        drift_records.append(
            _drift_cell_record(
                c, dpreps[c], variants[True], variants[False],
                DRIFT_INTERVALS, DRIFT_SHIFT_START,
            )
        )
    wall["drift_score_s"] = time.perf_counter() - t0

    all_cells = (
        list(cells) + list(offload_cells) + list(cotenant_cells)
        + list(fault_cells)
    )
    return {
        "schema_version": 6,
        "regenerate": regenerate,
        "quick": quick,
        "engine": engine,
        "iters": iters,
        "seeds": list(seeds),
        "wall_clock_s": {k: round(v, 4) for k, v in wall.items()},
        "grid": {
            "devices": sorted({c.device for c in all_cells}),
            "models": sorted({c.model for c in all_cells}),
            "workloads": sorted({c.workload for c in all_cells}),
            "regimes": sorted({c.regime for c in cells}),
            "offload_regimes": sorted({c.regime for c in offload_cells}),
            "cotenant_regimes": sorted({c.regime for c in cotenant_cells}),
            "fault_regimes": sorted({c.regime for c in fault_cells}),
        },
        "cells": records,
        "drift_cells": drift_records,
        "offload_cells": offload_records,
        "cotenant_cells": cotenant_records,
        "fault_cells": fault_records,
        "summary": _summarize(
            records, drift_records, offload_records, cotenant_records,
            fault_records,
        ),
    }


def _summarize(
    records: List[dict],
    drift_records: List[dict] = (),
    offload_records: List[dict] = (),
    cotenant_records: List[dict] = (),
    fault_records: List[dict] = (),
) -> dict:
    single = [
        r["coral"]["score"] for r in records if REGIMES[r["regime"]].single_target
    ]
    dual = [r for r in records if REGIMES[r["regime"]].dual_constraint]
    all_scores = [r["coral"]["score"] for r in records]
    summary = {
        "n_cells": len(records),
        "mean_coral_score": sum(all_scores) / max(len(all_scores), 1),
        # null, not NaN, when the grid has no single-target regime — bare
        # NaN tokens are not valid JSON for strict artifact consumers.
        "min_single_target_score": min(single) if single else None,
        "dual_power_violations": int(
            sum(r["coral"]["power_violations"] for r in dual)
        ),
        # τ-floor boundary misses (power stayed within budget) — reported
        # separately because the acceptance gate is the power cap.
        "dual_tau_miss_cells": int(
            sum(
                r["coral"]["violation_rate"] > 0
                and r["coral"]["power_violations"] == 0
                for r in dual
            )
        ),
        "n_drift_cells": len(drift_records),
        "min_drift_adaptive_score": (
            min(r["adaptive"]["final_score"] for r in drift_records)
            if drift_records
            else None
        ),
        "max_drift_static_score": (
            max(r["static"]["final_score"] for r in drift_records)
            if drift_records
            else None
        ),
        "min_drift_separation": (
            min(
                r["adaptive"]["final_score"] - r["static"]["final_score"]
                for r in drift_records
            )
            if drift_records
            else None
        ),
        "n_offload_cells": len(offload_records),
        "min_offload_score": (
            min(r["coral"]["score"] for r in offload_records)
            if offload_records
            else None
        ),
        "offload_power_violations": int(
            sum(r["coral"]["power_violations"] for r in offload_records)
        ),
        # Count of (preset | no-offload-ablation) entries that were truly
        # feasible — the tentpole claim is that this stays 0: only the
        # joint route-fraction × DVFS search can serve the offered demand
        # within budget.
        "offload_feasible_baselines": int(
            sum(
                not (b["violates_tau"] or b["violates_power"])
                for r in offload_records
                for b in (
                    r["baselines"]["max_power"],
                    r["baselines"]["default"],
                    r["baselines"]["min_power"],
                    r["offload"]["no_offload"],
                )
            )
        ),
        "n_cotenant_cells": len(cotenant_records),
        "min_cotenant_score": (
            min(r["coral"]["score"] for r in cotenant_records)
            if cotenant_records
            else None
        ),
        "cotenant_power_violations": int(
            sum(r["coral"]["power_violations"] for r in cotenant_records)
        ),
        # Count of (preset | per-tenant-greedy) entries that were truly
        # feasible — the tentpole claim is that this stays 0: only the
        # joint slots × shared-DVFS negotiation meets every tenant's
        # floor within the shared rail budget.
        "cotenant_feasible_baselines": int(
            sum(
                not (b["violates_tau"] or b["violates_power"])
                for r in cotenant_records
                for b in (
                    r["baselines"]["max_power"],
                    r["baselines"]["default"],
                    r["baselines"]["min_power"],
                    r["cotenant"]["greedy"],
                )
            )
        ),
        "n_fault_cells": len(fault_records),
        "min_fault_hardened_score": (
            min(r["hardened"]["score"] for r in fault_records)
            if fault_records
            else None
        ),
        "fault_power_violations": int(
            sum(r["hardened"]["power_violations"] for r in fault_records)
        ),
        # Count of non-hardened ablation (cell, seed) runs that ended
        # feasible — the tentpole claim is that this stays 0: under the
        # injected faults, only the hardened ingest/actuation path ends
        # on a truly-feasible operating point.
        "fault_feasible_ablations": int(
            sum(
                r["ablation"]["n_runs"] - r["ablation"]["failed_runs"]
                for r in fault_records
            )
        ),
    }
    return summary


def score_floors(record: dict) -> Dict[Tuple[str, str, str, str], float]:
    """(device, model, workload, regime) → recorded floor, for the
    bench-regression gate. Dynamic cells contribute their drift-adaptive
    floor and offload cells their CORAL floor — cell keys are unique
    across the arrays because a regime name belongs to exactly one
    family (stationary, dynamic, or offload)."""
    floors = {
        (c["device"], c["model"], c["workload"], c["regime"]): c["coral"][
            "score_floor"
        ]
        for c in record["cells"]
    }
    for c in record.get("drift_cells", ()):
        key = (c["device"], c["model"], c["workload"], c["regime"])
        floors[key] = c["adaptive"]["score_floor"]
    for c in record.get("offload_cells", ()):
        key = (c["device"], c["model"], c["workload"], c["regime"])
        floors[key] = c["coral"]["score_floor"]
    for c in record.get("cotenant_cells", ()):
        key = (c["device"], c["model"], c["workload"], c["regime"])
        floors[key] = c["coral"]["score_floor"]
    for c in record.get("fault_cells", ()):
        key = (c["device"], c["model"], c["workload"], c["regime"])
        floors[key] = c["hardened"]["score_floor"]
    return floors
