"""Run the scenario matrix: CORAL + all baselines through every cell.

Each cell is scored on three axes (the paper's Table/Fig. §IV summary):

  normalized score — performance of the chosen config, noise-free, as a
      fraction of the cell's exhaustive-search ORACLE under the regime's
      own objective (max_throughput: τ ratio; τ-targeted regimes:
      efficiency τ/p ratio among what the oracle ranks);
  violation rate  — fraction of runs whose chosen config truly breaks a
      constraint (evaluated on the noise-free twin, so a lucky noise
      sample can't hide a real power-budget bust);
  exploration cost — measurements until the first feasible observation
      (ORACLE pays the full grid; CORAL its iteration budget).

All optimizer selections run against the *noisy* device (the 1-second
tegrastats-style samples CORAL actually sees); all scoring runs against
the noise-free twin.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.baselines import Outcome, alert, alert_online, oracle, preset
from repro.core.evaluate import (
    RegimeTargets,
    measurements_to_feasible,
    run_regime,
)
from repro.experiments.scenarios import (
    REGIMES,
    Cell,
    cell_simulator,
    enumerate_cells,
    resolve_targets,
)

# Per-baseline device seeds: every baseline sees its own noise stream,
# deterministically, so matrix records are reproducible bit-for-bit.
_BASELINE_SEEDS = {"alert": 101, "alert_online": 102, "max_power": 103, "default": 104}

# Regression-gate margin: the recorded floor sits this far under the
# worst seed, absorbing cross-platform float jitter without letting a
# real regression through.
SCORE_FLOOR_MARGIN = 0.05


def _score(tau: float, power: float, regime_name: str, oracle_ref: Outcome) -> float:
    """Normalized-vs-oracle performance under the regime's objective."""
    if oracle_ref.config is None:
        return 0.0
    if REGIMES[regime_name].mode == "throughput":
        return tau / max(oracle_ref.tau, 1e-9)
    eff = tau / max(power, 1e-9)
    return eff / max(oracle_ref.efficiency, 1e-9)


def _violations(
    tau: float, power: float, targets: RegimeTargets
) -> Tuple[bool, bool]:
    """(τ-target miss, power-budget bust) of a chosen config, noise-free."""
    tau_miss = targets.mode != "throughput" and tau < targets.tau_target * (1 - 1e-9)
    power_bust = targets.capped and power > targets.p_budget * (1 + 1e-9)
    return tau_miss, power_bust


def run_cell(
    cell: Cell,
    iters: int = 10,
    seeds: Sequence[int] = (0, 1, 2),
    window: int = 10,
) -> dict:
    """One cell → one JSON-ready record (see schema.MATRIX_SCHEMA)."""
    sim0 = cell_simulator(cell, noise=0.0)
    space = sim0.space
    targets = resolve_targets(cell, sim0)
    oracle_ref = oracle(space, sim0, targets.tau_target, targets.p_budget)

    # ---- CORAL, one run per seed against the noisy device -------------
    scores: List[float] = []
    tau_misses: List[bool] = []
    power_busts: List[bool] = []
    m2f: List[Optional[int]] = []
    best: Optional[Tuple[float, float, float, tuple]] = None
    for seed in seeds:
        dev = cell_simulator(cell, seed=seed)
        out, tr = run_regime(space, dev, targets, iters=iters, window=window, seed=seed)
        if out.config is None:
            # found nothing: a feasibility failure (τ miss), not a power
            # bust — no config ever drew power over the cap. Same mapping
            # as _outcome_record below for config-less baselines.
            scores.append(0.0)
            tau_misses.append(True)
            power_busts.append(False)
            m2f.append(None)
            continue
        tau, power = sim0.exact(out.config)
        miss, bust = _violations(tau, power, targets)
        # A pick that truly breaks the regime's constraints earns no
        # credit — an infeasible low-clock config can beat the feasible
        # optimum on raw τ/p, and crediting it would let feasibility
        # regressions read as score improvements.
        s = 0.0 if (miss or bust) else _score(tau, power, cell.regime, oracle_ref)
        scores.append(s)
        tau_misses.append(miss)
        power_busts.append(bust)
        m2f.append(measurements_to_feasible(tr, targets))
        if not (miss or bust) and (best is None or s > best[0]):
            best = (s, tau, power, tuple(out.config))
    n = len(seeds)
    reached = [v for v in m2f if v is not None]
    coral = {
        "score": sum(scores) / n,
        "score_min": min(scores),
        "score_floor": round(max(0.0, min(scores) - SCORE_FLOOR_MARGIN), 4),
        "violation_rate": sum(a or b for a, b in zip(tau_misses, power_busts)) / n,
        "power_violations": int(sum(power_busts)),
        "found_feasible_rate": len(reached) / n,
        "measurements_to_feasible": (
            sum(reached) / len(reached) if reached else None
        ),
        "measurements": iters,
        "tau": best[1] if best else 0.0,
        "power": best[2] if best else 0.0,
        "config": list(best[3]) if best else None,
    }

    # ---- baselines, one run each --------------------------------------
    def _outcome_record(out: Outcome) -> dict:
        if out.config is None:
            return {
                "score": None,
                "tau": 0.0,
                "power": 0.0,
                "violates_tau": True,
                "violates_power": False,
                "measurements": out.measurements,
            }
        tau, power = sim0.exact(out.config)
        miss, bust = _violations(tau, power, targets)
        # Baselines keep their raw normalized score next to the violation
        # flags — the paper's presentation (ALERT achieves high τ *while*
        # busting the cap) needs both visible. Only CORAL's scores feed
        # the gates, and those zero out on violation above.
        return {
            "score": _score(tau, power, cell.regime, oracle_ref),
            "tau": tau,
            "power": power,
            "violates_tau": bool(miss),
            "violates_power": bool(bust),
            "measurements": out.measurements,
        }

    # ALERT prioritizes throughput (its published objective) — in capped
    # regimes the budget is handed over but, faithfully, soft.
    baselines = {
        "alert": _outcome_record(
            alert(
                space,
                cell_simulator(cell, seed=_BASELINE_SEEDS["alert"]),
                targets.tau_target,
                targets.p_budget,
            )
        ),
        "alert_online": _outcome_record(
            alert_online(
                space,
                cell_simulator(cell, seed=_BASELINE_SEEDS["alert_online"]),
                targets.tau_target,
                targets.p_budget,
                iters=iters,
                seed=_BASELINE_SEEDS["alert_online"],
            )
        ),
        "max_power": _outcome_record(
            preset(
                space,
                cell_simulator(cell, seed=_BASELINE_SEEDS["max_power"]),
                "max_power",
            )
        ),
        "default": _outcome_record(
            preset(
                space,
                cell_simulator(cell, seed=_BASELINE_SEEDS["default"]),
                "default",
            )
        ),
    }

    return {
        "device": cell.device,
        "model": cell.model,
        "workload": cell.workload,
        "regime": cell.regime,
        "mode": targets.mode,
        "tau_target": targets.tau_target,
        "p_budget": targets.p_budget if targets.capped else None,
        "space_size": space.size(),
        "oracle": {
            "config": list(oracle_ref.config) if oracle_ref.config else None,
            "tau": oracle_ref.tau,
            "power": oracle_ref.power,
            "measurements": oracle_ref.measurements,
        },
        "coral": coral,
        "baselines": baselines,
    }


def run_matrix(
    cells: Optional[Sequence[Cell]] = None,
    iters: int = 10,
    seeds: Sequence[int] = (0, 1, 2),
    regenerate: str = "PYTHONPATH=src python -m benchmarks.matrix_bench",
    quick: bool = False,
) -> dict:
    """Run every cell and assemble the schema'd BENCH_matrix record."""
    if cells is None:
        cells = enumerate_cells()
    records = [run_cell(c, iters=iters, seeds=seeds) for c in cells]
    return {
        "schema_version": 1,
        "regenerate": regenerate,
        "quick": quick,
        "iters": iters,
        "seeds": list(seeds),
        "grid": {
            "devices": sorted({c.device for c in cells}),
            "models": sorted({c.model for c in cells}),
            "workloads": sorted({c.workload for c in cells}),
            "regimes": sorted({c.regime for c in cells}),
        },
        "cells": records,
        "summary": _summarize(records),
    }


def _summarize(records: List[dict]) -> dict:
    single = [
        r["coral"]["score"] for r in records if REGIMES[r["regime"]].single_target
    ]
    dual = [r for r in records if REGIMES[r["regime"]].dual_constraint]
    all_scores = [r["coral"]["score"] for r in records]
    return {
        "n_cells": len(records),
        "mean_coral_score": sum(all_scores) / max(len(all_scores), 1),
        # null, not NaN, when the grid has no single-target regime — bare
        # NaN tokens are not valid JSON for strict artifact consumers.
        "min_single_target_score": min(single) if single else None,
        "dual_power_violations": int(
            sum(r["coral"]["power_violations"] for r in dual)
        ),
        # τ-floor boundary misses (power stayed within budget) — reported
        # separately because the acceptance gate is the power cap.
        "dual_tau_miss_cells": int(
            sum(
                r["coral"]["violation_rate"] > 0
                and r["coral"]["power_violations"] == 0
                for r in dual
            )
        ),
    }


def score_floors(record: dict) -> Dict[Tuple[str, str, str, str], float]:
    """(device, model, workload, regime) → recorded floor, for the
    bench-regression gate."""
    return {
        (c["device"], c["model"], c["workload"], c["regime"]): c["coral"][
            "score_floor"
        ]
        for c in record["cells"]
    }
