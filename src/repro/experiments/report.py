"""Markdown summary of a BENCH_matrix record, mirroring the paper's
table layout: one table per constraint regime, rows = device × model ×
workload, columns = CORAL vs every baseline — plus the fleet-convergence
figure for BENCH_fleet records (matplotlib optional)."""
from __future__ import annotations

from typing import List, Optional


def _fmt_score(s) -> str:
    return "—" if s is None else f"{s:.2f}"


def _fmt_m2f(v) -> str:
    return "—" if v is None else f"{v:.1f}"


def _viol(rec: dict) -> str:
    marks = []
    if rec.get("violates_tau"):
        marks.append("τ!")
    if rec.get("violates_power"):
        marks.append("P!")
    return "".join(marks)


def markdown_report(record: dict) -> str:
    """Render a BENCH_matrix record (any schema version — drift, offload
    and cotenant sections appear only when their cell arrays are
    non-empty) as the committed BENCH_matrix.md summary."""
    lines: List[str] = ["# Scenario matrix", ""]
    s = record["summary"]
    lines.append(
        f"{s['n_cells']} cells · iters={record['iters']} · "
        f"seeds={record['seeds']} · quick={record['quick']}"
    )
    lines.append("")
    lines.append(
        f"- mean CORAL normalized score: **{s['mean_coral_score']:.3f}**"
    )
    worst_single = s["min_single_target_score"]
    lines.append(
        "- worst single-target cell: "
        + (
            f"**{worst_single:.3f}** (gate ≥ 0.9)"
            if worst_single is not None
            else "— (no single-target regime in this grid)"
        )
    )
    lines.append(
        f"- dual-constraint power violations: "
        f"**{s['dual_power_violations']}** (gate = 0)"
    )
    if s.get("n_drift_cells"):
        lines.append(
            f"- drift cells: **{s['n_drift_cells']}** · worst adaptive "
            f"post-shift score **{s['min_drift_adaptive_score']:.3f}** "
            f"(gate ≥ 0.85) · best static ablation "
            f"**{s['max_drift_static_score']:.3f}** (gate ≤ 0.5)"
        )
    if s.get("n_offload_cells"):
        lines.append(
            f"- offload cells: **{s['n_offload_cells']}** · worst CORAL "
            f"joint-space score **{s['min_offload_score']:.3f}** "
            f"(gate ≥ 0.85) · power violations "
            f"**{s['offload_power_violations']}** (gate = 0) · feasible "
            f"presets/ablations **{s['offload_feasible_baselines']}** "
            f"(gate = 0)"
        )
    if s.get("n_cotenant_cells"):
        lines.append(
            f"- cotenant cells: **{s['n_cotenant_cells']}** · worst CORAL "
            f"joint-space score **{s['min_cotenant_score']:.3f}** "
            f"(gate ≥ 0.85) · shared-rail violations "
            f"**{s['cotenant_power_violations']}** (gate = 0) · feasible "
            f"presets/greedy **{s['cotenant_feasible_baselines']}** "
            f"(gate = 0)"
        )
    lines.append("")

    for regime in record["grid"]["regimes"]:
        cells = [c for c in record["cells"] if c["regime"] == regime]
        if not cells:
            continue
        head = cells[0]
        budget = "∞" if head["p_budget"] is None else "slack-capped"
        lines.append(f"## Regime `{regime}` (mode={head['mode']}, budget {budget})")
        lines.append("")
        lines.append(
            "| device | model | workload | τ* | P-cap | CORAL | viol | "
            "m→feas | ALERT | ALERT-On | max_power | default | oracle meas |"
        )
        lines.append("|" + "---|" * 13)
        for c in cells:
            b = c["baselines"]
            cap = "—" if c["p_budget"] is None else f"{c['p_budget']:.2f}W"
            coral = c["coral"]
            viol = (
                f"{coral['violation_rate']:.0%}"
                if coral["violation_rate"]
                else "0"
            )

            def col(name: str) -> str:
                r = b[name]
                mark = _viol(r)
                return f"{_fmt_score(r['score'])}{' ' + mark if mark else ''}"

            lines.append(
                f"| {c['device']} | {c['model']} | {c['workload']} "
                f"| {c['tau_target']:.2f} | {cap} "
                f"| **{coral['score']:.2f}** | {viol} "
                f"| {_fmt_m2f(coral['measurements_to_feasible'])} "
                f"| {col('alert')} | {col('alert_online')} "
                f"| {col('max_power')} | {col('default')} "
                f"| {c['oracle']['measurements']} |"
            )
        lines.append("")
    offload_cells = record.get("offload_cells", [])
    if offload_cells:
        lines.append("## Offload regimes (edge↔pod joint search)")
        lines.append("")
        lines.append(
            "| device | model | network | λ | edge-max | τ* | P-cap | "
            "CORAL | viol | no-offload | max_power | min_power |"
        )
        lines.append("|" + "---|" * 12)
        for c in offload_cells:
            o = c["offload"]
            coral = c["coral"]
            viol = (
                f"{coral['violation_rate']:.0%}"
                if coral["violation_rate"]
                else "0"
            )
            no = o["no_offload"]
            no_mark = _viol(no) or "ok"
            mp = c["baselines"]["max_power"]
            mn = c["baselines"]["min_power"]
            lines.append(
                f"| {c['device']} | {c['model']} | {o['network']} "
                f"| {o['demand']:.1f} | {o['edge_only_max']:.1f} "
                f"| {c['tau_target']:.1f} | {c['p_budget']:.2f}W "
                f"| **{coral['score']:.2f}** | {viol} "
                f"| {no_mark} | {_viol(mp) or 'ok'} | {_viol(mn) or 'ok'} |"
            )
        lines.append("")
        lines.append(
            "Offload cells offer demand λ = 2× the best the un-offloaded "
            "edge can serve, so every φ=0 row misses the SLO (`τ!` under "
            "`no-offload`) and the all-hi preset busts the edge power "
            "budget (`P!`) — only the joint route-fraction × concurrency "
            "× two-sided DVFS search is feasible. CORAL scores are "
            "efficiency ratios vs the batched joint-space oracle."
        )
        lines.append("")
    cotenant_cells = record.get("cotenant_cells", [])
    if cotenant_cells:
        lines.append("## Cotenant regimes (per-tenant slots × shared DVFS)")
        lines.append("")
        lines.append(
            "| device | regime | tenants | floors | P-cap | CORAL | viol | "
            "greedy | max_power | default | min_power |"
        )
        lines.append("|" + "---|" * 11)
        for c in cotenant_cells:
            ct = c["cotenant"]
            coral = c["coral"]
            viol = (
                f"{coral['violation_rate']:.0%}"
                if coral["violation_rate"]
                else "0"
            )
            tenants = "+".join(t["model"] for t in ct["tenants"])
            floors = "+".join(f"{t['floor']:.1f}" for t in ct["tenants"])
            greedy_mark = _viol(ct["greedy"]) or "ok"
            mp = c["baselines"]["max_power"]
            df = c["baselines"]["default"]
            mn = c["baselines"]["min_power"]
            lines.append(
                f"| {c['device']} | {c['regime']} | {tenants} "
                f"| {floors} | {c['p_budget']:.2f}W "
                f"| **{coral['score']:.2f}** | {viol} "
                f"| {greedy_mark} | {_viol(mp) or 'ok'} "
                f"| {_viol(df) or 'ok'} | {_viol(mn) or 'ok'} |"
            )
        lines.append("")
        lines.append(
            "Cotenant cells serve two tenants on one rail: each tenant's "
            "τ floor is a fraction of its *solo* maximum, and the shared "
            "power cap is slack over the joint minimum — so per-tenant "
            "greedy planning (each tenant optimizing as if it owned the "
            "rail, combined elementwise) misses a floor or busts the cap "
            "(`τ!`/`P!` under `greedy`). Only the joint per-tenant-slots × "
            "shared-DVFS search is feasible; CORAL scores are efficiency "
            "ratios vs the batched joint-space oracle on the scalarized "
            "(min-headroom, rail-power) channel."
        )
        lines.append("")
    drift_cells = record.get("drift_cells", [])
    if drift_cells:
        lines.append("## Dynamic regimes (drift-adaptive vs static CORAL)")
        lines.append("")
        lines.append(
            "| device | model | regime | shift | adaptive | static | "
            "recovery | transient viol | resets |"
        )
        lines.append("|" + "---|" * 9)
        for c in drift_cells:
            a, st = c["adaptive"], c["static"]
            rec = (
                f"{a['recovery_intervals']:.1f}"
                if a["recovery_intervals"] is not None
                else "—"
            )
            lines.append(
                f"| {c['device']} | {c['model']} | {c['regime']} "
                f"| t={c['drift']['shift_start']} "
                f"| **{a['final_score']:.2f}** | {st['final_score']:.2f} "
                f"| {rec} | {a['transient_violation_rate']:.0%} "
                f"| {a['resets']:.1f} |"
            )
        lines.append("")
        lines.append(
            "Drift scores compare each variant's end-of-run choice against "
            "the *post-shift* oracle (exhaustive search on the fully "
            "shifted landscape); `recovery` is the mean number of control "
            "intervals from the shift until the loop holds a ≥0.85-scoring "
            "config for the rest of the run."
        )
        lines.append("")
    lines.append(
        "Scores are normalized vs the cell's exhaustive-search oracle "
        "(max_throughput: τ ratio; targeted regimes: efficiency ratio); "
        "`τ!`/`P!` mark true constraint violations on the noise-free twin; "
        "`m→feas` is the mean number of measurements until the first "
        "feasible observation."
    )
    lines.append("")
    return "\n".join(lines)


def fleet_convergence_figure(record: dict, path: str) -> Optional[str]:
    """Fraction-of-twins-feasible vs measurement count, cold vs warm, one
    panel per device family (plus the all-families panel) from a
    BENCH_fleet record. Returns the written path, or None when matplotlib
    is unavailable (the figure is a nicety; the JSON record is the
    artifact of record)."""
    try:
        import matplotlib

        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        return None

    res = record["results"]
    curves = res["convergence"]
    names = ["all"] + [f for f in res["families"] if f in curves]
    names = list(dict.fromkeys(n for n in names if n in curves))
    fig, axes = plt.subplots(
        1, len(names), figsize=(3.4 * len(names), 3.2), sharey=True
    )
    if len(names) == 1:
        axes = [axes]
    for ax, name in zip(axes, names):
        c = curves[name]
        xs = range(1, len(c["cold"]) + 1)
        ax.plot(xs, c["cold"], label="cold", color="tab:blue")
        if c["warm"]:
            ax.plot(
                range(1, len(c["warm"]) + 1),
                c["warm"],
                label="warm",
                color="tab:orange",
            )
        ax.set_title(name, fontsize=9)
        ax.set_xlabel("measurements")
        ax.set_ylim(0, 1.02)
        ax.grid(alpha=0.3)
    axes[0].set_ylabel("fraction of twins feasible")
    axes[0].legend(loc="lower right", fontsize=8)
    gain = res["warm_gain"]
    gain_txt = "—" if gain is None else f"{gain:.2f}×"
    fig.suptitle(
        f"Fleet convergence — {res['n_twins']} twins, warm gain {gain_txt}",
        fontsize=10,
    )
    fig.tight_layout()
    fig.savefig(path, dpi=150)
    plt.close(fig)
    return path
