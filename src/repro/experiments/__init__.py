"""Scenario-matrix evaluation harness (paper §IV grid analogue).

Declaratively enumerates device profile × model config × workload trace ×
constraint regime cells, runs CORAL and every baseline through each, and
scores cells as normalized-vs-oracle performance, constraint-violation
rate and exploration cost. See EXPERIMENTS.md §Scenario matrix.
"""
from repro.experiments.matrix import (  # noqa: F401
    COTENANT_CORAL_GATE,
    COTENANT_ITERS,
    DRIFT_ADAPTIVE_GATE,
    DRIFT_SEPARATION,
    DRIFT_STATIC_CEILING,
    FAULT_CORAL_GATE,
    FAULT_ITERS,
    OFFLOAD_CORAL_GATE,
    OFFLOAD_ITERS,
    run_cell,
    run_cotenant_cell,
    run_drift_cell,
    run_fault_cell,
    run_matrix,
    run_offload_cell,
    run_static_cell,
)
from repro.experiments.fleet import (  # noqa: F401
    FLEET_ITERS,
    FLEET_WINDOW,
    FleetTwin,
    build_fleet,
    build_twin,
    ladder_banned_rows,
    match_neighbor,
    run_fleet,
    warm_context,
)
from repro.experiments.report import (  # noqa: F401
    fleet_convergence_figure,
    markdown_report,
)
from repro.experiments.scenarios import (  # noqa: F401
    COTENANT_REGIMES,
    DRIFT_INTERVALS,
    DRIFT_SHIFT_START,
    DRIFTS,
    FAULT_INTERVALS,
    FAULT_REGIMES,
    FAULTS,
    MATRIX_COTENANT_CELLS,
    MATRIX_DEVICES,
    MATRIX_DRIFT_CELLS,
    MATRIX_FAULT_CELLS,
    MATRIX_MODELS,
    MATRIX_OFFLOAD_CELLS,
    MATRIX_REGIMES,
    MATRIX_WORKLOADS,
    OFFLOAD_REGIMES,
    QUICK_COTENANT_CELLS,
    QUICK_DRIFT_CELLS,
    QUICK_FAULT_CELLS,
    QUICK_OFFLOAD_CELLS,
    REGIMES,
    WORKLOADS,
    Cell,
    CotenantRegime,
    FaultRegime,
    OffloadRegime,
    Regime,
    Workload,
    cell_simulator,
    cotenant_cell_simulator,
    drifting_cell_simulator,
    enumerate_cells,
    fault_cell_simulator,
    fault_tables,
    offload_cell_simulator,
    resolve_cotenant_targets,
    resolve_fault_targets,
    resolve_offload_targets,
    resolve_targets,
    tenant_names,
)
from repro.experiments.schema import (  # noqa: F401
    FLEET_SCHEMA,
    MATRIX_SCHEMA,
    validate_fleet_record,
    validate_matrix_record,
)
