"""JSON schemas for BENCH_matrix.json / BENCH_fleet.json and a
dependency-free validator.

``MATRIX_SCHEMA`` and ``FLEET_SCHEMA`` are standard JSON Schema (draft
2020-12 subset). When the ``jsonschema`` package is importable it is
used directly; otherwise the validators fall back to a built-in
structural checker covering the same constraints (type, required, enum,
bounds) — CI and air-gapped containers validate either way.

The version history of every BENCH_*.json artifact (what each schema
bump added, which blocks are deterministic vs machine-dependent, and
how ``benchmarks/check_regression.py`` gates each record) is documented
in docs/BENCH_SCHEMAS.md.
"""
from __future__ import annotations

from typing import Any, List

_OUTCOME = {
    "type": "object",
    "required": [
        "score",
        "tau",
        "power",
        "violates_tau",
        "violates_power",
        "measurements",
    ],
    "properties": {
        "score": {"type": ["number", "null"], "minimum": 0},
        "tau": {"type": "number", "minimum": 0},
        "power": {"type": "number", "minimum": 0},
        "violates_tau": {"type": "boolean"},
        "violates_power": {"type": "boolean"},
        "measurements": {"type": "integer", "minimum": 0},
    },
}

_CELL = {
    "type": "object",
    "required": [
        "device",
        "model",
        "workload",
        "regime",
        "mode",
        "tau_target",
        "p_budget",
        "space_size",
        "oracle",
        "coral",
        "baselines",
    ],
    "properties": {
        "device": {"type": "string"},
        "model": {"type": "string"},
        "workload": {"type": "string"},
        "regime": {"type": "string"},
        "mode": {"type": "string", "enum": ["dual", "throughput"]},
        "tau_target": {"type": "number", "minimum": 0},
        "p_budget": {"type": ["number", "null"]},
        "space_size": {"type": "integer", "minimum": 1},
        "oracle": {
            "type": "object",
            "required": ["config", "tau", "power", "measurements"],
            "properties": {
                "config": {
                    "type": ["array", "null"],
                    "items": {"type": "number"},
                },
                "tau": {"type": "number", "minimum": 0},
                "power": {"type": "number", "minimum": 0},
                "measurements": {"type": "integer", "minimum": 0},
            },
        },
        "coral": {
            "type": "object",
            "required": [
                "score",
                "score_min",
                "score_floor",
                "violation_rate",
                "power_violations",
                "found_feasible_rate",
                "measurements_to_feasible",
                "measurements",
                "tau",
                "power",
                "config",
            ],
            "properties": {
                "score": {"type": "number", "minimum": 0},
                "score_min": {"type": "number", "minimum": 0},
                "score_floor": {"type": "number", "minimum": 0},
                "violation_rate": {
                    "type": "number",
                    "minimum": 0,
                    "maximum": 1,
                },
                "power_violations": {"type": "integer", "minimum": 0},
                "found_feasible_rate": {
                    "type": "number",
                    "minimum": 0,
                    "maximum": 1,
                },
                "measurements_to_feasible": {
                    "type": ["number", "null"],
                    "minimum": 0,
                },
                "measurements": {"type": "integer", "minimum": 0},
                "tau": {"type": "number", "minimum": 0},
                "power": {"type": "number", "minimum": 0},
                "config": {
                    "type": ["array", "null"],
                    "items": {"type": "number"},
                },
            },
        },
        "baselines": {
            "type": "object",
            "required": ["alert", "alert_online", "max_power", "default"],
            "additionalProperties": _OUTCOME,
        },
    },
}

# Offload cells (schema v4) extend the static-cell shape: the baselines
# block additionally records the min_power preset (every preset must be
# visibly infeasible on a calibrated offload cell), and the ``offload``
# block carries the network/demand provenance plus the no-offload
# ablation — the best φ=0 row of the joint grid, with the violation
# flags that show why routing is required.
_OFFLOAD_CELL = {
    "type": "object",
    "required": _CELL["required"] + ["offload"],
    "properties": {
        **_CELL["properties"],
        "baselines": {
            "type": "object",
            "required": [
                "alert",
                "alert_online",
                "max_power",
                "default",
                "min_power",
            ],
            "additionalProperties": _OUTCOME,
        },
        "offload": {
            "type": "object",
            "required": [
                "network",
                "trace",
                "demand",
                "demand_factor",
                "slo_frac",
                "p_slack",
                "edge_only_max",
                "no_offload",
            ],
            "properties": {
                "network": {"type": "string"},
                "trace": {"type": "string"},
                "demand": {"type": "number", "minimum": 0},
                "demand_factor": {"type": "number", "minimum": 1},
                "slo_frac": {"type": "number", "minimum": 0, "maximum": 1},
                "p_slack": {"type": "number", "minimum": 1},
                "edge_only_max": {"type": "number", "minimum": 0},
                "no_offload": {
                    "type": "object",
                    "required": [
                        "feasible_rows",
                        "config",
                        "tau",
                        "power",
                        "violates_tau",
                        "violates_power",
                    ],
                    "properties": {
                        "feasible_rows": {"type": "integer", "minimum": 0},
                        "config": {
                            "type": ["array", "null"],
                            "items": {"type": "number"},
                        },
                        "tau": {"type": "number", "minimum": 0},
                        "power": {"type": "number", "minimum": 0},
                        "violates_tau": {"type": "boolean"},
                        "violates_power": {"type": "boolean"},
                    },
                },
            },
        },
    },
}

# Cotenant cells (schema v5) reuse the static-cell shape on the
# scalarized (joint headroom, rail power) channel — tau_target is the
# constant 1.0, the min_power preset is recorded (every preset must be
# visibly infeasible on a calibrated cell) — and add the ``cotenant``
# block: per-tenant provenance (model, workload, floor, solo max) plus
# the per-tenant-greedy ablation, whose combined config must miss a
# floor or bust the shared cap.
_COTENANT_CELL = {
    "type": "object",
    "required": _CELL["required"] + ["cotenant"],
    "properties": {
        **_CELL["properties"],
        "baselines": {
            "type": "object",
            "required": [
                "alert",
                "alert_online",
                "max_power",
                "default",
                "min_power",
            ],
            "additionalProperties": _OUTCOME,
        },
        "cotenant": {
            "type": "object",
            "required": ["n_tenants", "p_slack", "tenants", "greedy"],
            "properties": {
                "n_tenants": {"type": "integer", "minimum": 2},
                "p_slack": {"type": "number", "minimum": 1},
                "tenants": {
                    "type": "array",
                    "minItems": 2,
                    "items": {
                        "type": "object",
                        "required": [
                            "model",
                            "workload",
                            "tau_frac",
                            "floor",
                            "solo_max",
                        ],
                        "properties": {
                            "model": {"type": "string"},
                            "workload": {"type": "string"},
                            "tau_frac": {
                                "type": "number",
                                "minimum": 0,
                                "maximum": 1,
                            },
                            "floor": {"type": "number", "minimum": 0},
                            "solo_max": {"type": "number", "minimum": 0},
                        },
                    },
                },
                "greedy": {
                    "type": "object",
                    "required": [
                        "config",
                        "headroom",
                        "power",
                        "violates_tau",
                        "violates_power",
                    ],
                    "properties": {
                        "config": {
                            "type": ["array", "null"],
                            "items": {"type": "number"},
                        },
                        "headroom": {"type": "number", "minimum": 0},
                        "power": {"type": "number", "minimum": 0},
                        "violates_tau": {"type": "boolean"},
                        "violates_power": {"type": "boolean"},
                    },
                },
            },
        },
    },
}

_DRIFT_VARIANT = {
    "type": "object",
    "required": [
        "final_score",
        "final_score_min",
        "final_score_max",
        "score_floor",
        "recovered_rate",
        "recovery_intervals",
        "transient_violation_rate",
        "resets",
    ],
    "properties": {
        "final_score": {"type": "number", "minimum": 0},
        "final_score_min": {"type": "number", "minimum": 0},
        "final_score_max": {"type": "number", "minimum": 0},
        "score_floor": {"type": "number", "minimum": 0},
        "recovered_rate": {"type": "number", "minimum": 0, "maximum": 1},
        "recovery_intervals": {"type": ["number", "null"], "minimum": 0},
        "transient_violation_rate": {
            "type": "number",
            "minimum": 0,
            "maximum": 1,
        },
        "resets": {"type": "number", "minimum": 0},
    },
}

_DRIFT_CELL = {
    "type": "object",
    "required": [
        "device",
        "model",
        "workload",
        "regime",
        "mode",
        "tau_target",
        "p_budget",
        "p_budget_post",
        "space_size",
        "drift",
        "post_oracle",
        "adaptive",
        "static",
    ],
    "properties": {
        "device": {"type": "string"},
        "model": {"type": "string"},
        "workload": {"type": "string"},
        "regime": {"type": "string"},
        "mode": {"type": "string", "enum": ["dual", "throughput"]},
        "tau_target": {"type": "number", "minimum": 0},
        "p_budget": {"type": ["number", "null"]},
        "p_budget_post": {"type": ["number", "null"]},
        "space_size": {"type": "integer", "minimum": 1},
        "drift": {
            "type": "object",
            "required": ["schedule", "shift_start", "shift_end", "intervals"],
            "properties": {
                "schedule": {"type": "string"},
                "shift_start": {"type": "integer", "minimum": 0},
                "shift_end": {"type": "integer", "minimum": 0},
                "intervals": {"type": "integer", "minimum": 1},
            },
        },
        "post_oracle": {
            "type": "object",
            "required": ["config", "tau", "power"],
            "properties": {
                "config": {
                    "type": ["array", "null"],
                    "items": {"type": "number"},
                },
                "tau": {"type": "number", "minimum": 0},
                "power": {"type": "number", "minimum": 0},
            },
        },
        "adaptive": _DRIFT_VARIANT,
        "static": _DRIFT_VARIANT,
    },
}

# Fault-injection cell (schema v6): hardened CORAL vs the non-hardened
# ablation through byte-identical fault realizations, both scored on the
# fault-free twin against the fault-free oracle. ``failed_runs`` counts
# per-seed runs that ended infeasible or violating — the committed gate
# is hardened score ≥ 0.85 with zero power violations while the ablation
# has ``failed_runs == n_runs`` on every cell.
_FAULT_VARIANT = {
    "type": "object",
    "required": [
        "score",
        "score_min",
        "score_floor",
        "violation_rate",
        "power_violations",
        "n_runs",
        "failed_runs",
        "fallback_intervals",
        "rejected_samples",
        "tau",
        "power",
        "config",
    ],
    "properties": {
        "score": {"type": "number", "minimum": 0},
        "score_min": {"type": "number", "minimum": 0},
        "score_floor": {"type": "number", "minimum": 0},
        "violation_rate": {"type": "number", "minimum": 0, "maximum": 1},
        "power_violations": {"type": "integer", "minimum": 0},
        "n_runs": {"type": "integer", "minimum": 1},
        "failed_runs": {"type": "integer", "minimum": 0},
        "fallback_intervals": {"type": "number", "minimum": 0},
        "rejected_samples": {"type": "number", "minimum": 0},
        "tau": {"type": "number", "minimum": 0},
        "power": {"type": "number", "minimum": 0},
        "config": {"type": ["array", "null"], "items": {"type": "number"}},
    },
}

_FAULT_CELL = {
    "type": "object",
    "required": [
        "device",
        "model",
        "workload",
        "regime",
        "mode",
        "tau_target",
        "p_budget",
        "space_size",
        "fault",
        "oracle",
        "hardened",
        "ablation",
    ],
    "properties": {
        "device": {"type": "string"},
        "model": {"type": "string"},
        "workload": {"type": "string"},
        "regime": {"type": "string"},
        "mode": {"type": "string", "enum": ["dual", "throughput"]},
        "tau_target": {"type": "number", "minimum": 0},
        "p_budget": {"type": ["number", "null"]},
        "space_size": {"type": "integer", "minimum": 1},
        "fault": {
            "type": "object",
            "required": ["schedule", "base_regime", "intervals"],
            "properties": {
                "schedule": {"type": "string"},
                "base_regime": {"type": "string"},
                "intervals": {"type": "integer", "minimum": 1},
            },
        },
        "oracle": {
            "type": "object",
            "required": ["config", "tau", "power", "measurements"],
            "properties": {
                "config": {
                    "type": ["array", "null"],
                    "items": {"type": "number"},
                },
                "tau": {"type": "number", "minimum": 0},
                "power": {"type": "number", "minimum": 0},
                "measurements": {"type": "integer", "minimum": 0},
            },
        },
        "hardened": _FAULT_VARIANT,
        "ablation": _FAULT_VARIANT,
    },
}

# Per-phase wall-clock accounting (since schema v3; offload phases added
# in v4, cotenant in v5, fault in v6): where a matrix run spends its
# time. All fields in seconds; the ``*_episodes_s`` entries are the
# episode *control loops* — the part the compiled engine replaces.
_WALL_CLOCK_KEYS = (
    "static_prep_s",
    "static_episodes_s",
    "static_score_s",
    "offload_prep_s",
    "offload_episodes_s",
    "offload_score_s",
    "cotenant_prep_s",
    "cotenant_episodes_s",
    "cotenant_score_s",
    "fault_prep_s",
    "fault_episodes_s",
    "fault_score_s",
    "drift_prep_s",
    "drift_episodes_s",
    "drift_score_s",
)

_WALL_CLOCK = {
    "type": "object",
    "required": list(_WALL_CLOCK_KEYS),
    "properties": {
        k: {"type": "number", "minimum": 0} for k in _WALL_CLOCK_KEYS
    },
}

# Compiled-vs-scalar episode-engine speedup probe (benchmarks only —
# optional because plain ``run_matrix`` records don't re-run the scalar
# layer). ``compile_s`` is the one-time jit cost, amortized by the
# persistent compilation cache in CI.
_EPISODE_ENGINE = {
    "type": "object",
    "required": ["static", "drift", "compile_s"],
    "properties": {
        "static": {
            "type": "object",
            "required": ["scalar_s", "compiled_s", "speedup"],
            "properties": {
                "scalar_s": {"type": "number", "minimum": 0},
                "compiled_s": {"type": "number", "minimum": 0},
                "speedup": {"type": "number", "minimum": 0},
            },
        },
        "drift": {
            "type": "object",
            "required": ["scalar_s", "compiled_s", "speedup"],
            "properties": {
                "scalar_s": {"type": "number", "minimum": 0},
                "compiled_s": {"type": "number", "minimum": 0},
                "speedup": {"type": "number", "minimum": 0},
            },
        },
        "compile_s": {"type": "number", "minimum": 0},
    },
}

MATRIX_SCHEMA = {
    "$schema": "https://json-schema.org/draft/2020-12/schema",
    "title": "BENCH_matrix",
    "type": "object",
    "required": [
        "schema_version",
        "regenerate",
        "quick",
        "engine",
        "iters",
        "seeds",
        "wall_clock_s",
        "grid",
        "cells",
        "drift_cells",
        "offload_cells",
        "cotenant_cells",
        "fault_cells",
        "summary",
    ],
    "properties": {
        "schema_version": {"type": "integer", "enum": [6]},
        "regenerate": {"type": "string"},
        "quick": {"type": "boolean"},
        "engine": {"type": "string", "enum": ["compiled", "scalar"]},
        "wall_clock_s": _WALL_CLOCK,
        "episode_engine": _EPISODE_ENGINE,
        "iters": {"type": "integer", "minimum": 1},
        "seeds": {
            "type": "array",
            "items": {"type": "integer"},
            "minItems": 1,
        },
        "grid": {
            "type": "object",
            "required": [
                "devices",
                "models",
                "workloads",
                "regimes",
                "offload_regimes",
                "cotenant_regimes",
                "fault_regimes",
            ],
            "properties": {
                **{
                    k: {
                        "type": "array",
                        "items": {"type": "string"},
                        "minItems": 1,
                    }
                    for k in ("devices", "models", "workloads", "regimes")
                },
                # empty when the run carries no offload cells
                "offload_regimes": {
                    "type": "array",
                    "items": {"type": "string"},
                },
                # empty when the run carries no cotenant cells
                "cotenant_regimes": {
                    "type": "array",
                    "items": {"type": "string"},
                },
                # empty when the run carries no fault-injection cells
                "fault_regimes": {
                    "type": "array",
                    "items": {"type": "string"},
                },
            },
        },
        "cells": {"type": "array", "items": _CELL, "minItems": 1},
        # empty when the grid has no dynamic regime (e.g. trimmed runs)
        "drift_cells": {"type": "array", "items": _DRIFT_CELL},
        # empty when the run carries no edge↔pod offload cells
        "offload_cells": {"type": "array", "items": _OFFLOAD_CELL},
        # empty when the run carries no multi-tenant co-inference cells
        "cotenant_cells": {"type": "array", "items": _COTENANT_CELL},
        # empty when the run carries no fault-injection cells
        "fault_cells": {"type": "array", "items": _FAULT_CELL},
        "summary": {
            "type": "object",
            "required": [
                "n_cells",
                "mean_coral_score",
                "min_single_target_score",
                "dual_power_violations",
                "dual_tau_miss_cells",
                "n_drift_cells",
                "min_drift_adaptive_score",
                "max_drift_static_score",
                "min_drift_separation",
                "n_offload_cells",
                "min_offload_score",
                "offload_power_violations",
                "offload_feasible_baselines",
                "n_cotenant_cells",
                "min_cotenant_score",
                "cotenant_power_violations",
                "cotenant_feasible_baselines",
                "n_fault_cells",
                "min_fault_hardened_score",
                "fault_power_violations",
                "fault_feasible_ablations",
            ],
            "properties": {
                "n_cells": {"type": "integer", "minimum": 1},
                "mean_coral_score": {"type": "number"},
                "min_single_target_score": {"type": ["number", "null"]},
                "dual_power_violations": {"type": "integer", "minimum": 0},
                "dual_tau_miss_cells": {"type": "integer", "minimum": 0},
                "n_drift_cells": {"type": "integer", "minimum": 0},
                "min_drift_adaptive_score": {"type": ["number", "null"]},
                "max_drift_static_score": {"type": ["number", "null"]},
                "min_drift_separation": {"type": ["number", "null"]},
                "n_offload_cells": {"type": "integer", "minimum": 0},
                "min_offload_score": {"type": ["number", "null"]},
                "offload_power_violations": {"type": "integer", "minimum": 0},
                "offload_feasible_baselines": {
                    "type": "integer",
                    "minimum": 0,
                },
                "n_cotenant_cells": {"type": "integer", "minimum": 0},
                "min_cotenant_score": {"type": ["number", "null"]},
                "cotenant_power_violations": {
                    "type": "integer",
                    "minimum": 0,
                },
                "cotenant_feasible_baselines": {
                    "type": "integer",
                    "minimum": 0,
                },
                "n_fault_cells": {"type": "integer", "minimum": 0},
                "min_fault_hardened_score": {"type": ["number", "null"]},
                "fault_power_violations": {"type": "integer", "minimum": 0},
                "fault_feasible_ablations": {
                    "type": "integer",
                    "minimum": 0,
                },
            },
        },
    },
}

# ---------------------------------------------------------------------------
# BENCH_fleet.json — fleet-scale heterogeneous-twin tuning record.
#
# The ``results`` block is deterministic for a given (n_twins, seed,
# iters, window): twin sampling, noise streams and the compiled engine
# are all seeded, so two runs on the same software stack must agree
# byte-for-byte (tests/test_fleet.py enforces this). The ``engine``
# block is machine-dependent wall-clock/memory telemetry and is never
# part of the determinism contract.
# ---------------------------------------------------------------------------

_FLEET_FAMILY = {
    "type": "object",
    "required": ["n_twins", "feasible_rate", "mean_m2f", "mean_score"],
    "properties": {
        "n_twins": {"type": "integer", "minimum": 0},
        "feasible_rate": {"type": "number", "minimum": 0, "maximum": 1},
        "mean_m2f": {"type": ["number", "null"], "minimum": 0},
        "mean_score": {"type": ["number", "null"], "minimum": 0},
    },
}

_FLEET_CURVE = {
    "type": "object",
    "required": ["cold", "warm"],
    "properties": {
        k: {
            "type": "array",
            "items": {"type": "number", "minimum": 0, "maximum": 1},
        }
        for k in ("cold", "warm")
    },
}

FLEET_SCHEMA = {
    "$schema": "https://json-schema.org/draft/2020-12/schema",
    "title": "BENCH_fleet",
    "type": "object",
    "required": ["schema_version", "regenerate", "quick", "results", "engine"],
    "properties": {
        "schema_version": {"type": "integer", "enum": [1]},
        "regenerate": {"type": "string"},
        "quick": {"type": "boolean"},
        "results": {
            "type": "object",
            "required": [
                "n_twins",
                "seed",
                "iters",
                "window",
                "families",
                "model",
                "workload",
                "feasible_rate",
                "mean_m2f_cold",
                "mean_score",
                "warm_cohort",
                "warm_matched",
                "mean_m2f_cold_cohort",
                "mean_m2f_warm_cohort",
                "warm_gain",
                "per_family",
                "convergence",
            ],
            "properties": {
                "n_twins": {"type": "integer", "minimum": 1},
                "seed": {"type": "integer"},
                "iters": {"type": "integer", "minimum": 1},
                "window": {"type": "integer", "minimum": 2},
                "families": {
                    "type": "array",
                    "items": {"type": "string"},
                    "minItems": 1,
                },
                "model": {"type": "string"},
                "workload": {"type": "string"},
                "feasible_rate": {"type": "number", "minimum": 0, "maximum": 1},
                "mean_m2f_cold": {"type": ["number", "null"], "minimum": 0},
                "mean_score": {"type": ["number", "null"], "minimum": 0},
                "warm_cohort": {"type": "integer", "minimum": 0},
                "warm_matched": {"type": "integer", "minimum": 0},
                "mean_m2f_cold_cohort": {
                    "type": ["number", "null"],
                    "minimum": 0,
                },
                "mean_m2f_warm_cohort": {
                    "type": ["number", "null"],
                    "minimum": 0,
                },
                "warm_gain": {"type": ["number", "null"], "minimum": 0},
                "per_family": {
                    "type": "object",
                    "additionalProperties": _FLEET_FAMILY,
                },
                "convergence": {
                    "type": "object",
                    "additionalProperties": _FLEET_CURVE,
                },
            },
        },
        "engine": {
            "type": "object",
            "required": [
                "backend",
                "prep_s",
                "cold_wall_s",
                "warm_wall_s",
                "table_bytes",
                "batch_bytes",
                "consts_bytes",
            ],
            "properties": {
                "backend": {"type": "string"},
                "prep_s": {"type": "number", "minimum": 0},
                "cold_wall_s": {"type": "number", "minimum": 0},
                "warm_wall_s": {"type": "number", "minimum": 0},
                "steady_wall_s": {"type": ["number", "null"], "minimum": 0},
                "twins_per_s": {"type": ["number", "null"], "minimum": 0},
                "table_bytes": {"type": "integer", "minimum": 0},
                "batch_bytes": {"type": "integer", "minimum": 0},
                "consts_bytes": {"type": "integer", "minimum": 0},
                "peak_device_bytes": {"type": ["integer", "null"], "minimum": 0},
            },
        },
    },
}

_TYPES = {
    "object": dict,
    "array": list,
    "string": str,
    "boolean": bool,
    "null": type(None),
}


def _check(node: Any, schema: dict, path: str, errors: List[str]) -> None:
    """Minimal structural validator for the subset MATRIX_SCHEMA uses."""
    types = schema.get("type")
    if types is not None:
        allowed = [types] if isinstance(types, str) else list(types)
        ok = False
        for t in allowed:
            if t == "number":
                ok |= isinstance(node, (int, float)) and not isinstance(node, bool)
            elif t == "integer":
                ok |= isinstance(node, int) and not isinstance(node, bool)
            else:
                ok |= isinstance(node, _TYPES[t])
        if not ok:
            errors.append(f"{path}: expected {allowed}, got {type(node).__name__}")
            return
    if node is None:
        return
    if "enum" in schema and node not in schema["enum"]:
        errors.append(f"{path}: {node!r} not in {schema['enum']}")
    if isinstance(node, (int, float)) and not isinstance(node, bool):
        if "minimum" in schema and node < schema["minimum"]:
            errors.append(f"{path}: {node} < minimum {schema['minimum']}")
        if "maximum" in schema and node > schema["maximum"]:
            errors.append(f"{path}: {node} > maximum {schema['maximum']}")
    if isinstance(node, dict):
        for req in schema.get("required", ()):
            if req not in node:
                errors.append(f"{path}: missing required key {req!r}")
        props = schema.get("properties", {})
        extra = schema.get("additionalProperties")
        for k, v in node.items():
            if k in props:
                _check(v, props[k], f"{path}.{k}", errors)
            elif isinstance(extra, dict):
                _check(v, extra, f"{path}.{k}", errors)
    if isinstance(node, list):
        if "minItems" in schema and len(node) < schema["minItems"]:
            errors.append(f"{path}: fewer than {schema['minItems']} items")
        item_schema = schema.get("items")
        if isinstance(item_schema, dict):
            for i, v in enumerate(node):
                _check(v, item_schema, f"{path}[{i}]", errors)


def _validate(record: dict, schema: dict, title: str) -> None:
    try:
        import jsonschema
    except ImportError:
        jsonschema = None
    if jsonschema is not None:
        try:
            jsonschema.validate(record, schema)
        except jsonschema.ValidationError as e:
            raise ValueError(f"{title} record invalid: {e.message}") from e
        return
    errors: List[str] = []
    _check(record, schema, "$", errors)
    if errors:
        raise ValueError(
            f"{title} record invalid:\n  " + "\n  ".join(errors[:20])
        )


def validate_matrix_record(record: dict) -> None:
    """Raise ValueError if the record does not conform to MATRIX_SCHEMA."""
    _validate(record, MATRIX_SCHEMA, "BENCH_matrix")


def validate_fleet_record(record: dict) -> None:
    """Raise ValueError if the record does not conform to FLEET_SCHEMA."""
    _validate(record, FLEET_SCHEMA, "BENCH_fleet")
