"""Declarative scenario grid: which cells exist and what each one means.

A *cell* is one (device profile × model config × workload trace ×
constraint regime) combination — the paper's evaluation grid (two Jetson
devices × three detection models × single-target and strict dual
regimes) generalized so new devices, models, traces or regimes are one
registry entry away.

Everything here is declarative and deterministic: ``enumerate_cells``
yields the full cartesian product in a fixed order, and
``resolve_targets`` turns a regime's *relative* knobs (fraction of the
cell's max throughput, slack over the oracle's power draw) into absolute
(τ target, power budget) numbers for that cell — the paper sets targets
per device/model the same way (§IV-A).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.registry import get_config
from repro.core.baselines import oracle
from repro.core.evaluate import RegimeTargets
from repro.device.hw import (
    BudgetStep,
    CotenantStep,
    DriftSchedule,
    ThermalRamp,
    get_profile,
)
from repro.core.faults import (
    ActuationFailure,
    FaultSchedule,
    FaultTables,
    FirmwareReset,
    SensorDropout,
    TelemetrySpike,
)
from repro.device.cotenant import CotenantSimulator
from repro.device.network import OffloadSimulator, get_network
from repro.device.simulator import (
    DeviceSimulator,
    DriftingSimulator,
    FaultySimulator,
    build_cell_simulator,
)


@dataclasses.dataclass(frozen=True)
class Workload:
    """One workload trace: the step shape and the measurement regime.

    ``kind`` selects the roofline shape (decode: memory-bound weight
    streaming amortized over ``batch``; prefill: compute-bound over
    ``seq`` prompt tokens). ``noise`` is the relative σ of the 1-second
    tegrastats-style samples — bursty traffic reads noisier (τ, p).
    """

    name: str
    kind: str  # decode | prefill
    batch: int = 8
    seq: int = 256
    noise: float = 0.02


@dataclasses.dataclass(frozen=True)
class Regime:
    """One constraint regime, relative to the cell's own landscape.

    ``tau_frac`` — τ target as a fraction of the cell's max throughput
    (0 → no target). ``p_slack`` — power budget as a multiple of the
    cell's budget anchor (None → uncapped). ``mode`` is the CORAL
    objective ("dual" or "throughput").

    ``p_anchor`` names the landscape statistic the budget multiplies:
      "oracle"    — the single-target oracle's draw (the static grid's
                    convention: strict but satisfiable);
      "pmin"      — the minimum power that meets the τ floor (the
                    cheapest operating point satisfying the SLO — the
                    edge-deployment operating point drift knocks out);
      "max_power" — the grid's maximum draw (for throughput-mode board
                    caps).

    ``drift`` names a ``DRIFTS`` schedule for dynamic (non-stationary)
    regimes; None is a stationary cell.
    """

    name: str
    mode: str
    tau_frac: float = 0.0
    p_slack: Optional[float] = None
    p_anchor: str = "oracle"
    drift: Optional[str] = None

    @property
    def single_target(self) -> bool:
        return self.p_slack is None

    @property
    def dual_constraint(self) -> bool:
        return self.p_slack is not None

    @property
    def dynamic(self) -> bool:
        return self.drift is not None


@dataclasses.dataclass(frozen=True)
class Cell:
    device: str
    model: str
    workload: str
    regime: str

    def key(self) -> Tuple[str, str, str, str]:
        return (self.device, self.model, self.workload, self.regime)


WORKLOADS: Dict[str, Workload] = {
    w.name: w
    for w in (
        Workload("decode_steady", kind="decode", batch=8, noise=0.02),
        Workload("decode_bursty", kind="decode", batch=8, noise=0.04),
        Workload("decode_diurnal", kind="decode", batch=8, noise=0.03),
        Workload("prefill_steady", kind="prefill", seq=256, noise=0.02),
    )
}

# Control-interval timeline shared by every dynamic cell: explore, hold,
# shift at SHIFT_START, and enough post-shift intervals for detection +
# bounded re-exploration (up to 1 + max_retries epochs) + a settled hold.
DRIFT_INTERVALS = 64
DRIFT_SHIFT_START = 20

# Named drift schedules. Each one was validated against the dynamic grid
# below: the *static* one-shot tuner's held config demonstrably breaks
# (constraint bust or large score loss) while the post-shift landscape
# keeps a feasible region wide enough (≥ ~5% of the grid) for bounded
# re-exploration to reach ≥0.85 of the post-shift oracle.
DRIFTS: Dict[str, DriftSchedule] = {
    # Thermal throttling: delivered clocks derate per-level (quadratic in
    # the requested step) over a 6-interval ramp; hot silicon leaks extra
    # idle power. Breaks clock-racing helds; low-step configs shelter.
    "thermal-ramp": DriftSchedule(
        (
            ThermalRamp(
                DRIFT_SHIFT_START,
                duration=6,
                clock_derate=0.25,
                mem_derate=0.2,
                static_inflation=0.15,
            ),
        )
    ),
    # A co-located job lands on the host: preprocessing slows ~4×, mild
    # extra DRAM contention, and the co-tenant's own draw appears on the
    # shared rail. Moves the optimum toward deeper concurrency (hide the
    # host stage behind the device) — host-sensitive cells reorder.
    "cotenant-step": DriftSchedule(
        (
            CotenantStep(
                DRIFT_SHIFT_START,
                host_inflation=3.0,
                kappa_add=0.05,
                static_inflation=0.05,
            ),
        )
    ),
    # The operator cuts the board power cap to 55% (battery saver / rack
    # cap): a commanded change carried on the drift clock, not detected.
    "budget-step": DriftSchedule((BudgetStep(DRIFT_SHIFT_START, scale=0.55),)),
}

REGIMES: Dict[str, Regime] = {
    r.name: r
    for r in (
        # single-target: meet a τ floor at best efficiency (paper Fig. 3/4)
        Regime("single_tau", mode="dual", tau_frac=0.55),
        # single-target: maximize raw throughput (paper §IV-B)
        Regime("max_throughput", mode="throughput"),
        # strict dual: τ floor AND a tight power cap (paper Fig. 5/6).
        # The higher τ floor + 1.2× slack keeps every cell's feasible set
        # at ~10-20% of the grid — strict enough that presets and ALERT
        # bust the cap, wide enough that CORAL's 10-measurement budget
        # reliably lands inside (the paper's §IV-C operating point).
        Regime("strict_dual", mode="dual", tau_frac=0.7, p_slack=1.2),
        # ---- dynamic regimes (EXPERIMENTS.md §Drift) -------------------
        # τ floor + a cap anchored at the cheapest SLO-meeting draw: the
        # efficiency pick sits near the floor, so thermal derating knocks
        # it out while headroom higher up the ladder stays feasible.
        Regime(
            "thermal-ramp",
            mode="dual",
            tau_frac=0.55,
            p_slack=1.6,
            p_anchor="pmin",
            drift="thermal-ramp",
        ),
        Regime(
            "cotenant-step",
            mode="dual",
            tau_frac=0.5,
            p_slack=1.4,
            p_anchor="pmin",
            drift="cotenant-step",
        ),
        # max-τ under a board cap (85% of max draw); the commanded cut to
        # 55% strands the cap-adjacent held config above the new budget.
        Regime(
            "budget-step",
            mode="throughput",
            p_slack=0.85,
            p_anchor="max_power",
            drift="budget-step",
        ),
    )
}

# Default grid axes: the paper's 2 devices × 3 models × 2 regimes shape,
# with the model axis spanning a ~6× active-parameter range (the paper's
# detectors span ~20× — same heavy-tail idea on registry architectures).
MATRIX_DEVICES: Tuple[str, ...] = ("edge-xavier-nx", "edge-orin-nano")
MATRIX_MODELS: Tuple[str, ...] = ("qwen2.5-3b", "granite-8b", "internlm2-20b")
MATRIX_WORKLOADS: Tuple[str, ...] = ("decode_steady",)
MATRIX_REGIMES: Tuple[str, ...] = ("single_tau", "max_throughput", "strict_dual")

FULL_MATRIX_WORKLOADS: Tuple[str, ...] = (
    "decode_steady",
    "decode_bursty",
    "prefill_steady",
)

# Dynamic (drift) cells: each regime is paired with devices/models where
# its physics genuinely reorders the landscape — thermal throttling bites
# the clock-racing Orin NX; the commanded budget cut strands the
# efficiency-tuned Nano; host-side co-tenancy reorders the host-bound
# small models. Xavier NX is deliberately absent: its efficiency optimum
# sits in the corner of a τ plateau that every drift axis derates
# uniformly, so one-shot tuning there is drift-*insensitive* — the same
# device-dependent sensitivity PolyThrottle reports (EXPERIMENTS.md
# §Drift documents the reasoning).
MATRIX_DRIFT_CELLS: Tuple[Cell, ...] = (
    Cell("edge-orin-nx", "qwen2.5-3b", "decode_steady", "thermal-ramp"),
    Cell("edge-orin-nx", "granite-8b", "decode_steady", "thermal-ramp"),
    Cell("edge-orin-nx", "hymba-1.5b", "decode_steady", "cotenant-step"),
    Cell("edge-orin-nano", "whisper-medium", "decode_steady", "cotenant-step"),
    Cell("edge-orin-nano", "qwen2.5-3b", "decode_steady", "budget-step"),
    Cell("edge-orin-nano", "granite-8b", "decode_steady", "budget-step"),
)

# QUICK (CI-smoke) subset: one cell per dynamic regime.
QUICK_DRIFT_CELLS: Tuple[Cell, ...] = (
    MATRIX_DRIFT_CELLS[0],
    MATRIX_DRIFT_CELLS[2],
    MATRIX_DRIFT_CELLS[4],
)


@dataclasses.dataclass(frozen=True)
class OffloadRegime:
    """One offload regime: arrival pressure an un-offloaded edge device
    cannot serve, plus the network the overflow ships over.

    ``demand_factor`` scales the offered arrival rate λ as a multiple of
    the cell's *edge-only* max throughput (the best φ=0 row of the joint
    grid), so demand_factor > 1 makes every no-offload configuration
    infeasible by construction. ``slo_frac`` sets the end-to-end τ target
    as a fraction of λ, and ``p_slack`` the edge power budget as a
    multiple of the cheapest SLO-meeting draw (the "pmin" anchor of the
    static regimes) — calibrated so 5–18% of the joint grid is dual-
    feasible while ``max_power`` presets bust the budget on radio + max
    clocks. ``trace`` names the arrival process the regime models (MMPP
    bursts over a constrained LTE uplink, diurnal peaks over metro
    fiber); the paired workload's measurement noise reflects it.
    """

    name: str
    trace: str  # "mmpp" | "diurnal"
    network: str  # NETWORKS registry key
    demand_factor: float = 2.0
    slo_frac: float = 0.85
    p_slack: float = 1.35

    @property
    def dual_constraint(self) -> bool:
        return True

    @property
    def mode(self) -> str:
        return "dual"


OFFLOAD_REGIMES: Dict[str, OffloadRegime] = {
    r.name: r
    for r in (
        # Bursty MMPP arrivals over a bandwidth- and energy-constrained
        # LTE uplink: the radio tax makes high offload fractions power-
        # expensive, so the optimum balances route fraction against the
        # edge DVFS ladder.
        OffloadRegime("offload_mmpp", trace="mmpp", network="lte-uplink"),
        # Diurnal peak over metro fiber: cheap fat pipe, so the binding
        # resources are the pod slice and the edge power rail.
        OffloadRegime("offload_diurnal", trace="diurnal", network="fiber-metro"),
    )
}

# Offload cells: each pairs a regime with (device × model) combos whose
# joint grid keeps a 7–18% dual-feasible region at the regime's default
# knobs (calibrated against the noise-free landscape; see
# EXPERIMENTS.md §Offload). The MMPP regime rides the bursty workload's
# noisier samples; the diurnal regime gets its own trace noise.
MATRIX_OFFLOAD_CELLS: Tuple[Cell, ...] = (
    Cell("edge-xavier-nx", "qwen2.5-3b", "decode_bursty", "offload_mmpp"),
    Cell("edge-orin-nano", "granite-8b", "decode_bursty", "offload_mmpp"),
    Cell("edge-xavier-nx", "granite-8b", "decode_diurnal", "offload_diurnal"),
    Cell("edge-orin-nano", "qwen2.5-3b", "decode_diurnal", "offload_diurnal"),
)

# QUICK (CI-smoke) subset: one cell per offload regime.
QUICK_OFFLOAD_CELLS: Tuple[Cell, ...] = (
    MATRIX_OFFLOAD_CELLS[0],
    MATRIX_OFFLOAD_CELLS[3],
)


def offload_cell_simulator(
    cell: Cell, noise: Optional[float] = None, seed: int = 0
) -> OffloadSimulator:
    """Build the cell's edge↔pod twin over the joint offload grid, with
    the offered demand λ pinned at demand_factor × the edge-only max so
    every φ=0 row is infeasible. ``noise=None`` uses the workload's trace
    noise; ``noise=0.0`` is the ground-truth twin targets/oracle use."""
    regime = OFFLOAD_REGIMES[cell.regime]
    w = WORKLOADS[cell.workload]
    sim = OffloadSimulator(
        get_profile(cell.device),
        get_config(cell.model),
        get_network(regime.network),
        kind=w.kind,
        batch=w.batch,
        seq=w.seq,
        noise=w.noise if noise is None else noise,
        seed=seed,
    )
    sim.demand = round(regime.demand_factor * sim.edge_only_max(), 3)
    return sim


def resolve_offload_targets(
    cell: Cell, sim0: Optional[OffloadSimulator] = None
) -> RegimeTargets:
    """Absolute (τ target, edge power budget) for an offload cell: the
    τ target is slo_frac × the offered demand λ (an end-to-end served-
    throughput SLO), and the budget is p_slack × the cheapest edge-rail
    draw meeting it — the "pmin" anchor over the *joint* grid, radio
    energy included."""
    regime = OFFLOAD_REGIMES[cell.regime]
    if sim0 is None:
        sim0 = offload_cell_simulator(cell, noise=0.0)
    tau_target = round(regime.slo_frac * sim0.demand, 3)
    tau_all, p_all = sim0.exact_all()
    p_anchor = float(p_all[tau_all >= tau_target].min())
    return RegimeTargets(
        mode="dual", tau_target=tau_target, p_budget=p_anchor * regime.p_slack
    )


@dataclasses.dataclass(frozen=True)
class CotenantRegime:
    """One multi-tenant co-inference regime: per-tenant τ-floor fractions
    plus a shared power cap (EXPERIMENTS.md §Multi-tenant).

    ``tau_fracs[k]`` sets tenant k's τ floor as a fraction of its *solo
    max* — the best τ_k anywhere on the joint grid, i.e. what tenant k
    could reach if the allocator favored it outright. Floors calibrated
    this way are individually reachable but jointly tight: meeting both
    at once forces the slot split and the shared clocks to be negotiated,
    which is exactly the knob the per-tenant-greedy ablation ignores.
    ``p_slack`` is the shared rail budget as a multiple of the cheapest
    draw meeting every floor (the "pmin" anchor over the joint grid).
    """

    name: str
    tau_fracs: Tuple[float, ...] = (0.5, 0.5)
    p_slack: float = 1.25

    @property
    def dual_constraint(self) -> bool:
        return True

    @property
    def mode(self) -> str:
        return "dual"


COTENANT_REGIMES: Dict[str, CotenantRegime] = {
    r.name: r
    for r in (
        # Symmetric floors: both tenants claim the same fraction of their
        # solo max — the pure negotiation case. 0.625 is calibrated so
        # every static preset and the per-tenant-greedy combination miss
        # at least one floor on both cells while a 3–5% joint-feasible
        # region survives (p_slack 1.45 keeps the all-defaults preset
        # just over the rail budget on the Xavier cell).
        CotenantRegime("cotenant_balanced", tau_fracs=(0.625, 0.625), p_slack=1.45),
        # A latency-critical primary next to a best-effort batch tenant:
        # the primary's floor is high enough that naive equal splits and
        # the greedy combination miss it, while the joint-feasible region
        # stays discoverable within the COTENANT_ITERS budget.
        CotenantRegime("cotenant_skewed", tau_fracs=(0.70, 0.4), p_slack=1.45),
    )
}

# Cotenant cells encode the tenant pairs as '+'-joined composite model /
# workload strings, so the 4-field Cell (and every keying/reporting path
# built on it) carries multi-tenant cells unchanged.
MATRIX_COTENANT_CELLS: Tuple[Cell, ...] = (
    Cell(
        "edge-xavier-nx",
        "qwen2.5-3b+granite-8b",
        "decode_steady+decode_bursty",
        "cotenant_balanced",
    ),
    Cell(
        "edge-orin-nano",
        "qwen2.5-3b+hymba-1.5b",
        "decode_steady+decode_steady",
        "cotenant_balanced",
    ),
    Cell(
        "edge-xavier-nx",
        "granite-8b+hymba-1.5b",
        "decode_bursty+decode_steady",
        "cotenant_skewed",
    ),
    Cell(
        "edge-orin-nano",
        "granite-8b+whisper-medium",
        "decode_steady+decode_bursty",
        "cotenant_skewed",
    ),
)

# QUICK (CI-smoke) subset: one cell per cotenant regime.
QUICK_COTENANT_CELLS: Tuple[Cell, ...] = (
    MATRIX_COTENANT_CELLS[0],
    MATRIX_COTENANT_CELLS[3],
)


def tenant_names(cell: Cell) -> Tuple[Tuple[str, ...], Tuple[str, ...]]:
    """Split a cotenant cell's composite fields into per-tenant (models,
    workloads); validates the two lists pair up."""
    models = tuple(cell.model.split("+"))
    workloads = tuple(cell.workload.split("+"))
    if len(models) != len(workloads) or len(models) < 2:
        raise ValueError(
            f"cotenant cell needs matching '+'-joined model/workload "
            f"lists, got {cell.model!r} / {cell.workload!r}"
        )
    return models, workloads


def cotenant_cell_simulator(
    cell: Cell, noise: Optional[float] = None, seed: int = 0
) -> CotenantSimulator:
    """Build the cell's multi-tenant twin over the joint slots × shared-
    DVFS grid, with the per-tenant τ floors pinned from the regime's
    solo-max fractions (the pin-after-build pattern of the offload
    demand). ``noise=None`` uses the noisiest tenant's trace noise;
    ``noise=0.0`` is the ground-truth twin targets/oracle use."""
    regime = COTENANT_REGIMES[cell.regime]
    models, workloads = tenant_names(cell)
    ws = [WORKLOADS[w] for w in workloads]
    sim = CotenantSimulator(
        get_profile(cell.device),
        [get_config(m) for m in models],
        kinds=tuple(w.kind for w in ws),
        batches=tuple(w.batch for w in ws),
        seqs=tuple(w.seq for w in ws),
        noise=max(w.noise for w in ws) if noise is None else noise,
        seed=seed,
    )
    sim.floors = tuple(
        round(frac * sim.solo_max(k), 3)
        for k, frac in enumerate(regime.tau_fracs)
    )
    return sim


def resolve_cotenant_targets(
    cell: Cell, sim0: Optional[CotenantSimulator] = None
) -> RegimeTargets:
    """Absolute targets for a cotenant cell. The τ channel is the joint
    headroom min_k τ_k/floor_k (``core.coral.joint_headroom``), so the
    target is the constant 1.0; the budget is p_slack × the cheapest
    shared-rail draw with headroom ≥ 1 — the "pmin" anchor over the
    joint grid."""
    regime = COTENANT_REGIMES[cell.regime]
    if sim0 is None:
        sim0 = cotenant_cell_simulator(cell, noise=0.0)
    h_all, p_all = sim0.exact_all()
    p_anchor = float(p_all[h_all >= 1.0].min())
    return RegimeTargets(
        mode="dual", tau_target=1.0, p_budget=round(p_anchor * regime.p_slack, 3)
    )


@dataclasses.dataclass(frozen=True)
class FaultRegime:
    """One fault regime: a stationary base regime (whose constraint shape
    and landscape the cell keeps — faults corrupt the *measurement and
    actuation path*, never the device physics) plus a named ``FAULTS``
    schedule injected into it (EXPERIMENTS.md §Fault tolerance)."""

    name: str
    base: str  # REGIMES key supplying the (τ target, power budget) shape
    fault: str  # FAULTS schedule key

    @property
    def dual_constraint(self) -> bool:
        return REGIMES[self.base].dual_constraint

    @property
    def mode(self) -> str:
        return REGIMES[self.base].mode


# Control-interval timeline shared by every fault cell: the 10-sample
# static budget is too short to even see a blackout + recovery, so fault
# episodes run 40 intervals (explore → fault window → recover).
FAULT_INTERVALS = 40

# Named fault schedules. Each was validated against the fault grid below:
# hardened CORAL holds ≥ 0.85 of the fault-free oracle with zero budget
# violations on every cell, while the non-hardened ablation — same twin,
# same realization — ends infeasible or violating on every cell
# (benchmarks/check_regression.py gates both directions).
FAULTS: Dict[str, FaultSchedule] = {
    # Garbage telemetry under load: heavy-tailed spikes on both channels
    # plus lost samples. The τ channel wraps *upward* (counter-wrap /
    # unit-mismatch reads huge) — the decisive poison for a blind
    # ingester: one up-spiked τ on an in-budget row that truly misses
    # the floor anoints it best-feasible forever. The p channel glitches
    # both ways, prohibiting good rows. The MAD gate rejects all of it.
    "telemetry-storm": FaultSchedule(
        "telemetry-storm",
        (
            # a stuck counter spews garbage for 6 straight exploration
            # intervals (right after the gate's 5-sample calibration
            # prefix — the probes measured there are mostly infeasible
            # rows, which is what makes the poison fatal to the ablation)
            TelemetrySpike(
                start=6, stop=10, rate=1.0, magnitude=1000.0, axis="tau",
                direction="up",
            ),
            TelemetrySpike(
                start=10, rate=0.25, magnitude=1000.0, axis="power",
                direction="up",
            ),
            SensorDropout(start=10, rate=0.2),
        ),
    ),
    # The telemetry daemon dies for 8 straight intervals, then comes back
    # glitchy: trips the watchdog (dark ≥ K) → degrade to the safe anchor
    # → resume exploration when samples return.
    "sensor-blackout": FaultSchedule(
        "sensor-blackout",
        (
            SensorDropout(start=12, stop=20, rate=1.0),
            # the daemon comes back glitchy: the blind ingester learned
            # nothing from eight NaN intervals, so it is still probing
            # infeasible rows when the garbage window opens
            TelemetrySpike(
                start=20, stop=25, rate=1.0, magnitude=1000.0, axis="tau",
                direction="up",
            ),
            SensorDropout(start=26, rate=0.15),
        ),
    ),
    # Sticky knobs + governor resets: commanded ≠ applied, so the blind
    # writer attributes the max-power boot row's draw (or a stale
    # config's τ) to whatever it commanded; readback + bounded retry
    # keeps the hardened ledger attributed to the config in force.
    "flaky-actuator": FaultSchedule(
        "flaky-actuator",
        (
            ActuationFailure(start=3, rate=0.35, mean_tries=2.0),
            FirmwareReset(at=(14, 26)),
            TelemetrySpike(
                start=6, stop=8, rate=1.0, magnitude=1000.0, axis="tau",
                direction="up",
            ),
            SensorDropout(start=10, rate=0.15),
        ),
    ),
}

FAULT_REGIMES: Dict[str, FaultRegime] = {
    r.name: r
    for r in (
        FaultRegime("fault-telemetry", base="strict_dual", fault="telemetry-storm"),
        FaultRegime("fault-blackout", base="strict_dual", fault="sensor-blackout"),
        FaultRegime("fault-actuator", base="strict_dual", fault="flaky-actuator"),
    )
}

# Fault cells: every fault regime on both matrix devices — fault
# injection corrupts the measurement/actuation path, so unlike drift
# there is no device whose *landscape* shelters it.
MATRIX_FAULT_CELLS: Tuple[Cell, ...] = (
    Cell("edge-xavier-nx", "qwen2.5-3b", "decode_steady", "fault-telemetry"),
    Cell("edge-orin-nano", "granite-8b", "decode_steady", "fault-telemetry"),
    Cell("edge-xavier-nx", "granite-8b", "decode_steady", "fault-blackout"),
    Cell("edge-orin-nano", "qwen2.5-3b", "decode_steady", "fault-blackout"),
    Cell("edge-xavier-nx", "qwen2.5-3b", "decode_steady", "fault-actuator"),
    Cell("edge-orin-nano", "granite-8b", "decode_steady", "fault-actuator"),
)

# QUICK (CI-smoke) subset: one telemetry-path and one actuation-path cell.
QUICK_FAULT_CELLS: Tuple[Cell, ...] = (
    MATRIX_FAULT_CELLS[0],
    MATRIX_FAULT_CELLS[5],
)


def _fault_base_cell(cell: Cell) -> Cell:
    """The stationary cell a fault cell corrupts (same device/model/
    workload, the regime swapped for the fault regime's base)."""
    return Cell(
        cell.device, cell.model, cell.workload, FAULT_REGIMES[cell.regime].base
    )


def fault_tables(cell: Cell, seed: int, intervals: int = FAULT_INTERVALS) -> FaultTables:
    """The cell's realized fault tables at one seed — deterministic, so
    the scalar twin, the compiled engine and the scoring path all consume
    byte-identical realizations without sharing objects."""
    return FAULTS[FAULT_REGIMES[cell.regime].fault].realize(intervals, seed)


def fault_cell_simulator(
    cell: Cell, noise: Optional[float] = None, seed: int = 0
) -> FaultySimulator:
    """Build the cell's fault-injected twin: the base regime's stationary
    simulator wrapped in the schedule's realization at this seed.
    ``noise=0.0`` still injects faults — the ground-truth twin for fault
    cells is the *base* cell's simulator (``build_twin`` on
    ``_fault_base_cell``), because scoring asks what the chosen config
    delivers once the glitch is gone."""
    return FaultySimulator(
        cell_simulator(_fault_base_cell(cell), noise=noise, seed=seed),
        fault_tables(cell, seed),
    )


# Fault cells re-center the τ target at this fraction of the
# budget-constrained frontier (the best τ any in-budget row achieves).
# With the base regime's slack target, every in-budget row on the larger
# devices already meets τ, so a corrupted pick can only waste power —
# never violate. Fault tolerance is scored where it matters: near the
# feasibility boundary, where one swallowed outlier is the difference
# between a valid pick and a violating one.
FAULT_TAU_TIGHTEN = 0.9


def resolve_fault_targets(cell: Cell) -> RegimeTargets:
    """Absolute targets for a fault cell: the base regime's power budget
    (faults never move the power goalpost), with the τ target raised to
    ``FAULT_TAU_TIGHTEN`` of the budget-constrained frontier so the
    feasible set is a boundary sliver on every device class."""
    import numpy as np

    base = resolve_targets(_fault_base_cell(cell))
    sim0 = cell_simulator(_fault_base_cell(cell), noise=0.0)
    tau_all, p_all = (np.asarray(a) for a in sim0.exact_all())
    frontier = float(tau_all[p_all <= base.p_budget].max())
    tau_target = round(max(base.tau_target, FAULT_TAU_TIGHTEN * frontier), 3)
    return RegimeTargets(
        mode=base.mode, tau_target=tau_target, p_budget=base.p_budget
    )


def enumerate_cells(
    devices: Sequence[str] = MATRIX_DEVICES,
    models: Sequence[str] = MATRIX_MODELS,
    workloads: Sequence[str] = MATRIX_WORKLOADS,
    regimes: Sequence[str] = MATRIX_REGIMES,
) -> List[Cell]:
    """The exhaustive cell list, in deterministic axis-major order
    (devices outermost, regimes innermost). Unknown names fail fast."""
    for d in devices:
        get_profile(d)
    for m in models:
        get_config(m)
    unknown = [w for w in workloads if w not in WORKLOADS]
    unknown += [r for r in regimes if r not in REGIMES]
    if unknown:
        raise KeyError(f"unknown workload/regime names: {unknown}")
    return [
        Cell(d, m, w, r)
        for d in devices
        for m in models
        for w in workloads
        for r in regimes
    ]


def cell_simulator(
    cell: Cell, noise: Optional[float] = None, seed: int = 0
) -> DeviceSimulator:
    """Build the cell's device: profile knobs + model footprint + workload
    shape. ``noise=None`` uses the workload's trace noise; ``noise=0.0``
    gives the noise-free ground-truth twin ORACLE and scoring use."""
    w = WORKLOADS[cell.workload]
    return build_cell_simulator(
        get_profile(cell.device),
        get_config(cell.model),
        kind=w.kind,
        batch=w.batch,
        seq=w.seq,
        noise=w.noise if noise is None else noise,
        seed=seed,
    )


def resolve_targets(
    cell: Cell, sim0: Optional[DeviceSimulator] = None
) -> RegimeTargets:
    """Absolute (τ target, power budget) for a cell, from its noise-free
    landscape: τ target = tau_frac · max-τ; budget = p_slack × the
    regime's budget anchor — the single-target oracle's draw ("oracle",
    strict but satisfiable), the cheapest draw meeting the τ floor
    ("pmin"), or the grid's max draw ("max_power")."""
    regime = REGIMES[cell.regime]
    if sim0 is None:
        sim0 = cell_simulator(cell, noise=0.0)
    tau_target = 0.0
    if regime.tau_frac > 0.0:
        om = oracle(sim0.space, sim0, tau_target=0.0)
        tau_target = round(regime.tau_frac * om.tau, 3)
    p_budget = float("inf")
    if regime.p_slack is not None:
        if regime.p_anchor == "oracle":
            p_anchor = oracle(sim0.space, sim0, tau_target).power
        else:
            tau_all, p_all = sim0.exact_all()
            if regime.p_anchor == "pmin":
                p_anchor = float(p_all[tau_all >= tau_target].min())
            elif regime.p_anchor == "max_power":
                p_anchor = float(p_all.max())
            else:
                raise KeyError(f"unknown p_anchor {regime.p_anchor!r}")
        p_budget = p_anchor * regime.p_slack
    return RegimeTargets(mode=regime.mode, tau_target=tau_target, p_budget=p_budget)


def drifting_cell_simulator(
    cell: Cell, noise: Optional[float] = None, seed: int = 0
) -> DriftingSimulator:
    """The cell's time-varying device twin: its stationary simulator
    wrapped in the regime's drift schedule."""
    regime = REGIMES[cell.regime]
    if regime.drift is None:
        raise ValueError(f"regime {cell.regime!r} is stationary")
    return DriftingSimulator(
        cell_simulator(cell, noise=noise, seed=seed), DRIFTS[regime.drift]
    )
