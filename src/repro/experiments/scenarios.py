"""Declarative scenario grid: which cells exist and what each one means.

A *cell* is one (device profile × model config × workload trace ×
constraint regime) combination — the paper's evaluation grid (two Jetson
devices × three detection models × single-target and strict dual
regimes) generalized so new devices, models, traces or regimes are one
registry entry away.

Everything here is declarative and deterministic: ``enumerate_cells``
yields the full cartesian product in a fixed order, and
``resolve_targets`` turns a regime's *relative* knobs (fraction of the
cell's max throughput, slack over the oracle's power draw) into absolute
(τ target, power budget) numbers for that cell — the paper sets targets
per device/model the same way (§IV-A).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.registry import get_config
from repro.core.baselines import oracle
from repro.core.evaluate import RegimeTargets
from repro.device.hw import get_profile
from repro.device.simulator import DeviceSimulator, build_cell_simulator


@dataclasses.dataclass(frozen=True)
class Workload:
    """One workload trace: the step shape and the measurement regime.

    ``kind`` selects the roofline shape (decode: memory-bound weight
    streaming amortized over ``batch``; prefill: compute-bound over
    ``seq`` prompt tokens). ``noise`` is the relative σ of the 1-second
    tegrastats-style samples — bursty traffic reads noisier (τ, p).
    """

    name: str
    kind: str  # decode | prefill
    batch: int = 8
    seq: int = 256
    noise: float = 0.02


@dataclasses.dataclass(frozen=True)
class Regime:
    """One constraint regime, relative to the cell's own landscape.

    ``tau_frac`` — τ target as a fraction of the cell's max throughput
    (0 → no target). ``p_slack`` — power budget as a multiple of the
    power the single-target oracle draws (None → uncapped). ``mode`` is
    the CORAL objective ("dual" or "throughput").
    """

    name: str
    mode: str
    tau_frac: float = 0.0
    p_slack: Optional[float] = None

    @property
    def single_target(self) -> bool:
        return self.p_slack is None

    @property
    def dual_constraint(self) -> bool:
        return self.p_slack is not None


@dataclasses.dataclass(frozen=True)
class Cell:
    device: str
    model: str
    workload: str
    regime: str

    def key(self) -> Tuple[str, str, str, str]:
        return (self.device, self.model, self.workload, self.regime)


WORKLOADS: Dict[str, Workload] = {
    w.name: w
    for w in (
        Workload("decode_steady", kind="decode", batch=8, noise=0.02),
        Workload("decode_bursty", kind="decode", batch=8, noise=0.04),
        Workload("prefill_steady", kind="prefill", seq=256, noise=0.02),
    )
}

REGIMES: Dict[str, Regime] = {
    r.name: r
    for r in (
        # single-target: meet a τ floor at best efficiency (paper Fig. 3/4)
        Regime("single_tau", mode="dual", tau_frac=0.55),
        # single-target: maximize raw throughput (paper §IV-B)
        Regime("max_throughput", mode="throughput"),
        # strict dual: τ floor AND a tight power cap (paper Fig. 5/6).
        # The higher τ floor + 1.2× slack keeps every cell's feasible set
        # at ~10-20% of the grid — strict enough that presets and ALERT
        # bust the cap, wide enough that CORAL's 10-measurement budget
        # reliably lands inside (the paper's §IV-C operating point).
        Regime("strict_dual", mode="dual", tau_frac=0.7, p_slack=1.2),
    )
}

# Default grid axes: the paper's 2 devices × 3 models × 2 regimes shape,
# with the model axis spanning a ~6× active-parameter range (the paper's
# detectors span ~20× — same heavy-tail idea on registry architectures).
MATRIX_DEVICES: Tuple[str, ...] = ("edge-xavier-nx", "edge-orin-nano")
MATRIX_MODELS: Tuple[str, ...] = ("qwen2.5-3b", "granite-8b", "internlm2-20b")
MATRIX_WORKLOADS: Tuple[str, ...] = ("decode_steady",)
MATRIX_REGIMES: Tuple[str, ...] = ("single_tau", "max_throughput", "strict_dual")

FULL_MATRIX_WORKLOADS: Tuple[str, ...] = (
    "decode_steady",
    "decode_bursty",
    "prefill_steady",
)


def enumerate_cells(
    devices: Sequence[str] = MATRIX_DEVICES,
    models: Sequence[str] = MATRIX_MODELS,
    workloads: Sequence[str] = MATRIX_WORKLOADS,
    regimes: Sequence[str] = MATRIX_REGIMES,
) -> List[Cell]:
    """The exhaustive cell list, in deterministic axis-major order
    (devices outermost, regimes innermost). Unknown names fail fast."""
    for d in devices:
        get_profile(d)
    for m in models:
        get_config(m)
    unknown = [w for w in workloads if w not in WORKLOADS]
    unknown += [r for r in regimes if r not in REGIMES]
    if unknown:
        raise KeyError(f"unknown workload/regime names: {unknown}")
    return [
        Cell(d, m, w, r)
        for d in devices
        for m in models
        for w in workloads
        for r in regimes
    ]


def cell_simulator(
    cell: Cell, noise: Optional[float] = None, seed: int = 0
) -> DeviceSimulator:
    """Build the cell's device: profile knobs + model footprint + workload
    shape. ``noise=None`` uses the workload's trace noise; ``noise=0.0``
    gives the noise-free ground-truth twin ORACLE and scoring use."""
    w = WORKLOADS[cell.workload]
    return build_cell_simulator(
        get_profile(cell.device),
        get_config(cell.model),
        kind=w.kind,
        batch=w.batch,
        seq=w.seq,
        noise=w.noise if noise is None else noise,
        seed=seed,
    )


def resolve_targets(
    cell: Cell, sim0: Optional[DeviceSimulator] = None
) -> RegimeTargets:
    """Absolute (τ target, power budget) for a cell, from its noise-free
    landscape: τ target = tau_frac · max-τ; budget = p_slack × the power
    of the single-target oracle (so the cap is strict but satisfiable)."""
    regime = REGIMES[cell.regime]
    if sim0 is None:
        sim0 = cell_simulator(cell, noise=0.0)
    tau_target = 0.0
    if regime.tau_frac > 0.0:
        om = oracle(sim0.space, sim0, tau_target=0.0)
        tau_target = round(regime.tau_frac * om.tau, 3)
    p_budget = float("inf")
    if regime.p_slack is not None:
        anchor = oracle(sim0.space, sim0, tau_target)
        p_budget = anchor.power * regime.p_slack
    return RegimeTargets(mode=regime.mode, tau_target=tau_target, p_budget=p_budget)
