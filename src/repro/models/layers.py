"""Parameter-spec system + elementary layers.

Single source of truth: every model declares a pytree of ``ParamSpec``
(shape + logical axes + init scale). From that one tree we derive
  * real parameters      (``init_params``)
  * abstract parameters  (``abstract_params`` — ShapeDtypeStruct, no alloc)
  * sharding specs       (``repro.sharding.specs`` maps logical axes -> mesh)
so the dry-run, the trainer and the tests can never drift apart.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis name per dim (None = replicated)
    init: str = "normal"  # normal | zeros | ones | small_normal
    scale: float = 1.0  # stddev multiplier (normal: scale / sqrt(fan_in))

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn, specs: PyTree) -> PyTree:
    return jax.tree.map(fn, specs, is_leaf=_is_spec)


def init_params(key: jax.Array, specs: PyTree, dtype=jnp.float32) -> PyTree:
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))

    def one(k, s: ParamSpec):
        if s.init == "zeros":
            return jnp.zeros(s.shape, dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, dtype)
        fan_in = s.shape[-2] if len(s.shape) >= 2 else max(s.shape[-1], 1)
        std = s.scale / np.sqrt(fan_in)
        return (jax.random.normal(k, s.shape, jnp.float32) * std).astype(dtype)

    return jax.tree.unflatten(treedef, [one(k, s) for k, s in zip(keys, leaves)])


def abstract_params(specs: PyTree, dtype=jnp.float32) -> PyTree:
    return tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs
    )


def param_axes(specs: PyTree) -> PyTree:
    return tree_map_specs(lambda s: s.axes, specs)


# ---------------------------------------------------------------------------
# Elementary ops (pure functions over arrays)
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def swiglu(x: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, wg.astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, wu.astype(x.dtype))
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, wd.astype(x.dtype))


def gelu_mlp(x, w1, b1, w2, b2):
    h = jnp.einsum("...d,df->...f", x, w1.astype(x.dtype)) + b1.astype(x.dtype)
    h = jax.nn.gelu(h)
    return jnp.einsum("...f,fd->...d", h, w2.astype(x.dtype)) + b2.astype(x.dtype)


def sinusoidal_positions(n: int, d: int, dtype=jnp.float32) -> jax.Array:
    """Whisper-style sinusoidal absolute position embedding table."""
    pos = np.arange(n)[:, None]
    dim = np.arange(d // 2)[None, :]
    inv = np.exp(-np.log(10_000.0) * dim / max(d // 2 - 1, 1))
    ang = pos * inv
    tab = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(tab, dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,d/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array,
    positions: jax.Array,
    theta: float,
    sections: Tuple[int, int, int],
) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL).

    x: (B, S, H, D); positions: (3, B, S) int32 — temporal/height/width
    position ids. The D/2 rotary frequencies are split into ``sections``
    (t, h, w); each section takes its angle from the matching position id.
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (3,B,S,d/2)
    sec = jnp.concatenate(
        [jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)]
    )  # (d/2,)
    ang = jnp.take_along_axis(
        jnp.moveaxis(ang, 0, -1), sec[None, None, :, None], axis=-1
    )[..., 0]  # (B,S,d/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
