"""DeepSeek-V2 Multi-head Latent Attention (arXiv:2405.04434).

Prefill/train: latent is expanded to per-head K/V and runs through the
shared blocked attention. Decode: production **matrix-absorption** form —
scores are computed directly against the cached latent (plus the shared
RoPE key), so per-token decode cost is O(W·r) instead of O(W·H·d).
The KV cache stores only (latent, k_rope): 512+64 floats/token.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.runtime import RunConfig
from repro.models.attention import NEG_INF, _mask, attention
from repro.models.layers import ParamSpec, apply_rope, rms_norm


def mla_param_specs(cfg: ModelConfig, n_layers: int) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    L = (n_layers,)
    lx = ("layers",)
    return {
        "wq_a": ParamSpec(L + (d, m.q_lora_rank), lx + ("embed", None)),
        "q_norm": ParamSpec(L + (m.q_lora_rank,), lx + (None,), init="ones"),
        "wq_b": ParamSpec(
            L + (m.q_lora_rank, h * (m.qk_nope_head_dim + m.qk_rope_head_dim)),
            lx + (None, "heads_flat"),
        ),
        "wkv_a": ParamSpec(
            L + (d, m.kv_lora_rank + m.qk_rope_head_dim), lx + ("embed", None)
        ),
        "kv_norm": ParamSpec(L + (m.kv_lora_rank,), lx + (None,), init="ones"),
        "wkv_b": ParamSpec(
            L + (m.kv_lora_rank, h * (m.qk_nope_head_dim + m.v_head_dim)),
            lx + (None, "heads_flat"),
        ),
        "wo": ParamSpec(L + (h * m.v_head_dim, d), lx + ("heads_flat", "embed")),
    }


def _queries(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array):
    m = cfg.mla
    h = cfg.n_heads
    b, s, _ = x.shape
    ql = rms_norm(
        jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(x.dtype)),
        p["q_norm"], cfg.norm_eps,
    )
    q = jnp.einsum("bsr,re->bse", ql, p["wq_b"].astype(x.dtype))
    q = q.reshape(b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latent(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array):
    m = cfg.mla
    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(x.dtype))
    latent, k_rope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    latent = rms_norm(latent, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return latent, k_rope  # (B,S,r), (B,S,dr)


def mla_full(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    rcfg: RunConfig,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full-sequence MLA (train / prefill). Returns (out, (latent, k_rope))."""
    m = cfg.mla
    h = cfg.n_heads
    b, s, _ = x.shape
    q_nope, q_rope = _queries(cfg, p, x, positions)
    latent, k_rope = _latent(cfg, p, x, positions)
    kvb = p["wkv_b"].astype(x.dtype).reshape(
        m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim
    )
    kv = jnp.einsum("bsr,rhe->bshe", latent, kvb)
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    k_rope_h = jnp.broadcast_to(
        k_rope[:, :, None, :], (b, s, h, m.qk_rope_head_dim)
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    out = attention(q, k, v, positions, positions, causal=True, rcfg=rcfg)
    out = out.reshape(b, s, h * m.v_head_dim)
    out = jnp.einsum("bse,ed->bsd", out, p["wo"].astype(x.dtype))
    return out, (latent, k_rope)


def mla_decode(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,  # (B,1,d)
    positions: jax.Array,  # (B,1)
    latent_cache: jax.Array,  # (B,W,r)  — includes the just-written token
    krope_cache: jax.Array,  # (B,W,dr)
    kv_pos: jax.Array,  # (B,W) slot positions (negative = invalid)
) -> jax.Array:
    """Absorbed single-token decode."""
    m = cfg.mla
    h = cfg.n_heads
    b = x.shape[0]
    q_nope, q_rope = _queries(cfg, p, x, positions)  # (B,1,H,dn),(B,1,H,dr)
    kvb = p["wkv_b"].astype(x.dtype).reshape(
        m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim
    )
    wk = kvb[:, :, : m.qk_nope_head_dim]  # (r,H,dn)
    wv = kvb[:, :, m.qk_nope_head_dim :]  # (r,H,dv)
    q_eff = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32),
                       wk.astype(jnp.float32))  # (B,1,H,r)
    scale = 1.0 / jnp.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    scores = (
        jnp.einsum("bshr,bwr->bhsw", q_eff, latent_cache.astype(jnp.float32))
        + jnp.einsum(
            "bshd,bwd->bhsw",
            q_rope.astype(jnp.float32),
            krope_cache.astype(jnp.float32),
        )
    ) * scale
    msk = _mask(positions, kv_pos, True, None)  # (B,1,W)
    scores = jnp.where(msk[:, None, :, :], scores, NEG_INF)
    pr = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bhsw,bwr->bshr", pr, latent_cache.astype(jnp.float32))
    out = jnp.einsum("bshr,rhv->bshv", out_lat, wv.astype(jnp.float32))
    out = out.astype(x.dtype).reshape(b, 1, h * m.v_head_dim)
    return jnp.einsum("bse,ed->bsd", out, p["wo"].astype(x.dtype))
