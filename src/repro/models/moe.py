"""Mixture-of-Experts FFN.

Two implementations:
  * ``dense``            — compute-all-experts + one-hot combine. Exact and
                           differentiable; used for reduced smoke configs
                           and single-device runs (its FLOP waste is E/k×).
  * ``expert_parallel``  — production path: tokens sharded over the data
                           axes, experts sharded over "model". Inside
                           shard_map: router → top-k → sort-by-expert →
                           fixed-capacity dispatch buffers → all_to_all →
                           grouped per-expert GEMMs → all_to_all back →
                           weighted combine → all_gather. Exactly two
                           all-to-alls per MoE layer, matching the
                           collective roofline of a real MoE pod.

Token dropping follows the standard fixed-capacity model
(capacity = ceil(T_sub·k·cf / E)).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import ModelConfig
from repro.configs.runtime import RunConfig
from repro.models.layers import ParamSpec, swiglu


def moe_param_specs(cfg: ModelConfig, n_layers: int) -> dict:
    e = cfg.moe
    d = cfg.d_model
    L = (n_layers,)
    lx = ("layers",)
    specs = {
        "router": ParamSpec(L + (d, e.n_experts), lx + ("embed", None)),
        "we_gate": ParamSpec(
            L + (e.n_experts, d, e.d_ff_expert), lx + ("experts", "embed", "ff")
        ),
        "we_up": ParamSpec(
            L + (e.n_experts, d, e.d_ff_expert), lx + ("experts", "embed", "ff")
        ),
        "we_down": ParamSpec(
            L + (e.n_experts, e.d_ff_expert, d), lx + ("experts", "ff", "embed")
        ),
    }
    if e.n_shared_experts:
        ff_sh = e.d_ff_expert * e.n_shared_experts
        specs.update(
            {
                "ws_gate": ParamSpec(L + (d, ff_sh), lx + ("embed", "ff")),
                "ws_up": ParamSpec(L + (d, ff_sh), lx + ("embed", "ff")),
                "ws_down": ParamSpec(L + (ff_sh, d), lx + ("ff", "embed")),
            }
        )
    return specs


def _route(cfg: ModelConfig, router_w: jax.Array, xt: jax.Array):
    """xt: (T,d) -> (weights (T,k), ids (T,k), probs (T,E))."""
    e = cfg.moe
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, e.top_k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    return topw, topi, probs


def _aux_loss(cfg: ModelConfig, probs: jax.Array, topi: jax.Array) -> jax.Array:
    """Switch-style load-balance loss: E · Σ_e f_e · P_e."""
    e = cfg.moe
    onehot = jax.nn.one_hot(topi, e.n_experts, dtype=jnp.float32)  # (T,k,E)
    f = onehot.sum((0, 1)) / (topi.shape[0] * e.top_k)
    p = probs.mean(0)
    return e.n_experts * jnp.sum(f * p)


def _shared(p: dict, xt: jax.Array) -> jax.Array:
    if "ws_gate" not in p:
        return jnp.zeros_like(xt)
    return swiglu(xt, p["ws_gate"], p["ws_up"], p["ws_down"])


def moe_ffn_dense(cfg: ModelConfig, p: dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Compute-all-experts reference path. x: (B,S,d)."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    topw, topi, probs = _route(cfg, p["router"], xt)
    cdt = x.dtype
    g = jnp.einsum("td,edf->tef", xt, p["we_gate"].astype(cdt))
    u = jnp.einsum("td,edf->tef", xt, p["we_up"].astype(cdt))
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("tef,efd->ted", h, p["we_down"].astype(cdt))
    combine = jnp.zeros((xt.shape[0], cfg.moe.n_experts), jnp.float32)
    combine = jax.vmap(lambda c, i, w: c.at[i].add(w))(combine, topi, topw)
    y = jnp.einsum("ted,te->td", ye.astype(jnp.float32), combine).astype(cdt)
    y = y + _shared(p, xt)
    return y.reshape(b, s, d), _aux_loss(cfg, probs, topi)


# ---------------------------------------------------------------------------
# Expert-parallel path
# ---------------------------------------------------------------------------


def _capacity(cfg: ModelConfig, t_sub: int, cf: float) -> int:
    e = cfg.moe
    cap = int(math.ceil(t_sub * e.top_k * cf / e.n_experts))
    return max(8, -(-cap // 8) * 8)  # round up to a multiple of 8


def moe_ffn_ep(
    cfg: ModelConfig,
    rcfg: RunConfig,
    mesh,
    p: dict,
    x: jax.Array,  # (B,S,d) global
) -> Tuple[jax.Array, jax.Array]:
    e = cfg.moe
    model_axis = "model"
    n_model = mesh.shape[model_axis]
    data_axes = tuple(a for a in mesh.axis_names if a != model_axis)
    n_data = math.prod(mesh.shape[a] for a in data_axes)
    b, s, d = x.shape
    batch_spec = data_axes if (b % max(n_data, 1) == 0 and n_data > 1) else None
    x_spec = P(batch_spec, None, None)

    def block(xl, router_w, wg, wu, wd, shared_p):
        bl, sl, _ = xl.shape
        t = bl * sl
        xt = xl.reshape(t, d)
        # each model shard routes its own slice of the local tokens
        t_pad = -(-t // n_model) * n_model
        xt_p = jnp.pad(xt, ((0, t_pad - t), (0, 0)))
        t_sub = t_pad // n_model
        midx = jax.lax.axis_index(model_axis)
        xs = jax.lax.dynamic_slice_in_dim(xt_p, midx * t_sub, t_sub)  # (Tsub,d)

        topw, topi, probs = _route(cfg, router_w, xs)
        tk = t_sub * e.top_k
        eid = topi.reshape(tk)
        tokid = jnp.repeat(jnp.arange(t_sub), e.top_k)
        w_assign = topw.reshape(tk)

        cap = _capacity(cfg, t_sub, rcfg.capacity_factor)
        order = jnp.argsort(eid)  # stable
        eid_s, tok_s, w_s = eid[order], tokid[order], w_assign[order]
        counts = jnp.zeros((e.n_experts,), jnp.int32).at[eid].add(1)
        start = jnp.cumsum(counts) - counts
        pos = jnp.arange(tk) - start[eid_s]
        pos = jnp.where(pos < cap, pos, cap)  # cap -> out of bounds -> dropped

        buf = jnp.zeros((e.n_experts, cap, d), xt.dtype)
        buf = buf.at[eid_s, pos].set(xs[tok_s], mode="drop")
        # -> expert owners: (E_loc, n_model*cap, d)
        recv = jax.lax.all_to_all(
            buf, model_axis, split_axis=0, concat_axis=1, tiled=True
        )
        cdt = xt.dtype
        g = jnp.einsum("ecd,edf->ecf", recv, wg.astype(cdt))
        u = jnp.einsum("ecd,edf->ecf", recv, wu.astype(cdt))
        yexp = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wd.astype(cdt))
        back = jax.lax.all_to_all(
            yexp, model_axis, split_axis=1, concat_axis=0, tiled=True
        )  # (E, cap, d) — original dispatch layout
        y_tok = back.at[eid_s, jnp.minimum(pos, cap - 1)].get(mode="clip")
        y_tok = jnp.where((pos < cap)[:, None], y_tok, 0.0)
        contrib = y_tok * w_s[:, None].astype(y_tok.dtype)
        ysub = jnp.zeros((t_sub, d), jnp.float32).at[tok_s].add(
            contrib.astype(jnp.float32)
        )
        ysub = ysub.astype(cdt) + _shared(shared_p, xs)
        yl = jax.lax.all_gather(ysub, model_axis, axis=0, tiled=True)  # (t_pad,d)
        yl = yl[:t].reshape(bl, sl, d)
        aux = _aux_loss(cfg, probs, topi)
        aux = jax.lax.pmean(aux, model_axis)
        for ax in data_axes:
            aux = jax.lax.pmean(aux, ax)
        return yl, aux

    shared_p = {k: p[k] for k in ("ws_gate", "ws_up", "ws_down") if k in p}
    shared_specs = {k: P(None, None) for k in shared_p}
    out = shard_map(
        block,
        mesh=mesh,
        in_specs=(
            x_spec,
            P(None, None),
            P(model_axis, None, None),
            P(model_axis, None, None),
            P(model_axis, None, None),
            shared_specs,
        ),
        out_specs=(x_spec, P()),
        check_rep=False,
    )(x, p["router"], p["we_gate"], p["we_up"], p["we_down"], shared_p)
    return out


def moe_ffn_ep2d(
    cfg: ModelConfig,
    rcfg: RunConfig,
    mesh,
    p: dict,
    x: jax.Array,  # (B,S,d) global
) -> Tuple[jax.Array, jax.Array]:
    """2D expert sharding for serving: experts→model, d_ff_expert→data.

    Expert weights stay fully sharded across all 256 chips (they must —
    236B does not fit replicated), but instead of fsdp-gathering ~50 GB of
    weights per decode step, each data shard computes a d_ff slice of every
    expert and the down-projection partial-sums reduce with a ~MB-scale
    activation psum. Token counts at decode are tiny, so replicating them
    over the data axes is free.
    """
    e = cfg.moe
    model_axis = "model"
    n_model = mesh.shape[model_axis]
    data_axes = tuple(a for a in mesh.axis_names if a != model_axis)
    b, s, d = x.shape

    def block(xl, router_w, wg, wu, wd, shared_p):
        # xl: full (B,S,d); wg/wu: (E_loc, d, ff_loc); wd: (E_loc, ff_loc, d)
        t = b * s
        xt = xl.reshape(t, d)
        t_pad = -(-t // n_model) * n_model
        xt_p = jnp.pad(xt, ((0, t_pad - t), (0, 0)))
        t_sub = t_pad // n_model
        midx = jax.lax.axis_index(model_axis)
        xs = jax.lax.dynamic_slice_in_dim(xt_p, midx * t_sub, t_sub)

        topw, topi, probs = _route(cfg, router_w, xs)
        tk = t_sub * e.top_k
        eid = topi.reshape(tk)
        tokid = jnp.repeat(jnp.arange(t_sub), e.top_k)
        w_assign = topw.reshape(tk)
        cap = _capacity(cfg, t_sub, rcfg.capacity_factor)
        order = jnp.argsort(eid)
        eid_s, tok_s, w_s = eid[order], tokid[order], w_assign[order]
        counts = jnp.zeros((e.n_experts,), jnp.int32).at[eid].add(1)
        start = jnp.cumsum(counts) - counts
        pos = jnp.arange(tk) - start[eid_s]
        pos = jnp.where(pos < cap, pos, cap)

        buf = jnp.zeros((e.n_experts, cap, d), xt.dtype)
        buf = buf.at[eid_s, pos].set(xs[tok_s], mode="drop")
        recv = jax.lax.all_to_all(
            buf, model_axis, split_axis=0, concat_axis=1, tiled=True
        )
        cdt = xt.dtype
        g = jnp.einsum("ecd,edf->ecf", recv, wg.astype(cdt))
        u = jnp.einsum("ecd,edf->ecf", recv, wu.astype(cdt))
        yexp = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wd.astype(cdt))
        # partial over the ff slice -> reduce across the data axes
        for ax in data_axes:
            yexp = jax.lax.psum(yexp, ax)
        back = jax.lax.all_to_all(
            yexp, model_axis, split_axis=1, concat_axis=0, tiled=True
        )
        y_tok = back.at[eid_s, jnp.minimum(pos, cap - 1)].get(mode="clip")
        y_tok = jnp.where((pos < cap)[:, None], y_tok, 0.0)
        contrib = y_tok * w_s[:, None].astype(y_tok.dtype)
        ysub = jnp.zeros((t_sub, d), jnp.float32).at[tok_s].add(
            contrib.astype(jnp.float32)
        ).astype(cdt)
        if "ws_gate" in shared_p:  # shared experts: same ff-slice + psum
            gs = jnp.einsum("td,df->tf", xs, shared_p["ws_gate"].astype(cdt))
            us = jnp.einsum("td,df->tf", xs, shared_p["ws_up"].astype(cdt))
            ys = jnp.einsum("tf,fd->td", jax.nn.silu(gs) * us,
                            shared_p["ws_down"].astype(cdt))
            for ax in data_axes:
                ys = jax.lax.psum(ys, ax)
            ysub = ysub + ys
        yl = jax.lax.all_gather(ysub, model_axis, axis=0, tiled=True)
        yl = yl[:t].reshape(b, s, d)
        aux = _aux_loss(cfg, probs, topi)
        aux = jax.lax.pmean(aux, model_axis)
        return yl, aux

    shared_p = {k: p[k] for k in ("ws_gate", "ws_up", "ws_down") if k in p}
    da = data_axes[0] if len(data_axes) == 1 else data_axes
    shared_specs = {
        "ws_gate": P(None, da),
        "ws_up": P(None, da),
        "ws_down": P(da, None),
    }
    shared_specs = {k: v for k, v in shared_specs.items() if k in shared_p}
    out = shard_map(
        block,
        mesh=mesh,
        in_specs=(
            P(None, None, None),  # tokens replicated (tiny at decode)
            P(None, None),
            P(model_axis, None, da),
            P(model_axis, None, da),
            P(model_axis, da, None),
            shared_specs,
        ),
        out_specs=(P(None, None, None), P()),
        check_rep=False,
    )(x, p["router"], p["we_gate"], p["we_up"], p["we_down"], shared_p)
    return out


def moe_ffn(
    cfg: ModelConfig,
    rcfg: RunConfig,
    mesh,
    p: dict,
    x: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    impl = rcfg.moe_impl
    if impl == "auto":
        impl = (
            "expert_parallel"
            if mesh is not None
            and mesh.shape.get("model", 1) > 1
            and cfg.moe.n_experts % mesh.shape["model"] == 0
            else "dense"
        )
    if impl == "expert_parallel_2d":
        return moe_ffn_ep2d(cfg, rcfg, mesh, p, x)
    if impl == "expert_parallel":
        return moe_ffn_ep(cfg, rcfg, mesh, p, x)
    return moe_ffn_dense(cfg, p, x)
