"""Attention: GQA/MHA with causal + sliding-window masking.

Two execution paths:
  * direct   — single einsum; used for short KV (decode steps, smoke tests).
  * blocked  — online-softmax over (block_q × block_k) tiles via lax.map /
               lax.scan; used for long-sequence prefill/training so the
               S×S score matrix never materializes. This is the XLA twin of
               ``repro.kernels.flash_attention`` (the TPU Pallas deployment
               path) and what the multi-pod dry-run lowers.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.runtime import RunConfig

NEG_INF = -1e30


def _mask(q_pos, kv_pos, causal: bool, window: Optional[int]):
    """q_pos: (..., Sq), kv_pos: (..., Skv) -> bool (..., Sq, Skv)."""
    qp = q_pos[..., :, None]
    kp = kv_pos[..., None, :]
    m = kp >= 0  # negative kv positions mark invalid (unwritten ring slots)
    if causal:
        m &= kp <= qp
    if window is not None:
        m &= (qp - kp) < window
    return m


def _direct(q, k, v, q_pos, kv_pos, causal, window, scale):
    b, sq, hkv, g, d = q.shape
    # bf16 operands + f32 accumulation: avoids materializing an f32 copy of
    # the KV cache on the decode path (§Perf hillclimb #3)
    scores = jnp.einsum(
        "bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32
    ) * scale
    m = _mask(q_pos, kv_pos, causal, window)  # (B,Sq,Skv)
    scores = jnp.where(m[:, None, None, :, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return out


def _blocked(q, k, v, q_pos, kv_pos, causal, window, scale, bq, bk):
    """Online-softmax attention over q/kv tiles (flash-style, pure XLA)."""
    b, sq, hkv, g, d = q.shape
    skv = k.shape[1]
    bq = min(bq, sq)
    bk = min(bk, skv)
    # pad seq lens to multiples of the block sizes
    pq = (-sq) % bq
    pk = (-skv) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pq)), constant_values=0)
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pk)), constant_values=-1)
    nq, nk = (sq + pq) // bq, (skv + pk) // bk

    qb = q.reshape(b, nq, bq, hkv, g, d).transpose(1, 0, 2, 3, 4, 5)
    qpb = q_pos.reshape(b, nq, bq).transpose(1, 0, 2)
    kb = k.reshape(b, nk, bk, hkv, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nk, bk, hkv, v.shape[-1]).transpose(1, 0, 2, 3, 4)
    kpb = kv_pos.reshape(b, nk, bk).transpose(1, 0, 2)

    def per_qblock(args):
        qi, qpi = args  # (B,bq,Hkv,G,D), (B,bq)

        def kv_step(carry, xs):
            acc, mx, dn = carry
            ki, vi, kpi = xs  # (B,bk,Hkv,D), (B,bk)
            s = jnp.einsum(
                "bqhgd,bkhd->bhgqk", qi.astype(jnp.float32), ki.astype(jnp.float32)
            ) * scale
            m = _mask(qpi, kpi, causal, window)
            s = jnp.where(m[:, None, None, :, :], s, NEG_INF)
            mx_new = jnp.maximum(mx, s.max(axis=-1))
            corr = jnp.exp(mx - mx_new)
            p = jnp.exp(s - mx_new[..., None])
            dn = dn * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vi.astype(jnp.float32))
            acc = acc * corr[..., None] + pv
            return (acc, mx_new, dn), None

        acc0 = jnp.zeros((b, hkv, g, bq, v.shape[-1]), jnp.float32)
        mx0 = jnp.full((b, hkv, g, bq), NEG_INF, jnp.float32)
        dn0 = jnp.zeros((b, hkv, g, bq), jnp.float32)
        (acc, _, dn), _ = jax.lax.scan(kv_step, (acc0, mx0, dn0), (kb, vb, kpb))
        out = acc / jnp.maximum(dn[..., None], 1e-30)
        return out.transpose(0, 3, 1, 2, 4)  # (B,bq,Hkv,G,D)

    ob = jax.lax.map(per_qblock, (qb, qpb))  # (nq,B,bq,Hkv,G,Dv)
    out = ob.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq + pq, hkv, g, v.shape[-1])
    return out[:, :sq].astype(v.dtype)


def _blocked_swa(q, k, v, q_pos, kv_pos, window, scale, bq, bk):
    """Sliding-window attention with static KV slicing.

    For a static window W and contiguous positions (training/prefill), each
    q tile only attends to the ⌈(W+bq)/bk⌉+1 KV tiles covering
    [q_start − W, q_end] — O(S·W) work instead of a masked O(S²) grid.
    """
    b, sq, hkv, g, d = q.shape
    skv = k.shape[1]
    bq = min(bq, sq)
    bk = min(bk, skv)
    pq, pk = (-sq) % bq, (-skv) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pq)), constant_values=0)
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pk)), constant_values=-1)
    nq, nk = (sq + pq) // bq, (skv + pk) // bk
    nspan = min((window + bq) // bk + 2, nk)

    qb = q.reshape(b, nq, bq, hkv, g, d).transpose(1, 0, 2, 3, 4, 5)
    qpb = q_pos.reshape(b, nq, bq).transpose(1, 0, 2)
    kb = k.reshape(b, nk, bk, hkv, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nk, bk, hkv, v.shape[-1]).transpose(1, 0, 2, 3, 4)
    kpb = kv_pos.reshape(b, nk, bk).transpose(1, 0, 2)

    def per_qblock(args):
        i, qi, qpi = args  # block idx, (B,bq,Hkv,G,D), (B,bq)
        start = jnp.clip((i * bq - window) // bk, 0, nk - nspan)
        kspan = jax.lax.dynamic_slice_in_dim(kb, start, nspan, axis=0)
        vspan = jax.lax.dynamic_slice_in_dim(vb, start, nspan, axis=0)
        pspan = jax.lax.dynamic_slice_in_dim(kpb, start, nspan, axis=0)

        def kv_step(carry, xs):
            acc, mx, dn = carry
            ki, vi, kpi = xs
            s = jnp.einsum(
                "bqhgd,bkhd->bhgqk", qi.astype(jnp.float32), ki.astype(jnp.float32)
            ) * scale
            m = _mask(qpi, kpi, True, window)
            s = jnp.where(m[:, None, None, :, :], s, NEG_INF)
            mx_new = jnp.maximum(mx, s.max(axis=-1))
            corr = jnp.exp(mx - mx_new)
            p = jnp.exp(s - mx_new[..., None])
            dn = dn * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vi.astype(jnp.float32))
            acc = acc * corr[..., None] + pv
            return (acc, mx_new, dn), None

        acc0 = jnp.zeros((b, hkv, g, bq, v.shape[-1]), jnp.float32)
        mx0 = jnp.full((b, hkv, g, bq), NEG_INF, jnp.float32)
        dn0 = jnp.zeros((b, hkv, g, bq), jnp.float32)
        (acc, _, dn), _ = jax.lax.scan(kv_step, (acc0, mx0, dn0),
                                       (kspan, vspan, pspan))
        out = acc / jnp.maximum(dn[..., None], 1e-30)
        return out.transpose(0, 3, 1, 2, 4)

    ob = jax.lax.map(per_qblock, (jnp.arange(nq), qb, qpb))
    out = ob.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq + pq, hkv, g, v.shape[-1])
    return out[:, :sq].astype(v.dtype)


def attention(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Skv, Hkv, D)
    v: jax.Array,  # (B, Skv, Hkv, D)
    q_pos: jax.Array,  # (B, Sq) int32
    kv_pos: jax.Array,  # (B, Skv) int32 (negative = invalid slot)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    rcfg: RunConfig = RunConfig(),
) -> jax.Array:
    """Grouped-query attention. Returns (B, Sq, Hq, D)."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)

    if rcfg.use_pallas and sq > 1:
        from repro.kernels.flash_attention import ops as fa_ops

        out = fa_ops.flash_attention(
            qg, k, v, q_pos, kv_pos, causal=causal, window=window,
            block_q=rcfg.attn_block_q, block_k=rcfg.attn_block_k,
        )
    elif sq * k.shape[1] > rcfg.attn_blocked_threshold**2:
        if (
            causal
            and isinstance(window, int)
            and sq == k.shape[1]
            and window < k.shape[1]
        ):
            out = _blocked_swa(
                qg, k, v, q_pos, kv_pos, window, scale,
                rcfg.attn_block_q, rcfg.attn_block_k,
            )
        else:
            out = _blocked(
                qg, k, v, q_pos, kv_pos, causal, window, scale,
                rcfg.attn_block_q, rcfg.attn_block_k,
            )
    else:
        out = _direct(qg, k, v, q_pos, kv_pos, causal, window, scale)
    return out.reshape(b, sq, hq, v.shape[-1]).astype(v.dtype)
