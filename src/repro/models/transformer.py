"""Model assembly: every assigned architecture is built from one generic
decoder stack (+ optional encoder for enc-dec), driven entirely by
``ModelConfig``.

Layer stacking follows the MaxText pattern: per-layer parameters carry a
leading ``layers`` axis and the stack is applied with ``lax.scan`` (so a
94-layer config lowers/compiles one layer body). Heterogeneous leading
layers (dense-FFN prologue of DeepSeek/Moonlight MoE) are applied unrolled
before the scan.

Three entry points per architecture:
  forward_train(ctx, params, batch)             -> (logits, aux)
  prefill(ctx, params, batch)                   -> (cache, last_logits)
  decode_step(ctx, params, cache, tokens)       -> (cache, logits)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.runtime import RunConfig
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.attention import attention
from repro.models.layers import (
    ParamSpec,
    abstract_params,
    apply_mrope,
    apply_rope,
    init_params,
    layer_norm,
    param_axes,
    rms_norm,
    swiglu,
)
from repro.models.mla import mla_decode, mla_full, mla_param_specs

BIG_WINDOW = 1 << 30


@dataclasses.dataclass(frozen=True)
class ApplyCtx:
    cfg: ModelConfig
    rcfg: RunConfig
    mesh: Any = None  # jax Mesh or None (single device)


def constrain_batch(ctx: ApplyCtx, x: jax.Array) -> jax.Array:
    """Pin activations to batch-sharding over the data axes.

    Without this, XLA's SPMD partitioner may resolve the fsdp weight
    sharding by replicating the token dimension instead of gathering the
    weights — flop-equivalent per chip for plain matmuls but catastrophic
    for attention (S² work replicated 16×) and activation memory.
    """
    if ctx.mesh is None:
        return x
    if getattr(ctx.rcfg, "decode_tp_over_data", False) and x.shape[1] == 1:
        return x  # decode TP mode: leave single-token activations unpinned
    import math

    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.sharding.specs import data_axes

    da = data_axes(ctx.mesh)
    if not da:
        return x
    size = math.prod(ctx.mesh.shape[a] for a in da)
    if size <= 1 or x.shape[0] % size != 0:
        return x
    spec = P(da, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def _attn_specs(cfg: ModelConfig, n: int, cross: bool = False) -> dict:
    if cfg.mla is not None and not cross:
        return mla_param_specs(cfg, n)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    L = (n,)
    lx = ("layers",)
    s = {
        "wq": ParamSpec(L + (d, hq * hd), lx + ("embed", "heads_flat")),
        "wk": ParamSpec(L + (d, hkv * hd), lx + ("embed", "kv_heads_flat")),
        "wv": ParamSpec(L + (d, hkv * hd), lx + ("embed", "kv_heads_flat")),
        "wo": ParamSpec(L + (hq * hd, d), lx + ("heads_flat", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec(L + (hq * hd,), lx + ("heads_flat",), init="zeros")
        s["bk"] = ParamSpec(L + (hkv * hd,), lx + ("kv_heads_flat",), init="zeros")
        s["bv"] = ParamSpec(L + (hkv * hd,), lx + ("kv_heads_flat",), init="zeros")
    if cfg.qk_norm:
        s["q_norm"] = ParamSpec(L + (hd,), lx + (None,), init="ones")
        s["k_norm"] = ParamSpec(L + (hd,), lx + (None,), init="ones")
    return s


def _ffn_specs(cfg: ModelConfig, n: int, dense: bool) -> dict:
    d = cfg.d_model
    L = (n,)
    lx = ("layers",)
    if dense or cfg.moe is None:
        s = {
            "wg": ParamSpec(L + (d, cfg.d_ff), lx + ("embed", "ff")),
            "wu": ParamSpec(L + (d, cfg.d_ff), lx + ("embed", "ff")),
            "wd": ParamSpec(L + (cfg.d_ff, d), lx + ("ff", "embed")),
        }
        if cfg.arch_type == "audio":  # whisper MLP: gelu with biases, no gate
            del s["wu"]
            s["bg"] = ParamSpec(L + (cfg.d_ff,), lx + ("ff",), init="zeros")
            s["bd"] = ParamSpec(L + (d,), lx + (None,), init="zeros")
        return s
    return moe_lib.moe_param_specs(cfg, n)


def _norm_specs(cfg: ModelConfig, n: int, name: str) -> dict:
    L = (n,)
    lx = ("layers",)
    s = {name: ParamSpec(L + (cfg.d_model,), lx + (None,), init="ones")}
    if cfg.arch_type == "audio":  # whisper: LayerNorm with bias
        s[name + "_b"] = ParamSpec(L + (cfg.d_model,), lx + (None,), init="zeros")
    return s


def _layer_specs(cfg: ModelConfig, n: int, dense_ffn: bool) -> dict:
    s: dict = {}
    s.update(_norm_specs(cfg, n, "ln1"))
    if cfg.arch_type == "ssm":
        s["ssm"] = ssm_lib.ssm_param_specs(cfg, n)
        return s
    s["attn"] = _attn_specs(cfg, n)
    if cfg.arch_type == "hybrid":
        s["ssm"] = ssm_lib.ssm_param_specs(cfg, n)
        s["mix_gate"] = ParamSpec((n, 2), ("layers", None), init="ones")
    s.update(_norm_specs(cfg, n, "ln2"))
    s["ffn"] = _ffn_specs(cfg, n, dense_ffn)
    if cfg.is_encoder_decoder:
        s["cross"] = _attn_specs(cfg, n, cross=True)
        s.update(_norm_specs(cfg, n, "ln_cross"))
    return s


def _n_prologue(cfg: ModelConfig) -> int:
    return cfg.moe.first_moe_layer if cfg.moe is not None else 0


def param_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    n_pro = _n_prologue(cfg)
    specs: dict = {
        "embed": ParamSpec((cfg.vocab, d), ("vocab", "embed"), scale=d**0.5),
        "final_norm": ParamSpec((d,), (None,), init="ones"),
        "layers": _layer_specs(cfg, cfg.n_layers - n_pro, dense_ffn=False),
    }
    if cfg.arch_type == "audio":
        specs["final_norm_b"] = ParamSpec((d,), (None,), init="zeros")
    if not cfg.tie_embeddings:
        specs["head"] = ParamSpec((d, cfg.vocab), ("embed", "vocab"))
    if n_pro:
        specs["prologue"] = _layer_specs(cfg, n_pro, dense_ffn=True)
    if cfg.is_encoder_decoder:
        enc = {
            "layers": {
                k: v
                for k, v in _layer_specs(cfg, cfg.n_encoder_layers, True).items()
                if not k.startswith("ln_cross") and k != "cross"
            },
            "final_norm": ParamSpec((d,), (None,), init="ones"),
            "final_norm_b": ParamSpec((d,), (None,), init="zeros"),
        }
        specs["encoder"] = enc
    return specs


# ---------------------------------------------------------------------------
# Layer metadata (per-layer attention window)
# ---------------------------------------------------------------------------


def layer_windows(cfg: ModelConfig, n_layers: int, offset: int = 0) -> list:
    """Per-layer STATIC attention window (None = global attention).

    Static (trace-time) windows let the stack be applied as one lax.scan
    per contiguous same-window segment, so sliding-window layers compile a
    KV-sliced attention body (O(S·W)) instead of masking an O(S²) grid.
    """
    w = []
    globals_ = {0, cfg.n_layers // 2, cfg.n_layers - 1}
    for i in range(offset, offset + n_layers):
        if cfg.sliding_window is not None and i not in globals_:
            w.append(cfg.sliding_window)
        else:
            w.append(None)
    return w


def window_segments(windows: list) -> list:
    """[(start, end, window)] for maximal same-window runs."""
    segs = []
    start = 0
    for i in range(1, len(windows) + 1):
        if i == len(windows) or windows[i] != windows[start]:
            segs.append((start, i, windows[start]))
            start = i
    return segs


# ---------------------------------------------------------------------------
# Norm dispatch (rms vs whisper layer-norm)
# ---------------------------------------------------------------------------


def _norm(cfg: ModelConfig, lp: dict, name: str, x: jax.Array) -> jax.Array:
    if cfg.arch_type == "audio":
        return layer_norm(x, lp[name], lp[name + "_b"], cfg.norm_eps)
    return rms_norm(x, lp[name], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Attention application (full sequence)
# ---------------------------------------------------------------------------


def _qkv(cfg: ModelConfig, ap: dict, x: jax.Array, kv_x: jax.Array):
    hd = cfg.resolved_head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    dt = x.dtype
    q = jnp.einsum("bsd,de->bse", x, ap["wq"].astype(dt))
    k = jnp.einsum("bsd,de->bse", kv_x, ap["wk"].astype(dt))
    v = jnp.einsum("bsd,de->bse", kv_x, ap["wv"].astype(dt))
    if "bq" in ap:
        q = q + ap["bq"].astype(dt)
        k = k + ap["bk"].astype(dt)
        v = v + ap["bv"].astype(dt)
    q = q.reshape(*x.shape[:2], hq, hd)
    k = k.reshape(*kv_x.shape[:2], hkv, hd)
    v = v.reshape(*kv_x.shape[:2], hkv, hd)
    if "q_norm" in ap:
        q = rms_norm(q, ap["q_norm"], cfg.norm_eps)
        k = rms_norm(k, ap["k_norm"], cfg.norm_eps)
    return q, k, v


def _rope_qk(cfg: ModelConfig, q, k, positions, pos3):
    if cfg.rope_type == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope_type == "mrope":
        q = apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
    return q, k


def attn_full(
    ctx: ApplyCtx, ap: dict, x, positions, pos3, window, causal=True
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    cfg = ctx.cfg
    q, k, v = _qkv(cfg, ap, x, x)
    q, k = _rope_qk(cfg, q, k, positions, pos3)
    out = attention(
        q, k, v, positions, positions, causal=causal, window=window,
        rcfg=ctx.rcfg,
    )
    out = out.reshape(*x.shape[:2], -1)
    return jnp.einsum("bse,ed->bsd", out, ap["wo"].astype(x.dtype)), (k, v)


def cross_attn_full(ctx, ap, x, enc_out, enc_pos):
    cfg = ctx.cfg
    q, k, v = _qkv(cfg, ap, x, enc_out)
    b, s = x.shape[:2]
    qpos = jnp.zeros((b, s), jnp.int32)
    out = attention(q, k, v, qpos, enc_pos, causal=False, rcfg=ctx.rcfg)
    out = out.reshape(b, s, -1)
    return jnp.einsum("bse,ed->bsd", out, ap["wo"].astype(x.dtype)), (k, v)


# ---------------------------------------------------------------------------
# Full-sequence layer body (train / prefill)
# ---------------------------------------------------------------------------


def layer_full(
    ctx: ApplyCtx,
    lp: dict,
    window,
    h: jax.Array,
    positions,
    pos3,
    enc_out=None,
    enc_pos=None,
    want_cache: bool = False,
):
    cfg = ctx.cfg
    cache: Dict[str, jax.Array] = {}
    aux = jnp.zeros((), jnp.float32)
    hn = _norm(cfg, lp, "ln1", h)
    if cfg.arch_type == "ssm":
        out, state = ssm_lib.mamba2_forward(cfg, lp["ssm"], hn, ctx.rcfg)
        if want_cache:
            cache["ssm"] = state.astype(jnp.bfloat16)
            cache["conv"] = _conv_tail(cfg, hn, lp["ssm"])
        return h + out, cache, aux
    if cfg.mla is not None:
        attn_out, (latent, krope) = mla_full(cfg, lp["attn"], hn, positions, ctx.rcfg)
        if want_cache:
            cache["ckv"] = latent.astype(jnp.bfloat16)
            cache["krope"] = krope.astype(jnp.bfloat16)
    else:
        attn_out, (k, v) = attn_full(
            ctx, lp["attn"], hn, positions, pos3, window, causal=True
        )
        if want_cache:
            cache["k"] = k.astype(jnp.bfloat16)
            cache["v"] = v.astype(jnp.bfloat16)
    if cfg.arch_type == "hybrid":
        ssm_out, state = ssm_lib.mamba2_forward(cfg, lp["ssm"], hn, ctx.rcfg)
        g = jax.nn.sigmoid(lp["mix_gate"].astype(jnp.float32))
        attn_out = (g[0] * attn_out + g[1] * ssm_out).astype(hn.dtype)
        if want_cache:
            cache["ssm"] = state.astype(jnp.bfloat16)
            cache["conv"] = _conv_tail(cfg, hn, lp["ssm"])
    h = h + attn_out
    if cfg.is_encoder_decoder and enc_out is not None:
        hc = _norm(cfg, lp, "ln_cross", h)
        c_out, (ck, cv) = cross_attn_full(ctx, lp["cross"], hc, enc_out, enc_pos)
        h = h + c_out
        if want_cache:
            cache["cross_k"] = ck.astype(jnp.bfloat16)
            cache["cross_v"] = cv.astype(jnp.bfloat16)
    hn2 = _norm(cfg, lp, "ln2", h)
    fp = lp["ffn"]
    if "router" in fp:
        ff, aux = moe_lib.moe_ffn(cfg, ctx.rcfg, ctx.mesh, fp, hn2)
    elif cfg.arch_type == "audio":
        from repro.models.layers import gelu_mlp

        ff = gelu_mlp(hn2, fp["wg"], fp["bg"], fp["wd"], fp["bd"])
    else:
        ff = swiglu(hn2, fp["wg"], fp["wu"], fp["wd"])
    return h + ff, cache, aux


def _conv_tail(cfg: ModelConfig, hn: jax.Array, sp: dict) -> jax.Array:
    """Last (d_conv-1) pre-activation conv inputs — the decode conv state."""
    s = cfg.ssm
    proj = jnp.einsum("bsd,de->bse", hn, sp["in_proj"].astype(hn.dtype))
    di = s.inner(cfg.d_model)
    xbc = proj[..., di : 2 * di + 2 * s.d_state]
    k = s.d_conv - 1
    tail = xbc[:, -k:, :]
    pad = k - tail.shape[1]
    if pad > 0:
        tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
    return tail.astype(jnp.bfloat16)


# ---------------------------------------------------------------------------
# Stack application
# ---------------------------------------------------------------------------


def _maybe_remat(rcfg: RunConfig, fn):
    if rcfg.remat == "none":
        return fn
    if rcfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)


def run_stack(
    ctx: ApplyCtx,
    stack_params: dict,
    windows: jax.Array,
    h: jax.Array,
    positions,
    pos3,
    enc_out=None,
    enc_pos=None,
    want_cache: bool = False,
):
    aux = jnp.zeros((), jnp.float32)
    seg_caches = []
    for start, end, win in window_segments(windows):
        seg_params = jax.tree.map(lambda a: a[start:end], stack_params)

        def body(carry, lp, _win=win):
            hh, aux_c = carry
            hh, cache, aux_l = layer_full(
                ctx, lp, _win, hh, positions, pos3, enc_out, enc_pos, want_cache
            )
            hh = constrain_batch(ctx, hh)
            return (hh, aux_c + aux_l), cache

        body = _maybe_remat(ctx.rcfg, body)
        (h, aux), cache = jax.lax.scan(body, (h, aux), seg_params)
        seg_caches.append(cache)
    if want_cache and seg_caches:
        caches = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *seg_caches)
    else:
        caches = seg_caches[0] if seg_caches else {}
    return h, aux, caches


def run_prologue(ctx, pro_params, windows, h, positions, pos3, want_cache):
    """Unrolled leading layers (dense FFN before the MoE stack)."""
    n = len(windows)
    caches = []
    aux = jnp.zeros((), jnp.float32)
    for i in range(n):
        lp = jax.tree.map(lambda a: a[i], pro_params)
        h, cache, aux_l = layer_full(
            ctx, lp, windows[i], h, positions, pos3, None, None, want_cache
        )
        caches.append(cache)
        aux = aux + aux_l
    if want_cache and caches:
        caches = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    else:
        caches = {}
    return h, aux, caches


# ---------------------------------------------------------------------------
# Embedding / positions
# ---------------------------------------------------------------------------


def sinusoidal_pos(positions: jax.Array, d: int) -> jax.Array:
    """(B,S) int -> (B,S,d) sinusoidal embedding (computed, not a table)."""
    half = d // 2
    dim = jnp.arange(half, dtype=jnp.float32)
    inv = jnp.exp(-jnp.log(10_000.0) * dim / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def build_mrope_positions(b: int, s: int, n_vision: int, offset=0) -> jax.Array:
    """(3,B,S) t/h/w position ids: vision patches on a 16-wide grid at t=0,
    text tokens advance t beyond the vision span."""
    idx = jnp.arange(s)
    is_vis = idx < n_vision
    t = jnp.where(is_vis, 0, idx - n_vision + 1)
    hh = jnp.where(is_vis, idx // 16, t)
    ww = jnp.where(is_vis, idx % 16, t)
    pos = jnp.stack([t, hh, ww]).astype(jnp.int32) + offset
    return jnp.broadcast_to(pos[:, None, :], (3, b, s))


def embed(ctx: ApplyCtx, params, tokens, positions, vision_embeds=None):
    cfg = ctx.cfg
    h = params["embed"][tokens].astype(ctx.rcfg.cdtype)
    if vision_embeds is not None and cfg.n_vision_tokens:
        nv = vision_embeds.shape[1]
        h = jnp.concatenate([vision_embeds.astype(h.dtype), h[:, nv:]], axis=1)
    if cfg.rope_type == "none" and cfg.arch_type != "ssm":
        h = h + sinusoidal_pos(positions, cfg.d_model).astype(h.dtype)
    return constrain_batch(ctx, h)


def unembed(ctx: ApplyCtx, params, h):
    cfg = ctx.cfg
    if cfg.arch_type == "audio":
        h = layer_norm(h, params["final_norm"], params["final_norm_b"], cfg.norm_eps)
    else:
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    return jnp.einsum("bsd,dv->bsv", h, w.astype(h.dtype))


def encode(ctx: ApplyCtx, params, enc_feats):
    """Whisper encoder over stub frame embeddings (B, T_enc, d)."""
    cfg = ctx.cfg
    b, t, _ = enc_feats.shape
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    h = enc_feats.astype(ctx.rcfg.cdtype)
    h = h + sinusoidal_pos(pos, cfg.d_model).astype(h.dtype)
    def body(carry, lp):
        hh, _ = carry
        hn = _norm(cfg, lp, "ln1", hh)
        # bidirectional self-attention
        q, k, v = _qkv(cfg, lp["attn"], hn, hn)
        out = attention(q, k, v, pos, pos, causal=False, rcfg=ctx.rcfg)
        out = out.reshape(b, t, -1)
        hh = hh + jnp.einsum("bse,ed->bsd", out, lp["attn"]["wo"].astype(hh.dtype))
        hn2 = _norm(cfg, lp, "ln2", hh)
        from repro.models.layers import gelu_mlp

        fp = lp["ffn"]
        hh = hh + gelu_mlp(hn2, fp["wg"], fp["bg"], fp["wd"], fp["bd"])
        return (constrain_batch(ctx, hh), jnp.zeros((), jnp.float32)), None

    body = _maybe_remat(ctx.rcfg, body)
    (h, _), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                             params["encoder"]["layers"])
    h = layer_norm(h, params["encoder"]["final_norm"],
                   params["encoder"]["final_norm_b"], cfg.norm_eps)
    return h, pos


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def forward_train(ctx: ApplyCtx, params, batch) -> Tuple[jax.Array, jax.Array]:
    """batch: tokens (B,S) [+ vision_embeds | enc_feats] -> (logits, aux)."""
    cfg = ctx.cfg
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    pos3 = (
        build_mrope_positions(b, s, cfg.n_vision_tokens)
        if cfg.rope_type == "mrope"
        else None
    )
    enc_out = enc_pos = None
    if cfg.is_encoder_decoder:
        enc_out, enc_pos = encode(ctx, params, batch["enc_feats"])
    h = embed(ctx, params, tokens, positions, batch.get("vision_embeds"))
    n_pro = _n_prologue(cfg)
    aux = jnp.zeros((), jnp.float32)
    if n_pro:
        h, aux_p, _ = run_prologue(
            ctx, params["prologue"], layer_windows(cfg, n_pro), h, positions,
            pos3, False,
        )
        aux = aux + aux_p
    h, aux_m, _ = run_stack(
        ctx, params["layers"], layer_windows(cfg, cfg.n_layers - n_pro, n_pro),
        h, positions, pos3, enc_out, enc_pos, False,
    )
    aux = aux + aux_m
    return unembed(ctx, params, h), aux


def prefill(ctx: ApplyCtx, params, batch, capacity: Optional[int] = None):
    """Fill a KV cache over the whole prompt. Returns (cache, last_logits).

    ``capacity`` reserves extra slots for subsequent decode steps (defaults
    to the prompt length; decode then ring-overwrites the oldest slots,
    which is only correct for pure sliding-window attention).
    """
    cfg = ctx.cfg
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    pos3 = (
        build_mrope_positions(b, s, cfg.n_vision_tokens)
        if cfg.rope_type == "mrope"
        else None
    )
    enc_out = enc_pos = None
    cache: Dict[str, Any] = {}
    if cfg.is_encoder_decoder:
        enc_out, enc_pos = encode(ctx, params, batch["enc_feats"])
    h = embed(ctx, params, tokens, positions, batch.get("vision_embeds"))
    n_pro = _n_prologue(cfg)
    if n_pro:
        h, _, c_pro = run_prologue(
            ctx, params["prologue"], layer_windows(cfg, n_pro), h, positions,
            pos3, True,
        )
        cache["pro"] = c_pro
    h, _, c_main = run_stack(
        ctx, params["layers"], layer_windows(cfg, cfg.n_layers - n_pro, n_pro),
        h, positions, pos3, enc_out, enc_pos, True,
    )
    cache["main"] = c_main
    if capacity is not None and capacity > s:
        pad = capacity - s

        def pad_seq(path, leaf):
            name = str(path[-1].key) if hasattr(path[-1], "key") else ""
            if name in ("k", "v", "ckv", "krope"):
                width = [(0, 0)] * leaf.ndim
                width[2] = (0, pad)  # (L, B, W, ...) — grow the slot axis
                return jnp.pad(leaf, width)
            return leaf

        cache = jax.tree_util.tree_map_with_path(pad_seq, cache)
    cache["length"] = jnp.asarray(s, jnp.int32)
    logits = unembed(ctx, params, h[:, -1:])
    return cache, logits


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def _ring_kv_pos(length: jax.Array, w: int) -> jax.Array:
    """Positions currently held by each ring slot after writing pos=length.

    Slot s holds position p = length - ((length - s) mod W); invalid (never
    written) slots yield negative p.
    """
    s = jnp.arange(w, dtype=jnp.int32)
    p = length - ((length - s) % w)
    return p  # p in (length-W, length]; p<0 marks unwritten slots


def abstract_cache(
    cfg: ModelConfig, batch: int, w: int, enc_len: Optional[int] = None
) -> dict:
    """ShapeDtypeStruct cache pytree (capacity ``w`` per attention layer)."""

    def sds(shape):
        return jax.ShapeDtypeStruct(shape, jnp.bfloat16)

    def layer_cache(n: int) -> dict:
        c: Dict[str, Any] = {}
        hd, hkv = cfg.resolved_head_dim, cfg.n_kv_heads
        if cfg.arch_type != "ssm":
            if cfg.mla is not None:
                c["ckv"] = sds((n, batch, w, cfg.mla.kv_lora_rank))
                c["krope"] = sds((n, batch, w, cfg.mla.qk_rope_head_dim))
            else:
                c["k"] = sds((n, batch, w, hkv, hd))
                c["v"] = sds((n, batch, w, hkv, hd))
        if cfg.arch_type in ("ssm", "hybrid"):
            s = cfg.ssm
            nh = s.n_ssm_heads(cfg.d_model)
            conv_dim = s.inner(cfg.d_model) + 2 * s.d_state
            c["ssm"] = sds((n, batch, nh, s.headdim, s.d_state))
            c["conv"] = sds((n, batch, s.d_conv - 1, conv_dim))
        if cfg.is_encoder_decoder:
            el = enc_len or cfg.encoder_seq_len
            c["cross_k"] = sds((n, batch, el, hkv, hd))
            c["cross_v"] = sds((n, batch, el, hkv, hd))
        return c

    n_pro = _n_prologue(cfg)
    cache: Dict[str, Any] = {"main": layer_cache(cfg.n_layers - n_pro)}
    if n_pro:
        cache["pro"] = layer_cache(n_pro)
    cache["length"] = jax.ShapeDtypeStruct((), jnp.int32)
    return cache


def layer_decode(ctx: ApplyCtx, lp, window, lcache, h, pos, pos3):
    """One-token decode through one layer. Returns (h, updated lcache)."""
    cfg = ctx.cfg
    b = h.shape[0]
    t = pos[0, 0]  # scalar position (batch-aligned serving)
    hn = _norm(cfg, lp, "ln1", h)
    new_cache = dict(lcache)

    if cfg.arch_type == "ssm":
        out, st, cv = ssm_lib.mamba2_decode(
            cfg, lp["ssm"], hn, lcache["ssm"].astype(jnp.float32),
            lcache["conv"].astype(hn.dtype),
        )
        new_cache["ssm"] = st.astype(lcache["ssm"].dtype)
        new_cache["conv"] = cv.astype(lcache["conv"].dtype)
        return h + out, new_cache

    if cfg.mla is not None:
        from repro.models.mla import _latent  # shared projection helper

        latent, krope = _latent(cfg, lp["attn"], hn, pos)
        w = lcache["ckv"].shape[1]
        slot = t % w
        ckv = jax.lax.dynamic_update_slice(
            lcache["ckv"], latent.astype(lcache["ckv"].dtype), (0, slot, 0)
        )
        krc = jax.lax.dynamic_update_slice(
            lcache["krope"], krope.astype(lcache["krope"].dtype), (0, slot, 0)
        )
        kv_pos = jnp.broadcast_to(_ring_kv_pos(t, w), (b, w))
        attn_out = mla_decode(cfg, lp["attn"], hn, pos, ckv.astype(hn.dtype),
                              krc.astype(hn.dtype), kv_pos)
        new_cache["ckv"], new_cache["krope"] = ckv, krc
    else:
        q, k, v = _qkv(cfg, lp["attn"], hn, hn)
        q, k = _rope_qk(cfg, q, k, pos, pos3)
        w = lcache["k"].shape[1]
        slot = t % w
        kc = jax.lax.dynamic_update_slice(
            lcache["k"], k.astype(lcache["k"].dtype), (0, slot, 0, 0)
        )
        vc = jax.lax.dynamic_update_slice(
            lcache["v"], v.astype(lcache["v"].dtype), (0, slot, 0, 0)
        )
        kv_pos = jnp.broadcast_to(_ring_kv_pos(t, w), (b, w))
        attn_out = attention(
            q, kc.astype(hn.dtype), vc.astype(hn.dtype), pos, kv_pos,
            causal=True, window=window, rcfg=ctx.rcfg,
        )
        attn_out = attn_out.reshape(b, 1, -1)
        attn_out = jnp.einsum(
            "bse,ed->bsd", attn_out, lp["attn"]["wo"].astype(hn.dtype)
        )
        new_cache["k"], new_cache["v"] = kc, vc

    if cfg.arch_type == "hybrid":
        ssm_out, st, cv = ssm_lib.mamba2_decode(
            cfg, lp["ssm"], hn, lcache["ssm"].astype(jnp.float32),
            lcache["conv"].astype(hn.dtype),
        )
        g = jax.nn.sigmoid(lp["mix_gate"].astype(jnp.float32))
        attn_out = (g[0] * attn_out + g[1] * ssm_out).astype(hn.dtype)
        new_cache["ssm"] = st.astype(lcache["ssm"].dtype)
        new_cache["conv"] = cv.astype(lcache["conv"].dtype)

    h = h + attn_out

    if cfg.is_encoder_decoder:
        hc = _norm(cfg, lp, "ln_cross", h)
        ck = lcache["cross_k"].astype(hn.dtype)
        cv_ = lcache["cross_v"].astype(hn.dtype)
        el = ck.shape[1]
        q, _, _ = _qkv(cfg, lp["cross"], hc, hc)
        enc_pos = jnp.broadcast_to(jnp.arange(el, dtype=jnp.int32), (b, el))
        out = attention(q, ck, cv_, jnp.zeros((b, 1), jnp.int32), enc_pos,
                        causal=False, rcfg=ctx.rcfg)
        out = out.reshape(b, 1, -1)
        h = h + jnp.einsum("bse,ed->bsd", out, lp["cross"]["wo"].astype(hn.dtype))

    hn2 = _norm(cfg, lp, "ln2", h)
    fp = lp["ffn"]
    if "router" in fp:
        ff, _ = moe_lib.moe_ffn(cfg, ctx.rcfg, ctx.mesh, fp, hn2)
    elif cfg.arch_type == "audio":
        from repro.models.layers import gelu_mlp

        ff = gelu_mlp(hn2, fp["wg"], fp["bg"], fp["wd"], fp["bd"])
    else:
        ff = swiglu(hn2, fp["wg"], fp["wu"], fp["wd"])
    return h + ff, new_cache


def decode_step(ctx: ApplyCtx, params, cache, tokens):
    """One decode step: tokens (B,1) + cache -> (new cache, logits (B,1,V))."""
    cfg = ctx.cfg
    b = tokens.shape[0]
    t = cache["length"]
    pos = jnp.broadcast_to(t, (b, 1)).astype(jnp.int32)
    # M-RoPE: text positions advance from 1 past the vision span (matching
    # build_mrope_positions), not from the raw cache index.
    t3 = t - cfg.n_vision_tokens + 1 if cfg.n_vision_tokens else t
    pos3 = (
        jnp.broadcast_to(t3, (3, b, 1)).astype(jnp.int32)
        if cfg.rope_type == "mrope"
        else None
    )
    h = embed(ctx, params, tokens, pos, None)
    n_pro = _n_prologue(cfg)
    new_cache = dict(cache)
    if n_pro:
        windows = layer_windows(cfg, n_pro)
        pro_caches = []
        for i in range(n_pro):
            lp = jax.tree.map(lambda a: a[i], params["prologue"])
            lc = jax.tree.map(lambda a: a[i], cache["pro"])
            h, lc = layer_decode(ctx, lp, windows[i], lc, h, pos, pos3)
            pro_caches.append(lc)
        new_cache["pro"] = jax.tree.map(lambda *xs: jnp.stack(xs), *pro_caches)

    windows = layer_windows(cfg, cfg.n_layers - n_pro, n_pro)
    seg_caches = []
    for start, end, win in window_segments(windows):
        seg_params = jax.tree.map(lambda a: a[start:end], params["layers"])
        seg_cache = jax.tree.map(lambda a: a[start:end], cache["main"])

        def body(carry, xs, _win=win):
            hh = carry
            lp, lc = xs
            hh, lc = layer_decode(ctx, lp, _win, lc, hh, pos, pos3)
            return constrain_batch(ctx, hh), lc

        h, seg_out = jax.lax.scan(body, h, (seg_params, seg_cache))
        seg_caches.append(seg_out)
    new_cache["main"] = jax.tree.map(
        lambda *xs: jnp.concatenate(xs, axis=0), *seg_caches
    )
    new_cache["length"] = t + 1
    logits = unembed(ctx, params, h)
    return new_cache, logits


# ---------------------------------------------------------------------------
# Param construction helpers
# ---------------------------------------------------------------------------


def init_model_params(key, cfg: ModelConfig, rcfg: RunConfig):
    return init_params(key, param_specs(cfg), rcfg.pdtype)


def abstract_model_params(cfg: ModelConfig, rcfg: RunConfig):
    return abstract_params(param_specs(cfg), rcfg.pdtype)


def model_param_axes(cfg: ModelConfig):
    return param_axes(param_specs(cfg))
