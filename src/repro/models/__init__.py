from repro.models.transformer import (  # noqa: F401
    ApplyCtx,
    abstract_cache,
    abstract_model_params,
    decode_step,
    forward_train,
    init_model_params,
    model_param_axes,
    param_specs,
    prefill,
)
