"""Mamba2 block via SSD (state-space duality), per arXiv:2405.21060.

Prefill/train use the chunked dual form: intra-chunk attention-like
(C Bᵀ ⊙ L) matmuls + an inter-chunk state recurrence (lax.scan). Decode is
the pure recurrent step. The chunked intra-chunk matmuls are the compute
hot-spot and have a Pallas twin in ``repro.kernels.ssd_scan``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.runtime import RunConfig
from repro.models.layers import ParamSpec, rms_norm


def ssm_param_specs(cfg: ModelConfig, n_layers: int) -> dict:
    """Stacked (leading ``layers`` axis) Mamba2 params."""
    s = cfg.ssm
    d = cfg.d_model
    di = s.inner(d)
    nh = s.n_ssm_heads(d)
    conv_dim = di + 2 * s.d_state
    L = (n_layers,)
    lx = ("layers",)
    return {
        # z (gate), x, B, C, dt
        "in_proj": ParamSpec(
            L + (d, 2 * di + 2 * s.d_state + nh), lx + ("embed", "ssm_inner")
        ),
        "conv_w": ParamSpec(L + (s.d_conv, conv_dim), lx + (None, "ssm_inner")),
        "conv_b": ParamSpec(L + (conv_dim,), lx + ("ssm_inner",), init="zeros"),
        "dt_bias": ParamSpec(L + (nh,), lx + ("ssm_heads",), init="zeros"),
        "A_log": ParamSpec(L + (nh,), lx + ("ssm_heads",), init="ones"),
        "D": ParamSpec(L + (nh,), lx + ("ssm_heads",), init="ones"),
        "norm_w": ParamSpec(L + (di,), lx + ("ssm_inner",), init="ones"),
        "out_proj": ParamSpec(L + (di, d), lx + ("ssm_inner", "embed")),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    s = cfg.ssm
    di = s.inner(cfg.d_model)
    nh = s.n_ssm_heads(cfg.d_model)
    z, xbc_x, Bm, Cm, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + s.d_state, 2 * di + 2 * s.d_state], axis=-1
    )
    return z, xbc_x, Bm, Cm, dt, di, nh


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. xbc: (B,S,C), w: (K,C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return jax.nn.silu(out + b[None, None, :])


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, initial_state=None):
    """Chunked SSD scan.

    x:  (B,S,nh,hd)   dt: (B,S,nh)   A: (nh,)  [negative]
    Bm: (B,S,N)       Cm: (B,S,N)    (ngroups=1)
    Returns y: (B,S,nh,hd) and final state (B,nh,hd,N).
    """
    b, s, nh, hd = x.shape
    n = Bm.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // chunk
    xq = x.reshape(b, nc, chunk, nh, hd)
    dtq = dt.reshape(b, nc, chunk, nh)
    Bq = Bm.reshape(b, nc, chunk, n)
    Cq = Cm.reshape(b, nc, chunk, n)

    dA = dtq * A[None, None, None, :]  # (B,nc,Q,nh) <= 0
    dA_cs = jnp.cumsum(dA, axis=2)  # inclusive cumsum within chunk

    # --- intra-chunk (diagonal) blocks: Y_ij = C_i·B_j exp(cs_i - cs_j) dt_j x_j
    att = jnp.einsum("bcqn,bckn->bcqk", Cq.astype(jnp.float32), Bq.astype(jnp.float32))
    decay = jnp.exp(dA_cs[:, :, :, None, :] - dA_cs[:, :, None, :, :])  # (b,c,q,k,h)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(mask[None, None, :, :, None], decay, 0.0)
    xdt = xq * dtq[..., None]  # (b,c,q,h,p)
    y_diag = jnp.einsum("bcqk,bcqkh,bckhp->bcqhp", att, L, xdt.astype(jnp.float32))

    # --- chunk end-states: S_c = sum_j exp(cs_last - cs_j) B_j ⊗ (dt_j x_j)
    seg = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # (b,c,q,h)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", Bq.astype(jnp.float32), seg, xdt.astype(jnp.float32))

    # --- inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(jnp.sum(dA, axis=2))  # (b,c,h)
    s0 = (
        jnp.zeros((b, nh, hd, n), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def step(carry, xs):
        st_in, dec = xs  # (b,h,p,n), (b,h)
        new = carry * dec[:, :, None, None] + st_in
        return new, carry  # emit state *entering* this chunk

    final, prev_states = jax.lax.scan(
        step,
        s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b,c,h,p,n)

    # --- inter-chunk contribution: C_i · exp(cs_i) · S_prev
    instate_decay = jnp.exp(dA_cs)  # (b,c,q,h)
    y_off = jnp.einsum(
        "bcqn,bcqh,bchpn->bcqhp", Cq.astype(jnp.float32), instate_decay, prev_states
    )

    y = (y_diag + y_off).reshape(b, nc * chunk, nh, hd)[:, :s]
    return y.astype(x.dtype), final


def mamba2_forward(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,  # (B,S,d)
    rcfg: RunConfig,
    initial_state=None,
) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence Mamba2 block. Returns (out (B,S,d), final_state)."""
    s = cfg.ssm
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    z, xi, Bm, Cm, dt, di, nh = _split_proj(cfg, proj)
    xbc = jnp.concatenate([xi, Bm, Cm], axis=-1)
    xbc = _causal_conv(xbc, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype))
    xi, Bm, Cm = jnp.split(xbc, [di, di + s.d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xi.reshape(*xi.shape[:2], nh, s.headdim)
    if rcfg.use_pallas:
        from repro.kernels.ssd_scan import ops as ssd_ops

        y, state = ssd_ops.ssd(xh, dt, A, Bm, Cm, chunk=s.chunk_size,
                               initial_state=initial_state)
    else:
        y, state = ssd_chunked(xh, dt, A, Bm, Cm, s.chunk_size, initial_state)
    y = y + xh * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(*x.shape[:2], di)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    return out, state


def mamba2_decode(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,  # (B,1,d)
    ssm_state: jax.Array,  # (B,nh,hd,N)
    conv_state: jax.Array,  # (B,d_conv-1,conv_dim)
):
    """Single recurrent step. Returns (out (B,1,d), new_ssm, new_conv)."""
    s = cfg.ssm
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    z, xi, Bm, Cm, dt, di, nh = _split_proj(cfg, proj)
    xbc = jnp.concatenate([xi, Bm, Cm], axis=-1)  # (B,1,conv_dim)
    window = jnp.concatenate([conv_state, xbc], axis=1)  # (B,d_conv,conv_dim)
    new_conv = window[:, 1:]
    w = p["conv_w"].astype(x.dtype)
    conv_out = jnp.einsum("bkc,kc->bc", window, w) + p["conv_b"].astype(x.dtype)
    xbc = jax.nn.silu(conv_out)[:, None, :]
    xi, Bm, Cm = jnp.split(xbc, [di, di + s.d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xi.reshape(x.shape[0], nh, s.headdim)  # (B,nh,hd)
    dt1 = dt[:, 0]  # (B,nh)
    dA = jnp.exp(dt1 * A[None, :])  # (B,nh)
    dBx = jnp.einsum("bn,bh,bhp->bhpn", Bm[:, 0].astype(jnp.float32), dt1,
                     xh.astype(jnp.float32))
    new_state = ssm_state * dA[:, :, None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), new_state)
    y = y.astype(x.dtype) + xh * p["D"].astype(x.dtype)[None, :, None]
    y = y.reshape(x.shape[0], 1, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    return out, new_state.astype(ssm_state.dtype), new_conv
