"""The common evaluation loop (paper Fig. 2): optimizer proposes a config,
the device applies it and runs inference, measured (τ, p) feed back."""
from __future__ import annotations

import dataclasses
from typing import List

from repro.core.baselines import Outcome
from repro.core.coral import CORAL
from repro.core.space import ConfigSpace


@dataclasses.dataclass
class Trace:
    configs: List[tuple]
    taus: List[float]
    powers: List[float]
    rewards: List[float]


def run_coral(
    space: ConfigSpace,
    device,
    tau_target: float,
    p_budget: float = float("inf"),
    p_min: float = 0.0,
    iters: int = 10,
    window: int = 10,
    seed: int = 0,
    mode: str = "dual",  # dual | throughput (single-target §IV-B)
) -> tuple[Outcome, Trace]:
    # mode="throughput" is CORAL's own single-target path (reward = τ, no
    # τ target) — not an inf-target sentinel, which would route every
    # observation through the infeasible branch of Alg. 1 and maximize
    # -(p/τ) (efficiency) instead of throughput.
    opt = CORAL(
        space, tau_target, p_budget, p_min=p_min, window=window, seed=seed,
        mode=mode,
    )
    tr = Trace([], [], [], [])
    for _ in range(iters):
        cfg = opt.propose()
        tau, p = device.measure(cfg)
        r = opt.observe(cfg, tau, p)
        tr.configs.append(cfg)
        tr.taus.append(tau)
        tr.powers.append(p)
        tr.rewards.append(r)
    res = opt.result()
    if res is None:
        return Outcome(None, 0.0, 0.0, iters), tr
    return Outcome(res.config, res.tau, res.power, iters), tr
