"""The common evaluation loop (paper Fig. 2): optimizer proposes a config,
the device applies it and runs inference, measured (τ, p) feed back.

``run_cell(CellSpec)`` is the one public runner: regime family
(stationary / drift / offload / cotenant) is *data on the spec* — the
cell's regime name — and the returned ``CellRecord`` tags the family
next to the JSON-ready record. The older per-family entries
(``run_regime``, ``run_drift_regime``, ``run_coral`` here;
``run_cell``/``run_offload_cell`` in ``experiments.matrix``) remain as
thin deprecated aliases for one release: ``run_coral`` and
``run_drift_regime`` stay load-bearing *internally* as the scalar
executable specification the compiled episode engine is byte-checked
against, but new callers should go through ``run_cell``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

from repro.core.baselines import Outcome
from repro.core.coral import CORAL
from repro.core.drift import DriftConfig
from repro.core.space import ConfigSpace


@dataclasses.dataclass
class Trace:
    configs: List[tuple]
    taus: List[float]
    powers: List[float]
    rewards: List[float]


@dataclasses.dataclass(frozen=True)
class RegimeTargets:
    """Resolved constraint shape for one evaluation run.

    ``mode`` selects CORAL's objective ("dual": Alg. 1 reward, τ target +
    power budget; "throughput": single-target max-τ). ``p_budget`` is
    ``inf`` for uncapped regimes.
    """

    mode: str
    tau_target: float
    p_budget: float = float("inf")

    @property
    def capped(self) -> bool:
        return math.isfinite(self.p_budget)

    def feasible(self, tau: float, power: float) -> bool:
        return tau >= self.tau_target and power <= self.p_budget


@dataclasses.dataclass(frozen=True)
class CellSpec:
    """One runnable scenario cell, fully specified as data.

    ``cell`` is a ``repro.experiments.scenarios.Cell`` (its regime name
    selects the family); ``iters=None`` takes the family's calibrated
    measurement budget (10 static, ``OFFLOAD_ITERS`` offload,
    ``COTENANT_ITERS`` cotenant, ``FAULT_ITERS`` fault; drift cells pace
    by intervals instead).
    """

    cell: object
    iters: Optional[int] = None
    seeds: Sequence[int] = (0, 1, 2)
    window: int = 10
    engine: str = "compiled"


@dataclasses.dataclass(frozen=True)
class CellRecord:
    """A family-tagged, JSON-ready cell record (``family`` is one of
    "static" | "drift" | "offload" | "cotenant" | "fault"; ``record`` is
    the matching ``BENCH_matrix`` array entry)."""

    family: str
    record: dict


def run_cell(spec: CellSpec) -> CellRecord:
    """Run one cell of any family — the unified runner entrypoint.

    Dispatches on the spec's regime name: cotenant and offload regimes
    run CORAL over their joint grids at their calibrated budgets, drift
    regimes run the adaptive-vs-static ablation, everything else runs
    the stationary CORAL-vs-baselines loop. Imports are lazy — the
    regime tables and record assemblers live in ``repro.experiments``,
    which imports this module."""
    from repro.experiments import matrix, scenarios

    cell, seeds = spec.cell, tuple(spec.seeds)
    kw = dict(seeds=seeds, window=spec.window, engine=spec.engine)
    if cell.regime in scenarios.COTENANT_REGIMES:
        iters = matrix.COTENANT_ITERS if spec.iters is None else spec.iters
        return CellRecord(
            "cotenant", matrix.run_cotenant_cell(cell, iters=iters, **kw)
        )
    if cell.regime in scenarios.OFFLOAD_REGIMES:
        iters = matrix.OFFLOAD_ITERS if spec.iters is None else spec.iters
        return CellRecord(
            "offload", matrix.run_offload_cell(cell, iters=iters, **kw)
        )
    if cell.regime in scenarios.FAULT_REGIMES:
        iters = matrix.FAULT_ITERS if spec.iters is None else spec.iters
        return CellRecord(
            "fault", matrix.run_fault_cell(cell, iters=iters, **kw)
        )
    if scenarios.REGIMES[cell.regime].dynamic:
        return CellRecord("drift", matrix.run_drift_cell(cell, **kw))
    iters = 10 if spec.iters is None else spec.iters
    return CellRecord(
        "static", matrix.run_static_cell(cell, iters=iters, **kw)
    )


def run_regime(
    space: ConfigSpace,
    device,
    targets: RegimeTargets,
    iters: int = 10,
    window: int = 10,
    seed: int = 0,
) -> tuple[Outcome, Trace]:
    """``run_coral`` under a named constraint regime."""
    return run_coral(
        space,
        device,
        tau_target=targets.tau_target,
        p_budget=targets.p_budget,
        iters=iters,
        window=window,
        seed=seed,
        mode=targets.mode,
    )


def measurements_to_feasible(tr: Trace, targets: RegimeTargets) -> Optional[int]:
    """Exploration cost: 1-based index of the first measurement that met
    the regime's constraints (None if the run never did). Throughput-mode
    targets carry ``tau_target=0`` (no τ floor — see
    ``repro.experiments.scenarios.resolve_targets``), so only the power
    cap gates feasibility there."""
    for i, (tau, p) in enumerate(zip(tr.taus, tr.powers)):
        if targets.feasible(tau, p):
            return i + 1
    return None


@dataclasses.dataclass
class DriftTrace:
    """Per-interval record of a drift run: what was applied, what was
    measured, and whether the optimizer was exploring or holding."""

    configs: List[tuple]
    taus: List[float]
    powers: List[float]
    exploring: List[bool]
    budgets: List[float]  # effective p_budget at each interval
    resets: int = 0


def run_drift_regime(
    space: ConfigSpace,
    device,  # a DriftingSimulator (or anything with set_time + measure)
    targets: RegimeTargets,
    schedule,  # repro.device.hw.DriftSchedule
    intervals: int,
    explore_budget: int = 10,
    window: int = 10,
    seed: int = 0,
    adaptive: bool = True,
    sigma: float = 0.05,
) -> tuple[CORAL, DriftTrace]:
    """Closed loop over a non-stationary device twin.

    Each control interval advances the device's drift clock, applies the
    optimizer's next config (a proposal while exploring, the held config
    while monitoring) and feeds the measurement back. ``adaptive=False``
    is the static ablation: one exploration epoch, then hold forever with
    the change-point monitor off — the one-shot tuning that PolyThrottle
    shows breaking under changing operating conditions.

    Budget steps are *commanded*, not detected: the loop reads the
    schedule's ``budget_scale`` each interval and notifies the adaptive
    optimizer via ``set_p_budget``; the static ablation is oblivious (it
    keeps running against the stale budget, and is scored against the
    real one).
    """
    drift = DriftConfig(
        explore_budget=explore_budget,
        sigma=sigma,
        monitor=adaptive,
        halflife=float(window),
    )
    opt = CORAL(
        space,
        targets.tau_target,
        targets.p_budget,
        window=window,
        seed=seed,
        mode=targets.mode,
        drift=drift,
    )
    tr = DriftTrace([], [], [], [], [])
    for t in range(intervals):
        device.set_time(t)
        budget_t = targets.p_budget * schedule.state_at(t).budget_scale
        if adaptive and budget_t != opt.p_budget:
            opt.set_p_budget(budget_t)
        cfg = opt.next_config()
        # read the flag *after* next_config: an infeasible-epoch retry
        # flips the optimizer back into exploration and returns a probe,
        # which must not be logged (and scored) as a held operating point
        tr.exploring.append(opt.exploring)
        tau, p = device.measure(cfg)
        opt.record(cfg, tau, p)
        tr.configs.append(tuple(cfg))
        tr.taus.append(tau)
        tr.powers.append(p)
        tr.budgets.append(budget_t)
    tr.resets = opt.state.resets
    return opt, tr


def run_coral(
    space: ConfigSpace,
    device,
    tau_target: float,
    p_budget: float = float("inf"),
    p_min: float = 0.0,
    iters: int = 10,
    window: int = 10,
    seed: int = 0,
    mode: str = "dual",  # dual | throughput (single-target §IV-B)
) -> tuple[Outcome, Trace]:
    """One CORAL run against a measurable device: ``iters`` propose →
    measure → observe rounds of the Alg. 1–2 loop, returning the chosen
    ``Outcome`` and the full per-iteration ``Trace``. The scalar
    reference the compiled episode engine is byte-checked against
    (``run_regime`` wraps this with ``RegimeTargets``)."""
    # mode="throughput" is CORAL's own single-target path (reward = τ, no
    # τ target) — not an inf-target sentinel, which would route every
    # observation through the infeasible branch of Alg. 1 and maximize
    # -(p/τ) (efficiency) instead of throughput.
    opt = CORAL(
        space,
        tau_target,
        p_budget,
        p_min=p_min,
        window=window,
        seed=seed,
        mode=mode,
    )
    tr = Trace([], [], [], [])
    for _ in range(iters):
        cfg = opt.propose()
        tau, p = device.measure(cfg)
        r = opt.observe(cfg, tau, p)
        tr.configs.append(cfg)
        tr.taus.append(tau)
        tr.powers.append(p)
        tr.rewards.append(r)
    res = opt.result()
    if res is None:
        return Outcome(None, 0.0, 0.0, iters), tr
    return Outcome(res.config, res.tau, res.power, iters), tr


@dataclasses.dataclass
class FaultTrace:
    """Per-interval record of a fault run: what was commanded, what was
    actually in force, what came back over telemetry, and what the
    hardened ingest did with it."""

    commanded: List[tuple]
    applied: List[tuple]
    taus: List[float]
    powers: List[float]
    accepted: List[bool]  # sample survived the hardened ingest gate
    fallback: List[bool]  # watchdog held the safe config this interval


def run_fault_regime(
    space: ConfigSpace,
    device,  # a FaultySimulator (set_time + actuate + measure)
    targets: RegimeTargets,
    iters: int = 40,
    window: int = 10,
    seed: int = 0,
    hardened: bool = True,
    robust=None,
) -> tuple[CORAL, FaultTrace]:
    """Closed loop over a fault-injected device twin — the scalar
    executable specification of ``episode.run_fault_requests``.

    Each control interval: the optimizer commands a config; the
    actuation path applies it (or silently sticks / firmware-resets —
    the hardened controller retries up to ``robust.act_retries`` times,
    the ablation writes blind); the twin measures the config *actually
    in force*, possibly spiking or dropping the sample in transit.
    Hardened CORAL attributes the measurement to the readback config and
    runs it through the robust ingest gate; the non-hardened ablation
    attributes it to the *commanded* config and swallows it raw —
    exactly the two failure couplings the fault cells score.
    """
    from repro.core.faults import RobustConfig

    rb = robust if robust is not None else RobustConfig()
    # hardened constraint back-off: chase the margin-shrunk budget so
    # boundary noise cannot flip an over-budget config to feasible
    # (scoring upstream always uses the full budget)
    p_budget = targets.p_budget * (1.0 - rb.p_margin) if hardened else targets.p_budget
    opt = CORAL(
        space,
        targets.tau_target,
        p_budget,
        window=window,
        seed=seed,
        mode=targets.mode,
        robust=rb if hardened else None,
    )
    tr = FaultTrace([], [], [], [], [], [])
    for t in range(iters):
        device.set_time(t)
        # read the watchdog *before* next_config: that is the state the
        # compiled step's guard sees for this interval
        guarded = hardened and opt._dark >= rb.watchdog
        cmd = opt.next_config()
        applied = device.actuate(cmd, retries=rb.act_retries if hardened else 0)
        tau, p = device.measure(applied)
        attr = applied if hardened else cmd
        n_before = len(opt.state.history)
        opt.record(attr, tau, p)
        tr.commanded.append(tuple(cmd))
        tr.applied.append(tuple(applied))
        tr.taus.append(tau)
        tr.powers.append(p)
        tr.accepted.append(len(opt.state.history) > n_before)
        tr.fallback.append(guarded)
    return opt, tr


# The interpreter loops above are the *equivalence baseline* for the
# compiled episode engine (repro.core.episode) — the ``oracle_scalar``
# pattern: the scalar path stays as the executable specification, the
# scenario matrix routes through the engine by default, and
# tests/test_episode.py pins the two together seed-for-seed.
run_coral_scalar = run_coral
run_drift_regime_scalar = run_drift_regime
