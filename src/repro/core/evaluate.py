"""The common evaluation loop (paper Fig. 2): optimizer proposes a config,
the device applies it and runs inference, measured (τ, p) feed back.

``run_regime`` is the regime-parameterized entry the scenario matrix
uses: a ``RegimeTargets`` names the constraint shape (CORAL mode, τ
target, power budget) so one runner serves single-target and strict
dual-constraint cells alike.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional

from repro.core.baselines import Outcome
from repro.core.coral import CORAL
from repro.core.space import ConfigSpace


@dataclasses.dataclass
class Trace:
    configs: List[tuple]
    taus: List[float]
    powers: List[float]
    rewards: List[float]


@dataclasses.dataclass(frozen=True)
class RegimeTargets:
    """Resolved constraint shape for one evaluation run.

    ``mode`` selects CORAL's objective ("dual": Alg. 1 reward, τ target +
    power budget; "throughput": single-target max-τ). ``p_budget`` is
    ``inf`` for uncapped regimes.
    """

    mode: str
    tau_target: float
    p_budget: float = float("inf")

    @property
    def capped(self) -> bool:
        return math.isfinite(self.p_budget)

    def feasible(self, tau: float, power: float) -> bool:
        return tau >= self.tau_target and power <= self.p_budget


def run_regime(
    space: ConfigSpace,
    device,
    targets: RegimeTargets,
    iters: int = 10,
    window: int = 10,
    seed: int = 0,
) -> tuple[Outcome, Trace]:
    """``run_coral`` under a named constraint regime."""
    return run_coral(
        space,
        device,
        tau_target=targets.tau_target,
        p_budget=targets.p_budget,
        iters=iters,
        window=window,
        seed=seed,
        mode=targets.mode,
    )


def measurements_to_feasible(tr: Trace, targets: RegimeTargets) -> Optional[int]:
    """Exploration cost: 1-based index of the first measurement that met
    the regime's constraints (None if the run never did). Throughput-mode
    targets carry ``tau_target=0`` (no τ floor — see
    ``repro.experiments.scenarios.resolve_targets``), so only the power
    cap gates feasibility there."""
    for i, (tau, p) in enumerate(zip(tr.taus, tr.powers)):
        if targets.feasible(tau, p):
            return i + 1
    return None


def run_coral(
    space: ConfigSpace,
    device,
    tau_target: float,
    p_budget: float = float("inf"),
    p_min: float = 0.0,
    iters: int = 10,
    window: int = 10,
    seed: int = 0,
    mode: str = "dual",  # dual | throughput (single-target §IV-B)
) -> tuple[Outcome, Trace]:
    # mode="throughput" is CORAL's own single-target path (reward = τ, no
    # τ target) — not an inf-target sentinel, which would route every
    # observation through the infeasible branch of Alg. 1 and maximize
    # -(p/τ) (efficiency) instead of throughput.
    opt = CORAL(
        space, tau_target, p_budget, p_min=p_min, window=window, seed=seed,
        mode=mode,
    )
    tr = Trace([], [], [], [])
    for _ in range(iters):
        cfg = opt.propose()
        tau, p = device.measure(cfg)
        r = opt.observe(cfg, tau, p)
        tr.configs.append(cfg)
        tr.taus.append(tau)
        tr.powers.append(p)
        tr.rewards.append(r)
    res = opt.result()
    if res is None:
        return Outcome(None, 0.0, 0.0, iters), tr
    return Outcome(res.config, res.tau, res.power, iters), tr
