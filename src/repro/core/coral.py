"""The CORAL optimizer (paper §III).

Per iteration:
  Step 1 — Reward evaluation (Alg. 1): measure (τ, p) for the current
           config; feasible → r = τ/p, infeasible → prohibited + penalty.
  Step 2 — Correlation analysis (§III-D): distance correlations
           α_i = dCor(τ, s_i), β_i = dCor(p, s_i) over a sliding window of
           the W most recent observations.
  Step 3 — Configuration search (Alg. 2): correlation-weighted step from
           (best, second-best) toward the feasible/efficient region.

The loop runs a fixed iteration budget (10 in the paper).

Drift awareness (beyond the paper, EXPERIMENTS.md §Drift): constructed
with a ``DriftConfig`` the optimizer becomes epoch-structured — it
explores for ``explore_budget`` measurements, then *holds* its best
feasible config while a CUSUM monitor watches that config's repeated
(τ, p) measurements. A detected change-point (or an externally commanded
power-budget change that the held config violates) triggers *bounded
re-exploration*: the correlation window, anchors and exploration state
reset to a fresh epoch while the prohibited-set memory is kept — a warm
restart, not a cold one.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Set, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import search
from repro.core.dcov import dcor_all
from repro.core.drift import DriftConfig, DriftMonitor
from repro.core.faults import RobustConfig, mad_reject
from repro.core.reward import reward
from repro.core.space import (
    Config,
    ConfigSpace,
    index_coords,
    row_index,
    space_rows,
)


def joint_headroom(taus, floors) -> np.ndarray:
    """Scalarize per-tenant throughputs against per-tenant τ floors:
    min_k τ_k/floor_k over the leading (tenant) axis.

    This is how multi-tenant cells ride CORAL's *dual* mode unchanged
    (EXPERIMENTS.md §Multi-tenant): the optimizer's τ channel carries the
    joint headroom with ``tau_target = 1.0`` — headroom ≥ 1 ⇔ every
    tenant meets its floor — while the p channel stays the shared rail
    draw. The twin (``device.cotenant``), the batched joint oracle and
    the serving controller's measured feedback all call this one helper
    so the three paths can never disagree on the scalarization."""
    taus = np.asarray(taus, np.float64)
    f = np.asarray(floors, np.float64).reshape(-1, *([1] * (taus.ndim - 1)))
    return (taus / f).min(axis=0)


@dataclasses.dataclass
class Observation:
    """One measured (config, τ, p, reward) sample — the scalar-loop unit
    the compiled engine flattens into a ``hist_sm`` row (the anchors in
    ``core.contracts.CARRY_CONTRACT`` are this, as scalars)."""

    config: Config
    tau: float
    power: float
    reward: float
    t: int = 0  # control-interval clock at measurement time


@dataclasses.dataclass
class CoralState:
    """Everything Alg. 1–2 carry between iterations: the three anchors
    (best / second / last), the prohibited set, the full observation
    history, and the probe / epoch bookkeeping. The compiled engine's
    fixed-size mirror of this object is ``CARRY_CONTRACT`` in
    ``repro.core.contracts``."""

    best: Optional[Observation] = None
    second: Optional[Observation] = None
    last: Optional[Observation] = None
    prohibited: Set[Config] = dataclasses.field(default_factory=set)
    history: List[Observation] = dataclasses.field(default_factory=list)
    aside: bool = False
    # Lines 14-17 heuristic (cores→MIN, concurrency→MAX) state. With a
    # finite power budget the probe re-arms every time the best config
    # changes while still power-infeasible — the coordinated cores/
    # concurrency move is what jumps into narrow feasible bands that
    # one-notch walks straddle. Without a budget (single-target mode) it
    # fires once: permanent pinning would freeze two dimensions.
    probed_for: Optional[Config] = None
    power_probe_done: bool = False
    # Drift epochs: observations before ``epoch_start`` belong to earlier
    # epochs — they stay in ``history`` (and the prohibited set keeps its
    # memory) but anchors, correlation windows and revisit tracking only
    # see the current epoch.
    epoch_start: int = 0
    resets: int = 0


class CORAL:
    """Online throughput-power co-optimizer.

    Args:
      space: discrete hardware configuration space.
      tau_target: throughput target (τ(s*) ≥ τ_target).
      p_budget: power limit (p(s*) ≤ p_budget).
      p_min: power floor for the power-saving direction (paper's p_min).
      window: sliding-window length W for the correlation analysis.
      seed: RNG seed for tie-breaking / prohibited-escape jitter.
      mode: "dual" (Alg. 1 reward, τ target + power budget) or "throughput"
        (single-target §IV-B: maximize τ, optionally under p_budget; the
        τ target is ignored and the reward is τ itself, not efficiency).
    """

    def __init__(
        self,
        space: ConfigSpace,
        tau_target: float,
        p_budget: float = float("inf"),
        p_min: float = 0.0,
        window: int = 10,
        seed: int = 0,
        step_floor: bool = True,
        probe_policy: str = "budget_aware",  # budget_aware|oneshot|persistent|off
        gamma_mode: str = "max",  # max (paper) | directional (beyond-paper)
        mode: str = "dual",  # dual | throughput (single-target §IV-B)
        drift: Optional[DriftConfig] = None,
        robust: Optional[RobustConfig] = None,
    ):
        self.space = space
        self.mode = mode
        # Throughput mode has no τ target: an unreachable target keeps
        # Alg. 2 in its climb direction (the reward path is mode-aware and
        # never prohibits a config for missing it).
        if mode == "throughput":
            tau_target = float("inf")
        self.tau_target = tau_target
        self.p_budget = p_budget
        self.p_min = p_min
        self.window = window
        self.rng = np.random.default_rng(seed)
        self.step_floor = step_floor
        self.probe_policy = probe_policy
        self.gamma_mode = gamma_mode
        self.state = CoralState()
        self.drift = drift
        self.robust = robust
        self.clock = 0  # control-interval counter (explore + hold)
        self._held: Optional[Observation] = None
        self._monitor: Optional[DriftMonitor] = None
        self._retries = 0  # infeasible-hold retry epochs since last trigger
        self._dark = 0  # consecutive rejected/missing telemetry samples

    # ------------------------------------------------------------------
    # Drift epochs
    # ------------------------------------------------------------------
    @property
    def epoch_history(self) -> List[Observation]:
        return self.state.history[self.state.epoch_start :]

    @property
    def epoch_n(self) -> int:
        return len(self.state.history) - self.state.epoch_start

    @property
    def exploring(self) -> bool:
        """True while the current epoch's exploration budget is unspent.
        Without a DriftConfig, CORAL explores forever (paper behavior)."""
        if self.drift is None:
            return True
        return self.epoch_n < self.drift.explore_budget

    def hold_config(self) -> Config:
        """The config held (and monitored) between exploration epochs:
        the epoch's best feasible pick, falling back to best-by-reward."""
        if self._held is None:
            held = self.result()
            if held is None:
                held = self.state.last
            self._held = held
            if self.drift is not None and self.drift.monitor:
                self._monitor = DriftMonitor(
                    held.tau,
                    held.power,
                    sigma=self.drift.sigma,
                    k_sigma=self.drift.k_sigma,
                    h_sigma=self.drift.h_sigma,
                    calibration=self.drift.calibration,
                )
        return self._held.config

    def _feasible(self, tau: float, power: float) -> bool:
        """Feasibility under the *current* constraints. The τ target is
        the inf sentinel in throughput mode, so that mode only gates on
        the power cap (matching ``reward``)."""
        if self.mode == "throughput":
            return power <= self.p_budget
        return tau >= self.tau_target and power <= self.p_budget

    def _hold_reward(self, tau: float, power: float) -> float:
        """Alg. 1's reward shape without its prohibited-set mutation —
        what a calm hold interval reports (mutating would prohibit the
        held config on a single unlucky noise sample)."""
        if not self._feasible(tau, power):
            return -(power / max(tau, 1e-9))
        return tau if self.mode == "throughput" else tau / max(power, 1e-9)

    def next_config(self) -> Config:
        """Unified control-loop entry: propose while exploring, otherwise
        re-apply the held configuration.

        If an exploration epoch ends without a pick that is feasible
        under the *current* constraints, holding it would monitor a
        stably-bad signal — spend another (bounded) exploration epoch
        instead. Feasibility is re-evaluated here rather than read off
        the stored reward sign: a commanded budget change can invalidate
        a pick whose reward was computed under the old budget. The
        static ablation (monitor off) never retries: one-shot tuning
        holds whatever it found.

        With a ``RobustConfig``, a tripped telemetry watchdog (K
        consecutive rejected/missing samples) pre-empts everything:
        degrade to the safe config and hold it — no proposal, no probe
        bookkeeping — until a sample is accepted again.
        """
        if self.robust is not None and self._dark >= self.robust.watchdog:
            return self.safe_config()
        if self.exploring:
            return self.propose()
        if self._held is None and self.drift is not None and self.drift.monitor:
            held = self.result() or self.state.last
            infeasible = held is None or not self._feasible(held.tau, held.power)
            if infeasible and self._retries < self.drift.max_retries:
                self._retries += 1
                self.re_explore()
                return self.propose()
        return self.hold_config()

    def record(self, config: Config, tau: float, power: float) -> float:
        """Unified observation entry: exploration measurements feed the
        optimizer, hold measurements feed the change-point monitor (a
        trigger starts the next exploration epoch, seeded with the held
        config's just-measured post-shift performance).

        With a ``RobustConfig``, the sample first passes the hardened
        ingest gate: missing (NaN/inf) samples and MAD-flagged outliers
        are dropped *before* they can reach the dCor window, the anchor
        cascade, or the CUSUM monitor — the clock still advances, and
        the consecutive-rejection counter feeds the watchdog."""
        if self.robust is not None:
            if self._robust_reject(tau, power):
                self._dark += 1
                self.clock += 1
                return 0.0
            self._dark = 0
        if self.exploring:
            return self.observe(config, tau, power)
        self.clock += 1
        changed = self._monitor is not None and self._monitor.update(tau, power)
        if changed:
            self._retries = 0  # a real change-point refreshes the allowance
            self.re_explore()
            # Seed the new epoch with the held config's just-taken
            # measurement only if it is *infeasible* — prohibiting the
            # broken config steers the fresh search away from it. A
            # feasible-looking sample is discarded: the detector fires
            # mid-transient, and carrying a half-shifted (plus lucky
            # noise) measurement in once let a truly-infeasible config
            # outrank every genuine post-shift observation.
            if not self._feasible(tau, power):
                self.clock -= 1  # observe() re-advances the clock
                return self.observe(config, tau, power)
            return 0.0
        return self._hold_reward(tau, power)

    def re_explore(self) -> None:
        """Bounded re-exploration after a change-point: fresh epoch for
        anchors/window/probe state, prohibited-set memory retained."""
        st = self.state
        st.epoch_start = len(st.history)
        st.best = None
        st.second = None
        st.last = None
        st.aside = False
        st.probed_for = None
        st.power_probe_done = False
        st.resets += 1
        self._held = None
        self._monitor = None

    def set_p_budget(self, p_budget: float) -> None:
        """Commanded budget change (e.g. a rack-level cap step). Unlike
        environment drift this is *known*, not detected: if the held
        config's calibrated draw violates the new budget, re-explore
        immediately."""
        old = self.p_budget
        self.p_budget = p_budget
        if old == p_budget or self.exploring:
            return
        if self._held is None:
            return
        draw = (
            self._monitor.ref_power if self._monitor is not None
            else self._held.power
        )
        if draw > p_budget:
            self._retries = 0
            self.re_explore()

    # ------------------------------------------------------------------
    # Hardened ingest (EXPERIMENTS.md §Fault tolerance)
    # ------------------------------------------------------------------
    def _feasible32(self, tau: float, power: float) -> bool:
        """Feasibility evaluated in float32 — the compiled fault step
        checks the safe-fallback anchor against f32 carry scalars, and
        the scalar path must make the identical call on the boundary."""
        t32, p32 = np.float32(tau), np.float32(power)
        if self.mode == "throughput":
            return bool(p32 <= np.float32(self.p_budget))
        return bool(
            (t32 >= np.float32(self.tau_target))
            and (p32 <= np.float32(self.p_budget))
        )

    def safe_config(self) -> Config:
        """Graceful-degradation target while telemetry is dark: the best
        anchor if it is still feasible under the current constraints,
        ultimately the min-power row (never bust the power budget on a
        device we cannot observe)."""
        b = self.state.best
        if b is not None and self._feasible32(b.tau, b.power):
            return b.config
        return self.space.preset("min_power")

    def _robust_reject(self, tau: float, power: float) -> bool:
        """Hardened ingest decision for one (τ, p) sample: missing
        (non-finite) samples are always dropped; finite ones pass the
        shared MAD outlier gate (``faults.mad_reject``) against the
        current epoch window's float32 τ/p columns — the same jitted
        computation the compiled fault step traces inline, on the same
        window slice (``lo = max(epoch_start, n − W)``), so the two
        engines cannot disagree about what enters the dCor window."""
        if not (math.isfinite(tau) and math.isfinite(power)):
            return True
        rb = self.robust
        st = self.state
        n = len(st.history)
        lo = max(st.epoch_start, n - self.window)
        rows = st.history[lo:]
        win_tau = np.zeros(self.window, np.float32)
        win_p = np.zeros(self.window, np.float32)
        for k, o in enumerate(rows):
            win_tau[k] = o.tau
            win_p[k] = o.power
        return bool(
            mad_reject(
                jnp.asarray(win_tau),
                jnp.asarray(win_p),
                np.int32(len(rows)),
                np.float32(tau),
                np.float32(power),
                np.float32(rb.gate_g),
                np.float32(rb.gate_eps),
                np.int32(rb.min_accept),
            )
        )

    # ------------------------------------------------------------------
    # Step 2: correlation analysis over the sliding window
    # ------------------------------------------------------------------
    def correlations(self) -> Tuple[np.ndarray, np.ndarray]:
        """§III-D sensitivity weights: (α, β) arrays of length D — per-knob
        dCor against τ and p over the current epoch's last-W window
        (uniform weights below 3 samples). The window is zero-padded to a
        fixed W so one jitted ``dcor_all`` shape serves every fill level —
        the same padding the compiled engine's ``lax.dynamic_slice``
        window reproduces."""
        hist = self.epoch_history[-self.window :]
        if self.drift is not None and self.drift.halflife is not None:
            # Exponentially-decayed buffer, hard-truncated at the decay
            # horizon: a sample older than ~3 halflives carries <1/8 the
            # weight of a fresh one — below the dCor window's resolution —
            # so it is dropped rather than fractionally weighted.
            horizon = 3.0 * self.drift.halflife
            hist = [o for o in hist if self.clock - o.t <= horizon]
        d = len(self.space.dims)
        n = len(hist)
        if n < 3:  # not enough samples: uniform weights
            return np.ones(d), np.ones(d)
        # Pad the window to a fixed W so one jitted shape serves every fill
        # level; n_valid is traced, so partial windows don't recompile.
        settings = np.zeros((self.window, d), np.float32)
        metrics = np.zeros((self.window, 2), np.float32)
        for k, o in enumerate(hist):
            settings[k] = o.config
            metrics[k, 0] = o.tau
            metrics[k, 1] = o.power
        corr = np.asarray(
            dcor_all(jnp.asarray(settings), jnp.asarray(metrics), np.int32(n))
        )
        return corr[:, 0], corr[:, 1]

    # ------------------------------------------------------------------
    # Step 3: propose the next configuration
    # ------------------------------------------------------------------
    def propose(self) -> Config:
        """Alg. 2: the next configuration to measure. First probe is the
        grid midpoint, second a correlation-free diversity preset; from
        the third on, a correlation-weighted step from (best, second)
        via ``search.alg2_levels`` — the exact float32 op sequence the
        compiled scan traces — with the prohibited-escape argmin on top."""
        st = self.state
        n = self.epoch_n
        if n == 0:
            return self._escape_prohibited(self.space.midpoint())
        if n == 1 or st.second is None:
            # second probe: exploit correlation-free diversity — max preset
            # if target unmet, min if power-bound.
            if self.mode == "throughput":
                cand = (
                    self.space.preset("min_power")
                    if st.last is not None and st.last.power > self.p_budget
                    else self.space.preset("max_power")
                )
            elif st.last is not None and st.last.tau < self.tau_target:
                cand = self.space.preset("max_power")
            else:
                cand = self.space.preset("min_power")
            return self._escape_prohibited(cand)
        alpha, beta = self.correlations()
        if self.mode == "throughput":
            # The lines 14-17 move is a *power* optimization. With no
            # finite budget there is no power objective and the probe
            # stays off; with one, it re-arms per new best while the cap
            # is violated — the τ precondition is vacuously met (there is
            # no τ target), and comparing against the inf sentinel would
            # disable it entirely, the same class of bug as the old
            # inf-target reward. A violated cap also means every
            # observation so far is over it, so eff_target below is -inf
            # and the probe survives next_config's own guard.
            probe = (
                self.probe_policy != "off"
                and math.isfinite(self.p_budget)
                and st.best.config != st.probed_for
                and st.best.power > self.p_budget
            )
        elif self.probe_policy == "off":
            probe = False
        elif self.probe_policy == "persistent":  # Alg. 2 lines 14-17 verbatim
            probe = st.best.power > self.p_min and st.best.tau > self.tau_target
        elif self.probe_policy == "oneshot" or not math.isfinite(self.p_budget):
            probe = (
                not st.power_probe_done
                and st.best.power > self.p_min
                and st.best.tau > self.tau_target
            )
        else:  # budget_aware (default): re-arm per new best while p > budget
            probe = (
                st.best.config != st.probed_for
                and st.best.tau > self.tau_target
                and st.best.power > self.p_budget
            )
        # Throughput mode: Alg. 2's direction test (line 6) compares τ_last
        # against the target. With no target the search always climbs —
        # except over the power cap, where an always-met effective target
        # flips it into the power-saving direction.
        eff_target = self.tau_target
        if self.mode == "throughput" and st.last.power > self.p_budget:
            eff_target = -math.inf
        cand = search.next_config(
            self.space,
            st.best.config,
            st.second.config,
            alpha,
            beta,
            tau_last=st.last.tau,
            p_last=st.last.power,
            tau_target=eff_target,
            p_min=self.p_min,
            aside=st.aside,
            tau_best=st.best.tau,
            p_best=st.best.power,
            power_probe=probe,
            step_floor=self.step_floor,
            gamma_mode=self.gamma_mode,
        )
        if probe:
            st.power_probe_done = True
            st.probed_for = st.best.config
        return self._escape_prohibited(cand)

    def _escape_prohibited(self, cand: Config) -> Config:
        """Skip configs on the prohibited list (Alg. 1): jump to the
        *nearest unseen* config — minimum L1 distance in level-index
        space (the BFS level of the old frontier walk), ties broken by
        grid-row order. The canonical rule replaces the frontier BFS
        (whose within-level order depended on path enumeration) so the
        compiled episode engine can evaluate the identical argmin over
        the grid; ``tests/test_episode.py`` pins the two paths together.
        Revisit tracking is per-epoch: after a change-point, pre-shift
        measurements are stale, so re-measuring an old config is allowed
        (the prohibited set itself is kept — its entries were constraint
        violations)."""
        seen = self.state.prohibited | {o.config for o in self.epoch_history}
        if cand not in seen:
            return cand
        coords = index_coords(self.space)
        n = coords.shape[0]
        seen_mask = np.zeros(n, bool)
        for cfg in seen:
            seen_mask[row_index(self.space, cfg)] = True
        if seen_mask.all():  # exhausted grid — unreachable at episode scale
            return self.space.random(self.rng)
        ci = coords[row_index(self.space, cand)]
        dist = np.abs(coords - ci).sum(axis=1).astype(np.int32)
        key = dist * np.int32(n) + np.arange(n, dtype=np.int32)
        key = np.where(seen_mask, np.int32(np.iinfo(np.int32).max), key)
        return space_rows(self.space)[int(np.argmin(key))]

    # ------------------------------------------------------------------
    # Step 1: reward evaluation & state update
    # ------------------------------------------------------------------
    def observe(self, config: Config, tau: float, power: float) -> float:
        """Alg. 1: fold one measurement into the state — Eq. 3 reward
        (which may prohibit the config), history append, and the
        best/second/last anchor update. Returns the reward."""
        st = self.state
        r = reward(
            tau,
            power,
            config,
            st.prohibited,
            self.tau_target,
            self.p_budget,
            mode=self.mode,
        )
        obs = Observation(tuple(config), tau, power, r, t=self.clock)
        self.clock += 1
        st.history.append(obs)
        # aside: last probe failed to beat the current best → flip anchors
        st.aside = st.best is not None and r <= st.best.reward
        if st.best is None or r > st.best.reward:
            st.second = st.best
            st.best = obs
        elif st.second is None or r > st.second.reward:
            st.second = obs
        st.last = obs
        return r

    # ------------------------------------------------------------------
    def result(self) -> Optional[Observation]:
        """Best feasible observation (else best by reward).

        Dual mode ranks feasible observations by efficiency τ/p; throughput
        mode (no τ target) ranks the power-feasible ones by τ. Only the
        current epoch's observations are ranked — pre-shift measurements
        describe a device that no longer exists.
        """
        hist = self.epoch_history
        if self.mode == "throughput":
            feas = [o for o in hist if o.power <= self.p_budget]
            if feas:
                return max(feas, key=lambda o: o.tau)
            return self.state.best
        feas = [
            o
            for o in hist
            if o.tau >= self.tau_target and o.power <= self.p_budget
        ]
        if feas:
            return max(feas, key=lambda o: o.tau / max(o.power, 1e-9))
        return self.state.best

    # ------------------------------------------------------------------
    # Checkpoint / restore (docs/ARCHITECTURE.md §Checkpoint format)
    # ------------------------------------------------------------------
    def to_checkpoint(self) -> dict:
        """Serialize the full optimizer state to a JSON-compatible dict.

        Everything that influences a future decision goes in: the
        observation history and anchors, the prohibited set, probe and
        epoch bookkeeping, the control clock, drift-hold state (held
        config + CUSUM monitor), the hardened-ingest dark counter, the
        *current* constraints (a commanded budget change must survive a
        restart), and the tie-break RNG's bit-generator state. A
        restored optimizer continues byte-identically to one that never
        stopped (``tests/test_faults.py`` pins this).
        """

        def _obs(o: Optional[Observation]):
            if o is None:
                return None
            return [list(o.config), o.tau, o.power, o.reward, o.t]

        st = self.state
        mon = None
        if self._monitor is not None:
            m = self._monitor
            mon = {
                "ref_tau": m.ref_tau,
                "ref_power": m.ref_power,
                "sigma": m.sigma,
                "calibration": m.calibration,
                "calib_n": m._calib_n,
                "samples": m.samples,
                "k": m.tau_cusum.k,
                "h": m.tau_cusum.h,
                "tau_pos": m.tau_cusum.pos,
                "tau_neg": m.tau_cusum.neg,
                "p_pos": m.power_cusum.pos,
                "p_neg": m.power_cusum.neg,
            }
        return {
            "version": 1,
            "mode": self.mode,
            "dims": len(self.space.dims),
            "window": self.window,
            "tau_target": self.tau_target,
            "p_budget": self.p_budget,
            "clock": self.clock,
            "retries": self._retries,
            "dark": self._dark,
            "held": _obs(self._held),
            "monitor": mon,
            "rng": self.rng.bit_generator.state,
            "state": {
                "best": _obs(st.best),
                "second": _obs(st.second),
                "last": _obs(st.last),
                "prohibited": sorted(list(c) for c in st.prohibited),
                "history": [_obs(o) for o in st.history],
                "aside": st.aside,
                "probed_for": (
                    None if st.probed_for is None else list(st.probed_for)
                ),
                "power_probe_done": st.power_probe_done,
                "epoch_start": st.epoch_start,
                "resets": st.resets,
            },
        }

    def restore(self, ckpt: dict) -> None:
        """Load state from ``to_checkpoint`` output. The optimizer must
        have been constructed with the same space/mode/window as the
        checkpointed one (validated); constraints are taken from the
        checkpoint — the live values at checkpoint time win over the
        constructor arguments."""
        if ckpt.get("version") != 1:
            raise ValueError(f"unknown checkpoint version {ckpt.get('version')!r}")
        if ckpt["mode"] != self.mode or ckpt["dims"] != len(self.space.dims):
            raise ValueError("checkpoint does not match this optimizer's space/mode")
        if ckpt["window"] != self.window:
            raise ValueError("checkpoint window mismatch")

        def _obs(row) -> Optional[Observation]:
            if row is None:
                return None
            cfg, tau, power, r, t = row
            return Observation(tuple(cfg), tau, power, r, t=int(t))

        self.tau_target = ckpt["tau_target"]
        self.p_budget = ckpt["p_budget"]
        self.clock = int(ckpt["clock"])
        self._retries = int(ckpt["retries"])
        self._dark = int(ckpt["dark"])
        self._held = _obs(ckpt["held"])
        s = ckpt["state"]
        self.state = CoralState(
            best=_obs(s["best"]),
            second=_obs(s["second"]),
            last=_obs(s["last"]),
            prohibited={tuple(c) for c in s["prohibited"]},
            history=[_obs(o) for o in s["history"]],
            aside=bool(s["aside"]),
            probed_for=(
                None if s["probed_for"] is None else tuple(s["probed_for"])
            ),
            power_probe_done=bool(s["power_probe_done"]),
            epoch_start=int(s["epoch_start"]),
            resets=int(s["resets"]),
        )
        mon = ckpt["monitor"]
        if mon is None:
            self._monitor = None
        else:
            m = DriftMonitor(
                mon["ref_tau"],
                mon["ref_power"],
                sigma=mon["sigma"],
                k_sigma=mon["k"],
                h_sigma=mon["h"],
                calibration=int(mon["calibration"]),
            )
            # DriftMonitor's constructor clamps the references; restore
            # the exact running-mean values and CUSUM statistics on top.
            m.ref_tau = mon["ref_tau"]
            m.ref_power = mon["ref_power"]
            m._calib_n = int(mon["calib_n"])
            m.samples = int(mon["samples"])
            m.tau_cusum.pos = mon["tau_pos"]
            m.tau_cusum.neg = mon["tau_neg"]
            m.power_cusum.pos = mon["p_pos"]
            m.power_cusum.neg = mon["p_neg"]
            self._monitor = m
        self.rng = np.random.default_rng()
        self.rng.bit_generator.state = ckpt["rng"]
