"""Change-point detection for drift-adaptive CORAL.

CORAL as published converges once and then trusts its statistics forever;
on a non-stationary device (thermal throttling, co-tenant interference)
the held configuration silently degrades. The machinery here closes that
gap:

  ``CusumDetector``  — a two-sided CUSUM on standardized residuals: the
      classic sequential change-point statistic. With slack ``k`` and
      threshold ``h`` (both in σ units) the in-control false-alarm rate
      is astronomically small for the (k, h) defaults while a shift of a
      few σ fires within a handful of samples.
  ``DriftMonitor``   — two CUSUMs over the fractional (τ, p) residuals of
      repeated measurements of the *held* configuration vs. its reference
      value. The reference is calibrated from the first few hold samples
      (averaging down measurement noise), then frozen — an EWMA reference
      would chase the drift and mask it.
  ``DriftConfig``    — the knobs CORAL takes to become drift-aware: the
      per-epoch exploration budget, monitor calibration/sensitivity, and
      the observation-age horizon for the correlation window.

The monitor never sees exploration measurements (different configs are
expected to differ); it only consumes re-measurements of the held config,
so a trigger means "this exact configuration no longer performs as it
did" — the cleanest possible drift signal.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass(frozen=True)
class DriftConfig:
    """Drift-awareness knobs for CORAL.

    ``explore_budget`` — measurements per exploration epoch; after that
        many observations CORAL holds its best feasible config and
        monitors it (bounded re-exploration: each change-point spends the
        same budget again, it never free-runs).
    ``sigma`` — expected fractional noise of a single (τ, p) sample (the
        device's measurement σ; the workload trace noise in the matrix).
    ``k_sigma``/``h_sigma`` — CUSUM slack and decision threshold in σ
        units. Defaults give a negligible in-control false-alarm rate on
        Gaussian noise while a sustained ≥3σ shift fires within
        ~h/(shift−k) samples.
    ``calibration`` — hold samples averaged into the monitor reference
        before the CUSUMs arm. The slack must absorb the reference's
        residual error (~σ/√calibration), which is why ``k_sigma`` sits
        above 1: a miscalibrated reference adds a persistent bias to
        every standardized residual.
    ``monitor`` — set False for the *static* ablation: explore once, hold
        forever, never re-explore (the one-shot tuning PolyThrottle shows
        breaking under drift).
    ``halflife`` — observation-age horizon (in control intervals) for the
        correlation window: observations older than ~3 halflives are
        dropped from the dCor buffer even without a detected change, so a
        slow creep cannot poison the correlation statistics. None keeps
        the plain sliding window.
    ``max_retries`` — extra exploration epochs allowed when an epoch ends
        without a feasible config (holding a constraint-violating config
        and monitoring it would watch a stably-bad signal). Bounds the
        total exploration spend at (1 + retries per trigger) budgets.
    """

    explore_budget: int = 10
    sigma: float = 0.05
    k_sigma: float = 1.25
    h_sigma: float = 9.0
    calibration: int = 8
    monitor: bool = True
    halflife: Optional[float] = None
    max_retries: int = 2


class CusumDetector:
    """Two-sided CUSUM over standardized residuals z ~ N(0, 1)."""

    def __init__(self, k: float = 1.25, h: float = 9.0):
        self.k = k
        self.h = h
        self.pos = 0.0
        self.neg = 0.0

    def update(self, z: float) -> bool:
        # A non-finite residual must not touch the statistics: a NaN
        # during reference calibration poisons the monitor forever, and
        # even post-calibration `max(0.0, pos + nan - k)` silently wipes
        # the accumulated statistic (argument-order quirk of Python's
        # max). Skip the sample; the detector state is unchanged.
        if not math.isfinite(z):
            return self.tripped
        self.pos = max(0.0, self.pos + z - self.k)
        self.neg = max(0.0, self.neg - z - self.k)
        return self.tripped

    @property
    def tripped(self) -> bool:
        return self.pos > self.h or self.neg > self.h

    def reset(self) -> None:
        self.pos = 0.0
        self.neg = 0.0


class DriftMonitor:
    """CUSUMs on the fractional (τ, p) residuals of the held config.

    The first ``calibration`` samples refine the reference (mean of the
    calibration window seeded with the held config's exploration-time
    measurement); afterwards each sample feeds z = (x/ref − 1)/σ into a
    two-sided CUSUM per metric. ``update`` returns True once either
    metric's statistic crosses the threshold.
    """

    def __init__(
        self,
        ref_tau: float,
        ref_power: float,
        sigma: float = 0.05,
        k_sigma: float = 1.25,
        h_sigma: float = 9.0,
        calibration: int = 8,
    ):
        self.ref_tau = max(ref_tau, 1e-9)
        self.ref_power = max(ref_power, 1e-9)
        self.sigma = max(sigma, 1e-6)
        self.calibration = calibration
        self._calib_n = 1  # the reference itself counts as one sample
        self.tau_cusum = CusumDetector(k_sigma, h_sigma)
        self.power_cusum = CusumDetector(k_sigma, h_sigma)
        self.samples = 0

    def update(self, tau: float, power: float) -> bool:
        # Missing/garbage telemetry (NaN or inf τ/p) is skipped before it
        # can poison the calibration running mean or the CUSUMs — one NaN
        # folded into ``ref_tau`` would disable detection permanently.
        if not (math.isfinite(tau) and math.isfinite(power)):
            return self.tripped
        self.samples += 1
        if self._calib_n < self.calibration:
            # running mean: average measurement noise out of the reference
            n = self._calib_n
            self.ref_tau = (self.ref_tau * n + tau) / (n + 1)
            self.ref_power = (self.ref_power * n + power) / (n + 1)
            self._calib_n += 1
            return False
        z_tau = (tau / self.ref_tau - 1.0) / self.sigma
        z_p = (power / self.ref_power - 1.0) / self.sigma
        t1 = self.tau_cusum.update(z_tau)
        t2 = self.power_cusum.update(z_p)
        return t1 or t2

    @property
    def tripped(self) -> bool:
        return self.tau_cusum.tripped or self.power_cusum.tripped
