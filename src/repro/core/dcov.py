"""Distance covariance / distance correlation (paper Eq. 1-4).

Székely & Rizzo (2009), "Brownian Distance Covariance". Given n paired
observations of a metric m and a hardware setting s:

    a_ij = ||m_i - m_j||,  b_ij = ||s_i - s_j||              (Eq. 1)
    A_ij = a_ij - ā_i. - ā_.j + ā_..   (double centering)    (Eq. 2)
    dCov²(m,s) = (1/n²) Σ_ij A_ij B_ij                        (Eq. 3)
    dCor(m,s)  = dCov(m,s) / sqrt(dCov(m,m)·dCov(s,s))        (Eq. 4)

dCor ∈ [0,1]; 0 iff statistically independent. The pure-jnp version below
is the reference; ``repro.kernels.dcov`` is the blocked Pallas TPU twin for
ORACLE-scale n (thousands of profiled configs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.contracts import check_dcor_state, contracts_enabled


def _pairwise_dist(x: jax.Array) -> jax.Array:
    """x: (n,) or (n,d) -> (n,n) euclidean distance matrix."""
    if x.ndim == 1:
        x = x[:, None]
    diff = x[:, None, :] - x[None, :, :]
    return jnp.sqrt(jnp.sum(diff.astype(jnp.float32) ** 2, axis=-1) + 0.0)


def _double_center(a: jax.Array) -> jax.Array:
    row = a.mean(axis=1, keepdims=True)
    col = a.mean(axis=0, keepdims=True)
    grand = a.mean()
    return a - row - col + grand


def dcov2(x: jax.Array, y: jax.Array) -> jax.Array:
    """Squared distance covariance (Eq. 3). Non-negative up to fp error."""
    A = _double_center(_pairwise_dist(x))
    B = _double_center(_pairwise_dist(y))
    return jnp.mean(A * B)


def dcor(x: jax.Array, y: jax.Array, eps: float = 1e-12) -> jax.Array:
    """Distance correlation (Eq. 4) in [0, 1]; 0 for degenerate inputs."""
    A = _double_center(_pairwise_dist(x))
    B = _double_center(_pairwise_dist(y))
    return dcor_from_sums(jnp.mean(A * B), jnp.mean(A * A), jnp.mean(B * B), eps)


@jax.jit
def dcor_jit(x: jax.Array, y: jax.Array) -> jax.Array:
    """Jitted scalar distance correlation of two (W,) samples."""
    return dcor(x, y)


def centered_distance_stack(cols: jax.Array, n_valid: jax.Array) -> jax.Array:
    """Double-centered distance matrices for every column at once.

    cols: (W, C) — C independent 1-d samples stacked column-wise; rows at
          index >= n_valid are padding and are masked out of every mean.
    returns: (W, W, C) stack of A matrices (Eq. 2), zero outside the valid
          n_valid × n_valid block, so any contraction over (i, j) equals the
          unpadded computation exactly.
    """
    w = cols.shape[0]
    valid = jnp.arange(w, dtype=jnp.int32) < n_valid
    mask = (valid[:, None] & valid[None, :]).astype(jnp.float32)
    d = jnp.abs(cols.astype(jnp.float32)[:, None, :] - cols[None, :, :])
    d = d * mask[:, :, None]
    inv_n = 1.0 / n_valid.astype(jnp.float32)
    row = d.sum(axis=1, keepdims=True) * inv_n
    col = d.sum(axis=0, keepdims=True) * inv_n
    grand = d.sum(axis=(0, 1)) * inv_n * inv_n
    return (d - row - col + grand[None, None, :]) * mask[:, :, None]


def dcor_from_sums(
    sab: jax.Array, saa: jax.Array, sbb: jax.Array, eps: float = 1e-12
) -> jax.Array:
    """dCor (Eq. 4) from ⟨A,B⟩ / ⟨A,A⟩ / ⟨B,B⟩ sums (broadcasting)."""
    denom = jnp.sqrt(jnp.maximum(saa * sbb, 0.0))
    val = jnp.sqrt(jnp.maximum(sab, 0.0) / jnp.maximum(denom, eps))
    return jnp.where(denom < eps, 0.0, jnp.clip(val, 0.0, 1.0))


@jax.jit
def dcor_all(settings: jax.Array, metrics: jax.Array, n_valid: jax.Array) -> jax.Array:
    """All (setting dim, metric dim) correlation weights in one device call.

    Each column's double-centered distance matrix is computed once and
    all D×M pairs fall out of ONE (C, C) Gram contraction over the
    flattened stack — replacing the per-pair loop that re-centered every
    column 2×D times per optimizer iteration. The op count matters as
    much as the FLOPs: the episode engine inlines this function into a
    ``lax.scan`` body, where every kernel launch is paid T times per
    episode, so the column means are computed once (|x_i − x_j| is
    symmetric, column means are row means transposed — bitwise, not just
    mathematically) and the three contraction groups collapse into a
    single small matmul.

    settings: (W, D) sliding window of D hardware parameters (padded to a
              fixed W so JIT compiles one shape; n_valid rows are real).
    metrics:  (W, M) matching window of M performance metrics.
    returns:  (D, M) dCor matrix — column 0 is α (throughput), column 1 is
              β (power) in the CORAL formulation (Eq. 9).
    """
    d = settings.shape[1]
    cols = jnp.concatenate(
        [settings.astype(jnp.float32), metrics.astype(jnp.float32)], axis=1
    )
    return dcor_all_cols(cols, n_valid, d)


def dcor_all_cols(cols: jax.Array, n_valid: jax.Array, d: int) -> jax.Array:
    """``dcor_all`` on a pre-stacked (W, D+M) column block — the episode
    engine stores its observation window in exactly this layout, so it
    skips the concatenation (and stays bitwise-aligned with the scalar
    path, which reaches the same block through ``dcor_all``)."""
    w, c = cols.shape
    cols = cols.astype(jnp.float32)
    n = jnp.asarray(n_valid)
    valid = jnp.arange(w, dtype=jnp.int32) < n
    mask = (valid[:, None] & valid[None, :]).astype(jnp.float32)
    dist = jnp.abs(cols[:, None, :] - cols[None, :, :]) * mask[:, :, None]
    inv_n = 1.0 / n.astype(jnp.float32)
    row = dist.sum(axis=1, keepdims=True) * inv_n
    col = jnp.swapaxes(row, 0, 1)
    grand = row.sum(axis=(0, 1)) * inv_n
    A = (dist - row - col + grand[None, None, :]) * mask[:, :, None]
    gram = A.reshape(w * w, c).T @ A.reshape(w * w, c)
    diag = jnp.diagonal(gram)
    return dcor_from_sums(gram[:d, d:], diag[:d, None], diag[None, d:])


def dcor_numpy(x: np.ndarray, y: np.ndarray) -> float:
    """Convenience wrapper for host-side (optimizer-loop) use."""
    return float(dcor_jit(jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.float32)))


# ---------------------------------------------------------------------------
# Incremental windowed dCor (fleet hot path)
#
# ``dcor_all_cols`` rebuilds the full (W, W, C) distance stack every call
# — O(W²·C) per optimizer step. A sliding window only ever changes by one
# observation, and replacing ring slot k touches exactly row k and column
# k of every (symmetric) distance matrix, so the three sums dCor needs
# can be maintained instead of recomputed:
#
#     cross_ab = Σ_ij d^a_ij · d^b_ij          (C, C)
#     rows_i   = Σ_j  d_ij                     (W, C)   (row sums)
#     S        = Σ_ij d_ij = Σ_i rows_i        (C,)
#
# because for double-centered A (the masked Eq. 2 matrices):
#
#     Σ_ij A^a_ij A^b_ij
#       = cross_ab − (2/n)·Σ_i rows^a_i rows^b_i + S^a S^b / n²
#
# the standard dCov computing formula — every term is scale-consistent,
# and ``dcor_from_sums`` takes ratios, so the unnormalized sums feed it
# directly. One push is O(W·C) distance work plus two (W, C)ᵀ(W, C)
# matmuls: O(W·C²) total, independent of how the window got here. The
# (W, W, C) distance tensor rides along only so the *removed* row is
# available without recomputation.
# ---------------------------------------------------------------------------


def dcor_state_init(window: int, c: int) -> dict:
    """Empty incremental-dCor state for a (window, c)-shaped column
    block. Contract (core/contracts.py::DCOR_STATE_CONTRACT, enforced
    under REPRO_CONTRACTS=1): ``win: Float32[Array, "W C"]``, ``dist:
    Float32[Array, "W W C"]``, ``rows: Float32[Array, "W C"]``,
    ``cross: Float32[Array, "C C"]``."""
    f32 = jnp.float32
    state = {
        "win": jnp.zeros((window, c), f32),
        "dist": jnp.zeros((window, window, c), f32),
        "rows": jnp.zeros((window, c), f32),
        "cross": jnp.zeros((c, c), f32),
    }
    if contracts_enabled():  # trace-time check only
        check_dcor_state(state)
    return state


def dcor_state_from_window(cols: jax.Array, n_valid: jax.Array) -> dict:
    """Full O(W²·C) build — warm-start seeding and the test reference.

    cols: (W, C) column block; rows at index >= n_valid are padding.
    The result is bitwise what ``n_valid`` sequential pushes of the same
    rows into ``dcor_state_init`` produce (same masked |·| distances).
    """
    w, c = cols.shape
    cols = cols.astype(jnp.float32)
    valid = jnp.arange(w, dtype=jnp.int32) < n_valid
    mask = (valid[:, None] & valid[None, :]).astype(jnp.float32)
    dist = jnp.abs(cols[:, None, :] - cols[None, :, :]) * mask[:, :, None]
    flat = dist.reshape(w * w, c)
    state = {
        "win": cols * valid[:, None],
        "dist": dist,
        "rows": dist.sum(axis=1),
        "cross": flat.T @ flat,
    }
    if contracts_enabled():  # trace-time check only
        check_dcor_state(state)
    return state


def dcor_state_push(state: dict, row: jax.Array, slot, n_filled) -> dict:
    """Replace ring slot ``slot`` with observation ``row`` — O(W·C²).

    ``n_filled`` is the number of filled slots *before* this push (the
    sequential ring discipline: slot = step mod W, n_filled = min(step,
    W), so the replaced slot is either the first empty one or the oldest
    filled one). Removing old row/column k subtracts its pair sums;
    adding the new one is a masked (W, C) distance row plus rank-1-style
    updates to the row sums and the (C, C) cross products.
    """
    w = state["win"].shape[0]
    idx = jnp.arange(w, dtype=jnp.int32)
    keep = ((idx < n_filled) & (idx != slot)).astype(jnp.float32)[:, None]
    old = state["dist"][slot]  # (W, C); zero at unfilled slots
    new = jnp.abs(row[None, :].astype(jnp.float32) - state["win"]) * keep
    cross = state["cross"] - 2.0 * (old.T @ old) + 2.0 * (new.T @ new)
    rows = state["rows"] - old + new
    rows = rows.at[slot].set(new.sum(axis=0))
    dist = state["dist"].at[slot].set(new)
    dist = dist.at[:, slot].set(new)
    out = {
        "win": state["win"].at[slot].set(row.astype(jnp.float32)),
        "dist": dist,
        "rows": rows,
        "cross": cross,
    }
    if contracts_enabled():  # trace-time check only
        check_dcor_state(out)
    return out


def dcor_state_corr(state: dict, n_valid: jax.Array, d: int) -> jax.Array:
    """The (D, M) dCor matrix from maintained sums — what ``dcor_all``
    returns for the same window contents, without touching (W, W)."""
    n = jnp.maximum(n_valid, 1).astype(jnp.float32)
    rows = state["rows"]
    grand = rows.sum(axis=0)
    sums = (
        state["cross"]
        - (2.0 / n) * (rows.T @ rows)
        + grand[:, None] * grand[None, :] / (n * n)
    )
    diag = jnp.diagonal(sums)
    return dcor_from_sums(sums[:d, d:], diag[:d, None], diag[None, d:])
