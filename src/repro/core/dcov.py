"""Distance covariance / distance correlation (paper Eq. 1-4).

Székely & Rizzo (2009), "Brownian Distance Covariance". Given n paired
observations of a metric m and a hardware setting s:

    a_ij = ||m_i - m_j||,  b_ij = ||s_i - s_j||              (Eq. 1)
    A_ij = a_ij - ā_i. - ā_.j + ā_..   (double centering)    (Eq. 2)
    dCov²(m,s) = (1/n²) Σ_ij A_ij B_ij                        (Eq. 3)
    dCor(m,s)  = dCov(m,s) / sqrt(dCov(m,m)·dCov(s,s))        (Eq. 4)

dCor ∈ [0,1]; 0 iff statistically independent. The pure-jnp version below
is the reference; ``repro.kernels.dcov`` is the blocked Pallas TPU twin for
ORACLE-scale n (thousands of profiled configs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _pairwise_dist(x: jax.Array) -> jax.Array:
    """x: (n,) or (n,d) -> (n,n) euclidean distance matrix."""
    if x.ndim == 1:
        x = x[:, None]
    diff = x[:, None, :] - x[None, :, :]
    return jnp.sqrt(jnp.sum(diff.astype(jnp.float32) ** 2, axis=-1) + 0.0)


def _double_center(a: jax.Array) -> jax.Array:
    row = a.mean(axis=1, keepdims=True)
    col = a.mean(axis=0, keepdims=True)
    grand = a.mean()
    return a - row - col + grand


def dcov2(x: jax.Array, y: jax.Array) -> jax.Array:
    """Squared distance covariance (Eq. 3). Non-negative up to fp error."""
    A = _double_center(_pairwise_dist(x))
    B = _double_center(_pairwise_dist(y))
    return jnp.mean(A * B)


def dcor(x: jax.Array, y: jax.Array, eps: float = 1e-12) -> jax.Array:
    """Distance correlation (Eq. 4) in [0, 1]; 0 for degenerate inputs."""
    A = _double_center(_pairwise_dist(x))
    B = _double_center(_pairwise_dist(y))
    dxy = jnp.mean(A * B)
    dxx = jnp.mean(A * A)
    dyy = jnp.mean(B * B)
    denom = jnp.sqrt(jnp.maximum(dxx * dyy, 0.0))  # dVar(x)·dVar(y) = √(dxx·dyy)
    dcor2 = jnp.maximum(dxy, 0.0) / jnp.maximum(denom, eps)
    val = jnp.sqrt(dcor2)
    return jnp.where(denom < eps, 0.0, jnp.clip(val, 0.0, 1.0))


@jax.jit
def dcor_jit(x: jax.Array, y: jax.Array) -> jax.Array:
    return dcor(x, y)


def dcor_matrix(settings: jax.Array, metrics: jax.Array) -> jax.Array:
    """Correlation weights for every (setting dim, metric dim) pair.

    settings: (n, D) observations of D hardware parameters
    metrics:  (n, M) observations of M performance metrics
    returns:  (D, M) matrix of dCor values — column 0 is α (throughput),
              column 1 is β (power) in the CORAL formulation (Eq. 9).
    """
    def one_dim(s_col):
        return jax.vmap(lambda m_col: dcor(m_col, s_col), in_axes=1)(metrics)

    return jax.vmap(one_dim, in_axes=1)(settings)


def dcor_numpy(x: np.ndarray, y: np.ndarray) -> float:
    """Convenience wrapper for host-side (optimizer-loop) use."""
    return float(dcor_jit(jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.float32)))
