"""Hardware configuration space (paper §III-A, Table 2 analogue).

The five tunable dimensions mirror the paper's Jetson knobs mapped onto a
TPU v5e pod (DESIGN.md §2):

    host_cpu_freq  (MHz)  — input pipeline / dispatch speed
    host_cores     (#)    — preprocessing cores
    tpu_freq       (MHz)  — TPU core clock (scales peak FLOP/s)
    hbm_freq       (MHz)  — HBM clock (scales memory bandwidth)
    concurrency    (#)    — concurrent inference streams sharing the pod

Values are the *actual* physical values (not indices), as in the paper —
Alg. 2 does arithmetic on them and MINMAX/ROUND snaps back to the grid.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Iterable, List, Sequence, Tuple

import numpy as np

Config = Tuple[float, ...]


@dataclasses.dataclass(frozen=True)
class Dim:
    name: str
    values: Tuple[float, ...]  # sorted ascending

    def snap(self, v: float) -> float:
        arr = np.asarray(self.values)
        return float(arr[np.argmin(np.abs(arr - v))])

    @property
    def lo(self) -> float:
        return self.values[0]

    @property
    def hi(self) -> float:
        return self.values[-1]


@dataclasses.dataclass(frozen=True)
class ConfigSpace:
    dims: Tuple[Dim, ...]

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(d.name for d in self.dims)

    def size(self) -> int:
        n = 1
        for d in self.dims:
            n *= len(d.values)
        return n

    def snap(self, vec: Sequence[float]) -> Config:
        return tuple(d.snap(v) for d, v in zip(self.dims, vec))

    def clamp_round(self, vec: Sequence[float]) -> Config:
        """MINMAX(ROUND(v), r) of Alg. 2 — snap to the discrete grid."""
        return self.snap(vec)

    def all_configs(self) -> Iterable[Config]:
        return itertools.product(*(d.values for d in self.dims))

    def grid(self) -> np.ndarray:
        """(N, D) array of every config, rows in ``all_configs`` order —
        the array-native enumeration the batched device model sweeps."""
        mesh = np.meshgrid(
            *(np.asarray(d.values, np.float64) for d in self.dims), indexing="ij"
        )
        return np.stack([m.reshape(-1) for m in mesh], axis=1)

    def random(self, rng: np.random.Generator) -> Config:
        return tuple(float(rng.choice(d.values)) for d in self.dims)

    def index(self, name: str) -> int:
        return self.names.index(name)

    def midpoint(self) -> Config:
        """Grid midpoint — CORAL's iteration-0 probe (start anchor)."""
        return tuple(d.values[len(d.values) // 2] for d in self.dims)

    def preset(self, kind: str) -> Config:
        """Manufacturer-preset analogues (§IV-A baselines)."""
        if kind == "max_power":
            return tuple(d.hi for d in self.dims)
        if kind == "default":
            # nvpmodel default modes cap aggressively (e.g. Xavier 10W mode:
            # 2 cores, low clocks): second-lowest level, single stream.
            vals = []
            for d in self.dims:
                if d.name == CONCURRENCY_DIM:
                    vals.append(d.lo)
                else:
                    vals.append(d.values[min(1, len(d.values) - 1)])
            return tuple(vals)
        if kind == "min_power":
            return tuple(d.lo for d in self.dims)
        raise KeyError(kind)

    def neighbors(self, cfg: Config) -> List[Config]:
        out = []
        for i, d in enumerate(self.dims):
            j = d.values.index(cfg[i])
            for dj in (-1, 1):
                if 0 <= j + dj < len(d.values):
                    nb = list(cfg)
                    nb[i] = d.values[j + dj]
                    out.append(tuple(nb))
        return out


def tpu_pod_space() -> ConfigSpace:
    """Default TPU-pod knob grid (≈3.6k configs — paper scale, Table 4)."""
    return ConfigSpace(
        dims=(
            Dim("host_cpu_freq", tuple(float(v) for v in range(1200, 2800, 200))),  # 8
            Dim("host_cores", (2.0, 3.0, 4.0, 5.0, 6.0)),  # 5
            Dim("tpu_freq", (470.0, 564.0, 658.0, 752.0, 846.0, 940.0)),  # 6
            Dim("hbm_freq", (1600.0, 2133.0, 2665.0)),  # 3 (scales 819 GB/s)
            Dim("concurrency", (1.0, 2.0, 3.0, 4.0, 5.0)),  # 5
        )
    )


def jetson_like_space(device: str = "xavier_nx") -> ConfigSpace:
    """The paper's original Table-2 grids (for the fig-level benchmarks)."""
    if device == "xavier_nx":
        return ConfigSpace(
            dims=(
                Dim("cpu_freq", tuple(float(v) for v in range(1190, 1909, 100))),  # 8
                Dim("cpu_cores", (2.0, 3.0, 4.0, 5.0, 6.0)),  # 5
                Dim("gpu_freq", tuple(float(v) for v in range(510, 1101, 100))),  # 6
                Dim("mem_freq", (1500.0, 1600.0, 1866.0)),  # 3
                Dim("concurrency", (1.0, 2.0, 3.0)),  # 3
            )
        )
    if device == "orin_nano":
        return ConfigSpace(
            dims=(
                Dim("cpu_freq", tuple(float(v) for v in range(806, 1511, 100))),  # 8
                Dim("cpu_cores", (2.0, 3.0, 4.0, 5.0, 6.0)),  # 5
                Dim("gpu_freq", (306.0, 406.0, 506.0, 624.0)),  # 4
                Dim("mem_freq", (2133.0, 3199.0)),  # 2
                Dim("concurrency", (1.0, 2.0, 3.0, 4.0, 5.0)),  # 5
            )
        )
    raise KeyError(device)


def profile_space(kind: str) -> ConfigSpace:
    """Knob grids owned by the device-profile registry (``repro.device.hw``).

    These are the *deployment* ladders the scenario matrix tunes over —
    distinct from ``jetson_like_space``, which reproduces the paper's
    Table-2 grids verbatim for the figure-level benchmarks. The edge
    profiles differ in every ladder (CPU/GPU/EMC steps, stream counts):
    per-device tuning landscapes genuinely differ, which is what the
    matrix exists to show.
    """
    if kind == "edge_xavier_nx":
        return ConfigSpace(
            dims=(
                Dim("cpu_freq", tuple(float(v) for v in range(1190, 1909, 100))),  # 8
                Dim("cpu_cores", (2.0, 3.0, 4.0, 5.0, 6.0)),  # 5
                Dim("gpu_freq", tuple(float(v) for v in range(510, 1101, 100))),  # 6
                Dim("mem_freq", (1600.0, 1866.0)),  # 2 binned EMC steps
                Dim("concurrency", (1.0, 2.0, 3.0)),  # 3
            )
        )
    if kind == "edge_orin_nano":
        return ConfigSpace(
            dims=(
                Dim("cpu_freq", tuple(float(v) for v in range(806, 1511, 100))),  # 8
                Dim("cpu_cores", (2.0, 3.0, 4.0, 5.0, 6.0)),  # 5
                Dim("gpu_freq", (306.0, 406.0, 506.0, 624.0)),  # 4
                Dim("mem_freq", (2133.0, 3199.0)),  # 2
                Dim("concurrency", (1.0, 2.0, 3.0, 4.0, 5.0)),  # 5
            )
        )
    if kind == "edge_orin_nx":
        return ConfigSpace(
            dims=(
                Dim("cpu_freq", tuple(float(v) for v in range(1190, 1985, 110))),  # 8
                Dim("cpu_cores", (2.0, 3.0, 4.0, 5.0, 6.0)),  # 5
                Dim("gpu_freq", (306.0, 408.0, 510.0, 612.0, 765.0, 918.0)),  # 6
                Dim("mem_freq", (1866.0, 2665.0, 3733.0)),  # 3 LPDDR5 steps
                Dim("concurrency", (1.0, 2.0, 3.0, 4.0)),  # 4
            )
        )
    if kind == "tpu_pod":
        return tpu_pod_space()
    raise KeyError(kind)


def offload_space(edge_kind: str) -> ConfigSpace:
    """The joint edge↔pod offload grid for one edge profile.

    Five dimensions — the same D as every profile space, so offload
    episodes batch into the same compiled ``jit(vmap(scan))`` call as
    the stationary matrix cells (``repro.core.episode`` requires one
    grid dimensionality per batch):

        gpu_freq      — the edge accelerator ladder, coarsened to ≤4
                        levels (ends kept) to hold N in the low hundreds;
        mem_freq      — the edge memory ladder, unchanged;
        concurrency   — edge inference streams (first 3 ladder steps);
        offload_frac  — the route split φ: the fraction of admitted
                        items shipped to the pod (0 = all-local);
        pod_tpu_freq  — the pod-side TPU DVFS point (coarse 3-step
                        ladder), visible from the edge through the
                        offload path's window/slice capacity.

    Edge CPU knobs are not searched — ``OffloadSimulator`` pins them at
    nominal — so Alg. 2's cores-role mask is empty here, which
    ``repro.core.search.role_mask`` handles as a no-op."""
    edge = profile_space(edge_kind)
    gpu = edge.dims[edge.names.index("gpu_freq")].values
    if len(gpu) > 4:
        keep = np.linspace(0, len(gpu) - 1, 4).round().astype(int)
        gpu = tuple(gpu[i] for i in keep)
    mem = edge.dims[edge.names.index("mem_freq")].values
    conc = edge.dims[edge.names.index("concurrency")].values[:3]
    pod = tpu_pod_space()
    pod_f = pod.dims[pod.names.index("tpu_freq")].values
    pod_keep = np.linspace(0, len(pod_f) - 1, 3).round().astype(int)
    return ConfigSpace(
        dims=(
            Dim("gpu_freq", gpu),
            Dim("mem_freq", mem),
            Dim("concurrency", conc),
            Dim(OFFLOAD_DIM, (0.0, 0.2, 0.4, 0.6, 0.8)),
            Dim("pod_tpu_freq", tuple(pod_f[i] for i in pod_keep)),
        )
    )


def cotenant_space(edge_kind: str, n_tenants: int = 2) -> ConfigSpace:
    """The joint multi-tenant grid for one edge profile: per-tenant decode
    slot allocations × shared DVFS.

    Five dimensions for the default two tenants — the same D as every
    profile/offload space, so cotenant episodes batch into the same
    compiled ``jit(vmap(scan))`` call as the rest of the scenario matrix:

        gpu_freq   — the shared accelerator ladder, coarsened to ≤4
                     levels (ends kept) to hold N in the low hundreds;
        mem_freq   — the shared memory ladder, unchanged;
        cpu_freq   — the shared host ladder, coarsened to 3 levels (the
                     host stage is per-tenant work but the clock is one
                     rail-wide knob);
        slots_t0   — tenant 0's decode-slot allocation (streams);
        slots_t1   — tenant 1's decode-slot allocation.

    There is no ``concurrency`` or cores dimension: total stream pressure
    is the *sum* of the slot knobs (``CotenantSimulator`` feeds it into
    the shared contention kappa), so Alg. 2's cores/concurrency role
    masks are empty no-ops here, exactly as in ``offload_space``."""
    edge = profile_space(edge_kind)
    gpu = edge.dims[edge.names.index("gpu_freq")].values
    if len(gpu) > 4:
        keep = np.linspace(0, len(gpu) - 1, 4).round().astype(int)
        gpu = tuple(gpu[i] for i in keep)
    mem = edge.dims[edge.names.index("mem_freq")].values
    cpu = edge.dims[edge.names.index("cpu_freq")].values
    cpu_keep = np.linspace(0, len(cpu) - 1, 3).round().astype(int)
    slot_dims = tuple(
        Dim(f"{TENANT_SLOT_PREFIX}{k}", (1.0, 2.0, 3.0))
        for k in range(n_tenants)
    )
    return ConfigSpace(
        dims=(
            Dim("gpu_freq", gpu),
            Dim("mem_freq", mem),
            Dim("cpu_freq", tuple(cpu[i] for i in cpu_keep)),
        )
        + slot_dims
    )


def tenant_slot_indices(space: ConfigSpace) -> Tuple[int, ...]:
    """Indices of the per-tenant slot dims (``slots_t0``, ``slots_t1``, …)
    in tenant order — empty for single-tenant spaces. The serving
    controller and the cotenant twin both locate the allocation knobs
    through this instead of hard-coding positions."""
    found = [
        (int(n[len(TENANT_SLOT_PREFIX) :]), i)
        for i, n in enumerate(space.names)
        if n.startswith(TENANT_SLOT_PREFIX)
    ]
    return tuple(i for _, i in sorted(found))


# Dimension roles used by Alg. 2's power-optimization heuristic
CORES_DIM_CANDIDATES = ("host_cores", "cpu_cores")
CONCURRENCY_DIM = "concurrency"
CPU_FREQ_DIM_CANDIDATES = ("host_cpu_freq", "cpu_freq")
# The route-split knob of the joint edge↔pod offload space — a role
# name so the serving controller and admission seam can locate it
# without hard-coding a dimension index.
OFFLOAD_DIM = "offload_frac"
# Per-tenant slot-allocation knobs of the joint cotenant space are named
# ``slots_t<k>`` (tenant index k) — a prefix role, not a fixed name,
# because the tenant count is a property of the space.
TENANT_SLOT_PREFIX = "slots_t"


# ---------------------------------------------------------------------------
# Cached array views of a space — the index-space twin of ``grid()``.
#
# The episode engine (repro.core.episode) represents configurations as
# grid-row indices inside compiled scans; the scalar CORAL loop shares
# these same cached arrays so the two paths resolve rows, level indices
# and neighbor distances identically. ConfigSpace is a frozen (hashable)
# dataclass, so an lru_cache keyed on the space itself is sound.
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def space_grid(space: ConfigSpace) -> np.ndarray:
    """Cached ``space.grid()`` — (N, D) float64, ``all_configs`` order."""
    return space.grid()


@functools.lru_cache(maxsize=None)
def space_rows(space: ConfigSpace) -> Tuple[Config, ...]:
    """Row index → config tuple, in ``all_configs`` order."""
    return tuple(space.all_configs())


@functools.lru_cache(maxsize=None)
def index_coords(space: ConfigSpace) -> np.ndarray:
    """(N, D) int32 per-dimension *level* indices for every grid row."""
    sizes = [len(d.values) for d in space.dims]
    mesh = np.meshgrid(*(np.arange(s) for s in sizes), indexing="ij")
    return np.stack([m.reshape(-1) for m in mesh], axis=1).astype(np.int32)


@functools.lru_cache(maxsize=None)
def level_strides(space: ConfigSpace) -> np.ndarray:
    """(D,) int32 strides mapping level indices to the grid-row index
    (dim 0 outermost, matching ``all_configs``/``grid`` order)."""
    sizes = [len(d.values) for d in space.dims]
    strides = np.ones(len(sizes), np.int64)
    for i in range(len(sizes) - 2, -1, -1):
        strides[i] = strides[i + 1] * sizes[i + 1]
    return strides.astype(np.int32)


def row_index(space: ConfigSpace, cfg: Sequence[float]) -> int:
    """Grid-row index of an on-grid config (exact value match)."""
    levels = [d.values.index(v) for d, v in zip(space.dims, cfg)]
    return int(np.dot(levels, level_strides(space)))
