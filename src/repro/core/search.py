"""Configuration search — paper Algorithm 2.

For each dimension i:
    γ_i = max(α_i, β_i)                       (line 3)
    Δ_i = ½ |x_i − y_i| · γ_i                 (line 4, Eq. 10)
    (l, h) = (y_i, x_i) if aside else (x_i, y_i)   (line 5)
    if τ_last > τ_target and p_last ≥ p_min:  v_i = l − Δ_i   (power-saving)
    else:                                      v_i = h + Δ_i   (throughput)
    z_i = MINMAX(ROUND(v_i), ranges_i)        (line 11 — snap to grid)

Power-optimization heuristic (lines 14–17): when the best config already
meets the throughput target but its power is still above the floor, pin
CPU cores to MIN and concurrency to MAX (CPU is a dominant power consumer;
concurrency compensates for the reduced host throughput).

The paper leaves ``aside`` informally specified ("aside flag"). We set it
when the *last* probe failed to improve on the best reward — flipping the
(l, h) anchors makes the next step explore from the second-best side
instead of re-extrapolating past the best. This interpretation is recorded
in DESIGN.md and exercised by tests.

Discrete-grid adaptation (documented deviation): the paper's MHz ranges are
effectively continuous (100 MHz steps), so Δ_i > 0 whenever x_i ≠ y_i. On a
coarse grid the best/second-best anchors can collapse to x_i == y_i in most
dimensions, making Δ_i = 0 and freezing the search. We therefore floor the
raw step at one grid notch *before* scaling by γ_i:

    Δ_i = max(½|x_i − y_i|, notch_i) · γ_i

so after ROUND, dimensions with strong correlation (γ_i ≳ 0.5) always move
at least one level while weakly-correlated dimensions still "change
minimally" (round back to their current value) — preserving the paper's
stated semantics on a discrete grid.
"""
from __future__ import annotations

from typing import Sequence


from repro.core.space import (
    CONCURRENCY_DIM,
    CORES_DIM_CANDIDATES,
    ConfigSpace,
    Config,
)


def next_config(
    space: ConfigSpace,
    x: Config,  # best setting
    y: Config,  # second-best setting
    alpha: Sequence[float],  # dCor(τ, s_i)
    beta: Sequence[float],  # dCor(p, s_i)
    tau_last: float,
    p_last: float,
    tau_target: float,
    p_min: float,
    aside: bool,
    tau_best: float,
    p_best: float,
    power_probe: bool = True,
    step_floor: bool = True,
    gamma_mode: str = "max",  # max (paper Alg.2 line 3) | directional
) -> Config:
    z = []
    down = tau_last > tau_target and p_last >= p_min  # line 6
    for i, dim in enumerate(space.dims):
        if gamma_mode == "directional":
            # beyond-paper: weight the step by the correlation that matches
            # the direction's objective — β (power) when descending to save
            # power, α (throughput) when climbing toward the target
            gamma = beta[i] if down else alpha[i]
        else:
            gamma = max(alpha[i], beta[i])  # line 3
        notch = min(
            (abs(b - a) for a, b in zip(dim.values, dim.values[1:])),
            default=0.0,
        ) if step_floor else 0.0
        delta = max(0.5 * abs(x[i] - y[i]), notch) * gamma  # line 4 + floor
        lo, hi = (y[i], x[i]) if aside else (x[i], y[i])  # line 5
        v = (lo - delta) if down else (hi + delta)  # lines 7/9
        z.append(v)
    z = list(space.clamp_round(z))  # line 11

    if power_probe and p_best > p_min and tau_best > tau_target:  # lines 14-17
        for cand in CORES_DIM_CANDIDATES:
            if cand in space.names:
                z[space.index(cand)] = space.dims[space.index(cand)].lo
        if CONCURRENCY_DIM in space.names:
            z[space.index(CONCURRENCY_DIM)] = space.dims[
                space.index(CONCURRENCY_DIM)
            ].hi
    return tuple(z)
