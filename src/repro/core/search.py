"""Configuration search — paper Algorithm 2.

For each dimension i:
    γ_i = max(α_i, β_i)                       (line 3)
    Δ_i = ½ |x_i − y_i| · γ_i                 (line 4, Eq. 10)
    (l, h) = (y_i, x_i) if aside else (x_i, y_i)   (line 5)
    if τ_last > τ_target and p_last ≥ p_min:  v_i = l − Δ_i   (power-saving)
    else:                                      v_i = h + Δ_i   (throughput)
    z_i = MINMAX(ROUND(v_i), ranges_i)        (line 11 — snap to grid)

Power-optimization heuristic (lines 14–17): when the best config already
meets the throughput target but its power is still above the floor, pin
CPU cores to MIN and concurrency to MAX (CPU is a dominant power consumer;
concurrency compensates for the reduced host throughput).

The paper leaves ``aside`` informally specified ("aside flag"). We set it
when the *last* probe failed to improve on the best reward — flipping the
(l, h) anchors makes the next step explore from the second-best side
instead of re-extrapolating past the best. This interpretation is recorded
in DESIGN.md and exercised by tests.

Discrete-grid adaptation (documented deviation): the paper's MHz ranges are
effectively continuous (100 MHz steps), so Δ_i > 0 whenever x_i ≠ y_i. On a
coarse grid the best/second-best anchors can collapse to x_i == y_i in most
dimensions, making Δ_i = 0 and freezing the search. We therefore floor the
raw step at one grid notch *before* scaling by γ_i:

    Δ_i = max(½|x_i − y_i|, notch_i) · γ_i

so after ROUND, dimensions with strong correlation (γ_i ≳ 0.5) always move
at least one level while weakly-correlated dimensions still "change
minimally" (round back to their current value) — preserving the paper's
stated semantics on a discrete grid.

Canonical float32 arithmetic (episode-engine equivalence): the step is
evaluated by ``alg2_levels`` — one function written against the shared
numpy/jnp array API — in float32 throughout, because the correlation
weights arrive as float32 from ``dcor_all`` and the compiled episode
engine (repro.core.episode) traces the identical function under jax.
Running the scalar loop through the same op sequence at the same
precision is what makes compiled episodes reproduce scalar selections
bit-for-bit: grid values are exactly representable in float32, so the
only rounding happens in the γ-scaled step itself, identically on both
paths (argmin ties snap to the lower level on both).
"""
from __future__ import annotations

import functools
from typing import Sequence

import numpy as np

from repro.core.space import (
    CONCURRENCY_DIM,
    CORES_DIM_CANDIDATES,
    ConfigSpace,
    Config,
)


@functools.lru_cache(maxsize=None)
def dim_notches(space: ConfigSpace, step_floor: bool = True) -> np.ndarray:
    """(D,) float32 minimum grid gap per dimension (0 without the floor)."""
    if not step_floor:
        return np.zeros(len(space.dims), np.float32)
    return np.asarray(
        [
            min(
                (abs(b - a) for a, b in zip(d.values, d.values[1:])),
                default=0.0,
            )
            for d in space.dims
        ],
        np.float32,
    )


@functools.lru_cache(maxsize=None)
def padded_ladders(space: ConfigSpace) -> np.ndarray:
    """(D, Lmax) float32 per-dim value ladders, padded with +inf so the
    snap argmin never selects a padding level."""
    lmax = max(len(d.values) for d in space.dims)
    out = np.full((len(space.dims), lmax), np.inf, np.float32)
    for i, d in enumerate(space.dims):
        out[i, : len(d.values)] = np.asarray(d.values, np.float32)
    return out


def alg2_levels(
    xp,
    x,  # (D,) float32 best setting values
    y,  # (D,) float32 second-best setting values
    gamma,  # (D,) float32 correlation weights (line 3, already mode-resolved)
    notches,  # (D,) float32 step floor per dim (0 disables)
    ladders,  # (D, Lmax) float32 value ladders, +inf padded
    n_levels,  # (D,) int32 live levels per dim
    aside,  # bool scalar — flip (l, h) anchors (line 5)
    down,  # bool scalar — power-saving direction (line 6)
    probe,  # bool scalar — lines 14-17 requested by the caller's policy
    tau_best,
    p_best,
    tau_target,
    p_min,
    cores_mask,  # (D,) bool — the CPU-cores dimension (lines 14-17)
    conc_mask,  # (D,) bool — the concurrency dimension (lines 14-17)
):
    """Alg. 2 lines 3-17 on level indices, shared numpy/jnp (pass ``xp``).

    Returns (D,) int32 level indices of MINMAX(ROUND(v)). Written once
    against the common array API so the scalar loop (xp=numpy) and the
    compiled episode scan (xp=jax.numpy) execute the identical float32
    op sequence — the equivalence tests assert bitwise-equal proposals.
    """
    delta = xp.maximum(xp.float32(0.5) * xp.abs(x - y), notches) * gamma
    lo = xp.where(aside, y, x)
    hi = xp.where(aside, x, y)
    v = xp.where(down, lo - delta, hi + delta)  # lines 7/9
    levels = xp.argmin(xp.abs(ladders - v[:, None]), axis=1).astype(xp.int32)
    probe_eff = probe & (p_best > p_min) & (tau_best > tau_target)
    levels = xp.where(probe_eff & cores_mask, 0, levels)
    levels = xp.where(probe_eff & conc_mask, n_levels - 1, levels)
    return levels


def next_config(
    space: ConfigSpace,
    x: Config,  # best setting
    y: Config,  # second-best setting
    alpha: Sequence[float],  # dCor(τ, s_i)
    beta: Sequence[float],  # dCor(p, s_i)
    tau_last: float,
    p_last: float,
    tau_target: float,
    p_min: float,
    aside: bool,
    tau_best: float,
    p_best: float,
    power_probe: bool = True,
    step_floor: bool = True,
    gamma_mode: str = "max",  # max (paper Alg.2 line 3) | directional
) -> Config:
    """Paper Alg. 2 proposal: move each knob from the best setting ``x``
    toward/away from the second-best ``y`` by a step scaled with the
    per-dimension dCor weights (α for τ, β for p), descending when the
    last measurement cleared the target and climbing otherwise. Thin
    host wrapper over the array-based ``alg2_levels`` the engine jits."""
    down = tau_last > tau_target and p_last >= p_min  # line 6
    alpha32 = np.asarray(alpha, np.float32)
    beta32 = np.asarray(beta, np.float32)
    if gamma_mode == "directional":
        # beyond-paper: weight the step by the correlation that matches
        # the direction's objective — β (power) when descending to save
        # power, α (throughput) when climbing toward the target
        gamma = beta32 if down else alpha32
    else:
        gamma = np.maximum(alpha32, beta32)  # line 3
    levels = alg2_levels(
        np,
        np.asarray(x, np.float32),
        np.asarray(y, np.float32),
        gamma,
        dim_notches(space, step_floor),
        padded_ladders(space),
        np.asarray([len(d.values) for d in space.dims], np.int32),
        np.bool_(aside),
        np.bool_(down),
        np.bool_(power_probe),
        np.float32(tau_best),
        np.float32(p_best),
        np.float32(tau_target),
        np.float32(p_min),
        role_mask(space, CORES_DIM_CANDIDATES),
        role_mask(space, (CONCURRENCY_DIM,)),
    )
    return tuple(d.values[int(j)] for d, j in zip(space.dims, levels))


@functools.lru_cache(maxsize=None)
def role_mask(space: ConfigSpace, names: Sequence[str]) -> np.ndarray:
    """(D,) bool mask of the dimensions whose name is in ``names``."""
    return np.asarray([d.name in names for d in space.dims])
