"""Array-native episode engine: one compiled call per batch of episodes.

The scalar loops in ``repro.core.evaluate`` run CORAL one interpreter
iteration at a time — ~50 tiny jitted ``dcor_all`` dispatches per cell,
repeated across 54 static cells × 3 seeds and 6 drift cells × 3 seeds ×
2 variants every nightly run. This module re-expresses a *whole episode*
as a pure ``lax.scan`` step over fixed-size array state and lifts it
with ``vmap`` across seeds × cells × baseline variants: the entire
episode layer of the scenario matrix becomes ONE compiled call per
episode family (static, drift).

State layout (one episode):

  - history: one ``(T+W, D+4)`` append-only observation block — config
    values, τ, p, then the clock stamp and grid-row index as exact-
    integer float32 columns — so recording an observation is a single
    scatter. Appending at row ``n_obs`` keeps unwritten rows zero, so
    ``lax.dynamic_slice`` at the window start reproduces the scalar
    path's zero-padded ``(W, D+2)`` dcor input bit-for-bit — the *same*
    jitted dcor math serves both paths (``dcor_all_cols``).
  - seen tag: one ``(N,)`` int32 over ``space.grid()`` rows — a row is
    prohibited forever at ``INT_MAX`` (Alg. 1) or visited-this-epoch at
    the current ``epoch_id``; ``tag >= epoch_id`` is the whole revisit
    test, a drift re-exploration resets it by bumping the scalar
    ``epoch_id``, and writes are O(1) scatters. The canonical escape
    (CORAL._escape_prohibited) is one argmin over a precomputed,
    device-resident ``(N, N)`` key table of
    ``L1-level-distance · N + row``.
  - anchors: best / second / last as (row-index, τ, p, reward) scalars
    with validity flags, replacing ``Observation`` objects. Every state
    update is gated at the leaf (``where(taken, new, old)``) — there is
    no branch-and-select over the whole carry, which keeps the per-step
    op count flat.
  - the device twin is folded in as data: ``(T, N)`` measurement tables
    — the float64 landscape times the seed's exact numpy noise stream,
    precomputed host-side and cast to float32 — so a measurement inside
    the scan is a single gather. The adaptive and static variants of a
    drift cell share one table via ``table_id``.

Everything cell-specific — the constraint shape (``throughput`` flag,
τ target, budget), even the drift variant (``adaptive`` flag) — rides
the batch axis as data. Grids are zero-padded to the batch's largest
space (padding rows are born prohibited, so no code path can select
them); the padded per-space constants stay device-resident across calls
and are selected per episode by ``space_id``, so only measurement
tables cross the host/device boundary per call. One jit specializes
only on episode *structure*: (T, W, D, padded N, the participating
spaces, drift-ness).

Equivalence contract (tests/test_episode.py): compiled episodes replay
the scalar loops' *selections* exactly — same chosen configs per seed —
and τ/p traces are reconstructed in float64 from the same landscape ×
noise products, so they are bitwise equal to the scalar measurements.
Decision arithmetic inside the scan runs in float32; the scalar path
was canonicalized to the same float32 ops (``search.alg2_levels``),
leaving fp-tie flips (two float64 quantities within one float32 ulp) as
the only divergence channel — never observed across the matrix, and
pinned by the equivalence suite.

What is deliberately NOT vectorized: see EXPERIMENTS.md §Episode engine
(open-loop baselines are gathers, not scans; the ALERT offline profiler
is already one ``measure_all`` sweep; per-cell scoring stays numpy
float64 host code).
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import functools
import os
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import contracts, faults, sanitize, search
from repro.core.baselines import Outcome
from repro.core.dcov import (
    dcor_all_cols,
    dcor_state_corr,
    dcor_state_from_window,
    dcor_state_push,
)
from repro.core.space import (
    CONCURRENCY_DIM,
    CORES_DIM_CANDIDATES,
    ConfigSpace,
    index_coords,
    level_strides,
    row_index,
    space_grid,
    space_rows,
)

_INT_MAX = np.int32(np.iinfo(np.int32).max)

# The episode jits donate their per-call operands (batch + measurement
# tables). Buffers whose shapes don't line up with an output can't be
# *reused* by XLA, and jax warns about that — but donation still drops
# the host reference so the backing memory is released at dispatch
# instead of surviving the whole call, which is the effect the fleet
# path needs. The warning is expected, per-compile, and non-actionable.
warnings.filterwarnings("ignore", message="Some donated buffers were not usable")


# ---------------------------------------------------------------------------
# Engine specification — only what shapes the compiled program.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """Structural (compile-time) episode parameters. Hashable: one
    compiled executable per distinct spec, cached via ``lru_cache``.
    ``spaces`` is the ordered tuple of distinct grids in the batch —
    their padded constants are baked into the executable and selected
    per episode by ``space_id``."""

    spaces: Tuple[ConfigSpace, ...]
    iters: int  # episode length T (intervals for drift episodes)
    window: int  # dCor sliding window W
    drift: bool = False  # epoch-structured drift episode
    # fleet episodes trade the dense (T, N) measurement tables for a
    # factored form — (U, N) landscapes × per-episode (T, 2) noise, an
    # outer product evaluated inside the scan — and run the windowed
    # dCor incrementally (O(W·C²) per step instead of O(W²·C)). They
    # also accept warm-start state. No scalar twin exists for this path:
    # its contract is determinism, not bitwise equivalence.
    fleet: bool = False
    explore_budget: int = 10
    halflife: Optional[float] = None  # dCor age horizon (drift: window)
    calibration: int = 8
    k_sigma: float = 1.25
    h_sigma: float = 9.0
    max_retries: int = 2
    p_min: float = 0.0
    # fault episodes: static episodes whose measurement tables carry
    # spikes/NaN and whose actuation path can stick or firmware-reset;
    # the robustness constants mirror core.faults.RobustConfig and are
    # compile-time (one hardened-vs-ablation pair shares a program —
    # ``hardened`` itself is traced episode data)
    fault: bool = False
    gate_g: float = 2.5
    gate_eps: float = 0.7
    min_accept: int = 5
    watchdog: int = 3
    act_retries: int = 3

    @property
    def n(self) -> int:
        return max(s.size() for s in self.spaces)

    @property
    def d(self) -> int:
        return len(self.spaces[0].dims)

    @property
    def lmax(self) -> int:
        return max(len(d.values) for s in self.spaces for d in s.dims)


@functools.lru_cache(maxsize=None)
def _space_consts(space: ConfigSpace) -> Dict[str, np.ndarray]:
    """Per-space constant arrays, padded per batch by ``_packed_consts``."""
    return {
        "grid32": space_grid(space).astype(np.float32),
        "coords": index_coords(space),
        "strides": level_strides(space),
        "ladders": search.padded_ladders(space),
        "n_levels": np.asarray([len(d.values) for d in space.dims], np.int32),
        "notches": search.dim_notches(space, True),
        "cores_mask": np.asarray(search.role_mask(space, CORES_DIM_CANDIDATES)),
        "conc_mask": np.asarray(search.role_mask(space, (CONCURRENCY_DIM,))),
        "mid_idx": np.int32(row_index(space, space.midpoint())),
        "max_idx": np.int32(row_index(space, space.preset("max_power"))),
        "min_idx": np.int32(row_index(space, space.preset("min_power"))),
    }


@functools.lru_cache(maxsize=None)
def _packed_consts(spec: EngineSpec) -> Dict[str, np.ndarray]:
    """The batch's space constants stacked over ``spaces`` and padded to
    (n rows, lmax levels). Padding grid rows are zeros and born
    prohibited (``pad_mask``); padding ladder levels are +inf so the
    snap argmin never selects them."""
    n, lmax, d = spec.n, spec.lmax, spec.d
    s = len(spec.spaces)
    out = {
        "grid32": np.zeros((s, n, d), np.float32),
        "ladders": np.full((s, d, lmax), np.inf, np.float32),
        "pad_mask": np.ones((s, n), bool),
    }
    for name in ("strides", "n_levels", "notches", "cores_mask", "conc_mask"):
        out[name] = np.stack(
            [_space_consts(sp)[name] for sp in spec.spaces]
        )
    for name in ("mid_idx", "max_idx", "min_idx"):
        out[name] = np.asarray(
            [_space_consts(sp)[name] for sp in spec.spaces], np.int32
        )
    for i, sp in enumerate(spec.spaces):
        k = _space_consts(sp)
        n0 = k["grid32"].shape[0]
        out["grid32"][i, :n0] = k["grid32"]
        out["ladders"][i, :, : k["ladders"].shape[1]] = k["ladders"]
        out["pad_mask"][i, :n0] = False
    return out


@functools.lru_cache(maxsize=None)
def _escape_key_table(space: ConfigSpace, n: int) -> np.ndarray:
    """(n, n) int32 canonical escape keys: row c is ``L1-level-distance
    to c · n + row index`` for every grid row — the exact ordering
    CORAL._escape_prohibited minimizes (the padded multiplier n ≥ N
    preserves the (distance, row) lexicographic order). Precomputing the
    table turns the per-step escape into one row gather + argmin instead
    of an (N × D) distance reduction inside the scan."""
    coords = index_coords(space).astype(np.int32)
    n0 = coords.shape[0]
    dist = np.zeros((n0, n0), np.int32)
    for dim in range(coords.shape[1]):
        lev = coords[:, dim]
        dist += np.abs(lev[:, None] - lev[None, :])
    out = np.full((n, n), _INT_MAX, np.int32)
    out[:n0, :n0] = dist * np.int32(n) + np.arange(n0, dtype=np.int32)[None, :]
    return out


@functools.lru_cache(maxsize=None)
def _device_consts(spec: EngineSpec) -> Dict[str, jnp.ndarray]:
    """Device-resident constants for one spec, staged once — passed as
    (unbatched) jit arguments so calls move only measurement tables."""
    dc = {name: jnp.asarray(v) for name, v in _packed_consts(spec).items()}
    dc["key_tab"] = jnp.asarray(
        np.stack([_escape_key_table(sp, spec.n) for sp in spec.spaces])
    )
    return dc


# ---------------------------------------------------------------------------
# Carry construction and (flat, gated) epoch reset
# ---------------------------------------------------------------------------


def _init_carry(spec: EngineSpec, ep: Dict, pad_mask) -> Dict[str, jnp.ndarray]:
    """Fixed-size episode carry. Contract (core/contracts.py, enforced
    under REPRO_CONTRACTS=1, cross-checked statically by lint rule
    RL04): ``hist_sm: Float32[Array, "T+W D+4"]``, ``seen_tag:
    Int32[Array, "N"]`` plus f32/i32/bool anchor scalars; fleet adds the
    ``dc_*`` dCor accumulators (``Float32[Array, "W C"]``, C = D+2),
    drift adds the budget/CUSUM-monitor scalars."""
    t, w, d = spec.iters, spec.window, spec.d
    f32, i32 = jnp.float32, jnp.int32
    c = {
        # one (T+W, D+4) observation block: config values, τ, p, then
        # the clock stamp and the grid-row index as exact-integer
        # float32 columns — the whole observation is ONE scatter per
        # step, and the leading D+2 columns are already the dcor window
        # layout so the propose step slices it once
        "hist_sm": jnp.zeros((t + w, d + 4), f32),
        "n_obs": i32(0),
        "epoch_start": i32(0),
        "epoch_id": i32(0),
        "clock": i32(0),
        # one (N,) "seen" tag: row is prohibited forever at INT_MAX
        # (padding rows are born there, so no code path selects them) or
        # visited-this-epoch at the current epoch_id — ``tag >= epoch_id``
        # is the whole revisit test, and re-exploration resets it by
        # bumping the scalar epoch_id
        "seen_tag": jnp.where(pad_mask, jnp.int32(_INT_MAX), jnp.int32(-1)),
        "best_idx": i32(-1),
        "best_tau": f32(0),
        "best_p": f32(0),
        "best_r": f32(-jnp.inf),
        "best_valid": jnp.bool_(False),
        "sec_idx": i32(-1),
        "sec_tau": f32(0),
        "sec_p": f32(0),
        "sec_r": f32(-jnp.inf),
        "sec_valid": jnp.bool_(False),
        "last_idx": i32(-1),
        "last_tau": f32(0),
        "last_p": f32(0),
        "last_valid": jnp.bool_(False),
        "aside": jnp.bool_(False),
        "probed_for": i32(-1),
        "probe_done": jnp.bool_(False),
    }
    if spec.fleet:
        # Warm-started twins inherit a converged neighbor's context —
        # dCor window rows, prohibited set, anchors — as *data*, gated
        # per episode by the ``warm`` flag so cold twins share the
        # program. The inherited rows live at history rows [0, warm_n)
        # and ring slots [0, warm_n), so the sequential slot discipline
        # (slot = n_obs mod W) continues seamlessly.
        warm = ep["warm"]
        warm_n = jnp.where(warm, ep["warm_n"], 0).astype(i32)
        wh = jnp.where(warm, ep["warm_hist"], 0.0).astype(f32)  # (W, D+4)
        c["hist_sm"] = c["hist_sm"].at[:w].set(wh)
        c["n_obs"] = warm_n
        c["seen_tag"] = jnp.where(
            warm & ep["warm_prohibit"], jnp.int32(_INT_MAX), c["seen_tag"]
        )
        for nm in ("best", "sec"):
            for fld, dtype in (("idx", i32), ("tau", f32), ("p", f32), ("r", f32)):
                c[f"{nm}_{fld}"] = jnp.where(
                    warm, ep[f"warm_{nm}_{fld}"].astype(dtype), c[f"{nm}_{fld}"]
                )
            c[f"{nm}_valid"] = warm & ep[f"warm_{nm}_valid"]
        for fld, dtype in (("idx", i32), ("tau", f32), ("p", f32)):
            c[f"last_{fld}"] = jnp.where(
                warm, ep[f"warm_last_{fld}"].astype(dtype), c[f"last_{fld}"]
            )
        c["last_valid"] = warm & ep["warm_last_valid"]
        # incremental dCor accumulators, seeded from the warm window
        # (cold twins: n_valid = 0 builds the all-zero state)
        st = dcor_state_from_window(wh[:, : d + 2], warm_n)
        for nm, v in st.items():
            c[f"dc_{nm}"] = v
    if spec.drift:
        c.update(
            p_budget=jnp.asarray(ep["p_budget0"], f32),
            mon_sigma=jnp.maximum(jnp.asarray(ep["sigma"], f32), 1e-6),
            held_idx=i32(-1),
            held_tau=f32(0),
            held_p=f32(0),
            held_valid=jnp.bool_(False),
            mon_ref_tau=f32(1),
            mon_ref_p=f32(1),
            mon_calib=i32(0),
            mon_pos_tau=f32(0),
            mon_neg_tau=f32(0),
            mon_pos_p=f32(0),
            mon_neg_p=f32(0),
            mon_active=jnp.bool_(False),
            retries=i32(0),
            resets=i32(0),
        )
    if spec.fault:
        c.update(
            # a rebooted device is on its firmware default row; the
            # watchdog counter starts calm
            applied_idx=jnp.asarray(ep["boot_idx"], i32),
            dark=i32(0),
        )
    # REPRO_CONTRACTS=1: validate against core/contracts.py (trace-time
    # only — nothing runs per scan step); rule RL04 cross-checks the
    # same tables statically
    if contracts.contracts_enabled():
        contracts.check_carry(spec, c)
    return c


def _re_explore(c: Dict, cond) -> Dict:
    """CORAL.re_explore gated by ``cond``: fresh epoch for anchors /
    window / probe / revisit state, prohibited memory kept. Scalar-only
    updates — revisit tracking resets by bumping ``epoch_id``."""
    c = dict(c)
    c["epoch_start"] = jnp.where(cond, c["n_obs"], c["epoch_start"])
    c["epoch_id"] = c["epoch_id"] + cond.astype(jnp.int32)
    neg_inf = jnp.float32(-jnp.inf)
    for k in ("best", "sec", "last"):
        c[f"{k}_valid"] = c[f"{k}_valid"] & ~cond
    c["best_r"] = jnp.where(cond, neg_inf, c["best_r"])
    c["sec_r"] = jnp.where(cond, neg_inf, c["sec_r"])
    c["aside"] = c["aside"] & ~cond
    c["probed_for"] = jnp.where(cond, -1, c["probed_for"])
    c["probe_done"] = c["probe_done"] & ~cond
    if "held_valid" in c:
        c["held_valid"] = c["held_valid"] & ~cond
        c["mon_active"] = c["mon_active"] & ~cond
        c["resets"] = c["resets"] + cond.astype(jnp.int32)
    return c


# ---------------------------------------------------------------------------
# CORAL step pieces (exact mirrors of repro.core.coral)
# ---------------------------------------------------------------------------


def _feasible(thr, tau, p, tau_target, p_budget):
    """Feasibility under the current constraints; mode is traced data
    (in throughput mode ``tau_target`` carries the +inf sentinel and is
    never consulted — matching CORAL._feasible)."""
    return jnp.where(thr, p <= p_budget, (tau >= tau_target) & (p <= p_budget))


def _reward(thr, tau, p, tau_target, p_budget):
    # the infeasibility predicate is spelled exactly as core.reward
    # spells it — (τ < target) | (p > budget) — rather than ~_feasible.
    # For real samples the two are identical; for the NaN missing-sample
    # sentinel (fault episodes' non-hardened ablation) they differ, and
    # the scalar reward() semantics are the executable spec: a NaN
    # sample is neither prohibited nor a gain — its reward is NaN.
    infeas = jnp.where(thr, p > p_budget, (tau < tau_target) | (p > p_budget))
    penalty = -(p / jnp.maximum(tau, 1e-9))
    gain = jnp.where(thr, tau, tau / jnp.maximum(p, 1e-9))
    return jnp.where(infeas, penalty, gain), infeas


def _result(c: Dict, thr, tau_target, p_budget):
    """CORAL.result(): best feasible epoch observation (dual: by τ/p,
    throughput: by τ), falling back to the epoch best-by-reward."""
    taus, powers = c["hist_sm"][:, -4], c["hist_sm"][:, -3]
    rows = jnp.arange(taus.shape[0], dtype=jnp.int32)
    valid = (rows >= c["epoch_start"]) & (rows < c["n_obs"])
    feas = valid & _feasible(thr, taus, powers, tau_target, p_budget)
    val = jnp.where(thr, taus, taus / jnp.maximum(powers, 1e-9))
    any_feas = feas.any()
    pick = jnp.argmax(jnp.where(feas, val, -jnp.inf))
    idx = jnp.where(
        any_feas, c["hist_sm"][pick, -1].astype(jnp.int32), c["best_idx"]
    )
    tau = jnp.where(any_feas, taus[pick], c["best_tau"])
    p = jnp.where(any_feas, powers[pick], c["best_p"])
    return idx, tau, p, any_feas | c["best_valid"]


def _propose(spec: EngineSpec, k: Dict, c: Dict, thr, tau_target, p_budget):
    """CORAL.propose(): returns (row index, probe-bookkeeping updates)."""
    w = spec.window
    epoch_n = c["n_obs"] - c["epoch_start"]

    # ---- Step 2: windowed correlations (same jitted math as scalar) ---
    if spec.fleet:
        # fleet hot path: the window's three dCor sums are maintained
        # incrementally (dcov.dcor_state_push), so the correlations fall
        # out of (C, C)-sized contractions — no (W, W, C) rebuild here
        n_valid = jnp.minimum(epoch_n, w)
        corr = dcor_state_corr(
            {nm: c[f"dc_{nm}"] for nm in ("win", "dist", "rows", "cross")},
            jnp.maximum(n_valid, 1),
            spec.d,
        )
        uniform = n_valid < 3
        alpha = jnp.where(uniform, 1.0, corr[:, 0])
        beta = jnp.where(uniform, 1.0, corr[:, 1])
        return _propose_tail(spec, k, c, thr, tau_target, p_budget, alpha, beta)
    lo = jnp.maximum(c["epoch_start"], c["n_obs"] - w)
    if spec.halflife is not None:
        horizon = jnp.float32(3.0 * spec.halflife)
        t_win = jax.lax.dynamic_slice(
            c["hist_sm"], (lo, jnp.int32(spec.d + 2)), (w, 1)
        )[:, 0]
        in_win = jnp.arange(w, dtype=jnp.int32) < (c["n_obs"] - lo)
        fresh = (c["clock"].astype(jnp.float32) - t_win) <= horizon
        lo = c["n_obs"] - (in_win & fresh).sum()
    win = jax.lax.dynamic_slice(
        c["hist_sm"], (lo, jnp.int32(0)), (w, spec.d + 2)
    )
    n_valid = c["n_obs"] - lo
    corr = dcor_all_cols(win, jnp.maximum(n_valid, 1), spec.d)
    uniform = n_valid < 3
    alpha = jnp.where(uniform, 1.0, corr[:, 0])
    beta = jnp.where(uniform, 1.0, corr[:, 1])
    return _propose_tail(spec, k, c, thr, tau_target, p_budget, alpha, beta)


def _propose_tail(
    spec: EngineSpec, k: Dict, c: Dict, thr, tau_target, p_budget, alpha, beta
):
    """Steps 3+ of CORAL.propose — everything downstream of the window
    correlations, shared by the full-recompute and incremental paths."""
    epoch_n = c["n_obs"] - c["epoch_start"]

    # ---- power-probe policy (CORAL.propose, budget_aware default) -----
    probe_thr = (
        jnp.isfinite(p_budget)
        & (c["best_idx"] != c["probed_for"])
        & (c["best_p"] > p_budget)
    )
    budget_aware = (
        (c["best_idx"] != c["probed_for"])
        & (c["best_tau"] > tau_target)
        & (c["best_p"] > p_budget)
    )
    oneshot = (
        ~c["probe_done"]
        & (c["best_p"] > jnp.float32(spec.p_min))
        & (c["best_tau"] > tau_target)
    )
    probe_dual = jnp.where(jnp.isfinite(p_budget), budget_aware, oneshot)
    probe = jnp.where(thr, probe_thr, probe_dual)

    # ---- Step 3: Alg. 2 via the shared float32 step -------------------
    eff_target = jnp.where(
        thr & (c["last_p"] > p_budget), jnp.float32(-jnp.inf), tau_target
    )
    down = (c["last_tau"] > eff_target) & (c["last_p"] >= jnp.float32(spec.p_min))
    levels = search.alg2_levels(
        jnp,
        k["grid32"][c["best_idx"]],
        k["grid32"][c["sec_idx"]],
        jnp.maximum(alpha, beta),
        k["notches"],
        k["ladders"],
        k["n_levels"],
        c["aside"],
        down,
        probe,
        c["best_tau"],
        c["best_p"],
        eff_target,
        jnp.float32(spec.p_min),
        k["cores_mask"],
        k["conc_mask"],
    )
    cand2 = (levels * k["strides"]).sum().astype(jnp.int32)

    # ---- iteration-0 / iteration-1 branches ---------------------------
    cand1_thr = jnp.where(
        c["last_valid"] & (c["last_p"] > p_budget), k["min_idx"], k["max_idx"]
    )
    cand1_dual = jnp.where(
        c["last_valid"] & (c["last_tau"] < tau_target),
        k["max_idx"],
        k["min_idx"],
    )
    cand1 = jnp.where(thr, cand1_thr, cand1_dual)
    searching = (epoch_n >= 2) & c["sec_valid"]
    cand = jnp.where(
        epoch_n == 0, k["mid_idx"], jnp.where(searching, cand2, cand1)
    )

    # ---- canonical prohibited/visited escape --------------------------
    seen = c["seen_tag"] >= c["epoch_id"]
    key = jnp.where(seen, _INT_MAX, k["key_tab"][k["sid"], cand])
    cand = jnp.where(seen[cand], jnp.argmin(key).astype(jnp.int32), cand)

    fired = searching & probe
    probe_updates = {
        "probe_done": c["probe_done"] | fired,
        "probed_for": jnp.where(fired, c["best_idx"], c["probed_for"]),
    }
    return cand, probe_updates


def _observe(k: Dict, c: Dict, cand, tau, p, thr, tau_target, p_budget, taken):
    """CORAL.observe() gated by ``taken`` (same statement order as the
    scalar method — ``aside`` reads the *old* best before the anchors
    shift). (N,)- and history-sized state only sees O(1) scatters."""
    c = dict(c)
    r, infeas = _reward(thr, tau, p, tau_target, p_budget)
    # one scatter covers both Alg. 1's prohibit (pin at INT_MAX forever)
    # and the per-epoch revisit tag (raise to the current epoch_id)
    tag = c["seen_tag"][cand]
    c["seen_tag"] = c["seen_tag"].at[cand].set(
        jnp.where(
            infeas & taken,
            jnp.int32(_INT_MAX),
            jnp.where(taken, jnp.maximum(tag, c["epoch_id"]), tag),
        )
    )
    c["aside"] = jnp.where(
        taken, c["best_valid"] & (r <= c["best_r"]), c["aside"]
    )
    improves = taken & (~c["best_valid"] | (r > c["best_r"]))
    to_second = taken & ~improves & (~c["sec_valid"] | (r > c["sec_r"]))
    old_best = (c["best_idx"], c["best_tau"], c["best_p"], c["best_r"])
    obs = (cand, tau, p, r)
    for name, bval, oval in zip(
        ("sec_idx", "sec_tau", "sec_p", "sec_r"), old_best, obs
    ):
        c[name] = jnp.where(improves, bval, jnp.where(to_second, oval, c[name]))
    c["sec_valid"] = jnp.where(
        improves, c["best_valid"], c["sec_valid"] | to_second
    )
    for name, oval in zip(("best_idx", "best_tau", "best_p", "best_r"), obs):
        c[name] = jnp.where(improves, oval, c[name])
    c["best_valid"] = c["best_valid"] | taken
    for name, oval in zip(("last_idx", "last_tau", "last_p"), obs):
        c[name] = jnp.where(taken, oval, c[name])
    c["last_valid"] = c["last_valid"] | taken
    n = c["n_obs"]
    obs_row = jnp.concatenate(
        [
            k["grid32"][cand],
            jnp.stack(
                [
                    tau,
                    p,
                    c["clock"].astype(jnp.float32),
                    cand.astype(jnp.float32),
                ]
            ),
        ]
    )
    c["hist_sm"] = c["hist_sm"].at[n].set(
        jnp.where(taken, obs_row, c["hist_sm"][n])
    )
    c["n_obs"] = n + taken.astype(jnp.int32)
    return c


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------


def _static_step(spec: EngineSpec, k: Dict, ep: Dict, tables: Dict):
    """run_coral's loop body: propose → measure → observe. Measuring is
    a point gather into the episode's (T, N) table slot — the tables
    stay unbatched and are addressed by ``table_id``."""
    thr, tau_target, p_budget = ep["throughput"], ep["tau_target"], ep["p_budget"]
    tid = ep["table_id"]
    always = jnp.bool_(True)

    def step(c, t):
        cand, probe_updates = _propose(spec, k, c, thr, tau_target, p_budget)
        c = {**c, **probe_updates}
        tau, p = tables["tau"][tid, t, cand], tables["p"][tid, t, cand]
        c = _observe(k, c, cand, tau, p, thr, tau_target, p_budget, always)
        c["clock"] = c["clock"] + 1
        return c, cand

    return step


def _fleet_step(spec: EngineSpec, k: Dict, ep: Dict, tables: Dict):
    """Fleet twin of ``_static_step`` with the factored measurement
    model: the per-twin (N,) float32 landscape row (deduped by
    ``table_id``) times the episode's own (T, 2) noise stream — the
    outer product a dense (T, N) table would materialize, evaluated as
    two scalars inside the scan. After each observation the incremental
    dCor accumulators absorb the new window row in O(W·C²)."""
    thr, tau_target, p_budget = ep["throughput"], ep["tau_target"], ep["p_budget"]
    tid = ep["table_id"]
    always = jnp.bool_(True)
    w = spec.window

    def step(c, t):
        cand, probe_updates = _propose(spec, k, c, thr, tau_target, p_budget)
        c = {**c, **probe_updates}
        z = ep["noise"][t]
        tau = jnp.maximum(tables["tau"][tid, cand] * (1.0 + z[0]), 1e-9)
        p = jnp.maximum(tables["p"][tid, cand] * (1.0 + z[1]), 1e-9)
        n0 = c["n_obs"]
        c = _observe(k, c, cand, tau, p, thr, tau_target, p_budget, always)
        row = jnp.concatenate([k["grid32"][cand], jnp.stack([tau, p])])
        st = {nm: c[f"dc_{nm}"] for nm in ("win", "dist", "rows", "cross")}
        st = dcor_state_push(st, row, n0 % w, jnp.minimum(n0, w))
        for nm, v in st.items():
            c[f"dc_{nm}"] = v
        c["clock"] = c["clock"] + 1
        return c, cand

    return step


def _fault_step(spec: EngineSpec, k: Dict, ep: Dict, tables: Dict):
    """run_fault_regime's loop body: watchdog-guarded next_config →
    faulty actuation → measure the config actually in force → hardened
    ingest gate → observe. ``ep["hardened"]`` is traced data, so the
    hardened run and its non-hardened ablation share one compiled
    program; the fault realization itself lives in the measurement
    tables (spikes/NaN baked in) and the per-interval ``stick``/``reset``
    actuation streams."""
    thr, tau_target, p_budget = ep["throughput"], ep["tau_target"], ep["p_budget"]
    tid = ep["table_id"]
    hardened = ep["hardened"]
    retry_budget = jnp.where(hardened, jnp.int32(spec.act_retries), jnp.int32(0))
    w = spec.window

    def step(c, t):
        # ---- next_config: watchdog guard over the normal proposal -----
        cand, probe_updates = _propose(spec, k, c, thr, tau_target, p_budget)
        guard = hardened & (c["dark"] >= jnp.int32(spec.watchdog))
        feas_best = c["best_valid"] & _feasible(
            thr, c["best_tau"], c["best_p"], tau_target, p_budget
        )
        safe = jnp.where(feas_best, c["best_idx"], k["min_idx"])
        cmd = jnp.where(guard, safe, cand)
        c = dict(c)
        # probe bookkeeping belongs to the taken propose branch only
        # (the scalar watchdog path returns before propose runs)
        c["probe_done"] = jnp.where(
            guard, c["probe_done"], probe_updates["probe_done"]
        )
        c["probed_for"] = jnp.where(
            guard, c["probed_for"], probe_updates["probed_for"]
        )

        # ---- actuation: silently-sticking knobs + firmware resets -----
        ok = ep["stick"][t] <= retry_budget
        applied = jnp.where(ok, cmd, c["applied_idx"])
        applied = jnp.where(ep["reset"][t], k["max_idx"], applied)
        c["applied_idx"] = applied

        # ---- measure the config actually in force ---------------------
        tau, p = tables["tau"][tid, t, applied], tables["p"][tid, t, applied]
        # hardened attributes via readback; the ablation trusts the
        # command — exactly the misattribution the fault cells score
        attr = jnp.where(hardened, applied, cmd)

        # ---- hardened ingest gate (CORAL._robust_reject's math) -------
        lo = jnp.maximum(c["epoch_start"], c["n_obs"] - w)
        win = jax.lax.dynamic_slice(
            c["hist_sm"], (lo, jnp.int32(0)), (w, spec.d + 2)
        )
        n_valid = c["n_obs"] - lo
        missing = ~(jnp.isfinite(tau) & jnp.isfinite(p))
        outlier = faults.mad_reject_trace(
            win[:, spec.d],
            win[:, spec.d + 1],
            n_valid,
            tau,
            p,
            jnp.float32(spec.gate_g),
            jnp.float32(spec.gate_eps),
            jnp.int32(spec.min_accept),
        )
        taken = jnp.where(hardened, ~(missing | outlier), jnp.bool_(True))
        c = _observe(k, c, attr, tau, p, thr, tau_target, p_budget, taken)
        c["dark"] = jnp.where(hardened & ~taken, c["dark"] + 1, jnp.int32(0))
        c["clock"] = c["clock"] + 1
        return c, (cmd, applied, taken, guard)

    return step


def _monitor_update(spec: EngineSpec, c: Dict, tau, p, gate):
    """DriftMonitor.update gated by ``gate``: running-mean calibration,
    then two-sided CUSUMs on the fractional (τ, p) residuals."""
    c = dict(c)
    calibrating = c["mon_calib"] < spec.calibration
    upd = gate & calibrating
    n = c["mon_calib"].astype(jnp.float32)
    c["mon_ref_tau"] = jnp.where(
        upd, (c["mon_ref_tau"] * n + tau) / (n + 1), c["mon_ref_tau"]
    )
    c["mon_ref_p"] = jnp.where(
        upd, (c["mon_ref_p"] * n + p) / (n + 1), c["mon_ref_p"]
    )
    c["mon_calib"] = c["mon_calib"] + upd.astype(jnp.int32)
    kk = jnp.float32(spec.k_sigma)
    armed = gate & ~calibrating
    z_tau = (tau / c["mon_ref_tau"] - 1.0) / c["mon_sigma"]
    z_p = (p / c["mon_ref_p"] - 1.0) / c["mon_sigma"]
    for name, z in (("tau", z_tau), ("p", z_p)):
        pos = jnp.maximum(0.0, c[f"mon_pos_{name}"] + z - kk)
        neg = jnp.maximum(0.0, c[f"mon_neg_{name}"] - z - kk)
        c[f"mon_pos_{name}"] = jnp.where(armed, pos, c[f"mon_pos_{name}"])
        c[f"mon_neg_{name}"] = jnp.where(armed, neg, c[f"mon_neg_{name}"])
    h = jnp.float32(spec.h_sigma)
    tripped = (
        (c["mon_pos_tau"] > h)
        | (c["mon_neg_tau"] > h)
        | (c["mon_pos_p"] > h)
        | (c["mon_neg_p"] > h)
    )
    return c, armed & tripped


def _drift_step(spec: EngineSpec, k: Dict, ep: Dict, tables: Dict):
    """run_drift_regime's loop body: commanded budget → next_config →
    measure → record, with bounded re-exploration on CUSUM triggers.
    ``ep["adaptive"]`` is traced data: the static ablation (monitor off,
    budget commands ignored) shares the compiled program."""
    thr, tau_target = ep["throughput"], ep["tau_target"]
    adaptive = ep["adaptive"]
    tid = ep["table_id"]

    def step(c, t):
        budget_t = ep["budgets"][t]
        clock0 = c["clock"]

        # ---- commanded budget change (CORAL.set_p_budget) + retry -----
        # Both pre-measure resets are mutually exclusive (a budget
        # trigger flips the loop back into exploration, which disarms
        # the retry check), so one gated re_explore serves both.
        changed = adaptive & (budget_t != c["p_budget"])
        exploring0 = (c["n_obs"] - c["epoch_start"]) < spec.explore_budget
        draw = jnp.where(c["mon_active"], c["mon_ref_p"], c["held_p"])
        trigger_b = changed & ~exploring0 & c["held_valid"] & (draw > budget_t)
        c = dict(c)
        c["p_budget"] = jnp.where(adaptive, budget_t, c["p_budget"])
        p_budget = c["p_budget"]

        # infeasible-hold retry: an epoch that ends without a pick
        # feasible under the *current* constraints spends another
        # (bounded) exploration epoch instead of monitoring it
        r_idx, r_tau, r_p, r_valid = _result(c, thr, tau_target, p_budget)
        h_tau = jnp.where(r_valid, r_tau, c["last_tau"])
        h_p = jnp.where(r_valid, r_p, c["last_p"])
        h_exists = r_valid | c["last_valid"]
        infeasible = ~h_exists | ~_feasible(thr, h_tau, h_p, tau_target, p_budget)
        retry = (
            adaptive
            & ~trigger_b
            & ~exploring0
            & ~c["held_valid"]
            & infeasible
            & (c["retries"] < spec.max_retries)
        )
        c = _re_explore(c, trigger_b | retry)
        c["retries"] = jnp.where(
            trigger_b, 0, c["retries"] + retry.astype(jnp.int32)
        )
        exploring = (c["n_obs"] - c["epoch_start"]) < spec.explore_budget

        cand_explore, probe_updates = _propose(
            spec, k, c, thr, tau_target, p_budget
        )

        # hold_config: first non-exploring interval resolves the held
        # config (epoch best feasible, else last) and arms the monitor.
        # The retry path above flipped ``exploring`` back on, so the
        # stale pre-reset result can never arm a hold.
        h_idx = jnp.where(r_valid, r_idx, c["last_idx"])
        arm = ~exploring & ~c["held_valid"]
        c["held_idx"] = jnp.where(arm, h_idx, c["held_idx"])
        c["held_tau"] = jnp.where(arm, h_tau, c["held_tau"])
        c["held_p"] = jnp.where(arm, h_p, c["held_p"])
        c["held_valid"] = c["held_valid"] | arm
        arm_mon = arm & adaptive
        c["mon_ref_tau"] = jnp.where(
            arm_mon, jnp.maximum(h_tau, 1e-9), c["mon_ref_tau"]
        )
        c["mon_ref_p"] = jnp.where(arm_mon, jnp.maximum(h_p, 1e-9), c["mon_ref_p"])
        c["mon_calib"] = jnp.where(arm_mon, 1, c["mon_calib"])
        for nm in ("pos_tau", "neg_tau", "pos_p", "neg_p"):
            c[f"mon_{nm}"] = jnp.where(arm_mon, 0.0, c[f"mon_{nm}"])
        c["mon_active"] = c["mon_active"] | arm_mon

        # probe bookkeeping belongs to the *taken* propose branch only
        c["probe_done"] = jnp.where(
            exploring, probe_updates["probe_done"], c["probe_done"]
        )
        c["probed_for"] = jnp.where(
            exploring, probe_updates["probed_for"], c["probed_for"]
        )
        cand = jnp.where(exploring, cand_explore, c["held_idx"])

        # ---- measure --------------------------------------------------
        tau, p = tables["tau"][tid, t, cand], tables["p"][tid, t, cand]

        # ---- record (CORAL.record) ------------------------------------
        # calm hold: the monitor consumes the re-measurement
        hold = ~exploring
        c, tripped = _monitor_update(spec, c, tau, p, hold & c["mon_active"])
        trig = hold & c["mon_active"] & tripped
        c = _re_explore(c, trig)
        c["retries"] = jnp.where(trig, 0, c["retries"])
        # a trigger seeds the fresh epoch with the held config's just-
        # taken measurement only if it is infeasible; both the seed and
        # the exploration observation stamp the interval's clock
        seed_obs = trig & ~_feasible(thr, tau, p, tau_target, p_budget)
        c = _observe(
            k, c, cand, tau, p, thr, tau_target, p_budget, exploring | seed_obs
        )
        c["clock"] = clock0 + 1
        return c, (cand, exploring)

    return step


# ---------------------------------------------------------------------------
# Compiled batch runners — one jit per EngineSpec, vmapped over episodes.
# ---------------------------------------------------------------------------

_FINAL_KEYS = ("n_obs", "epoch_start", "best_idx", "best_valid")


def _compiled_runner(spec: EngineSpec):
    """jit(vmap(scan)) runner for ``spec``, checkify-wrapped when the
    REPRO_CHECKIFY=1 sanitizer lane is on. The flag is part of the cache
    key (not read inside the cached build) so flipping it mid-process
    can never serve a stale program."""
    return _compiled_runner_impl(spec, sanitize.checkify_enabled())


@functools.lru_cache(maxsize=None)
def _compiled_runner_impl(spec: EngineSpec, checkified: bool):
    """jit(vmap(scan)) for one episode structure. Episode data — the
    measurement tables, targets, mode/variant flags — ride the batch
    axis; the padded space constants stay device-resident across calls
    and are selected per episode by ``space_id``."""

    def run(batch, tables, consts):
        def one_episode(ep):
            sid = ep["space_id"]
            # per-episode materialized views are (N, ·)-sized; the
            # (S, N, N) escape table is row-gathered per step instead
            k = {
                name: consts[name][sid]
                for name in consts
                if name != "key_tab"
            }
            k["key_tab"] = consts["key_tab"]
            k["sid"] = sid
            pad = k["pad_mask"]
            if spec.fleet:
                # firmware-locked DVFS rows are born prohibited, exactly
                # like grid padding; the iteration-0/1 presets can be
                # warm-start overrides (a neighbor's observed extremes)
                pad = pad | ep["banned"]
                k["min_idx"] = ep["min_idx"]
                k["max_idx"] = ep["max_idx"]
            c = _init_carry(spec, ep, pad)
            ts = jnp.arange(spec.iters, dtype=jnp.int32)
            # unroll=2 halves the while-loop's per-iteration fixed cost;
            # beyond that, program size outweighs the gain on CPU
            if spec.drift:
                step = _drift_step(spec, k, ep, tables)
                final, (idxs, exploring) = jax.lax.scan(step, c, ts, unroll=2)
                out = {
                    "idx": idxs,
                    "exploring": exploring,
                    "resets": final["resets"],
                }
            elif spec.fault:
                step = _fault_step(spec, k, ep, tables)
                final, (cmds, applieds, takens, guards) = jax.lax.scan(
                    step, c, ts, unroll=2
                )
                out = {
                    "idx": cmds,
                    "applied": applieds,
                    "taken": takens,
                    "guard": guards,
                }
            elif spec.fleet:
                step = _fleet_step(spec, k, ep, tables)
                final, idxs = jax.lax.scan(step, c, ts, unroll=2)
                start = jnp.maximum(final["n_obs"] - spec.window, 0)
                # dtype-slimmed fleet outputs: int16 row traces (grids
                # are < 32k rows), bool prohibited masks, and only the
                # last-W window rows (the warm-start seed for a next
                # wave) instead of the whole history block
                out = {
                    "idx": idxs.astype(jnp.int16),
                    "prohibited": final["seen_tag"] == jnp.int32(_INT_MAX),
                    "window": jax.lax.dynamic_slice(
                        final["hist_sm"],
                        (start, jnp.int32(0)),
                        (spec.window, spec.d + 4),
                    ),
                }
                for nm in (
                    "best_tau",
                    "best_p",
                    "best_r",
                    "sec_idx",
                    "sec_tau",
                    "sec_p",
                    "sec_r",
                    "sec_valid",
                    "last_idx",
                    "last_tau",
                    "last_p",
                    "last_valid",
                ):
                    out[nm] = final[nm]
                out.update({name: final[name] for name in _FINAL_KEYS})
                return out
            else:
                step = _static_step(spec, k, ep, tables)
                final, idxs = jax.lax.scan(step, c, ts, unroll=2)
                out = {"idx": idxs}
            out.update({name: final[name] for name in _FINAL_KEYS})
            out["hist_idx"] = (
                final["hist_sm"][: spec.iters, -1].astype(jnp.int32)
            )
            out["hist_t"] = (
                final["hist_sm"][: spec.iters, -2].astype(jnp.int32)
            )
            return out

        return jax.vmap(one_episode)(batch)

    # Donating the per-call operands (the measurement tables dominate)
    # lets XLA reuse or at least immediately release their buffers —
    # at fleet scale that is the difference between O(B·(N+T)) and
    # 2× that in transient peak memory. The space constants (argument 2)
    # are cached across calls and must NOT be donated.
    if checkified:
        # checkify preserves argument positions (it returns (err, out)),
        # so the same donate_argnums apply to the wrapped function
        jitted = jax.jit(sanitize.wrap_checkify(run), donate_argnums=(0, 1))

        def _checked(ep_batch, meas_tables):
            err, out = jitted(ep_batch, meas_tables, _device_consts(spec))
            err.throw()  # raises JaxRuntimeError on NaN/OOB/div poison
            return out

        return _checked
    jitted = jax.jit(run, donate_argnums=(0, 1))
    return lambda batch, tables: jitted(batch, tables, _device_consts(spec))


def measurement_noise(seed: int, sigma: float, steps: int) -> np.ndarray:
    """(T, 2) noise block from the device RNG stream — bitwise the same
    draws as T sequential scalar ``measure`` calls (τ draw, then p)."""
    if sigma == 0.0:
        return np.zeros((steps, 2))
    return np.random.default_rng(seed).normal(0.0, sigma, size=(steps, 2))


def _fill_tables(
    meas_tau: np.ndarray,  # (B, T, N) float32 batch slot to fill at row b
    meas_p: np.ndarray,
    b: int,
    land_tau: np.ndarray,  # (T, N0) or (N0,) float64 landscape
    land_p: np.ndarray,
    z: np.ndarray,  # (T, 2) float64 noise
) -> None:
    """Write episode b's float32 measurement tables: the float64
    landscape × noise product, rounded once on assignment — the same
    float64 values the scalar ``measure`` produces, cast to the scan's
    working precision."""
    t = z.shape[0]
    if land_tau.ndim == 1:
        land_tau = np.broadcast_to(land_tau, (t, land_tau.shape[0]))
        land_p = np.broadcast_to(land_p, (t, land_p.shape[0]))
    lt, lp = land_tau, land_p
    n0 = lt.shape[1]
    meas_tau[b, :, :n0] = np.maximum(lt * (1.0 + z[:, :1]), 1e-9)
    meas_p[b, :, :n0] = np.maximum(lp * (1.0 + z[:, 1:]), 1e-9)


def _fill_all(meas_tau, meas_p, reqs, steps) -> List[np.ndarray]:
    """Noise draws + table fills for every request; the per-episode
    float64 landscape×noise products run on a small thread pool (numpy
    releases the GIL for the array work). Returns the noise blocks in
    request order."""
    noises = [
        measurement_noise(r["seed"], r["noise"], steps) for r in reqs
    ]
    workers = min(len(reqs), os.cpu_count() or 1)
    if workers > 1:
        with concurrent.futures.ThreadPoolExecutor(workers) as pool:
            list(
                pool.map(
                    lambda ib: _fill_tables(
                        meas_tau,
                        meas_p,
                        ib,
                        reqs[ib]["land_tau"],
                        reqs[ib]["land_p"],
                        noises[ib],
                    ),
                    range(len(reqs)),
                )
            )
    else:
        for i, r in enumerate(reqs):
            _fill_tables(meas_tau, meas_p, i, r["land_tau"], r["land_p"], noises[i])
    return noises


def _trace_f64(
    land_tau: np.ndarray, land_p: np.ndarray, z: np.ndarray, idxs: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """Float64 measurement trace at the chosen configs — bitwise equal
    to the scalar loop's ``measure`` returns (same product, same
    clamp)."""
    steps = np.arange(idxs.shape[0])
    lt = land_tau[steps, idxs] if land_tau.ndim == 2 else land_tau[idxs]
    lp = land_p[steps, idxs] if land_p.ndim == 2 else land_p[idxs]
    taus = np.maximum(lt * (1.0 + z[:, 0]), 1e-9)
    powers = np.maximum(lp * (1.0 + z[:, 1]), 1e-9)
    return taus, powers


def _engine_tau_target(mode: str, targets) -> np.float32:
    """Throughput mode has no τ target: CORAL.__init__ replaces it with
    the +inf sentinel so Alg. 2 stays in its climb direction — the
    engine mirrors that here (the reward/feasibility paths are
    mode-aware and never read it in throughput mode)."""
    if mode == "throughput":
        return np.float32(np.inf)
    return np.float32(targets.tau_target)


@dataclasses.dataclass
class EpisodeResult:
    """One episode's outcome + per-step float64 trace, reconstructed
    host-side so τ/p match the scalar loop's measurements bitwise."""

    configs: List[tuple]
    taus: List[float]
    powers: List[float]
    rewards: List[float]
    outcome: Outcome
    exploring: Optional[List[bool]] = None
    budgets: Optional[List[float]] = None
    resets: int = 0
    result_config: Optional[tuple] = None

    def trace(self):
        """The episode as an ``evaluate.Trace`` (scalar-loop shape)."""
        from repro.core.evaluate import Trace

        return Trace(
            list(self.configs), list(self.taus), list(self.powers),
            list(self.rewards),
        )


def _f64_reward(mode, tau, p, tau_target, p_budget):
    infeas = (
        (p > p_budget)
        if mode == "throughput"
        else (tau < tau_target) | (p > p_budget)
    )
    gain = tau if mode == "throughput" else tau / np.maximum(p, 1e-9)
    return np.where(infeas, -(p / np.maximum(tau, 1e-9)), gain)


def _f64_result(
    mode,
    idxs: np.ndarray,
    taus: np.ndarray,
    powers: np.ndarray,
    rewards: np.ndarray,
    tau_target: float,
    p_budget: float,
) -> Optional[int]:
    """CORAL.result() over a float64 (sub-)history: position of the best
    feasible observation, else best by reward; None for empty input."""
    if idxs.size == 0:
        return None
    if mode == "throughput":
        feas = powers <= p_budget
        val = taus
    else:
        feas = (taus >= tau_target) & (powers <= p_budget)
        val = taus / np.maximum(powers, 1e-9)
    if feas.any():
        return int(np.argmax(np.where(feas, val, -np.inf)))
    return int(np.argmax(rewards))


def _batch_spaces(reqs: List[dict]) -> Tuple[ConfigSpace, ...]:
    """Ordered distinct spaces across a request batch (the EngineSpec
    key). Mixed dimensionalities cannot share one program."""
    spaces: List[ConfigSpace] = []
    for r in reqs:
        if r["space"] not in spaces:
            spaces.append(r["space"])
    d = len(spaces[0].dims)
    for s in spaces:
        if len(s.dims) != d:
            raise ValueError("episode batch mixes grid dimensionalities")
    return tuple(spaces)


def run_coral_batch(
    space: ConfigSpace,
    land_tau: np.ndarray,  # (N,) float64 noise-free τ landscape
    land_p: np.ndarray,  # (N,) float64 noise-free p landscape
    targets,  # RegimeTargets (mode, tau_target, p_budget)
    seeds: Sequence[int],
    iters: int = 10,
    window: int = 10,
    noise: float = 0.02,
) -> List[EpisodeResult]:
    """Compiled twin of N× ``run_coral``: one vmapped scan over seeds."""
    reqs = [
        {
            "space": space,
            "land_tau": land_tau,
            "land_p": land_p,
            "targets": targets,
            "seed": s,
            "noise": noise,
        }
        for s in seeds
    ]
    return run_static_requests(reqs, iters=iters, window=window)


def run_static_requests(
    reqs: List[dict], iters: int = 10, window: int = 10
) -> List[EpisodeResult]:
    """Run a batch of static CORAL episodes through the compiled engine.

    Each request: {space, land_tau, land_p, targets, seed, noise}. The
    whole batch — every (cell × seed), any mix of spaces and modes —
    is ONE compiled vmapped call; results return in input order.
    """
    if not reqs:
        return []
    spaces = _batch_spaces(reqs)
    spec = EngineSpec(spaces=spaces, iters=iters, window=window)
    b, n = len(reqs), spec.n
    meas_tau = np.zeros((b, iters, n), np.float32)
    meas_p = np.zeros((b, iters, n), np.float32)
    noises = _fill_all(meas_tau, meas_p, reqs, iters)
    ep = {
        "space_id": np.empty(b, np.int32),
        "table_id": np.arange(b, dtype=np.int32),
        "tau_target": np.empty(b, np.float32),
        "p_budget": np.empty(b, np.float32),
        "throughput": np.empty(b, bool),
    }
    for i, r in enumerate(reqs):
        ep["space_id"][i] = spaces.index(r["space"])
        ep["tau_target"][i] = _engine_tau_target(r["targets"].mode, r["targets"])
        ep["p_budget"][i] = np.float32(r["targets"].p_budget)
        ep["throughput"][i] = r["targets"].mode == "throughput"
    batch = {name: jnp.asarray(v) for name, v in ep.items()}
    tables = {"tau": jnp.asarray(meas_tau), "p": jnp.asarray(meas_p)}
    res = jax.device_get(_compiled_runner(spec)(batch, tables))
    out: List[EpisodeResult] = []
    for i, r in enumerate(reqs):
        idxs = res["idx"][i]
        taus, powers = _trace_f64(r["land_tau"], r["land_p"], noises[i], idxs)
        mode = r["targets"].mode
        rewards = _f64_reward(
            mode, taus, powers, r["targets"].tau_target, r["targets"].p_budget
        )
        rows = space_rows(r["space"])
        configs = [rows[int(j)] for j in idxs]
        pick = _f64_result(
            mode, idxs, taus, powers, rewards,
            r["targets"].tau_target, r["targets"].p_budget,
        )
        outcome = Outcome(
            configs[pick], float(taus[pick]), float(powers[pick]), iters
        )
        out.append(
            EpisodeResult(
                configs=configs,
                taus=[float(v) for v in taus],
                powers=[float(v) for v in powers],
                rewards=[float(v) for v in rewards],
                outcome=outcome,
                result_config=configs[pick],
            )
        )
    return out


def run_drift_requests(
    reqs: List[dict],
    intervals: int,
    explore_budget: int = 10,
    window: int = 10,
) -> List[EpisodeResult]:
    """Run a batch of drift episodes through the compiled engine.

    Each request: {space, land_tau (T, N), land_p (T, N), budget_scale
    (T,), targets, seed, noise, adaptive}. The drift variant (adaptive
    vs static ablation) is traced data, so the whole batch is ONE
    compiled vmapped call.
    """
    if not reqs:
        return []
    spaces = _batch_spaces(reqs)
    spec = EngineSpec(
        spaces=spaces,
        iters=intervals,
        window=window,
        drift=True,
        explore_budget=explore_budget,
        halflife=float(window),
    )
    b, n = len(reqs), spec.n
    # the adaptive and static variants of a (cell, seed) read the same
    # landscape × noise tables — fill and ship each unique table once,
    # and let episodes address theirs by ``table_id``
    def table_key(r):
        return (id(r["land_tau"]), id(r["land_p"]), r["seed"], r["noise"])

    uniq: Dict[tuple, int] = {}
    table_ids = np.empty(b, np.int32)
    uniq_reqs = []
    for i, r in enumerate(reqs):
        key = table_key(r)
        if key not in uniq:
            uniq[key] = len(uniq_reqs)
            uniq_reqs.append(r)
        table_ids[i] = uniq[key]
    meas_tau = np.zeros((len(uniq_reqs), intervals, n), np.float32)
    meas_p = np.zeros((len(uniq_reqs), intervals, n), np.float32)
    uniq_noises = _fill_all(meas_tau, meas_p, uniq_reqs, intervals)
    noises = [uniq_noises[table_ids[i]] for i in range(b)]
    budgets64 = []
    ep = {
        "space_id": np.empty(b, np.int32),
        "table_id": table_ids,
        "tau_target": np.empty(b, np.float32),
        "p_budget0": np.empty(b, np.float32),
        "sigma": np.empty(b, np.float32),
        "throughput": np.empty(b, bool),
        "adaptive": np.empty(b, bool),
        "budgets": np.empty((b, intervals), np.float32),
    }
    for i, r in enumerate(reqs):
        # repro-lint: disable=RL04 — host f64 mirrors the oracle budget trace
        b64 = r["targets"].p_budget * np.asarray(r["budget_scale"], np.float64)
        budgets64.append(b64)
        ep["space_id"][i] = spaces.index(r["space"])
        ep["tau_target"][i] = _engine_tau_target(r["targets"].mode, r["targets"])
        ep["p_budget0"][i] = np.float32(r["targets"].p_budget)
        ep["sigma"][i] = np.float32(r.get("sigma", r["noise"]))
        ep["throughput"][i] = r["targets"].mode == "throughput"
        ep["adaptive"][i] = bool(r["adaptive"])
        ep["budgets"][i] = b64
    batch = {name: jnp.asarray(v) for name, v in ep.items()}
    tables = {"tau": jnp.asarray(meas_tau), "p": jnp.asarray(meas_p)}
    res = jax.device_get(_compiled_runner(spec)(batch, tables))
    out: List[EpisodeResult] = []
    for i, r in enumerate(reqs):
        idxs = res["idx"][i]
        taus, powers = _trace_f64(r["land_tau"], r["land_p"], noises[i], idxs)
        mode = r["targets"].mode
        rows = space_rows(r["space"])
        configs = [rows[int(j)] for j in idxs]
        # final result: epoch history rows re-read in float64. The
        # history rows are (interval, config) pairs — an epoch row's
        # measurement equals the trace value at its interval.
        n_obs = int(res["n_obs"][i])
        e0 = int(res["epoch_start"][i])
        h_t = res["hist_t"][i][e0:n_obs]
        h_idx = res["hist_idx"][i][e0:n_obs]
        final_budget = (
            float(budgets64[i][-1])
            if r["adaptive"]
            else float(r["targets"].p_budget)
        )
        ep_taus = taus[h_t]
        ep_powers = powers[h_t]
        ep_budgets = (
            budgets64[i][h_t]
            if r["adaptive"]
            else np.full(h_t.shape, r["targets"].p_budget)
        )
        ep_rewards = _f64_reward(
            mode, ep_taus, ep_powers, r["targets"].tau_target, ep_budgets
        )
        pick = _f64_result(
            mode, h_idx, ep_taus, ep_powers, ep_rewards,
            r["targets"].tau_target, final_budget,
        )
        if pick is not None:
            result_config = rows[int(h_idx[pick])]
            outcome = Outcome(
                result_config,
                float(ep_taus[pick]),
                float(ep_powers[pick]),
                intervals,
            )
        elif bool(res["best_valid"][i]):
            result_config = rows[int(res["best_idx"][i])]
            outcome = Outcome(result_config, 0.0, 0.0, intervals)
        else:
            result_config, outcome = None, Outcome(None, 0.0, 0.0, intervals)
        out.append(
            EpisodeResult(
                configs=configs,
                taus=[float(v) for v in taus],
                powers=[float(v) for v in powers],
                rewards=[],
                outcome=outcome,
                exploring=[bool(v) for v in res["exploring"][i]],
                budgets=[float(v) for v in budgets64[i]],
                resets=int(res["resets"][i]),
                result_config=result_config,
            )
        )
    return out


def _fill_fault_tables(
    meas_tau: np.ndarray,  # (U, T, N) float32 batch slot to fill at row u
    meas_p: np.ndarray,
    u: int,
    land_tau: np.ndarray,  # (N0,) float64 stationary landscape
    land_p: np.ndarray,
    z: np.ndarray,  # (T, 2) float64 noise
    ftab,  # realized core.faults.FaultTables
) -> None:
    """Write one fault episode's float32 measurement tables: the clean
    float64 landscape × noise product (same clamp as ``_fill_tables``),
    then the telemetry-spike factors in float64 — matching
    ``FaultySimulator.measure``'s op order exactly — then NaN on dropped
    intervals, cast to float32 once on assignment."""
    t = z.shape[0]
    n0 = land_tau.shape[0]
    lt = np.broadcast_to(land_tau, (t, n0))
    lp = np.broadcast_to(land_p, (t, n0))
    tau64 = np.maximum(lt * (1.0 + z[:, :1]), 1e-9) * ftab.spike[:, 0:1]
    p64 = np.maximum(lp * (1.0 + z[:, 1:]), 1e-9) * ftab.spike[:, 1:2]
    tau64 = np.where(ftab.drop[:, None], np.nan, tau64)
    p64 = np.where(ftab.drop[:, None], np.nan, p64)
    meas_tau[u, :, :n0] = tau64
    meas_p[u, :, :n0] = p64


def fault_trace_f64(
    land_tau: np.ndarray,
    land_p: np.ndarray,
    z: np.ndarray,
    idxs: np.ndarray,  # (T,) applied grid rows
    ftab,
) -> Tuple[np.ndarray, np.ndarray]:
    """Float64 telemetry trace at the *applied* configs with the fault
    realization folded in — bitwise what ``FaultySimulator.measure``
    returned each interval (NaN on dropped samples)."""
    taus, powers = _trace_f64(land_tau, land_p, z, idxs)
    taus = taus * ftab.spike[:, 0]
    powers = powers * ftab.spike[:, 1]
    taus = np.where(ftab.drop, np.nan, taus)
    powers = np.where(ftab.drop, np.nan, powers)
    return taus, powers


def fault_pick(mode, h_idx, taus, powers, tau_target, p_budget) -> Optional[int]:
    """CORAL.result() over a fault episode's *recorded* history rows.

    NaN rewards (missing samples the ablation swallowed raw) rank below
    everything in the best-by-reward fallback — deterministic for both
    engines, since the matrix computes scalar and compiled results
    through this one helper."""
    rewards = _f64_reward(mode, taus, powers, tau_target, p_budget)
    rewards = np.where(np.isnan(rewards), -np.inf, rewards)
    return _f64_result(mode, h_idx, taus, powers, rewards, tau_target, p_budget)


def run_fault_requests(
    reqs: List[dict],
    iters: int = 40,
    window: int = 10,
    robust=None,
) -> List[dict]:
    """Run a batch of fault episodes through the compiled engine.

    Each request: {space, land_tau (N,), land_p (N,), targets, seed,
    noise, hardened, and either ``tables`` (a realized
    ``core.faults.FaultTables``) or ``schedule`` (a ``FaultSchedule``
    realized here at (iters, seed))}. A hardened run and its ablation
    that share the same ``tables`` *object* also share one shipped
    measurement table (``table_id`` dedup). The whole batch is ONE
    compiled vmapped call; ``robust`` (a ``RobustConfig``) sets the
    compile-time hardening constants.

    Returns per-request dicts: the commanded/applied row traces, the
    float64 telemetry trace (NaN on drops), per-interval accepted /
    fallback flags, the recorded-history rows, and the final pick
    (``fault_pick`` over the recorded history — the same helper the
    scalar cell runner uses).
    """
    if not reqs:
        return []
    rb = robust if robust is not None else faults.RobustConfig()
    spaces = _batch_spaces(reqs)
    spec = EngineSpec(
        spaces=spaces,
        iters=iters,
        window=window,
        fault=True,
        gate_g=rb.gate_g,
        gate_eps=rb.gate_eps,
        min_accept=rb.min_accept,
        watchdog=rb.watchdog,
        act_retries=rb.act_retries,
    )
    b, n = len(reqs), spec.n
    ftabs = [
        r["tables"] if "tables" in r else r["schedule"].realize(iters, r["seed"])
        for r in reqs
    ]

    uniq: Dict[tuple, int] = {}
    table_ids = np.empty(b, np.int32)
    uniq_rows: List[int] = []
    for i, r in enumerate(reqs):
        key = (id(r["land_tau"]), id(r["land_p"]), r["seed"], r["noise"],
               id(ftabs[i]))
        if key not in uniq:
            uniq[key] = len(uniq_rows)
            uniq_rows.append(i)
        table_ids[i] = uniq[key]
    meas_tau = np.full((len(uniq_rows), iters, n), 0.0, np.float32)
    meas_p = np.full((len(uniq_rows), iters, n), 0.0, np.float32)
    noises = [measurement_noise(r["seed"], r["noise"], iters) for r in reqs]
    for u, i in enumerate(uniq_rows):
        r = reqs[i]
        _fill_fault_tables(
            meas_tau, meas_p, u, r["land_tau"], r["land_p"], noises[i],
            ftabs[i],
        )

    ep = {
        "space_id": np.empty(b, np.int32),
        "table_id": table_ids,
        "tau_target": np.empty(b, np.float32),
        "p_budget": np.empty(b, np.float32),
        "throughput": np.empty(b, bool),
        "hardened": np.empty(b, bool),
        "boot_idx": np.empty(b, np.int32),
        "stick": np.empty((b, iters), np.int32),
        "reset": np.empty((b, iters), bool),
    }
    # hardened constraint back-off: the optimizer chases the
    # margin-shrunk budget (scoring upstream always uses the full one) —
    # the same f64 multiply evaluate.run_fault_regime hands its CORAL
    eff_budget = [
        r["targets"].p_budget * (1.0 - rb.p_margin)
        if r["hardened"]
        else r["targets"].p_budget
        for r in reqs
    ]
    for i, r in enumerate(reqs):
        sp = r["space"]
        ep["space_id"][i] = spaces.index(sp)
        ep["tau_target"][i] = _engine_tau_target(r["targets"].mode, r["targets"])
        ep["p_budget"][i] = np.float32(eff_budget[i])
        ep["throughput"][i] = r["targets"].mode == "throughput"
        ep["hardened"][i] = bool(r["hardened"])
        ep["boot_idx"][i] = _space_consts(sp)["max_idx"]
        ep["stick"][i] = ftabs[i].stick[:iters]
        ep["reset"][i] = ftabs[i].reset[:iters]
    batch = {name: jnp.asarray(v) for name, v in ep.items()}
    tables = {"tau": jnp.asarray(meas_tau), "p": jnp.asarray(meas_p)}
    res = jax.device_get(_compiled_runner(spec)(batch, tables))

    out: List[dict] = []
    for i, r in enumerate(reqs):
        mode = r["targets"].mode
        rows = space_rows(r["space"])
        applieds = res["applied"][i]
        taus, powers = fault_trace_f64(
            r["land_tau"], r["land_p"], noises[i], applieds, ftabs[i]
        )
        n_obs = int(res["n_obs"][i])
        h_t = res["hist_t"][i][:n_obs]
        h_idx = res["hist_idx"][i][:n_obs]
        rec_taus, rec_powers = taus[h_t], powers[h_t]
        pick = fault_pick(
            mode, h_idx, rec_taus, rec_powers,
            r["targets"].tau_target, eff_budget[i],
        )
        if pick is not None:
            result_config = rows[int(h_idx[pick])]
            outcome = Outcome(
                result_config,
                float(rec_taus[pick]),
                float(rec_powers[pick]),
                iters,
            )
        else:
            result_config, outcome = None, Outcome(None, 0.0, 0.0, iters)
        out.append(
            {
                "commanded": [rows[int(j)] for j in res["idx"][i]],
                "applied": [rows[int(j)] for j in applieds],
                "taus": [float(v) for v in taus],
                "powers": [float(v) for v in powers],
                "accepted": [bool(v) for v in res["taken"][i]],
                "fallback": [bool(v) for v in res["guard"][i]],
                "rec_idx": h_idx.astype(np.int64),
                "rec_t": h_t.astype(np.int64),
                "n_obs": n_obs,
                "result_config": result_config,
                "outcome": outcome,
            }
        )
    return out


def run_fleet_requests(
    reqs: List[dict],
    iters: int = 30,
    window: int = 12,
    stats: Optional[dict] = None,
) -> List[dict]:
    """Run a fleet of static CORAL episodes — one compiled vmapped scan
    over heterogeneous device twins, with the factored measurement model
    (``EngineSpec.fleet``).

    Each request::

        {space, land_tau (N0,) float64, land_p, targets, seed, noise,
         banned:  optional (N0,) bool — firmware-locked grid rows,
         warm:    optional dict — converged-neighbor context:
                  {hist (w, D+4) float32 window rows (w <= window),
                   prohibit (N0,) bool,
                   best/sec/last anchor scalars (+ *_valid flags),
                   min_idx, max_idx}}

    Landscape tables are deduped by array identity (``table_id``) — a
    warm re-run of a twin ships its (N,) float32 landscapes once. The
    per-request result dict carries the chosen-row trace, the final
    prohibited mask and window rows (the warm-start seed for a next
    wave), and the anchor scalars. When ``stats`` is a dict it receives
    the shipped-bytes accounting (tables / batch / device constants).
    """
    if not reqs:
        return []
    spaces = _batch_spaces(reqs)
    spec = EngineSpec(spaces=spaces, iters=iters, window=window, fleet=True)
    b, n, d, w = len(reqs), spec.n, spec.d, spec.window
    if w > iters:
        raise ValueError("fleet window must not exceed iters")

    uniq: Dict[tuple, int] = {}
    table_ids = np.empty(b, np.int32)
    uniq_reqs: List[dict] = []
    for i, r in enumerate(reqs):
        key = (id(r["land_tau"]), id(r["land_p"]))
        if key not in uniq:
            uniq[key] = len(uniq_reqs)
            uniq_reqs.append(r)
        table_ids[i] = uniq[key]
    land_tau32 = np.zeros((len(uniq_reqs), n), np.float32)
    land_p32 = np.zeros((len(uniq_reqs), n), np.float32)
    for u, r in enumerate(uniq_reqs):
        n0 = r["land_tau"].shape[0]
        land_tau32[u, :n0] = r["land_tau"]
        land_p32[u, :n0] = r["land_p"]

    noises = np.zeros((b, iters, 2), np.float32)
    f32, i32 = np.float32, np.int32
    ep: Dict[str, np.ndarray] = {
        "space_id": np.empty(b, i32),
        "table_id": table_ids,
        "tau_target": np.empty(b, f32),
        "p_budget": np.empty(b, f32),
        "throughput": np.empty(b, bool),
        "banned": np.zeros((b, n), bool),
        "min_idx": np.empty(b, i32),
        "max_idx": np.empty(b, i32),
        "warm": np.zeros(b, bool),
        "warm_n": np.zeros(b, i32),
        "warm_hist": np.zeros((b, w, d + 4), f32),
        "warm_prohibit": np.zeros((b, n), bool),
        "warm_last_valid": np.zeros(b, bool),
    }
    for nm in ("best", "sec"):
        ep[f"warm_{nm}_idx"] = np.full(b, -1, i32)
        ep[f"warm_{nm}_tau"] = np.zeros(b, f32)
        ep[f"warm_{nm}_p"] = np.zeros(b, f32)
        ep[f"warm_{nm}_r"] = np.full(b, -np.inf, f32)
        ep[f"warm_{nm}_valid"] = np.zeros(b, bool)
    ep["warm_last_idx"] = np.full(b, -1, i32)
    ep["warm_last_tau"] = np.zeros(b, f32)
    ep["warm_last_p"] = np.zeros(b, f32)

    for i, r in enumerate(reqs):
        sp = r["space"]
        n0 = sp.size()
        consts = _space_consts(sp)
        ep["space_id"][i] = spaces.index(sp)
        ep["tau_target"][i] = _engine_tau_target(r["targets"].mode, r["targets"])
        ep["p_budget"][i] = np.float32(r["targets"].p_budget)
        ep["throughput"][i] = r["targets"].mode == "throughput"
        noises[i] = measurement_noise(r["seed"], r["noise"], iters)
        banned = r.get("banned")
        if banned is not None:
            ep["banned"][i, :n0] = banned
        ep["min_idx"][i] = consts["min_idx"]
        ep["max_idx"][i] = consts["max_idx"]
        warm = r.get("warm")
        if warm is not None:
            rows = np.asarray(warm["hist"], f32)[-w:]
            ep["warm"][i] = True
            ep["warm_n"][i] = rows.shape[0]
            ep["warm_hist"][i, : rows.shape[0]] = rows
            ep["warm_prohibit"][i, :n0] = warm["prohibit"]
            for nm in ("best", "sec", "last"):
                for fld in ("idx", "tau", "p", "r", "valid"):
                    key = f"{nm}_{fld}"
                    if key in warm:
                        ep[f"warm_{key}"][i] = warm[key]
            ep["min_idx"][i] = warm.get("min_idx", consts["min_idx"])
            ep["max_idx"][i] = warm.get("max_idx", consts["max_idx"])

    ep["noise"] = noises
    if contracts.contracts_enabled():
        contracts.check_fleet_batch(ep, b=b, n=n, w=w, d=d, t=iters)
    batch = {name: jnp.asarray(v) for name, v in ep.items()}
    tables = {"tau": jnp.asarray(land_tau32), "p": jnp.asarray(land_p32)}
    if stats is not None:
        stats["table_bytes"] = int(land_tau32.nbytes + land_p32.nbytes)
        stats["batch_bytes"] = int(sum(v.nbytes for v in ep.values()))
        stats["consts_bytes"] = int(
            sum(int(v.nbytes) for v in _device_consts(spec).values())
        )
        stats["episodes"] = b
    res = jax.device_get(_compiled_runner(spec)(batch, tables))
    out: List[dict] = []
    for i, r in enumerate(reqs):
        n0 = r["space"].size()
        one = {
            "idx": res["idx"][i].astype(np.int64),
            "prohibited": res["prohibited"][i][:n0].copy(),
            "window": res["window"][i],
            "n_obs": int(res["n_obs"][i]),
        }
        for nm in (
            "best_idx",
            "best_tau",
            "best_p",
            "best_r",
            "best_valid",
            "sec_idx",
            "sec_tau",
            "sec_p",
            "sec_r",
            "sec_valid",
            "last_idx",
            "last_tau",
            "last_p",
            "last_valid",
        ):
            one[nm] = res[nm][i].item()
        out.append(one)
    return out


# ---------------------------------------------------------------------------
# Open-loop baselines in the same harness.
#
# ALERT-Online and the presets have NO sequential dependence — the next
# measurement never depends on the previous one — so their "scan step"
# degenerates to a gather against the same measurement tables the CORAL
# scan uses. Running them through lax.scan would add dispatch for zero
# fusion benefit; they are deliberately evaluated as one table lookup
# (EXPERIMENTS.md §Episode engine documents the boundary).
# ---------------------------------------------------------------------------


def preset_outcome(
    space: ConfigSpace,
    land_tau: np.ndarray,
    land_p: np.ndarray,
    kind: str,
    noise: float,
    seed: int,
) -> Outcome:
    """Bitwise twin of ``baselines.preset`` against a landscape table."""
    idx = row_index(space, space.preset(kind))
    z = measurement_noise(seed, noise, 1)
    tau = max(float(land_tau[idx]) * (1.0 + z[0, 0]), 1e-9)
    p = max(float(land_p[idx]) * (1.0 + z[0, 1]), 1e-9)
    return Outcome(space.preset(kind), tau, p, 1)


def alert_online_outcome(
    space: ConfigSpace,
    land_tau: np.ndarray,
    land_p: np.ndarray,
    targets,
    noise: float,
    seed: int,
    iters: int = 10,
) -> Outcome:
    """Bitwise twin of ``baselines.alert_online``: the trial configs come
    from the same config-RNG stream, the measurements from the same
    device-noise stream, and the best-feasible-by-efficiency selection
    runs in float64 — identical Outcome, no scan required."""
    cfg_rng = np.random.default_rng(seed)
    cfgs = [space.random(cfg_rng) for _ in range(iters)]
    idxs = np.asarray([row_index(space, c) for c in cfgs])
    z = measurement_noise(seed, noise, iters)
    taus = np.maximum(land_tau[idxs] * (1.0 + z[:, 0]), 1e-9)
    powers = np.maximum(land_p[idxs] * (1.0 + z[:, 1]), 1e-9)
    feas = (taus >= targets.tau_target) & (powers <= targets.p_budget)
    if feas.any():
        eff = taus / np.maximum(powers, 1e-9)
        best = int(np.argmax(np.where(feas, eff, -np.inf)))
    elif targets.tau_target <= 0:
        best = int(np.argmax(taus))
    else:
        return Outcome(None, 0.0, 0.0, iters)
    return Outcome(cfgs[best], float(taus[best]), float(powers[best]), iters)