"""Baselines from the paper's §IV-A.

  ORACLE        — exhaustive profiling of the full space (noise-free); the
                  per-scenario upper bound.
  ALERT         — offline profiling + Kalman-filtered online selection.
                  Faithful to the paper's adaptation: ALERT *prioritizes
                  throughput* (it was designed for latency/energy, not a
                  hard power cap), which is why it exceeds power budgets in
                  dual-constraint scenarios.
  ALERT-Online  — ALERT with offline profiling replaced by 10 random
                  online trials (same iteration budget as CORAL).
  max-power / default — manufacturer-preset analogues.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.kalman import ScalarKalman
from repro.core.space import Config, ConfigSpace


@dataclasses.dataclass
class Outcome:
    config: Optional[Config]
    tau: float
    power: float
    measurements: int  # how many device evaluations were spent

    @property
    def efficiency(self) -> float:
        return self.tau / max(self.power, 1e-9)

    def feasible(self, tau_target: float, p_budget: float) -> bool:
        return (
            self.config is not None
            and self.tau >= tau_target
            and self.power <= p_budget
        )


def _measure_all(space: ConfigSpace, device, exact: bool) -> Dict[Config, Tuple[float, float]]:
    out = {}
    for cfg in space.all_configs():
        tau, p = (device.exact(cfg) if exact else device.measure(cfg))
        out[cfg] = (tau, p)
    return out


def oracle(
    space: ConfigSpace, device, tau_target: float, p_budget: float = float("inf")
) -> Outcome:
    """Exhaustive search; best feasible config by efficiency (single-target:
    pass p_budget=inf and tau_target=0 → max throughput)."""
    table = _measure_all(space, device, exact=True)
    feas = {
        c: tp
        for c, tp in table.items()
        if tp[0] >= tau_target and tp[1] <= p_budget
    }
    n = len(table)
    if not feas:
        return Outcome(None, 0.0, 0.0, n)
    if tau_target <= 0:  # single-target: maximize throughput
        best = max(feas, key=lambda c: feas[c][0])
    else:
        best = max(feas, key=lambda c: feas[c][0] / max(feas[c][1], 1e-9))
    return Outcome(best, *feas[best], n)


def oracle_max_throughput(space: ConfigSpace, device) -> Outcome:
    return oracle(space, device, tau_target=0.0)


def alert(
    space: ConfigSpace,
    device,
    tau_target: float,
    p_budget: float = float("inf"),
    online_iters: int = 10,
) -> Outcome:
    """Offline-profiled ALERT with Kalman-filtered online re-selection.

    Selection rule (throughput-prioritized, per the paper's description):
    among configs predicted to meet the throughput target, pick max
    predicted throughput; else pick global max predicted throughput. The
    power budget is a soft preference only — reproducing the paper's
    observation that ALERT exceeds strict power caps.
    """
    profile = _measure_all(space, device, exact=False)  # offline, noisy
    kf = ScalarKalman()
    chosen = None
    tau = p = 0.0
    n = len(profile)
    for _ in range(online_iters):
        xi = kf.x

        def pred_tau(c):
            return profile[c][0] * xi

        meets = [c for c in profile if pred_tau(c) >= tau_target]
        pool = meets or list(profile)
        # throughput first; power only as a tie-breaking preference
        chosen = max(pool, key=lambda c: (pred_tau(c), -profile[c][1]))
        tau, p = device.measure(chosen)
        n += 1
        kf.update(tau / max(profile[chosen][0], 1e-9))
    return Outcome(chosen, tau, p, n)


def alert_online(
    space: ConfigSpace,
    device,
    tau_target: float,
    p_budget: float = float("inf"),
    iters: int = 10,
    seed: int = 0,
) -> Outcome:
    """ALERT-Online: 10 random trials + Kalman smoothing, no offline data."""
    rng = np.random.default_rng(seed)
    kf = ScalarKalman()
    trials: List[Tuple[Config, float, float]] = []
    first_tau = None
    for _ in range(iters):
        cfg = space.random(rng)
        tau, p = device.measure(cfg)
        if first_tau is None:
            first_tau = max(tau, 1e-9)
        kf.update(tau / first_tau)
        trials.append((cfg, tau, p))
    feas = [t for t in trials if t[1] >= tau_target and t[2] <= p_budget]
    if feas:
        best = max(feas, key=lambda t: t[1] / max(t[2], 1e-9))
        return Outcome(best[0], best[1], best[2], iters)
    if tau_target <= 0:
        best = max(trials, key=lambda t: t[1])
        return Outcome(best[0], best[1], best[2], iters)
    return Outcome(None, 0.0, 0.0, iters)  # failed to find a valid config


def preset(space: ConfigSpace, device, kind: str) -> Outcome:
    cfg = space.preset(kind)
    tau, p = device.measure(cfg)
    return Outcome(cfg, tau, p, 1)
