"""Baselines from the paper's §IV-A.

  ORACLE        — exhaustive profiling of the full space (noise-free); the
                  per-scenario upper bound.
  ALERT         — offline profiling + Kalman-filtered online selection.
                  Faithful to the paper's adaptation: ALERT *prioritizes
                  throughput* (it was designed for latency/energy, not a
                  hard power cap), which is why it exceeds power budgets in
                  dual-constraint scenarios.
  ALERT-Online  — ALERT with offline profiling replaced by 10 random
                  online trials (same iteration budget as CORAL).
  max-power / default — manufacturer-preset analogues.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core.space import Config, ConfigSpace


@dataclasses.dataclass
class ScalarKalman:
    """Scalar Kalman filter for ALERT's global slowdown factor ξ
    (Wan et al., ATC'20): observed = ξ · profiled + noise. Lives here —
    inlined from the former core/kalman.py — because ``alert()`` is its
    only consumer (``alert_online`` replaces it with direct trials)."""

    x: float = 1.0  # state estimate (slowdown factor)
    p: float = 1.0  # estimate covariance
    q: float = 1e-3  # process noise
    r: float = 1e-2  # measurement noise

    def update(self, measured_ratio: float) -> float:
        # predict
        self.p += self.q
        # update
        k = self.p / (self.p + self.r)
        self.x += k * (measured_ratio - self.x)
        self.p *= 1.0 - k
        return self.x


@dataclasses.dataclass
class Outcome:
    config: Optional[Config]
    tau: float
    power: float
    measurements: int  # how many device evaluations were spent

    @property
    def efficiency(self) -> float:
        return self.tau / max(self.power, 1e-9)

    def feasible(self, tau_target: float, p_budget: float) -> bool:
        return (
            self.config is not None
            and self.tau >= tau_target
            and self.power <= p_budget
        )


def _sweep(
    space: ConfigSpace, device, exact: bool
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(grid (N,D), tau (N,), p (N,)) for the full space — one vectorized
    evaluation when the device supports batched sweeps, else a Python loop
    (any object with only scalar ``exact``/``measure``)."""
    grid = space.grid()
    if exact and hasattr(device, "exact_all"):
        tau, p = device.exact_all(grid)
    elif not exact and hasattr(device, "measure_all"):
        tau, p = device.measure_all(grid)
    else:
        pairs = [
            (device.exact(tuple(row)) if exact else device.measure(tuple(row)))
            for row in grid
        ]
        tau = np.array([t for t, _ in pairs])
        p = np.array([q for _, q in pairs])
    return grid, np.asarray(tau, np.float64), np.asarray(p, np.float64)


def oracle(
    space: ConfigSpace, device, tau_target: float, p_budget: float = float("inf")
) -> Outcome:
    """Exhaustive search; best feasible config by efficiency (single-target:
    pass p_budget=inf and tau_target=0 → max throughput). Runs as one
    array-based sweep over ``space.grid()``."""
    grid, tau, p = _sweep(space, device, exact=True)
    n = grid.shape[0]
    feas = (tau >= tau_target) & (p <= p_budget)
    if not feas.any():
        return Outcome(None, 0.0, 0.0, n)
    score = tau if tau_target <= 0 else tau / np.maximum(p, 1e-9)
    best = int(np.argmax(np.where(feas, score, -np.inf)))
    return Outcome(
        tuple(float(v) for v in grid[best]), float(tau[best]), float(p[best]), n
    )


def oracle_scalar(
    space: ConfigSpace, device, tau_target: float, p_budget: float = float("inf")
) -> Outcome:
    """The original one-config-at-a-time sweep. Kept as the equivalence
    oracle for the vectorized ``oracle`` (and its benchmark baseline)."""
    table = {}
    for cfg in space.all_configs():
        table[cfg] = device.exact(cfg)
    feas = {
        c: tp
        for c, tp in table.items()
        if tp[0] >= tau_target and tp[1] <= p_budget
    }
    n = len(table)
    if not feas:
        return Outcome(None, 0.0, 0.0, n)
    if tau_target <= 0:  # single-target: maximize throughput
        best = max(feas, key=lambda c: feas[c][0])
    else:
        best = max(feas, key=lambda c: feas[c][0] / max(feas[c][1], 1e-9))
    return Outcome(best, *feas[best], n)


def oracle_max_throughput(space: ConfigSpace, device) -> Outcome:
    """Exhaustive-search oracle for the single-target regime: the
    highest-τ config under the device's power budget (``oracle`` with
    the τ target disabled)."""
    return oracle(space, device, tau_target=0.0)


def alert(
    space: ConfigSpace,
    device,
    tau_target: float,
    p_budget: float = float("inf"),
    online_iters: int = 10,
) -> Outcome:
    """Offline-profiled ALERT with Kalman-filtered online re-selection.

    Selection rule (throughput-prioritized, per the paper's description):
    among configs predicted to meet the throughput target, pick max
    predicted throughput; else pick global max predicted throughput. The
    power budget is a soft preference only — reproducing the paper's
    observation that ALERT exceeds strict power caps.
    """
    grid, tau_prof, p_prof = _sweep(space, device, exact=False)  # offline, noisy
    kf = ScalarKalman()
    chosen = None
    tau = p = 0.0
    n = grid.shape[0]
    for _ in range(online_iters):
        pred = tau_prof * kf.x
        meets = pred >= tau_target
        pool = meets if meets.any() else np.ones_like(meets)
        # throughput first; power only as a tie-breaking preference:
        # lexsort's primary key is pred descending, secondary power
        # ascending, stable — the first row is the scalar max()'s pick.
        idx = int(np.lexsort((p_prof, -np.where(pool, pred, -np.inf)))[0])
        chosen = tuple(float(v) for v in grid[idx])
        tau, p = device.measure(chosen)
        n += 1
        kf.update(tau / max(tau_prof[idx], 1e-9))
    return Outcome(chosen, tau, p, n)


def alert_online(
    space: ConfigSpace,
    device,
    tau_target: float,
    p_budget: float = float("inf"),
    iters: int = 10,
    seed: int = 0,
) -> Outcome:
    """ALERT-Online: 10 random trials, best feasible by efficiency.

    ALERT's Kalman filter tracks the global slowdown factor ξ between
    *offline-profiled* and observed performance (observed = ξ·profiled).
    With profiling replaced by one noisy online measurement per random
    config there is no profiled baseline for ξ to correct: the only
    available ratio, τ_i/τ_0, conflates config-to-config throughput
    differences with runtime drift, so smoothing it cannot improve the
    ranking. The filter is therefore deliberately absent here — selection
    is exactly the best measured feasible trial (see
    tests/test_serving_fixes.py for the regression).
    """
    rng = np.random.default_rng(seed)
    trials: List[Tuple[Config, float, float]] = []
    for _ in range(iters):
        cfg = space.random(rng)
        tau, p = device.measure(cfg)
        trials.append((cfg, tau, p))
    feas = [t for t in trials if t[1] >= tau_target and t[2] <= p_budget]
    if feas:
        best = max(feas, key=lambda t: t[1] / max(t[2], 1e-9))
        return Outcome(best[0], best[1], best[2], iters)
    if tau_target <= 0:
        best = max(trials, key=lambda t: t[1])
        return Outcome(best[0], best[1], best[2], iters)
    return Outcome(None, 0.0, 0.0, iters)  # failed to find a valid config


def preset(space: ConfigSpace, device, kind: str) -> Outcome:
    """One-measurement static baseline: apply the named preset
    (``max_power`` / ``default`` / ``min_power`` — see
    ``ConfigSpace.preset``) and record what the device does there."""
    cfg = space.preset(kind)
    tau, p = device.measure(cfg)
    return Outcome(cfg, tau, p, 1)
