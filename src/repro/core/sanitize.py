"""Runtime sanitizer lanes for the episode engine.

Two independent tools, both zero-cost when off:

- **checkify lane** (``REPRO_CHECKIFY=1``): wraps the episode scan in
  ``jax.experimental.checkify`` with NaN/div error checks (see
  :func:`checkify_errors` for why OOB index checks are excluded). The
  compiled program then carries error state through the scan and the
  host raises on the first poisoned value — turning a silent NaN in the
  reward or an out-of-bounds gather into a hard failure with a payload.
  ``core/episode.py::_compiled_runner`` keys its executable cache on
  the flag, so flipping it mid-process can never serve a stale program.

- **compile-count guard**: :func:`count_compiles` captures
  ``jax.log_compiles`` output and counts executable builds per jitted
  function name. One ``Compiling <name> with global shapes`` line is
  emitted per build — including persistent-compilation-cache hits
  (deserialization still lowers), and never on in-process jit cache
  reuse — so "the static matrix compiles exactly once per engine spec"
  is assertable in CI regardless of the cache state.
"""
from __future__ import annotations

import logging
import re
from contextlib import contextmanager
from typing import Iterator, List

import jax

from repro.envflags import env_flag


def checkify_enabled() -> bool:
    """The REPRO_CHECKIFY=1 lane (single parser: envflags)."""
    return env_flag("REPRO_CHECKIFY")


def checkify_errors():
    """NaN + div-by-zero (+ explicit checkify.check assertions).

    ``checkify.index_checks`` is deliberately absent: this jax release
    crashes inside its own scatter OOB rule (``checkify.py::scatter_oob``
    raises IndexError) on the engine's vmapped ``.at[slot].set`` ring
    updates. OOB gathers are instead covered by the engine's explicit
    sentinel clamps plus the contracts lane's shape checks."""
    from jax.experimental import checkify

    return checkify.float_checks | checkify.div_checks


def wrap_checkify(fn):
    """checkify ``fn`` and re-pack as same-signature callable that
    raises on the first poisoned value. Argument positions are
    preserved, so an enclosing ``jax.jit(..., donate_argnums=...)``
    keeps donating the same buffers."""
    from jax.experimental import checkify

    return checkify.checkify(fn, errors=checkify_errors())


_COMPILING = re.compile(r"^Compiling ([^\s]+) with global shapes")


class CompileLog:
    """Captured executable builds: ``total`` across all jitted names,
    ``count(name)`` per function name."""

    def __init__(self) -> None:
        self.names: List[str] = []

    @property
    def total(self) -> int:
        return len(self.names)

    def count(self, name: str) -> int:
        return sum(1 for n in self.names if n == name)


class _Capture(logging.Handler):
    def __init__(self, log: CompileLog) -> None:
        super().__init__(level=logging.DEBUG)
        self._log = log

    def emit(self, record: logging.LogRecord) -> None:
        m = _COMPILING.match(record.getMessage())
        if m:
            self._log.names.append(m.group(1))


@contextmanager
def count_compiles() -> Iterator[CompileLog]:
    """Count executable builds inside the block::

        with count_compiles() as cc:
            runner(batch, tables)
        assert cc.count("run") == 1   # one build of the episode scan
        with count_compiles() as cc2:
            runner(batch2, tables2)   # same spec, fresh data
        assert cc2.total == 0         # no recompilation

    Attaches one handler to the root ``jax`` logger (child records
    propagate there exactly once)."""
    log = CompileLog()
    handler = _Capture(log)
    jax_logger = logging.getLogger("jax")
    jax_logger.addHandler(handler)
    try:
        with jax.log_compiles(True):
            yield log
    finally:
        jax_logger.removeHandler(handler)
