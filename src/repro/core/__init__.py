# The paper's primary contribution: CORAL — covariance-guided online
# hardware configuration search with throughput-power co-optimization.
from repro.core.coral import CORAL, CoralState, Observation  # noqa: F401
from repro.core.dcov import dcor, dcor_all, dcov2  # noqa: F401
from repro.core.drift import CusumDetector, DriftConfig, DriftMonitor  # noqa: F401
from repro.core.episode import (  # noqa: F401
    EpisodeResult,
    run_coral_batch,
    run_drift_requests,
    run_static_requests,
)
from repro.core.coral import joint_headroom  # noqa: F401
from repro.core.evaluate import (  # noqa: F401
    CellRecord,
    CellSpec,
    DriftTrace,
    RegimeTargets,
    measurements_to_feasible,
    run_cell,
    run_coral,
    run_coral_scalar,
    run_drift_regime,
    run_regime,
)
from repro.core.reward import reward  # noqa: F401
from repro.core.search import next_config  # noqa: F401
from repro.core.space import (  # noqa: F401
    ConfigSpace,
    Dim,
    jetson_like_space,
    tpu_pod_space,
)
