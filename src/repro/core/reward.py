"""Reward calculation — paper Algorithm 1, verbatim.

Feasible  (τ ≥ τ_target and p ≤ p_budget):  r = τ/p      (efficiency, Eq. 7)
Infeasible:  appended to the prohibited set, r = -(p/τ)   (penalty,   Eq. 8)
"""
from __future__ import annotations

from typing import Set, Tuple

Config = Tuple[float, ...]


def reward(
    tau: float,
    p: float,
    x: Config,
    prohibited: Set[Config],
    tau_target: float,
    p_budget: float,
) -> float:
    if tau < tau_target or p > p_budget:  # Alg. 1 line 3
        prohibited.add(tuple(x))  # line 4
        return -(p / max(tau, 1e-9))  # line 5
    return tau / max(p, 1e-9)  # line 7
