"""Reward calculation — paper Algorithm 1, plus the single-target mode.

Dual-constraint (the paper's Alg. 1, verbatim):
  Feasible  (τ ≥ τ_target and p ≤ p_budget):  r = τ/p      (efficiency, Eq. 7)
  Infeasible:  appended to the prohibited set, r = -(p/τ)   (penalty,   Eq. 8)

Single-target throughput (§IV-B): the objective is max τ, optionally under
a power cap. Feasible → r = τ (not τ/p — the search must prefer the
fastest config, not the most efficient one); power violation → the same
prohibited + penalty path as Alg. 1. There is no τ_target in this mode,
so no observation is prohibited for being "too slow".
"""
from __future__ import annotations

from typing import Set, Tuple

Config = Tuple[float, ...]


def reward(
    tau: float,
    p: float,
    x: Config,
    prohibited: Set[Config],
    tau_target: float,
    p_budget: float,
    mode: str = "dual",
) -> float:
    """Paper Eq. 3 reward of one measured (τ, p) at config ``x``:
    -(p/τ) efficiency when feasible, constraint-violation penalties
    otherwise, -inf for prohibited configs. ``mode="throughput"``
    switches to the single-target reward (τ under the power cap)."""
    if mode == "throughput":  # single-target §IV-B: maximize τ under p cap
        if p > p_budget:
            prohibited.add(tuple(x))
            return -(p / max(tau, 1e-9))
        return tau
    if tau < tau_target or p > p_budget:  # Alg. 1 line 3
        prohibited.add(tuple(x))  # line 4
        return -(p / max(tau, 1e-9))  # line 5
    return tau / max(p, 1e-9)  # line 7
