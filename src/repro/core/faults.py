"""Declarative fault injection + hardened-ingest knobs (EXPERIMENTS.md
§Fault tolerance).

Real edge deployments are not the fault-free world the rest of the stack
assumes: telemetry samples go missing or come back as garbage, a DVFS /
concurrency knob silently sticks at its previous value, a firmware
watchdog resets the governor to its default row, and the edge→pod link
drops shipped requests. ``FaultSchedule`` composes those failure modes
as *data* — the same declarative-schedule shape ``DriftSchedule`` uses —
and ``realize`` turns a schedule into per-interval numpy fault tables
with a prefix-stable RNG stream, so the scalar reference loop
(``evaluate.run_fault_regime``) and the compiled episode engine
(``episode.run_fault_requests``) consume byte-identical realizations.

The hardened side lives here too: ``RobustConfig`` bundles the ingest
knobs (MAD outlier gate, missing-sample watchdog, actuation-retry
budget) that CORAL and the serving controller share, and ``mad_reject``
is the one gate implementation both engines call — the scalar path
through the jitted wrapper, the compiled fault step by tracing the same
function inline — so the accept/reject decision can never fork.

Fault semantics (mirrored exactly in ``device.FaultySimulator`` and
``episode._fault_step``):

- ``SensorDropout``    — the interval's (τ, p) sample is missing; the
  twin reports NaN for both channels (the noise stream still advances,
  so dropped intervals don't shift later draws).
- ``TelemetrySpike``   — heavy-tailed multiplicative outliers: the
  sample is scaled by ``exp(±u·ln(magnitude))`` with u ~ U[1, 2] — the
  unit-mismatch / counter-wrap class of glitch, orders of magnitude off.
- ``ActuationFailure`` — the knob silently sticks: the realization draws
  the number of *failed actuation attempts* for the interval; an
  attempt budget of R (hardened readback+retry) actuates iff the draw
  is ≤ R, a single blind write (the ablation) iff it is 0.
- ``FirmwareReset``    — the config snaps to the firmware default row
  (the ``max_power`` preset: performance-governor boot defaults are the
  dangerous, realistic kind) regardless of what was commanded.
- ``PodLinkOutage``    — the edge→pod offload path drops shipped
  requests during the window; consumed by the serving runtime
  (``ServingRuntime.set_pod_outage``), not the device twin.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

# realization stream tag — keeps fault draws disjoint from the twin's
# measurement-noise stream and the fleet's perturbation stream
_FAULT_STREAM = 777_013


@dataclasses.dataclass(frozen=True)
class SensorDropout:
    """Missing (τ, p) samples: each interval in [start, stop) is dropped
    with probability ``rate``."""

    start: int = 0
    stop: int = 1_000_000
    rate: float = 0.1


@dataclasses.dataclass(frozen=True)
class TelemetrySpike:
    """Heavy-tailed multiplicative outliers on the reported sample:
    intervals in [start, stop) spike with probability ``rate``; the
    factor is ``exp(s·u·ln(magnitude))`` with u ~ U[1, 2] and s = ±1
    (``direction``: "up" | "down" | "both"). ``axis`` selects the τ
    channel, the p channel, or a correlated glitch on both."""

    start: int = 0
    stop: int = 1_000_000
    rate: float = 0.1
    magnitude: float = 1000.0
    axis: str = "tau"  # tau | power | both
    direction: str = "both"  # up | down | both


@dataclasses.dataclass(frozen=True)
class ActuationFailure:
    """Silently-sticking knobs: intervals in [start, stop) fail with
    probability ``rate``; a firing interval draws the number of failed
    actuation attempts from Geometric(1/mean_tries) (support ≥ 1)."""

    start: int = 0
    stop: int = 1_000_000
    rate: float = 0.2
    mean_tries: float = 2.0


@dataclasses.dataclass(frozen=True)
class FirmwareReset:
    """The config snaps to the firmware default row (``max_power``
    preset) at exactly the listed intervals."""

    at: Tuple[int, ...] = ()


@dataclasses.dataclass(frozen=True)
class PodLinkOutage:
    """The edge→pod link is down for intervals in [start, stop): shipped
    requests error/time out and must be re-admitted locally."""

    start: int = 0
    stop: int = 0


FaultEvent = Union[
    SensorDropout, TelemetrySpike, ActuationFailure, FirmwareReset, PodLinkOutage
]


@dataclasses.dataclass(frozen=True)
class FaultTables:
    """One realized fault episode over T control intervals — plain numpy
    data shared verbatim by the scalar loop and the compiled engine:
    ``drop (T,) bool``, ``spike (T, 2) float64`` multiplicative factors
    (1.0 = clean), ``stick (T,) int32`` failed actuation attempts,
    ``reset (T,) bool``, ``pod_out (T,) bool``."""

    drop: np.ndarray
    spike: np.ndarray
    stick: np.ndarray
    reset: np.ndarray
    pod_out: np.ndarray

    @staticmethod
    def clean(intervals: int) -> "FaultTables":
        """The fault-free realization (every table inert)."""
        return FaultTables(
            drop=np.zeros(intervals, bool),
            spike=np.ones((intervals, 2), np.float64),
            stick=np.zeros(intervals, np.int32),
            reset=np.zeros(intervals, bool),
            pod_out=np.zeros(intervals, bool),
        )


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """A named, declarative composition of fault events — pure data, so
    fault regimes enumerate in the scenario matrix exactly like drift
    regimes do (``experiments.scenarios.FAULTS``)."""

    name: str
    events: Tuple[FaultEvent, ...] = ()

    def realize(self, intervals: int, seed: int) -> FaultTables:
        """Realize the schedule over ``intervals`` control intervals.

        Each event draws from its own prefix-stable stream
        ``default_rng([seed, _FAULT_STREAM, event_index])`` (the
        ``sample_perturbations`` pattern), so adding an event never
        shifts the realization of the others and the same (schedule,
        seed, T) always produces byte-identical tables.
        """
        t = np.arange(intervals)
        out = FaultTables.clean(intervals)
        drop, spike = out.drop, out.spike
        stick, reset, pod_out = out.stick, out.reset, out.pod_out
        for i, ev in enumerate(self.events):
            rng = np.random.default_rng([seed, _FAULT_STREAM, i])
            if isinstance(ev, SensorDropout):
                window = (t >= ev.start) & (t < ev.stop)
                drop |= window & (rng.random(intervals) < ev.rate)
            elif isinstance(ev, TelemetrySpike):
                window = (t >= ev.start) & (t < ev.stop)
                fire = window & (rng.random(intervals) < ev.rate)
                if ev.direction == "up":
                    sign = np.ones(intervals)
                elif ev.direction == "down":
                    sign = -np.ones(intervals)
                else:
                    sign = np.where(rng.random(intervals) < 0.5, 1.0, -1.0)
                u = 1.0 + rng.random(intervals)
                factor = np.exp(sign * u * np.log(ev.magnitude))
                factor = np.where(fire, factor, 1.0)
                if ev.axis in ("tau", "both"):
                    spike[:, 0] *= factor
                if ev.axis in ("power", "both"):
                    spike[:, 1] *= factor
            elif isinstance(ev, ActuationFailure):
                window = (t >= ev.start) & (t < ev.stop)
                fire = window & (rng.random(intervals) < ev.rate)
                tries = rng.geometric(1.0 / max(ev.mean_tries, 1.0), intervals)
                stick[:] = np.maximum(
                    stick, np.where(fire, tries, 0).astype(np.int32)
                )
            elif isinstance(ev, FirmwareReset):
                for at in ev.at:
                    if 0 <= at < intervals:
                        reset[at] = True
            elif isinstance(ev, PodLinkOutage):
                pod_out |= (t >= ev.start) & (t < ev.stop)
            else:
                raise TypeError(f"unknown fault event {ev!r}")
        return out


@dataclasses.dataclass(frozen=True)
class RobustConfig:
    """Hardened-ingest knobs shared by CORAL, the serving controller and
    the compiled fault engine (mirrored into ``EngineSpec``'s fault
    fields — the two must agree or scalar↔compiled parity breaks).

    ``gate_g``/``gate_eps`` — the MAD outlier gate: a sample is rejected
        when its log-deviation from the window's (lower-)median exceeds
        ``gate_g · (1.4826·MAD + gate_eps)``. The eps floor (in log
        units, ≈ a 2× multiplicative band at the default) keeps a
        near-degenerate window — e.g. a watchdog fallback re-measuring
        one config — from rejecting every legitimately different sample.
    ``min_accept`` — window fill level below which the outlier gate
        stays open (missing samples are still skipped).
    ``watchdog`` — consecutive rejected/missing samples before the
        controller degrades to the safe config (last known-feasible
        anchor, ultimately the min-power row).
    ``act_retries`` — actuation verification budget: readback + retry up
        to this many times before accepting that the knob is stuck (the
        residual is then attributed to the config actually in force).
    ``backoff_s`` — base of the exponential backoff between actuation
        retries in the live serving controller (the twin path retries
        within the interval and never sleeps).
    ``p_margin`` — constraint back-off (robust-MPC style): the hardened
        optimizer chases ``p_budget · (1 − p_margin)`` so that ordinary
        measurement noise near the budget boundary cannot flip a
        truly-over-budget config to feasible. Scoring always uses the
        full budget; the margin only shrinks what the optimizer targets.
        The default covers ≳2σ of the matrix workloads' sample noise.
    """

    gate_g: float = 2.5
    gate_eps: float = 0.7
    min_accept: int = 5
    watchdog: int = 3
    act_retries: int = 3
    backoff_s: float = 0.05
    p_margin: float = 0.05


def mad_reject_trace(win_tau, win_p, n_valid, tau, p, gate_g, gate_eps,
                     min_accept):
    """The MAD outlier gate on one (τ, p) sample, as traceable jnp ops.

    ``win_tau``/``win_p`` are the current dCor window's float32 τ/p
    columns (length W, rows ≥ ``n_valid`` ignored), exactly as the
    compiled carry stores them; the scalar path passes the same values
    through the jitted ``mad_reject`` wrapper so both engines run the
    identical float32 op sequence. Deviations are measured in log space
    (spikes are multiplicative) from the lower median, against a scale
    of ``1.4826·MAD + gate_eps``. Below ``min_accept`` accepted samples
    the gate stays open. NaN samples fall through (all comparisons
    false) — missing-sample handling is the caller's separate check.
    """

    def deviates(vals, x):
        mask = jnp.arange(vals.shape[0], dtype=jnp.int32) < n_valid
        logs = jnp.where(
            mask, jnp.log(jnp.maximum(vals, jnp.float32(1e-9))), jnp.inf
        )
        mid = jnp.maximum((n_valid - 1) // 2, 0)
        med = jnp.sort(logs)[mid]
        dev = jnp.where(mask, jnp.abs(logs - med), jnp.inf)
        mad = jnp.sort(dev)[mid]
        scale = jnp.float32(1.4826) * mad + gate_eps
        x_log = jnp.log(jnp.maximum(x, jnp.float32(1e-9)))
        return jnp.abs(x_log - med) > gate_g * scale

    return (n_valid >= min_accept) & (deviates(win_tau, tau) | deviates(win_p, p))


# the scalar ingest path (CORAL.record) calls the gate through this
# jitted wrapper — same XLA computation as the compiled fault step
mad_reject = jax.jit(mad_reject_trace)
