"""Scalar Kalman filter, as used by the ALERT baseline (Wan et al., ATC'20).

ALERT models the runtime deviation between offline-profiled and currently
observed performance as a global multiplicative slowdown factor ξ tracked
by a Kalman filter: observed = ξ · profiled + noise.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class ScalarKalman:
    x: float = 1.0  # state estimate (slowdown factor)
    p: float = 1.0  # estimate covariance
    q: float = 1e-3  # process noise
    r: float = 1e-2  # measurement noise

    def update(self, measured_ratio: float) -> float:
        # predict
        self.p += self.q
        # update
        k = self.p / (self.p + self.r)
        self.x += k * (measured_ratio - self.x)
        self.p *= 1.0 - k
        return self.x
