"""Shape/dtype contracts for the fixed-size engine state containers.

The episode carry, the incremental dCor state and the fleet batch are
the engine's load-bearing data structures: every field has a pinned
dtype (float32/int32/bool — never float64) and a shape that is a pure
function of the compile-time EngineSpec. The tables below write those
invariants down once, jaxtyping-style (``Float32[Array, "T+W D+4"]``),
and three consumers keep them honest:

- runtime: ``REPRO_CONTRACTS=1`` makes ``_init_carry``, the dcov state
  constructors and ``run_fleet_requests`` validate their containers at
  trace/build time (zero cost when the flag is off, and zero cost per
  scan step when on — checks run once per trace);
- static: repro-lint rule RL04 cross-checks the carry fields written in
  ``core/episode.py::_init_carry`` against these tables, so a new carry
  field without a contract fails lint;
- docs: the tables are the authoritative field list for EXPERIMENTS.md
  §Episode engine.

Dimension symbols: T episode iters, W dCor window, D config dims,
N padded grid rows, C = D+2 dCor columns, B batch (fleet requests).
"""
from __future__ import annotations

import re
from typing import Dict, Mapping

from repro.envflags import env_flag

# ------------------------------------------------------------------ tables

# base carry — every episode flavor (core/episode.py::_init_carry)
CARRY_CONTRACT: Dict[str, str] = {
    "hist_sm": 'Float32[Array, "T+W D+4"]',
    "n_obs": 'Int32[Array, ""]',
    "epoch_start": 'Int32[Array, ""]',
    "epoch_id": 'Int32[Array, ""]',
    "clock": 'Int32[Array, ""]',
    "seen_tag": 'Int32[Array, "N"]',
    "best_idx": 'Int32[Array, ""]',
    "best_tau": 'Float32[Array, ""]',
    "best_p": 'Float32[Array, ""]',
    "best_r": 'Float32[Array, ""]',
    "best_valid": 'Bool[Array, ""]',
    "sec_idx": 'Int32[Array, ""]',
    "sec_tau": 'Float32[Array, ""]',
    "sec_p": 'Float32[Array, ""]',
    "sec_r": 'Float32[Array, ""]',
    "sec_valid": 'Bool[Array, ""]',
    "last_idx": 'Int32[Array, ""]',
    "last_tau": 'Float32[Array, ""]',
    "last_p": 'Float32[Array, ""]',
    "last_valid": 'Bool[Array, ""]',
    "aside": 'Bool[Array, ""]',
    "probed_for": 'Int32[Array, ""]',
    "probe_done": 'Bool[Array, ""]',
}

# fleet episodes add the incremental dCor accumulators (carried instead
# of recomputed from the window — O(W·C²) per step)
FLEET_CARRY_CONTRACT: Dict[str, str] = {
    "dc_win": 'Float32[Array, "W C"]',
    "dc_dist": 'Float32[Array, "W W C"]',
    "dc_rows": 'Float32[Array, "W C"]',
    "dc_cross": 'Float32[Array, "C C"]',
}

# drift episodes add the budget schedule slot + CUSUM monitor state
DRIFT_CARRY_CONTRACT: Dict[str, str] = {
    "p_budget": 'Float32[Array, ""]',
    "mon_sigma": 'Float32[Array, ""]',
    "held_idx": 'Int32[Array, ""]',
    "held_tau": 'Float32[Array, ""]',
    "held_p": 'Float32[Array, ""]',
    "held_valid": 'Bool[Array, ""]',
    "mon_ref_tau": 'Float32[Array, ""]',
    "mon_ref_p": 'Float32[Array, ""]',
    "mon_calib": 'Int32[Array, ""]',
    "mon_pos_tau": 'Float32[Array, ""]',
    "mon_neg_tau": 'Float32[Array, ""]',
    "mon_pos_p": 'Float32[Array, ""]',
    "mon_neg_p": 'Float32[Array, ""]',
    "mon_active": 'Bool[Array, ""]',
    "retries": 'Int32[Array, ""]',
    "resets": 'Int32[Array, ""]',
}

# fault episodes add the actuation readback + telemetry watchdog state
FAULT_CARRY_CONTRACT: Dict[str, str] = {
    "applied_idx": 'Int32[Array, ""]',
    "dark": 'Int32[Array, ""]',
}

# incremental dCor state (core/dcov.py::dcor_state_*)
DCOR_STATE_CONTRACT: Dict[str, str] = {
    "win": 'Float32[Array, "W C"]',
    "dist": 'Float32[Array, "W W C"]',
    "rows": 'Float32[Array, "W C"]',
    "cross": 'Float32[Array, "C C"]',
}

# the host-built fleet request batch (episode.py::run_fleet_requests);
# leading B is the vmapped episode axis
FLEET_BATCH_CONTRACT: Dict[str, str] = {
    "space_id": 'Int32[Array, "B"]',
    "table_id": 'Int32[Array, "B"]',
    "tau_target": 'Float32[Array, "B"]',
    "p_budget": 'Float32[Array, "B"]',
    "throughput": 'Bool[Array, "B"]',
    "banned": 'Bool[Array, "B N"]',
    "min_idx": 'Int32[Array, "B"]',
    "max_idx": 'Int32[Array, "B"]',
    "warm": 'Bool[Array, "B"]',
    "warm_n": 'Int32[Array, "B"]',
    "warm_hist": 'Float32[Array, "B W D+4"]',
    "warm_prohibit": 'Bool[Array, "B N"]',
    "warm_best_idx": 'Int32[Array, "B"]',
    "warm_best_tau": 'Float32[Array, "B"]',
    "warm_best_p": 'Float32[Array, "B"]',
    "warm_best_r": 'Float32[Array, "B"]',
    "warm_best_valid": 'Bool[Array, "B"]',
    "warm_sec_idx": 'Int32[Array, "B"]',
    "warm_sec_tau": 'Float32[Array, "B"]',
    "warm_sec_p": 'Float32[Array, "B"]',
    "warm_sec_r": 'Float32[Array, "B"]',
    "warm_sec_valid": 'Bool[Array, "B"]',
    "warm_last_idx": 'Int32[Array, "B"]',
    "warm_last_tau": 'Float32[Array, "B"]',
    "warm_last_p": 'Float32[Array, "B"]',
    "warm_last_valid": 'Bool[Array, "B"]',
    "noise": 'Float32[Array, "B T 2"]',
}

# the unpadded per-twin ground truth (experiments/fleet.py::FleetTwin);
# N0 is the twin's own grid size, float64 on purpose — this is the
# noise-free oracle landscape, rounded to f32 only at the device boundary
TWIN_CONTRACT: Dict[str, str] = {
    "banned": 'Bool[Array, "N0"]',
    "land_tau": 'Float64[Array, "N0"]',
    "land_p": 'Float64[Array, "N0"]',
}

# ------------------------------------------------------- TWIN_RNG_PROTOCOL
#
# The behavioral contract every device twin behind ``device.build_twin``
# honors (DeviceSimulator, DriftingSimulator, OffloadSimulator,
# CotenantSimulator — and anything the factory grows next). The compiled
# episode engine replays a twin's noise stream from (seed, noise) alone,
# so the protocol is byte-exact, not approximate:
#
#   state      one ``np.random.default_rng(seed)`` Generator per twin,
#              advanced only by the measurement calls below;
#   measure    exact (τ, p) then two *sequential* scalar draws —
#              ``τ *= 1 + rng.normal(0, noise)`` then the same for p —
#              clamped to ≥ 1e-9;
#   measure_all  exact arrays then ONE config-major block
#              ``z = rng.normal(0, noise, size=(N, 2))`` with
#              ``τ *= 1 + z[:, 0]``, ``p *= 1 + z[:, 1]``, clamped —
#              the stream equals N sequential ``measure`` calls;
#   noise=0.0  must not draw at all (the ground-truth twin oracles use);
#   exact/exact_all  pure float64, no RNG advance, no clamping of the
#              model output beyond the twin's own physics;
#   channels   whatever the twin's (τ, p) *mean* is fair game — offload
#              twins report served throughput, cotenant twins the joint
#              headroom min_k τ_k/floor_k — but the noise protocol above
#              applies to the reported pair unchanged.
#
# tests/test_episode.py and tests/test_cotenant.py pin scalar↔compiled
# byte-equivalence through this contract; a twin that draws in a
# different order or shape breaks replay silently, so new twins must
# copy the reference implementation in ``device/simulator.py``.

_DTYPES = {"Float32": "float32", "Float64": "float64", "Int32": "int32",
           "Bool": "bool"}
_SPEC_RE = re.compile(r'^(\w+)\[Array, "(.*)"\]$')


class ContractError(AssertionError):
    """A container violated its shape/dtype contract."""


def contracts_enabled() -> bool:
    """The REPRO_CONTRACTS=1 runtime lane (single parser: envflags)."""
    return env_flag("REPRO_CONTRACTS")


def _parse(spec: str):
    m = _SPEC_RE.match(spec)
    if m is None:
        raise ContractError(f"malformed contract spec {spec!r}")
    return _DTYPES[m.group(1)], m.group(2)


def _expect_shape(dims_expr: str, dims: Mapping[str, int]):
    if not dims_expr:
        return ()
    env = {"__builtins__": {}}
    return tuple(
        int(eval(tok, env, dict(dims)))  # tokens like "T+W" — repo-authored
        for tok in dims_expr.split()
    )


def check_container(
    name: str,
    container: Mapping[str, object],
    contract: Mapping[str, str],
    dims: Mapping[str, int],
) -> None:
    """Exact-key, dtype and shape validation of one state container.
    Works on tracers (trace-time check under jit/vmap) and on host
    numpy arrays alike — both expose .dtype/.shape."""
    got, want = set(container), set(contract)
    if got != want:
        missing, extra = sorted(want - got), sorted(got - want)
        raise ContractError(
            f"{name}: field set mismatch (missing={missing}, extra={extra})"
        )
    for field, spec in contract.items():
        dtype, dims_expr = _parse(spec)
        arr = container[field]
        actual = str(arr.dtype)
        if actual != dtype:
            raise ContractError(
                f"{name}.{field}: dtype {actual}, contract says {dtype}"
            )
        shape = _expect_shape(dims_expr, dims)
        if tuple(arr.shape) != shape:
            raise ContractError(
                f"{name}.{field}: shape {tuple(arr.shape)}, contract says "
                f"{shape} ({spec})"
            )


def carry_contract(fleet: bool, drift: bool, fault: bool = False) -> Dict[str, str]:
    """The contract table for one episode flavor: the base carry plus
    the fleet dCor accumulators, the drift monitor fields and/or the
    fault actuation/watchdog fields."""
    table = dict(CARRY_CONTRACT)
    if fleet:
        table.update(FLEET_CARRY_CONTRACT)
    if drift:
        table.update(DRIFT_CARRY_CONTRACT)
    if fault:
        table.update(FAULT_CARRY_CONTRACT)
    return table


def check_carry(spec, carry: Mapping[str, object]) -> None:
    """Validate an episode carry against its EngineSpec (trace-time)."""
    dims = {"T": spec.iters, "W": spec.window, "D": spec.d, "N": spec.n,
            "C": spec.d + 2}
    check_container(
        "carry", carry, carry_contract(spec.fleet, spec.drift, spec.fault),
        dims,
    )


def check_dcor_state(state: Mapping[str, object]) -> None:
    """Validate an incremental dCor state dict; W and C are taken from
    the ``win`` field (the constructors fix them)."""
    win = state.get("win")
    if win is None:
        raise ContractError("dcor state: missing 'win' field")
    w, c = win.shape
    check_container(
        "dcor_state", state, DCOR_STATE_CONTRACT, {"W": w, "C": c}
    )


def check_twin(twin) -> None:
    """Validate a FleetTwin's ground-truth arrays against its space."""
    check_container(
        "fleet_twin",
        {"banned": twin.banned, "land_tau": twin.land_tau,
         "land_p": twin.land_p},
        TWIN_CONTRACT,
        {"N0": twin.space.size()},
    )


def check_fleet_batch(ep: Mapping[str, object], *, b: int, n: int, w: int,
                      d: int, t: int) -> None:
    """Validate the host-built fleet batch before device upload."""
    check_container(
        "fleet_batch", ep, FLEET_BATCH_CONTRACT,
        {"B": b, "N": n, "W": w, "D": d, "T": t},
    )
