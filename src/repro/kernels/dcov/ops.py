"""Jit'd public wrappers: distance correlation via the blocked Pallas
kernels.

``interpret`` defaults to the backend: interpret=True off-TPU (kernel body
executed in Python — CPU CI), Mosaic-compiled on TPU. Pass an explicit
``interpret``/``block`` to override.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.dcov import dcor_from_sums
from repro.kernels.dcov.dcov import dcov_gram_pallas, dcov_sums_pallas


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dcor_pallas(
    x: jax.Array,
    y: jax.Array,
    block: Optional[int] = None,
    interpret: Optional[bool] = None,
    eps: float = 1e-12,
) -> jax.Array:
    """Distance correlation (Eq. 4) without materializing n×n matrices."""
    sab, saa, sbb = dcov_sums_pallas(x, y, block=block, interpret=interpret)
    return dcor_from_sums(sab, saa, sbb, eps)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dcor_all_pallas(
    settings: jax.Array,
    metrics: jax.Array,
    block: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """TPU twin of ``repro.core.dcov.dcor_all`` (full windows only).

    settings: (n, D), metrics: (n, M) → (D, M) dCor matrix from one batched
    Gram kernel launch; every column's distance structure is computed once
    and shared across all D×M pairs.
    """
    d = settings.shape[1]
    cols = jnp.concatenate(
        [settings.astype(jnp.float32), metrics.astype(jnp.float32)], axis=1
    )
    gram = dcov_gram_pallas(cols, block=block, interpret=interpret)
    diag = jnp.diagonal(gram)
    sab = gram[:d, d:]
    return dcor_from_sums(sab, diag[:d, None], diag[None, d:])
