"""Jit'd public wrapper: distance correlation via the blocked Pallas kernel.

On CPU CI we run interpret=True (kernel body executed in Python); on TPU
set interpret=False for the Mosaic-compiled path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.dcov.dcov import dcov_sums_pallas


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dcor_pallas(
    x: jax.Array, y: jax.Array, block: int = 256, interpret: bool = True,
    eps: float = 1e-12,
) -> jax.Array:
    """Distance correlation (Eq. 4) without materializing n×n matrices."""
    sab, saa, sbb = dcov_sums_pallas(x, y, block=block, interpret=interpret)
    denom = jnp.sqrt(jnp.maximum(saa * sbb, 0.0))
    val = jnp.sqrt(jnp.maximum(sab, 0.0) / jnp.maximum(denom, eps))
    return jnp.where(denom < eps, 0.0, jnp.clip(val, 0.0, 1.0))
