from repro.kernels.dcov.ops import dcor_pallas  # noqa: F401
from repro.kernels.dcov.ref import dcor_ref, dcov_sums_ref  # noqa: F401
