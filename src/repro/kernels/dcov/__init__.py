from repro.kernels.dcov.dcov import (  # noqa: F401
    dcov_gram_pallas,
    dcov_sums_pallas,
    default_interpret,
)
from repro.kernels.dcov.ops import dcor_all_pallas, dcor_pallas  # noqa: F401
from repro.kernels.dcov.ref import dcor_ref, dcov_gram_ref, dcov_sums_ref  # noqa: F401
