"""Blocked Pallas TPU kernel for distance covariance (paper Eq. 1-3).

The O(n²) pairwise-distance computation is the paper's core compute. For
ORACLE-scale analyses (n = thousands of profiled configs) the n×n distance
matrices must not materialize in HBM. Two passes over (block_i × block_j)
VMEM tiles:

  pass 1 (row sums):   r_a[i] = Σ_j |x_i − x_j|, r_b likewise
  pass 2 (contraction): Σ_ij A_ij·B_ij, Σ A², Σ B² where
                        A_ij = a_ij − ā_i − ā_j + ā

Grid iteration on TPU is sequential over the minor axis, so accumulating
into the same output block across j-steps is the standard reduction
pattern (init at j==0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _row_sum_kernel(xi_ref, xj_ref, yi_ref, yj_ref, ra_ref, rb_ref, *, n, bi, bj):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        ra_ref[...] = jnp.zeros_like(ra_ref)
        rb_ref[...] = jnp.zeros_like(rb_ref)

    gi = i * bi + jax.lax.broadcasted_iota(jnp.int32, (bi, 1), 0)
    gj = j * bj + jax.lax.broadcasted_iota(jnp.int32, (1, bj), 1)
    mask = ((gi < n) & (gj < n)).astype(jnp.float32)
    a = jnp.abs(xi_ref[...] - xj_ref[...].T) * mask  # (bi, bj)
    b = jnp.abs(yi_ref[...] - yj_ref[...].T) * mask
    ra_ref[...] += a.sum(axis=1, keepdims=True)
    rb_ref[...] += b.sum(axis=1, keepdims=True)


def _center_kernel(
    xi_ref, xj_ref, yi_ref, yj_ref, rai_ref, raj_ref, rbi_ref, rbj_ref,
    ga_ref, gb_ref, sab_ref, saa_ref, sbb_ref, *, n, bi, bj,
):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        sab_ref[...] = jnp.zeros_like(sab_ref)
        saa_ref[...] = jnp.zeros_like(saa_ref)
        sbb_ref[...] = jnp.zeros_like(sbb_ref)

    gi = i * bi + jax.lax.broadcasted_iota(jnp.int32, (bi, 1), 0)
    gj = j * bj + jax.lax.broadcasted_iota(jnp.int32, (1, bj), 1)
    mask = ((gi < n) & (gj < n)).astype(jnp.float32)
    inv_n = 1.0 / n
    ga = ga_ref[0, 0] * inv_n * inv_n  # grand mean
    gb = gb_ref[0, 0] * inv_n * inv_n
    a = jnp.abs(xi_ref[...] - xj_ref[...].T)
    b = jnp.abs(yi_ref[...] - yj_ref[...].T)
    A = a - rai_ref[...] * inv_n - raj_ref[...].T * inv_n + ga
    B = b - rbi_ref[...] * inv_n - rbj_ref[...].T * inv_n + gb
    A = A * mask
    B = B * mask
    sab_ref[0, 0] += jnp.sum(A * B)
    saa_ref[0, 0] += jnp.sum(A * A)
    sbb_ref[0, 0] += jnp.sum(B * B)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dcov_sums_pallas(x, y, block: int = 256, interpret: bool = True):
    """Returns (Σ A·B, Σ A², Σ B²) for double-centered distance matrices.

    x, y: (n,) float32. Padded internally to a block multiple.
    """
    n = x.shape[0]
    nb = pl.cdiv(n, block)
    npad = nb * block
    xp = jnp.pad(x.astype(jnp.float32), (0, npad - n)).reshape(npad, 1)
    yp = jnp.pad(y.astype(jnp.float32), (0, npad - n)).reshape(npad, 1)

    col = lambda i, j: (i, 0)
    row = lambda i, j: (j, 0)
    ra, rb = pl.pallas_call(
        functools.partial(_row_sum_kernel, n=n, bi=block, bj=block),
        grid=(nb, nb),
        in_specs=[
            pl.BlockSpec((block, 1), col),
            pl.BlockSpec((block, 1), row),
            pl.BlockSpec((block, 1), col),
            pl.BlockSpec((block, 1), row),
        ],
        out_specs=[
            pl.BlockSpec((block, 1), col),
            pl.BlockSpec((block, 1), col),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad, 1), jnp.float32),
            jax.ShapeDtypeStruct((npad, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xp, xp, yp, yp)

    ga = ra.sum().reshape(1, 1)  # Σ_ij a_ij (grand sum)
    gb = rb.sum().reshape(1, 1)

    scalar = lambda i, j: (0, 0)
    sab, saa, sbb = pl.pallas_call(
        functools.partial(_center_kernel, n=n, bi=block, bj=block),
        grid=(nb, nb),
        in_specs=[
            pl.BlockSpec((block, 1), col),
            pl.BlockSpec((block, 1), row),
            pl.BlockSpec((block, 1), col),
            pl.BlockSpec((block, 1), row),
            pl.BlockSpec((block, 1), col),
            pl.BlockSpec((block, 1), row),
            pl.BlockSpec((block, 1), col),
            pl.BlockSpec((block, 1), row),
            pl.BlockSpec((1, 1), scalar),
            pl.BlockSpec((1, 1), scalar),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), scalar),
            pl.BlockSpec((1, 1), scalar),
            pl.BlockSpec((1, 1), scalar),
        ],
        out_shape=[jax.ShapeDtypeStruct((1, 1), jnp.float32)] * 3,
        interpret=interpret,
    )(xp, xp, yp, yp, ra, ra, rb, rb, ga, gb)
    return sab[0, 0], saa[0, 0], sbb[0, 0]
