"""Blocked Pallas TPU kernels for distance covariance (paper Eq. 1-3).

The O(n²) pairwise-distance computation is the paper's core compute. For
ORACLE-scale analyses (n = thousands of profiled configs) the n×n distance
matrices must not materialize in HBM. The kernel is batched over a column
set: given C 1-d samples stacked as (n, C), two passes over
(block_i × block_j) VMEM tiles shared across all columns:

  pass 1 (row sums):   r_c[i] = Σ_j |x_ci − x_cj| for every column c
  pass 2 (Gram):       G[c,c'] = Σ_ij A_c,ij · A_c',ij where
                       A_c,ij = a_c,ij − ā_c,i − ā_c,j + ā_c

The full C×C Gram matrix of ⟨A_c, A_c'⟩ sums falls out of one contraction
per tile (an MXU matmul over the flattened tile), so D settings × M metrics
correlation analyses cost one kernel launch instead of D·M pairwise ones.

Grid iteration on TPU is sequential over the minor axis, so accumulating
into the same output block across grid steps is the standard reduction
pattern (init at the first step).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Columns are padded to a multiple of the lane-friendly width; padded
# columns are all-zero → zero distance matrices → zero Gram rows, sliced
# away by the wrapper.
_COL_PAD = 8


# Canonical interpret-mode routing lives in repro.kernels.runtime; the
# names are re-exported here because this module hosted them first and
# benchmarks.common / tests still import them from this path.
from repro.kernels.runtime import (  # noqa: F401
    default_interpret,
    parse_interpret_env,
)


# VMEM working-set budget for one (block, block, cpad) tile family. The
# gram pass keeps ~3 such float32 intermediates live (the |xi−xj| tile,
# the centered tile and the flattened matmul operand), so the block edge
# is sized to keep 3·b²·cpad·4B within budget — half of a v5e core's
# ~16 MB VMEM, leaving headroom for the row-sum operands and Mosaic's
# own buffers.
_VMEM_BUDGET = 8 << 20


def _auto_block(n: int, cpad: int) -> int:
    """Largest power-of-two block edge whose tile family fits the VMEM
    budget (never larger than n, never smaller than 8). Callers that
    pass an explicit ``block`` keep it — this only drives the default,
    so window/grid sizes beyond one VMEM tile run the real blocked
    kernel instead of degrading to an oversized single tile."""
    edge = int((_VMEM_BUDGET / (12 * cpad)) ** 0.5)
    block = 8
    while block * 2 <= min(edge, max(n, 8)) and block < 1024:
        block *= 2
    return block


def _row_sum_batch_kernel(ci_ref, cj_ref, rs_ref, *, n, b):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        rs_ref[...] = jnp.zeros_like(rs_ref)

    gi = i * b + jax.lax.broadcasted_iota(jnp.int32, (b, 1), 0)
    gj = j * b + jax.lax.broadcasted_iota(jnp.int32, (1, b), 1)
    mask = ((gi < n) & (gj < n)).astype(jnp.float32)  # (b, b)
    a = jnp.abs(ci_ref[...][:, None, :] - cj_ref[...][None, :, :])
    rs_ref[...] += (a * mask[:, :, None]).sum(axis=1)


def _gram_batch_kernel(
    ci_ref, cj_ref, rsi_ref, rsj_ref, g_ref, gram_ref, *, n, b
):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        gram_ref[...] = jnp.zeros_like(gram_ref)

    gi = i * b + jax.lax.broadcasted_iota(jnp.int32, (b, 1), 0)
    gj = j * b + jax.lax.broadcasted_iota(jnp.int32, (1, b), 1)
    mask = ((gi < n) & (gj < n)).astype(jnp.float32)
    inv_n = 1.0 / n
    grand = g_ref[...][0] * inv_n * inv_n  # (C,) per-column grand mean
    a = jnp.abs(ci_ref[...][:, None, :] - cj_ref[...][None, :, :])
    A = (
        a
        - rsi_ref[...][:, None, :] * inv_n
        - rsj_ref[...][None, :, :] * inv_n
        + grand[None, None, :]
    ) * mask[:, :, None]
    Af = A.reshape(b * b, A.shape[-1])
    gram_ref[...] += jnp.dot(Af.T, Af, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dcov_gram_pallas(
    cols, block: Optional[int] = None, interpret: Optional[bool] = None
):
    """Gram matrix of double-centered distance matrices for a column batch.

    cols: (n, C) float32 — C independent 1-d samples.
    returns: (C, C) where [c, c'] = Σ_ij A_c,ij · A_c',ij. Diagonal entries
    are the dVar sums; off-diagonals the dCov sums (both unnormalized — the
    caller divides by n² or cancels it in the dCor ratio).

    ``block=None`` picks the largest tile edge whose working set fits
    the VMEM budget for this column count (see ``_auto_block``), so
    ORACLE-scale n (thousands of rows) runs the real blocked kernel.
    """
    if interpret is None:
        interpret = default_interpret()
    n, c = cols.shape
    if block is None:
        block = _auto_block(n, pl.cdiv(c, _COL_PAD) * _COL_PAD)
    nb = pl.cdiv(n, block)
    npad = nb * block
    cpad = pl.cdiv(c, _COL_PAD) * _COL_PAD
    cp = jnp.pad(cols.astype(jnp.float32), ((0, npad - n), (0, cpad - c)))

    col = lambda i, j: (i, 0)
    row = lambda i, j: (j, 0)
    rs = pl.pallas_call(
        functools.partial(_row_sum_batch_kernel, n=n, b=block),
        grid=(nb, nb),
        in_specs=[
            pl.BlockSpec((block, cpad), col),
            pl.BlockSpec((block, cpad), row),
        ],
        out_specs=pl.BlockSpec((block, cpad), col),
        out_shape=jax.ShapeDtypeStruct((npad, cpad), jnp.float32),
        interpret=interpret,
    )(cp, cp)

    g = rs.sum(axis=0, keepdims=True)  # (1, C) per-column grand sums

    scalar = lambda i, j: (0, 0)
    gram = pl.pallas_call(
        functools.partial(_gram_batch_kernel, n=n, b=block),
        grid=(nb, nb),
        in_specs=[
            pl.BlockSpec((block, cpad), col),
            pl.BlockSpec((block, cpad), row),
            pl.BlockSpec((block, cpad), col),
            pl.BlockSpec((block, cpad), row),
            pl.BlockSpec((1, cpad), scalar),
        ],
        out_specs=pl.BlockSpec((cpad, cpad), scalar),
        out_shape=jax.ShapeDtypeStruct((cpad, cpad), jnp.float32),
        interpret=interpret,
    )(cp, cp, rs, rs, g)
    return gram[:c, :c]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dcov_sums_pallas(
    x, y, block: Optional[int] = None, interpret: Optional[bool] = None
):
    """Returns (Σ A·B, Σ A², Σ B²) for double-centered distance matrices.

    x, y: (n,) float32. Thin two-column wrapper over ``dcov_gram_pallas``.
    """
    cols = jnp.stack([x.astype(jnp.float32), y.astype(jnp.float32)], axis=1)
    gram = dcov_gram_pallas(cols, block=block, interpret=interpret)
    return gram[0, 1], gram[0, 0], gram[1, 1]
