"""Pure-jnp oracle for the dcov kernel: materialized distance matrices."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dcov import _double_center, _pairwise_dist


def dcov_sums_ref(x: jax.Array, y: jax.Array):
    """(Σ A·B, Σ A², Σ B²) with full n×n materialization."""
    A = _double_center(_pairwise_dist(x.astype(jnp.float32)))
    B = _double_center(_pairwise_dist(y.astype(jnp.float32)))
    return jnp.sum(A * B), jnp.sum(A * A), jnp.sum(B * B)


def dcor_ref(x: jax.Array, y: jax.Array, eps: float = 1e-12) -> jax.Array:
    sab, saa, sbb = dcov_sums_ref(x, y)
    denom = jnp.sqrt(jnp.maximum(saa * sbb, 0.0))
    val = jnp.sqrt(jnp.maximum(sab, 0.0) / jnp.maximum(denom, eps))
    return jnp.where(denom < eps, 0.0, jnp.clip(val, 0.0, 1.0))
