"""Pure-jnp oracle for the dcov kernels: materialized distance matrices."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dcov import (
    _double_center,
    _pairwise_dist,
    centered_distance_stack,
    dcor_from_sums,
)


def dcov_sums_ref(x: jax.Array, y: jax.Array):
    """(Σ A·B, Σ A², Σ B²) with full n×n materialization."""
    A = _double_center(_pairwise_dist(x.astype(jnp.float32)))
    B = _double_center(_pairwise_dist(y.astype(jnp.float32)))
    return jnp.sum(A * B), jnp.sum(A * A), jnp.sum(B * B)


def dcor_ref(x: jax.Array, y: jax.Array, eps: float = 1e-12) -> jax.Array:
    return dcor_from_sums(*dcov_sums_ref(x, y), eps)


def dcov_gram_ref(cols: jax.Array) -> jax.Array:
    """(C, C) Gram of ⟨A_c, A_c'⟩ sums with full n×n×C materialization."""
    A = centered_distance_stack(
        cols.astype(jnp.float32), jnp.asarray(cols.shape[0])
    )
    return jnp.einsum("ijc,ijd->cd", A, A)
