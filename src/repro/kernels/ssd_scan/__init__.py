from repro.kernels.ssd_scan.ops import ssd  # noqa: F401
from repro.kernels.ssd_scan.ref import ssd_ref  # noqa: F401
from repro.kernels.ssd_scan.ssd_scan import ssd_pallas  # noqa: F401
