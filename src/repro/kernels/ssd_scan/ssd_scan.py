"""Mamba2 SSD chunked-scan Pallas TPU kernel (arXiv:2405.21060 Alg. SSD).

Grid (B, NH, NC) with the chunk axis iterated sequentially (minor), so the
(hd × N) recurrent state lives in VMEM scratch and carries across chunks —
the inter-chunk recurrence costs no HBM round-trips. Per chunk, the
intra-chunk dual form is three MXU matmuls:

  att    = C · Bᵀ                        (Q × Q)
  y_diag = (att ⊙ L) · (dt ⊙ x)          (Q × hd)
  y_off  = exp(cs) ⊙ (C · Sᵀ)            (Q × hd)  — S is the carried state
  S'     = exp(Σ dA)·S + (dt⊙x)ᵀ·(seg⊙B) (hd × N)

TPU adaptation: chunk Q and headdim/state sizes are chosen MXU-friendly
(multiples of 128 at deployment; tests sweep smaller interpret shapes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import default_interpret


def _ssd_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref, s0_ref, y_ref, sfin_ref, state_ref,
    *, nc, q, hd, n,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0, 0].astype(jnp.float32)

    x = x_ref[0, 0, 0].astype(jnp.float32)  # (Q, hd)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)  # (Q, 1)
    A = a_ref[0, 0]  # scalar (negative)
    Bm = b_ref[0, 0, 0].astype(jnp.float32)  # (Q, N)
    Cm = c_ref[0, 0, 0].astype(jnp.float32)  # (Q, N)

    dA = dt[:, 0] * A  # (Q,)
    cs = jnp.cumsum(dA)  # inclusive
    xdt = x * dt  # (Q, hd)

    # intra-chunk
    att = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, Q)
    decay = jnp.exp(cs[:, None] - cs[None, :])
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(ii >= jj, decay, 0.0)
    y = jax.lax.dot_general(
        att * L, xdt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (Q, hd)

    # inter-chunk contribution from the carried state
    state = state_ref[...]  # (hd, N)
    y_off = jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, hd)
    y = y + jnp.exp(cs)[:, None] * y_off
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    # state update
    seg = jnp.exp(cs[-1] - cs)  # (Q,)
    new_state = state * jnp.exp(cs[-1]) + jax.lax.dot_general(
        xdt, Bm * seg[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (hd, N)
    state_ref[...] = new_state

    @pl.when(ci == nc - 1)
    def _fin():
        sfin_ref[0, 0, 0] = new_state.astype(sfin_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_pallas(
    x: jax.Array,  # (B, S, NH, hd)
    dt: jax.Array,  # (B, S, NH) — post-softplus
    A: jax.Array,  # (NH,) negative
    Bm: jax.Array,  # (B, S, N)  (ngroups=1)
    Cm: jax.Array,  # (B, S, N)
    chunk: int = 256,
    initial_state=None,
    interpret: bool | None = None,
):
    """Returns (y (B,S,NH,hd), final_state (B,NH,hd,N))."""
    if interpret is None:  # static param: resolved at trace time
        interpret = default_interpret()
    b, s, nh, hd = x.shape
    n = Bm.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nc = sp // chunk

    # layout: (B, NH, NC, Q, ·)
    xq = x.transpose(0, 2, 1, 3).reshape(b, nh, nc, chunk, hd)
    dtq = dt.transpose(0, 2, 1).reshape(b, nh, nc, chunk, 1)
    bq = Bm.reshape(b, 1, nc, chunk, n)
    cq = Cm.reshape(b, 1, nc, chunk, n)
    a2 = A.reshape(1, nh).astype(jnp.float32)
    s0 = (
        jnp.zeros((b, nh, hd, n), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )
    s0 = s0.reshape(b, nh, 1, hd, n)

    y, sfin = pl.pallas_call(
        functools.partial(_ssd_kernel, nc=nc, q=chunk, hd=hd, n=n),
        grid=(b, nh, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, hd), lambda b_, h, c: (b_, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, 1), lambda b_, h, c: (b_, h, c, 0, 0)),
            pl.BlockSpec((1, 1), lambda b_, h, c: (0, h)),
            pl.BlockSpec((1, 1, 1, chunk, n), lambda b_, h, c: (b_, 0, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, n), lambda b_, h, c: (b_, 0, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, hd, n), lambda b_, h, c: (b_, h, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, chunk, hd), lambda b_, h, c: (b_, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, hd, n), lambda b_, h, c: (b_, h, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nh, nc, chunk, hd), x.dtype),
            jax.ShapeDtypeStruct((b, nh, 1, hd, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, n), jnp.float32)],
        interpret=interpret,
    )(xq, dtq, a2, bq, cq, s0)

    y = y.reshape(b, nh, sp, hd).transpose(0, 2, 1, 3)[:, :s]
    return y, sfin.reshape(b, nh, hd, n)
