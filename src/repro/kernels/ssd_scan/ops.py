"""Jit'd public wrapper for the SSD Pallas kernel. ``interpret=None``
routes through ``repro.kernels.runtime.default_interpret``."""
from repro.kernels.ssd_scan.ssd_scan import ssd_pallas


def ssd(x, dt, A, Bm, Cm, chunk=256, initial_state=None, interpret=None):
    return ssd_pallas(x, dt, A, Bm, Cm, chunk=chunk,
                      initial_state=initial_state, interpret=interpret)
