"""Jit'd public wrapper for the SSD Pallas kernel."""
from repro.kernels.ssd_scan.ssd_scan import ssd_pallas


def ssd(x, dt, A, Bm, Cm, chunk=256, initial_state=None, interpret=True):
    return ssd_pallas(x, dt, A, Bm, Cm, chunk=chunk,
                      initial_state=initial_state, interpret=interpret)
