"""Pure-jnp oracle: the chunked SSD implementation from the model zoo."""
from repro.models.ssm import ssd_chunked


def ssd_ref(x, dt, A, Bm, Cm, chunk=256, initial_state=None):
    return ssd_chunked(x, dt, A, Bm, Cm, chunk, initial_state)
