# Pallas TPU kernels for the compute hot-spots (validated in interpret
# mode on CPU against ref.py oracles):
#   dcov            — the paper's distance-covariance computation (Eq. 1-3)
#   flash_attention — causal/SWA/GQA online-softmax attention (prefill)
#   ssd_scan        — Mamba2 SSD chunked scan with VMEM-carried state
