"""Jit'd public wrapper matching the model-side call convention
(B, S, H, D) ⇄ the kernel's (B, H, S, D). ``interpret=None`` routes
through ``repro.kernels.runtime.default_interpret`` inside the kernel."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention_bhsd


def flash_attention(
    qg: jax.Array,  # (B, Sq, Hkv, G, D) — model-side grouped layout
    k: jax.Array,  # (B, Sk, Hkv, D)
    v: jax.Array,  # (B, Sk, Hkv, Dv)
    q_pos=None,
    kv_pos=None,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    b, sq, hkv, g, d = qg.shape
    q = qg.reshape(b, sq, hkv * g, d).transpose(0, 2, 1, 3)
    kk = k.transpose(0, 2, 1, 3)
    vv = v.transpose(0, 2, 1, 3)
    o = flash_attention_bhsd(
        q, kk, vv, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return o.transpose(0, 2, 1, 3).reshape(b, sq, hkv, g, v.shape[-1])
