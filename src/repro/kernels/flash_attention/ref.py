"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Sk, D)
    v: jax.Array,  # (B, Hkv, Sk, Dv)
    *,
    causal: bool = True,
    window: int | None = None,
) -> jax.Array:
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, sq, d).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32)) / (d**0.5)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, sq, v.shape[-1]).astype(v.dtype)
