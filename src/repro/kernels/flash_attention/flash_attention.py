"""Flash attention Pallas TPU kernel: causal + sliding-window + GQA.

Online-softmax over (block_q × block_k) VMEM tiles; fp32 accumulators in
VMEM scratch; grid (B, Hq, nQ, nK) with the KV axis iterated sequentially
(minor) so running max / denominator / accumulator carry across k-steps.
GQA is expressed purely in the k/v BlockSpec index maps (head h reads KV
head h // group) — no KV head replication in HBM.

Assumes contiguous positions from 0 (training/prefill). Decode uses the
XLA path (Sq = 1 is bandwidth-bound; MXU tiling buys nothing).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import default_interpret

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale, causal, window, bq, bk, nk, seq_k,
):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * bq
    k_start = kj * bk
    # cheap block-level skip: fully-masked tiles don't touch the MXU
    q_end = q_start + bq - 1
    relevant = True
    if causal:
        relevant = k_start <= q_end
    if window is not None:
        relevant = relevant & (k_start + bk - 1 > q_start - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < seq_k
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]  # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _finalize():
        o_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention_bhsd(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Sk, D)
    v: jax.Array,  # (B, Hkv, Sk, Dv)
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:  # static param: resolved at trace time
        interpret = default_interpret()
    b, hq, sq, d = q.shape
    hkv, sk, dv = k.shape[1], k.shape[2], v.shape[3]
    assert hq % hkv == 0
    g = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    pq, pk = (-sq) % bq, (-sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq, nk = (sq + pq) // bq, (sk + pk) // bk
    scale = 1.0 / (d**0.5)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal, window=window,
            bq=bq, bk=bk, nk=nk, seq_k=sk,
        ),
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, i, j: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, dv), lambda b_, h, i, j: (b_, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dv), lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq + pq, dv), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dv), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq]
