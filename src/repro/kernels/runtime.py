"""Canonical interpret-mode routing for every Pallas kernel in the repo.

A kernel must never derive its own ``interpret=`` value (that is lint
rule RL05): the decision lives here, in one place, so the dcov, flash
attention and SSD-scan entry points — and the bench harness view
``benchmarks.common.pallas_interpret`` — can never disagree about
whether the Mosaic compiler or the interpreter runs a kernel.

Resolution order:

1. ``PALLAS_INTERPRET`` env var, parsed by the repo's single truthy
   parser (:mod:`repro.envflags`): "0"/"false"/"no" forces compiled
   mode, any other non-empty value forces interpret mode.
2. Backend auto-detect: interpret everywhere except a real TPU backend.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

from repro.envflags import parse_tristate


def parse_interpret_env(raw: Optional[str]) -> Optional[bool]:
    """The one parser for PALLAS_INTERPRET: ``None`` for unset/empty
    (backend-auto), else :func:`repro.envflags.truthy`."""
    return parse_tristate(raw)


def default_interpret() -> bool:
    """Interpret mode unless running on an actual TPU backend; the
    PALLAS_INTERPRET env flag overrides the backend-derived default."""
    env = parse_interpret_env(os.environ.get("PALLAS_INTERPRET"))
    if env is not None:
        return env
    return jax.default_backend() != "tpu"
