"""Logical-axis → mesh-axis sharding rules.

Every parameter carries logical axis names (see repro.models.layers
ParamSpec). A *rule set* maps logical names to mesh axes; unmapped axes are
replicated. A mapping is dropped (axis replicated) when the dimension size
is not divisible by the mesh-axis size (e.g. 2 KV heads on a 16-way model
axis).

Rule sets are a hillclimb knob (RunConfig.sharding_rules).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes)
RULE_SETS: Dict[str, Dict[str, object]] = {
    # Megatron-style tensor parallelism + fsdp-style weight sharding over
    # the data axis on the embed dimension (needed to fit 236B params).
    "megatron_fsdp": {
        "vocab": "model",
        "heads_flat": "model",
        "kv_heads_flat": "model",
        "ff": "model",
        "experts": "model",
        "ssm_inner": "model",
        "ssm_heads": "model",
        "embed": "data",  # fsdp: gather on use
        "layers": None,
    },
    # pure tensor parallelism (params replicated over data)
    "megatron": {
        "vocab": "model",
        "heads_flat": "model",
        "kv_heads_flat": "model",
        "ff": "model",
        "experts": "model",
        "ssm_inner": "model",
        "ssm_heads": "model",
        "embed": None,
        "layers": None,
    },
    # serving: 2D expert sharding (experts→model, ff→data) — weights stay
    # fully sharded but are never gathered; MoE down-projections reduce
    # with a small activation psum over data (§Perf hillclimb #2).
    "serving_2d": {
        "vocab": "model",
        "heads_flat": "model",
        "kv_heads_flat": "model",
        "ff": "data",
        "experts": "model",
        "ssm_inner": "model",
        "ssm_heads": "model",
        "embed": None,
        "layers": None,
    },
    # fsdp over the layer stack axis instead of the embed axis
    "fsdp_layers": {
        "vocab": "model",
        "heads_flat": "model",
        "kv_heads_flat": "model",
        "ff": "model",
        "experts": "model",
        "ssm_inner": "model",
        "ssm_heads": "model",
        "embed": None,
        "layers": "data",
    },
}


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return math.prod(mesh.shape[a] for a in axis)
    return mesh.shape[axis]


def spec_for_axes(
    mesh: Mesh, axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
    rules: Dict[str, object],
) -> P:
    parts = []
    used = set()
    for dim, name in zip(shape, axes):
        mesh_axis = rules.get(name) if name else None
        if mesh_axis is None or mesh_axis in used:
            parts.append(None)
            continue
        if dim % _axis_size(mesh, mesh_axis) != 0:
            parts.append(None)  # indivisible: replicate
            continue
        parts.append(mesh_axis)
        used.add(mesh_axis)
    return P(*parts)


def data_axes(mesh: Mesh) -> tuple:
    """All mesh axes used for batch/data parallelism ((pod, data) if present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def param_shardings(mesh: Mesh, specs_tree, rules_name: str):
    """ParamSpec pytree -> NamedSharding pytree."""
    from repro.models.layers import ParamSpec, tree_map_specs

    base_rules = dict(RULE_SETS[rules_name])
    # multi-pod: fsdp over ("pod","data") jointly when embed->data
    if "pod" in getattr(mesh, "axis_names", ()):
        for k, v in list(base_rules.items()):
            if v == "data":
                base_rules[k] = ("pod", "data")

    def one(s: ParamSpec):
        return NamedSharding(mesh, spec_for_axes(mesh, s.axes, s.shape, base_rules))

    return tree_map_specs(one, specs_tree)


def batch_spec(mesh: Mesh, global_batch: int) -> P:
    """Sharding for the leading batch dim of activations/inputs."""
    da = data_axes(mesh)
    size = math.prod(mesh.shape[a] for a in da)
    if da and global_batch % size == 0:
        return P(da)
    # try pod-only / data-only before giving up
    for sub in (("data",), ("pod",)):
        if all(a in mesh.axis_names for a in sub):
            s = math.prod(mesh.shape[a] for a in sub)
            if global_batch % s == 0:
                return P(sub)
    return P(None)


def activation_sharding(mesh: Mesh, global_batch: int, extra_dims: int):
    """(B, ..., d) activations: batch over data axes, trailing dims replicated."""
    return NamedSharding(mesh, P(*batch_spec(mesh, global_batch), *([None] * extra_dims)))


def cache_shardings(mesh: Mesh, cfg, cache_tree, global_batch: int):
    """KV-cache sharding: batch dim over data axes; the cache sequence dim
    over "model" (flash-decode style: each model shard owns a slice of the
    context and the softmax reduction runs as a collective)."""
    da = data_axes(mesh)
    bsize = math.prod(mesh.shape[a] for a in da)
    bspec = da if (da and global_batch % bsize == 0) else None

    def one(path_leaf):
        leaf = path_leaf
        nd = len(leaf.shape)
        if nd == 0:  # length scalar
            return NamedSharding(mesh, P())
        # layout (L, B, W, ...) for kv/latent; (L, B, ...) for ssm state
        parts = [None] * nd
        if nd >= 2:
            parts[1] = bspec
        if nd >= 3 and leaf.shape[2] % mesh.shape["model"] == 0:
            parts[2] = "model"
        return NamedSharding(mesh, P(*parts))

    return jax.tree.map(one, cache_tree)
