from repro.sharding.specs import (  # noqa: F401
    RULE_SETS,
    activation_sharding,
    batch_spec,
    cache_shardings,
    data_axes,
    param_shardings,
    spec_for_axes,
)
