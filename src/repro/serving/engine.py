"""Serving engine: batched prefill + decode against a KV cache.

``make_prefill_step`` / ``make_serve_step`` are the jit-able step functions
the multi-pod dry-run lowers; ``ServingEngine`` is the runnable host-side
loop used by examples and by the WalltimeDevice (real measured throughput
for the CORAL optimizer).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import (
    ApplyCtx,
    decode_step,
    prefill,
)


def make_prefill_step(ctx: ApplyCtx, capacity=None):
    """Jit-able prefill step ``(params, batch) -> (cache, logits)`` for
    a fixed model context; ``capacity`` pads the KV cache length."""

    def prefill_step(params, batch):
        return prefill(ctx, params, batch, capacity=capacity)

    return prefill_step


def make_serve_step(ctx: ApplyCtx):
    """Jit-able single-token decode step
    ``(params, cache, tokens) -> (cache, logits)``."""

    def serve_step(params, cache, tokens):
        return decode_step(ctx, params, cache, tokens)

    return serve_step


class ServingEngine:
    """Greedy-decoding engine over batch-aligned request groups.

    Concurrency (the CORAL knob ``c``) is modeled as multiple in-flight
    request groups: host-side token sampling/bookkeeping of group i
    overlaps device compute of group j, as on a real serving host.
    """

    def __init__(self, ctx: ApplyCtx, params, batch_size: int, max_len: int):
        self.ctx = ctx
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self._prefill = jax.jit(make_prefill_step(ctx, capacity=max_len))
        self._decode = jax.jit(make_serve_step(ctx))

    def prefill(self, tokens: np.ndarray, extras: Optional[Dict] = None):
        batch = {"tokens": jnp.asarray(tokens)}
        if extras:
            batch.update({k: jnp.asarray(v) for k, v in extras.items()})
        cache, logits = self._prefill(self.params, batch)
        return cache, logits

    def decode(self, cache, tokens):
        """One decode step. Dispatch is asynchronous: the returned
        (cache, logits) are device futures, which is what lets the runtime
        keep ``c`` groups in flight on the device queue."""
        return self._decode(self.params, cache, tokens)

    def generate(
        self,
        prompt: np.ndarray,
        n_tokens: int,
        extras: Optional[Dict] = None,
        temperature: float = 0.0,
        seed: int = 0,
    ) -> np.ndarray:
        cache, logits = self.prefill(prompt, extras)
        key = jax.random.PRNGKey(seed)
        out = []
        tok = self._sample(logits, temperature, key)
        for i in range(n_tokens):
            out.append(np.asarray(tok))
            cache, logits = self._decode(self.params, cache, tok)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, temperature, sub)
        return np.concatenate(out, axis=1)

    @staticmethod
    def _sample(logits, temperature, key):
        if temperature <= 0.0:
            return jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits[:, -1] / temperature, axis=-1
        )[:, None].astype(jnp.int32)

    # NOTE: throughput probing lives in repro.serving.runtime
    # (measure_runtime_throughput / measure_concurrency_curve) so every
    # reported number comes from the same continuous-batching path.
