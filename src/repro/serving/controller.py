"""Closed-loop CORAL over the live serving runtime.

The paper's evaluation loop (Fig. 2) — optimizer proposes a config, the
device applies it, measured (τ, p) feed back — wired to real traffic
instead of a device model: each control interval the controller

  1. applies CORAL's proposed config to the runtime (the concurrency knob
     is applied *for real* via ``set_concurrency``; the DVFS knobs — no
     clock control in this container — are enacted as pacing via
     ``set_rate_scale``, so a down-clocked config genuinely serves slower
     and its backlog genuinely grows; power stays analytical, the same
     split as ``WalltimeDevice``),
  2. releases the next ``interval_s`` worth of workload-trace arrivals
     into the runtime's pool,
  3. serves one wall-clock control interval and observes its windowed
     (τ, p), which CORAL's reward/correlation machinery consumes.

Under a bursty trace the queue builds up during under-provisioned
intervals, so infeasible configs are penalized by what they actually did
to live traffic — not by a model of what they would have done.

With a ``drift_schedule`` the live intervals carry the drift clock
(EXPERIMENTS.md §Drift): each control interval reads the schedule's
operating condition, enacts the derated delivered rate and inflated rail
draw on real traffic, relays commanded budget steps to the optimizer,
and lets CORAL's change-point monitor watch the held config between
exploration epochs.

With a ``network`` the controller tunes an *offload-aware* space
(EXPERIMENTS.md §Offload): the ``offload_frac`` knob is enacted for
real — the runtime's admission pool routes that fraction of requests
to the pod — while the analytical rail model keeps pricing the edge
knobs only (placement dims are stripped, the radio's hold-active draw
is added whenever φ > 0, per-token ship energy is metered live by the
runtime). Offload and drift schedules are mutually exclusive for now:
drifted-rate pacing would double-count the routed fraction.

Over a *cotenant* space (``core.space.cotenant_space`` — per-tenant
``slots_t<k>`` dims beside the shared DVFS knobs, EXPERIMENTS.md
§Multi-tenant) the controller drives one multi-tenant runtime: each
slot dim is enacted on the matching tenant ring in registration order
(``set_slot_allocation``), the shared DVFS knobs pace every ring
alike, and the measured feedback is the *joint headroom* — each ring's
windowed τ over its ``tau_floor``, scalarized by
``core.coral.joint_headroom`` so CORAL's dual mode tunes all tenants
against ``tau_target=1.0`` plus the one shared power cap.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable, Iterable, List, Optional, Tuple

from repro.core.baselines import Outcome
from repro.core.coral import CORAL, joint_headroom
from repro.core.drift import DriftConfig
from repro.core.faults import RobustConfig
from repro.core.space import (
    CONCURRENCY_DIM,
    OFFLOAD_DIM,
    TENANT_SLOT_PREFIX,
    ConfigSpace,
    tenant_slot_indices,
)
from repro.device.hw import (
    DEFAULT_HW,
    DeviceProfile,
    DriftSchedule,
    TPUv5eSpec,
)
from repro.device.measure import analytic_scale_and_power
from repro.serving.runtime import Request, ServingRuntime


@dataclasses.dataclass
class IntervalRecord:
    """One control interval: what was applied and what the traffic saw."""

    config: tuple
    tau: float  # measured tok/s over the interval (joint headroom when
    # the tuned space is cotenant), DVFS-scaled
    power: float  # analytical pod power at this config
    reward: float
    requests_done: int
    queue_depth: int  # backlog left when the interval ended
    p50_latency_s: float
    p99_latency_s: float
    # cotenant spaces only: each ring's windowed tok/s this interval
    tenant_taus: Optional[dict] = None


class ServingController:
    """The closed loop: one CORAL optimizer driving one live runtime.

    Built either from an explicit ``ConfigSpace`` + the hand-wired HW
    constants, or from a ``DeviceProfile`` (the scenario-matrix unit),
    which supplies both. With a ``network`` it tunes an offload-aware
    space: the ``offload_frac`` knob is enacted for real at the
    runtime's admission pool (EXPERIMENTS.md §Offload), and the radio's
    hold-active draw joins the analytical edge-rail power whenever the
    link carries traffic.
    """

    def __init__(
        self,
        runtime: ServingRuntime,
        space: Optional[ConfigSpace],
        workload: Iterable[Request],
        tau_target: float,
        p_budget: float = float("inf"),
        interval_s: float = 0.5,
        hw: TPUv5eSpec = DEFAULT_HW,
        mode: str = "dual",
        seed: int = 0,
        window: int = 10,
        profile: Optional[DeviceProfile] = None,
        drift_schedule: Optional[DriftSchedule] = None,
        drift: Optional[DriftConfig] = None,
        network=None,  # NetworkProfile: attach the edge↔pod uplink
        pod_time_per_token: float = 2e-3,
        robust: Optional[RobustConfig] = None,
        sleeper: Optional[Callable[[float], None]] = None,
    ):
        # An injected device profile supplies both the knob grid and the
        # power-model constants — the serving loop tunes whatever target
        # the scenario matrix describes, not only the hand-wired default.
        if profile is not None:
            hw = profile.hw
            if space is None:
                space = profile.space()
        if space is None:
            raise ValueError("pass a ConfigSpace or a DeviceProfile")
        self.profile = profile
        self.runtime = runtime
        self.space = space
        self.workload = iter(workload)
        self.interval_s = interval_s
        self.hw = hw
        self.tau_target = tau_target
        self.p_budget = p_budget
        # Live intervals carry the drift clock: each control interval is
        # one tick of the schedule, so thermal ramps / co-tenant steps /
        # budget steps land on real traffic at the interval they name.
        # A schedule without an explicit DriftConfig still gets a
        # monitoring-enabled optimizer — drift without detection would
        # silently degrade the held config.
        self.drift_schedule = drift_schedule
        if drift is None and drift_schedule is not None:
            drift = DriftConfig()
        # Hardened mode (EXPERIMENTS.md §Fault tolerance): the optimizer
        # gets the robust ingest gate + telemetry watchdog, and the
        # controller verifies every knob it enacts by readback with
        # bounded retry + exponential backoff. ``sleeper`` is the backoff
        # clock — injectable so tests run without wall-clock sleeps.
        self.robust = robust
        self._sleep = sleeper if sleeper is not None else time.sleep
        self.actuation_failures = 0  # knobs still mismatched after retries
        self.opt = CORAL(
            space,
            tau_target,
            p_budget,
            window=window,
            seed=seed,
            mode=mode,
            drift=drift,
            robust=robust,
        )
        self.records: List[IntervalRecord] = []
        self._pending: Optional[Request] = None
        # Cotenant spaces trade the single concurrency knob for per-tenant
        # slot dims; exactly one of the two shapes is present.
        self._c_index = (
            space.index(CONCURRENCY_DIM)
            if CONCURRENCY_DIM in space.names
            else None
        )
        self._slot_indices = tenant_slot_indices(space)
        if self._slot_indices:
            rings = list(runtime.tenants.values())
            if len(rings) != len(self._slot_indices):
                raise ValueError(
                    f"space has {len(self._slot_indices)} tenant slot dims "
                    f"but the runtime has {len(rings)} tenant rings; "
                    "add_tenant each co-served model before building the "
                    "controller (slot dim k drives ring k in registration "
                    "order)"
                )
            if any(r.tau_floor <= 0.0 for r in rings):
                raise ValueError(
                    "cotenant control scores joint headroom τ_k/floor_k: "
                    "every tenant ring needs a positive tau_floor"
                )
            if drift_schedule is not None:
                raise ValueError(
                    "cotenant serving and device-drift schedules are not "
                    "combined yet; tune one axis at a time"
                )
        # Offload-aware spaces expose the route-fraction knob; when the
        # tuned space carries it, attach the uplink so admission can
        # genuinely ship requests (see ServingRuntime.set_offload).
        self.network = network
        self._phi_index = (
            space.index(OFFLOAD_DIM) if OFFLOAD_DIM in space.names else None
        )
        if self._phi_index is not None and network is None:
            raise ValueError(
                "the tuned space has an offload_frac knob; pass a "
                "NetworkProfile so admission can route to the pod"
            )
        if self._phi_index is not None and drift_schedule is not None:
            raise ValueError(
                "offload-aware serving and device-drift schedules are not "
                "combined yet; tune one axis at a time"
            )
        if network is not None:
            runtime.attach_pod(network, pod_time_per_token=pod_time_per_token)

    def _submit_until(self, horizon_s: float) -> None:
        """Release trace arrivals with offsets inside the next interval."""
        if self._pending is not None:
            pending_at = self._pending.arrival_s
            if pending_at is not None and pending_at > horizon_s:
                return
            self.runtime.submit(self._pending, self._pending.tenant)
            self._pending = None
        for r in self.workload:
            if r.arrival_s is not None and r.arrival_s > horizon_s:
                self._pending = r
                return
            # multi-tenant traces pre-stamp each request's tenant; None
            # lands on the default ring (single-tenant traces unchanged)
            self.runtime.submit(r, r.tenant)

    def _verified_apply(self, setter, getter, value, matches=None) -> bool:
        """Enact one knob and verify it took, by readback.

        Actuation on a real board can silently stick (a driver rejects
        the write, firmware holds the old value); attributing the
        interval's residual to the *commanded* config then poisons the
        correlation window. Write → read back → compare; on mismatch
        retry up to ``robust.act_retries`` times with exponential backoff
        (base ``robust.backoff_s``). Non-robust controllers keep the old
        fire-and-forget single write. Returns whether the readback
        matched; exhausted retries are counted in
        ``actuation_failures`` and the caller attributes to the readback.
        """
        ok = matches if matches is not None else (lambda got: got == value)
        tries = 1 + (self.robust.act_retries if self.robust is not None else 0)
        for attempt in range(tries):
            setter(value)
            if ok(getter()):
                return True
            if self.robust is not None and attempt + 1 < tries:
                self._sleep(self.robust.backoff_s * (2.0 ** attempt))
        self.actuation_failures += 1
        return False

    def control_step(self) -> IntervalRecord:
        """One control interval: propose → apply (concurrency for real,
        DVFS as pacing, placement at admission) → release one interval of
        trace arrivals → serve it on the wall clock → feed the windowed
        (τ, p) back to the optimizer. Returns the interval's record."""
        # the interval index is the drift clock: schedules are defined in
        # control intervals, and each step serves exactly one
        t = len(self.records)
        state = (
            self.drift_schedule.state_at(t)
            if self.drift_schedule is not None
            else None
        )
        if state is not None:
            budget_t = self.p_budget * state.budget_scale
            if budget_t != self.opt.p_budget:
                self.opt.set_p_budget(budget_t)  # commanded, not detected
        cfg = self.opt.next_config()
        names, knob_cfg = self.space.names, cfg
        phi = 0.0
        if self._phi_index is not None:
            # the analytical rail model evaluates the *edge* knobs only:
            # strip the placement dims and pin the host knobs the joint
            # space does not expose at their nominal operating points
            phi = float(cfg[self._phi_index])
            drop = {OFFLOAD_DIM, "pod_tpu_freq"}
            names = [n for n in self.space.names if n not in drop]
            knob_cfg = [
                v for n, v in zip(self.space.names, cfg) if n not in drop
            ]
            names = names + ["host_cpu_freq", "host_cores"]
            knob_cfg = knob_cfg + [self.hw.nominal_host_freq, 6.0]
        slots: List[int] = []
        if self._slot_indices:
            # the shared rail prices total occupancy: strip the per-tenant
            # slot dims and pin concurrency to their sum (host cores fixed
            # at the cotenant twin's operating point, device.cotenant)
            slots = [max(1, int(round(cfg[i]))) for i in self._slot_indices]
            keep = [
                (n, v)
                for n, v in zip(self.space.names, cfg)
                if not n.startswith(TENANT_SLOT_PREFIX)
            ]
            names = [n for n, _ in keep] + ["concurrency", "host_cores"]
            knob_cfg = [v for _, v in keep] + [float(sum(slots)), 6.0]
        dev_rel, power = analytic_scale_and_power(names, knob_cfg, self.hw)
        if self._phi_index is not None:
            # placement is enacted for real at admission; the radio's
            # hold-active draw lands on the edge rail whenever the link
            # carries traffic (per-token ship energy is metered live by
            # the runtime's network_energy_j counter)
            self.runtime.set_offload(phi)
            if phi > 0.0:
                power += self.network.radio_idle_w
        if state is not None and not state.stationary:
            # Enact the drifted operating condition on live traffic: the
            # pacing scale carries the per-level clock derating and the
            # co-tenant's stream contention (host_inflation is not paced —
            # the runtime's host stage is real work, not a dial), and the
            # analytical rail draw carries the extra static power.
            from repro.device.perfmodel import canon

            d = canon(dict(zip(self.space.names, cfg)))
            f_rel = d["tpu_freq"] / self.hw.nominal_tpu_freq
            m_rel = d["hbm_freq"] / self.hw.nominal_hbm_freq
            derate = min(
                1.0 - state.clock_derate * f_rel,
                1.0 - state.mem_derate * m_rel,
            )
            contention = 1.0 + state.kappa_add * (d["concurrency"] - 1.0)
            dev_rel = dev_rel * max(derate, 0.05) / contention
            power = power + state.static_inflation * (
                self.hw.p_idle_chip + self.hw.p_host_idle
            )
        attr_cfg = cfg
        if self._slot_indices:
            # slot dim k drives tenant ring k, in registration order
            alloc = dict(zip(self.runtime.tenants, slots))
            self._verified_apply(
                self.runtime.set_slot_allocation,
                lambda: {
                    n: r.slot_budget for n, r in self.runtime.tenants.items()
                },
                alloc,
            )
        elif self._c_index is not None:
            want_c = max(1, int(cfg[self._c_index]))
            applied = self._verified_apply(
                self.runtime.set_concurrency,
                lambda: self.runtime.concurrency,
                want_c,
            )
            if self.robust is not None and not applied:
                # the knob is stuck: attribute this interval's measurement
                # to the config actually in force, not the commanded one
                attr_cfg = list(cfg)
                attr_cfg[self._c_index] = float(self.runtime.concurrency)
                attr_cfg = tuple(attr_cfg)
        want_scale = min(1.0, max(0.05, float(dev_rel)))
        self._verified_apply(
            self.runtime.set_rate_scale,
            lambda: self.runtime.rate_scale,
            dev_rel,
            matches=lambda got: abs(got - want_scale) < 1e-9,
        )
        self._submit_until(self.runtime.now() + self.interval_s)
        m = self.runtime.run_for(self.interval_s, idle_wait=True)
        tenant_taus = None
        if self._slot_indices:
            # per-ring windowed τ over the just-served interval, scalarized
            # against the rings' floors — CORAL's τ channel is the joint
            # headroom, so dual mode needs no per-tenant plumbing
            tm = self.runtime.tenant_metrics(self.interval_s)
            tenant_taus = {
                n: tm[n]["throughput_tok_s"] for n in self.runtime.tenants
            }
            floors = [
                ring.tau_floor for ring in self.runtime.tenants.values()
            ]
            tau = float(
                joint_headroom(list(tenant_taus.values()), floors)
            )
        else:
            tau = m["throughput_tok_s"]  # pacing already enacted DVFS
        r = self.opt.record(attr_cfg, tau, power)
        rec = IntervalRecord(
            config=tuple(attr_cfg),
            tau=tau,
            power=power,
            reward=r,
            requests_done=int(m["requests"]),
            queue_depth=int(m["queue_depth"]),
            p50_latency_s=m["p50_latency_s"],
            p99_latency_s=m["p99_latency_s"],
            tenant_taus=tenant_taus,
        )
        self.records.append(rec)
        return rec

    def run(self, iters: int = 10) -> Tuple[Outcome, List[IntervalRecord]]:
        """Run ``iters`` control intervals (the paper's 10-measurement
        budget by default) and return CORAL's best feasible pick plus the
        per-interval records."""
        for _ in range(iters):
            self.control_step()
        res = self.opt.result()
        if res is None:
            return Outcome(None, 0.0, 0.0, iters), self.records
        return Outcome(res.config, res.tau, res.power, iters), self.records

    # ------------------------------------------------------------------
    # checkpoint / restore (crash recovery)
    # ------------------------------------------------------------------
    def checkpoint(self) -> dict:
        """JSON-serializable controller state: the full optimizer
        checkpoint (``CORAL.to_checkpoint`` — anchors, history, monitor,
        RNG bit-state) plus the interval ledger. A restarted controller
        built with the same constructor arguments resumes byte-identical
        after ``restore`` (tests/test_faults.py pins the equivalence);
        ``docs/ARCHITECTURE.md`` §Checkpoint format documents the layout.
        """
        return {
            "version": 1,
            "optimizer": self.opt.to_checkpoint(),
            "records": [dataclasses.asdict(r) for r in self.records],
            "actuation_failures": self.actuation_failures,
        }

    def restore(self, ckpt: dict) -> None:
        """Resume from a ``checkpoint()`` dict (or its JSON round-trip)."""
        if ckpt.get("version") != 1:
            raise ValueError(
                f"unknown controller checkpoint version {ckpt.get('version')!r}"
            )
        self.opt.restore(ckpt["optimizer"])
        self.records = [
            IntervalRecord(**{**r, "config": tuple(r["config"])})
            for r in ckpt["records"]
        ]
        self.actuation_failures = int(ckpt["actuation_failures"])

    def save_checkpoint(self, path) -> None:
        """``checkpoint()`` to a file, written atomically (tmp + rename)
        so a crash mid-write can never leave a torn checkpoint behind."""
        import os

        tmp = f"{path}.tmp"
        with open(tmp, "w") as f:
            json.dump(self.checkpoint(), f)
        os.replace(tmp, path)

    def restore_checkpoint(self, path) -> None:
        with open(path) as f:
            self.restore(json.load(f))


def build_serving_record(
    regenerate: str,
    c_values,
    curve,
    rounds: int,
    batch_size: int,
    iters: int,
    interval_s: float,
    tau_target: float,
    p_budget: float,
    outcome: Outcome,
    records: List[IntervalRecord],
    include_intervals: bool = False,
) -> dict:
    """The BENCH_serving.json payload — one schema for every producer
    (benchmarks/serving_bench.py and examples/tune_serving.py), so the
    CI-uploaded artifact's shape does not depend on which ran last."""
    closed = {
        "iters": iters,
        "interval_s": interval_s,
        "tau_target": tau_target,
        "p_budget": p_budget,
        "feasible": outcome.feasible(tau_target, p_budget),
        "config": list(outcome.config) if outcome.config else None,
        "tau": outcome.tau,
        "power": outcome.power,
        "max_queue_depth": max(r.queue_depth for r in records),
    }
    if include_intervals:
        closed["intervals"] = [
            {"config": list(r.config), "tau": r.tau, "power": r.power,
             "reward": r.reward, "queue_depth": r.queue_depth,
             "p99_latency_s": r.p99_latency_s}
            for r in records
        ]
    return {
        "regenerate": regenerate,
        "results": {
            "tau_vs_concurrency": {
                "concurrency": list(c_values),
                "tok_s": [curve[c] for c in c_values],
                "gain_best_c_vs_c1": (
                    max(curve[c] for c in c_values[1:]) / curve[c_values[0]]
                ),
                "batch_size": batch_size,
                "rounds_best_of": rounds,
            },
            "closed_loop_bursty": closed,
        },
    }
