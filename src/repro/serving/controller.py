"""Closed-loop CORAL over the live serving runtime.

The paper's evaluation loop (Fig. 2) — optimizer proposes a config, the
device applies it, measured (τ, p) feed back — wired to real traffic
instead of a device model: each control interval the controller

  1. applies CORAL's proposed config to the runtime (the concurrency knob
     is applied *for real* via ``set_concurrency``; the DVFS knobs — no
     clock control in this container — are enacted as pacing via
     ``set_rate_scale``, so a down-clocked config genuinely serves slower
     and its backlog genuinely grows; power stays analytical, the same
     split as ``WalltimeDevice``),
  2. releases the next ``interval_s`` worth of workload-trace arrivals
     into the runtime's pool,
  3. serves one wall-clock control interval and observes its windowed
     (τ, p), which CORAL's reward/correlation machinery consumes.

Under a bursty trace the queue builds up during under-provisioned
intervals, so infeasible configs are penalized by what they actually did
to live traffic — not by a model of what they would have done.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Tuple

from repro.core.baselines import Outcome
from repro.core.coral import CORAL
from repro.core.space import CONCURRENCY_DIM, ConfigSpace
from repro.device.hw import DEFAULT_HW, DeviceProfile, TPUv5eSpec
from repro.device.measure import analytic_scale_and_power
from repro.serving.runtime import Request, ServingRuntime


@dataclasses.dataclass
class IntervalRecord:
    """One control interval: what was applied and what the traffic saw."""

    config: tuple
    tau: float  # measured tok/s over the interval, DVFS-scaled
    power: float  # analytical pod power at this config
    reward: float
    requests_done: int
    queue_depth: int  # backlog left when the interval ended
    p50_latency_s: float
    p99_latency_s: float


class ServingController:
    def __init__(
        self,
        runtime: ServingRuntime,
        space: Optional[ConfigSpace],
        workload: Iterable[Request],
        tau_target: float,
        p_budget: float = float("inf"),
        interval_s: float = 0.5,
        hw: TPUv5eSpec = DEFAULT_HW,
        mode: str = "dual",
        seed: int = 0,
        window: int = 10,
        profile: Optional[DeviceProfile] = None,
    ):
        # An injected device profile supplies both the knob grid and the
        # power-model constants — the serving loop tunes whatever target
        # the scenario matrix describes, not only the hand-wired default.
        if profile is not None:
            hw = profile.hw
            if space is None:
                space = profile.space()
        if space is None:
            raise ValueError("pass a ConfigSpace or a DeviceProfile")
        self.profile = profile
        self.runtime = runtime
        self.space = space
        self.workload = iter(workload)
        self.interval_s = interval_s
        self.hw = hw
        self.tau_target = tau_target
        self.p_budget = p_budget
        self.opt = CORAL(
            space, tau_target, p_budget, window=window, seed=seed, mode=mode
        )
        self.records: List[IntervalRecord] = []
        self._pending: Optional[Request] = None
        self._c_index = space.index(CONCURRENCY_DIM)

    def _submit_until(self, horizon_s: float) -> None:
        """Release trace arrivals with offsets inside the next interval."""
        if self._pending is not None:
            if self._pending.arrival_s is not None and self._pending.arrival_s > horizon_s:
                return
            self.runtime.submit(self._pending)
            self._pending = None
        for r in self.workload:
            if r.arrival_s is not None and r.arrival_s > horizon_s:
                self._pending = r
                return
            self.runtime.submit(r)

    def control_step(self) -> IntervalRecord:
        cfg = self.opt.propose()
        dev_rel, power = analytic_scale_and_power(self.space.names, cfg, self.hw)
        self.runtime.set_concurrency(int(cfg[self._c_index]))
        self.runtime.set_rate_scale(dev_rel)
        self._submit_until(self.runtime.now() + self.interval_s)
        m = self.runtime.run_for(self.interval_s, idle_wait=True)
        tau = m["throughput_tok_s"]  # pacing already enacted the DVFS scale
        r = self.opt.observe(cfg, tau, power)
        rec = IntervalRecord(
            config=tuple(cfg),
            tau=tau,
            power=power,
            reward=r,
            requests_done=int(m["requests"]),
            queue_depth=int(m["queue_depth"]),
            p50_latency_s=m["p50_latency_s"],
            p99_latency_s=m["p99_latency_s"],
        )
        self.records.append(rec)
        return rec

    def run(self, iters: int = 10) -> Tuple[Outcome, List[IntervalRecord]]:
        for _ in range(iters):
            self.control_step()
        res = self.opt.result()
        if res is None:
            return Outcome(None, 0.0, 0.0, iters), self.records
        return Outcome(res.config, res.tau, res.power, iters), self.records


def build_serving_record(
    regenerate: str,
    c_values,
    curve,
    rounds: int,
    batch_size: int,
    iters: int,
    interval_s: float,
    tau_target: float,
    p_budget: float,
    outcome: Outcome,
    records: List[IntervalRecord],
    include_intervals: bool = False,
) -> dict:
    """The BENCH_serving.json payload — one schema for every producer
    (benchmarks/serving_bench.py and examples/tune_serving.py), so the
    CI-uploaded artifact's shape does not depend on which ran last."""
    closed = {
        "iters": iters,
        "interval_s": interval_s,
        "tau_target": tau_target,
        "p_budget": p_budget,
        "feasible": outcome.feasible(tau_target, p_budget),
        "config": list(outcome.config) if outcome.config else None,
        "tau": outcome.tau,
        "power": outcome.power,
        "max_queue_depth": max(r.queue_depth for r in records),
    }
    if include_intervals:
        closed["intervals"] = [
            {"config": list(r.config), "tau": r.tau, "power": r.power,
             "reward": r.reward, "queue_depth": r.queue_depth,
             "p99_latency_s": r.p99_latency_s}
            for r in records
        ]
    return {
        "regenerate": regenerate,
        "results": {
            "tau_vs_concurrency": {
                "concurrency": list(c_values),
                "tok_s": [curve[c] for c in c_values],
                "gain_best_c_vs_c1": (
                    max(curve[c] for c in c_values[1:]) / curve[c_values[0]]
                ),
                "batch_size": batch_size,
                "rounds_best_of": rounds,
            },
            "closed_loop_bursty": closed,
        },
    }
