"""Workload traces for the serving runtime.

Each generator returns a list of `Request`s whose ``arrival_s`` offsets
(seconds from the runtime clock start) follow a named arrival process:

  steady          — fixed inter-arrival gap (closed-form rate).
  bursty_poisson  — two-state Markov-modulated Poisson: calm and burst
                    phases alternate every ``phase_s`` seconds, with the
                    burst rate ``burst_factor``× the calm rate; the mean
                    rate stays ≈ ``rate``. The backlog built in bursts is
                    what the concurrency knob has to absorb.
  diurnal         — inhomogeneous Poisson with a sinusoidal rate (period
                    ``period_s``), the load-shape analogue of day/night
                    traffic, sampled by thinning.

Prompt lengths are drawn from ``prompt_lens`` (keep this set small — each
distinct length compiles one prefill shape) and output lengths uniformly
from ``new_tokens`` when a (lo, hi) tuple is given.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple, Union

import numpy as np

from repro.serving.runtime import Request

Lens = Union[int, Sequence[int]]
NewTokens = Union[int, Tuple[int, int]]


def _materialize(
    times: Sequence[float],
    rng: np.random.Generator,
    prompt_lens: Lens,
    new_tokens: NewTokens,
    vocab: int,
    rid0: int,
) -> List[Request]:
    lens = (prompt_lens,) if isinstance(prompt_lens, int) else tuple(prompt_lens)
    out = []
    for i, t in enumerate(times):
        length = int(rng.choice(lens))
        if isinstance(new_tokens, tuple):
            n = int(rng.integers(new_tokens[0], new_tokens[1] + 1))
        else:
            n = int(new_tokens)
        out.append(
            Request(
                rid0 + i,
                rng.integers(0, vocab, length, dtype=np.int32),
                n,
                arrival_s=float(t),
            )
        )
    return out


def steady(
    rate: float,
    duration_s: float,
    prompt_lens: Lens = 16,
    new_tokens: NewTokens = 8,
    vocab: int = 512,
    seed: int = 0,
    rid0: int = 0,
) -> List[Request]:
    """Deterministic constant-rate arrivals: one request every
    ``1/rate`` seconds for ``duration_s``."""
    rng = np.random.default_rng(seed)
    times = np.arange(0.0, duration_s, 1.0 / rate)
    return _materialize(times, rng, prompt_lens, new_tokens, vocab, rid0)


def bursty_poisson(
    rate: float,
    duration_s: float,
    burst_factor: float = 4.0,
    phase_s: float = 0.5,
    prompt_lens: Lens = 16,
    new_tokens: NewTokens = 8,
    vocab: int = 512,
    seed: int = 0,
    rid0: int = 0,
) -> List[Request]:
    """MMPP-style bursty trace: Poisson arrivals alternating between a
    calm and a ``burst_factor``× rate every ``phase_s`` seconds, with
    the duty cycle averaging back to ``rate``."""
    rng = np.random.default_rng(seed)
    # calm/burst rates chosen so the 50% duty cycle averages back to `rate`
    calm = 2.0 * rate / (1.0 + burst_factor)
    times = []
    t = float(rng.exponential(1.0 / rate))
    while t < duration_s:
        times.append(t)
        in_burst = int(t / phase_s) % 2 == 1
        lam = calm * burst_factor if in_burst else calm
        t += float(rng.exponential(1.0 / lam))
    return _materialize(times, rng, prompt_lens, new_tokens, vocab, rid0)


def diurnal(
    rate: float,
    duration_s: float,
    period_s: float = 4.0,
    depth: float = 0.8,
    prompt_lens: Lens = 16,
    new_tokens: NewTokens = 8,
    vocab: int = 512,
    seed: int = 0,
    rid0: int = 0,
) -> List[Request]:
    """Sinusoidally modulated Poisson trace (a compressed diurnal
    cycle): rate swings ±``depth`` around ``rate`` with period
    ``period_s``, sampled by thinning."""
    rng = np.random.default_rng(seed)
    lam_max = rate * (1.0 + depth)
    times = []
    t = 0.0
    while True:  # thinning: homogeneous candidates at lam_max, accept at λ(t)/lam_max
        t += float(rng.exponential(1.0 / lam_max))
        if t >= duration_s:
            break
        lam_t = rate * (1.0 + depth * np.sin(2.0 * np.pi * t / period_s))
        if rng.uniform() * lam_max <= lam_t:
            times.append(t)
    return _materialize(times, rng, prompt_lens, new_tokens, vocab, rid0)
