"""Back-compat facade over the continuous-batching runtime.

The original ``Scheduler`` drained its queue strictly sequentially (the
concurrency knob was a no-op) and padded/clipped every request in a group
to the first request's prompt length, silently truncating longer prompts.
Both are fixed by ``repro.serving.runtime.ServingRuntime``: groups are
formed from equal-length requests and ``concurrency`` decode groups
genuinely pipeline on the device queue. This module keeps the old
submit/run surface for existing callers (``repro.launch.serve``, tests).
"""
from __future__ import annotations

from typing import Dict

from repro.serving.runtime import Request, ServingRuntime  # noqa: F401 (re-export)


class Scheduler:
    def __init__(self, engine, batch_size: int, concurrency: int = 1):
        self.engine = engine
        self.runtime = ServingRuntime(
            engine, batch_size=batch_size, concurrency=concurrency
        )

    # live views of the runtime, not construction-time copies — the old
    # Scheduler honored `sched.concurrency = c` between runs, so the
    # facade must too rather than silently pinning the initial value
    @property
    def batch_size(self) -> int:
        return self.runtime.batch

    @property
    def concurrency(self) -> int:
        return self.runtime.concurrency

    @concurrency.setter
    def concurrency(self, c: int) -> None:
        self.runtime.set_concurrency(c)

    @property
    def done(self):
        return self.runtime.done

    def submit(self, req: Request) -> None:
        self.runtime.submit(req)

    def run(self) -> Dict[str, float]:
        """Drain the queue; returns aggregate serving metrics."""
        return self.runtime.drain()
