"""Request scheduler: groups incoming requests into batch-aligned decode
groups and runs ``concurrency`` groups in flight — the application-level
knob the paper tunes (§II-A "Concurrency level")."""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (prompt_len,)
    max_new_tokens: int
    arrived: float = dataclasses.field(default_factory=time.monotonic)
    output: Optional[np.ndarray] = None
    finished: float = 0.0


class Scheduler:
    """FIFO batcher: pulls up to ``batch_size`` same-length requests per
    group; ``concurrency`` groups are processed round-robin so host work
    overlaps device work (the engine pipelines on the device queue)."""

    def __init__(self, engine, batch_size: int, concurrency: int = 1):
        self.engine = engine
        self.batch_size = batch_size
        self.concurrency = max(1, concurrency)
        self.queue: Deque[Request] = collections.deque()
        self.done: List[Request] = []

    def submit(self, req: Request):
        self.queue.append(req)

    def _next_group(self) -> Optional[List[Request]]:
        if not self.queue:
            return None
        group = [self.queue.popleft() for _ in range(min(self.batch_size, len(self.queue)))]
        # pad group to batch_size by repeating the last request's shape
        return group

    def run(self) -> Dict[str, float]:
        """Drain the queue; returns aggregate serving metrics."""
        t0 = time.monotonic()
        n_tokens = 0
        groups = []
        while True:
            g = self._next_group()
            if g is None:
                break
            groups.append(g)
        # round-robin over `concurrency` groups at a time
        for i in range(0, len(groups), self.concurrency):
            inflight = groups[i : i + self.concurrency]
            for g in inflight:
                prompts = np.stack(
                    [
                        np.pad(r.prompt, (0, max(0, g[0].prompt.size - r.prompt.size)))[
                            : g[0].prompt.size
                        ]
                        for r in g
                    ]
                )
                if prompts.shape[0] < self.batch_size:
                    prompts = np.pad(
                        prompts,
                        ((0, self.batch_size - prompts.shape[0]), (0, 0)),
                    )
                out = self.engine.generate(prompts, g[0].max_new_tokens)
                for j, r in enumerate(g):
                    r.output = out[j]
                    r.finished = time.monotonic()
                    n_tokens += out.shape[1]
                self.done.extend(g)
        wall = time.monotonic() - t0
        lat = [r.finished - r.arrived for r in self.done] or [0.0]
        return {
            "throughput_tok_s": n_tokens / max(wall, 1e-9),
            "p50_latency_s": float(np.percentile(lat, 50)),
            "p99_latency_s": float(np.percentile(lat, 99)),
            "requests": len(self.done),
        }
