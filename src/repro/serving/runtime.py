"""Continuous-batching serving runtime — per-tenant decode rings over a
shared slot pool.

The paper (§II-A) tunes concurrency as a first-class resource knob, which
only means anything if ``c`` in-flight decode groups genuinely pipeline.
This runtime serves one or more *tenants* — each a (model engine,
workload trace, τ-floor) triple with its own admission queue, decode
ring and windowed metrics — over shared DVFS pacing and one shared
power rail:

  * each tenant ring holds a request pool with arrival-time admission —
    requests carry an ``arrival_s`` offset (seconds from the shared
    runtime clock start, produced by ``repro.serving.workload`` traces)
    and are only eligible once the serving clock passes it;
  * a ring owns ``slot_budget`` decode *slots*, each holding a
    batch-aligned group with its own KV cache. Slots are visited in ring
    order, and each visit retires the slot's outstanding logits
    (host-side sampling + per-row bookkeeping) and immediately
    re-dispatches its next decode. Because dispatch is asynchronous,
    blocking on slot i's logits happens while the decodes of every other
    in-flight slot — *across all tenants* — are already queued on the
    device: host work overlaps device work, and throughput rises with
    total slots until the device queue saturates (the paper's Fig. 1
    knee). Granting one tenant a slot genuinely slows the others: their
    decodes queue behind it, which is the live analogue of the twin's
    stream-contention kappa (``device.cotenant``);
  * slot refill on completion: rows that reach ``max_new_tokens`` are
    masked out, and when a group's last row finishes the slot re-admits
    a new group from its tenant's pool (group-granularity refill: the KV
    cache keeps one shared ``length`` per group, so rows cannot be
    swapped individually — documented deviation from per-sequence
    refill);
  * rolling-window and per-control-interval (τ, latency) metrics per
    tenant, plus the aggregate — ``run_for`` serves one control interval
    and reports what happened inside it, which is what the closed-loop
    CORAL controller observes; ``tenant_metrics`` exposes the per-ring
    split the multi-tenant controller scores against per-tenant floors;
  * one shared rail: DVFS pacing (``set_rate_scale``) stretches every
    tenant's pass — there is one clock domain — and ``attribute_power``
    splits a measured/modelled rail draw across tenants in proportion to
    their windowed token throughput, summing exactly to the rail total.

A runtime built the old way (``ServingRuntime(engine, ...)``) is the
single-tenant special case: one default ring, with the historical
surface (``waiting`` / ``done`` / ``slots`` / ``submit`` / ``drain``)
delegating to it unchanged. ``add_tenant`` adds rings — each may carry
its *own* engine (a different registry model) — and
``set_slot_allocation`` is the live per-tenant slot knob the joint
CORAL config drives.

Groups are formed from same-prompt-length requests only (no padding to a
neighbour's length), which fixes the old scheduler's silent truncation of
prompts longer than the group head's.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Mapping, Optional, Tuple

import numpy as np

# The single-tenant compatibility ring every runtime starts with.
DEFAULT_TENANT = "default"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (prompt_len,)
    max_new_tokens: int
    arrival_s: Optional[float] = None  # offset from clock start; None = now
    arrived: float = dataclasses.field(default_factory=time.monotonic)
    started: float = 0.0  # prefill dispatch time
    finished: float = 0.0
    tokens: List[int] = dataclasses.field(default_factory=list)
    output: Optional[np.ndarray] = None
    # placement, decided once at admission: None until the request becomes
    # admissible, then "edge" (local slots) or "pod" (shipped upstream)
    route: Optional[str] = None
    # owning tenant ring, stamped at submit
    tenant: Optional[str] = None


class _Slot:
    """One in-flight decode group: KV cache + outstanding logits future."""

    __slots__ = ("group", "cache", "logits", "live", "remaining")

    def __init__(self):
        self.group: Optional[List[Request]] = None
        self.cache = None
        self.logits = None
        self.live: List[bool] = []
        self.remaining: List[int] = []


class _TenantRing:
    """One tenant: admission queue, decode slots, windowed metrics.

    The ring owns everything per-tenant — its engine (model), batch
    shape, slot budget, τ-floor, pools and token/event accounting — and
    borrows the shared pieces (clock, pacing, pod seam) from the owning
    ``ServingRuntime``.
    """

    def __init__(
        self,
        name: str,
        runtime: "ServingRuntime",
        engine,
        batch_size: Optional[int] = None,
        slots: int = 1,
        tau_floor: float = 0.0,
    ):
        self.name = name
        self.rt = runtime
        self.engine = engine
        self.batch = int(batch_size or engine.batch)
        self.slot_budget = max(1, int(slots))
        self.tau_floor = float(tau_floor)
        self.waiting: List[Request] = []
        self.done: List[Request] = []
        self.slots: List[_Slot] = []
        self._events: Deque[Tuple[float, int]] = collections.deque()
        self._tokens_total = 0
        self.steps = 0
        self.prefills = 0

    # -------------------------------------------------------------- pool
    def _form_group(self) -> Optional[List[Request]]:
        """FIFO group of admissible requests sharing the head's prompt
        length — equal-length grouping, never pad/clip to another
        request's shape. Pod-routed requests never appear here:
        ``_route_admissible`` removed them from the pool at admission."""
        now = self.rt.now()
        length = None
        picked: List[Request] = []
        for r in self.waiting:
            if r.arrival_s is not None and r.arrival_s > now:
                continue
            if length is None:
                length = r.prompt.size
            if r.prompt.size == length:
                picked.append(r)
                if len(picked) == self.batch:
                    break
        if not picked:
            return None
        ids = {id(r) for r in picked}
        self.waiting = [r for r in self.waiting if id(r) not in ids]
        return picked

    # ---------------------------------------------------------- pipeline
    def _start_group(self, slot: _Slot, group: List[Request]) -> None:
        prompts = np.stack([r.prompt for r in group])
        if len(group) < self.batch:
            prompts = np.pad(prompts, ((0, self.batch - len(group)), (0, 0)))
        t = time.monotonic()
        for r in group:
            r.started = t
        # async dispatch: the prefill (and its first logits) queue behind
        # whatever the other slots — every tenant's — already have in
        # flight. The last-position slice is dispatched here, not at
        # retire: retire must only ever *transfer* a ready buffer — a
        # sliced read there would enqueue a fresh device op behind every
        # other slot's in-flight decode and serialize the whole ring.
        slot.cache, logits = self.engine.prefill(prompts)
        slot.logits = logits[:, -1:]
        slot.group = group
        slot.live = [True] * len(group)
        slot.remaining = [max(1, int(r.max_new_tokens)) for r in group]
        self.prefills += 1

    def _retire(self, slot: _Slot) -> None:
        """Host stage: block on this slot's logits, sample greedily on the
        host, account tokens/completions, then dispatch the next decode."""
        # (B, 1, vocab) device→host copy: blocks on *this slot's* buffer
        # only (a pure transfer skips the execute queue, so the other
        # slots' decodes keep running underneath the host work)
        lg = np.asarray(slot.logits)
        tok = lg[:, -1].argmax(axis=-1).astype(np.int32)  # host-side sampling
        t = time.monotonic()
        n_live = 0
        for j, r in enumerate(slot.group):
            if not slot.live[j]:
                continue
            r.tokens.append(int(tok[j]))
            slot.remaining[j] -= 1
            n_live += 1
            if slot.remaining[j] == 0:
                slot.live[j] = False
                r.finished = t
                r.output = np.asarray(r.tokens, np.int32)
                self.done.append(r)
        self._record(t, n_live)
        self.steps += 1
        if any(slot.live):
            slot.cache, slot.logits = self.engine.decode(slot.cache, tok[:, None])
        else:
            slot.group = None
            slot.cache = slot.logits = None

    def step_pass(self) -> bool:
        """One ring pass over this tenant's slots: refill idle slots from
        its pool, retire+redispatch active ones. Returns False when
        nothing could progress."""
        progressed = False
        active = [s for s in self.slots if s.group is not None]
        idle = [s for s in self.slots if s.group is None]
        self.slots = active + idle[: max(0, self.slot_budget - len(active))]
        while len(self.slots) < self.slot_budget:
            self.slots.append(_Slot())
        for slot in self.slots:
            if slot.group is None:
                group = self._form_group()
                if group:
                    self._start_group(slot, group)
                    progressed = True
                continue
            self._retire(slot)
            progressed = True
        return progressed

    # ----------------------------------------------------------- metrics
    def _record(self, t: float, n_tokens: int) -> None:
        self._tokens_total += n_tokens
        self._events.append((t, n_tokens))
        horizon = t - max(4.0 * self.rt.window_s, 10.0)
        while self._events and self._events[0][0] < horizon:
            self._events.popleft()

    def window_tokens(self, window_s: Optional[float] = None) -> int:
        w = window_s or self.rt.window_s
        now = time.monotonic()
        return sum(n for t, n in self._events if t >= now - w)

    def metrics_window(
        self, window_s: Optional[float] = None
    ) -> Dict[str, float]:
        """This tenant's rolling-window metrics: its own completions,
        queue and in-flight groups only — one tenant's burst never lands
        in a neighbour's record (tests/test_serving_runtime.py pins the
        isolation)."""
        w = window_s or self.rt.window_s
        now = time.monotonic()
        tokens = self.window_tokens(w)
        span = w if self.rt._t0 is None else min(w, now - self.rt._t0)
        reqs = [r for r in self.done if r.finished >= now - w]
        lat = [r.finished - self.rt._effective_arrival(r) for r in reqs] or [
            0.0
        ]
        return {
            "throughput_tok_s": tokens / max(span, 1e-9),
            "p50_latency_s": float(np.percentile(lat, 50)),
            "p99_latency_s": float(np.percentile(lat, 99)),
            "requests": len(reqs),
            "queue_depth": len(self.waiting),
            "in_flight": sum(s.group is not None for s in self.slots),
            "tau_floor": self.tau_floor,
            "interval_s": span,
        }


class ServingRuntime:
    def __init__(
        self,
        engine,
        batch_size: Optional[int] = None,
        concurrency: int = 1,
        window_s: float = 2.0,
    ):
        self.engine = engine
        self.window_s = window_s
        self._t0: Optional[float] = None
        self.rate_scale = 1.0
        # per-tenant decode rings over the shared pool, insertion-ordered;
        # the constructor's engine/batch/concurrency become the default
        # (single-tenant compatibility) ring
        self.tenants: Dict[str, _TenantRing] = {}
        self._default = self.add_tenant(
            DEFAULT_TENANT,
            engine=engine,
            batch_size=batch_size,
            slots=concurrency,
        )
        # ---- edge↔pod offload seam (attach_pod / set_offload) ----------
        self.pod_network = None  # repro.device.network.NetworkProfile
        self.pod_time_per_token = 0.0
        self.pod_timeout_s = 30.0  # shipped-request deadline (attach_pod)
        self.pod_outage = False  # link down: responses lost until cleared
        self.offload_frac = 0.0
        self._route_acc = 0.0  # deterministic fractional-routing carry
        # (done_at, deadline, request, owning ring)
        self._pod_inflight: List[Tuple[float, float, Request, _TenantRing]] = []
        self.pod_tokens_total = 0
        self.pod_expired = 0  # shipped requests that hit the deadline
        self.network_energy_j = 0.0

    # ------------------------------------------------------------------
    # tenants
    # ------------------------------------------------------------------
    def add_tenant(
        self,
        name: str,
        engine=None,
        batch_size: Optional[int] = None,
        slots: int = 1,
        tau_floor: float = 0.0,
    ) -> _TenantRing:
        """Register a tenant ring. ``engine`` defaults to the runtime's
        (same model); pass a different compiled engine to serve a second
        registry model on the same rail. ``slots`` is the ring's share of
        the decode-slot pool and ``tau_floor`` its τ SLO, both live knobs
        afterwards (``set_slot_allocation``)."""
        if name in self.tenants:
            raise ValueError(f"tenant {name!r} already registered")
        ring = _TenantRing(
            name,
            self,
            engine if engine is not None else self.engine,
            batch_size=batch_size,
            slots=slots,
            tau_floor=tau_floor,
        )
        self.tenants[name] = ring
        return ring

    def ring(self, tenant: Optional[str] = None) -> _TenantRing:
        """The named tenant's ring (default ring when ``tenant`` is None)."""
        return self.tenants[DEFAULT_TENANT if tenant is None else tenant]

    def set_slot_allocation(self, alloc: Mapping[str, int]) -> None:
        """Live per-tenant slot knob: ``{tenant: slots}``. Growth adds
        idle slots on the ring's next pass; shrink lets excess groups
        finish and then drops their slots (no preemption) — the same
        semantics the single-tenant ``set_concurrency`` always had."""
        for name, c in alloc.items():
            self.tenants[name].slot_budget = max(1, int(c))

    # ------------------------------------------------------------------
    # single-tenant compatibility surface (delegates to the default ring)
    # ------------------------------------------------------------------
    @property
    def batch(self) -> int:
        return self._default.batch

    @property
    def concurrency(self) -> int:
        return self._default.slot_budget

    @concurrency.setter
    def concurrency(self, c: int) -> None:
        self._default.slot_budget = max(1, int(c))

    @property
    def waiting(self) -> List[Request]:
        return self._default.waiting

    @property
    def done(self) -> List[Request]:
        return self._default.done

    @property
    def slots(self) -> List[_Slot]:
        return self._default.slots

    @property
    def steps(self) -> int:
        return sum(r.steps for r in self.tenants.values())

    @property
    def prefills(self) -> int:
        return sum(r.prefills for r in self.tenants.values())

    def set_concurrency(self, c: int) -> None:
        """Live knob: target number of in-flight decode groups on the
        *default* ring (the single-tenant special case; multi-tenant
        callers use ``set_slot_allocation``)."""
        self._default.slot_budget = max(1, int(c))

    # ------------------------------------------------------------------
    # clock & admission
    # ------------------------------------------------------------------
    def start_clock(self) -> None:
        if self._t0 is None:
            self._t0 = time.monotonic()

    def now(self) -> float:
        """Seconds since the serving clock started (starts it on first use)."""
        self.start_clock()
        return time.monotonic() - self._t0

    def submit(self, req: Request, tenant: Optional[str] = None) -> None:
        ring = self.ring(tenant)
        req.tenant = ring.name
        ring.waiting.append(req)

    def set_rate_scale(self, scale: float) -> None:
        """DVFS emulation: pace the serving loop to ``scale``× its natural
        rate (this container has no clock control, so reduced clocks are
        enacted as a pass-level pacing sleep — the queue then genuinely
        builds up under slow configs, which is what the closed-loop
        controller's latency/backlog signals feed on). One clock domain:
        the pace stretches every tenant's pass alike."""
        self.rate_scale = min(1.0, max(0.05, float(scale)))

    # ------------------------------------------------------------------
    # edge↔pod offload seam
    # ------------------------------------------------------------------
    def attach_pod(
        self,
        network,
        pod_time_per_token: float = 2e-3,
        timeout_s: float = 30.0,
    ) -> None:
        """Attach the uplink to the pod slice: ``network`` is a
        ``repro.device.network.NetworkProfile`` and ``pod_time_per_token``
        the slice's per-token decode service time. Until ``set_offload``
        raises the route fraction above 0, everything still runs locally.
        ``timeout_s`` is the per-request response deadline: a shipped
        request whose reply has not landed by then is re-admitted to its
        owning ring and served locally (no silent leak).
        """
        self.pod_network = network
        self.pod_time_per_token = float(pod_time_per_token)
        self.pod_timeout_s = float(timeout_s)

    def set_pod_outage(self, active: bool) -> None:
        """Live fault knob: while the link is down, no new request ships
        (admissions run locally) and responses stop arriving — in-flight
        shipped requests sit until their deadline and are then re-admitted
        to the edge. Clearing the outage before a request's deadline lets
        its response land normally."""
        self.pod_outage = bool(active)

    def set_offload(self, frac: float) -> None:
        """Live placement knob: the fraction of *admitted* requests routed
        to the pod. Routing is decided once per request at admission by a
        deterministic fractional accumulator (no RNG: every 1/frac-th
        admissible request ships), so two runs with the same trace and
        knob settings route identically."""
        self.offload_frac = min(1.0, max(0.0, float(frac)))

    def _ship_to_pod(self, r: Request, t: float, ring: _TenantRing) -> None:
        """Ship one request over the attached uplink. End-to-end latency
        is network + remote service: upload serialization + one RTT + the
        pod slice's per-token decode time. The radio energy meter charges
        per shipped token (prompt up, generated tokens down) — the only
        place pod-routed work ever touches the edge power rail. The local
        engine is never invoked for shipped requests."""
        net = self.pod_network
        n_tok = int(r.prompt.size) + int(r.max_new_tokens)
        upload_s = int(r.prompt.size) * net.token_bytes / net.bandwidth
        done_at = (
            t
            + upload_s
            + net.rtt_s
            + int(r.max_new_tokens) * self.pod_time_per_token
        )
        self.network_energy_j += n_tok * net.ship_energy_per_token_j
        self.pod_tokens_total += int(r.max_new_tokens)
        r.started = t
        deadline = t + max(self.pod_timeout_s, done_at - t)
        self._pod_inflight.append((done_at, deadline, r, ring))

    def _route_admissible(self, t: float) -> bool:
        """Admission-time placement: walk every ring's pool once, decide
        edge vs pod for each newly-admissible request, and ship the
        pod-routed ones. Requests stay route="edge" forever once
        committed — the accumulator only advances on first admission, so
        later knob changes affect later arrivals only. One accumulator
        across tenants: the route fraction is a property of the shared
        uplink, not of any one ring."""
        if self.pod_network is None or self.pod_outage:
            # link absent or down: requests stay route=None and the rings
            # serve them locally; the accumulator holds so the route
            # fraction resumes cleanly when the link returns
            return False
        now = self.now()
        progressed = False
        for ring in self.tenants.values():
            shipped: List[Request] = []
            for r in ring.waiting:
                if r.route is not None:
                    continue
                if r.arrival_s is not None and r.arrival_s > now:
                    continue
                self._route_acc += self.offload_frac
                if self._route_acc >= 1.0 - 1e-12:
                    self._route_acc -= 1.0
                    r.route = "pod"
                    shipped.append(r)
                else:
                    r.route = "edge"
            if not shipped:
                continue
            ids = {id(r) for r in shipped}
            ring.waiting = [r for r in ring.waiting if id(r) not in ids]
            for r in shipped:
                self._ship_to_pod(r, t, ring)
            progressed = True
        return progressed

    def _poll_pod(self, t: float) -> bool:
        """Retire pod-routed requests whose (network + remote service)
        completion time has passed, and expire the ones whose deadline
        has. Completion is token-accounted like a local retire — on the
        owning tenant's ring — so windowed throughput/latency metrics see
        pod traffic, including its network latency, on equal terms.
        Expired requests (deadline passed with no response — a dead link
        or a stalled pod) are re-admitted to their owning ring pinned to
        the edge route, so nothing the runtime accepted is ever leaked."""
        if not self._pod_inflight:
            return False
        keep: List[Tuple[float, float, Request, _TenantRing]] = []
        due: List[Tuple[float, float, Request, _TenantRing]] = []
        expired: List[Tuple[float, float, Request, _TenantRing]] = []
        for e in self._pod_inflight:
            if not self.pod_outage and e[0] <= t:
                due.append(e)
            elif e[1] <= t:
                expired.append(e)
            else:
                keep.append(e)
        if not due and not expired:
            return False
        self._pod_inflight = keep
        for done_at, _, r, ring in sorted(due, key=lambda e: e[0]):
            r.finished = done_at
            r.tokens = [0] * int(r.max_new_tokens)
            r.output = np.zeros(int(r.max_new_tokens), np.int32)
            ring.done.append(r)
            ring._record(done_at, int(r.max_new_tokens))
        for _, _, r, ring in expired:
            # pin to the edge so the retry cannot bounce back to a dead
            # link — the local ring serves it on its next pass
            r.route = "edge"
            r.tokens = []
            self.pod_expired += 1
            ring.waiting.append(r)
        return True

    # ------------------------------------------------------------------
    # the shared pass
    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One pass over every tenant's ring: route/poll the pod seam,
        then each ring refills idle slots from its own pool and
        retires+redispatches active ones. Returns False when nothing
        could progress (all rings idle and no admissible request).
        Pacing is applied once, to the whole pass — shared DVFS means
        one clock domain for every tenant."""
        self.start_clock()
        t_pass = time.monotonic()
        progressed = self._route_admissible(t_pass)
        progressed |= self._poll_pod(t_pass)
        for ring in self.tenants.values():
            progressed |= ring.step_pass()
        if progressed and self.rate_scale < 1.0:
            # stretch the pass to 1/scale of its natural duration
            time.sleep((1.0 / self.rate_scale - 1.0) * (time.monotonic() - t_pass))
        return progressed

    # ------------------------------------------------------------------
    # metrics
    # ------------------------------------------------------------------
    def _effective_arrival(self, r: Request) -> float:
        if r.arrival_s is not None and self._t0 is not None:
            return self._t0 + r.arrival_s
        return r.arrived

    def _metrics(
        self, reqs: List[Request], tokens: int, span: float
    ) -> Dict[str, float]:
        lat = [r.finished - self._effective_arrival(r) for r in reqs] or [0.0]
        return {
            "throughput_tok_s": tokens / max(span, 1e-9),
            "p50_latency_s": float(np.percentile(lat, 50)),
            "p99_latency_s": float(np.percentile(lat, 99)),
            "requests": len(reqs),
            "queue_depth": sum(
                len(ring.waiting) for ring in self.tenants.values()
            ),
            "in_flight": sum(
                sum(s.group is not None for s in ring.slots)
                for ring in self.tenants.values()
            ),
            "pod_inflight": len(self._pod_inflight),
            "pod_expired": self.pod_expired,
            "network_energy_j": self.network_energy_j,
            "interval_s": span,
        }

    def metrics_window(self, window_s: Optional[float] = None) -> Dict[str, float]:
        """Aggregate rolling-window metrics over the last ``window_s``
        seconds, across every tenant (the shared-rail view the
        single-tenant controller observes)."""
        w = window_s or self.window_s
        now = time.monotonic()
        tokens = sum(r.window_tokens(w) for r in self.tenants.values())
        span = w if self._t0 is None else min(w, now - self._t0)
        reqs = [
            r
            for ring in self.tenants.values()
            for r in ring.done
            if r.finished >= now - w
        ]
        return self._metrics(reqs, tokens, span)

    def tenant_metrics(
        self, window_s: Optional[float] = None
    ) -> Dict[str, Dict[str, float]]:
        """Per-tenant rolling-window metrics: ``{tenant: metrics}`` —
        the split the multi-tenant controller scores against per-tenant
        τ floors (``core.coral.joint_headroom``)."""
        return {
            name: ring.metrics_window(window_s)
            for name, ring in self.tenants.items()
        }

    def attribute_power(
        self, total_w: float, window_s: Optional[float] = None
    ) -> Dict[str, float]:
        """Split a shared-rail power reading across tenants in proportion
        to their windowed token throughput (equal split when the window
        is empty). The attributions sum *exactly* to ``total_w`` — the
        rail is one meter, attribution is accounting, and a lossy split
        would let per-tenant ledgers disagree with the rail."""
        names = list(self.tenants)
        weights = np.asarray(
            [self.tenants[n].window_tokens(window_s) for n in names],
            np.float64,
        )
        if weights.sum() <= 0:
            weights = np.ones(len(names))
        shares = total_w * weights / weights.sum()
        # pin the float ledger: the last tenant absorbs rounding residue
        shares[-1] = total_w - float(shares[:-1].sum())
        return {n: float(s) for n, s in zip(names, shares)}

    # ------------------------------------------------------------------
    # serving loops
    # ------------------------------------------------------------------
    def _busy(self) -> bool:
        return any(
            ring.waiting
            or any(s.group is not None for s in ring.slots)
            for ring in self.tenants.values()
        )

    def run_for(self, seconds: float, idle_wait: bool = False) -> Dict[str, float]:
        """Serve one control interval; returns aggregate metrics for what
        completed inside it (``tenant_metrics`` for the per-ring split).
        With ``idle_wait`` the runtime sits out traffic gaps (closed-loop
        control under a trace); without it, an empty pool ends the
        interval early (metrics use the actual elapsed span)."""
        self.start_clock()
        t0 = time.monotonic()
        tok0 = {n: r._tokens_total for n, r in self.tenants.items()}
        done0 = {n: len(r.done) for n, r in self.tenants.items()}
        while time.monotonic() - t0 < seconds:
            if not self.step():
                if not idle_wait and not self._busy() and not self._pod_inflight:
                    break
                time.sleep(5e-4)
        span = time.monotonic() - t0
        new = [
            r
            for n, ring in self.tenants.items()
            for r in ring.done[done0[n]:]
        ]
        tokens = sum(
            r._tokens_total - tok0[n] for n, r in self.tenants.items()
        )
        return self._metrics(new, tokens, span)

    def drain(self, timeout_s: float = 300.0) -> Dict[str, float]:
        """Serve until every submitted request — every tenant's —
        completes (or ``timeout_s`` elapses; a leftover ``queue_depth``
        marks an incomplete drain); aggregate metrics (the old
        ``Scheduler.run`` contract)."""
        self.start_clock()
        t0 = time.monotonic()
        tok0 = {n: r._tokens_total for n, r in self.tenants.items()}
        done0 = {n: len(r.done) for n, r in self.tenants.items()}
        while self._busy() or self._pod_inflight:
            if time.monotonic() - t0 > timeout_s:
                break
            if not self.step():
                time.sleep(5e-4)
        span = time.monotonic() - t0
        new = [
            r
            for n, ring in self.tenants.items()
            for r in ring.done[done0[n]:]
        ]
        tokens = sum(
            r._tokens_total - tok0[n] for n, r in self.tenants.items()
        )
        return self._metrics(new, tokens, span)


def measure_runtime_throughput(
    engine,
    concurrency: int,
    prompt_len: int = 16,
    new_tokens: int = 16,
    groups: int = 4,
    batch_size: Optional[int] = None,
    vocab: int = 512,
    seed: int = 0,
    warmup: bool = True,
) -> float:
    """Measured decode tokens/sec of the runtime at a given concurrency.

    Serves a fixed saturating workload (``groups`` full batches submitted
    up front, no arrival gaps) and reports drain throughput — the probe
    behind ``WalltimeDevice`` and the τ-vs-concurrency benchmark. Pass the
    same ``groups`` (≥ the largest concurrency to be compared, ideally 2×)
    at every concurrency level so the knob is the only variable."""
    rng = np.random.default_rng(seed)
    if warmup:
        # compile prefill/decode for this (batch, prompt_len) outside the
        # timed drain — otherwise the first probed level caches a
        # several-fold-understated rate and can invert the c→τ signal
        wrt = ServingRuntime(engine, batch_size=batch_size, concurrency=1)
        for rid in range(wrt.batch):
            wrt.submit(
                Request(
                    -1 - rid, rng.integers(0, vocab, prompt_len, dtype=np.int32), 2
                )
            )
        wrt.drain()
    runtime = ServingRuntime(engine, batch_size=batch_size, concurrency=concurrency)
    for rid in range(groups * runtime.batch):
        runtime.submit(
            Request(
                rid,
                rng.integers(0, vocab, prompt_len, dtype=np.int32),
                new_tokens,
            )
        )
    return runtime.drain()["throughput_tok_s"]


def measure_concurrency_curve(
    engine,
    c_values,
    rounds: int = 4,
    min_rounds: int = 2,
    gain_gate: float = 1.2,
    prompt_len: int = 8,
    new_tokens: int = 16,
    groups: int = 10,
    batch_size: Optional[int] = None,
    vocab: int = 512,
    seed: int = 0,
) -> Tuple[Dict[int, float], int]:
    """Best-of interleaved τ-vs-concurrency sweep over ``c_values``
    (ascending, starting at the baseline level, normally 1).

    One shared protocol for the benchmark, the example and the
    sensitivity test: on shared hosts neighbour interference only ever
    slows a run down, so the per-level running max converges to the
    level's capability, and rounds are interleaved so drift hits every
    level equally. Stops early (after ``min_rounds``) once the knee is
    visible — the second level above the first and some c past
    ``gain_gate``× the baseline. Returns ({c: best tok/s}, rounds used).
    """
    c_values = [int(c) for c in c_values]
    best = {c: 0.0 for c in c_values}
    used = 0
    warm = True
    for used in range(1, max(rounds, min_rounds) + 1):
        for c in c_values:
            best[c] = max(
                best[c],
                measure_runtime_throughput(
                    engine,
                    c,
                    prompt_len=prompt_len,
                    new_tokens=new_tokens,
                    groups=groups,
                    batch_size=batch_size,
                    vocab=vocab,
                    seed=seed,
                    warmup=warm,
                ),
            )
            warm = False  # shapes compiled by the first probe's warmup
        base = best[c_values[0]]
        if (
            used >= min_rounds
            and len(c_values) > 1
            and best[c_values[1]] > base
            and max(best[c] for c in c_values[1:]) >= gain_gate * base
        ):
            break
    return best, used
