from repro.serving.engine import ServingEngine, make_prefill_step, make_serve_step  # noqa: F401
from repro.serving.scheduler import Request, Scheduler  # noqa: F401
