from repro.serving.engine import (  # noqa: F401
    ServingEngine,
    make_prefill_step,
    make_serve_step,
)
from repro.serving.runtime import (  # noqa: F401
    Request,
    ServingRuntime,
    measure_concurrency_curve,
    measure_runtime_throughput,
)
from repro.serving.controller import (  # noqa: F401
    IntervalRecord,
    ServingController,
    build_serving_record,
)
from repro.serving import workload  # noqa: F401
