"""Pod power model.

Structure mirrors what Jetson exposes via tegrastats, scaled to a pod:
  chip:   P_idle + P_dyn·(f/f0)³·util + P_hbm·(m/m0)·mem_bound·util
  host:   P_idle + cores·P_core·(f_cpu/f0)²
Dynamic power ∝ f³ (DVFS: P ∝ f·V², V ∝ f) is the classic non-linearity
that makes "same throughput, 2× power" configurations possible (Fig. 1).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.device.hw import DEFAULT_HW, TPUv5eSpec
from repro.device.perfmodel import PerfModel


@dataclasses.dataclass(frozen=True)
class PowerModel:
    perf: PerfModel
    hw: TPUv5eSpec = DEFAULT_HW

    def power(self, config: dict) -> float:
        """Total pod power (W) for a knob dict."""
        hw = self.hw
        n = self.perf.terms.n_chips
        util = self.perf.utilization(config)
        f_rel = config["tpu_freq"] / hw.nominal_tpu_freq
        m_rel = config["hbm_freq"] / hw.nominal_hbm_freq
        mem_frac = self.perf.memory_boundedness(config)
        p_chip = (
            hw.p_idle_chip
            + hw.p_dyn_chip * (f_rel**3) * util
            + hw.p_hbm_chip * m_rel * mem_frac * util
        )
        n_hosts = max(n // hw.chips_per_host, 1)
        c_rel = config["host_cpu_freq"] / hw.nominal_host_freq
        p_host = hw.p_host_idle + config["host_cores"] * hw.p_host_core * c_rel**2
        return n * p_chip + n_hosts * p_host

    def power_batch(
        self,
        cols: Dict[str, np.ndarray],
        util: np.ndarray = None,
        mem_frac: np.ndarray = None,
    ) -> np.ndarray:
        """Batched twin of ``power``: canonical knob columns (N,) → (N,).
        ``util``/``mem_frac`` can be passed from a prior ``stats_batch``
        call to avoid recomputing the pipeline terms."""
        hw = self.hw
        n = self.perf.terms.n_chips
        if util is None or mem_frac is None:
            _, util, mem_frac = self.perf.stats_batch(cols)
        f_rel = cols["tpu_freq"] / hw.nominal_tpu_freq
        m_rel = cols["hbm_freq"] / hw.nominal_hbm_freq
        p_chip = (
            hw.p_idle_chip
            + hw.p_dyn_chip * (f_rel**3) * util
            + hw.p_hbm_chip * m_rel * mem_frac * util
        )
        n_hosts = max(n // hw.chips_per_host, 1)
        c_rel = cols["host_cpu_freq"] / hw.nominal_host_freq
        p_host = hw.p_host_idle + cols["host_cores"] * hw.p_host_core * c_rel**2
        return n * p_chip + n_hosts * p_host
