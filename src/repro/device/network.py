"""Edge↔pod network link model and the joint offload device twin.

The offload scenario (arxiv 2504.14611's joint offloading/batching/DVFS
setting mapped onto this repo's registry) gives CORAL a *placement* knob
on top of DVFS: a fraction ``offload_frac`` of admitted requests is
shipped over a radio link to the shared ``pod-v5e`` profile instead of
running on the local edge silicon. The pieces:

  ``NetworkProfile``   — the static link: uplink bandwidth, round-trip
      latency, radio energy per shipped byte, bytes shipped per item,
      the in-flight window, and the edge's fair-share divisor of the
      pod slice.
  ``NetworkSchedule``  — link degradation over the control-interval
      clock (bandwidth drops, RTT inflation ramps), the same declarative
      event shape as ``repro.device.hw.DriftSchedule``.
  ``OffloadSimulator`` — the measurable twin over the joint
      ``offload_space`` grid. It implements the exact
      ``exact``/``measure``/``exact_all``/``measure_all`` protocol of
      ``DeviceSimulator`` (sequential τ-then-p noise draws; (N, 2)
      config-major noise blocks), so ORACLE, ALERT-style profiling, the
      scalar CORAL loop and the compiled episode engine all run on it
      unchanged.

Throughput model (items/s, float64 throughout): a route split φ sends
φ of the admitted stream to the pod and 1−φ to the edge. The system is
a two-path capacity network —

    edge path  : τ_edge(gpu_freq, mem_freq, concurrency) / (1 − φ)
    pod path   : min(bandwidth/ship_bytes,                 (uplink)
                     max_inflight / (rtt + tenants/τ_pod), (window)
                     τ_pod(pod_tpu_freq) / tenants) / φ    (slice)
    served τ   = min(edge path, pod path, demand λ)

so φ=0 degenerates to the plain edge twin and a demand λ far above the
edge's best τ makes every φ=0 row SLO-infeasible — the regime the
offload scenario cells are built around. The measured power channel is
the *edge device rail only*: edge compute power, plus the radio
(idle hold + per-shipped-byte energy) whenever φ>0. Pod-side power
never appears on the edge rail (see tests/test_offload.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.space import ConfigSpace, Config, offload_space
from repro.device.hw import DeviceProfile, get_profile
from repro.device.perfmodel import PerfModel, model_roofline_terms
from repro.device.power import PowerModel


# ---------------------------------------------------------------------------
# The link
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NetworkProfile:
    """One edge↔pod link: bandwidth/latency/energy per shipped item.

    ``bandwidth`` is the sustained uplink in bytes/s and ``ship_bytes``
    the bytes shipped per offloaded item (tokens + context), so
    ``bandwidth / ship_bytes`` is the uplink item rate. ``max_inflight``
    is the transport window in items; with a pod-slice service time of
    ``pod_tenants / τ_pod`` the window caps the rate at
    ``max_inflight / (rtt_s + pod_tenants/τ_pod)`` — which is what makes
    pod DVFS visible from the edge. ``energy_per_byte`` and
    ``radio_idle_w`` are the radio's per-shipped-byte and hold-active
    draws on the edge power rail."""

    name: str
    bandwidth: float  # B/s uplink
    rtt_s: float  # round-trip latency, seconds
    energy_per_byte: float  # J/B on the edge radio
    radio_idle_w: float  # W while the link is held active (φ > 0)
    ship_bytes: float  # B shipped per offloaded item
    max_inflight: float  # transport window, items
    slice_chips: int  # pod chips provisioned behind the tenant slice
    pod_tenants: float  # edge tenants sharing the provisioned slice
    token_bytes: float = 1e3  # B shipped per token at the serving layer

    @property
    def ship_energy_j(self) -> float:
        """Radio energy per shipped item (J)."""
        return self.energy_per_byte * self.ship_bytes

    @property
    def ship_energy_per_token_j(self) -> float:
        """Radio energy per shipped token (J) — the serving runtime's
        per-token metering unit (``ServingRuntime.network_energy_j``)."""
        return self.energy_per_byte * self.token_bytes

    @property
    def uplink_items_s(self) -> float:
        """Bandwidth-bound item rate of the uplink."""
        return self.bandwidth / self.ship_bytes


# Link registry, one entry per deployment class. Magnitudes are
# LTE/fiber-class: a shipped item carries its context/frame (~256 KB),
# radio energy per byte is the cellular-uplink figure scaled to a
# modem+RF chain that is not the dominant board rail, and each edge
# tenant gets a 2-chip provisioned slice of the pod shared ~14 ways.
NETWORKS: Dict[str, NetworkProfile] = {
    n.name: n
    for n in (
        NetworkProfile(
            name="lte-uplink",
            bandwidth=40e6,  # 40 MB/s class uplink
            rtt_s=0.045,
            energy_per_byte=0.15e-6,
            radio_idle_w=1.2,
            ship_bytes=256e3,
            max_inflight=24.0,
            slice_chips=2,
            pod_tenants=14.0,
        ),
        NetworkProfile(
            name="fiber-metro",
            bandwidth=120e6,
            rtt_s=0.018,
            energy_per_byte=0.05e-6,
            radio_idle_w=0.8,
            ship_bytes=256e3,
            max_inflight=32.0,
            slice_chips=2,
            pod_tenants=14.0,
        ),
    )
}


def get_network(name: str) -> NetworkProfile:
    """Look up a network profile by registry name (KeyError lists the
    known names)."""
    if name not in NETWORKS:
        raise KeyError(f"unknown network profile {name!r}; known: {sorted(NETWORKS)}")
    return NETWORKS[name]


# ---------------------------------------------------------------------------
# Link degradation: the drift-event shape on the network
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NetworkState:
    """The link's operating condition at one control interval.

    ``bw_scale`` multiplies the deliverable bandwidth (congestion,
    fading); ``rtt_inflation`` adds that fraction of the nominal RTT
    (queueing delay, jitter). Mirrors ``repro.device.hw.DriftState``."""

    bw_scale: float = 1.0
    rtt_inflation: float = 0.0

    @property
    def stationary(self) -> bool:
        return self == NET_NOMINAL


NET_NOMINAL = NetworkState()


@dataclasses.dataclass(frozen=True)
class LinkDegrade:
    """A congestion step: bandwidth drops to ``bw_scale``× and RTT
    inflates at ``start`` (recovering at ``until`` if set)."""

    start: int
    bw_scale: float = 0.5
    rtt_inflation: float = 0.5
    until: Optional[int] = None

    def state_at(self, t: int) -> NetworkState:
        active = t >= self.start and (self.until is None or t < self.until)
        if not active:
            return NET_NOMINAL
        return NetworkState(bw_scale=self.bw_scale, rtt_inflation=self.rtt_inflation)

    @property
    def end(self) -> int:
        return self.start


@dataclasses.dataclass(frozen=True)
class RttRamp:
    """Queueing delay builds linearly over ``duration`` intervals from
    ``start`` and then holds — the link analogue of ``ThermalRamp``."""

    start: int
    duration: int = 6
    rtt_inflation: float = 1.0

    def state_at(self, t: int) -> NetworkState:
        ramp = min(max((t - self.start) / max(self.duration, 1), 0.0), 1.0)
        return NetworkState(rtt_inflation=ramp * self.rtt_inflation)

    @property
    def end(self) -> int:
        return self.start + self.duration


NetworkEvent = object  # LinkDegrade | RttRamp


@dataclasses.dataclass(frozen=True)
class NetworkSchedule:
    """Link-degradation events composed over the control-interval clock:
    ``bw_scale`` factors multiply (floored at 0.05), ``rtt_inflation``
    terms sum — the composition rules of ``DriftSchedule``."""

    events: Tuple[NetworkEvent, ...] = ()

    def state_at(self, t: int) -> NetworkState:
        bw, rtt = 1.0, 0.0
        for ev in self.events:
            s = ev.state_at(t)
            bw *= s.bw_scale
            rtt += s.rtt_inflation
        return NetworkState(bw_scale=max(bw, 0.05), rtt_inflation=rtt)

    @property
    def shift_start(self) -> int:
        return min((ev.start for ev in self.events), default=0)

    @property
    def shift_end(self) -> int:
        return max((ev.end for ev in self.events), default=0)

    def states_stacked(self, intervals: int) -> Dict[str, np.ndarray]:
        """(intervals,) float64 arrays of every ``NetworkState`` field."""
        states = [self.state_at(t) for t in range(intervals)]
        return {
            f.name: np.asarray([getattr(s, f.name) for s in states], np.float64)
            for f in dataclasses.fields(NetworkState)
        }


NO_DEGRADATION = NetworkSchedule(())


# ---------------------------------------------------------------------------
# The joint offload twin
# ---------------------------------------------------------------------------


class OffloadSimulator:
    """Measurable twin over the joint edge↔pod ``offload_space`` grid.

    Evaluates the two-path capacity model in the module docstring for
    (N, 5) config matrices over the dims (gpu_freq, mem_freq,
    concurrency, offload_frac, pod_tpu_freq). Dims the joint space does
    not expose (edge CPU knobs, pod HBM/host/concurrency) are pinned at
    their nominal operating points so the edge and pod ``PerfModel``s
    evaluate on full canonical columns.

    ``demand`` is the offered arrival rate λ (items/s): served τ
    saturates at it, and ``float('inf')`` (the default) reads the raw
    path capacity — which is how ``edge_only_max`` calibrates λ before
    the scenario pins it. The measurement protocol is byte-compatible
    with ``DeviceSimulator``: ``measure`` draws τ then p noise from the
    same ``default_rng(seed)`` stream, ``measure_all`` draws the (N, 2)
    block config-major, so the compiled episode engine's replayed noise
    matches the scalar loop's exactly.
    """

    def __init__(
        self,
        edge_profile: DeviceProfile,
        model_cfg,
        network: NetworkProfile,
        pod_profile: Optional[DeviceProfile] = None,
        kind: str = "decode",
        batch: int = 8,
        seq: int = 256,
        noise: float = 0.02,
        seed: int = 0,
        demand: float = float("inf"),
        schedule: NetworkSchedule = NO_DEGRADATION,
    ):
        pod_profile = pod_profile or get_profile("pod-v5e")
        self.space: ConfigSpace = offload_space(edge_profile.space_kind)
        self.network = network
        self.demand = float(demand)
        self.noise = noise
        self.rng = np.random.default_rng(seed)
        self.n_measurements = 0
        self.schedule = schedule
        self._state = schedule.state_at(0)

        edge_terms = model_roofline_terms(
            model_cfg, edge_profile, kind=kind, batch=batch, seq=seq
        )
        self.edge_perf = PerfModel(
            edge_terms, edge_profile.hw, edge_profile.contention_kappa
        )
        self.edge_power = PowerModel(self.edge_perf, edge_profile.hw)
        # The tenant slice is provisioned as a few dedicated pod chips —
        # device-bound at that scale, so the pod power-state ladder
        # (which scales core and HBM clocks together, see offload_cap)
        # genuinely moves the slice's throughput.
        slice_profile = dataclasses.replace(
            pod_profile, n_chips=network.slice_chips
        )
        pod_terms = model_roofline_terms(
            model_cfg, slice_profile, kind=kind, batch=batch, seq=seq
        )
        self.pod_perf = PerfModel(
            pod_terms, pod_profile.hw, pod_profile.contention_kappa
        )
        # pinned operating points for dims absent from the joint space
        self._edge_fixed = {
            "host_cpu_freq": edge_profile.hw.nominal_host_freq,
            "host_cores": 6.0,
        }
        self._pod_fixed = {
            "hbm_freq": pod_profile.hw.nominal_hbm_freq,
            "host_cpu_freq": pod_profile.hw.nominal_host_freq,
            "host_cores": 6.0,
            "concurrency": 4.0,
        }

    # -------------------------------------------------------------- clock
    def set_time(self, t: int) -> None:
        """Advance the link-degradation clock (no-op without events)."""
        self._state = self.schedule.state_at(int(t))

    @property
    def state(self) -> NetworkState:
        return self._state

    # ----------------------------------------------------------- evaluate
    def _columns(self, grid: np.ndarray) -> Dict[str, np.ndarray]:
        return {n: grid[:, i] for i, n in enumerate(self.space.names)}

    def offload_cap(self, pod_freq: np.ndarray) -> np.ndarray:
        """Item rate the pod path can carry (N,): the min of the uplink,
        the transport window over the effective round trip, and the
        edge's fair share of the provisioned pod slice. The pod
        power-state ladder scales core and HBM clocks together (coupled
        DVFS domains), so ``pod_tpu_freq`` moves the slice rate even for
        memory-bound decode."""
        net, state = self.network, self._state
        freq = np.asarray(pod_freq, np.float64)
        pod_cols = {k: np.full_like(freq, v) for k, v in self._pod_fixed.items()}
        pod_cols["tpu_freq"] = freq
        pod_cols["hbm_freq"] = self._pod_fixed["hbm_freq"] * (
            freq / self.pod_perf.hw.nominal_tpu_freq
        )
        tau_pod = self.pod_perf.stats_batch(pod_cols)[0]
        slice_rate = tau_pod / net.pod_tenants
        rtt = net.rtt_s * (1.0 + state.rtt_inflation)
        window_rate = net.max_inflight / (rtt + 1.0 / np.maximum(slice_rate, 1e-12))
        uplink_rate = net.uplink_items_s * state.bw_scale
        return np.minimum(np.minimum(uplink_rate, window_rate), slice_rate)

    def capacity_all(
        self, configs: Optional[np.ndarray] = None
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Noise-free (capacity, edge-rail power) over an (N, 5) config
        matrix, *before* the demand saturation — ``exact_all`` is
        ``min(capacity, demand)`` on the τ channel."""
        if configs is None:
            configs = self.space.grid()
        grid = np.asarray(configs, np.float64)
        g = self._columns(grid)
        edge_cols = {
            "tpu_freq": g["gpu_freq"],
            "hbm_freq": g["mem_freq"],
            "concurrency": g["concurrency"],
            "host_cpu_freq": np.full(grid.shape[0], self._edge_fixed["host_cpu_freq"]),
            "host_cores": np.full(grid.shape[0], self._edge_fixed["host_cores"]),
        }
        tau_edge, util, mem_frac = self.edge_perf.stats_batch(edge_cols)
        p_edge = self.edge_power.power_batch(edge_cols, util, mem_frac)

        phi = g["offload_frac"]
        off_cap = self.offload_cap(g["pod_tpu_freq"])
        with np.errstate(divide="ignore"):
            edge_rate = np.where(phi < 1.0, tau_edge / np.maximum(1.0 - phi, 1e-12), np.inf)
            off_rate = np.where(phi > 0.0, off_cap / np.maximum(phi, 1e-12), np.inf)
        capacity = np.minimum(edge_rate, off_rate)
        return capacity, p_edge

    def exact_all(
        self, configs: Optional[np.ndarray] = None
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Noise-free served (τ, edge-rail p) for an (N, 5) config matrix
        (defaults to the full ``space.grid()``). τ saturates at the
        offered demand; the power channel adds the radio hold + the
        per-shipped-item energy of what actually ships."""
        if configs is None:
            configs = self.space.grid()
        grid = np.asarray(configs, np.float64)
        capacity, p_edge = self.capacity_all(grid)
        phi = self._columns(grid)["offload_frac"]
        tau = np.minimum(capacity, self.demand)
        shipped = phi * tau  # items/s actually routed to the pod
        net = self.network
        p = p_edge + np.where(
            phi > 0.0, net.radio_idle_w + net.ship_energy_j * shipped, 0.0
        )
        return tau, p

    def edge_only_max(self) -> float:
        """Best served τ over the φ=0 rows at unlimited demand — the
        un-offloaded edge capacity the scenario scales λ against."""
        grid = self.space.grid()
        phi = self._columns(grid)["offload_frac"]
        cap, _ = self.capacity_all(grid)
        return float(cap[phi == 0.0].max())

    def exact(self, config: Config) -> Tuple[float, float]:
        tau, p = self.exact_all(np.asarray([config], np.float64))
        return float(tau[0]), float(p[0])

    def measure(self, config: Config) -> Tuple[float, float]:
        tau, p = self.exact(config)
        self.n_measurements += 1
        if self.noise:
            tau *= 1.0 + self.rng.normal(0.0, self.noise)
            p *= 1.0 + self.rng.normal(0.0, self.noise)
        return max(tau, 1e-9), max(p, 1e-9)

    def measure_all(
        self, configs: Optional[np.ndarray] = None
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Noisy batched measurement — the (N, 2) config-major noise
        block of ``DeviceSimulator.measure_all``."""
        if configs is None:
            configs = self.space.grid()
        tau, p = self.exact_all(configs)
        self.n_measurements += tau.size
        if self.noise:
            z = self.rng.normal(0.0, self.noise, size=(tau.size, 2))
            tau = tau * (1.0 + z[:, 0])
            p = p * (1.0 + z[:, 1])
        return np.maximum(tau, 1e-9), np.maximum(p, 1e-9)
