"""``build_twin``: one constructor for every device-twin flavor.

The scenario matrix used to hand-assemble a simulator per regime family
(``cell_simulator`` / ``drifting_cell_simulator`` /
``offload_cell_simulator`` / ``cotenant_cell_simulator``). This factory
folds that dispatch into ``device``: a ``Cell``'s regime name alone
decides which twin is built —

  stationary regime  → ``DeviceSimulator``         (single-model edge)
  drift regime       → ``DriftingSimulator``       (non-stationary wrap)
  offload regime     → ``OffloadSimulator``        (edge↔pod joint grid)
  cotenant regime    → ``CotenantSimulator``       (multi-tenant rail)
  fault regime       → ``FaultySimulator``         (fault-injected wrap)

Every twin honors the same measurement surface and the exact-RNG noise
protocol (``core.contracts`` §TWIN_RNG_PROTOCOL): ``measure`` /
``measure_all`` / ``exact`` / ``exact_all`` over a ``.space`` grid, with
seeded multiplicative noise replayable by the compiled episode engine.

Imports from ``repro.experiments.scenarios`` are deliberately lazy: the
regime tables live in experiments (they are calibration data, not device
physics), and ``device`` must stay importable without them.
"""
from __future__ import annotations

from typing import Optional


def build_twin(cell, noise: Optional[float] = None, seed: int = 0):
    """Build the device twin a cell's regime calls for.

    ``noise=None`` takes the cell's workload-trace noise (the noisy
    device the optimizer sees); ``noise=0.0`` is the ground-truth twin
    scoring and oracles use. Raises ``KeyError`` on an unknown regime.
    """
    from repro.experiments import scenarios as sc

    if cell.regime in sc.COTENANT_REGIMES:
        return sc.cotenant_cell_simulator(cell, noise=noise, seed=seed)
    if cell.regime in sc.OFFLOAD_REGIMES:
        return sc.offload_cell_simulator(cell, noise=noise, seed=seed)
    if cell.regime in sc.FAULT_REGIMES:
        return sc.fault_cell_simulator(cell, noise=noise, seed=seed)
    regime = sc.REGIMES[cell.regime]
    if regime.dynamic:
        return sc.drifting_cell_simulator(cell, noise=noise, seed=seed)
    return sc.cell_simulator(cell, noise=noise, seed=seed)
