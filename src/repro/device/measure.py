"""WalltimeDevice: CORAL against *measured* throughput.

Runs a reduced model's decode loop on the actual host (jitted XLA, real
wall-clock tokens/sec) instead of the analytical simulator. The base rate
*and the concurrency effect* are measured — each concurrency level is
probed once through the continuous-batching runtime
(``repro.serving.runtime``) and cached, so the knob's τ response is the
real pipelining behaviour of this host, not a modeled utilization curve.
Clock knobs still modulate the measured rate analytically (this container
has no DVFS control or power rail; the power model is analytical too).
Used by examples/tune_serving.py, the serving controller and integration
tests.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.core.space import Config, ConfigSpace
from repro.device.hw import DEFAULT_HW, TPUv5eSpec
from repro.device.perfmodel import canon
from repro.device.power import PowerModel
from repro.device.perfmodel import PerfModel, RooflineTerms


def analytic_scale_and_power(
    names, config: Config, hw: TPUv5eSpec = DEFAULT_HW
) -> Tuple[float, float]:
    """(device-rate scale, analytical power) for a config on this host.

    The scale is the relative decode-rate multiplier of the DVFS knobs
    (min of the compute and memory rooflines); power reuses the analytical
    pod model at n_chips=1. Shared between WalltimeDevice and the serving
    controller so both halves of the measured/analytical split agree.
    """
    d = canon(dict(zip(names, config)))
    f_rel = d["tpu_freq"] / hw.nominal_tpu_freq
    m_rel = d["hbm_freq"] / hw.nominal_hbm_freq
    dev_rel = min(f_rel, m_rel * 1.25)
    terms = RooflineTerms(1e-3 / max(f_rel, 1e-3), 8e-4 / max(m_rel, 1e-3),
                          0.0, 1e-3, 1.0, n_chips=1)
    pm = PowerModel(PerfModel(terms, hw), hw)
    return dev_rel, pm.power(d)


class WalltimeDevice:
    def __init__(
        self,
        space: ConfigSpace,
        engine,  # repro.serving.ServingEngine over a reduced model
        prompt_len: int = 32,
        steps: int = 8,
        hw: TPUv5eSpec = DEFAULT_HW,
        seed: int = 0,
        groups: int = 0,  # saturating groups per probe; 0 = auto from space
    ):
        self.space = space
        self.engine = engine
        self.prompt_len = prompt_len
        self.steps = steps
        self.hw = hw
        self.rng = np.random.default_rng(seed)
        self.n_measurements = 0
        self._c_index = space.index("concurrency")
        c_max = int(space.dims[self._c_index].hi)
        self.groups = groups or max(4, 2 * c_max)
        self._rate_cache: Dict[int, float] = {}

    def _measured_rate(self, concurrency: int) -> float:
        """Drain throughput of the runtime at this concurrency (measured
        once per level; decode rate is stable within a process)."""
        c = max(1, int(concurrency))
        if c not in self._rate_cache:
            from repro.serving.runtime import measure_runtime_throughput

            self._rate_cache[c] = measure_runtime_throughput(
                self.engine,
                concurrency=c,
                prompt_len=self.prompt_len,
                new_tokens=self.steps,
                groups=self.groups,
            )
        return self._rate_cache[c]

    def exact(self, config: Config) -> Tuple[float, float]:
        base = self._measured_rate(config[self._c_index])
        dev_rel, power = analytic_scale_and_power(self.space.names, config, self.hw)
        return base * dev_rel, power

    def measure(self, config: Config) -> Tuple[float, float]:
        self.n_measurements += 1
        tau, p = self.exact(config)
        # symmetric noise on both channels; clamp like DeviceSimulator so a
        # noise tail can never emit τ ≤ 0 (which would flip the reward
        # penalty's sign) or negative power
        tau *= 1.0 + self.rng.normal(0, 0.01)
        p *= 1.0 + self.rng.normal(0, 0.01)
        return max(tau, 1e-9), max(p, 1e-9)
