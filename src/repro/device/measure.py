"""WalltimeDevice: CORAL against *measured* throughput.

Runs a reduced model's decode loop on the actual host (jitted XLA, real
wall-clock tokens/sec) instead of the analytical simulator. Clock knobs
modulate the measured base rate (this container has no DVFS control or
power rail — the scaling and the power model are analytical, the base
throughput and the concurrency/batching effects are real). Used by
examples/tune_serving.py and integration tests.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.core.space import Config, ConfigSpace
from repro.device.hw import DEFAULT_HW, TPUv5eSpec
from repro.device.perfmodel import canon
from repro.device.power import PowerModel
from repro.device.perfmodel import PerfModel, RooflineTerms


class WalltimeDevice:
    def __init__(
        self,
        space: ConfigSpace,
        engine,  # repro.serving.ServingEngine over a reduced model
        prompt_len: int = 32,
        steps: int = 8,
        hw: TPUv5eSpec = DEFAULT_HW,
        seed: int = 0,
    ):
        self.space = space
        self.engine = engine
        self.prompt_len = prompt_len
        self.steps = steps
        self.hw = hw
        self.rng = np.random.default_rng(seed)
        self.n_measurements = 0
        self._base_rate = None  # measured once; decode rate is stable

    def _measure_base(self) -> float:
        if self._base_rate is None:
            self._base_rate = self.engine.measure_decode_throughput(
                self.prompt_len, self.steps
            )
        return self._base_rate

    def exact(self, config: Config) -> Tuple[float, float]:
        d = canon(dict(zip(self.space.names, config)))
        base = self._measure_base()
        # clock scaling is analytical (no DVFS control in this container)
        f_rel = d["tpu_freq"] / self.hw.nominal_tpu_freq
        m_rel = d["hbm_freq"] / self.hw.nominal_hbm_freq
        c = d["concurrency"]
        dev_rel = min(f_rel, m_rel * 1.25)
        util = min(c * 0.45, 1.0)
        tau = base * dev_rel * (0.55 + 0.45 * util)
        # power: reuse the analytical pod model at n_chips=1 scale
        terms = RooflineTerms(1e-3 / max(f_rel, 1e-3), 8e-4 / max(m_rel, 1e-3),
                              0.0, 1e-3, 1.0, n_chips=1)
        pm = PowerModel(PerfModel(terms, self.hw), self.hw)
        return tau, pm.power(d)

    def measure(self, config: Config) -> Tuple[float, float]:
        self.n_measurements += 1
        tau, p = self.exact(config)
        return tau * (1 + self.rng.normal(0, 0.01)), p
