"""Analytical throughput model of a TPU pod under DVFS + concurrency.

The base times come from the *compiled dry-run* of the selected
(arch × shape × mesh): compute seconds, memory seconds and collective
seconds at nominal clocks (EXPERIMENTS.md §Roofline). The knobs rescale
them:

    t_comp(f)   = t_comp0 · f0/f          (MXU clock)
    t_mem(m)    = t_mem0  · m0/m          (HBM clock)
    t_coll      = t_coll0                 (ICI links are not DVFS-scaled)
    device step = max(t_comp, t_mem, t_coll) · contention(c)
    host step   = t_host0 · (f_cpu0/f_cpu) · (cores0/cores)^0.7

Concurrency pipelines host work against device work (classic two-stage
pipeline): with c in-flight streams the steady-state throughput is

    τ(s) = min( c / (t_host + t_dev),  1 / t_dev_contended ) · batch_rate

which saturates once the device is busy — reproducing the non-linear
knee the paper exploits (Fig. 1). Contention grows mildly with c
(shared HBM): t_dev · (1 + κ·(c−1)).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple

import numpy as np

from repro.device.hw import DEFAULT_HW, TPUv5eSpec


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    """Per-step base times at nominal clocks (seconds) + workload meta."""

    t_compute: float
    t_memory: float
    t_collective: float
    t_host: float = 2.0e-3  # host-side dispatch/preprocess per step
    items_per_step: float = 1.0  # inferences (or sequences) per device step
    n_chips: int = 256

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)


def model_roofline_terms(
    model_cfg,
    profile,
    kind: str = "decode",
    batch: int = 8,
    seq: int = 256,
) -> RooflineTerms:
    """Per-(device, model) RooflineTerms from a model's analytic footprint.

    ``model_cfg`` is a ``repro.configs.base.ModelConfig`` (anything with
    ``flops_per_token``/``bytes_per_token``); ``profile`` a
    ``repro.device.hw.DeviceProfile``. Two workload kinds:

      decode  — one step produces ``batch`` tokens; compute scales with
                the batch, the weight stream does not → memory-bound at
                small batch (the LLM analogue of the paper's detectors).
      prefill — one step ingests ``seq`` prompt tokens for one sequence;
                compute-bound for any realistic ``seq``.

    Device work is sharded across the profile's chips; host preprocess
    scales with items per step. This is what lets the scenario matrix
    build a simulator for every (device profile × registry model) cell
    instead of the single hand-wired device the scripts used before.
    """
    hw = profile.hw
    eff_flops = hw.peak_flops_bf16 * profile.compute_eff * profile.n_chips
    eff_bw = hw.hbm_bw * profile.mem_eff * profile.n_chips
    bytes_per_step = model_cfg.bytes_per_token()
    if kind == "decode":
        flops_per_step = model_cfg.flops_per_token() * batch
        items = float(batch)
    elif kind == "prefill":
        flops_per_step = model_cfg.flops_per_token() * seq
        items = 1.0
    else:
        raise KeyError(f"unknown workload kind {kind!r}")
    return RooflineTerms(
        t_compute=flops_per_step / eff_flops,
        t_memory=bytes_per_step / eff_bw,
        t_collective=0.0 if profile.n_chips == 1 else 0.05 * flops_per_step / eff_flops,
        t_host=profile.t_host_per_item * items,
        items_per_step=items,
        n_chips=profile.n_chips,
    )


# knob-name aliases: TPU-pod space vs the paper's original Jetson grids
_ALIASES = {
    "tpu_freq": ("tpu_freq", "gpu_freq"),
    "hbm_freq": ("hbm_freq", "mem_freq"),
    "host_cpu_freq": ("host_cpu_freq", "cpu_freq"),
    "host_cores": ("host_cores", "cpu_cores"),
    "concurrency": ("concurrency",),
}


def canon(config: dict) -> dict:
    """Normalize a knob dict to canonical names (``gpu_freq`` →
    ``tpu_freq`` etc.); raises KeyError when any of the five canonical
    knobs is missing from the input."""
    out = {}
    for canon_name, names in _ALIASES.items():
        for n in names:
            if n in config:
                out[canon_name] = config[n]
                break
        else:
            raise KeyError(f"missing knob {canon_name} in {sorted(config)}")
    return out


def canon_columns(names: Sequence[str], grid: np.ndarray) -> Dict[str, np.ndarray]:
    """Split an (N, D) config matrix into canonical knob columns.

    ``names`` are the space's dimension names (either alias family); the
    result maps every canonical knob to its (N,) column — the batched
    analogue of ``canon`` for the array-based sweeps."""
    cols = {n: grid[:, i] for i, n in enumerate(names)}
    return canon(cols)


@dataclasses.dataclass(frozen=True)
class PerfModel:
    terms: RooflineTerms
    hw: TPUv5eSpec = DEFAULT_HW
    contention_kappa: float = 0.06  # HBM contention per extra stream

    def device_time(
        self, tpu_freq: float, hbm_freq: float, concurrency: float
    ) -> float:
        t_c = self.terms.t_compute * (self.hw.nominal_tpu_freq / tpu_freq)
        t_m = self.terms.t_memory * (self.hw.nominal_hbm_freq / hbm_freq)
        t_l = self.terms.t_collective
        base = max(t_c, t_m, t_l)
        return base * (1.0 + self.contention_kappa * (concurrency - 1.0))

    def host_time(self, cpu_freq: float, cores: float) -> float:
        return (
            self.terms.t_host
            * (self.hw.nominal_host_freq / cpu_freq)
            * (6.0 / cores) ** 0.7
        )

    def throughput(self, config: dict) -> float:
        """items/sec for a knob dict (see repro.core.space.tpu_pod_space)."""
        c = config["concurrency"]
        t_dev = self.device_time(config["tpu_freq"], config["hbm_freq"], c)
        t_host = self.host_time(config["host_cpu_freq"], config["host_cores"])
        rate = min(c / (t_host + t_dev), 1.0 / t_dev)
        return rate * self.terms.items_per_step

    def utilization(self, config: dict) -> float:
        c = config["concurrency"]
        t_dev = self.device_time(config["tpu_freq"], config["hbm_freq"], c)
        t_host = self.host_time(config["host_cpu_freq"], config["host_cores"])
        rate = min(c / (t_host + t_dev), 1.0 / t_dev)
        return min(rate * t_dev, 1.0)

    def memory_boundedness(self, config: dict) -> float:
        """Fraction of device time attributable to HBM streaming (for the
        HBM power term)."""
        t_c = self.terms.t_compute * (self.hw.nominal_tpu_freq / config["tpu_freq"])
        t_m = self.terms.t_memory * (self.hw.nominal_hbm_freq / config["hbm_freq"])
        return t_m / max(t_c + t_m, 1e-12)

    # ------------------------------------------------------------------
    # Batched twins: identical formulas, numpy broadcasting over (N,)
    # knob columns (see ``canon_columns``) — one sweep call instead of N
    # Python evaluations for ORACLE / ALERT / figure-level exhaustive
    # searches.
    # ------------------------------------------------------------------
    def device_time_batch(
        self, tpu_freq: np.ndarray, hbm_freq: np.ndarray, concurrency: np.ndarray
    ) -> np.ndarray:
        t_c = self.terms.t_compute * (self.hw.nominal_tpu_freq / tpu_freq)
        t_m = self.terms.t_memory * (self.hw.nominal_hbm_freq / hbm_freq)
        base = np.maximum(np.maximum(t_c, t_m), self.terms.t_collective)
        return base * (1.0 + self.contention_kappa * (concurrency - 1.0))

    def host_time_batch(self, cpu_freq: np.ndarray, cores: np.ndarray) -> np.ndarray:
        return (
            self.terms.t_host
            * (self.hw.nominal_host_freq / cpu_freq)
            * (6.0 / cores) ** 0.7
        )

    def stats_batch(
        self, cols: Dict[str, np.ndarray]
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(throughput, utilization, memory_boundedness) in one pass —
        the pipeline terms are computed once and shared (the power model
        needs util and mem_frac on top of τ)."""
        c = cols["concurrency"]
        t_dev = self.device_time_batch(cols["tpu_freq"], cols["hbm_freq"], c)
        t_host = self.host_time_batch(cols["host_cpu_freq"], cols["host_cores"])
        rate = np.minimum(c / (t_host + t_dev), 1.0 / t_dev)
        tau = rate * self.terms.items_per_step
        util = np.minimum(rate * t_dev, 1.0)
        t_c = self.terms.t_compute * (self.hw.nominal_tpu_freq / cols["tpu_freq"])
        t_m = self.terms.t_memory * (self.hw.nominal_hbm_freq / cols["hbm_freq"])
        mem_frac = t_m / np.maximum(t_c + t_m, 1e-12)
        return tau, util, mem_frac

    def throughput_batch(self, cols: Dict[str, np.ndarray]) -> np.ndarray:
        """items/sec for canonical knob columns (N,) → (N,)."""
        return self.stats_batch(cols)[0]
