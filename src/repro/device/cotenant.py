"""Multi-tenant co-inference twin: K models sharing one edge rail.

PR 4's ``CotenantStep`` models the neighbor as exogenous drift — a kappa
bump the tuner can only react to. This twin makes the neighbor a *knob*:
each tenant k is a (model, workload) pair with its own decode-slot
allocation ``slots_t<k>`` in the joint space (``core.space.cotenant_space``)
while the DVFS clocks and the power rail stay shared. Interference flows
through the existing stream-contention kappa: every tenant's device step
is stretched by the *total* number of live streams, so granting one
tenant a slot genuinely slows the other.

Per-tenant steady state at a joint config (the two-stage pipeline of
``PerfModel``, with a fair device-share bound replacing the solo device
bound):

    t_dev_k  = max(t_c_k·f0/f, t_m_k·m0/m)·(1 + κ·(s_total − 1))
    t_host_k = t_host0_k·(f_cpu0/f_cpu)·(6/6)^0.7        (cores pinned)
    rate_k   = min( s_k / (t_host_k + t_dev_k),          # pipelining
                    (s_k / s_total) · 1 / t_dev_k )      # fair share
    τ_k      = rate_k · items_k

Shared rail power is the usual chip+host curve at the shared clocks with
``util = min(Σ_k rate_k·t_dev_k, 1)`` and the memory-boundedness averaged
across tenants weighted by their device occupancy — pod-style attribution
questions (who pays for which watt) live in the serving runtime's
``attribute_power``, not here: the twin's p channel is the one rail.

The measured channel is *scalarized* so CORAL's dual mode, the batched
joint oracle and the compiled episode engine all run unchanged: the τ
channel is the joint **headroom** min_k τ_k/floor_k against the
per-tenant floors (feasible ⇔ headroom ≥ 1, so ``tau_target`` is 1.0),
and the p channel is the shared rail draw. The noise protocol is the
exact-RNG contract of ``DeviceSimulator`` (see ``core.contracts``
§TWIN_RNG_PROTOCOL): sequential τ-then-p draws in ``measure``, one
config-major (N, 2) block in ``measure_all``, 1e-9 clamps — byte-for-byte
replayable by ``core.episode``.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.coral import joint_headroom
from repro.core.space import (
    Config,
    ConfigSpace,
    TENANT_SLOT_PREFIX,
    cotenant_space,
    tenant_slot_indices,
)
from repro.device.hw import DeviceProfile
from repro.device.perfmodel import PerfModel, model_roofline_terms

# The host stage runs with every core available — the cores ladder is
# not part of the joint space (the slot knobs own the tenant axis).
_FIXED_CORES = 6.0


class CotenantSimulator:
    """K-tenant twin over the joint slots × shared-DVFS space.

    ``model_cfgs`` is one registry ModelConfig per tenant; ``kinds`` /
    ``batches`` the per-tenant workload shape (decode by default).
    ``floors`` start at 1.0 per tenant and are pinned post-construction
    by the scenario's calibration (``resolve_cotenant_targets``) — the
    same pin-after-build pattern as ``OffloadSimulator.demand``.
    """

    def __init__(
        self,
        profile: DeviceProfile,
        model_cfgs: Sequence,
        kinds: Sequence[str] = ("decode", "decode"),
        batches: Sequence[int] = (8, 8),
        seqs: Sequence[int] = (256, 256),
        noise: float = 0.02,
        seed: int = 0,
        space: Optional[ConfigSpace] = None,
    ):
        self.profile = profile
        self.hw = profile.hw
        self.space = (
            cotenant_space(profile.space_kind, n_tenants=len(model_cfgs))
            if space is None
            else space
        )
        self.perfs = tuple(
            PerfModel(
                model_roofline_terms(m, profile, kind=k, batch=b, seq=s),
                profile.hw,
                profile.contention_kappa,
            )
            for m, k, b, s in zip(model_cfgs, kinds, batches, seqs)
        )
        self.n_tenants = len(self.perfs)
        self._slot_idx = tenant_slot_indices(self.space)
        if len(self._slot_idx) != self.n_tenants:
            raise ValueError(
                f"space has {len(self._slot_idx)} {TENANT_SLOT_PREFIX}* dims "
                f"for {self.n_tenants} tenants"
            )
        self.floors: Tuple[float, ...] = (1.0,) * self.n_tenants
        self.noise = noise
        self.rng = np.random.default_rng(seed)
        self.n_measurements = 0

    # ------------------------------------------------------------------
    # Ground truth: per-tenant rates and the shared rail
    # ------------------------------------------------------------------
    def _columns(self, configs: Optional[np.ndarray]) -> dict:
        if configs is None:
            configs = self.space.grid()
        grid = np.asarray(configs, np.float64)
        return {n: grid[:, i] for i, n in enumerate(self.space.names)}

    def tenant_stats(
        self, configs: Optional[np.ndarray] = None
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Per-tenant noise-free stats at each joint config: (K, N) τ,
        (K, N) device occupancy rate_k·t_dev_k, (K, N) mem-boundedness."""
        cols = self._columns(configs)
        slots = [cols[self.space.names[i]] for i in self._slot_idx]
        total = np.sum(slots, axis=0)
        taus, busys, fracs = [], [], []
        for k, perf in enumerate(self.perfs):
            t_dev = perf.device_time_batch(
                cols["gpu_freq"], cols["mem_freq"], total
            )
            t_host = perf.host_time_batch(
                cols["cpu_freq"], np.full_like(total, _FIXED_CORES)
            )
            s_k = slots[k]
            rate = np.minimum(
                s_k / (t_host + t_dev), (s_k / total) / t_dev
            )
            taus.append(rate * perf.terms.items_per_step)
            busys.append(rate * t_dev)
            t_c = perf.terms.t_compute * (
                perf.hw.nominal_tpu_freq / cols["gpu_freq"]
            )
            t_m = perf.terms.t_memory * (
                perf.hw.nominal_hbm_freq / cols["mem_freq"]
            )
            fracs.append(t_m / np.maximum(t_c + t_m, 1e-12))
        return np.stack(taus), np.stack(busys), np.stack(fracs)

    def tenant_taus(self, configs: Optional[np.ndarray] = None) -> np.ndarray:
        """(K, N) noise-free per-tenant throughput at each joint config."""
        return self.tenant_stats(configs)[0]

    def rail_power(self, configs: Optional[np.ndarray] = None) -> np.ndarray:
        """(N,) shared-rail power: one chip+host curve at the shared
        clocks, utilization summed across tenants (capped at busy)."""
        cols = self._columns(configs)
        _, busy, fracs = self.tenant_stats(configs)
        util = np.minimum(busy.sum(axis=0), 1.0)
        occ = np.maximum(busy.sum(axis=0), 1e-12)
        mem_frac = (busy * fracs).sum(axis=0) / occ
        hw = self.hw
        n = self.perfs[0].terms.n_chips
        f_rel = cols["gpu_freq"] / hw.nominal_tpu_freq
        m_rel = cols["mem_freq"] / hw.nominal_hbm_freq
        p_chip = (
            hw.p_idle_chip
            + hw.p_dyn_chip * (f_rel**3) * util
            + hw.p_hbm_chip * m_rel * mem_frac * util
        )
        n_hosts = max(n // hw.chips_per_host, 1)
        c_rel = cols["cpu_freq"] / hw.nominal_host_freq
        p_host = hw.p_host_idle + _FIXED_CORES * hw.p_host_core * c_rel**2
        return n * p_chip + n_hosts * p_host

    def solo_max(self, k: int) -> float:
        """Tenant k's best achievable τ anywhere on the joint grid — the
        calibration anchor the scenario's τ-floor fractions scale."""
        return float(self.tenant_taus()[k].max())

    # ------------------------------------------------------------------
    # The measured channel: (joint headroom, rail power) — the exact-RNG
    # protocol of DeviceSimulator on the scalarized pair.
    # ------------------------------------------------------------------
    def exact_all(
        self, configs: Optional[np.ndarray] = None
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Noise-free (headroom, power) arrays; feasible ⇔ headroom ≥ 1."""
        taus = self.tenant_taus(configs)
        return joint_headroom(taus, self.floors), self.rail_power(configs)

    def exact(self, config: Config) -> Tuple[float, float]:
        h, p = self.exact_all(np.asarray([config], np.float64))
        return float(h[0]), float(p[0])

    def measure(self, config: Config) -> Tuple[float, float]:
        tau, p = self.exact(config)
        self.n_measurements += 1
        if self.noise:
            tau *= 1.0 + self.rng.normal(0.0, self.noise)
            p *= 1.0 + self.rng.normal(0.0, self.noise)
        return max(tau, 1e-9), max(p, 1e-9)

    def measure_all(
        self, configs: Optional[np.ndarray] = None
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Noisy batched measurement; (N, 2) config-major noise block so
        the stream matches N sequential ``measure`` calls exactly."""
        if configs is None:
            configs = self.space.grid()
        tau, p = self.exact_all(configs)
        self.n_measurements += tau.size
        if self.noise:
            z = self.rng.normal(0.0, self.noise, size=(tau.size, 2))
            tau = tau * (1.0 + z[:, 0])
            p = p * (1.0 + z[:, 1])
        return np.maximum(tau, 1e-9), np.maximum(p, 1e-9)
